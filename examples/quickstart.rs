//! Quickstart: the public API in one file.
//!
//! 1. Generate a synthetic image and score its tokens with the energy
//!    function (Eq. 4).
//! 2. Run one PiToMe merge step and inspect protection.
//! 3. Run the full CPU reference ViT with and without merging and compare
//!    predictions + FLOPs.
//! 4. Serve repeated requests through the owning `Engine`/`Session` API
//!    (the zero-allocation steady-state path).
//!
//! Run: `cargo run --release --example quickstart`
//! (needs `make artifacts` for the trained weights).

use pitome::config::ViTConfig;
use pitome::data::{patchify, shape_item, Rng, TEST_SEED};
use pitome::engine::Engine;
use pitome::merge::{energy_scores, merge_step, MergeCtx, MergeMode};
use pitome::model::{flops, load_model_params, ViTModel};
use pitome::runtime::Registry;

fn main() -> anyhow::Result<()> {
    // --- 1. tokens + energy ------------------------------------------------
    let item = shape_item(TEST_SEED, 42);
    println!("image 42: label={} ({})", item.label,
             pitome::data::shapes::SHAPE_NAMES[item.label]);
    let patches = patchify(&item.image, 4);
    let energy = energy_scores(&patches, 0.45);
    let mean_e: f32 = energy.iter().sum::<f32>() / energy.len() as f32;
    println!("token energy: mean {mean_e:.3}, max {:.3}, min {:.3}",
             energy.iter().cloned().fold(f32::MIN, f32::max),
             energy.iter().cloned().fold(f32::MAX, f32::min));

    // --- 2. one merge step -------------------------------------------------
    let sizes = vec![1.0; patches.rows];
    let attn = vec![0.0; patches.rows];
    let ctx = MergeCtx {
        x: &patches, kf: &patches, sizes: &sizes, attn_cls: &attn,
        margin: 0.45, k: 16, protect_first: 0,
        tofu_threshold: pitome::config::DEFAULT_TOFU_PRUNE_THRESHOLD,
    };
    let mut rng = Rng::new(1);
    let (merged, new_sizes) = merge_step(MergeMode::PiToMe, &ctx, &mut rng);
    println!("one PiToMe step: {} -> {} tokens (mass {:.1} conserved)",
             patches.rows, merged.rows, new_sizes.iter().sum::<f32>());

    // --- 3. full model, merged vs unmerged ----------------------------------
    let dir = Registry::default_dir();
    let ps = match load_model_params(&dir, "vit") {
        Ok(ps) => ps,
        Err(e) => {
            println!("(skipping model demo — run `make artifacts` first: {e})");
            return Ok(());
        }
    };
    for (mode, r) in [("none", 1.0), ("pitome", 0.9)] {
        let cfg = ViTConfig { merge_mode: mode.into(), merge_r: r,
                              ..Default::default() };
        let model = ViTModel::new(&ps, cfg.clone());
        let pred = model.predict(&patches, &mut rng)
            .map_err(|e| anyhow::anyhow!("{e}"))?;
        println!("mode={mode:<7} r={r:<5} pred={pred} plan={:?} {:.4} GFLOPs",
                 cfg.plan(), flops::vit_gflops(&cfg));
    }

    // --- 4. the owning Engine/Session API (hot serving path) ---------------
    // One Engine per process (weights + resolution cache), one session per
    // worker; after the first request, everything below runs through
    // pooled buffers with zero heap allocations.
    let engine = Engine::from_store(ps);
    let cfg = ViTConfig { merge_mode: "pitome".into(), merge_r: 0.9,
                          ..Default::default() };
    let mut sess = engine.vit_session(&cfg)
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    for i in 0..3u64 {
        let item = shape_item(TEST_SEED, i);
        sess.begin(1);
        sess.set_patches(0, &patchify(&item.image, cfg.patch_size))
            .map_err(|e| anyhow::anyhow!("{e}"))?;
        sess.forward(i).map_err(|e| anyhow::anyhow!("{e}"))?;
        println!("engine request {i}: pred={} (label {})",
                 sess.predict(0), item.label);
    }
    Ok(())
}
