//! End-to-end training driver (the DESIGN.md §4/T3 e2e validation):
//! trains the ViT **from Rust** by repeatedly executing the AOT-compiled
//! `train_step` HLO artifact (forward + backward + Adam inside XLA), with
//! PiToMe merging active in every block, on the deterministic ShapeBench
//! stream — then evaluates with the forward artifact.
//!
//! Python never runs here; the artifact was lowered once at build time.
//!
//! Run: `cargo run --release --example train_e2e -- --steps 300`

use std::path::PathBuf;
use std::time::Instant;

use pitome::data::{patchify, shape_batch, shape_item, TEST_SEED, TRAIN_SEED};
use pitome::runtime::{load_flat_params, Engine, HostTensor, Registry};
use pitome::util::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse();
    let dir = PathBuf::from(args.get("artifacts",
        Registry::default_dir().to_str().unwrap_or("artifacts")));
    let steps: usize = args.get_parse("steps", 300);
    let artifact = args.get("train-artifact", "vit_train_pitome_r900_b32");

    let reg = Registry::load(&dir).map_err(|e| anyhow::anyhow!("{e}"))?;
    let engine = Engine::cpu().map_err(|e| anyhow::anyhow!("{e}"))?;
    let exe = engine.load(&reg, &artifact).map_err(|e| anyhow::anyhow!("{e}"))?;
    let psize = exe.entry.meta.param_size
        .ok_or_else(|| anyhow::anyhow!("artifact has no param_size"))?;

    println!("# train_e2e: {artifact} ({psize} params), {steps} steps, batch 32");
    let mut flat = load_flat_params(&dir, "vit_init.bin")
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    let mut m = vec![0f32; psize];
    let mut v = vec![0f32; psize];
    let batch = 32usize;
    let t0 = Instant::now();
    let mut loss_curve: Vec<(usize, f32)> = Vec::new();
    for s in 1..=steps {
        let start = ((s - 1) * batch) % 4000;
        let (xs, ys) = shape_batch(TRAIN_SEED, start as u64, batch, 4);
        let mut xdata = Vec::with_capacity(batch * 64 * 16);
        for x in &xs {
            xdata.extend_from_slice(&x.data);
        }
        let ydata: Vec<i32> = ys.iter().map(|&y| y as i32).collect();
        let out = exe.run(&[
            HostTensor::F32(flat, vec![psize]),
            HostTensor::F32(m, vec![psize]),
            HostTensor::F32(v, vec![psize]),
            HostTensor::F32(vec![s as f32], vec![]),
            HostTensor::F32(xdata, vec![batch, 64, 16]),
            HostTensor::I32(ydata, vec![batch]),
        ]).map_err(|e| anyhow::anyhow!("{e}"))?;
        flat = out[0].as_f32().map_err(|e| anyhow::anyhow!("{e}"))?.to_vec();
        m = out[1].as_f32().map_err(|e| anyhow::anyhow!("{e}"))?.to_vec();
        v = out[2].as_f32().map_err(|e| anyhow::anyhow!("{e}"))?.to_vec();
        let loss = out[3].as_f32().map_err(|e| anyhow::anyhow!("{e}"))?[0];
        if s == 1 || s % 25 == 0 || s == steps {
            let sps = s as f64 / t0.elapsed().as_secs_f64();
            println!("step {s:>4}  loss {loss:.4}  ({sps:.1} steps/s)");
            loss_curve.push((s, loss));
        }
    }

    // loss must have decreased substantially — this is the e2e check
    let first = loss_curve.first().unwrap().1;
    let last = loss_curve.last().unwrap().1;
    println!("\nloss: {first:.4} -> {last:.4}");

    // evaluate with the forward artifact
    let fwd = if artifact.contains("pitome") { "vit_pitome_r900_b8" }
              else { "vit_none_b8" };
    let acc = eval_acc(&engine, &reg, fwd, &flat, 256)?;
    println!("eval acc after Rust-driven training: {acc:.2}%  (forward: {fwd})");
    println!("train_e2e OK");
    Ok(())
}

fn eval_acc(engine: &Engine, reg: &Registry, name: &str, flat: &[f32],
            n: usize) -> anyhow::Result<f64> {
    let exe = engine.load(reg, name).map_err(|e| anyhow::anyhow!("{e}"))?;
    let b = exe.entry.meta.batch;
    let mut ok = 0usize;
    let mut done = 0usize;
    while done < n {
        let count = b.min(n - done);
        let mut xdata = Vec::with_capacity(b * 64 * 16);
        let mut labels = Vec::with_capacity(b);
        for i in 0..b {
            let idx = (done + i.min(count - 1)) as u64;
            let item = shape_item(TEST_SEED, idx);
            xdata.extend_from_slice(&patchify(&item.image, 4).data);
            labels.push(item.label);
        }
        let out = exe.run(&[
            HostTensor::F32(flat.to_vec(), vec![flat.len()]),
            HostTensor::F32(xdata, vec![b, 64, 16]),
        ]).map_err(|e| anyhow::anyhow!("{e}"))?;
        let logits = out[0].as_f32().map_err(|e| anyhow::anyhow!("{e}"))?;
        let classes = logits.len() / b;
        for i in 0..count {
            let row = &logits[i * classes..(i + 1) * classes];
            let pred = row.iter().enumerate()
                .max_by(|a, b2| a.1.partial_cmp(b2.1).unwrap()).unwrap().0;
            if pred == labels[i] {
                ok += 1;
            }
        }
        done += count;
    }
    Ok(100.0 * ok as f64 / n as f64)
}
