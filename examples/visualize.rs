//! Token-merging visualization (the paper's Fig. 1 / Fig. 11, ASCII
//! edition): run a few merge rounds over a ShapeBench image's patch
//! features and print which patches ended up merged together — PiToMe vs
//! ToMe side by side.  Letters = merge groups (same letter = merged);
//! '.' = singleton; foreground patches are marked with '#' in the
//! reference mask.
//!
//! Run: `cargo run --release --example visualize -- --index 42`

use pitome::data::{patchify, shape_item, Rng, TEST_SEED};
use pitome::merge::energy::energy_scores;
use pitome::merge::pitome::{ordered_bsm_plan, Split};
use pitome::merge::tome::tome_plan;
use pitome::merge::{apply_plan, MergeTracker};
use pitome::tensor::Mat;
use pitome::util::Args;

const GRID: usize = 8; // 32/4 patches per side

fn run_merges(patches: &Mat, use_pitome: bool, rounds: usize, k: usize)
              -> MergeTracker {
    let mut tracker = MergeTracker::new(patches.rows);
    let mut x = patches.clone();
    let mut sizes = vec![1.0f32; patches.rows];
    let mut rng = Rng::new(5);
    for round in 0..rounds {
        let margin = 0.9 - 0.9 * round as f32 / rounds as f32;
        let plan = if use_pitome {
            let e = energy_scores(&x, margin);
            ordered_bsm_plan(&x, &e, k, 0, Split::Alternate, true, &mut rng)
        } else {
            tome_plan(&x, k, 0, None)
        };
        tracker.push(&plan);
        let (x2, s2) = apply_plan(&x, &sizes, &plan);
        x = x2;
        sizes = s2;
    }
    tracker
}

fn render(groups: &[usize], mask: &[bool]) -> Vec<String> {
    // letters for groups that contain >= 2 patches, '.' for singletons
    let mut counts = std::collections::HashMap::new();
    for &g in groups {
        *counts.entry(g).or_insert(0usize) += 1;
    }
    let mut letter = std::collections::HashMap::new();
    let alphabet: Vec<char> = ('a'..='z').chain('0'..='9').collect();
    let mut next = 0usize;
    let mut rows = Vec::new();
    for y in 0..GRID {
        let mut line = String::new();
        for x in 0..GRID {
            let i = y * GRID + x;
            let g = groups[i];
            let ch = if counts[&g] < 2 {
                '.'
            } else {
                *letter.entry(g).or_insert_with(|| {
                    let c = alphabet[next % alphabet.len()];
                    next += 1;
                    c
                })
            };
            line.push(if mask[i] { ch.to_ascii_uppercase() } else { ch });
            line.push(' ');
        }
        rows.push(line);
    }
    rows
}

fn main() {
    let args = Args::parse();
    let index: u64 = args.get_parse("index", 42);
    let rounds: usize = args.get_parse("rounds", 3);
    let k: usize = args.get_parse("k", 12);

    let item = shape_item(TEST_SEED, index);
    println!("# image {index}: {} at quadrant {}, merged over {rounds} rounds x k={k}",
             pitome::data::shapes::SHAPE_NAMES[item.label], item.quadrant);
    let patches = patchify(&item.image, 4);

    // foreground mask: patches with high variance carry the shape edge
    let mask: Vec<bool> = (0..patches.rows)
        .map(|i| {
            let r = patches.row(i);
            let mu: f32 = r.iter().sum::<f32>() / r.len() as f32;
            let var: f32 =
                r.iter().map(|v| (v - mu) * (v - mu)).sum::<f32>() / r.len() as f32;
            var.sqrt() > 0.08
        })
        .collect();

    let pit = run_merges(&patches, true, rounds, k);
    let tom = run_merges(&patches, false, rounds, k);
    let left = render(&pit.groups(), &mask);
    let right = render(&tom.groups(), &mask);
    println!("\n{:<20} {}", "PiToMe", "ToMe");
    println!("{:<20} {}", "(uppercase = foreground patch)", "");
    for (l, r) in left.iter().zip(&right) {
        println!("{l:<20} {r}");
    }

    // quantify: how many foreground patches got merged away?
    let fg_merged = |t: &MergeTracker| {
        let groups = t.groups();
        let mut counts = std::collections::HashMap::new();
        for &g in &groups {
            *counts.entry(g).or_insert(0usize) += 1;
        }
        mask.iter()
            .zip(&groups)
            .filter(|(m, g)| **m && counts[*g] >= 2)
            .count()
    };
    let fg_total = mask.iter().filter(|&&m| m).count();
    println!("\nforeground patches merged: pitome {}/{fg_total}, tome {}/{fg_total}",
             fg_merged(&pit), fg_merged(&tom));
    println!("visualize OK");
}
