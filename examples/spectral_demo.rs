//! Spectral demo: watch Theorem 1 happen on a single token set.
//!
//! Builds a clustered token graph (A1-A3), coarsens it step by step with
//! PiToMe and ToMe, and prints the spectral distance and the partitions'
//! cross-cluster contamination after every step.
//!
//! Run: `cargo run --release --example spectral_demo`

use pitome::eval::spectral::{clustered_tokens, cross_cluster_fraction,
                             iterative_coarsen, ClusterSpec, CoarsenAlgo,
                             Layout};
use pitome::graph::{spectral_distance, token_graph};
use pitome::merge::energy_scores;

fn main() {
    let spec = ClusterSpec {
        sizes: vec![12, 8, 4, 2],
        h: 16,
        noise: 0.05,
        seed: 9,
        layout: Layout::Interleaved,
    };
    let (kf, labels) = clustered_tokens(&spec);
    let w = token_graph(&kf);
    println!("# token set: clusters {:?}, h={}, noise={}", spec.sizes, spec.h,
             spec.noise);

    let e = energy_scores(&kf, 0.6);
    println!("\nper-cluster mean energy (high = redundant = mergeable):");
    for c in 0..spec.sizes.len() {
        let idx: Vec<usize> =
            (0..labels.len()).filter(|&i| labels[i] == c).collect();
        let mean: f32 = idx.iter().map(|&i| e[i]).sum::<f32>() / idx.len() as f32;
        println!("  cluster {c} (|V|={:2}): {mean:+.3}", spec.sizes[c]);
    }
    println!("-> bigger clusters score higher energy, exactly Eq. (4)'s intent");

    println!("\nstep-by-step coarsening (k=2 pairs per step):");
    println!("{:<6} {:<9} {:>12} {:>12}", "steps", "algo", "SD(G,Gc)", "cross");
    for s in 1..=5usize {
        for (algo, name) in [(CoarsenAlgo::PiToMe, "pitome"),
                             (CoarsenAlgo::ToMe, "tome")] {
            let p = iterative_coarsen(&kf, algo, s, 2, 0.6, 3);
            println!("{:<6} {:<9} {:>12.4} {:>12.3}", s, name,
                     spectral_distance(&w, &p),
                     cross_cluster_fraction(&p, &labels));
        }
    }
    println!("\nspectral_demo OK");
}
