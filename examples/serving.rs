//! Serving example: boot the coordinator with a compression ladder
//! (uncompressed + PiToMe r=0.9), replay a bursty trace, and report
//! latency/throughput per variant — including the router's load-shedding
//! to the compressed variant under pressure.
//!
//! Run: `cargo run --release --example serving -- --rate 600 --requests 400`

use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

use pitome::config::ServingConfig;
use pitome::coordinator::{Coordinator, Qos};
use pitome::data::{generate_trace, patchify, shape_item, TraceConfig, TEST_SEED};
use pitome::runtime::{HostTensor, Registry};
use pitome::util::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse();
    let dir = PathBuf::from(args.get("artifacts",
        Registry::default_dir().to_str().unwrap_or("artifacts")));
    let rate: f64 = args.get_parse("rate", 600.0);
    let requests: usize = args.get_parse("requests", 400);

    let reg = Registry::load(&dir).map_err(|e| anyhow::anyhow!("{e}"))?;
    let selection = [("vit", vec!["vit_none_b8".to_string(),
                                  "vit_pitome_r900_b8".to_string()])];
    let cfg = ServingConfig { queue_capacity: 64, ..Default::default() };
    let coord = Arc::new(Coordinator::boot(&reg, &dir, &selection, cfg)
        .map_err(|e| anyhow::anyhow!("{e}"))?);

    // warm both variants (first request waits for compilation)
    for qos in [Qos::Accuracy, Qos::Throughput] {
        let item = shape_item(TEST_SEED, 0);
        let patches = patchify(&item.image, 4);
        coord.submit("vit", qos,
                     vec![HostTensor::F32(patches.data, vec![64, 16])])
            .map_err(|e| anyhow::anyhow!("{e}"))?;
    }
    println!("# serving example: bursty trace at {rate} req/s, {requests} requests");

    let trace = generate_trace(&TraceConfig {
        rate, count: requests, burstiness: 0.7, seed: 11, ..Default::default()
    }).map_err(|e| anyhow::anyhow!("{e}"))?;
    let t0 = Instant::now();
    let mut pending = Vec::new();
    let mut correct_possible = 0usize;
    for ev in &trace {
        let target = Duration::from_micros(ev.at_us);
        if let Some(w) = target.checked_sub(t0.elapsed()) {
            std::thread::sleep(w);
        }
        let item = shape_item(TEST_SEED, ev.item);
        let patches = patchify(&item.image, 4);
        correct_possible += 1;
        pending.push((item.label, coord.submit_nowait(
            "vit", Qos::Balanced,
            vec![HostTensor::F32(patches.data, vec![64, 16])])
            .map_err(|e| anyhow::anyhow!("{e}"))?));
    }
    let mut ok = 0usize;
    let mut correct = 0usize;
    for (label, rx) in pending {
        if let Ok(resp) = rx.recv() {
            ok += 1;
            let logits = resp.outputs[0].as_f32()
                .map_err(|e| anyhow::anyhow!("{e}"))?;
            let pred = logits.iter().enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap()).unwrap().0;
            if pred == label {
                correct += 1;
            }
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    println!("completed {ok}/{requests} in {wall:.2}s ({:.1} req/s), \
              accuracy {:.1}%",
             ok as f64 / wall, 100.0 * correct as f64 / correct_possible as f64);
    for (model, artifact, snap) in coord.metrics() {
        println!("  {model}/{artifact:24} n={:<5} mean={:>7.0}us p50={:>7}us \
                  p99={:>7}us batch={:.2}",
                 snap.count, snap.mean_us, snap.p50_us, snap.p99_us,
                 snap.mean_batch);
    }
    println!("serving OK");
    Ok(())
}
