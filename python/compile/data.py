"""Deterministic synthetic workloads — Python half.

This module is mirrored *bit-for-bit* by ``rust/src/data/`` (same SplitMix64
PRNG, same f64 arithmetic, no transcendentals), so build-time training in
Python and runtime evaluation in Rust see the identical dataset.  Parity is
asserted by ``python/tests/test_data.py`` against vectors checked by the
Rust unit tests.

Datasets (DESIGN.md §6 substitutions):
  - ShapeBench: 32x32 grayscale images, structured exactly like the paper's
    assumption — a large redundant background cluster plus a small
    informative foreground shape.  10 shape classes.
  - SynthSent: variable-length token sequences with sentiment-bearing tokens
    among distractors (SST-2 / IMDb stand-in).
  - Caption/retrieval and VQA views are derived from ShapeBench images.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

import numpy as np

MASK64 = (1 << 64) - 1


def splitmix64(state: int) -> Tuple[int, int]:
    """One SplitMix64 step: returns (new_state, output)."""
    state = (state + 0x9E3779B97F4A7C15) & MASK64
    z = state
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK64
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK64
    z = z ^ (z >> 31)
    return state, z


class Rng:
    """Deterministic PRNG shared with rust/src/data/rng.rs."""

    def __init__(self, seed: int):
        self.state = seed & MASK64

    def next_u64(self) -> int:
        self.state, out = splitmix64(self.state)
        return out

    def next_f64(self) -> float:
        """Uniform in [0, 1) with 53 bits."""
        return (self.next_u64() >> 11) * (1.0 / 9007199254740992.0)

    def uniform(self, lo: float, hi: float) -> float:
        return lo + (hi - lo) * self.next_f64()

    def next_below(self, n: int) -> int:
        """Uniform integer in [0, n) (modulo method — fine for small n)."""
        return self.next_u64() % n


def item_seed(dataset_seed: int, index: int) -> int:
    """Stable per-item seed: one extra splitmix scramble of (seed, index)."""
    _, z = splitmix64((dataset_seed ^ (index * 0x9E3779B97F4A7C15)) & MASK64)
    return z


# ---------------------------------------------------------------------------
# ShapeBench images
# ---------------------------------------------------------------------------

N_SHAPE_CLASSES = 10
IMG = 32

SHAPE_NAMES = ["disk", "ring", "square", "frame", "triangle",
               "cross", "hbar", "vbar", "diamond", "checker"]


def _inside(cls: int, dx: float, dy: float, s: float, phase: int) -> bool:
    """Pixel predicate for shape ``cls`` at offset (dx, dy) from center,
    scale s. Pure comparisons — replicated exactly in Rust."""
    ax, ay = abs(dx), abs(dy)
    if cls == 0:      # disk
        return dx * dx + dy * dy <= s * s
    if cls == 1:      # ring
        rr = dx * dx + dy * dy
        return (0.36 * s * s) <= rr <= s * s
    if cls == 2:      # square
        return ax <= s and ay <= s
    if cls == 3:      # frame
        return (ax <= s and ay <= s) and not (ax <= 0.55 * s and ay <= 0.55 * s)
    if cls == 4:      # triangle (upward)
        return dy <= s and dy >= -s and ax <= (s - dy) * 0.5
    if cls == 5:      # cross
        return (ax <= 0.33 * s and ay <= s) or (ay <= 0.33 * s and ax <= s)
    if cls == 6:      # hbar
        return ax <= s and ay <= 0.33 * s
    if cls == 7:      # vbar
        return ax <= 0.33 * s and ay <= s
    if cls == 8:      # diamond
        return ax + ay <= s
    if cls == 9:      # checker
        if not (ax <= s and ay <= s):
            return False
        cx = int((dx + s) // (0.5 * s + 1e-9))
        cy = int((dy + s) // (0.5 * s + 1e-9))
        return (cx + cy + phase) % 2 == 0
    raise ValueError(cls)


@dataclass
class ShapeItem:
    image: np.ndarray      # (IMG, IMG) float32 in [0,1]
    label: int             # shape class
    quadrant: int          # 0..3 (position of shape center)
    size_bucket: int       # 0..2


def shape_item(dataset_seed: int, index: int) -> ShapeItem:
    rng = Rng(item_seed(dataset_seed, index))
    cls = rng.next_below(N_SHAPE_CLASSES)
    bg = rng.uniform(0.25, 0.55)
    fg_delta = rng.uniform(0.3, 0.42)
    fg = bg + fg_delta if rng.next_f64() < 0.5 else bg - fg_delta
    noise_amp = rng.uniform(0.01, 0.05)
    s = rng.uniform(4.0, 9.0)
    cx = rng.uniform(s + 2.0, IMG - s - 2.0)
    cy = rng.uniform(s + 2.0, IMG - s - 2.0)
    phase = rng.next_below(2)
    # horizontal background gradient (adds redundancy structure, not class info)
    grad = rng.uniform(-0.08, 0.08)

    img = np.empty((IMG, IMG), dtype=np.float64)
    for y in range(IMG):
        for x in range(IMG):
            base = bg + grad * (x / (IMG - 1.0) - 0.5)
            if _inside(cls, x - cx, y - cy, s, phase):
                base = fg
            base += rng.uniform(-noise_amp, noise_amp)
            img[y, x] = min(max(base, 0.0), 1.0)

    quadrant = (1 if cx >= IMG / 2 else 0) + (2 if cy >= IMG / 2 else 0)
    size_bucket = 0 if s < 5.7 else (1 if s < 7.4 else 2)
    return ShapeItem(img.astype(np.float32), cls, quadrant, size_bucket)


def shape_batch(dataset_seed: int, start: int, count: int
                ) -> Tuple[np.ndarray, np.ndarray]:
    xs = np.stack([shape_item(dataset_seed, start + i).image
                   for i in range(count)])
    ys = np.array([shape_item(dataset_seed, start + i).label
                   for i in range(count)], dtype=np.int32)
    return xs, ys


# ---------------------------------------------------------------------------
# SynthSent text
# ---------------------------------------------------------------------------

VOCAB = 512
PAD, CLS_TOK = 0, 1
DISTRACT_LO, DISTRACT_HI = 4, 452
POS_LO, POS_HI = 452, 482
NEG_LO, NEG_HI = 482, 512


def sent_item(dataset_seed: int, index: int, seq_len: int = 128,
              min_len: int = 16) -> Tuple[np.ndarray, int]:
    """Returns (tokens (seq_len+1,), label). tokens[0] = CLS."""
    rng = Rng(item_seed(dataset_seed ^ 0x5E17, index))
    label = rng.next_below(2)
    length = min_len + rng.next_below(seq_len - min_len + 1)
    n_sent = 3 + rng.next_below(6)
    n_noise_sent = rng.next_below(2)
    toks = np.full((seq_len + 1,), PAD, dtype=np.int32)
    toks[0] = CLS_TOK
    sent_positions = set()
    while len(sent_positions) < min(n_sent + n_noise_sent, length):
        sent_positions.add(1 + rng.next_below(length))
    sent_positions = sorted(sent_positions)
    for p in range(1, length + 1):
        toks[p] = DISTRACT_LO + rng.next_below(DISTRACT_HI - DISTRACT_LO)
    for j, p in enumerate(sent_positions):
        flip = j >= n_sent  # noise tokens carry opposite polarity
        pol = label ^ (1 if flip else 0)
        if pol == 1:
            toks[p] = POS_LO + rng.next_below(POS_HI - POS_LO)
        else:
            toks[p] = NEG_LO + rng.next_below(NEG_HI - NEG_LO)
    return toks, label


def sent_batch(dataset_seed: int, start: int, count: int, seq_len: int = 128
               ) -> Tuple[np.ndarray, np.ndarray]:
    xs, ys = [], []
    for i in range(count):
        t, l = sent_item(dataset_seed, start + i, seq_len)
        xs.append(t)
        ys.append(l)
    return np.stack(xs), np.array(ys, dtype=np.int32)


# ---------------------------------------------------------------------------
# Caption / retrieval and VQA views
# ---------------------------------------------------------------------------

CAP_LEN = 16
CAP_SHAPE_BASE = 8            # + class (10)
CAP_QUAD_BASE = 24            # + quadrant (4)
CAP_SIZE_BASE = 32            # + size bucket (3)
CAP_FILLER_LO, CAP_FILLER_HI = 64, 256

N_ANSWERS = 17                # 10 shapes + 4 quadrants + 3 sizes
Q_SHAPE, Q_QUAD, Q_SIZE = 2, 3, 4   # question-type tokens


def caption_for(dataset_seed: int, index: int) -> np.ndarray:
    """Caption tokens (CAP_LEN+1,) describing image ``index``; CLS first."""
    item = shape_item(dataset_seed, index)
    rng = Rng(item_seed(dataset_seed ^ 0xCA97, index))
    toks = np.full((CAP_LEN + 1,), PAD, dtype=np.int32)
    toks[0] = CLS_TOK
    content = [CAP_SHAPE_BASE + item.label, CAP_QUAD_BASE + item.quadrant,
               CAP_SIZE_BASE + item.size_bucket]
    # shuffle content order + filler words
    order = [0, 1, 2]
    for i in range(2, 0, -1):
        j = rng.next_below(i + 1)
        order[i], order[j] = order[j], order[i]
    length = 6 + rng.next_below(CAP_LEN - 6 - 1)
    pos = sorted({1 + rng.next_below(length) for _ in range(8)})[:3]
    while len(pos) < 3:
        pos.append(pos[-1] + 1 if pos else 1)
    for p in range(1, length + 1):
        toks[p] = CAP_FILLER_LO + rng.next_below(CAP_FILLER_HI - CAP_FILLER_LO)
    for slot, o in zip(pos, order):
        toks[slot] = content[o]
    return toks


def vqa_item(dataset_seed: int, index: int) -> Tuple[np.ndarray, int]:
    """(question tokens (CAP_LEN+1,), answer id)."""
    item = shape_item(dataset_seed, index)
    rng = Rng(item_seed(dataset_seed ^ 0x70A, index))
    qtype = rng.next_below(3)
    toks = np.full((CAP_LEN + 1,), PAD, dtype=np.int32)
    toks[0] = CLS_TOK
    toks[1] = [Q_SHAPE, Q_QUAD, Q_SIZE][qtype]
    for p in range(2, 8):
        toks[p] = CAP_FILLER_LO + rng.next_below(CAP_FILLER_HI - CAP_FILLER_LO)
    if qtype == 0:
        ans = item.label
    elif qtype == 1:
        ans = 10 + item.quadrant
    else:
        ans = 14 + item.size_bucket
    return toks, ans


def patchify(images: np.ndarray, patch: int = 4) -> np.ndarray:
    """(B, H, W) -> (B, n_patches, patch*patch) row-major patches."""
    b, hgt, wid = images.shape
    ph, pw = hgt // patch, wid // patch
    x = images.reshape(b, ph, patch, pw, patch)
    x = x.transpose(0, 1, 3, 2, 4).reshape(b, ph * pw, patch * patch)
    return x


def prng_test_vectors() -> dict:
    """Cross-language parity vectors (asserted by Rust tests too)."""
    r = Rng(42)
    u = [r.next_u64() for _ in range(4)]
    f = [Rng(7).next_f64(), Rng(7 + 1).next_f64()]
    it = shape_item(123, 0)
    st, sl = sent_item(9, 3, seq_len=32)
    return {
        "u64": [str(x) for x in u],
        "f64": f,
        "img_sum": float(np.float64(it.image.astype(np.float64).sum())),
        "img_label": it.label,
        "sent_tokens": st.tolist(),
        "sent_label": int(sl),
    }
