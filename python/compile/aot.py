"""AOT compilation driver: JAX -> HLO text artifacts for the Rust runtime.

``python -m compile.aot`` (run by ``make artifacts``):
  1. pretrains all models on the synthetic workloads (skipped if weights
     exist) — see train.py;
  2. lowers every (model, merge-mode, r, batch) variant to HLO *text*
     (not serialized protos: jax >= 0.5 emits 64-bit instruction ids that
     xla_extension 0.5.1 rejects; the text parser reassigns ids);
  3. dumps cross-language test vectors (kernel outputs, merge outputs,
     model logits, PRNG parity) consumed by the Rust unit tests;
  4. writes artifacts/manifest.json describing every artifact's I/O.
"""

from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import data as D
from .bert import bert_logits, init_bert
from .clip import ClipConfig, image_embed, text_embed, init_clip
from .common import TextConfig, ViTConfig
from .kernels import ref
from .model import init_vit, vit_logits
from .params import flatten_params, load_params, unflatten_params
from .train import (ART, make_train_step, shape_dataset, softmax_xent,
                    train_all)
from .vqa import VqaConfig, vqa_logits


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see /opt/xla-example)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _io_entry(shapes_dtypes):
    return [{"shape": list(s.shape), "dtype": str(s.dtype)}
            for s in shapes_dtypes]


class Builder:
    def __init__(self, outdir: Path):
        self.outdir = outdir
        self.manifest = {}

    def lower(self, name: str, fn, in_specs, meta: dict):
        t0 = time.time()
        lowered = jax.jit(fn).lower(*in_specs)
        text = to_hlo_text(lowered)
        path = self.outdir / f"{name}.hlo.txt"
        path.write_text(text)
        out_shapes = jax.eval_shape(fn, *in_specs)
        outs = jax.tree_util.tree_leaves(out_shapes)
        self.manifest[name] = {
            "file": f"{name}.hlo.txt",
            "inputs": _io_entry(in_specs),
            "outputs": _io_entry(outs),
            "meta": meta,
        }
        print(f"  lowered {name}: {len(text)/1e6:.2f} MB "
              f"({time.time()-t0:.1f}s)", flush=True)


PATCH_DIM = 16
N_PATCHES = 64
CAP = D.CAP_LEN + 1


def build_artifacts(outdir: Path) -> None:
    b = Builder(outdir)

    # ---- ViT classifier variants -------------------------------------
    vit_params_np = load_params(str(ART / "params" / "vit.bin"),
                                str(ART / "params" / "vit.json"))
    vit_flat, vit_manifest = flatten_params(vit_params_np)
    np.asarray(vit_flat).tofile(outdir / "params" / "vit_flat.bin")

    def vit_fn(cfg):
        def fn(flat, patches):
            p = unflatten_params(flat, vit_manifest)
            return (vit_logits(p, patches, cfg),)
        return fn

    vit_variants = [
        ("none", 1.0, 1), ("none", 1.0, 8),
        ("pitome", 0.9, 1), ("pitome", 0.9, 8),
        ("tome", 0.9, 8),
    ]
    for mode, r, batch in vit_variants:
        cfg = ViTConfig(merge_mode=mode, merge_r=r)
        tag = f"vit_{mode}" + (f"_r{int(r*1000):03d}" if mode != "none" else "")
        b.lower(f"{tag}_b{batch}", vit_fn(cfg),
                [spec((int(vit_flat.size),)),
                 spec((batch, N_PATCHES, PATCH_DIM))],
                {"model": "vit", "mode": mode, "r": r, "batch": batch,
                 "params": "vit_flat.bin", "plan": cfg.plan()})

    # ---- CLIP towers ---------------------------------------------------
    clip_params_np = load_params(str(ART / "params" / "clip.bin"),
                                 str(ART / "params" / "clip.json"))
    clip_flat, clip_manifest = flatten_params(clip_params_np)
    np.asarray(clip_flat).tofile(outdir / "params" / "clip_flat.bin")

    for mode, r in [("none", 1.0), ("pitome", 0.95)]:
        ccfg = ClipConfig()
        ccfg.vision.merge_mode = mode
        ccfg.vision.merge_r = r

        def img_fn(flat, patches, _cfg=ccfg):
            p = unflatten_params(flat, clip_manifest)
            return (image_embed(p, patches, _cfg),)

        tag = f"clip_img_{mode}" + (f"_r{int(r*1000):03d}" if mode != "none" else "")
        b.lower(f"{tag}_b8", img_fn,
                [spec((int(clip_flat.size),)), spec((8, N_PATCHES, PATCH_DIM))],
                {"model": "clip_img", "mode": mode, "r": r, "batch": 8,
                 "params": "clip_flat.bin"})

    def txt_fn(flat, tokens):
        p = unflatten_params(flat, clip_manifest)
        return (text_embed(p, tokens, ClipConfig()),)

    b.lower("clip_txt_b8", txt_fn,
            [spec((int(clip_flat.size),)), spec((8, CAP), jnp.int32)],
            {"model": "clip_txt", "mode": "none", "r": 1.0, "batch": 8,
             "params": "clip_flat.bin"})

    # ---- BERT text classifier ------------------------------------------
    bert_params_np = load_params(str(ART / "params" / "bert.bin"),
                                 str(ART / "params" / "bert.json"))
    bert_flat, bert_manifest = flatten_params(bert_params_np)
    np.asarray(bert_flat).tofile(outdir / "params" / "bert_flat.bin")

    for mode, r in [("none", 1.0), ("pitome", 0.8)]:
        tcfg = TextConfig(merge_mode=mode, merge_r=r)

        def bert_fn(flat, tokens, _cfg=tcfg):
            p = unflatten_params(flat, bert_manifest)
            return (bert_logits(p, tokens, _cfg),)

        tag = f"bert_{mode}" + (f"_r{int(r*1000):03d}" if mode != "none" else "")
        b.lower(f"{tag}_b8", bert_fn,
                [spec((int(bert_flat.size),)),
                 spec((8, tcfg.n_tokens), jnp.int32)],
                {"model": "bert", "mode": mode, "r": r, "batch": 8,
                 "params": "bert_flat.bin", "plan": tcfg.plan()})

    # ---- VQA -------------------------------------------------------------
    vqa_params_np = load_params(str(ART / "params" / "vqa.bin"),
                                str(ART / "params" / "vqa.json"))
    vqa_flat, vqa_manifest = flatten_params(vqa_params_np)
    np.asarray(vqa_flat).tofile(outdir / "params" / "vqa_flat.bin")

    for mode, r in [("none", 1.0), ("pitome", 0.9)]:
        qcfg = VqaConfig()
        qcfg.vision.merge_mode = mode
        qcfg.vision.merge_r = r

        def vqa_fn(flat, patches, questions, _cfg=qcfg):
            p = unflatten_params(flat, vqa_manifest)
            return (vqa_logits(p, patches, questions, _cfg),)

        tag = f"vqa_{mode}" + (f"_r{int(r*1000):03d}" if mode != "none" else "")
        b.lower(f"{tag}_b8", vqa_fn,
                [spec((int(vqa_flat.size),)),
                 spec((8, N_PATCHES, PATCH_DIM)), spec((8, CAP), jnp.int32)],
                {"model": "vqa", "mode": mode, "r": r, "batch": 8,
                 "params": "vqa_flat.bin"})

    # ---- train-step artifacts (driven from Rust: examples/train_e2e) ----
    for mode, r in [("none", 1.0), ("pitome", 0.9)]:
        cfg = ViTConfig(merge_mode=mode, merge_r=r)
        fresh_flat, fresh_manifest = flatten_params(init_vit(cfg))

        def loss(p, x, y, _cfg=cfg):
            return softmax_xent(vit_logits(p, x, _cfg), y)

        step = make_train_step(loss, fresh_manifest, lr=1e-3)
        tag = f"vit_train_{mode}" + (f"_r{int(r*1000):03d}" if mode != "none" else "")
        psize = int(fresh_flat.size)
        b.lower(f"{tag}_b32", step,
                [spec((psize,)), spec((psize,)), spec((psize,)), spec(()),
                 spec((32, N_PATCHES, PATCH_DIM)), spec((32,), jnp.int32)],
                {"model": "vit_train", "mode": mode, "r": r, "batch": 32,
                 "param_size": psize, "lr": 1e-3})
    # fresh init vector for Rust-driven training
    f0, _ = flatten_params(init_vit(ViTConfig(merge_mode="pitome",
                                              merge_r=0.9, seed=3)))
    np.asarray(f0).tofile(outdir / "params" / "vit_init.bin")

    with open(outdir / "manifest.json", "w") as f:
        json.dump(b.manifest, f, indent=1)


def build_testvectors(outdir: Path) -> None:
    """Cross-language parity vectors for the Rust engine."""
    tv = {}
    tv["prng"] = D.prng_test_vectors()

    rng = np.random.default_rng(0)
    kf = rng.standard_normal((16, 8)).astype(np.float32)
    tv["energy"] = {
        "kf": kf.tolist(),
        "margin": 0.45,
        "expected": np.asarray(
            ref.energy_scores(jnp.asarray(kf), 0.45)).tolist(),
    }

    x = rng.standard_normal((21, 8)).astype(np.float32)
    kf2 = rng.standard_normal((21, 8)).astype(np.float32)
    sizes = np.abs(rng.standard_normal(21)).astype(np.float32) + 1.0
    attn = np.abs(rng.standard_normal(21)).astype(np.float32)
    xs, kj, sj = jnp.asarray(x), jnp.asarray(kf2), jnp.asarray(sizes)
    cases = {}
    e = ref.energy_scores(kj, 0.45)
    for name, (o, s) in {
        "pitome": ref.apply_merge_mm(xs, sj, *ref.ordered_bsm_plan_mm(kj, e, 5)),
        "tome": ref.apply_merge_mm(xs, sj, *ref.tome_plan_mm(kj, 5)),
        "tofu": ref.apply_merge_mm(
            xs, sj, *ref.tome_plan_mm(kj, 5, prune_threshold=0.45)),
        "dct": ref.dct_merge(xs, kj, sj, 5),
        "diffrate": ref.apply_merge_mm(
            xs, sj, *ref.diffrate_plan_mm(kj, jnp.asarray(attn), 5)),
    }.items():
        cases[name] = {"out": np.asarray(o).tolist(),
                       "sizes": np.asarray(s).tolist()}
    tv["merge"] = {
        "x": x.tolist(), "kf": kf2.tolist(), "sizes": sizes.tolist(),
        "attn_cls": attn.tolist(), "margin": 0.45, "k": 5, "cases": cases,
    }

    # attention parity
    q = rng.standard_normal((2, 9, 4)).astype(np.float32)
    k_ = rng.standard_normal((2, 9, 4)).astype(np.float32)
    v = rng.standard_normal((2, 9, 4)).astype(np.float32)
    sz = np.abs(rng.standard_normal(9)).astype(np.float32) + 1.0
    o = ref.multihead_proportional_attention(
        jnp.asarray(q), jnp.asarray(k_), jnp.asarray(v), jnp.asarray(sz))
    tv["attention"] = {"q": q.tolist(), "k": k_.tolist(), "v": v.tolist(),
                       "sizes": sz.tolist(),
                       "expected": np.asarray(o).tolist()}

    # full model parity: trained ViT logits on 2 test samples, 3 modes
    vit_params = load_params(str(ART / "params" / "vit.bin"),
                             str(ART / "params" / "vit.json"))
    _, _, xte, yte = shape_dataset()
    xb = jnp.asarray(xte[:2])
    model_cases = {}
    for mode, r in [("none", 1.0), ("pitome", 0.9), ("tome", 0.9)]:
        cfg = ViTConfig(merge_mode=mode, merge_r=r)
        lg = vit_logits({k2: jnp.asarray(v2) for k2, v2 in vit_params.items()},
                        xb, cfg)
        model_cases[f"{mode}_r{int(r*1000):03d}"] = np.asarray(lg).tolist()
    tv["vit_logits"] = {"n_samples": 2, "cases": model_cases,
                        "labels": yte[:2].tolist()}

    with open(outdir / "testvectors.json", "w") as f:
        json.dump(tv, f)
    print("  wrote testvectors.json")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=str(ART))
    ap.add_argument("--force-train", action="store_true")
    ap.add_argument("--skip-artifacts", action="store_true")
    args = ap.parse_args()
    outdir = Path(args.out)
    (outdir / "params").mkdir(parents=True, exist_ok=True)

    print("== build-time pretraining ==", flush=True)
    train_all(force=args.force_train)
    if not args.skip_artifacts:
        print("== lowering artifacts ==", flush=True)
        build_artifacts(outdir)
    print("== test vectors ==", flush=True)
    build_testvectors(outdir)
    print("artifacts complete.")


if __name__ == "__main__":
    main()
