"""Blocked Pallas matmul — used for (a) the Gram matrix in matching and
(b) the merge-as-matmul assignment application (DESIGN.md §5).

The paper's PyTorch implementation uses ``scatter_reduce``; on TPU the
MXU-friendly formulation is ``X_out = S^T (m ⊙ X)`` with a one-hot
assignment matrix S — i.e. a plain matmul, which this kernel tiles.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(a_ref, b_ref, o_ref):
    o_ref[...] = jnp.dot(a_ref[...], b_ref[...],
                         preferred_element_type=jnp.float32)


def matmul_pallas(a: jnp.ndarray, b: jnp.ndarray, block_m: int = 64,
                  block_n: int = 64, interpret: bool = True) -> jnp.ndarray:
    """C = A @ B with (block_m x K) x (K x block_n) tiles.

    K is kept resident per tile — correct for the token-merging regime where
    K = h or K = N is small; block over the large M/N dims.
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"inner dims mismatch {k} vs {k2}"
    bm, bn = min(block_m, m), min(block_n, n)
    grid = (pl.cdiv(m, bm), pl.cdiv(n, bn))
    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(a, b)


def merge_matmul_pallas(x_weighted: jnp.ndarray, assign: jnp.ndarray,
                        interpret: bool = True) -> jnp.ndarray:
    """Merged tokens = assign^T @ x_weighted.

    assign: (k, P) one-hot destination matrix (row a -> dest column);
    x_weighted: (k, h) size-weighted source tokens. Result (P, h) is the
    per-destination sum, exactly scatter_reduce(sum) but as an MXU matmul.
    """
    return matmul_pallas(assign.T, x_weighted, interpret=interpret)
