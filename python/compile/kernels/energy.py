"""Pallas kernel for the PiToMe energy score (Eq. 4) — the O(N^2 h) hot-spot.

TPU adaptation (DESIGN.md §5): the kernel fuses the cosine-similarity Gram
matrix with the ELU-clamped row reduction, so the N x N similarity matrix is
only ever materialized one (block_n x N) tile at a time in VMEM.  The Gram
tile is a (block_n, h) x (h, N) matmul — MXU-shaped — followed by a VPU
elementwise clamp and a row-sum.

Runs under ``interpret=True`` (CPU PJRT cannot execute Mosaic custom-calls);
the BlockSpec structure is what a real TPU lowering would tile.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..common import ALPHA


def _energy_kernel(kn_blk_ref, kn_all_ref, out_ref, *, margin: float,
                   alpha: float, n_total: int, block_n: int):
    """One grid step: energy for a block of rows against all columns."""
    i = pl.program_id(0)
    kn_blk = kn_blk_ref[...]                    # (bn, h) normalized keys
    kn_all = kn_all_ref[...]                    # (N, h)
    # Gram tile: (bn, N) — MXU matmul shape.
    s = jnp.dot(kn_blk, kn_all.T, preferred_element_type=jnp.float32)
    # ELU-style clamp of Eq. (4).
    fs = jnp.where(s >= margin, s, alpha * (jnp.exp(s - margin) - 1.0))
    # Mask the diagonal (self is not a neighbour) and padded rows/cols.
    row = i * block_n + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    col = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    valid = (row != col) & (col < n_total) & (row < n_total)
    fs = jnp.where(valid, fs, 0.0)
    out_ref[...] = jnp.sum(fs, axis=1) / n_total


def energy_scores_pallas(kf: jnp.ndarray, margin: float,
                         alpha: float = ALPHA, block_n: int = 64,
                         interpret: bool = True) -> jnp.ndarray:
    """Energy E (N,) of Eq. (4) for key features kf (N, h).

    Matches ``ref.energy_scores`` to float32 tolerance.
    """
    n, h = kf.shape
    kn = kf / (jnp.linalg.norm(kf, axis=-1, keepdims=True) + 1e-6)
    bn = min(block_n, n)
    grid = (pl.cdiv(n, bn),)
    kernel = functools.partial(_energy_kernel, margin=float(margin),
                               alpha=float(alpha), n_total=n, block_n=bn)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, h), lambda i: (i, 0)),      # row tile
            pl.BlockSpec((n, h), lambda i: (0, 0)),       # all keys (resident)
        ],
        out_specs=pl.BlockSpec((bn,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=interpret,
    )(kn, kn)


def energy_vmem_bytes(n: int, h: int, block_n: int = 64) -> int:
    """Estimated VMEM working set per grid step (f32): row tile + resident
    keys + Gram tile + output. Used by the §Perf roofline estimate."""
    bn = min(block_n, n)
    return 4 * (bn * h + n * h + bn * n + bn)
