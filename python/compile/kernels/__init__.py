"""L1 Pallas kernels (interpret=True) + pure-jnp oracles for the PiToMe stack."""

from . import ref
from .attention import attn_vmem_bytes, proportional_attention_pallas
from .energy import energy_scores_pallas, energy_vmem_bytes
from .matmul import matmul_pallas, merge_matmul_pallas

__all__ = [
    "ref",
    "energy_scores_pallas",
    "energy_vmem_bytes",
    "proportional_attention_pallas",
    "attn_vmem_bytes",
    "matmul_pallas",
    "merge_matmul_pallas",
]
