"""Pure-jnp reference oracles for every kernel and merge algorithm.

These are the *ground truth* the Pallas kernels (energy.py, matmul.py,
attention.py) and the Rust engine (rust/src/merge/) are tested against.
Everything is static-shaped: the number of merged pairs ``k`` is a Python
int, so all of this jit-lowers to fixed-shape HLO.

Notation follows the paper (Sec 3.2, Alg. 1):
  - tokens x: (N, h); key features kf: (N, h); sizes m: (N,)
  - W[i,j] = cos(v_i, v_j); energy E_i = 1/N * sum_j f_m(W[i,j])
  - merge = argsort(E)[:2k]  (descending), protect = rest
  - A = merge[0::2], B = merge[1::2]; each a merges into argmax_b W[a,b]
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from ..common import ALPHA


# ---------------------------------------------------------------------------
# building blocks
# ---------------------------------------------------------------------------

def normalize(x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    """L2-normalize along the last axis."""
    return x / (jnp.linalg.norm(x, axis=-1, keepdims=True) + eps)


def cosine_matrix(kf: jnp.ndarray) -> jnp.ndarray:
    """Pairwise cosine similarity W (N, N) of key features kf (N, h)."""
    kn = normalize(kf)
    return kn @ kn.T


def f_margin(x: jnp.ndarray, margin: float, alpha: float = ALPHA) -> jnp.ndarray:
    """ELU-style clamp of Eq. (4): identity above margin, soft floor below."""
    return jnp.where(x >= margin, x, alpha * (jnp.exp(x - margin) - 1.0))


def energy_scores(kf: jnp.ndarray, margin: float,
                  alpha: float = ALPHA) -> jnp.ndarray:
    """Energy E (N,) of Eq. (4). Neighbours = all other tokens (diag masked)."""
    n = kf.shape[0]
    w = cosine_matrix(kf)
    fw = f_margin(w, margin, alpha)
    fw = fw * (1.0 - jnp.eye(n, dtype=kf.dtype))
    return jnp.sum(fw, axis=1) / n


# ---------------------------------------------------------------------------
# PiToMe merge (Alg. 1), static k
# ---------------------------------------------------------------------------

def pitome_plan(kf: jnp.ndarray, margin: float, k: int, protect_first: int = 1
                ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Compute the merge plan: (protect_idx, a_idx, b_idx, dst) — all static
    shapes. ``protect_first`` leading tokens (CLS) are always protected and
    excluded from candidates.

    Returns
    -------
    protect_idx : (N - 2k,) token indices kept as-is (ascending, CLS first)
    a_idx       : (k,) source tokens (merged away)
    b_idx       : (k,) destination candidates (set B)
    dst         : (k,) for each a, the *position in b_idx* it merges into
    """
    n = kf.shape[0]
    w = cosine_matrix(kf)
    e = energy_scores(kf, margin)
    # Exclude protected prefix from candidate ranking by sinking its energy.
    neg_inf = jnp.finfo(kf.dtype).min
    e_cand = jnp.where(jnp.arange(n) < protect_first, neg_inf, e)
    order = jnp.argsort(-jax.lax.stop_gradient(e_cand))                 # descending energy
    merge_idx = order[: 2 * k]
    rest = order[2 * k:]                          # low energy candidates + CLS
    # Keep protected tokens in original index order (CLS stays at slot 0).
    protect_idx = jnp.sort(rest)
    a_idx = merge_idx[0::2]
    b_idx = merge_idx[1::2]
    # Each a merges into its most similar b.
    sim_ab = w[a_idx][:, b_idx]                  # (k, k)
    dst = jnp.argmax(sim_ab, axis=1)
    return protect_idx, a_idx, b_idx, dst


def apply_merge(x: jnp.ndarray, sizes: jnp.ndarray, protect_idx: jnp.ndarray,
                a_idx: jnp.ndarray, b_idx: jnp.ndarray, dst: jnp.ndarray
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Size-weighted merge of tokens a into their destinations in B.

    out = concat(x[protect], merged_B); sizes follow the same layout.
    """
    xa = x[a_idx] * sizes[a_idx][:, None]
    xb = x[b_idx] * sizes[b_idx][:, None]
    mb = sizes[b_idx]
    ma = sizes[a_idx]
    xb = xb.at[dst].add(xa)
    mb = mb.at[dst].add(ma)
    merged = xb / mb[:, None]
    out = jnp.concatenate([x[protect_idx], merged], axis=0)
    out_sizes = jnp.concatenate([sizes[protect_idx], mb], axis=0)
    return out, out_sizes


def pitome_merge(x: jnp.ndarray, kf: jnp.ndarray, sizes: jnp.ndarray,
                 margin: float, k: int, protect_first: int = 1
                 ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full PiToMe step: returns (x_merged (N-k, h), sizes (N-k,))."""
    if k <= 0:
        return x, sizes
    plan = pitome_plan(kf, margin, k, protect_first)
    return apply_merge(x, sizes, *plan)


# ---------------------------------------------------------------------------
# Baselines
# ---------------------------------------------------------------------------

def tome_merge(x: jnp.ndarray, kf: jnp.ndarray, sizes: jnp.ndarray, k: int,
               protect_first: int = 1) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """ToMe bipartite soft matching: candidates split by index parity;
    the k most-similar A-tokens merge into their best B match."""
    if k <= 0:
        return x, sizes
    n = x.shape[0]
    cand = jnp.arange(protect_first, n)
    a_all = cand[0::2]
    b_all = cand[1::2]
    kn = normalize(kf)
    sim = kn[a_all] @ kn[b_all].T               # (|A|, |B|)
    best = jnp.max(sim, axis=1)
    nbr = jnp.argmax(sim, axis=1)
    order = jnp.argsort(-jax.lax.stop_gradient(best))
    merged_a_pos = order[:k]                     # positions in a_all
    kept_a_pos = jnp.sort(order[k:])
    a_idx = a_all[merged_a_pos]
    dst = nbr[merged_a_pos]                      # positions in b_all
    # protected = CLS + unmerged A tokens; B set receives merges.
    protect_idx = jnp.concatenate(
        [jnp.arange(protect_first), a_all[kept_a_pos]])
    return apply_merge(x, sizes, protect_idx, a_idx, b_all, dst)


def tofu_merge(x: jnp.ndarray, kf: jnp.ndarray, sizes: jnp.ndarray, k: int,
               protect_first: int = 1, prune_threshold: float = 0.45
               ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """ToFu-style fusion: ToMe matching, but low-similarity pairs *prune*
    (source token dropped, destination kept unchanged) instead of averaging —
    bridging merge and prune as in Kim et al. (simplified: hard threshold
    instead of a learned gate)."""
    if k <= 0:
        return x, sizes
    n = x.shape[0]
    cand = jnp.arange(protect_first, n)
    a_all = cand[0::2]
    b_all = cand[1::2]
    kn = normalize(kf)
    sim = kn[a_all] @ kn[b_all].T
    best = jnp.max(sim, axis=1)
    nbr = jnp.argmax(sim, axis=1)
    order = jnp.argsort(-jax.lax.stop_gradient(best))
    merged_a_pos = order[:k]
    kept_a_pos = jnp.sort(order[k:])
    a_idx = a_all[merged_a_pos]
    dst = nbr[merged_a_pos]
    gate = (best[merged_a_pos] >= prune_threshold).astype(x.dtype)  # 1=merge
    xa = x[a_idx] * sizes[a_idx][:, None] * gate[:, None]
    ma = sizes[a_idx] * gate
    xb = x[b_all] * sizes[b_all][:, None]
    mb = sizes[b_all]
    xb = xb.at[dst].add(xa)
    mb = mb.at[dst].add(ma)
    merged = xb / mb[:, None]
    protect_idx = jnp.concatenate(
        [jnp.arange(protect_first), a_all[kept_a_pos]])
    out = jnp.concatenate([x[protect_idx], merged], axis=0)
    out_sizes = jnp.concatenate([sizes[protect_idx], mb], axis=0)
    return out, out_sizes


def dct_matrix(n: int, dtype=jnp.float32) -> jnp.ndarray:
    """Orthonormal DCT-II matrix D (n, n): D @ x computes the DCT."""
    i = jnp.arange(n, dtype=dtype)[:, None]     # freq
    j = jnp.arange(n, dtype=dtype)[None, :]     # time
    d = jnp.cos(jnp.pi / n * (j + 0.5) * i)
    scale = jnp.where(i == 0, jnp.sqrt(1.0 / n), jnp.sqrt(2.0 / n))
    return (d * scale).astype(dtype)


def dct_merge(x: jnp.ndarray, kf: jnp.ndarray, sizes: jnp.ndarray, k: int,
              protect_first: int = 1) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """DCT baseline (Fourier-transformer style): truncate the token sequence
    in frequency space to the target length, then map back to token space
    with the adjoint of the kept band. Sizes reset to 1 (no tracking)."""
    if k <= 0:
        return x, sizes
    body = x[protect_first:]
    nb = body.shape[0]
    keep = nb - k
    d = dct_matrix(nb, x.dtype)
    freq = d @ body                              # (nb, h)
    trunc = freq[:keep]                          # low-frequency band
    # Resynthesize `keep` tokens on a coarse grid: adjoint of the band
    # restricted to `keep` sample points (orthonormal rows -> stable).
    body_out = d[:keep, :keep].T @ trunc
    out = jnp.concatenate([x[:protect_first], body_out], axis=0)
    out_sizes = jnp.ones((out.shape[0],), x.dtype)
    return out, out_sizes


def diffrate_merge(x: jnp.ndarray, kf: jnp.ndarray, sizes: jnp.ndarray,
                   attn_cls: jnp.ndarray, k: int, protect_first: int = 1
                   ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """DiffRate-style (simplified): rank candidates by CLS attention score,
    merge the k *least attended* tokens into their most similar kept token.
    (The learned-rate search of DiffRate is replaced by the fixed ratio-r
    schedule; see DESIGN.md §6.)"""
    if k <= 0:
        return x, sizes
    n = x.shape[0]
    score = jnp.where(jnp.arange(n) < protect_first, jnp.inf, attn_cls)
    order = jnp.argsort(jax.lax.stop_gradient(score))                  # ascending attention
    a_idx = order[:k]                            # least informative -> merged
    keep_idx = jnp.sort(order[k:])
    kn = normalize(kf)
    sim = kn[a_idx] @ kn[keep_idx].T
    # CLS should not receive merges: mask protected columns.
    col_protected = keep_idx < protect_first
    sim = jnp.where(col_protected[None, :], -jnp.inf, sim)
    dst = jnp.argmax(sim, axis=1)
    xk = x[keep_idx] * sizes[keep_idx][:, None]
    mk = sizes[keep_idx]
    xk = xk.at[dst].add(x[a_idx] * sizes[a_idx][:, None])
    mk = mk.at[dst].add(sizes[a_idx])
    out = xk / mk[:, None]
    return out, mk


def random_prune(x: jnp.ndarray, sizes: jnp.ndarray, k: int, key: jax.Array,
                 protect_first: int = 1) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Random pruning baseline: drop k random non-protected tokens."""
    if k <= 0:
        return x, sizes
    n = x.shape[0]
    perm = jax.random.permutation(key, n - protect_first) + protect_first
    keep = jnp.sort(jnp.concatenate([jnp.arange(protect_first), perm[k:]]))
    return x[keep], sizes[keep]


# ---------------------------------------------------------------------------
# Proportional attention (Sec 3.2, "Tracking Token Sizes")
# ---------------------------------------------------------------------------

def proportional_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                           sizes: jnp.ndarray) -> jnp.ndarray:
    """softmax(q k^T / sqrt(d) + log sizes) v for one head.

    q,k,v: (N, d); sizes: (N,) — the number of patches each token represents.
    """
    d = q.shape[-1]
    logits = q @ k.T / jnp.sqrt(jnp.asarray(d, q.dtype))
    logits = logits + jnp.log(sizes)[None, :]
    p = jax.nn.softmax(logits, axis=-1)
    return p @ v


def multihead_proportional_attention(q, k, v, sizes):
    """(H, N, d) batched version."""
    return jax.vmap(proportional_attention, in_axes=(0, 0, 0, None))(
        q, k, v, sizes)


# ---------------------------------------------------------------------------
# Ablation variants (Table 1 / Figure 4)
# ---------------------------------------------------------------------------

def ordered_bsm_merge(x: jnp.ndarray, kf: jnp.ndarray, sizes: jnp.ndarray,
                      scores: jnp.ndarray, k: int, protect_first: int = 1,
                      split: str = "alternate", protect: bool = True,
                      key: jax.Array | None = None
                      ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Generalized energy-ordered BSM used by the ablation variants.

    scores: (N,) ranking signal — higher = more mergeable. Variants:
      - PiToMe            : scores = energy, split=alternate, protect=True
      - w/o protection    : protect=False (all candidates mergeable; top-k
                            most similar pairs merged, like ToMe ranking)
      - random split      : split="random" (A/B assignment shuffled)
      - cls-attn indicator: scores = -attn_cls (low attention = mergeable)
    """
    if k <= 0:
        return x, sizes
    n = x.shape[0]
    w = cosine_matrix(kf)
    neg_inf = jnp.finfo(x.dtype).min
    s_cand = jnp.where(jnp.arange(n) < protect_first, neg_inf, scores)
    order = jnp.argsort(-jax.lax.stop_gradient(s_cand))
    if protect:
        merge_idx = order[: 2 * k]
        rest = order[2 * k:]
    else:
        # no protection: every candidate participates in matching
        n_c = n - protect_first
        nc2 = (n_c // 2) * 2
        merge_idx = order[:nc2]
        rest = order[nc2:]
    if split == "random":
        assert key is not None
        perm = jax.random.permutation(key, merge_idx.shape[0])
        merge_idx = merge_idx[perm]
    a_all = merge_idx[0::2]
    b_all = merge_idx[1::2]
    sim = w[a_all][:, b_all]
    best = jnp.max(sim, axis=1)
    nbr = jnp.argmax(sim, axis=1)
    pair_order = jnp.argsort(-jax.lax.stop_gradient(best))
    merged_pos = pair_order[:k]
    kept_pos = jnp.sort(pair_order[k:])
    a_idx = a_all[merged_pos]
    dst = nbr[merged_pos]
    protect_idx = jnp.sort(jnp.concatenate([rest, a_all[kept_pos]]))
    return apply_merge(x, sizes, protect_idx, a_idx, b_all, dst)


# ---------------------------------------------------------------------------
# Matmul (assignment-matrix) formulation — DESIGN.md §5
# ---------------------------------------------------------------------------
# This environment's jax build cannot differentiate batched gather/scatter on
# float tensors (GatherDimensionNumbers lacks operand_batching_dims), and on
# TPU a matmul against a one-hot assignment matrix is MXU-friendly anyway.
# The functions below express every merge as
#     out = (M @ (m ⊙ X)) / (M @ m),   M built from one_hot comparisons,
# so both forward and backward lower to plain dots.  Integer index plumbing
# (argsort / int gathers) carries no tangents and is safe.
#
# Plan contract: (protect_idx, a_idx, b_idx, dst, gate) with
#   len(protect_idx) + len(b_idx) == n_out  (static),
#   every A token either merges into b_idx[dst] (gate=1) or is pruned
#   (gate=0, ToFu).  Output layout: [protected..., B...].

def one_hot_rows(idx: jnp.ndarray, n: int, dtype=jnp.float32) -> jnp.ndarray:
    """(len(idx), n) selection matrix: row j = e_{idx[j]}."""
    return (idx[:, None] == jnp.arange(n)[None, :]).astype(dtype)


def _pair_similarity(kf: jnp.ndarray, a_idx: jnp.ndarray, b_idx: jnp.ndarray
                     ) -> jnp.ndarray:
    """(|A|, |B|) cosine similarity via selection matmuls (no float gather)."""
    n = kf.shape[0]
    kn = normalize(kf)
    a_sel = one_hot_rows(a_idx, n, kf.dtype)
    b_sel = one_hot_rows(b_idx, n, kf.dtype)
    return (a_sel @ kn) @ (b_sel @ kn).T


def ordered_bsm_plan_mm(kf: jnp.ndarray, scores: jnp.ndarray, k: int,
                        protect_first: int = 1, split: str = "alternate",
                        protect: bool = True, key: jax.Array | None = None):
    """PiToMe plan (and its ablation variants) in the mm contract."""
    n = kf.shape[0]
    neg_inf = jnp.finfo(kf.dtype).min
    s_cand = jnp.where(jnp.arange(n) < protect_first, neg_inf, scores)
    order = jnp.argsort(-jax.lax.stop_gradient(s_cand))
    n_pairs = k if protect else ((n - protect_first) // 2)
    merge_idx = order[: 2 * n_pairs]
    rest = order[2 * n_pairs:]
    if split == "random":
        assert key is not None
        perm = jax.random.permutation(key, merge_idx.shape[0])
        merge_idx = merge_idx[perm]
    a_all = merge_idx[0::2]
    b_idx = merge_idx[1::2]
    sim = _pair_similarity(kf, a_all, b_idx)
    best = jnp.max(sim, axis=1)
    dst_all = jnp.argmax(sim, axis=1)
    if n_pairs == k:
        gate = jnp.ones((k,), kf.dtype)
        return jnp.sort(rest), a_all, b_idx, dst_all, gate
    # keep only the k most similar pairs; surviving A tokens are protected
    pair_rank = jnp.argsort(-jax.lax.stop_gradient(best))
    a_merge = a_all[pair_rank[:k]]
    dst = dst_all[pair_rank[:k]]
    a_keep = a_all[pair_rank[k:]]
    protect_idx = jnp.sort(jnp.concatenate([rest, a_keep]))
    return protect_idx, a_merge, b_idx, dst, jnp.ones((k,), kf.dtype)


def tome_plan_mm(kf: jnp.ndarray, k: int, protect_first: int = 1,
                 prune_threshold: float | None = None):
    """ToMe parity plan (ToFu when prune_threshold is set)."""
    n = kf.shape[0]
    cand = jnp.arange(protect_first, n)
    a_all = cand[0::2]
    b_idx = cand[1::2]
    sim = _pair_similarity(kf, a_all, b_idx)
    best = jnp.max(sim, axis=1)
    dst_all = jnp.argmax(sim, axis=1)
    pair_rank = jnp.argsort(-jax.lax.stop_gradient(best))
    a_merge = a_all[pair_rank[:k]]
    dst = dst_all[pair_rank[:k]]
    a_keep = a_all[pair_rank[k:]]
    protect_idx = jnp.sort(jnp.concatenate([jnp.arange(protect_first), a_keep]))
    if prune_threshold is None:
        gate = jnp.ones((k,), kf.dtype)
    else:
        gate = (best[pair_rank[:k]] >= prune_threshold).astype(kf.dtype)
    return protect_idx, a_merge, b_idx, dst, gate


def diffrate_plan_mm(kf: jnp.ndarray, attn_cls: jnp.ndarray, k: int,
                     protect_first: int = 1):
    """DiffRate-style plan: merge the k least-attended tokens into the most
    similar kept token (protected columns masked)."""
    n = kf.shape[0]
    score = jnp.where(jnp.arange(n) < protect_first, jnp.inf, attn_cls)
    order = jnp.argsort(jax.lax.stop_gradient(score))
    a_idx = order[:k]
    b_idx = jnp.sort(order[k:])          # all kept tokens (incl. CLS)
    sim = _pair_similarity(kf, a_idx, b_idx)
    sim = jnp.where((b_idx < protect_first)[None, :], -jnp.inf, sim)
    dst = jnp.argmax(sim, axis=1)
    protect_idx = jnp.zeros((0,), order.dtype)
    return protect_idx, a_idx, b_idx, dst, jnp.ones((k,), kf.dtype)


def random_plan_mm(n: int, k: int, key: jax.Array, protect_first: int = 1,
                   dtype=jnp.float32):
    """Random pruning in the mm contract (empty B; pruned tokens in A)."""
    perm = jax.random.permutation(key, n - protect_first) + protect_first
    protect_idx = jnp.sort(jnp.concatenate(
        [jnp.arange(protect_first), perm[k:]]))
    a_idx = perm[:k]
    b_idx = jnp.zeros((0,), a_idx.dtype)
    dst = jnp.zeros((k,), a_idx.dtype)
    gate = jnp.zeros((k,), dtype)        # gate 0 => pruned
    return protect_idx, a_idx, b_idx, dst, gate


def apply_merge_mm(x: jnp.ndarray, sizes: jnp.ndarray,
                   protect_idx: jnp.ndarray, a_idx: jnp.ndarray,
                   b_idx: jnp.ndarray, dst: jnp.ndarray, gate: jnp.ndarray
                   ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Apply a merge plan as one assignment matmul.

    Output: (len(protect_idx) + len(b_idx), h) tokens and their sizes.
    """
    n = x.shape[0]
    p_sel = one_hot_rows(protect_idx, n, x.dtype)            # (P, N)
    kb = b_idx.shape[0]
    if kb > 0:
        a_sel = one_hot_rows(a_idx, n, x.dtype)              # (Ka, N)
        b_sel = one_hot_rows(b_idx, n, x.dtype)              # (Kb, N)
        dst_oh = one_hot_rows(dst, kb, x.dtype)              # (Ka, Kb)
        m_merge = b_sel + (dst_oh * gate[:, None]).T @ a_sel
        m = jnp.concatenate([p_sel, m_merge], axis=0)
    else:
        m = p_sel
    new_sizes = m @ sizes
    out = (m @ (x * sizes[:, None])) / jnp.maximum(new_sizes, 1e-9)[:, None]
    return out, new_sizes


def embed_lookup_mm(table: jnp.ndarray, tokens: jnp.ndarray) -> jnp.ndarray:
    """Token-embedding lookup as a one-hot matmul (grad+vmap safe)."""
    oh = (tokens[:, None] == jnp.arange(table.shape[0])[None, :]
          ).astype(table.dtype)
    return oh @ table
