"""Pallas kernel for proportional attention (Sec 3.2, "Tracking Token Sizes").

softmax(q k^T / sqrt(d) + log m) v — the ``log m`` bias re-weights merged
tokens by the number of patches they represent, so a token that absorbed 10
patches contributes like 10 tokens to the softmax.

Grid: (heads, row-blocks). K/V stay resident per head (N is small after
merging — that is the point of the paper); row blocks stream through VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _attn_kernel(q_ref, k_ref, v_ref, logm_ref, o_ref, *, scale: float,
                 n_total: int):
    q = q_ref[0]                                 # (bn, d)
    k = k_ref[0]                                 # (N, d)
    v = v_ref[0]                                 # (N, d)
    logm = logm_ref[...]                         # (N,)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    col = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = s + logm[None, :]
    s = jnp.where(col < n_total, s, -jnp.inf)    # mask padded columns
    s = s - jnp.max(s, axis=1, keepdims=True)    # stable softmax
    p = jnp.exp(s)
    p = p / jnp.sum(p, axis=1, keepdims=True)
    o_ref[0] = jnp.dot(p, v, preferred_element_type=jnp.float32)


def proportional_attention_pallas(q: jnp.ndarray, k: jnp.ndarray,
                                  v: jnp.ndarray, sizes: jnp.ndarray,
                                  block_n: int = 64,
                                  interpret: bool = True) -> jnp.ndarray:
    """Multi-head proportional attention.

    q, k, v: (H, N, d); sizes: (N,). Returns (H, N, d).
    Matches ``ref.multihead_proportional_attention`` to f32 tolerance.
    """
    heads, n, d = q.shape
    scale = 1.0 / (d ** 0.5)
    logm = jnp.log(sizes)
    bn = min(block_n, n)
    grid = (heads, pl.cdiv(n, bn))
    kernel = functools.partial(_attn_kernel, scale=scale, n_total=n)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bn, d), lambda h, i: (h, i, 0)),
            pl.BlockSpec((1, n, d), lambda h, i: (h, 0, 0)),
            pl.BlockSpec((1, n, d), lambda h, i: (h, 0, 0)),
            pl.BlockSpec((n,), lambda h, i: (0,)),
        ],
        out_specs=pl.BlockSpec((1, bn, d), lambda h, i: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((heads, n, d), jnp.float32),
        interpret=interpret,
    )(q, k, v, logm)


def attn_vmem_bytes(n: int, d: int, block_n: int = 64) -> int:
    """Estimated VMEM working set per grid step (f32)."""
    bn = min(block_n, n)
    return 4 * (bn * d + 2 * n * d + bn * n + n + bn * d)
