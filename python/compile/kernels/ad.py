"""Autodiff wrappers: Pallas kernel on the forward pass, pure-jnp reference
gradient on the backward pass.

Interpret-mode ``pallas_call`` has no reverse-mode rule in this JAX build;
since ref.py is numerically identical (tested to 3e-5), using its VJP is
exact up to float error.  This keeps the L1 kernels on the hot path of both
the inference artifacts *and* the AOT train-step artifacts.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref
from .attention import proportional_attention_pallas
from .energy import energy_scores_pallas


@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def energy_scores_ad(kf: jnp.ndarray, margin: float) -> jnp.ndarray:
    return energy_scores_pallas(kf, margin)


def _energy_fwd(kf, margin):
    return energy_scores_pallas(kf, margin), kf


def _energy_bwd(margin, kf, g):
    _, vjp = jax.vjp(lambda k: ref.energy_scores(k, margin), kf)
    return vjp(g)


energy_scores_ad.defvjp(_energy_fwd, _energy_bwd)


@jax.custom_vjp
def proportional_attention_ad(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                              sizes: jnp.ndarray) -> jnp.ndarray:
    return proportional_attention_pallas(q, k, v, sizes)


def _attn_fwd(q, k, v, sizes):
    return proportional_attention_pallas(q, k, v, sizes), (q, k, v, sizes)


def _attn_bwd(res, g):
    q, k, v, sizes = res
    _, vjp = jax.vjp(ref.multihead_proportional_attention, q, k, v, sizes)
    return vjp(g)


proportional_attention_ad.defvjp(_attn_fwd, _attn_bwd)
