"""Parameter flattening for the AOT boundary.

Every AOT artifact takes a single flat f32 vector ``params_flat`` as its
first argument; the jitted model unflattens it with *static* offsets.  The
manifest (name, shape, offset) is written next to the trained weights so the
Rust runtime can load/save/update the same buffer, and the Rust-driven
training loop can round-trip params through ``train_step`` artifacts.
"""

from __future__ import annotations

import json
from typing import Dict, List, Tuple

import jax.numpy as jnp
import numpy as np


def param_names(params: Dict[str, jnp.ndarray]) -> List[str]:
    return sorted(params.keys())


def flatten_params(params: Dict[str, np.ndarray]) -> Tuple[np.ndarray, list]:
    """Returns (flat f32 vector, manifest [{name, shape, offset, size}])."""
    manifest = []
    chunks = []
    off = 0
    for name in param_names(params):
        arr = np.asarray(params[name], dtype=np.float32)
        manifest.append({"name": name, "shape": list(arr.shape),
                         "offset": off, "size": int(arr.size)})
        chunks.append(arr.reshape(-1))
        off += arr.size
    flat = np.concatenate(chunks) if chunks else np.zeros((0,), np.float32)
    return flat, manifest


def unflatten_params(flat: jnp.ndarray, manifest: list) -> Dict[str, jnp.ndarray]:
    """Static-offset unflatten usable inside jit."""
    out = {}
    for ent in manifest:
        off, size = ent["offset"], ent["size"]
        out[ent["name"]] = jnp.reshape(flat[off:off + size], ent["shape"])
    return out


def manifest_total(manifest: list) -> int:
    if not manifest:
        return 0
    last = manifest[-1]
    return last["offset"] + last["size"]


def save_params(path_bin: str, path_manifest: str,
                params: Dict[str, np.ndarray]) -> None:
    flat, manifest = flatten_params(params)
    flat.tofile(path_bin)
    with open(path_manifest, "w") as f:
        json.dump({"total": int(flat.size), "entries": manifest}, f, indent=1)


def load_params(path_bin: str, path_manifest: str) -> Dict[str, np.ndarray]:
    with open(path_manifest) as f:
        manifest = json.load(f)["entries"]
    flat = np.fromfile(path_bin, dtype=np.float32)
    out = {}
    for ent in manifest:
        off, size = ent["offset"], ent["size"]
        out[ent["name"]] = flat[off:off + size].reshape(ent["shape"])
    return out
