"""Build-time pretraining of every model on the synthetic workloads.

Runs once under ``make artifacts`` (skipped when weights already exist).
Adam is implemented over the *flat* parameter vector so that the exact same
optimizer state layout round-trips through the AOT ``train_step`` artifacts
the Rust example drives (examples/train_e2e.rs).
"""

from __future__ import annotations

import json
import time
from pathlib import Path
from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import data as D
from .bert import bert_logits, init_bert
from .clip import ClipConfig, clip_loss, init_clip
from .common import TextConfig, ViTConfig
from .model import vit_logits, init_vit
from .params import flatten_params, unflatten_params, save_params
from .vqa import VqaConfig, init_vqa, vqa_logits

ART = Path(__file__).resolve().parents[2] / "artifacts"

ADAM_B1, ADAM_B2, ADAM_EPS = 0.9, 0.999, 1e-8


def adam_update(flat, g, m, v, step, lr):
    m = ADAM_B1 * m + (1 - ADAM_B1) * g
    v = ADAM_B2 * v + (1 - ADAM_B2) * g * g
    mhat = m / (1 - ADAM_B1 ** step)
    vhat = v / (1 - ADAM_B2 ** step)
    return flat - lr * mhat / (jnp.sqrt(vhat) + ADAM_EPS), m, v


def make_train_step(loss_fn: Callable, manifest: list, lr: float):
    """loss_fn(params_dict, batch...) -> scalar.  Returns jitted
    step(flat, m, v, step_idx, *batch) -> (flat', m', v', loss)."""

    def step(flat, m, v, step_idx, *batch):
        def flat_loss(fl):
            return loss_fn(unflatten_params(fl, manifest), *batch)
        loss, g = jax.value_and_grad(flat_loss)(flat)
        flat2, m2, v2 = adam_update(flat, g, m, v, step_idx, lr)
        return flat2, m2, v2, loss

    return step


def softmax_xent(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=1))


# ---------------------------------------------------------------------------
# dataset materialization (deterministic, shared with Rust via SplitMix64)
# ---------------------------------------------------------------------------

TRAIN_SEED, TEST_SEED = 1000, 2000
N_TRAIN, N_TEST = 4096, 512


def _cache(name: str, fn):
    ART.mkdir(exist_ok=True)
    f = ART / f"cache_{name}.npz"
    if f.exists():
        z = np.load(f)
        return tuple(z[k] for k in z.files)
    out = fn()
    np.savez(f, *out)
    return out


def shape_dataset():
    def gen():
        xs_tr, ys_tr = D.shape_batch(TRAIN_SEED, 0, N_TRAIN)
        xs_te, ys_te = D.shape_batch(TEST_SEED, 0, N_TEST)
        return (D.patchify(xs_tr), ys_tr, D.patchify(xs_te), ys_te)
    return _cache("shapes", gen)


def caption_dataset():
    def gen():
        caps_tr = np.stack([D.caption_for(TRAIN_SEED, i) for i in range(N_TRAIN)])
        caps_te = np.stack([D.caption_for(TEST_SEED, i) for i in range(N_TEST)])
        return (caps_tr, caps_te)
    return _cache("captions", gen)


def vqa_dataset():
    def gen():
        qa_tr = [D.vqa_item(TRAIN_SEED, i) for i in range(N_TRAIN)]
        qa_te = [D.vqa_item(TEST_SEED, i) for i in range(N_TEST)]
        return (np.stack([q for q, _ in qa_tr]),
                np.array([a for _, a in qa_tr], np.int32),
                np.stack([q for q, _ in qa_te]),
                np.array([a for _, a in qa_te], np.int32))
    return _cache("vqa", gen)


def sent_dataset(seq_len: int = 128):
    def gen():
        xs_tr, ys_tr = D.sent_batch(TRAIN_SEED ^ 0xAB, 0, N_TRAIN, seq_len)
        xs_te, ys_te = D.sent_batch(TEST_SEED ^ 0xAB, 0, N_TEST, seq_len)
        return (xs_tr, ys_tr, xs_te, ys_te)
    return _cache("sent", gen)


# ---------------------------------------------------------------------------
# pretraining loops
# ---------------------------------------------------------------------------

def _run_training(tag: str, params: Dict[str, np.ndarray], loss_fn, batches,
                  steps: int, lr: float, batch_size: int) -> Dict[str, np.ndarray]:
    flat_np, manifest = flatten_params(params)
    flat = jnp.asarray(flat_np)
    m = jnp.zeros_like(flat)
    v = jnp.zeros_like(flat)
    step_fn = jax.jit(make_train_step(loss_fn, manifest, lr))
    t0 = time.time()
    n = batches[0].shape[0]
    for s in range(1, steps + 1):
        idx = np.random.default_rng(s).integers(0, n, size=batch_size)
        batch = [jnp.asarray(b[idx]) for b in batches]
        flat, m, v, loss = step_fn(flat, m, v, jnp.float32(s), *batch)
        if s % max(1, steps // 8) == 0 or s == 1:
            print(f"  [{tag}] step {s}/{steps} loss={float(loss):.4f} "
                  f"({time.time()-t0:.0f}s)", flush=True)
    out = unflatten_params(np.asarray(flat), manifest)
    return {k: np.asarray(val) for k, val in out.items()}


def train_vit(steps: int = 400, lr: float = 1e-3) -> None:
    cfg = ViTConfig()
    xs, ys, xte, yte = shape_dataset()
    params = init_vit(cfg)
    loss = lambda p, x, y: softmax_xent(vit_logits(p, x, cfg), y)
    trained = _run_training("vit", params, loss, [xs, ys], steps, lr, 64)
    acc = evaluate_vit(trained, cfg, xte, yte)
    print(f"  [vit] test acc (mode=none): {acc:.3f}")
    save_params(str(ART / "params" / "vit.bin"),
                str(ART / "params" / "vit.json"), trained)


def evaluate_vit(params, cfg: ViTConfig, xte, yte, batch: int = 128) -> float:
    f = jax.jit(lambda x: vit_logits(
        {k: jnp.asarray(v) for k, v in params.items()}, x, cfg))
    correct = 0
    for i in range(0, len(xte), batch):
        lg = np.asarray(f(jnp.asarray(xte[i:i + batch])))
        correct += int((lg.argmax(1) == yte[i:i + batch]).sum())
    return correct / len(xte)


def train_clip(steps: int = 300, lr: float = 1e-3) -> None:
    cfg = ClipConfig()
    xs, _, _, _ = shape_dataset()
    caps_tr, _ = caption_dataset()
    params = init_clip(cfg)
    loss = lambda p, x, t: clip_loss(p, x, t, cfg)
    trained = _run_training("clip", params, loss, [xs, caps_tr], steps, lr, 64)
    save_params(str(ART / "params" / "clip.bin"),
                str(ART / "params" / "clip.json"), trained)


def train_bert(steps: int = 300, lr: float = 1e-3) -> None:
    cfg = TextConfig()
    xs, ys, xte, yte = sent_dataset(cfg.seq_len)
    params = init_bert(cfg)
    loss = lambda p, x, y: softmax_xent(bert_logits(p, x, cfg), y)
    trained = _run_training("bert", params, loss, [xs, ys], steps, lr, 64)
    save_params(str(ART / "params" / "bert.bin"),
                str(ART / "params" / "bert.json"), trained)


def train_vqa(steps: int = 300, lr: float = 1e-3) -> None:
    cfg = VqaConfig()
    xs, _, _, _ = shape_dataset()
    q_tr, a_tr, _, _ = vqa_dataset()
    params = init_vqa(cfg)
    loss = lambda p, x, q, a: softmax_xent(vqa_logits(p, x, q, cfg), a)
    trained = _run_training("vqa", params, loss, [xs, q_tr, a_tr], steps, lr, 64)
    save_params(str(ART / "params" / "vqa.bin"),
                str(ART / "params" / "vqa.json"), trained)


def train_all(force: bool = False) -> None:
    (ART / "params").mkdir(parents=True, exist_ok=True)
    jobs = [("vit", train_vit), ("clip", train_clip), ("bert", train_bert),
            ("vqa", train_vqa)]
    for name, fn in jobs:
        if not force and (ART / "params" / f"{name}.json").exists():
            print(f"  [{name}] params exist, skipping")
            continue
        print(f"== training {name} ==", flush=True)
        fn()


if __name__ == "__main__":
    train_all()
