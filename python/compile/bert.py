"""BERT-style text classifier for the Sec 4.4 / App. D experiments.

Compression is applied to the *first three layers only* (merge_layers =
[0, 1, 2]) exactly as in the paper; deeper layers run on the shortened
sequence.
"""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from .common import TextConfig
from .model import Params, _dense_init, init_text_encoder, text_features_single


def init_bert(cfg: TextConfig) -> Dict[str, np.ndarray]:
    rng = np.random.default_rng(cfg.seed)
    p = init_text_encoder(rng, "bert.", cfg.vocab_size, cfg.n_tokens,
                          cfg.dim, cfg.depth, cfg.heads,
                          int(cfg.dim * cfg.mlp_ratio))
    p["bert.head.w"] = _dense_init(rng, cfg.dim, cfg.num_classes)
    p["bert.head.b"] = np.zeros((cfg.num_classes,), np.float32)
    return p


def bert_logits_single(params: Params, tokens: jnp.ndarray, cfg: TextConfig
                       ) -> jnp.ndarray:
    f = text_features_single(params, tokens, "bert.", cfg.plan(), cfg.dim,
                             cfg.depth, cfg.heads, cfg.merge_mode,
                             cfg.prop_attn)
    return f @ params["bert.head.w"] + params["bert.head.b"]


def bert_logits(params: Params, tokens: jnp.ndarray, cfg: TextConfig
                ) -> jnp.ndarray:
    """tokens (B, N) int32 -> (B, num_classes)."""
    return jax.vmap(lambda t: bert_logits_single(params, t, cfg))(tokens)
