"""Synthetic VQA model (Sec 4.2 stand-in, DESIGN.md §6).

A small vision-language model: the merging ViT encodes the image, a tiny
text encoder encodes the question, and an answer head classifies over the
joint feature.  Mirrors the paper's LLaVA setting in the property that
matters: the decoder consumes ``r^L * N`` visual tokens, so vision-side
merging degrades (or not) the answer accuracy.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from . import data as D
from .common import ViTConfig
from .model import (Params, _dense_init, init_text_encoder, init_vit,
                    text_features_single, vit_features_single)


@dataclass
class VqaConfig:
    name: str = "vqa-small"
    vision: ViTConfig = field(default_factory=lambda: ViTConfig(
        name="vqa-vision", dim=64, depth=4, heads=4))
    text_dim: int = 64
    text_depth: int = 2
    text_heads: int = 4
    q_len: int = D.CAP_LEN + 1
    vocab: int = D.VOCAB
    n_answers: int = D.N_ANSWERS

    def text_plan(self) -> List[int]:
        return [self.q_len] * (self.text_depth + 1)


def init_vqa(cfg: VqaConfig) -> Dict[str, np.ndarray]:
    rng = np.random.default_rng(11)
    p = init_vit(cfg.vision)
    p.update(init_text_encoder(rng, "q.", cfg.vocab, cfg.q_len, cfg.text_dim,
                               cfg.text_depth, cfg.text_heads,
                               cfg.text_dim * 2))
    joint = cfg.vision.dim + cfg.text_dim
    p["vqa.fc1"] = _dense_init(rng, joint, 128)
    p["vqa.fc1b"] = np.zeros((128,), np.float32)
    p["vqa.head.w"] = _dense_init(rng, 128, cfg.n_answers)
    p["vqa.head.b"] = np.zeros((cfg.n_answers,), np.float32)
    return p


def vqa_logits(params: Params, patches: jnp.ndarray, questions: jnp.ndarray,
               cfg: VqaConfig) -> jnp.ndarray:
    """(B, n_patches, patch_dim), (B, q_len) -> (B, n_answers)."""
    vf = jax.vmap(lambda pp: vit_features_single(params, pp, cfg.vision))(
        patches)
    qf = jax.vmap(lambda t: text_features_single(
        params, t, "q.", cfg.text_plan(), cfg.text_dim, cfg.text_depth,
        cfg.text_heads, "none"))(questions)
    j = jnp.concatenate([vf, qf], axis=-1)
    h = jnp.maximum(j @ params["vqa.fc1"] + params["vqa.fc1b"], 0.0)
    return h @ params["vqa.head.w"] + params["vqa.head.b"]
