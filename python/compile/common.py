"""Shared constants and static-shape planning helpers for the PiToMe stack.

Everything here is *compile-time* machinery: the AOT path (aot.py) needs a
fully static plan of token counts per layer, because XLA/PJRT artifacts are
static-shaped.  The ratio-r schedule of the paper (keep ``r`` of tokens per
block) therefore becomes a concrete list ``[N_0, N_1, ..., N_L]`` baked into
each artifact.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import List, Optional

# ELU floor used by the paper for out-of-margin neighbours (alpha in Eq. 4).
ALPHA = 1.0

# Base margin of the layer-dependent schedule m_l = M0 - M0 * l / L (Sec 3.2).
MARGIN_BASE = 0.9


def layer_margin(layer_idx: int, num_layers: int, base: float = MARGIN_BASE) -> float:
    """Margin m for Eq. (4) at encoder layer ``layer_idx`` of ``num_layers``."""
    return base - base * layer_idx / max(num_layers, 1)


def tokens_after_merge(n: int, r: float, protect_first: int = 1) -> int:
    """Number of tokens after one ratio-r merge step.

    ``protect_first`` tokens (CLS) are never merge candidates. The number of
    *merged-away* tokens is k = n_c - floor(n_c * r) over the candidate set,
    clamped so at least 2 candidates always survive.
    """
    n_c = n - protect_first
    k = n_c - int(math.floor(n_c * r))
    # 2k candidates must fit in the candidate set, and >= 2 must survive.
    k = max(0, min(k, n_c // 2, n_c - 2))
    return n - k


def merge_plan(n0: int, r: float, num_layers: int, protect_first: int = 1,
               merge_layers: Optional[List[int]] = None) -> List[int]:
    """Static token-count plan: entry l is the token count *entering* block l,
    with a final entry for the output count.

    ``merge_layers``: if given, merging only happens in those block indices
    (e.g. BERT experiments compress only the first 3 layers, Sec 4.4).
    """
    plan = [n0]
    n = n0
    for l in range(num_layers):
        if merge_layers is None or l in merge_layers:
            n = tokens_after_merge(n, r, protect_first)
        plan.append(n)
    return plan


def fixed_k_plan(n0: int, k: int, num_layers: int, protect_first: int = 1) -> List[int]:
    """ToMe's original schedule: remove a fixed k tokens per layer (App. C)."""
    plan = [n0]
    n = n0
    for _ in range(num_layers):
        kk = min(k, (n - protect_first - 2) // 2)
        kk = max(kk, 0)
        n = n - kk
        plan.append(n)
    return plan


@dataclass
class MergeSpec:
    """Static description of one in-block merge step."""
    n_in: int            # tokens entering the block
    n_out: int           # tokens after merging
    protect_first: int = 1

    @property
    def k(self) -> int:
        """Number of merged-away tokens (= |A| = |B| pair count)."""
        return self.n_in - self.n_out

    @property
    def n_candidates(self) -> int:
        return self.n_in - self.protect_first


@dataclass
class ViTConfig:
    """Config for the small ViT family used across experiments."""
    name: str = "vit-ti"
    image_size: int = 32
    patch_size: int = 4
    in_channels: int = 1
    dim: int = 64
    depth: int = 4
    heads: int = 4
    mlp_ratio: float = 2.0
    num_classes: int = 10
    merge_mode: str = "none"        # none|pitome|tome|tofu|dct|diffrate|random
    merge_r: float = 1.0            # keep-ratio per layer
    merge_layers: Optional[List[int]] = None
    prop_attn: bool = True
    seed: int = 0

    @property
    def num_patches(self) -> int:
        return (self.image_size // self.patch_size) ** 2

    @property
    def n_tokens(self) -> int:
        return self.num_patches + 1  # + CLS

    @property
    def head_dim(self) -> int:
        assert self.dim % self.heads == 0
        return self.dim // self.heads

    def plan(self) -> List[int]:
        if self.merge_mode == "none" or self.merge_r >= 1.0:
            return [self.n_tokens] * (self.depth + 1)
        return merge_plan(self.n_tokens, self.merge_r, self.depth,
                          protect_first=1, merge_layers=self.merge_layers)


@dataclass
class TextConfig:
    """Config for the BERT-style text classifier (Sec 4.4)."""
    name: str = "bert-small"
    vocab_size: int = 512
    seq_len: int = 128
    dim: int = 64
    depth: int = 4
    heads: int = 4
    mlp_ratio: float = 2.0
    num_classes: int = 2
    merge_mode: str = "none"
    merge_r: float = 1.0
    merge_layers: Optional[List[int]] = field(default_factory=lambda: [0, 1, 2])
    prop_attn: bool = True
    seed: int = 1

    @property
    def n_tokens(self) -> int:
        return self.seq_len + 1  # + CLS

    @property
    def head_dim(self) -> int:
        return self.dim // self.heads

    def plan(self) -> List[int]:
        if self.merge_mode == "none" or self.merge_r >= 1.0:
            return [self.n_tokens] * (self.depth + 1)
        return merge_plan(self.n_tokens, self.merge_r, self.depth,
                          protect_first=1, merge_layers=self.merge_layers)


MERGE_MODES = ("none", "pitome", "tome", "tofu", "dct", "diffrate", "random")
