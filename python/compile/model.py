"""L2: transformer encoders with in-block token merging (paper Sec 3.1).

One shared encoder implementation serves every experiment:

  - ``vit_*``  : patch-embedding ViT for ShapeBench classification, and the
    vision tower of the CLIP/VQA models.
  - ``text_*`` (bert.py / clip.py) reuse ``encoder_forward`` with a token
    embedding front-end.

The merge step runs *between attention and MLP* exactly as Eq. (2):
``X^{l+1} = Xm + MLP(LN(Xm))`` with ``Xm = f_m(X̂, K, r)``.  All token
counts follow the static plan from ``common.merge_plan`` so the whole model
lowers to fixed-shape HLO.  The L1 Pallas kernels are called for the energy
score and the proportional attention; matching/gather machinery is plain
jnp (it lowers into the same HLO module).
"""

from __future__ import annotations

from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .common import TextConfig, ViTConfig, layer_margin
from .kernels import ref
from .kernels.ad import energy_scores_ad, proportional_attention_ad

Params = Dict[str, jnp.ndarray]

# Pallas kernels are used on the single-sample path and vmapped over batch;
# interpret=True lowers them to plain HLO (DESIGN.md §5).
USE_PALLAS = True


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def _dense_init(rng: np.random.Generator, n_in: int, n_out: int) -> np.ndarray:
    lim = float(np.sqrt(6.0 / (n_in + n_out)))
    return rng.uniform(-lim, lim, size=(n_in, n_out)).astype(np.float32)


def init_encoder(rng: np.random.Generator, prefix: str, dim: int, depth: int,
                 heads: int, mlp_hidden: int) -> Dict[str, np.ndarray]:
    p: Dict[str, np.ndarray] = {}
    for l in range(depth):
        b = f"{prefix}blk{l}."
        p[b + "ln1.w"] = np.ones((dim,), np.float32)
        p[b + "ln1.b"] = np.zeros((dim,), np.float32)
        p[b + "wq"] = _dense_init(rng, dim, dim)
        p[b + "wk"] = _dense_init(rng, dim, dim)
        p[b + "wv"] = _dense_init(rng, dim, dim)
        p[b + "wo"] = _dense_init(rng, dim, dim)
        p[b + "bo"] = np.zeros((dim,), np.float32)
        p[b + "ln2.w"] = np.ones((dim,), np.float32)
        p[b + "ln2.b"] = np.zeros((dim,), np.float32)
        p[b + "mlp1"] = _dense_init(rng, dim, mlp_hidden)
        p[b + "mlp1b"] = np.zeros((mlp_hidden,), np.float32)
        p[b + "mlp2"] = _dense_init(rng, mlp_hidden, dim)
        p[b + "mlp2b"] = np.zeros((dim,), np.float32)
    p[prefix + "lnf.w"] = np.ones((dim,), np.float32)
    p[prefix + "lnf.b"] = np.zeros((dim,), np.float32)
    return p


# ---------------------------------------------------------------------------
# forward building blocks (single sample; vmapped over batch)
# ---------------------------------------------------------------------------

def layernorm(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
              eps: float = 1e-5) -> jnp.ndarray:
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * w + b


def gelu(x: jnp.ndarray) -> jnp.ndarray:
    return 0.5 * x * (1.0 + jnp.tanh(0.7978845608028654 *
                                     (x + 0.044715 * x ** 3)))


def _merge_step(mode: str, x: jnp.ndarray, kf: jnp.ndarray,
                sizes: jnp.ndarray, attn_cls: jnp.ndarray, margin: float,
                k: int, layer: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Dispatch one merge step (static mode/k). x: (n, dim)."""
    if k <= 0 or mode == "none":
        return x, sizes
    if mode == "dct":
        return ref.dct_merge(x, kf, sizes, k)
    if mode == "pitome":
        e = (energy_scores_ad(kf, margin) if USE_PALLAS
             else ref.energy_scores(kf, margin))
        plan = ref.ordered_bsm_plan_mm(kf, e, k)
    elif mode == "pitome_noprot":
        e = ref.energy_scores(kf, margin)
        plan = ref.ordered_bsm_plan_mm(kf, e, k, protect=False)
    elif mode == "pitome_rand":
        e = ref.energy_scores(kf, margin)
        plan = ref.ordered_bsm_plan_mm(kf, e, k, split="random",
                                       key=jax.random.PRNGKey(layer))
    elif mode == "pitome_attn":
        # CLS-attention indicator instead of energy (Fig. 4 ablation):
        # low attention = mergeable.
        plan = ref.ordered_bsm_plan_mm(kf, -attn_cls, k)
    elif mode == "tome":
        plan = ref.tome_plan_mm(kf, k)
    elif mode == "tofu":
        plan = ref.tome_plan_mm(kf, k, prune_threshold=0.45)
    elif mode == "diffrate":
        plan = ref.diffrate_plan_mm(kf, attn_cls, k)
    elif mode == "random":
        plan = ref.random_plan_mm(x.shape[0], k, jax.random.PRNGKey(layer))
    else:
        raise ValueError(f"unknown merge mode {mode!r}")
    return ref.apply_merge_mm(x, sizes, *plan)


def encoder_forward(params: Params, prefix: str, x: jnp.ndarray,
                    plan: List[int], dim: int, depth: int, heads: int,
                    merge_mode: str, prop_attn: bool = True,
                    margin_base: float = 0.9) -> jnp.ndarray:
    """Run ``depth`` blocks on a single sample x (N0, dim).

    ``plan[l]`` is the token count entering block l; ``plan[l+1]`` after its
    merge. Returns final tokens (plan[-1], dim) after the last LN.
    """
    d = dim // heads
    sizes = jnp.ones((x.shape[0],), x.dtype)
    for l in range(depth):
        b = f"{prefix}blk{l}."
        n_in, n_out = plan[l], plan[l + 1]
        assert x.shape[0] == n_in, (x.shape, n_in, l)
        h = layernorm(x, params[b + "ln1.w"], params[b + "ln1.b"])
        q = h @ params[b + "wq"]
        kf = h @ params[b + "wk"]                 # (n, dim) key features
        v = h @ params[b + "wv"]
        qh = q.reshape(n_in, heads, d).transpose(1, 0, 2)
        kh = kf.reshape(n_in, heads, d).transpose(1, 0, 2)
        vh = v.reshape(n_in, heads, d).transpose(1, 0, 2)
        attn_sizes = sizes if prop_attn else jnp.ones_like(sizes)
        if USE_PALLAS:
            oh = proportional_attention_ad(qh, kh, vh, attn_sizes)
        else:
            oh = ref.multihead_proportional_attention(qh, kh, vh, attn_sizes)
        o = oh.transpose(1, 0, 2).reshape(n_in, dim)
        x = x + o @ params[b + "wo"] + params[b + "bo"]

        # CLS attention scores (mean over heads) for attention-based modes.
        scale = 1.0 / jnp.sqrt(jnp.asarray(d, x.dtype))
        cls_logits = jnp.einsum("hd,hnd->hn", qh[:, 0, :], kh) * scale
        attn_cls = jnp.mean(jax.nn.softmax(cls_logits, axis=-1), axis=0)

        k = n_in - n_out
        margin = layer_margin(l, depth, margin_base)
        x, sizes = _merge_step(merge_mode, x, kf, sizes, attn_cls, margin,
                               k, l)

        h2 = layernorm(x, params[b + "ln2.w"], params[b + "ln2.b"])
        m = gelu(h2 @ params[b + "mlp1"] + params[b + "mlp1b"])
        x = x + m @ params[b + "mlp2"] + params[b + "mlp2b"]
    return layernorm(x, params[prefix + "lnf.w"], params[prefix + "lnf.b"])


# ---------------------------------------------------------------------------
# ViT classifier
# ---------------------------------------------------------------------------

def init_vit(cfg: ViTConfig) -> Dict[str, np.ndarray]:
    rng = np.random.default_rng(cfg.seed)
    patch_dim = cfg.patch_size ** 2 * cfg.in_channels
    p = init_encoder(rng, "vit.", cfg.dim, cfg.depth, cfg.heads,
                     int(cfg.dim * cfg.mlp_ratio))
    p["vit.embed.w"] = _dense_init(rng, patch_dim, cfg.dim)
    p["vit.embed.b"] = np.zeros((cfg.dim,), np.float32)
    p["vit.cls"] = (0.02 * rng.standard_normal((cfg.dim,))).astype(np.float32)
    p["vit.pos"] = (0.02 * rng.standard_normal(
        (cfg.n_tokens, cfg.dim))).astype(np.float32)
    p["vit.head.w"] = _dense_init(rng, cfg.dim, cfg.num_classes)
    p["vit.head.b"] = np.zeros((cfg.num_classes,), np.float32)
    return p


def vit_tokens(params: Params, patches: jnp.ndarray, cfg: ViTConfig
               ) -> jnp.ndarray:
    """Patch embed + CLS + pos: (n_patches, patch_dim) -> (N, dim)."""
    emb = patches @ params["vit.embed.w"] + params["vit.embed.b"]
    x = jnp.concatenate([params["vit.cls"][None, :], emb], axis=0)
    return x + params["vit.pos"]


def vit_features_single(params: Params, patches: jnp.ndarray, cfg: ViTConfig
                        ) -> jnp.ndarray:
    x = vit_tokens(params, patches, cfg)
    out = encoder_forward(params, "vit.", x, cfg.plan(), cfg.dim, cfg.depth,
                          cfg.heads, cfg.merge_mode, cfg.prop_attn)
    return out[0]                                  # CLS feature


def vit_logits_single(params: Params, patches: jnp.ndarray, cfg: ViTConfig
                      ) -> jnp.ndarray:
    f = vit_features_single(params, patches, cfg)
    return f @ params["vit.head.w"] + params["vit.head.b"]


def vit_logits(params: Params, patches: jnp.ndarray, cfg: ViTConfig
               ) -> jnp.ndarray:
    """Batched logits: patches (B, n_patches, patch_dim) -> (B, classes)."""
    return jax.vmap(lambda pp: vit_logits_single(params, pp, cfg))(patches)


def vit_features(params: Params, patches: jnp.ndarray, cfg: ViTConfig
                 ) -> jnp.ndarray:
    return jax.vmap(lambda pp: vit_features_single(params, pp, cfg))(patches)


# ---------------------------------------------------------------------------
# Text encoder front-end (shared by BERT classifier and CLIP text tower)
# ---------------------------------------------------------------------------

def init_text_encoder(rng: np.random.Generator, prefix: str, vocab: int,
                      n_tokens: int, dim: int, depth: int, heads: int,
                      mlp_hidden: int) -> Dict[str, np.ndarray]:
    p = init_encoder(rng, prefix, dim, depth, heads, mlp_hidden)
    p[prefix + "tok"] = (0.02 * rng.standard_normal(
        (vocab, dim))).astype(np.float32)
    p[prefix + "pos"] = (0.02 * rng.standard_normal(
        (n_tokens, dim))).astype(np.float32)
    return p


def text_features_single(params: Params, tokens: jnp.ndarray, prefix: str,
                         plan: List[int], dim: int, depth: int, heads: int,
                         merge_mode: str, prop_attn: bool = True
                         ) -> jnp.ndarray:
    x = ref.embed_lookup_mm(params[prefix + "tok"], tokens) + params[prefix + "pos"]
    out = encoder_forward(params, prefix, x, plan, dim, depth, heads,
                          merge_mode, prop_attn)
    return out[0]
