"""CLIP-style two-tower retrieval model (Sec 4.1 stand-in, DESIGN.md §6).

Vision tower = the merging ViT; text tower = small text encoder over
captions.  Both project into a shared embedding space; training is
symmetric InfoNCE.  Token merging is applied to the *vision* tower only,
exactly as the paper does for CLIP/BLIP.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import data as D
from .common import ViTConfig, merge_plan
from .model import (Params, _dense_init, init_text_encoder, init_vit,
                    text_features_single, vit_features_single)


@dataclass
class ClipConfig:
    name: str = "clip-small"
    embed_dim: int = 64
    vision: ViTConfig = field(default_factory=lambda: ViTConfig(
        name="clip-vision", dim=64, depth=4, heads=4, num_classes=10))
    text_dim: int = 64
    text_depth: int = 2
    text_heads: int = 4
    cap_len: int = D.CAP_LEN + 1
    vocab: int = D.VOCAB
    temperature: float = 0.07

    def text_plan(self) -> List[int]:
        return [self.cap_len] * (self.text_depth + 1)


def init_clip(cfg: ClipConfig) -> Dict[str, np.ndarray]:
    rng = np.random.default_rng(7)
    p = init_vit(cfg.vision)
    p.update(init_text_encoder(rng, "txt.", cfg.vocab, cfg.cap_len,
                               cfg.text_dim, cfg.text_depth, cfg.text_heads,
                               cfg.text_dim * 2))
    p["proj.img"] = _dense_init(rng, cfg.vision.dim, cfg.embed_dim)
    p["proj.txt"] = _dense_init(rng, cfg.text_dim, cfg.embed_dim)
    return p


def image_embed(params: Params, patches: jnp.ndarray, cfg: ClipConfig
                ) -> jnp.ndarray:
    """patches (B, n_patches, patch_dim) -> L2-normalized (B, embed_dim)."""
    f = jax.vmap(lambda pp: vit_features_single(params, pp, cfg.vision))(
        patches)
    e = f @ params["proj.img"]
    return e / (jnp.linalg.norm(e, axis=-1, keepdims=True) + 1e-6)


def text_embed(params: Params, tokens: jnp.ndarray, cfg: ClipConfig
               ) -> jnp.ndarray:
    """tokens (B, cap_len) -> L2-normalized (B, embed_dim)."""
    f = jax.vmap(lambda t: text_features_single(
        params, t, "txt.", cfg.text_plan(), cfg.text_dim, cfg.text_depth,
        cfg.text_heads, "none"))(tokens)
    e = f @ params["proj.txt"]
    return e / (jnp.linalg.norm(e, axis=-1, keepdims=True) + 1e-6)


def clip_loss(params: Params, patches: jnp.ndarray, tokens: jnp.ndarray,
              cfg: ClipConfig) -> jnp.ndarray:
    """Symmetric InfoNCE over the batch."""
    ie = image_embed(params, patches, cfg)
    te = text_embed(params, tokens, cfg)
    logits = ie @ te.T / cfg.temperature
    labels = jnp.arange(ie.shape[0])
    li = -jnp.mean(jax.nn.log_softmax(logits, axis=1)[labels, labels])
    lt = -jnp.mean(jax.nn.log_softmax(logits, axis=0)[labels, labels])
    return 0.5 * (li + lt)


def recall_at_k(sim: np.ndarray, ks=(1, 5, 10)) -> Dict[str, float]:
    """sim[i, j] = image i vs text j; diagonal = matching pairs.
    Returns recall@k both directions (Rt = text retrieval given image)."""
    n = sim.shape[0]
    out = {}
    rank_t = (-sim).argsort(axis=1)
    rank_i = (-sim).argsort(axis=0)
    for k in ks:
        rt = float(np.mean([i in rank_t[i, :k] for i in range(n)]))
        ri = float(np.mean([i in rank_i[:k, i] for i in range(n)]))
        out[f"Rt@{k}"] = 100.0 * rt
        out[f"Ri@{k}"] = 100.0 * ri
    out["Rsum"] = sum(out.values())
    return out
