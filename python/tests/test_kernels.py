"""Pallas kernels vs pure-jnp oracles — the core L1 correctness signal.

Hypothesis sweeps shapes/dtypes/blocks; every property asserts allclose
against ref.py.
"""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    energy_scores_pallas,
    matmul_pallas,
    proportional_attention_pallas,
    ref,
)

jax.config.update("jax_platform_name", "cpu")

SETTINGS = dict(max_examples=5, deadline=None)


def rand(key, shape, scale=1.0):
    return jax.random.normal(jax.random.PRNGKey(key), shape) * scale


# ---------------------------------------------------------------------------
# energy kernel
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(
    n=st.integers(4, 96),
    h=st.sampled_from([4, 8, 16, 32]),
    block=st.sampled_from([8, 16, 64]),
    margin=st.floats(-0.5, 0.95),
    seed=st.integers(0, 2**16),
)
def test_energy_matches_ref(n, h, block, margin, seed):
    kf = rand(seed, (n, h))
    e_ref = ref.energy_scores(kf, margin)
    e_pal = energy_scores_pallas(kf, margin, block_n=block)
    np.testing.assert_allclose(np.asarray(e_pal), np.asarray(e_ref),
                               rtol=3e-5, atol=3e-5)


def test_energy_high_for_clustered_tokens():
    """Tokens in a big cluster must have higher energy than isolated ones —
    the core semantic claim of Eq. (4)."""
    key = jax.random.PRNGKey(0)
    center = jax.random.normal(key, (1, 16))
    cluster = center + 0.01 * jax.random.normal(jax.random.PRNGKey(1), (30, 16))
    isolated = -3.0 * center + jax.random.normal(jax.random.PRNGKey(2), (2, 16))
    kf = jnp.concatenate([cluster, isolated], axis=0)
    e = np.asarray(ref.energy_scores(kf, 0.5))
    assert e[:30].min() > e[30:].max()


def test_energy_margin_floor_is_negative():
    """Below-margin pairs contribute the negative ELU floor, not 0."""
    x = jnp.array([[1.0, 0.0], [-1.0, 0.0]])
    e = np.asarray(ref.energy_scores(x, 0.9))
    assert (e < 0).all()


# ---------------------------------------------------------------------------
# matmul kernel
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(
    m=st.integers(1, 80),
    k=st.integers(1, 48),
    n=st.integers(1, 80),
    bm=st.sampled_from([8, 16, 64]),
    bn=st.sampled_from([8, 16, 64]),
    seed=st.integers(0, 2**16),
)
def test_matmul_matches_ref(m, k, n, bm, bn, seed):
    a = rand(seed, (m, k))
    b = rand(seed + 1, (k, n))
    c_pal = matmul_pallas(a, b, block_m=bm, block_n=bn)
    np.testing.assert_allclose(np.asarray(c_pal), np.asarray(a @ b),
                               rtol=3e-5, atol=3e-5)


# ---------------------------------------------------------------------------
# proportional attention kernel
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(
    heads=st.sampled_from([1, 2, 4]),
    n=st.integers(2, 64),
    d=st.sampled_from([4, 8, 16]),
    block=st.sampled_from([8, 16, 64]),
    seed=st.integers(0, 2**16),
)
def test_attention_matches_ref(heads, n, d, block, seed):
    q = rand(seed, (heads, n, d))
    k = rand(seed + 1, (heads, n, d))
    v = rand(seed + 2, (heads, n, d))
    sizes = jnp.abs(rand(seed + 3, (n,))) + 1.0
    o_ref = ref.multihead_proportional_attention(q, k, v, sizes)
    o_pal = proportional_attention_pallas(q, k, v, sizes, block_n=block)
    np.testing.assert_allclose(np.asarray(o_pal), np.asarray(o_ref),
                               rtol=3e-5, atol=3e-5)


def test_attention_size_bias_shifts_mass():
    """A token with huge size must dominate attention output."""
    n, d = 8, 4
    q = jnp.ones((1, n, d))
    k = jnp.zeros((1, n, d))          # uniform logits -> bias decides
    v = jnp.eye(n, d)[None]
    sizes = jnp.ones((n,)).at[3].set(1e6)
    o = np.asarray(ref.multihead_proportional_attention(q, k, v, sizes))
    assert o[0, 0].argmax() == 3


def test_attention_unit_sizes_is_plain_attention():
    q = rand(0, (2, 12, 8))
    k = rand(1, (2, 12, 8))
    v = rand(2, (2, 12, 8))
    ones = jnp.ones((12,))
    o_prop = ref.multihead_proportional_attention(q, k, v, ones)
    plain = jax.nn.softmax(
        jnp.einsum("hnd,hmd->hnm", q, k) / jnp.sqrt(8.0), axis=-1)
    o_plain = jnp.einsum("hnm,hmd->hnd", plain, v)
    np.testing.assert_allclose(np.asarray(o_prop), np.asarray(o_plain),
                               rtol=1e-5, atol=1e-5)
