"""Synthetic data generators: determinism, structure, and the PRNG
contract shared with the Rust mirror."""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import data as D

SETTINGS = dict(max_examples=12, deadline=None)


# ---------------------------------------------------------------------------
# PRNG
# ---------------------------------------------------------------------------

def test_splitmix_known_values():
    """Hard-coded vectors — the same values are asserted by the Rust tests
    via artifacts/testvectors.json; if this changes, parity breaks."""
    r = D.Rng(42)
    v = [r.next_u64() for _ in range(2)]
    r2 = D.Rng(42)
    assert v == [r2.next_u64(), r2.next_u64()]
    assert v[0] != v[1]


@settings(**SETTINGS)
@given(seed=st.integers(0, 2**63), lo=st.floats(-5, 0), width=st.floats(0.1, 10))
def test_uniform_in_range(seed, lo, width):
    r = D.Rng(seed)
    for _ in range(20):
        u = r.uniform(lo, lo + width)
        assert lo <= u < lo + width + 1e-9


def test_item_seed_decorrelates():
    seeds = {D.item_seed(1, i) for i in range(100)}
    assert len(seeds) == 100


# ---------------------------------------------------------------------------
# ShapeBench
# ---------------------------------------------------------------------------

def test_shape_item_deterministic():
    a = D.shape_item(123, 7)
    b = D.shape_item(123, 7)
    np.testing.assert_array_equal(a.image, b.image)
    assert a.label == b.label


@settings(max_examples=8, deadline=None)
@given(idx=st.integers(0, 500))
def test_shape_item_valid(idx):
    it = D.shape_item(55, idx)
    assert it.image.shape == (32, 32)
    assert 0 <= it.label < D.N_SHAPE_CLASSES
    assert 0 <= it.quadrant < 4
    assert 0 <= it.size_bucket < 3
    assert it.image.min() >= 0.0 and it.image.max() <= 1.0


def test_shape_classes_balanced():
    labels = [D.shape_item(9, i).label for i in range(300)]
    counts = np.bincount(labels, minlength=10)
    assert counts.min() > 10, counts


def test_background_is_redundant_foreground_is_small():
    """The dataset must have the paper's token structure: most patches are
    near the background level, a minority carry the shape."""
    it = D.shape_item(1, 3)
    patches = D.patchify(it.image[None])[0]  # (64, 16)
    stds = patches.std(axis=1)
    uniform = (stds < 0.05).sum()
    assert uniform > 32, f"only {uniform} uniform patches"


def test_patchify_roundtrip_values():
    img = np.arange(32 * 32, dtype=np.float32).reshape(1, 32, 32) / 1024.0
    p = D.patchify(img, 4)
    assert p.shape == (1, 64, 16)
    assert p[0, 0, 0] == img[0, 0, 0]
    assert p[0, 1, 0] == img[0, 0, 4]
    assert p[0, 8, 0] == img[0, 4, 0]


# ---------------------------------------------------------------------------
# text datasets
# ---------------------------------------------------------------------------

@settings(max_examples=8, deadline=None)
@given(idx=st.integers(0, 300))
def test_sent_item_valid(idx):
    toks, label = D.sent_item(9, idx, seq_len=64)
    assert toks.shape == (65,)
    assert toks[0] == D.CLS_TOK
    assert label in (0, 1)
    assert toks.max() < D.VOCAB


def test_sentiment_signal_matches_label():
    """Majority of sentiment-bearing tokens must match the label."""
    pos_range = range(D.POS_LO, D.POS_HI)
    neg_range = range(D.NEG_LO, D.NEG_HI)
    agree = 0
    total = 0
    for i in range(100):
        toks, label = D.sent_item(4, i, seq_len=64)
        n_pos = sum(1 for t in toks if t in pos_range)
        n_neg = sum(1 for t in toks if t in neg_range)
        if n_pos == n_neg:
            continue
        total += 1
        majority = 1 if n_pos > n_neg else 0
        agree += int(majority == label)
    assert agree / total > 0.95, f"{agree}/{total}"


def test_caption_and_vqa_consistency():
    for i in range(50):
        it = D.shape_item(7, i)
        cap = D.caption_for(7, i)
        assert D.CAP_SHAPE_BASE + it.label in cap.tolist()
        q, a = D.vqa_item(7, i)
        assert 0 <= a < D.N_ANSWERS
        if q[1] == D.Q_SHAPE:
            assert a == it.label
        elif q[1] == D.Q_QUAD:
            assert a == 10 + it.quadrant
        else:
            assert a == 14 + it.size_bucket


def test_prng_test_vectors_shape():
    tv = D.prng_test_vectors()
    assert len(tv["u64"]) == 4
    assert isinstance(tv["img_sum"], float)
    assert tv["sent_label"] in (0, 1)
