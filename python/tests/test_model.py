"""L2 model tests: shapes, merge-plan adherence, mode behavior, params
round-trip, and training-step sanity."""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import data as D
from compile.bert import bert_logits, init_bert
from compile.clip import ClipConfig, clip_loss, init_clip, image_embed, text_embed
from compile.common import TextConfig, ViTConfig, merge_plan
from compile.model import init_vit, vit_features, vit_logits
from compile.params import flatten_params, unflatten_params
from compile.train import make_train_step, softmax_xent
from compile.vqa import VqaConfig, init_vqa, vqa_logits

BATCH = 4


@pytest.fixture(scope="module")
def patches():
    xs, ys = D.shape_batch(1, 0, BATCH)
    return jnp.asarray(D.patchify(xs)), ys


ALL_MODES = ["none", "pitome", "tome", "tofu", "dct", "diffrate", "random",
             "pitome_attn", "pitome_noprot", "pitome_rand"]


@pytest.mark.parametrize("mode", ALL_MODES)
def test_vit_logits_shape_all_modes(mode, patches):
    xp, _ = patches
    cfg = ViTConfig(merge_mode=mode, merge_r=0.9)
    p = init_vit(cfg)
    lg = jax.jit(lambda x: vit_logits(p, x, cfg))(xp)
    assert lg.shape == (BATCH, cfg.num_classes)
    assert bool(jnp.isfinite(lg).all())


def test_merge_actually_changes_output(patches):
    xp, _ = patches
    cfg0 = ViTConfig(merge_mode="none")
    cfg1 = ViTConfig(merge_mode="pitome", merge_r=0.85)
    p = init_vit(cfg0)
    lg0 = np.asarray(vit_logits(p, xp, cfg0))
    lg1 = np.asarray(vit_logits(p, xp, cfg1))
    assert not np.allclose(lg0, lg1, atol=1e-5)


def test_prop_attn_matters_after_merge(patches):
    xp, _ = patches
    cfg_on = ViTConfig(merge_mode="pitome", merge_r=0.8, prop_attn=True)
    cfg_off = ViTConfig(merge_mode="pitome", merge_r=0.8, prop_attn=False)
    p = init_vit(cfg_on)
    a = np.asarray(vit_logits(p, xp, cfg_on))
    b = np.asarray(vit_logits(p, xp, cfg_off))
    assert not np.allclose(a, b, atol=1e-6)


def test_plan_static_and_monotone():
    cfg = ViTConfig(merge_mode="pitome", merge_r=0.9)
    plan = cfg.plan()
    assert plan[0] == cfg.n_tokens
    assert all(b <= a for a, b in zip(plan, plan[1:]))
    assert plan == merge_plan(cfg.n_tokens, 0.9, cfg.depth)


def test_params_flatten_roundtrip():
    cfg = ViTConfig()
    p = init_vit(cfg)
    flat, manifest = flatten_params(p)
    p2 = unflatten_params(jnp.asarray(flat), manifest)
    for k in p:
        np.testing.assert_array_equal(np.asarray(p2[k]), p[k])


def test_bert_logits_with_merge():
    cfg = TextConfig(merge_mode="pitome", merge_r=0.8)
    p = init_bert(cfg)
    xs, ys = D.sent_batch(2, 0, 2, cfg.seq_len)
    lg = jax.jit(lambda t: bert_logits(p, t, cfg))(jnp.asarray(xs))
    assert lg.shape == (2, 2)
    assert bool(jnp.isfinite(lg).all())


def test_clip_embeds_normalized(patches):
    xp, _ = patches
    cfg = ClipConfig()
    cfg.vision.merge_mode = "pitome"
    cfg.vision.merge_r = 0.9
    p = init_clip(cfg)
    ie = np.asarray(image_embed(p, xp, cfg))
    caps = np.stack([D.caption_for(1, i) for i in range(BATCH)])
    te = np.asarray(text_embed(p, jnp.asarray(caps), cfg))
    np.testing.assert_allclose(np.linalg.norm(ie, axis=1), 1.0, atol=1e-3)
    np.testing.assert_allclose(np.linalg.norm(te, axis=1), 1.0, atol=1e-3)
    loss = clip_loss(p, xp, jnp.asarray(caps), cfg)
    assert np.isfinite(float(loss))


def test_vqa_logits_shape(patches):
    xp, _ = patches
    cfg = VqaConfig()
    cfg.vision.merge_mode = "pitome"
    cfg.vision.merge_r = 0.9
    p = init_vqa(cfg)
    qs = np.stack([D.vqa_item(1, i)[0] for i in range(BATCH)])
    lg = vqa_logits(p, xp, jnp.asarray(qs), cfg)
    assert lg.shape == (BATCH, cfg.n_answers)


def test_train_step_decreases_loss(patches):
    """Three steps of Adam on one batch must reduce the loss — gradient
    flow through the merge (incl. pallas custom-vjp) is intact."""
    xp, ys = patches
    cfg = ViTConfig(merge_mode="pitome", merge_r=0.9)
    p = init_vit(cfg)
    flat, manifest = flatten_params(p)
    loss_fn = lambda pp, x, y: softmax_xent(vit_logits(pp, x, cfg), y)
    step = jax.jit(make_train_step(loss_fn, manifest, 5e-3))
    f = jnp.asarray(flat)
    m = jnp.zeros_like(f)
    v = jnp.zeros_like(f)
    y = jnp.asarray(ys)
    losses = []
    for s in range(1, 6):
        f, m, v, l = step(f, m, v, jnp.float32(s), xp, y)
        losses.append(float(l))
    assert losses[-1] < losses[0], losses
