"""Merge algorithm invariants and semantics (pure-jnp reference level)."""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import common
from compile.kernels import ref

SETTINGS = dict(max_examples=6, deadline=None)


def rand(key, shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape)


def run_mode(mode, x, kf, sizes, k, seed=0):
    if mode == "pitome":
        return ref.pitome_merge(x, kf, sizes, 0.5, k)
    if mode == "tome":
        return ref.tome_merge(x, kf, sizes, k)
    if mode == "tofu":
        return ref.tofu_merge(x, kf, sizes, k)
    if mode == "dct":
        return ref.dct_merge(x, kf, sizes, k)
    if mode == "diffrate":
        attn = jnp.abs(rand(seed + 9, (x.shape[0],)))
        return ref.diffrate_merge(x, kf, sizes, attn, k)
    if mode == "random":
        return ref.random_prune(x, sizes, k, jax.random.PRNGKey(seed))
    raise ValueError(mode)


SIZE_TRACKING = ("pitome", "tome", "tofu", "diffrate")
ALL_MODES = SIZE_TRACKING + ("dct", "random")


@settings(**SETTINGS)
@given(
    n=st.integers(12, 80),
    h=st.sampled_from([8, 16]),
    frac=st.floats(0.05, 0.45),
    mode=st.sampled_from(ALL_MODES),
    seed=st.integers(0, 2**12),
)
def test_output_shape(n, h, frac, mode, seed):
    k = max(1, min(int(n * frac), (n - 1) // 2))
    x = rand(seed, (n, h))
    kf = rand(seed + 1, (n, h))
    sizes = jnp.ones((n,))
    out, out_sizes = run_mode(mode, x, kf, sizes, k, seed)
    assert out.shape == (n - k, h)
    assert out_sizes.shape == (n - k,)


@settings(**SETTINGS)
@given(
    n=st.integers(12, 80),
    frac=st.floats(0.05, 0.45),
    mode=st.sampled_from(SIZE_TRACKING),
    seed=st.integers(0, 2**12),
)
def test_size_conservation(n, frac, mode, seed):
    """Total token mass is conserved by merging (not by pruning modes)."""
    k = max(1, min(int(n * frac), (n - 1) // 2))
    x = rand(seed, (n, 8))
    kf = rand(seed + 1, (n, 8))
    sizes = jnp.abs(rand(seed + 2, (n,))) + 1.0
    _, out_sizes = run_mode(mode, x, kf, sizes, k, seed)
    if mode == "tofu":
        # tofu may prune (drop mass) but never create it
        assert float(out_sizes.sum()) <= float(sizes.sum()) + 1e-3
    else:
        np.testing.assert_allclose(float(out_sizes.sum()), float(sizes.sum()),
                                   rtol=1e-5)


@settings(**SETTINGS)
@given(
    n=st.integers(12, 64),
    frac=st.floats(0.05, 0.4),
    mode=st.sampled_from(("pitome", "tome")),
    seed=st.integers(0, 2**12),
)
def test_merged_mean_is_convex_combination(n, frac, mode, seed):
    """Every output token lies inside the convex hull coordinate bounds."""
    k = max(1, min(int(n * frac), (n - 1) // 2))
    x = rand(seed, (n, 8))
    kf = rand(seed + 1, (n, 8))
    sizes = jnp.ones((n,))
    out, _ = run_mode(mode, x, kf, sizes, k, seed)
    assert float(out.max()) <= float(x.max()) + 1e-5
    assert float(out.min()) >= float(x.min()) - 1e-5


def test_pitome_protects_low_energy_tokens():
    """Isolated (informative) tokens survive unchanged; clustered ones merge."""
    key = jax.random.PRNGKey(0)
    center = jax.random.normal(key, (1, 16))
    cluster = center + 0.01 * jax.random.normal(jax.random.PRNGKey(1), (28, 16))
    iso = jax.random.normal(jax.random.PRNGKey(2), (4, 16)) * 2.0 - center
    kf = jnp.concatenate([jnp.zeros((1, 16)), cluster, iso])  # CLS + tokens
    x = kf.copy()
    sizes = jnp.ones((kf.shape[0],))
    k = 8
    protect_idx, a_idx, b_idx, dst = ref.pitome_plan(kf, 0.5, k)
    merged_set = set(np.asarray(a_idx).tolist()) | set(np.asarray(b_idx).tolist())
    iso_ids = set(range(29, 33))
    # All merged candidates must come from the cluster, never the iso tokens.
    assert merged_set.isdisjoint(iso_ids)
    assert 0 in np.asarray(protect_idx).tolist()  # CLS protected


def test_pitome_cls_never_merged():
    for seed in range(5):
        kf = rand(seed, (33, 8))
        protect_idx, a_idx, b_idx, _ = ref.pitome_plan(kf, 0.3, 8)
        assert 0 not in np.asarray(a_idx)
        assert 0 not in np.asarray(b_idx)
        assert np.asarray(protect_idx)[0] == 0


def test_identical_tokens_merge_exactly():
    """Two identical tokens merging produce the same vector, size 2.

    Construction: mutually-orthogonal one-hot tokens (cos 0 pairwise, so
    low energy) plus one duplicated dense vector (cos 1, highest energy by
    Eq. 4) -> the duplicate pair is the unique top-2 merge candidate."""
    n, h = 10, 10
    x = jnp.eye(n, h)
    dup = jnp.full((h,), 1.0) / np.sqrt(h)
    x = x.at[4].set(dup).at[5].set(dup)
    sizes = jnp.ones((n,))
    out, out_sizes = ref.pitome_merge(x, x, sizes, 0.5, 1)
    assert out.shape == (n - 1, h)
    i = int(np.asarray(out_sizes).argmax())
    assert float(out_sizes[i]) == 2.0
    np.testing.assert_allclose(np.asarray(out[i]), np.asarray(dup),
                               rtol=1e-5, atol=1e-6)


def test_pitome_beats_tome_on_adversarial_parity_layout():
    """The motivating failure case (Fig. 1): when a whole object lands on the
    same parity class, ToMe must merge across objects; PiToMe does not.

    We build 2 tight clusters with *unequal* cardinality (assumption A3 —
    equal sizes make energies tie and the energy ordering uninformative),
    interleaved so one cluster is stranded on ToMe's parity class.
    Metric: cross-cluster contamination of merged tokens."""
    h = 16
    c1 = rand(10, (1, h))
    c2 = -c1
    n1, n2 = 16, 8
    x = jnp.zeros((1 + n1 + n2, h))
    # interleave: odd slots <- cluster1 until n2 exhausted, then c1 fills
    slots1 = list(range(1, 1 + 2 * n2, 2)) + list(range(1 + 2 * n2, 1 + n1 + n2))
    slots2 = list(range(2, 2 + 2 * n2, 2))
    for j, s in enumerate(slots1):
        x = x.at[s].set(c1[0] + 0.01 * rand(20 + j, (h,)))
    for j, s in enumerate(slots2):
        x = x.at[s].set(c2[0] + 0.01 * rand(40 + j, (h,)))
    sizes = jnp.ones((x.shape[0],))
    k = 6

    def contamination(out):
        # fraction of output tokens that are "between" clusters
        sim1 = np.asarray(out @ c1[0] / (np.linalg.norm(np.asarray(out), axis=1)
                          * float(jnp.linalg.norm(c1[0])) + 1e-9))
        return float(np.sum((np.abs(sim1) < 0.9)[1:]))  # exclude CLS slot

    out_p, _ = ref.pitome_merge(x, x, sizes, 0.5, k)
    out_t, _ = ref.tome_merge(x, x, sizes, k)
    assert contamination(out_p) <= contamination(out_t)


@settings(**SETTINGS)
@given(n=st.integers(8, 60), r=st.floats(0.5, 0.99))
def test_plan_monotone(n, r):
    plan = common.merge_plan(n, r, 6)
    assert plan[0] == n
    for a, b in zip(plan, plan[1:]):
        assert 2 <= b <= a


def test_fixed_k_vs_ratio_plan():
    """Ratio-r removes more tokens early; fixed-k removes linearly (App. C)."""
    rp = common.merge_plan(197, 0.9, 12)
    fp = common.fixed_k_plan(197, 8, 12)
    assert rp[1] < fp[1] or rp[-1] != fp[-1]
    removed_early_ratio = rp[0] - rp[1]
    removed_late_ratio = rp[-2] - rp[-1]
    assert removed_early_ratio >= removed_late_ratio
