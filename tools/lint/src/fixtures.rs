//! Embedded fixture snippets: each rule × one seeded violation + one
//! clean near-miss, run by `pitome-lint selftest` and by the crate's
//! test suite.  The fixtures are linted through the exact same engine
//! as the real tree (`crate::lint_sources`), so they prove each rule
//! fires on a violation and stays quiet on clean code.

use crate::rules::Finding;
use crate::{lint_sources, SourceFile};

/// One self-test case.
pub struct Fixture {
    /// Case name (reported by `selftest`).
    pub name: &'static str,
    /// `(repo-relative path, source)` pairs fed to the engine.
    pub files: &'static [(&'static str, &'static str)],
    /// Rule under test.
    pub rule: &'static str,
    /// Whether the rule must fire (`true`) or stay quiet (`false`).
    pub should_fire: bool,
}

/// All fixture cases.
pub const FIXTURES: &[Fixture] = &[
    Fixture {
        name: "hot-path-alloc fires on a stray to_vec in a merge builder",
        files: &[(
            "rust/src/merge/fixture.rs",
            r##"
pub fn stray_into(xs: &[f32], out: &mut Vec<f32>) {
    let tmp = xs.to_vec();
    out.copy_from_slice(&tmp);
}
"##,
        )],
        rule: "hot-path-alloc",
        should_fire: true,
    },
    Fixture {
        name: "hot-path-alloc fires on vec![] and Vec::new in engine code",
        files: &[(
            "rust/src/engine/fixture.rs",
            r##"
pub fn hot(n: usize) -> usize {
    let a = vec![0f32; n];
    let b: Vec<f32> = Vec::new();
    a.len() + b.len()
}
"##,
        )],
        rule: "hot-path-alloc",
        should_fire: true,
    },
    Fixture {
        name: "hot-path-alloc stays quiet behind an allow(alloc) marker",
        files: &[(
            "rust/src/merge/fixture.rs",
            r##"
/// Cold-path constructor.
// lint: allow(alloc) reason=cold-path constructor, called once per worker
pub fn empty() -> Vec<f32> {
    Vec::new()
}
"##,
        )],
        rule: "hot-path-alloc",
        should_fire: false,
    },
    Fixture {
        name: "hot-path-alloc fires when the marker has no reason",
        files: &[(
            "rust/src/merge/fixture.rs",
            r##"
// lint: allow(alloc)
pub fn empty() -> Vec<f32> {
    Vec::new()
}
"##,
        )],
        rule: "hot-path-alloc",
        should_fire: true,
    },
    Fixture {
        name: "hot-path-alloc ignores cold modules and test mods",
        files: &[
            (
                "rust/src/eval/fixture.rs",
                r##"
pub fn cold(xs: &[f32]) -> Vec<f32> {
    xs.to_vec()
}
"##,
            ),
            (
                "rust/src/merge/fixture.rs",
                r##"
pub fn hot(xs: &mut [f32]) {
    xs[0] = 1.0;
}

#[cfg(test)]
mod tests {
    #[test]
    fn t() {
        let v = vec![1f32, 2.0];
        assert_eq!(v.to_vec().len(), 2);
    }
}
"##,
            ),
        ],
        rule: "hot-path-alloc",
        should_fire: false,
    },
    Fixture {
        name: "one-gram fires on an unsanctioned CosineGram::build",
        files: &[(
            "rust/src/engine/fixture.rs",
            r##"
use crate::tensor::{CosineGram, Mat};

pub fn sneaky_second_gram(kf: &Mat) -> CosineGram {
    CosineGram::build(kf)
}
"##,
        )],
        rule: "one-gram",
        should_fire: true,
    },
    Fixture {
        name: "one-gram fires on an unsanctioned .rebuild(...)",
        files: &[(
            "rust/src/model/encoder.rs",
            r##"
pub fn hidden_rebuild(g: &mut CosineGram, kf: &Mat, kn: &mut Mat) {
    g.rebuild(kf, kn);
}
"##,
        )],
        rule: "one-gram",
        should_fire: true,
    },
    Fixture {
        name: "one-gram stays quiet at a sanctioned call site",
        files: &[(
            "rust/src/merge/tome.rs",
            r##"
pub fn tome_plan(kf: &Mat, k: usize) -> MergePlan {
    tome_plan_gram(&CosineGram::build(kf), k)
}
"##,
        )],
        rule: "one-gram",
        should_fire: false,
    },
    Fixture {
        name: "deprecated-internal-use fires on a cross-module call",
        files: &[
            (
                "rust/src/model/fixture.rs",
                r##"
#[deprecated(note = "use the session API")]
pub fn old_api(x: u32) -> u32 {
    x + 1
}
"##,
            ),
            (
                "rust/src/eval/fixture.rs",
                r##"
pub fn caller() -> u32 {
    old_api(1)
}
"##,
            ),
        ],
        rule: "deprecated-internal-use",
        should_fire: true,
    },
    Fixture {
        name: "deprecated-internal-use honors allow(deprecated) wrappers",
        files: &[
            (
                "rust/src/model/fixture.rs",
                r##"
#[deprecated(note = "use the session API")]
pub fn old_api(x: u32) -> u32 {
    x + 1
}

#[deprecated(note = "use the session API")]
#[allow(deprecated)]
pub fn old_api_batch(x: u32) -> u32 {
    old_api(x)
}
"##,
            ),
            (
                "rust/src/eval/fixture.rs",
                r##"
#![allow(deprecated)]

pub fn parity_reference() -> u32 {
    old_api(1)
}
"##,
            ),
        ],
        rule: "deprecated-internal-use",
        should_fire: false,
    },
    Fixture {
        name: "unsafe-audit fires on an undocumented unsafe block",
        files: &[(
            "rust/src/util/fixture.rs",
            r##"
pub fn peek(xs: &[f32]) -> f32 {
    unsafe { *xs.get_unchecked(0) }
}
"##,
        )],
        rule: "unsafe-audit",
        should_fire: true,
    },
    Fixture {
        name: "unsafe-audit stays quiet with SAFETY comments",
        files: &[(
            "rust/src/util/fixture.rs",
            r##"
pub fn peek(xs: &[f32]) -> f32 {
    // SAFETY: caller guarantees xs is non-empty.
    unsafe { *xs.get_unchecked(0) }
}

// SAFETY: the wrapper only forwards to the system allocator.
unsafe impl Send for Holder {}
"##,
        )],
        rule: "unsafe-audit",
        should_fire: false,
    },
    Fixture {
        name: "lock-discipline fires on two undocumented locks",
        files: &[(
            "rust/src/coordinator/fixture.rs",
            r##"
pub fn drain(&self) -> usize {
    let a = self.pool.lock().unwrap().len();
    let b = self.metrics.lock().unwrap().len();
    a + b
}
"##,
        )],
        rule: "lock-discipline",
        should_fire: true,
    },
    Fixture {
        name: "lock-discipline honors a lock-order comment",
        files: &[(
            "rust/src/coordinator/fixture.rs",
            r##"
// lock-order: pool before metrics; never held across a batch cycle.
pub fn drain(&self) -> usize {
    let a = self.pool.lock().unwrap().len();
    let b = self.metrics.lock().unwrap().len();
    a + b
}
"##,
        )],
        rule: "lock-discipline",
        should_fire: false,
    },
    Fixture {
        name: "lock-discipline ignores repeated locks of one mutex",
        files: &[(
            "rust/src/coordinator/fixture.rs",
            r##"
pub fn twice(&self) -> usize {
    let a = self.pool.lock().unwrap().len();
    let b = self.pool.lock().unwrap().len();
    a + b
}
"##,
        )],
        rule: "lock-discipline",
        should_fire: false,
    },
];

/// Run one fixture; `Ok` findings-count on expectation match, else a
/// human-readable failure description.
pub fn run_fixture(fx: &Fixture) -> Result<usize, String> {
    let files: Vec<SourceFile> = fx
        .files
        .iter()
        .map(|(rel, src)| SourceFile {
            rel: rel.to_string(),
            text: src.to_string(),
        })
        .collect();
    let findings: Vec<Finding> = lint_sources(&files)
        .into_iter()
        .filter(|f| f.rule == fx.rule)
        .collect();
    let fired = !findings.is_empty();
    if fired == fx.should_fire {
        Ok(findings.len())
    } else {
        Err(format!(
            "fixture `{}`: expected rule `{}` to {} but it {} ({} findings)",
            fx.name,
            fx.rule,
            if fx.should_fire { "fire" } else { "stay quiet" },
            if fired { "fired" } else { "stayed quiet" },
            findings.len(),
        ))
    }
}

/// Run all fixtures; collect failures.
pub fn run_all() -> Vec<String> {
    FIXTURES.iter().filter_map(|fx| run_fixture(fx).err()).collect()
}
