//! Baseline file support: triage pre-existing findings without blocking
//! CI on them.
//!
//! A baseline is a text file of finding *keys* (one per line, `#`
//! comments and blanks ignored).  Keys are line-insensitive —
//! `rule file fn=<name>` — so unrelated edits don't invalidate them; a
//! key suppresses every finding of that rule in that function.  The
//! intended workflow: a new rule lands with its pre-existing findings
//! captured via `--write-baseline`, and the baseline only ever shrinks
//! as findings are fixed (`check` reports stale entries).

use std::path::Path;

use crate::rules::Finding;

/// Result of filtering findings through a baseline.
pub struct Applied {
    /// Findings not covered by the baseline (these fail the check).
    pub active: Vec<Finding>,
    /// Number of findings suppressed by the baseline.
    pub suppressed: usize,
    /// Baseline entries that matched nothing (stale; should be removed).
    pub unused: Vec<String>,
}

/// Load baseline keys from `path`; a missing file is an empty baseline.
pub fn load(path: &Path) -> Vec<String> {
    let Ok(text) = std::fs::read_to_string(path) else {
        return Vec::new();
    };
    parse(&text)
}

/// Parse baseline text into keys.
pub fn parse(text: &str) -> Vec<String> {
    text.lines()
        .map(str::trim)
        .filter(|l| !l.is_empty() && !l.starts_with('#'))
        .map(str::to_string)
        .collect()
}

/// Split `findings` into active vs baselined.
pub fn apply(findings: Vec<Finding>, baseline: &[String]) -> Applied {
    let mut used = vec![false; baseline.len()];
    let mut active = Vec::new();
    let mut suppressed = 0usize;
    for f in findings {
        match baseline.iter().position(|k| *k == f.key) {
            Some(i) => {
                used[i] = true;
                suppressed += 1;
            }
            None => active.push(f),
        }
    }
    let unused = baseline
        .iter()
        .zip(used.iter())
        .filter(|(_, u)| !**u)
        .map(|(k, _)| k.clone())
        .collect();
    Applied {
        active,
        suppressed,
        unused,
    }
}

/// Render findings as baseline text (sorted unique keys + header).
pub fn render(findings: &[Finding]) -> String {
    let mut keys: Vec<&str> = findings.iter().map(|f| f.key.as_str()).collect();
    keys.sort_unstable();
    keys.dedup();
    let mut out = String::from(
        "# pitome-lint baseline: pre-existing findings triaged out of CI.\n\
         # One key per line (`rule file fn=<name>`); regenerate with\n\
         # `cargo run -p pitome-lint -- check --write-baseline`.\n\
         # This file should only ever shrink.\n",
    );
    for k in keys {
        out.push_str(k);
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn f(rule: &'static str, file: &str, fnn: &str, line: usize) -> Finding {
        Finding {
            rule,
            file: file.to_string(),
            line,
            msg: "m".to_string(),
            key: format!("{rule} {file} fn={fnn}"),
        }
    }

    #[test]
    fn apply_suppresses_and_reports_stale() {
        let findings = vec![
            f("one-gram", "rust/src/a.rs", "x", 3),
            f("one-gram", "rust/src/a.rs", "x", 9),
            f("unsafe-audit", "rust/src/b.rs", "y", 1),
        ];
        let baseline = vec![
            "one-gram rust/src/a.rs fn=x".to_string(),
            "lock-discipline rust/src/gone.rs fn=z".to_string(),
        ];
        let a = apply(findings, &baseline);
        assert_eq!(a.suppressed, 2, "one key suppresses all findings in the fn");
        assert_eq!(a.active.len(), 1);
        assert_eq!(a.active[0].rule, "unsafe-audit");
        assert_eq!(a.unused, vec!["lock-discipline rust/src/gone.rs fn=z".to_string()]);
    }

    #[test]
    fn render_roundtrips_through_parse() {
        let findings = vec![f("one-gram", "a.rs", "x", 3), f("one-gram", "a.rs", "x", 9)];
        let text = render(&findings);
        let keys = parse(&text);
        assert_eq!(keys, vec!["one-gram a.rs fn=x".to_string()]);
    }
}
