//! The five invariant rules.
//!
//! Each rule is a pure function from a lexed+parsed file (plus, for the
//! deprecated rule, a cross-file name set) to [`Finding`]s.  Escape
//! hatches are source comments, never linter edits:
//!
//! * `// lint: allow(alloc) reason=...` — sanction an intentional
//!   cold-path allocation inside a hot-path function.
//! * `// lint: allow(one-gram) reason=...` — sanction an extra Gram
//!   build site.
//! * `// lint: allow(deprecated) reason=...` — sanction a deprecated
//!   call (normally `#[allow(deprecated)]` should be used instead).
//! * `// lint: allow(lock) reason=...` or a `// lock-order: ...`
//!   comment — document a multi-mutex function's acquisition order.
//! * `// SAFETY: ...` — document an `unsafe` site.

use std::collections::BTreeSet;

use crate::config;
use crate::lexer::{Lexed, Tok, TokKind};
use crate::parse::{enclosing_fn, in_regions, FnItem, Parsed, UnsafeKind};

/// One rule violation.
#[derive(Debug, Clone)]
pub struct Finding {
    /// Rule id (`hot-path-alloc`, `one-gram`, ...).
    pub rule: &'static str,
    /// Repo-relative file path.
    pub file: String,
    /// 1-based line of the violation.
    pub line: usize,
    /// Human-readable message.
    pub msg: String,
    /// Stable baseline key: `rule file fn=<name>` (line-insensitive so
    /// baselines survive unrelated edits).
    pub key: String,
}

/// One file ready for rule evaluation.
pub struct FileCtx<'a> {
    /// Repo-relative path (`rust/src/...`).
    pub rel: &'a str,
    /// Lexer output.
    pub lexed: &'a Lexed,
    /// Parser output.
    pub parsed: &'a Parsed,
}

fn is_punct(toks: &[Tok], i: usize, text: &str) -> bool {
    toks.get(i).is_some_and(|t| t.kind == TokKind::Punct && t.text == text)
}

fn is_open(toks: &[Tok], i: usize, text: &str) -> bool {
    toks.get(i).is_some_and(|t| t.kind == TokKind::Open && t.text == text)
}

fn is_ident(toks: &[Tok], i: usize, text: &str) -> bool {
    toks.get(i).is_some_and(|t| t.kind == TokKind::Ident && t.text == text)
}

fn fn_name_or_dash(f: Option<&FnItem>) -> String {
    match f {
        Some(f) if !f.name.is_empty() => f.name.clone(),
        _ => "-".to_string(),
    }
}

fn push(
    out: &mut Vec<Finding>,
    rule: &'static str,
    rel: &str,
    line: usize,
    fn_name: &str,
    msg: String,
) {
    out.push(Finding {
        rule,
        file: rel.to_string(),
        line,
        msg,
        key: format!("{rule} {rel} fn={fn_name}"),
    });
}

/// Result of looking for a `// lint: allow(<rule>) reason=...` marker.
enum Marker {
    Absent,
    Ok,
    MissingReason(usize),
}

/// Look for a marker comment for `rule_key` between lines `lo..=hi`.
fn find_marker(lexed: &Lexed, lo: usize, hi: usize, rule_key: &str) -> Marker {
    let want = format!("allow({rule_key})");
    for c in &lexed.comments {
        if c.line < lo || c.line > hi {
            continue;
        }
        if let Some(p) = c.text.find("lint:") {
            let rest = &c.text[p + 5..];
            if rest.contains(want.as_str()) {
                if let Some(rp) = rest.find("reason=") {
                    if !rest[rp + 7..].trim().is_empty() {
                        return Marker::Ok;
                    }
                }
                return Marker::MissingReason(c.line);
            }
        }
    }
    Marker::Absent
}

/// Marker lookup for a violation at `line`: scoped to the enclosing
/// function when there is one, otherwise to the two lines around the
/// violation (top-level items).
fn marker_for(ctx: &FileCtx, f: Option<&FnItem>, line: usize, rule_key: &str) -> Marker {
    match f {
        Some(f) => find_marker(
            ctx.lexed,
            f.span_lo().saturating_sub(3),
            f.body_close_line,
            rule_key,
        ),
        None => find_marker(ctx.lexed, line.saturating_sub(2), line + 1, rule_key),
    }
}

/// Apply a marker decision to a candidate violation.
fn flag_unless_marked(
    ctx: &FileCtx,
    out: &mut Vec<Finding>,
    rule: &'static str,
    rule_key: &str,
    line: usize,
    msg: String,
) {
    let f = enclosing_fn(ctx.parsed, line);
    let name = fn_name_or_dash(f);
    match marker_for(ctx, f, line, rule_key) {
        Marker::Ok => {}
        Marker::MissingReason(ml) => {
            let m = format!(
                "`lint: allow({rule_key})` marker is missing a non-empty `reason=`",
            );
            push(out, rule, ctx.rel, ml, &name, m);
        }
        Marker::Absent => push(out, rule, ctx.rel, line, &name, msg),
    }
}

/// **hot-path-alloc** — allocating constructs are forbidden inside the
/// declared hot-path modules unless the enclosing function carries a
/// `// lint: allow(alloc) reason=...` marker.  Complements the runtime
/// counting-allocator assertions in `rust/tests/alloc_free.rs`.
pub fn hot_path_alloc(ctx: &FileCtx, out: &mut Vec<Finding>) {
    if !config::is_hot_path(ctx.rel) {
        return;
    }
    let toks = &ctx.lexed.toks;
    for i in 0..toks.len() {
        let t = &toks[i];
        if t.kind != TokKind::Ident {
            continue;
        }
        if in_regions(&ctx.parsed.test_regions, t.line) {
            continue;
        }
        let name = t.text.as_str();
        let construct = if config::ALLOC_PATHS.contains(&name)
            && is_punct(toks, i + 1, ":")
            && is_punct(toks, i + 2, ":")
            && is_ident(toks, i + 3, "new")
        {
            Some(format!("{name}::new"))
        } else if config::ALLOC_MACROS.contains(&name) && is_punct(toks, i + 1, "!") {
            Some(format!("{name}!"))
        } else if config::ALLOC_METHODS.contains(&name)
            && i > 0
            && is_punct(toks, i - 1, ".")
            && (is_open(toks, i + 1, "(") || is_punct(toks, i + 1, ":"))
        {
            Some(format!(".{name}()"))
        } else {
            None
        };
        if let Some(c) = construct {
            let msg = format!(
                "allocating construct `{c}` in hot-path module (add \
                 `// lint: allow(alloc) reason=...` if this is an \
                 intentional cold-path allocation)",
            );
            flag_unless_marked(ctx, out, "hot-path-alloc", "alloc", t.line, msg);
        }
    }
}

/// **one-gram** — `CosineGram::build` / `.rebuild(...)` may only be
/// called from the sanctioned sites in
/// [`config::ONE_GRAM_ALLOWED`], mirroring the runtime
/// `gram_builds_this_thread()` counter.
pub fn one_gram(ctx: &FileCtx, out: &mut Vec<Finding>) {
    if ctx.rel.starts_with("rust/tests/") {
        return;
    }
    let toks = &ctx.lexed.toks;
    for i in 0..toks.len() {
        let t = &toks[i];
        if t.kind != TokKind::Ident {
            continue;
        }
        if in_regions(&ctx.parsed.test_regions, t.line) {
            continue;
        }
        let hit = if t.text == "CosineGram"
            && is_punct(toks, i + 1, ":")
            && is_punct(toks, i + 2, ":")
            && is_ident(toks, i + 3, "build")
        {
            Some("CosineGram::build")
        } else if t.text == "rebuild"
            && i > 0
            && is_punct(toks, i - 1, ".")
            && is_open(toks, i + 1, "(")
        {
            Some(".rebuild(...)")
        } else {
            None
        };
        if let Some(what) = hit {
            let f = enclosing_fn(ctx.parsed, t.line);
            let name = fn_name_or_dash(f);
            if config::one_gram_allowed(ctx.rel, &name) {
                continue;
            }
            let msg = format!(
                "`{what}` outside the sanctioned one-Gram call sites \
                 (see tools/lint/src/config.rs)",
            );
            flag_unless_marked(ctx, out, "one-gram", "one-gram", t.line, msg);
        }
    }
}

/// Collect the names of `#[deprecated]` functions defined in a file.
pub fn deprecated_names(parsed: &Parsed, into: &mut BTreeSet<String>) {
    for f in &parsed.fns {
        if f.name.is_empty() {
            continue;
        }
        if f.attrs.iter().any(|a| a.trim_start().starts_with("deprecated")) {
            into.insert(f.name.clone());
        }
    }
}

/// **deprecated-internal-use** — non-test source must not call the
/// `#[deprecated]` entry points unless the call sits under an
/// `#[allow(deprecated)]` (file, block, or item level), is itself inside
/// a deprecated wrapper, or carries an explicit marker.
pub fn deprecated_use(ctx: &FileCtx, names: &BTreeSet<String>, out: &mut Vec<Finding>) {
    if ctx.parsed.file_allows_deprecated {
        return;
    }
    let toks = &ctx.lexed.toks;
    for i in 0..toks.len() {
        let t = &toks[i];
        if t.kind != TokKind::Ident || !names.contains(&t.text) {
            continue;
        }
        // definitions are not calls
        if i > 0 && is_ident(toks, i - 1, "fn") {
            continue;
        }
        // only call syntax: `name(` or `name::<`
        if !(is_open(toks, i + 1, "(") || is_punct(toks, i + 1, ":")) {
            continue;
        }
        if in_regions(&ctx.parsed.test_regions, t.line)
            || in_regions(&ctx.parsed.allow_dep_regions, t.line)
        {
            continue;
        }
        let f = enclosing_fn(ctx.parsed, t.line);
        if let Some(f) = f {
            let sanctioned = f.attrs.iter().any(|a| {
                let a = a.trim_start();
                a.starts_with("deprecated") || (a.starts_with("allow") && a.contains("deprecated"))
            });
            if sanctioned {
                continue;
            }
        }
        let msg = format!(
            "call to `#[deprecated]` entry point `{}` from non-test source \
             (migrate to the engine/session API, or add `#[allow(deprecated)]` \
             on a wrapper that must keep exercising it)",
            t.text,
        );
        flag_unless_marked(ctx, out, "deprecated-internal-use", "deprecated", t.line, msg);
    }
}

/// **unsafe-audit** — every `unsafe` fn/impl/block needs a `// SAFETY:`
/// comment immediately around it (up to 3 lines above, trailing, or the
/// first line inside a block).
pub fn unsafe_audit(ctx: &FileCtx, out: &mut Vec<Finding>) {
    for site in &ctx.parsed.unsafe_sites {
        let lo = site.line.saturating_sub(3);
        let hi = site.line + 1;
        let documented = ctx
            .lexed
            .comments
            .iter()
            .any(|c| c.line >= lo && c.line <= hi && c.text.contains("SAFETY:"));
        if documented {
            continue;
        }
        let what = match site.kind {
            UnsafeKind::Fn => "unsafe fn",
            UnsafeKind::Impl => "unsafe impl",
            UnsafeKind::Block => "unsafe block",
        };
        let f = enclosing_fn(ctx.parsed, site.line);
        let name = fn_name_or_dash(f);
        let msg = format!("`{what}` without a `// SAFETY:` comment");
        push(out, "unsafe-audit", ctx.rel, site.line, &name, msg);
    }
}

/// Extract the receiver path of a `.lock()` call, walking back from the
/// `.` token.  Non-path receivers (`foo().lock()`) come back as a
/// position-unique placeholder so they conservatively count as distinct.
fn lock_receiver(toks: &[Tok], dot: usize) -> String {
    let mut parts: Vec<String> = Vec::new();
    let mut j = dot;
    while j > 0 {
        j -= 1;
        let t = &toks[j];
        match t.kind {
            TokKind::Ident => parts.push(t.text.clone()),
            TokKind::Punct if t.text == "." || t.text == ":" => parts.push(t.text.clone()),
            _ => break,
        }
    }
    if parts.is_empty() {
        return format!("<expr@{}>", toks[dot].line);
    }
    parts.reverse();
    parts.concat()
}

/// **lock-discipline** — a function that acquires two *different*
/// mutexes must declare the ordering with a `// lock-order: ...` comment
/// (or a `// lint: allow(lock) reason=...` marker), so pool/metrics/
/// cache interactions can't deadlock silently as pools multiply.
pub fn lock_discipline(ctx: &FileCtx, out: &mut Vec<Finding>) {
    let toks = &ctx.lexed.toks;
    // (fn sig_line, receiver, line) per .lock() call, innermost-fn owned
    let mut hits: Vec<(usize, String, usize)> = Vec::new();
    for i in 0..toks.len() {
        if toks[i].kind == TokKind::Ident
            && toks[i].text == "lock"
            && i > 0
            && is_punct(toks, i - 1, ".")
            && is_open(toks, i + 1, "(")
        {
            if in_regions(&ctx.parsed.test_regions, toks[i].line) {
                continue;
            }
            if let Some(f) = enclosing_fn(ctx.parsed, toks[i].line) {
                hits.push((f.sig_line, lock_receiver(toks, i - 1), toks[i].line));
            }
        }
    }
    let mut seen_fns: BTreeSet<usize> = BTreeSet::new();
    for &(sig, _, _) in &hits {
        if !seen_fns.insert(sig) {
            continue;
        }
        let mut recvs: Vec<String> = Vec::new();
        let mut second_line = 0usize;
        for h in hits.iter().filter(|h| h.0 == sig) {
            if !recvs.iter().any(|r| *r == h.1) {
                recvs.push(h.1.clone());
                if recvs.len() == 2 {
                    second_line = h.2;
                }
            }
        }
        if recvs.len() < 2 {
            continue;
        }
        let f = match ctx.parsed.fns.iter().find(|f| f.sig_line == sig) {
            Some(f) => f,
            None => continue,
        };
        let has_order = ctx.lexed.comments.iter().any(|c| {
            c.line >= f.span_lo().saturating_sub(3)
                && c.line <= f.body_close_line
                && c.text.contains("lock-order:")
        });
        if has_order {
            continue;
        }
        if let Marker::Ok = marker_for(ctx, Some(f), second_line, "lock") {
            continue;
        }
        let name = fn_name_or_dash(Some(f));
        let msg = format!(
            "function `{}` acquires {} different locks ({}) without a \
             `// lock-order:` comment declaring the acquisition order",
            name,
            recvs.len(),
            recvs.join(", "),
        );
        push(out, "lock-discipline", ctx.rel, second_line, &name, msg);
    }
}
