//! Light block parser over the token stream from [`crate::lexer`].
//!
//! Recovers just enough structure for the rules: function items with
//! their attributes and body spans, `#[cfg(test)] mod` regions,
//! `#![allow(deprecated)]` regions, and `unsafe` sites.  It is a single
//! forward pass with a delimiter stack — no expression parsing.

use crate::lexer::{Lexed, Tok, TokKind};

/// A function item (free fn, method, or nested fn).
#[derive(Debug, Clone)]
pub struct FnItem {
    /// Function name (empty if unnamed/unparseable).
    pub name: String,
    /// Outer attribute texts attached to the item (token texts joined
    /// with spaces, literals dropped), e.g. `"deprecated ( note = )"`.
    pub attrs: Vec<String>,
    /// Line of the first attribute (== `sig_line` when there are none).
    pub attr_line: usize,
    /// Line of the `fn` keyword.
    pub sig_line: usize,
    /// Token index of the body `{` (`usize::MAX` for bodyless decls).
    pub body_open: usize,
    /// Token index of the body `}` (`usize::MAX` for bodyless decls).
    pub body_close: usize,
    /// Line of the body `{`.
    pub body_open_line: usize,
    /// Line of the body `}`.
    pub body_close_line: usize,
}

impl FnItem {
    /// Whether `line` falls inside this item (signature or body).
    pub fn contains_line(&self, line: usize) -> bool {
        line >= self.sig_line && line <= self.body_close_line
    }

    /// First line of the item including attributes.
    pub fn span_lo(&self) -> usize {
        self.attr_line.min(self.sig_line)
    }
}

/// An inclusive line region.
#[derive(Debug, Clone, Copy)]
pub struct Region {
    /// First line of the region.
    pub start_line: usize,
    /// Last line of the region.
    pub end_line: usize,
}

impl Region {
    /// Whether `line` falls inside the region.
    pub fn contains(&self, line: usize) -> bool {
        line >= self.start_line && line <= self.end_line
    }
}

/// What kind of `unsafe` introduced a site.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum UnsafeKind {
    /// `unsafe fn`.
    Fn,
    /// `unsafe impl`.
    Impl,
    /// `unsafe { ... }` block.
    Block,
}

/// One `unsafe` occurrence.
#[derive(Debug, Clone, Copy)]
pub struct UnsafeSite {
    /// Line of the `unsafe` keyword.
    pub line: usize,
    /// Site kind.
    pub kind: UnsafeKind,
}

/// Parser output for one file.
#[derive(Debug, Default)]
pub struct Parsed {
    /// All function items, in source order (nested fns included).
    pub fns: Vec<FnItem>,
    /// Line regions of `#[cfg(test)] mod` blocks.
    pub test_regions: Vec<Region>,
    /// Line regions of blocks carrying `#![allow(deprecated)]`.
    pub allow_dep_regions: Vec<Region>,
    /// All `unsafe` fn/impl/block sites.
    pub unsafe_sites: Vec<UnsafeSite>,
    /// Whether the file carries a top-level `#![allow(deprecated)]`.
    pub file_allows_deprecated: bool,
}

fn is_punct(toks: &[Tok], i: usize, text: &str) -> bool {
    toks.get(i).is_some_and(|t| t.kind == TokKind::Punct && t.text == text)
}

fn is_open(toks: &[Tok], i: usize, text: &str) -> bool {
    toks.get(i).is_some_and(|t| t.kind == TokKind::Open && t.text == text)
}

fn is_ident(toks: &[Tok], i: usize, text: &str) -> bool {
    toks.get(i).is_some_and(|t| t.kind == TokKind::Ident && t.text == text)
}

/// Index of the delimiter closing the one opened at `open`.
fn matching(toks: &[Tok], open: usize) -> usize {
    let oc = toks[open].text.clone();
    let cc = match oc.as_str() {
        "(" => ")",
        "[" => "]",
        _ => "}",
    };
    let mut depth = 0usize;
    let mut j = open;
    while j < toks.len() {
        if toks[j].kind == TokKind::Open && toks[j].text == oc {
            depth += 1;
        } else if toks[j].kind == TokKind::Close && toks[j].text == cc {
            depth -= 1;
            if depth == 0 {
                return j;
            }
        }
        j += 1;
    }
    toks.len().saturating_sub(1)
}

/// Join attribute tokens into a matchable string (literal contents were
/// already dropped by the lexer, so strings can't spoof a match).
fn join(toks: &[Tok]) -> String {
    let mut s = String::new();
    for t in toks {
        if !s.is_empty() {
            s.push(' ');
        }
        s.push_str(&t.text);
    }
    s
}

/// Does any collected attribute mark a `#[cfg(test)]` item?
fn attrs_mark_test(attrs: &[(String, usize)]) -> bool {
    attrs
        .iter()
        .any(|(a, _)| a.contains("cfg") && a.contains("test") && !a.contains("not"))
}

/// Parse the token stream of one file.
pub fn parse(lx: &Lexed) -> Parsed {
    struct Blk {
        open_line: usize,
        test_mod: bool,
        allow_dep: bool,
    }
    let toks = &lx.toks;
    let mut p = Parsed::default();
    let mut stack: Vec<Blk> = Vec::new();
    let mut pending_attrs: Vec<(String, usize)> = Vec::new();
    let mut pending_test_mod = false;
    let mut i = 0usize;
    while i < toks.len() {
        let line = toks[i].line;
        // attributes: #[...] (outer) and #![...] (inner)
        if is_punct(toks, i, "#") {
            let inner = is_punct(toks, i + 1, "!");
            let open = if inner { i + 2 } else { i + 1 };
            if is_open(toks, open, "[") {
                let close = matching(toks, open);
                let text = join(&toks[open + 1..close]);
                if inner {
                    if text.contains("allow") && text.contains("deprecated") {
                        match stack.last_mut() {
                            Some(top) => top.allow_dep = true,
                            None => p.file_allows_deprecated = true,
                        }
                    }
                } else {
                    pending_attrs.push((text, line));
                }
                i = close + 1;
                continue;
            }
            i += 1;
            continue;
        }
        if toks[i].kind == TokKind::Ident {
            match toks[i].text.as_str() {
                "fn" => {
                    let name = match toks.get(i + 1) {
                        Some(t) if t.kind == TokKind::Ident => t.text.clone(),
                        _ => String::new(),
                    };
                    // find the body `{` (or `;` for bodyless decls) at
                    // paren depth 0 after the signature
                    let mut j = i + 1;
                    let mut depth = 0isize;
                    let mut body_open = None;
                    while j < toks.len() {
                        match toks[j].kind {
                            TokKind::Open => {
                                if toks[j].text == "{" && depth == 0 {
                                    body_open = Some(j);
                                    break;
                                }
                                depth += 1;
                            }
                            TokKind::Close => depth -= 1,
                            TokKind::Punct => {
                                if toks[j].text == ";" && depth == 0 {
                                    break;
                                }
                            }
                            _ => {}
                        }
                        j += 1;
                    }
                    let attrs: Vec<String> =
                        pending_attrs.iter().map(|(a, _)| a.clone()).collect();
                    let attr_line =
                        pending_attrs.first().map(|(_, l)| *l).unwrap_or(line);
                    pending_attrs.clear();
                    let item = match body_open {
                        Some(bo) => {
                            let bc = matching(toks, bo);
                            FnItem {
                                name,
                                attrs,
                                attr_line,
                                sig_line: line,
                                body_open: bo,
                                body_close: bc,
                                body_open_line: toks[bo].line,
                                body_close_line: toks[bc].line,
                            }
                        }
                        None => FnItem {
                            name,
                            attrs,
                            attr_line,
                            sig_line: line,
                            body_open: usize::MAX,
                            body_close: usize::MAX,
                            body_open_line: line,
                            body_close_line: line,
                        },
                    };
                    p.fns.push(item);
                    i += 1;
                    continue;
                }
                "mod" => {
                    if attrs_mark_test(&pending_attrs) {
                        pending_test_mod = true;
                    }
                    pending_attrs.clear();
                    i += 1;
                    continue;
                }
                "unsafe" => {
                    let kind = if is_ident(toks, i + 1, "fn") {
                        Some(UnsafeKind::Fn)
                    } else if is_ident(toks, i + 1, "impl") {
                        Some(UnsafeKind::Impl)
                    } else if is_open(toks, i + 1, "{") {
                        Some(UnsafeKind::Block)
                    } else {
                        None
                    };
                    if let Some(k) = kind {
                        p.unsafe_sites.push(UnsafeSite { line, kind: k });
                    }
                    i += 1;
                    continue;
                }
                _ => {
                    i += 1;
                    continue;
                }
            }
        }
        if toks[i].kind == TokKind::Open && toks[i].text == "{" {
            stack.push(Blk {
                open_line: line,
                test_mod: pending_test_mod,
                allow_dep: false,
            });
            pending_test_mod = false;
            pending_attrs.clear();
            i += 1;
            continue;
        }
        if toks[i].kind == TokKind::Close && toks[i].text == "}" {
            if let Some(b) = stack.pop() {
                if b.test_mod {
                    p.test_regions.push(Region {
                        start_line: b.open_line,
                        end_line: line,
                    });
                }
                if b.allow_dep {
                    p.allow_dep_regions.push(Region {
                        start_line: b.open_line,
                        end_line: line,
                    });
                }
            }
            pending_attrs.clear();
            i += 1;
            continue;
        }
        if is_punct(toks, i, ";") {
            pending_attrs.clear();
            pending_test_mod = false; // `#[cfg(test)] mod foo;` declaration
        }
        i += 1;
    }
    p
}

/// Innermost function item containing `line`, if any.
pub fn enclosing_fn(parsed: &Parsed, line: usize) -> Option<&FnItem> {
    parsed
        .fns
        .iter()
        .filter(|f| f.contains_line(line))
        .min_by_key(|f| f.body_close_line.saturating_sub(f.sig_line))
}

/// Whether `line` falls inside any of `regions`.
pub fn in_regions(regions: &[Region], line: usize) -> bool {
    regions.iter().any(|r| r.contains(line))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lexer::lex;

    #[test]
    fn fn_items_and_attrs() {
        let src = "#[deprecated(note = \"old\")]\npub fn old_api(x: u32) -> u32 {\n    x\n}\n\npub fn fresh() {}\n";
        let p = parse(&lex(src));
        assert_eq!(p.fns.len(), 2);
        assert_eq!(p.fns[0].name, "old_api");
        assert!(p.fns[0].attrs[0].starts_with("deprecated"));
        assert_eq!(p.fns[0].attr_line, 1);
        assert_eq!(p.fns[0].sig_line, 2);
        assert_eq!(p.fns[0].body_close_line, 4);
        assert_eq!(p.fns[1].name, "fresh");
        assert!(p.fns[1].attrs.is_empty(), "attrs must not leak across items");
    }

    #[test]
    fn test_mod_region_detected() {
        let src = "fn a() {}\n#[cfg(test)]\nmod tests {\n    fn b() {}\n}\n";
        let p = parse(&lex(src));
        assert_eq!(p.test_regions.len(), 1);
        assert!(in_regions(&p.test_regions, 4));
        assert!(!in_regions(&p.test_regions, 1));
    }

    #[test]
    fn cfg_not_test_is_not_a_test_region() {
        let src = "#[cfg(not(test))]\nmod real {\n    fn b() {}\n}\n";
        let p = parse(&lex(src));
        assert!(p.test_regions.is_empty());
    }

    #[test]
    fn inner_allow_deprecated_regions() {
        let src = "mod legacy {\n    #![allow(deprecated)]\n    fn c() {}\n}\nfn d() {}\n";
        let p = parse(&lex(src));
        assert_eq!(p.allow_dep_regions.len(), 1);
        assert!(in_regions(&p.allow_dep_regions, 3));
        assert!(!in_regions(&p.allow_dep_regions, 5));
        assert!(!p.file_allows_deprecated);
        let p2 = parse(&lex("#![allow(deprecated)]\nfn e() {}\n"));
        assert!(p2.file_allows_deprecated);
    }

    #[test]
    fn unsafe_sites_classified() {
        let src = "unsafe impl Send for X {}\nunsafe fn f() {}\nfn g() { unsafe { h() } }\n";
        let p = parse(&lex(src));
        let kinds: Vec<UnsafeKind> = p.unsafe_sites.iter().map(|s| s.kind).collect();
        assert_eq!(kinds, vec![UnsafeKind::Impl, UnsafeKind::Fn, UnsafeKind::Block]);
    }

    #[test]
    fn enclosing_fn_is_innermost() {
        let src = "fn outer() {\n    fn inner() {\n        body();\n    }\n}\n";
        let p = parse(&lex(src));
        assert_eq!(enclosing_fn(&p, 3).unwrap().name, "inner");
        assert_eq!(enclosing_fn(&p, 5).unwrap().name, "outer");
    }
}
