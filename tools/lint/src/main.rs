//! `pitome-lint` CLI.
//!
//! ```text
//! cargo run -p pitome-lint -- check [--root DIR] [--baseline FILE]
//!                                   [--write-baseline]
//! cargo run -p pitome-lint -- selftest
//! ```
//!
//! `check` lints `rust/src`, `rust/benches`, and `rust/tests` under the
//! workspace root, filters findings through the checked-in baseline
//! (`tools/lint/baseline.txt`), prints rustc-style diagnostics, and
//! exits nonzero on any active finding.  `selftest` runs the embedded
//! fixture suite (each rule × seeded violation + clean near-miss).

use std::path::PathBuf;
use std::process::ExitCode;

use pitome_lint::{baseline, collect_repo_files, fixtures, lint_sources};

fn find_root() -> PathBuf {
    let mut dir = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
    loop {
        if dir.join("rust/src").is_dir() && dir.join("Cargo.toml").is_file() {
            return dir;
        }
        if !dir.pop() {
            return PathBuf::from(".");
        }
    }
}

fn usage() -> ExitCode {
    eprintln!(
        "usage: pitome-lint <check|selftest> [--root DIR] [--baseline FILE] \
         [--write-baseline]"
    );
    ExitCode::from(2)
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut cmd: Option<String> = None;
    let mut root: Option<PathBuf> = None;
    let mut baseline_path: Option<PathBuf> = None;
    let mut write_baseline = false;
    let mut i = 0usize;
    while i < args.len() {
        match args[i].as_str() {
            "--root" => {
                i += 1;
                root = args.get(i).map(PathBuf::from);
            }
            "--baseline" => {
                i += 1;
                baseline_path = args.get(i).map(PathBuf::from);
            }
            "--write-baseline" => write_baseline = true,
            a if a.starts_with('-') => return usage(),
            a => {
                if cmd.is_some() {
                    return usage();
                }
                cmd = Some(a.to_string());
            }
        }
        i += 1;
    }
    match cmd.as_deref().unwrap_or("check") {
        "selftest" => {
            let failures = fixtures::run_all();
            let total = fixtures::FIXTURES.len();
            if failures.is_empty() {
                println!("pitome-lint selftest: {total}/{total} fixtures ok");
                ExitCode::SUCCESS
            } else {
                for f in &failures {
                    eprintln!("selftest failure: {f}");
                }
                eprintln!(
                    "pitome-lint selftest: {}/{} fixtures ok",
                    total - failures.len(),
                    total
                );
                ExitCode::FAILURE
            }
        }
        "check" => {
            let root = root.unwrap_or_else(find_root);
            let bpath =
                baseline_path.unwrap_or_else(|| root.join("tools/lint/baseline.txt"));
            let files = match collect_repo_files(&root) {
                Ok(f) => f,
                Err(e) => {
                    eprintln!("pitome-lint: cannot read tree under {}: {e}", root.display());
                    return ExitCode::FAILURE;
                }
            };
            if files.is_empty() {
                eprintln!(
                    "pitome-lint: no .rs files under {} (wrong --root?)",
                    root.display()
                );
                return ExitCode::FAILURE;
            }
            let findings = lint_sources(&files);
            if write_baseline {
                let text = baseline::render(&findings);
                if let Err(e) = std::fs::write(&bpath, text) {
                    eprintln!("pitome-lint: cannot write {}: {e}", bpath.display());
                    return ExitCode::FAILURE;
                }
                println!(
                    "pitome-lint: wrote {} baseline keys to {}",
                    findings.len(),
                    bpath.display()
                );
                return ExitCode::SUCCESS;
            }
            let keys = baseline::load(&bpath);
            let applied = baseline::apply(findings, &keys);
            for f in &applied.active {
                println!("error[{}]: {}", f.rule, f.msg);
                println!("  --> {}:{}", f.file, f.line);
            }
            for k in &applied.unused {
                println!("warning: stale baseline entry (fixed? remove it): {k}");
            }
            println!(
                "pitome-lint: {} file(s), {} violation(s), {} baselined, \
                 {} stale baseline entr(ies)",
                files.len(),
                applied.active.len(),
                applied.suppressed,
                applied.unused.len()
            );
            if applied.active.is_empty() {
                ExitCode::SUCCESS
            } else {
                ExitCode::FAILURE
            }
        }
        _ => usage(),
    }
}
