//! Repo-specific rule configuration: the declared hot-path module list
//! and the sanctioned `CosineGram` build sites.
//!
//! Paths are matched against repo-relative file paths like
//! `rust/src/merge/plan.rs`; entries ending in `/` are directory
//! prefixes, all others are suffix matches.

/// Modules whose steady-state loops must stay allocation-free
/// (statically complementing `rust/tests/alloc_free.rs`).
pub const HOT_PATH_MODULES: &[&str] = &[
    "src/tensor/ops.rs",
    "src/merge/",
    "src/model/encoder.rs",
    "src/engine/",
    "src/coordinator/pool.rs",
    // the batching loop and its work-stealing joint fan-out: every warmed
    // cycle through these workers must allocate nothing
    "src/coordinator/batcher.rs",
    // admission control rides the submit path: routing (ladder shedding),
    // deadline stamping, and the non-blocking shed decision must all stay
    // allocation-free or overload handling itself becomes the bottleneck
    "src/coordinator/router.rs",
    "src/coordinator/server.rs",
    // the embedding gallery: the blocked scan, bounded top-k selection
    // and k-way merge are the per-query serving path — a warmed
    // query→top-k cycle must allocate nothing
    "src/gallery/",
    // the tracing spine rides every one of the modules above: span
    // recording and merge telemetry must stay atomic-store-only, with
    // ring/export allocations confined to marked cold constructors
    "src/obs/",
];

/// Sanctioned `CosineGram::build` / `.rebuild(...)` call sites, as
/// `(path suffix, function name)` pairs; `"*"` sanctions a whole file.
/// This mirrors the runtime `gram_builds_this_thread()` counter: exactly
/// one Gram build per merge/coarsen step, owned by the dispatch points
/// below, plus the allocating convenience wrappers that the hot path
/// never calls.
pub const ONE_GRAM_ALLOWED: &[(&str, &str)] = &[
    // defining module (build/rebuild themselves, cosine_matrix helper)
    ("src/tensor/ops.rs", "*"),
    // allocating convenience wrappers that build their own Gram
    ("src/merge/pitome.rs", "ordered_bsm_plan"),
    ("src/merge/tome.rs", "tome_plan"),
    ("src/merge/diffrate.rs", "diffrate_plan"),
    ("src/merge/energy.rs", "energy_scores"),
    // the two hot-path dispatch points: one build/rebuild per merge step
    ("src/merge/mod.rs", "merge_step"),
    ("src/merge/mod.rs", "merge_step_scratch"),
    // one rebuild per spectral coarsening step
    ("src/eval/spectral.rs", "iterative_coarsen_scratch"),
];

/// Allocating constructs forbidden on hot paths: `Path::new`-style calls.
pub const ALLOC_PATHS: &[&str] = &["Vec", "Box", "String"];

/// Allocating constructs forbidden on hot paths: macros.
pub const ALLOC_MACROS: &[&str] = &["vec", "format"];

/// Allocating constructs forbidden on hot paths: method calls.
pub const ALLOC_METHODS: &[&str] = &["to_vec", "clone", "collect"];

/// Whether `rel` is inside the declared hot-path module list.
pub fn is_hot_path(rel: &str) -> bool {
    HOT_PATH_MODULES.iter().any(|m| {
        if m.ends_with('/') {
            rel.contains(m)
        } else {
            rel.ends_with(m)
        }
    })
}

/// Whether `(rel, fn_name)` is a sanctioned Gram build site.
pub fn one_gram_allowed(rel: &str, fn_name: &str) -> bool {
    ONE_GRAM_ALLOWED
        .iter()
        .any(|(path, f)| rel.ends_with(path) && (*f == "*" || *f == fn_name))
}
