//! `pitome-lint` — offline static analysis for the PiToMe repo.
//!
//! Enforces the serving stack's load-bearing invariants at the source
//! level, complementing the runtime counting-allocator and parity tests
//! (`rust/tests/alloc_free.rs`, `rust/tests/prop_engine.rs`):
//!
//! * **hot-path-alloc** — no allocating constructs inside the declared
//!   hot-path modules without an explicit `// lint: allow(alloc)
//!   reason=...` marker.
//! * **one-gram** — `CosineGram::build`/`.rebuild` only at sanctioned
//!   call sites (one Gram per merge/coarsen step).
//! * **deprecated-internal-use** — non-test source must not call
//!   `#[deprecated]` entry points.
//! * **unsafe-audit** — every `unsafe` fn/impl/block carries a
//!   `// SAFETY:` comment.
//! * **lock-discipline** — multi-mutex functions declare their
//!   acquisition order with a `// lock-order:` comment.
//!
//! The crate is dependency-free: a hand-rolled lexer ([`lexer`]) and
//! block parser ([`parse`]) feed the rule engine ([`rules`]); a
//! checked-in baseline ([`baseline`]) triages pre-existing findings and
//! embedded fixtures ([`fixtures`]) self-test every rule.

pub mod baseline;
pub mod config;
pub mod fixtures;
pub mod lexer;
pub mod parse;
pub mod rules;

use std::collections::BTreeSet;
use std::path::Path;

use rules::{FileCtx, Finding};

/// One source file to lint.
pub struct SourceFile {
    /// Repo-relative path with forward slashes (`rust/src/...`).
    pub rel: String,
    /// File contents.
    pub text: String,
}

/// Lint a set of sources; findings are sorted and deduplicated.
pub fn lint_sources(files: &[SourceFile]) -> Vec<Finding> {
    let prepped: Vec<(&str, lexer::Lexed, parse::Parsed)> = files
        .iter()
        .map(|f| {
            let lx = lexer::lex(&f.text);
            let p = parse::parse(&lx);
            (f.rel.as_str(), lx, p)
        })
        .collect();
    let mut deprecated: BTreeSet<String> = BTreeSet::new();
    for (_, _, p) in &prepped {
        rules::deprecated_names(p, &mut deprecated);
    }
    let mut out: Vec<Finding> = Vec::new();
    for (rel, lx, p) in &prepped {
        let ctx = FileCtx {
            rel,
            lexed: lx,
            parsed: p,
        };
        rules::hot_path_alloc(&ctx, &mut out);
        rules::one_gram(&ctx, &mut out);
        rules::deprecated_use(&ctx, &deprecated, &mut out);
        rules::unsafe_audit(&ctx, &mut out);
        rules::lock_discipline(&ctx, &mut out);
    }
    out.sort_by(|a, b| {
        (&a.file, a.line, a.rule, &a.msg).cmp(&(&b.file, b.line, b.rule, &b.msg))
    });
    out.dedup_by(|a, b| {
        a.file == b.file && a.line == b.line && a.rule == b.rule && a.msg == b.msg
    });
    out
}

/// Collect the lintable tree under `root`: `rust/src`, `rust/benches`,
/// `rust/tests` (vendored stubs are deliberately out of scope).
pub fn collect_repo_files(root: &Path) -> std::io::Result<Vec<SourceFile>> {
    let mut files = Vec::new();
    for sub in ["rust/src", "rust/benches", "rust/tests"] {
        let dir = root.join(sub);
        if dir.is_dir() {
            walk(&dir, root, &mut files)?;
        }
    }
    files.sort_by(|a, b| a.rel.cmp(&b.rel));
    Ok(files)
}

fn walk(dir: &Path, root: &Path, out: &mut Vec<SourceFile>) -> std::io::Result<()> {
    for entry in std::fs::read_dir(dir)? {
        let path = entry?.path();
        if path.is_dir() {
            walk(&path, root, out)?;
        } else if path.extension().map(|x| x == "rs").unwrap_or(false) {
            let rel = path
                .strip_prefix(root)
                .unwrap_or(&path)
                .to_string_lossy()
                .replace('\\', "/");
            out.push(SourceFile {
                rel,
                text: std::fs::read_to_string(&path)?,
            });
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn findings_are_sorted_and_deduped() {
        let files = vec![SourceFile {
            rel: "rust/src/merge/x.rs".to_string(),
            text: "pub fn a() { let v = vec![1]; }\npub fn b() { let w = vec![2]; }\n"
                .to_string(),
        }];
        let fs = lint_sources(&files);
        assert_eq!(fs.len(), 2);
        assert!(fs[0].line <= fs[1].line);
        assert!(fs.iter().all(|f| f.rule == "hot-path-alloc"));
        assert_eq!(fs[0].key, "hot-path-alloc rust/src/merge/x.rs fn=a");
    }
}
