//! Minimal hand-rolled Rust lexer.
//!
//! Produces a flat token stream (identifiers, punctuation, delimiters,
//! opaque literals) plus a side channel of comments with their line
//! numbers.  String/char literal *contents* are deliberately dropped so
//! that rule matching (`contains("deprecated")`, `CosineGram :: build`,
//! ...) can never be fooled by text inside a literal.  Lifetimes are
//! consumed and discarded; doc comments land in the comment channel like
//! any other comment.
//!
//! This is not a full Rust lexer — it only needs to be faithful enough
//! for block structure (brace matching), attribute text, and the handful
//! of token patterns the rules in [`crate::rules`] look for.

/// Kind of a lexed token.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TokKind {
    /// Identifier or keyword (`fn`, `Vec`, `self`, ...).
    Ident,
    /// Single punctuation character (`.`, `:`, `#`, `!`, `;`, ...).
    Punct,
    /// Opening delimiter: one of `(`, `[`, `{`.
    Open,
    /// Closing delimiter: one of `)`, `]`, `}`.
    Close,
    /// String/char/number literal (contents dropped, `text` is empty).
    Lit,
}

/// One source token with its 1-based line number.
#[derive(Debug, Clone)]
pub struct Tok {
    /// Token class.
    pub kind: TokKind,
    /// Token text (empty for [`TokKind::Lit`]).
    pub text: String,
    /// 1-based source line the token starts on.
    pub line: usize,
}

/// One comment (line, block, or doc) with markers stripped.
#[derive(Debug, Clone)]
pub struct Comment {
    /// 1-based line the comment starts on.
    pub line: usize,
    /// Comment text without the `//` / `/* */` markers, trimmed.
    pub text: String,
}

/// Lexer output: the token stream plus all comments.
#[derive(Debug, Default)]
pub struct Lexed {
    /// Code tokens in source order.
    pub toks: Vec<Tok>,
    /// Comments in source order.
    pub comments: Vec<Comment>,
}

/// If `cs[i]` starts a raw string (`r"`, `r#"`, `br#"` ...), return the
/// index one past its closing quote+hashes; otherwise `None`.
fn raw_string_end(cs: &[char], i: usize) -> Option<usize> {
    let mut j = i;
    if j < cs.len() && cs[j] == 'b' {
        j += 1;
    }
    if j >= cs.len() || cs[j] != 'r' {
        return None;
    }
    j += 1;
    let mut hashes = 0usize;
    while j < cs.len() && cs[j] == '#' {
        hashes += 1;
        j += 1;
    }
    if j >= cs.len() || cs[j] != '"' {
        return None;
    }
    j += 1;
    while j < cs.len() {
        if cs[j] == '"' {
            let mut k = j + 1;
            let mut h = 0usize;
            while k < cs.len() && h < hashes && cs[k] == '#' {
                h += 1;
                k += 1;
            }
            if h == hashes {
                return Some(k);
            }
        }
        j += 1;
    }
    Some(cs.len())
}

/// Skip a normal `"..."` string starting at the opening quote index;
/// returns the index one past the closing quote and bumps `line` for any
/// embedded newlines.
fn skip_string(cs: &[char], quote: usize, line: &mut usize) -> usize {
    let mut j = quote + 1;
    while j < cs.len() {
        match cs[j] {
            '\\' => j += 2,
            '"' => return j + 1,
            '\n' => {
                *line += 1;
                j += 1;
            }
            _ => j += 1,
        }
    }
    j
}

/// Lex `src` into tokens + comments.
pub fn lex(src: &str) -> Lexed {
    let cs: Vec<char> = src.chars().collect();
    let n = cs.len();
    let mut out = Lexed::default();
    let mut i = 0usize;
    let mut line = 1usize;
    while i < n {
        let c = cs[i];
        if c == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c.is_whitespace() {
            i += 1;
            continue;
        }
        // line comment (incl. /// and //! doc comments)
        if c == '/' && i + 1 < n && cs[i + 1] == '/' {
            let mut j = i + 2;
            while j < n && (cs[j] == '/' || cs[j] == '!') {
                j += 1;
            }
            let start = j;
            while j < n && cs[j] != '\n' {
                j += 1;
            }
            let text: String = cs[start..j].iter().collect();
            out.comments.push(Comment {
                line,
                text: text.trim().to_string(),
            });
            i = j;
            continue;
        }
        // block comment (Rust block comments nest)
        if c == '/' && i + 1 < n && cs[i + 1] == '*' {
            let start_line = line;
            let mut depth = 1usize;
            let mut j = i + 2;
            let mut text = String::new();
            while j < n && depth > 0 {
                if cs[j] == '/' && j + 1 < n && cs[j + 1] == '*' {
                    depth += 1;
                    j += 2;
                    continue;
                }
                if cs[j] == '*' && j + 1 < n && cs[j + 1] == '/' {
                    depth -= 1;
                    j += 2;
                    continue;
                }
                if cs[j] == '\n' {
                    line += 1;
                }
                text.push(cs[j]);
                j += 1;
            }
            out.comments.push(Comment {
                line: start_line,
                text: text.trim().to_string(),
            });
            i = j;
            continue;
        }
        // raw strings: r"..." / r#"..."# / br"..." ...
        if c == 'r' || c == 'b' {
            if let Some(end) = raw_string_end(&cs, i) {
                let start_line = line;
                for k in i..end.min(n) {
                    if cs[k] == '\n' {
                        line += 1;
                    }
                }
                out.toks.push(Tok {
                    kind: TokKind::Lit,
                    text: String::new(),
                    line: start_line,
                });
                i = end;
                continue;
            }
            if c == 'b' && i + 1 < n && cs[i + 1] == '"' {
                let start_line = line;
                i = skip_string(&cs, i + 1, &mut line);
                out.toks.push(Tok {
                    kind: TokKind::Lit,
                    text: String::new(),
                    line: start_line,
                });
                continue;
            }
        }
        // normal string
        if c == '"' {
            let start_line = line;
            i = skip_string(&cs, i, &mut line);
            out.toks.push(Tok {
                kind: TokKind::Lit,
                text: String::new(),
                line: start_line,
            });
            continue;
        }
        // char literal vs lifetime
        if c == '\'' {
            if i + 1 < n && cs[i + 1] == '\\' {
                // escaped char literal: skip to closing quote
                let mut j = i + 2;
                while j < n && cs[j] != '\'' {
                    j += 1;
                }
                out.toks.push(Tok {
                    kind: TokKind::Lit,
                    text: String::new(),
                    line,
                });
                i = j + 1;
                continue;
            }
            if i + 2 < n && cs[i + 2] == '\'' {
                // plain 'x' char literal
                out.toks.push(Tok {
                    kind: TokKind::Lit,
                    text: String::new(),
                    line,
                });
                i += 3;
                continue;
            }
            // lifetime: consume quote + identifier, emit nothing
            let mut j = i + 1;
            while j < n && (cs[j].is_alphanumeric() || cs[j] == '_') {
                j += 1;
            }
            i = j.max(i + 1);
            continue;
        }
        // identifier / keyword
        if c.is_alphabetic() || c == '_' {
            let mut j = i;
            while j < n && (cs[j].is_alphanumeric() || cs[j] == '_') {
                j += 1;
            }
            let text: String = cs[i..j].iter().collect();
            out.toks.push(Tok {
                kind: TokKind::Ident,
                text,
                line,
            });
            i = j;
            continue;
        }
        // number literal (dots only when followed by a digit, so `0..n`
        // still yields two `.` puncts)
        if c.is_ascii_digit() {
            let mut j = i;
            while j < n {
                let d = cs[j];
                if d.is_alphanumeric() || d == '_' {
                    j += 1;
                    continue;
                }
                if d == '.' && j + 1 < n && cs[j + 1].is_ascii_digit() {
                    j += 2;
                    continue;
                }
                break;
            }
            out.toks.push(Tok {
                kind: TokKind::Lit,
                text: String::new(),
                line,
            });
            i = j;
            continue;
        }
        // delimiters and single-char punctuation
        let kind = match c {
            '(' | '[' | '{' => TokKind::Open,
            ')' | ']' | '}' => TokKind::Close,
            _ => TokKind::Punct,
        };
        out.toks.push(Tok {
            kind,
            text: c.to_string(),
            line,
        });
        i += 1;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn comments_and_strings_are_side_channeled() {
        let lx = lex("// top\nfn a() { let s = \"vec![]\"; } /* block */\n");
        assert_eq!(lx.comments.len(), 2);
        assert_eq!(lx.comments[0].text, "top");
        assert_eq!(lx.comments[1].text, "block");
        // the vec![] inside the string must NOT appear as tokens
        assert!(!lx.toks.iter().any(|t| t.text == "vec"));
        assert!(lx.toks.iter().any(|t| t.text == "fn" && t.line == 2));
    }

    #[test]
    fn raw_strings_and_lifetimes() {
        let lx = lex("fn f<'a>(x: &'a str) -> &'a str { r#\"clone()\"# ; x }");
        assert!(!lx.toks.iter().any(|t| t.text == "clone"));
        assert!(lx.toks.iter().any(|t| t.text == "fn"));
    }

    #[test]
    fn char_literal_is_not_a_lifetime() {
        let lx = lex("let c = 'x'; let nl = '\\n'; let lt: &'static str = s;");
        let idents: Vec<&str> =
            lx.toks.iter().filter(|t| t.kind == TokKind::Ident).map(|t| t.text.as_str()).collect();
        assert!(idents.contains(&"c"));
        // 'static consumed as lifetime, not an ident
        assert!(!idents.contains(&"static"));
    }

    #[test]
    fn number_range_keeps_dot_puncts() {
        let lx = lex("for i in 0..n.len() {}");
        let dots = lx.toks.iter().filter(|t| t.text == ".").count();
        assert_eq!(dots, 3); // two from `..`, one from `n.len`
    }
}
