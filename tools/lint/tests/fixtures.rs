//! Rule self-tests: every embedded fixture (seeded violation + clean
//! near-miss per rule) must behave as declared, and the baseline
//! mechanism must suppress a seeded violation end-to-end.

use pitome_lint::fixtures::{run_fixture, FIXTURES};
use pitome_lint::{baseline, lint_sources, SourceFile};

#[test]
fn every_fixture_behaves_as_declared() {
    let mut failures = Vec::new();
    for fx in FIXTURES {
        if let Err(e) = run_fixture(fx) {
            failures.push(e);
        }
    }
    assert!(failures.is_empty(), "fixture failures:\n{}", failures.join("\n"));
}

#[test]
fn each_rule_has_a_firing_and_a_quiet_fixture() {
    for rule in [
        "hot-path-alloc",
        "one-gram",
        "deprecated-internal-use",
        "unsafe-audit",
        "lock-discipline",
    ] {
        let fires = FIXTURES.iter().any(|f| f.rule == rule && f.should_fire);
        let quiet = FIXTURES.iter().any(|f| f.rule == rule && !f.should_fire);
        assert!(fires, "rule {rule} has no seeded-violation fixture");
        assert!(quiet, "rule {rule} has no clean near-miss fixture");
    }
}

#[test]
fn baseline_suppresses_a_seeded_violation_end_to_end() {
    let files = vec![SourceFile {
        rel: "rust/src/merge/seeded.rs".to_string(),
        text: "pub fn stray(xs: &[f32]) -> Vec<f32> {\n    xs.to_vec()\n}\n".to_string(),
    }];
    let findings = lint_sources(&files);
    assert_eq!(findings.len(), 1, "seeded violation must fire");
    // capture into a baseline, re-apply: nothing active, nothing stale
    let keys = baseline::parse(&baseline::render(&findings));
    let applied = baseline::apply(lint_sources(&files), &keys);
    assert!(applied.active.is_empty());
    assert_eq!(applied.suppressed, 1);
    assert!(applied.unused.is_empty());
    // a fixed tree makes the entry stale
    let clean = vec![SourceFile {
        rel: "rust/src/merge/seeded.rs".to_string(),
        text: "pub fn stray(xs: &[f32], out: &mut Vec<f32>) {\n    \
               out.extend_from_slice(xs);\n}\n"
            .to_string(),
    }];
    let applied = baseline::apply(lint_sources(&clean), &keys);
    assert!(applied.active.is_empty());
    assert_eq!(applied.unused.len(), 1);
}
