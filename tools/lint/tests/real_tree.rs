//! The real tree must lint clean modulo the checked-in baseline — this
//! is the same gate CI runs via `cargo run -p pitome-lint -- check`.

use std::path::PathBuf;

use pitome_lint::{baseline, collect_repo_files, lint_sources};

fn repo_root() -> PathBuf {
    // tools/lint/ -> workspace root
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../..")
}

#[test]
fn repo_lints_clean_modulo_baseline() {
    let root = repo_root();
    let files = collect_repo_files(&root).expect("read repo tree");
    assert!(
        files.len() > 40,
        "expected the full rust tree, got {} files",
        files.len()
    );
    let findings = lint_sources(&files);
    let keys = baseline::load(&root.join("tools/lint/baseline.txt"));
    let applied = baseline::apply(findings, &keys);
    let rendered: Vec<String> = applied
        .active
        .iter()
        .map(|f| format!("error[{}] {}:{}: {}", f.rule, f.file, f.line, f.msg))
        .collect();
    assert!(
        applied.active.is_empty(),
        "pitome-lint found {} non-baselined violation(s):\n{}",
        applied.active.len(),
        rendered.join("\n")
    );
    assert!(
        applied.unused.is_empty(),
        "stale baseline entries (fixed findings — remove them):\n{}",
        applied.unused.join("\n")
    );
}

#[test]
fn tree_contains_known_invariant_anchors() {
    // sanity: the scan actually sees the hot-path modules and the
    // one-gram dispatch point, so a path refactor can't silently turn
    // the whole check into a no-op
    let files = collect_repo_files(&repo_root()).expect("read repo tree");
    for anchor in [
        "rust/src/tensor/ops.rs",
        "rust/src/merge/mod.rs",
        "rust/src/model/encoder.rs",
        "rust/src/coordinator/pool.rs",
        "rust/src/gallery/scan.rs",
        "rust/src/obs/ring.rs",
        "rust/src/util/alloc.rs",
    ] {
        assert!(
            files.iter().any(|f| f.rel == anchor),
            "expected {anchor} in the lint scan"
        );
    }
}
