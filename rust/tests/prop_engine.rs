//! Bitwise parity between the owning `Engine`/`Session` API and every
//! deprecated legacy entry point, across all ten merge modes:
//!
//! * `Session::forward_batch` vs `encoder_forward_batch[_pooled]`
//!   (identical per-(layer, sample) seeding — stochastic modes included);
//! * `Session::forward_one` vs `encoder_forward` /
//!   `encoder_forward_scratch` (identical shared-RNG stream);
//! * `VitSession` vs `ViTModel::{features,logits,predict}_batch[_pooled]`
//!   and the single-sample `ViTModel::{features,logits,predict}`;
//! * `BertSession` vs `bert_logits_batch[_pooled]`.
//!
//! Plus the stale-pool regression: one session driven through growing and
//! shrinking batch sizes must match fresh sessions exactly, and inputs
//! whose shape contradicts the config must be rejected.
#![allow(deprecated)]

use pitome::config::{TextConfig, ViTConfig};
use pitome::data::Rng;
use pitome::engine::Engine;
use pitome::model::{bert_logits_batch, bert_logits_batch_pooled,
                    encoder_forward, encoder_forward_batch,
                    encoder_forward_batch_pooled, encoder_forward_scratch,
                    synthetic_vit_store, EncoderCfg, EncoderScratch,
                    ParamEntry, ParamStore, ScratchPool, ViTModel};
use pitome::tensor::Mat;

/// Every mode the encoder can run (paper modes + ablations + baselines).
const MODES: &[&str] = &[
    "none", "pitome", "pitome_noprot", "pitome_rand", "pitome_attn",
    "tome", "tofu", "dct", "diffrate", "random",
];

fn vit_cfg(mode: &str) -> ViTConfig {
    ViTConfig { merge_mode: mode.into(), merge_r: 0.9, ..Default::default() }
}

fn random_input(n: usize, dim: usize, seed: u64) -> Mat {
    let mut rng = Rng::new(seed);
    Mat::from_fn(n, dim, |_, _| (rng.next_f64() * 0.2 - 0.1) as f32)
}

fn random_patches(vcfg: &ViTConfig, seed: u64) -> Mat {
    random_input(vcfg.num_patches(), vcfg.patch_dim(), seed)
}

#[test]
fn session_forward_batch_matches_batch_wrappers_in_every_mode() {
    for &mode in MODES {
        let vcfg = vit_cfg(mode);
        let ps = synthetic_vit_store(&vcfg, 42);
        let cfg = EncoderCfg::from_vit(&vcfg);
        let xs: Vec<Mat> = (0..4)
            .map(|i| random_input(cfg.plan[0], cfg.dim, 10 + i))
            .collect();
        let mut pool = ScratchPool::new();
        let want_pooled = encoder_forward_batch_pooled(
            &ps, &cfg, xs.clone(), 9, 2, &mut pool).unwrap();
        let want_plain =
            encoder_forward_batch(&ps, &cfg, xs.clone(), 9, 2).unwrap();

        let engine = Engine::from_store(synthetic_vit_store(&vcfg, 42));
        let mut sess = engine.session(cfg).unwrap();
        sess.set_workers(2);
        let got = sess.forward_batch(&xs, 9).unwrap();
        assert_eq!(got.len(), want_pooled.len());
        for (i, g) in got.iter().enumerate() {
            assert!(g.max_abs_diff(&want_pooled[i]) == 0.0,
                    "{mode} sample {i}: session != batch_pooled wrapper");
            assert!(g.max_abs_diff(&want_plain[i]) == 0.0,
                    "{mode} sample {i}: session != batch wrapper");
        }
    }
}

#[test]
fn session_forward_one_matches_serial_wrappers_in_every_mode() {
    for &mode in MODES {
        let vcfg = vit_cfg(mode);
        let ps = synthetic_vit_store(&vcfg, 7);
        let cfg = EncoderCfg::from_vit(&vcfg);
        let engine = Engine::from_store(synthetic_vit_store(&vcfg, 7));
        let mut sess = engine.session(cfg.clone()).unwrap();
        let mut scratch = EncoderScratch::new();
        // three trials through ONE session: the shared RNG stream and the
        // wrappers' streams must stay in lockstep (stochastic modes too)
        for trial in 0..3u64 {
            let x = random_input(cfg.plan[0], cfg.dim, 20 + trial);
            let mut r1 = Rng::new(trial);
            let want = encoder_forward(&ps, &cfg, x.clone(), &mut r1).unwrap();
            let mut r2 = Rng::new(trial);
            let want2 = encoder_forward_scratch(&ps, &cfg, x.clone(), &mut r2,
                                                &mut scratch).unwrap();
            let mut r3 = Rng::new(trial);
            let got = sess.forward_one(&x, &mut r3).unwrap();
            assert!(got.max_abs_diff(&want) == 0.0,
                    "{mode} trial {trial}: session != encoder_forward");
            assert!(got.max_abs_diff(&want2) == 0.0,
                    "{mode} trial {trial}: session != encoder_forward_scratch");
        }
    }
}

#[test]
fn vit_session_matches_vit_model_wrappers_in_every_mode() {
    for &mode in MODES {
        let vcfg = vit_cfg(mode);
        let ps = synthetic_vit_store(&vcfg, 3);
        let model = ViTModel::new(&ps, vcfg.clone());
        let patches: Vec<Mat> =
            (0..3).map(|i| random_patches(&vcfg, 60 + i)).collect();
        let mut pool = ScratchPool::new();
        let want_feats =
            model.features_batch_pooled(&patches, 5, 2, &mut pool).unwrap();
        let want_logits =
            model.logits_batch_pooled(&patches, 5, 2, &mut pool).unwrap();
        let want_logits2 = model.logits_batch(&patches, 5, 2).unwrap();
        let want_preds =
            model.predict_batch_pooled(&patches, 5, 2, &mut pool).unwrap();
        let want_preds2 = model.predict_batch(&patches, 5, 2).unwrap();

        let engine = Engine::from_store(synthetic_vit_store(&vcfg, 3));
        let mut sess = engine.vit_session(&vcfg).unwrap();
        sess.set_workers(2);
        sess.begin(patches.len());
        for (i, p) in patches.iter().enumerate() {
            sess.set_patches(i, p).unwrap();
        }
        sess.forward(5).unwrap();
        for i in 0..patches.len() {
            assert_eq!(sess.features(i), &want_feats[i][..],
                       "{mode} sample {i}: features diverged");
            assert_eq!(sess.logits(i), &want_logits[i][..],
                       "{mode} sample {i}: logits diverged");
            assert_eq!(sess.logits(i), &want_logits2[i][..],
                       "{mode} sample {i}: logits (plain wrapper) diverged");
            assert_eq!(sess.predict(i), want_preds[i],
                       "{mode} sample {i}: prediction diverged");
            assert_eq!(sess.predict(i), want_preds2[i],
                       "{mode} sample {i}: prediction (plain) diverged");
        }

        // single-sample serial contract vs ViTModel::{features,logits,
        // predict}: one shared RNG stream threads through all samples
        let mut r1 = Rng::new(77);
        let mut r2 = Rng::new(77);
        for (i, p) in patches.iter().enumerate() {
            let want_f = model.features(p, &mut r1).unwrap();
            let got_f = sess.features_one(p, &mut r2).unwrap();
            assert_eq!(got_f, &want_f[..], "{mode} sample {i}: features_one");
        }
        let mut r1 = Rng::new(78);
        let mut r2 = Rng::new(78);
        for (i, p) in patches.iter().enumerate() {
            let want_lg = model.logits(p, &mut r1).unwrap();
            let want_pred = pitome::tensor::argmax(&want_lg);
            sess.begin(1);
            sess.set_patches(0, p).unwrap();
            sess.forward_serial(&mut r2).unwrap();
            assert_eq!(sess.logits(0), &want_lg[..],
                       "{mode} sample {i}: serial logits diverged");
            assert_eq!(sess.predict(0), want_pred,
                       "{mode} sample {i}: serial prediction diverged");
        }
    }
}

/// Build a synthetic BERT-style parameter store covering every tensor the
/// text encoder path names (mirrors `synthetic_vit_store`'s scheme).
fn synthetic_bert_store(cfg: &TextConfig, seed: u64) -> ParamStore {
    let dim = cfg.dim;
    let hidden = (cfg.dim as f64 * cfg.mlp_ratio) as usize;
    let scale = 1.0 / (dim as f32).sqrt();
    let mut rng = Rng::new(seed);
    let mut flat: Vec<f32> = Vec::new();
    let mut entries: Vec<ParamEntry> = Vec::new();
    let push = |flat: &mut Vec<f32>, entries: &mut Vec<ParamEntry>,
                    name: &str, shape: &[usize], s: f32, rng: &mut Rng| {
        let size: usize = shape.iter().product();
        let offset = flat.len();
        for _ in 0..size {
            let v = if s == 0.0 {
                if name.ends_with(".w") && name.contains("ln") { 1.0 } else { 0.0 }
            } else {
                (rng.next_f64() * 2.0 - 1.0) as f32 * s
            };
            flat.push(v);
        }
        entries.push(ParamEntry { name: name.into(), shape: shape.to_vec(),
                                  offset, size });
    };
    push(&mut flat, &mut entries, "bert.tok", &[cfg.vocab_size, dim], 0.02, &mut rng);
    push(&mut flat, &mut entries, "bert.pos", &[cfg.n_tokens(), dim], 0.02, &mut rng);
    for l in 0..cfg.depth {
        let p = format!("bert.blk{l}.");
        push(&mut flat, &mut entries, &format!("{p}ln1.w"), &[dim], 0.0, &mut rng);
        push(&mut flat, &mut entries, &format!("{p}ln1.b"), &[dim], 0.0, &mut rng);
        push(&mut flat, &mut entries, &format!("{p}wq"), &[dim, dim], scale, &mut rng);
        push(&mut flat, &mut entries, &format!("{p}wk"), &[dim, dim], scale, &mut rng);
        push(&mut flat, &mut entries, &format!("{p}wv"), &[dim, dim], scale, &mut rng);
        push(&mut flat, &mut entries, &format!("{p}wo"), &[dim, dim], scale, &mut rng);
        push(&mut flat, &mut entries, &format!("{p}bo"), &[dim], 0.0, &mut rng);
        push(&mut flat, &mut entries, &format!("{p}ln2.w"), &[dim], 0.0, &mut rng);
        push(&mut flat, &mut entries, &format!("{p}ln2.b"), &[dim], 0.0, &mut rng);
        push(&mut flat, &mut entries, &format!("{p}mlp1"), &[dim, hidden], scale, &mut rng);
        push(&mut flat, &mut entries, &format!("{p}mlp1b"), &[hidden], 0.0, &mut rng);
        push(&mut flat, &mut entries, &format!("{p}mlp2"), &[hidden, dim],
             1.0 / (hidden as f32).sqrt(), &mut rng);
        push(&mut flat, &mut entries, &format!("{p}mlp2b"), &[dim], 0.0, &mut rng);
    }
    push(&mut flat, &mut entries, "bert.lnf.w", &[dim], 0.0, &mut rng);
    push(&mut flat, &mut entries, "bert.lnf.b", &[dim], 0.0, &mut rng);
    push(&mut flat, &mut entries, "bert.head.w", &[dim, cfg.num_classes], scale, &mut rng);
    push(&mut flat, &mut entries, "bert.head.b", &[cfg.num_classes], 0.0, &mut rng);
    ParamStore::from_parts(flat, entries)
}

#[test]
fn bert_session_matches_bert_wrappers_in_every_mode() {
    for &mode in MODES {
        let tcfg = TextConfig {
            merge_mode: mode.into(),
            merge_r: 0.8,
            seq_len: 24,
            vocab_size: 64,
            ..Default::default()
        };
        let ps = synthetic_bert_store(&tcfg, 9);
        let mut rng = Rng::new(31);
        let seqs: Vec<Vec<i32>> = (0..3)
            .map(|_| {
                (0..tcfg.n_tokens())
                    .map(|_| rng.next_below(tcfg.vocab_size as u64) as i32)
                    .collect()
            })
            .collect();
        let mut pool = ScratchPool::new();
        let want = bert_logits_batch_pooled(&ps, &tcfg, &seqs, 4, 2,
                                            &mut pool).unwrap();
        let want2 = bert_logits_batch(&ps, &tcfg, &seqs, 4, 2).unwrap();

        let engine = Engine::from_store(synthetic_bert_store(&tcfg, 9));
        let mut sess = engine.bert_session(&tcfg).unwrap();
        sess.set_workers(2);
        sess.begin(seqs.len());
        for (i, s) in seqs.iter().enumerate() {
            sess.set_tokens(i, s).unwrap();
        }
        sess.forward(4).unwrap();
        for i in 0..seqs.len() {
            assert_eq!(sess.logits(i), &want[i][..],
                       "{mode} seq {i}: logits != batch_pooled wrapper");
            assert_eq!(sess.logits(i), &want2[i][..],
                       "{mode} seq {i}: logits != batch wrapper");
        }
    }
}

#[test]
fn one_session_survives_growing_and_shrinking_batches() {
    // the stale-pool regression: ONE session (and one vit session) driven
    // through interleaved batch sizes must match fresh sessions bitwise —
    // any buffer whose logical length lags the round's shape would show up
    let vcfg = vit_cfg("pitome");
    let engine = Engine::from_store(synthetic_vit_store(&vcfg, 21));
    let cfg = EncoderCfg::from_vit(&vcfg);
    let mut reused = engine.session(cfg.clone()).unwrap();
    for (round, &bs) in [5usize, 2, 7, 1, 4].iter().enumerate() {
        let xs: Vec<Mat> = (0..bs)
            .map(|i| random_input(cfg.plan[0], cfg.dim,
                                  (round * 100 + i) as u64))
            .collect();
        let mut fresh = engine.session(cfg.clone()).unwrap();
        let want: Vec<Mat> =
            fresh.forward_batch(&xs, round as u64).unwrap().to_vec();
        let got = reused.forward_batch(&xs, round as u64).unwrap();
        assert_eq!(got.len(), bs, "round {round}");
        for (i, g) in got.iter().enumerate() {
            assert!(g.max_abs_diff(&want[i]) == 0.0,
                    "round {round} (batch {bs}) sample {i}: reused session \
                     diverged from fresh");
        }
    }

    let mut vit = engine.vit_session(&vcfg).unwrap();
    for (round, &bs) in [3usize, 1, 6, 2].iter().enumerate() {
        let patches: Vec<Mat> = (0..bs)
            .map(|i| random_patches(&vcfg, (round * 50 + i) as u64))
            .collect();
        let mut fresh = engine.vit_session(&vcfg).unwrap();
        fresh.begin(bs);
        vit.begin(bs);
        for (i, p) in patches.iter().enumerate() {
            fresh.set_patches(i, p).unwrap();
            vit.set_patches(i, p).unwrap();
        }
        fresh.forward(round as u64).unwrap();
        vit.forward(round as u64).unwrap();
        for i in 0..bs {
            assert_eq!(vit.logits(i), fresh.logits(i),
                       "vit round {round} sample {i}: reused session diverged");
        }
    }
}

#[test]
fn sessions_reject_stale_or_contradictory_shapes() {
    let vcfg = vit_cfg("pitome");
    let engine = Engine::from_store(synthetic_vit_store(&vcfg, 2));
    // raw session: an input left at a previous (wrong) shape is an error
    let mut sess = engine.session(EncoderCfg::from_vit(&vcfg)).unwrap();
    sess.begin(2);
    sess.input_mut(0).reshape(3, 3);
    sess.input_mut(1).reshape(3, 3);
    assert!(sess.forward(0).is_err(), "wrong-shape input must be rejected");
    // and the session recovers once the inputs are refilled correctly
    let xs: Vec<Mat> = (0..2)
        .map(|i| random_input(vcfg.n_tokens(), vcfg.dim, i))
        .collect();
    sess.forward_batch(&xs, 0).unwrap();

    // vit session: wrong patch shapes and wrong raw lengths are rejected
    let mut vit = engine.vit_session(&vcfg).unwrap();
    vit.begin(1);
    let bad = Mat::zeros(3, 3);
    assert!(vit.set_patches(0, &bad).is_err());
    assert!(vit.set_patches_slice(0, &[0.0; 7]).is_err());

    // bert session: wrong sequence length and out-of-vocab ids rejected
    let tcfg = TextConfig { seq_len: 12, vocab_size: 32,
                            ..Default::default() };
    let bert_ps = synthetic_bert_store(&tcfg, 4);
    let bert_engine = Engine::from_store(bert_ps);
    let mut bert = bert_engine.bert_session(&tcfg).unwrap();
    bert.begin(1);
    assert!(bert.set_tokens(0, &[1, 2, 3]).is_err(), "short seq accepted");
    let bad_ids = vec![999i32; tcfg.n_tokens()];
    assert!(bert.set_tokens(0, &bad_ids).is_err(), "oov ids accepted");
}
