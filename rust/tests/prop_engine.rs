//! Bitwise parity between the owning `Engine`/`Session` API and every
//! deprecated legacy entry point, across all ten merge modes:
//!
//! * `Session::forward_batch` vs `encoder_forward_batch[_pooled]`
//!   (identical per-(layer, sample) seeding — stochastic modes included);
//! * `Session::forward_one` vs `encoder_forward` /
//!   `encoder_forward_scratch` (identical shared-RNG stream);
//! * `VitSession` vs `ViTModel::{features,logits,predict}_batch[_pooled]`
//!   and the single-sample `ViTModel::{features,logits,predict}`;
//! * `BertSession` vs `bert_logits_batch[_pooled]`.
//!
//! Plus the stale-pool regression: one session driven through growing and
//! shrinking batch sizes must match fresh sessions exactly, and inputs
//! whose shape contradicts the config must be rejected.
//!
//! The multimodal additions: a [`JointSession`] under the serial
//! shared-RNG contract is bitwise-identical to the deprecated
//! per-sample VQA path (`eval::vqa::vqa_logits`) and retrieval path
//! (`clip_image_embed` + `clip_text_embed`) in **every** merge mode, and
//! one joint session driven through ragged growing/shrinking halves
//! matches fresh sessions exactly.
#![allow(deprecated)]

use pitome::config::{TextConfig, ViTConfig};
use pitome::data::{caption_for, patchify, shape_item, vqa_item, Rng,
                   TEST_SEED};
use pitome::engine::{Engine, JointConfig};
use pitome::eval::retrieval::clip_image_embed;
use pitome::eval::vqa::vqa_logits;
use pitome::model::{bert_logits_batch, bert_logits_batch_pooled,
                    clip_text_embed, encoder_forward, encoder_forward_batch,
                    encoder_forward_batch_pooled, encoder_forward_scratch,
                    synthetic_bert_store, synthetic_mm_store,
                    synthetic_vit_store, EncoderCfg, EncoderScratch,
                    ScratchPool, ViTModel};
use pitome::tensor::Mat;

/// Every mode the encoder can run (paper modes + ablations + baselines).
const MODES: &[&str] = &[
    "none", "pitome", "pitome_noprot", "pitome_rand", "pitome_attn",
    "tome", "tofu", "dct", "diffrate", "random",
];

fn vit_cfg(mode: &str) -> ViTConfig {
    ViTConfig { merge_mode: mode.into(), merge_r: 0.9, ..Default::default() }
}

fn random_input(n: usize, dim: usize, seed: u64) -> Mat {
    let mut rng = Rng::new(seed);
    Mat::from_fn(n, dim, |_, _| (rng.next_f64() * 0.2 - 0.1) as f32)
}

fn random_patches(vcfg: &ViTConfig, seed: u64) -> Mat {
    random_input(vcfg.num_patches(), vcfg.patch_dim(), seed)
}

#[test]
fn session_forward_batch_matches_batch_wrappers_in_every_mode() {
    for &mode in MODES {
        let vcfg = vit_cfg(mode);
        let ps = synthetic_vit_store(&vcfg, 42);
        let cfg = EncoderCfg::from_vit(&vcfg);
        let xs: Vec<Mat> = (0..4)
            .map(|i| random_input(cfg.plan[0], cfg.dim, 10 + i))
            .collect();
        let mut pool = ScratchPool::new();
        let want_pooled = encoder_forward_batch_pooled(
            &ps, &cfg, xs.clone(), 9, 2, &mut pool).unwrap();
        let want_plain =
            encoder_forward_batch(&ps, &cfg, xs.clone(), 9, 2).unwrap();

        let engine = Engine::from_store(synthetic_vit_store(&vcfg, 42));
        let mut sess = engine.session(cfg).unwrap();
        sess.set_workers(2);
        let got = sess.forward_batch(&xs, 9).unwrap();
        assert_eq!(got.len(), want_pooled.len());
        for (i, g) in got.iter().enumerate() {
            assert!(g.max_abs_diff(&want_pooled[i]) == 0.0,
                    "{mode} sample {i}: session != batch_pooled wrapper");
            assert!(g.max_abs_diff(&want_plain[i]) == 0.0,
                    "{mode} sample {i}: session != batch wrapper");
        }
    }
}

#[test]
fn session_forward_one_matches_serial_wrappers_in_every_mode() {
    for &mode in MODES {
        let vcfg = vit_cfg(mode);
        let ps = synthetic_vit_store(&vcfg, 7);
        let cfg = EncoderCfg::from_vit(&vcfg);
        let engine = Engine::from_store(synthetic_vit_store(&vcfg, 7));
        let mut sess = engine.session(cfg.clone()).unwrap();
        let mut scratch = EncoderScratch::new();
        // three trials through ONE session: the shared RNG stream and the
        // wrappers' streams must stay in lockstep (stochastic modes too)
        for trial in 0..3u64 {
            let x = random_input(cfg.plan[0], cfg.dim, 20 + trial);
            let mut r1 = Rng::new(trial);
            let want = encoder_forward(&ps, &cfg, x.clone(), &mut r1).unwrap();
            let mut r2 = Rng::new(trial);
            let want2 = encoder_forward_scratch(&ps, &cfg, x.clone(), &mut r2,
                                                &mut scratch).unwrap();
            let mut r3 = Rng::new(trial);
            let got = sess.forward_one(&x, &mut r3).unwrap();
            assert!(got.max_abs_diff(&want) == 0.0,
                    "{mode} trial {trial}: session != encoder_forward");
            assert!(got.max_abs_diff(&want2) == 0.0,
                    "{mode} trial {trial}: session != encoder_forward_scratch");
        }
    }
}

#[test]
fn vit_session_matches_vit_model_wrappers_in_every_mode() {
    for &mode in MODES {
        let vcfg = vit_cfg(mode);
        let ps = synthetic_vit_store(&vcfg, 3);
        let model = ViTModel::new(&ps, vcfg.clone());
        let patches: Vec<Mat> =
            (0..3).map(|i| random_patches(&vcfg, 60 + i)).collect();
        let mut pool = ScratchPool::new();
        let want_feats =
            model.features_batch_pooled(&patches, 5, 2, &mut pool).unwrap();
        let want_logits =
            model.logits_batch_pooled(&patches, 5, 2, &mut pool).unwrap();
        let want_logits2 = model.logits_batch(&patches, 5, 2).unwrap();
        let want_preds =
            model.predict_batch_pooled(&patches, 5, 2, &mut pool).unwrap();
        let want_preds2 = model.predict_batch(&patches, 5, 2).unwrap();

        let engine = Engine::from_store(synthetic_vit_store(&vcfg, 3));
        let mut sess = engine.vit_session(&vcfg).unwrap();
        sess.set_workers(2);
        sess.begin(patches.len());
        for (i, p) in patches.iter().enumerate() {
            sess.set_patches(i, p).unwrap();
        }
        sess.forward(5).unwrap();
        for i in 0..patches.len() {
            assert_eq!(sess.features(i), &want_feats[i][..],
                       "{mode} sample {i}: features diverged");
            assert_eq!(sess.logits(i), &want_logits[i][..],
                       "{mode} sample {i}: logits diverged");
            assert_eq!(sess.logits(i), &want_logits2[i][..],
                       "{mode} sample {i}: logits (plain wrapper) diverged");
            assert_eq!(sess.predict(i), want_preds[i],
                       "{mode} sample {i}: prediction diverged");
            assert_eq!(sess.predict(i), want_preds2[i],
                       "{mode} sample {i}: prediction (plain) diverged");
        }

        // single-sample serial contract vs ViTModel::{features,logits,
        // predict}: one shared RNG stream threads through all samples
        let mut r1 = Rng::new(77);
        let mut r2 = Rng::new(77);
        for (i, p) in patches.iter().enumerate() {
            let want_f = model.features(p, &mut r1).unwrap();
            let got_f = sess.features_one(p, &mut r2).unwrap();
            assert_eq!(got_f, &want_f[..], "{mode} sample {i}: features_one");
        }
        let mut r1 = Rng::new(78);
        let mut r2 = Rng::new(78);
        for (i, p) in patches.iter().enumerate() {
            let want_lg = model.logits(p, &mut r1).unwrap();
            let want_pred = pitome::tensor::argmax(&want_lg);
            sess.begin(1);
            sess.set_patches(0, p).unwrap();
            sess.forward_serial(&mut r2).unwrap();
            assert_eq!(sess.logits(0), &want_lg[..],
                       "{mode} sample {i}: serial logits diverged");
            assert_eq!(sess.predict(0), want_pred,
                       "{mode} sample {i}: serial prediction diverged");
        }
    }
}

#[test]
fn bert_session_matches_bert_wrappers_in_every_mode() {
    for &mode in MODES {
        let tcfg = TextConfig {
            merge_mode: mode.into(),
            merge_r: 0.8,
            seq_len: 24,
            vocab_size: 64,
            ..Default::default()
        };
        let ps = synthetic_bert_store(&tcfg, 9);
        let mut rng = Rng::new(31);
        let seqs: Vec<Vec<i32>> = (0..3)
            .map(|_| {
                (0..tcfg.n_tokens())
                    .map(|_| rng.next_below(tcfg.vocab_size as u64) as i32)
                    .collect()
            })
            .collect();
        let mut pool = ScratchPool::new();
        let want = bert_logits_batch_pooled(&ps, &tcfg, &seqs, 4, 2,
                                            &mut pool).unwrap();
        let want2 = bert_logits_batch(&ps, &tcfg, &seqs, 4, 2).unwrap();

        let engine = Engine::from_store(synthetic_bert_store(&tcfg, 9));
        let mut sess = engine.bert_session(&tcfg).unwrap();
        sess.set_workers(2);
        sess.begin(seqs.len());
        for (i, s) in seqs.iter().enumerate() {
            sess.set_tokens(i, s).unwrap();
        }
        sess.forward(4).unwrap();
        for i in 0..seqs.len() {
            assert_eq!(sess.logits(i), &want[i][..],
                       "{mode} seq {i}: logits != batch_pooled wrapper");
            assert_eq!(sess.logits(i), &want2[i][..],
                       "{mode} seq {i}: logits != batch wrapper");
        }
    }
}

#[test]
fn one_session_survives_growing_and_shrinking_batches() {
    // the stale-pool regression: ONE session (and one vit session) driven
    // through interleaved batch sizes must match fresh sessions bitwise —
    // any buffer whose logical length lags the round's shape would show up
    let vcfg = vit_cfg("pitome");
    let engine = Engine::from_store(synthetic_vit_store(&vcfg, 21));
    let cfg = EncoderCfg::from_vit(&vcfg);
    let mut reused = engine.session(cfg.clone()).unwrap();
    for (round, &bs) in [5usize, 2, 7, 1, 4].iter().enumerate() {
        let xs: Vec<Mat> = (0..bs)
            .map(|i| random_input(cfg.plan[0], cfg.dim,
                                  (round * 100 + i) as u64))
            .collect();
        let mut fresh = engine.session(cfg.clone()).unwrap();
        let want: Vec<Mat> =
            fresh.forward_batch(&xs, round as u64).unwrap().to_vec();
        let got = reused.forward_batch(&xs, round as u64).unwrap();
        assert_eq!(got.len(), bs, "round {round}");
        for (i, g) in got.iter().enumerate() {
            assert!(g.max_abs_diff(&want[i]) == 0.0,
                    "round {round} (batch {bs}) sample {i}: reused session \
                     diverged from fresh");
        }
    }

    let mut vit = engine.vit_session(&vcfg).unwrap();
    for (round, &bs) in [3usize, 1, 6, 2].iter().enumerate() {
        let patches: Vec<Mat> = (0..bs)
            .map(|i| random_patches(&vcfg, (round * 50 + i) as u64))
            .collect();
        let mut fresh = engine.vit_session(&vcfg).unwrap();
        fresh.begin(bs);
        vit.begin(bs);
        for (i, p) in patches.iter().enumerate() {
            fresh.set_patches(i, p).unwrap();
            vit.set_patches(i, p).unwrap();
        }
        fresh.forward(round as u64).unwrap();
        vit.forward(round as u64).unwrap();
        for i in 0..bs {
            assert_eq!(vit.logits(i), fresh.logits(i),
                       "vit round {round} sample {i}: reused session diverged");
        }
    }
}

#[test]
fn sessions_reject_stale_or_contradictory_shapes() {
    let vcfg = vit_cfg("pitome");
    let engine = Engine::from_store(synthetic_vit_store(&vcfg, 2));
    // raw session: an input left at a previous (wrong) shape is an error
    let mut sess = engine.session(EncoderCfg::from_vit(&vcfg)).unwrap();
    sess.begin(2);
    sess.input_mut(0).reshape(3, 3);
    sess.input_mut(1).reshape(3, 3);
    assert!(sess.forward(0).is_err(), "wrong-shape input must be rejected");
    // and the session recovers once the inputs are refilled correctly
    let xs: Vec<Mat> = (0..2)
        .map(|i| random_input(vcfg.n_tokens(), vcfg.dim, i))
        .collect();
    sess.forward_batch(&xs, 0).unwrap();

    // vit session: wrong patch shapes and wrong raw lengths are rejected
    let mut vit = engine.vit_session(&vcfg).unwrap();
    vit.begin(1);
    let bad = Mat::zeros(3, 3);
    assert!(vit.set_patches(0, &bad).is_err());
    assert!(vit.set_patches_slice(0, &[0.0; 7]).is_err());

    // bert session: wrong sequence length and out-of-vocab ids rejected
    let tcfg = TextConfig { seq_len: 12, vocab_size: 32,
                            ..Default::default() };
    let bert_ps = synthetic_bert_store(&tcfg, 4);
    let bert_engine = Engine::from_store(bert_ps);
    let mut bert = bert_engine.bert_session(&tcfg).unwrap();
    bert.begin(1);
    assert!(bert.set_tokens(0, &[1, 2, 3]).is_err(), "short seq accepted");
    let bad_ids = vec![999i32; tcfg.n_tokens()];
    assert!(bert.set_tokens(0, &bad_ids).is_err(), "oov ids accepted");
}

#[test]
fn joint_session_matches_deprecated_vqa_path_in_every_mode() {
    // the serial shared-RNG contract: sess.vqa_one must reproduce the
    // deprecated per-sample ViTModel::features + text_features + dense
    // head path bit-for-bit, stochastic merge modes included (one RNG
    // stream threads vision-then-question through consecutive samples)
    for &mode in MODES {
        let vcfg = vit_cfg(mode);
        let ps = synthetic_mm_store(&vcfg, 5);
        let engine = Engine::from_store(synthetic_mm_store(&vcfg, 5));
        let mut sess =
            engine.joint_session(&JointConfig::vqa(vcfg.clone())).unwrap();
        let mut r1 = Rng::new(3);
        let mut r2 = Rng::new(3);
        for i in 0..3u64 {
            let item = shape_item(TEST_SEED, i);
            let patches = patchify(&item.image, vcfg.patch_size);
            let (q, _) = vqa_item(TEST_SEED, i);
            let want = vqa_logits(&ps, &vcfg, &patches, &q, &mut r1).unwrap();
            let got = sess.vqa_one(&patches, &q, &mut r2).unwrap();
            assert_eq!(got, &want[..],
                       "{mode} sample {i}: joint session diverged from the \
                        deprecated VQA path");
        }
    }
}

#[test]
fn joint_session_matches_deprecated_retrieval_path_in_every_mode() {
    for &mode in MODES {
        let vcfg = ViTConfig { merge_mode: mode.into(), merge_r: 0.9,
                               num_classes: 10, ..Default::default() };
        let engine = Engine::from_store(synthetic_mm_store(&vcfg, 8));
        let mut sess = engine
            .joint_session(&JointConfig::retrieval(vcfg.clone()))
            .unwrap();
        let mut r1 = Rng::new(4);
        let mut r2 = Rng::new(4);
        for i in 0..3u64 {
            let item = shape_item(TEST_SEED, i);
            let patches = patchify(&item.image, vcfg.patch_size);
            let cap = caption_for(TEST_SEED, i);
            let want_ie =
                clip_image_embed(&engine, &vcfg, &patches, &mut r1).unwrap();
            let want_te = clip_text_embed(engine.params(), &cap, 64, 2, 4,
                                          64, &mut r1).unwrap();
            let (ie, te) =
                sess.embed_pair_one(&patches, &cap, &mut r2).unwrap();
            assert_eq!(ie, &want_ie[..],
                       "{mode} sample {i}: image embed diverged");
            assert_eq!(te, &want_te[..],
                       "{mode} sample {i}: text embed diverged");
        }
    }
}

#[test]
fn one_joint_session_survives_ragged_growing_and_shrinking_halves() {
    // the joint stale-pool regression: ONE session driven through
    // interleaved (bv, bt) half sizes must match fresh sessions bitwise
    let vcfg = vit_cfg("pitome");
    let engine = Engine::from_store(synthetic_mm_store(&vcfg, 21));
    let jcfg = JointConfig::vqa(vcfg.clone());
    let mut reused = engine.joint_session(&jcfg).unwrap();
    for (round, &(bv, bt)) in
        [(3usize, 3usize), (1, 4), (5, 2), (2, 2)].iter().enumerate()
    {
        let mut fresh = engine.joint_session(&jcfg).unwrap();
        for sess in [&mut reused, &mut fresh] {
            sess.begin(bv, bt);
            for i in 0..bv {
                let item = shape_item(TEST_SEED, (round * 10 + i) as u64);
                sess.set_patches(i, &patchify(&item.image, vcfg.patch_size))
                    .unwrap();
            }
            for j in 0..bt {
                let (q, _) = vqa_item(TEST_SEED, (round * 10 + j) as u64);
                sess.set_text(j, &q).unwrap();
            }
            sess.forward(round as u64).unwrap();
        }
        let pairs: Vec<(usize, usize)> =
            (0..bv.min(bt)).map(|i| (i, i)).collect();
        reused.fuse_vqa(&pairs).unwrap();
        fresh.fuse_vqa(&pairs).unwrap();
        for p in 0..pairs.len() {
            assert_eq!(reused.answer_logits(p), fresh.answer_logits(p),
                       "round {round} ({bv}, {bt}) pair {p}: reused joint \
                        session diverged from fresh");
        }
        for i in 0..bv {
            assert_eq!(reused.image_feature(i), fresh.image_feature(i),
                       "round {round} image {i} diverged");
        }
        for j in 0..bt {
            assert_eq!(reused.text_feature(j), fresh.text_feature(j),
                       "round {round} text {j} diverged");
        }
    }

    // retrieval kind: ragged projection rounds through one session
    let rcfg = JointConfig::retrieval(vcfg.clone());
    let mut reused = engine.joint_session(&rcfg).unwrap();
    for (round, &(bv, bt)) in [(2usize, 4usize), (4, 1), (1, 3)]
        .iter().enumerate()
    {
        let mut fresh = engine.joint_session(&rcfg).unwrap();
        for sess in [&mut reused, &mut fresh] {
            sess.begin(bv, bt);
            for i in 0..bv {
                let item = shape_item(TEST_SEED, (round * 7 + i) as u64);
                sess.set_patches(i, &patchify(&item.image, vcfg.patch_size))
                    .unwrap();
            }
            for j in 0..bt {
                let cap = caption_for(TEST_SEED, (round * 7 + j) as u64);
                sess.set_text(j, &cap).unwrap();
            }
            sess.forward(round as u64).unwrap();
            sess.project().unwrap();
        }
        for i in 0..bv {
            assert_eq!(reused.image_embed(i), fresh.image_embed(i),
                       "retrieval round {round} image {i} diverged");
            for j in 0..bt {
                assert_eq!(reused.score(i, j), fresh.score(i, j),
                           "retrieval round {round} score ({i}, {j})");
            }
        }
    }
}
