//! Steady-state allocation guarantees of the scratch-workspace encoder,
//! measured with the `CountingAllocator` test hook (installed as this
//! test binary's global allocator; the counter is per-thread, so parallel
//! test threads don't pollute each other).
//!
//! * With merging off, the warmed encoder layer loop must perform **zero**
//!   heap allocations (the ISSUE acceptance criterion).
//! * With PiToMe merging on, only the small per-step plan/index vectors
//!   may allocate — bounded and independent of token/feature dims.

use pitome::config::ViTConfig;
use pitome::data::Rng;
use pitome::merge::MergeMode;
use pitome::model::{encoder_layers, synthetic_vit_store, EncoderCfg,
                    EncoderScratch, ResolvedEncoder};
use pitome::tensor::Mat;
use pitome::util::alloc::{allocs_this_thread, CountingAllocator};

#[global_allocator]
static ALLOC: CountingAllocator = CountingAllocator;

fn encoder_cfg(vcfg: &ViTConfig) -> EncoderCfg {
    EncoderCfg {
        prefix: "vit.".into(),
        dim: vcfg.dim,
        depth: vcfg.depth,
        heads: vcfg.heads,
        mode: vcfg.mode(),
        plan: vcfg.plan(),
        prop_attn: true,
        tofu_threshold: vcfg.tofu_threshold,
    }
}

fn random_input(n: usize, dim: usize, seed: u64) -> Mat {
    let mut rng = Rng::new(seed);
    Mat::from_fn(n, dim, |_, _| (rng.next_f64() * 0.2 - 0.1) as f32)
}

/// Warm `scratch` with one pass, then count allocations over a second,
/// steady-state pass of the layer loop.
fn steady_state_allocs(vcfg: &ViTConfig) -> u64 {
    let ps = synthetic_vit_store(vcfg, 5);
    let cfg = encoder_cfg(vcfg);
    let re = ResolvedEncoder::new(&ps, &cfg).unwrap();
    let mut scratch = EncoderScratch::new();
    let n0 = cfg.plan[0];
    let x0 = random_input(n0, cfg.dim, 1);
    for pass in 0..2 {
        let mut x = x0.clone();
        let mut sizes = vec![1.0f32; n0];
        let mut rng = Rng::new(0);
        let before = allocs_this_thread();
        encoder_layers(&re, &cfg, &mut x, &mut sizes, &mut rng, &mut scratch);
        if pass == 1 {
            return allocs_this_thread() - before;
        }
    }
    unreachable!()
}

#[test]
fn merge_free_encoder_loop_is_allocation_free() {
    // mode "none": the pure attention + MLP loop
    let vcfg = ViTConfig::default();
    assert_eq!(encoder_cfg(&vcfg).mode, MergeMode::None);
    let allocs = steady_state_allocs(&vcfg);
    assert_eq!(allocs, 0,
               "steady-state encoder loop allocated {allocs} times");
}

#[test]
fn merging_encoder_loop_allocates_only_small_plan_vectors() {
    let vcfg = ViTConfig {
        merge_mode: "pitome".into(),
        merge_r: 0.9,
        ..Default::default()
    };
    let allocs = steady_state_allocs(&vcfg);
    // depth-4 pitome: per merge layer only the energy vector and the plan
    // builder's index vectors allocate — nothing proportional to dim, and
    // no Gram / QKV / score / output buffers
    assert!(allocs > 0, "pitome plan building is expected to allocate");
    assert!(allocs < 200,
            "merge layers allocated {allocs} times — scratch reuse broken?");
}

#[test]
fn second_forward_reuses_all_encoder_buffers() {
    // whole-forward view: pass 2 over a reused scratch must allocate far
    // less than pass 1 (which grows every buffer)
    let vcfg = ViTConfig {
        merge_mode: "pitome".into(),
        merge_r: 0.9,
        ..Default::default()
    };
    let ps = synthetic_vit_store(&vcfg, 5);
    let cfg = encoder_cfg(&vcfg);
    let re = ResolvedEncoder::new(&ps, &cfg).unwrap();
    let mut scratch = EncoderScratch::new();
    let n0 = cfg.plan[0];
    let x0 = random_input(n0, cfg.dim, 2);
    let mut per_pass = Vec::new();
    for _ in 0..2 {
        let mut x = x0.clone();
        let mut sizes = vec![1.0f32; n0];
        let mut rng = Rng::new(0);
        let before = allocs_this_thread();
        encoder_layers(&re, &cfg, &mut x, &mut sizes, &mut rng, &mut scratch);
        per_pass.push(allocs_this_thread() - before);
    }
    // pass 1 additionally grows every scratch buffer (>= the ~15 backing
    // stores); pass 2 pays only the per-step plan vectors
    assert!(per_pass[1] + 10 <= per_pass[0],
            "cold {} vs warm {}: buffer growth should only be paid once",
            per_pass[0], per_pass[1]);
}
