//! Steady-state allocation guarantees of the scratch-workspace pipeline,
//! measured with the `CountingAllocator` test hook (installed as this
//! test binary's global allocator; the counter is per-thread, so parallel
//! test threads don't pollute each other).
//!
//! * A warmed encoder forward must perform **zero** heap allocations in
//!   the layer loop for **every** merge mode — attention, MLP, Gram
//!   rebuild, plan construction (the `*_plan_gram_into` builders), plan
//!   application, and the DCT/random baselines included.  The historical
//!   "bounded plan-only allocations" carve-out is gone.
//! * A warmed engine [`Session`] must run **whole batches** — input
//!   copy-in, layer loop, final LayerNorm, per-sample outputs —
//!   allocation-free, and a warmed CPU serving worker booted through
//!   `Coordinator::boot_cpu` must report a **zero-allocation inference
//!   region** for a complete request→response cycle (tracked per batch
//!   in `Snapshot::last_infer_allocs`).
//! * The transport boundary is no longer exempt: a warmed **joint**
//!   (patches, question)→answer-logits request through
//!   `Coordinator::boot_cpu_workloads` — pooled inputs, bounded channel,
//!   recycled response buffer, release-on-drop — must allocate **zero**
//!   on the submitter thread and across the worker's whole batch cycle
//!   (`Snapshot::last_cycle_allocs`).
//! * A warmed **gallery** query — probe embed through the vision tower,
//!   blocked top-k scan over the sharded embedding store, `[id, score]`
//!   response rows — must likewise allocate zero on the submitter thread
//!   and across the worker's whole batch cycle once the store and the
//!   worker's scan scratch are warm (ingests may grow shard segments;
//!   queries never do).
//! * All three serving cycles run with **tracing enabled**
//!   (`ServingConfig::trace_capacity > 0`): the span rings, per-batch
//!   stage spans, and merge telemetry ride the hot path through
//!   preallocated fixed-capacity buffers, so observability must not
//!   cost a single steady-state allocation.
//! * A warmed `iterative_coarsen_scratch` SD-sweep workspace must also
//!   run allocation-free for every coarsening algorithm, and a warmed
//!   [`EigScratch`] must evaluate the full SD(G, Gc) spectral distance —
//!   coarsen, lift, Laplacians, eigensolves — without allocating.

use std::sync::Arc;
use std::time::Duration;

use pitome::config::{ServingConfig, ViTConfig};
use pitome::coordinator::{Admission, Coordinator, CpuWorkloads, Payload, Qos,
                          Workload};
use pitome::data::Rng;
use pitome::engine::JointKind;
use pitome::engine::Engine;
use pitome::eval::spectral::{clustered_tokens, iterative_coarsen_scratch,
                             ClusterSpec, CoarsenAlgo, CoarsenScratch,
                             Layout};
use pitome::graph::{spectral_distance_scratch, token_graph, EigScratch,
                    Partition};
use pitome::merge::MergeMode;
use pitome::model::{encoder_layers, synthetic_mm_store,
                    synthetic_vit_store, EncoderCfg, EncoderScratch,
                    ResolvedEncoder};
use pitome::runtime::HostTensor;
use pitome::tensor::Mat;
use pitome::util::alloc::{allocs_this_thread, CountingAllocator};

#[global_allocator]
static ALLOC: CountingAllocator = CountingAllocator;

/// Every mode the encoder can run (paper modes + ablations + baselines).
const MODES: &[&str] = &[
    "none", "pitome", "pitome_noprot", "pitome_rand", "pitome_attn",
    "tome", "tofu", "dct", "diffrate", "random",
];

fn encoder_cfg(vcfg: &ViTConfig) -> EncoderCfg {
    EncoderCfg::from_vit(vcfg)
}

fn random_input(n: usize, dim: usize, seed: u64) -> Mat {
    let mut rng = Rng::new(seed);
    Mat::from_fn(n, dim, |_, _| (rng.next_f64() * 0.2 - 0.1) as f32)
}

/// Warm `scratch` with one pass, then count allocations over a second,
/// steady-state pass of the layer loop.
fn steady_state_allocs(vcfg: &ViTConfig) -> u64 {
    let ps = synthetic_vit_store(vcfg, 5);
    let cfg = encoder_cfg(vcfg);
    let re = ResolvedEncoder::new(&ps, &cfg).unwrap();
    let mut scratch = EncoderScratch::new();
    let n0 = cfg.plan[0];
    let x0 = random_input(n0, cfg.dim, 1);
    for pass in 0..2 {
        let mut x = x0.clone();
        let mut sizes = vec![1.0f32; n0];
        let mut rng = Rng::new(0);
        let before = allocs_this_thread();
        encoder_layers(&ps, &re, &cfg, &mut x, &mut sizes, &mut rng,
                       &mut scratch);
        if pass == 1 {
            return allocs_this_thread() - before;
        }
    }
    unreachable!()
}

#[test]
fn merge_free_encoder_loop_is_allocation_free() {
    // mode "none": the pure attention + MLP loop
    let vcfg = ViTConfig::default();
    assert_eq!(encoder_cfg(&vcfg).mode, MergeMode::None);
    let allocs = steady_state_allocs(&vcfg);
    assert_eq!(allocs, 0,
               "steady-state encoder loop allocated {allocs} times");
}

#[test]
fn steady_state_forward_is_allocation_free_for_every_mode() {
    // the full guarantee: with a warmed scratch, a whole forward — merge
    // steps included — performs zero heap allocations in every mode
    for &mode in MODES {
        let vcfg = ViTConfig {
            merge_mode: mode.into(),
            merge_r: 0.9,
            ..Default::default()
        };
        let allocs = steady_state_allocs(&vcfg);
        assert_eq!(allocs, 0,
                   "{mode}: steady-state forward allocated {allocs} times");
    }
}

#[test]
fn coarsen_sweep_is_allocation_free_after_warmup() {
    let spec = ClusterSpec { sizes: vec![16, 8, 6, 2], h: 16, noise: 0.1,
                             seed: 5, layout: Layout::Interleaved };
    let (kf, _) = clustered_tokens(&spec);
    let algos = [(CoarsenAlgo::PiToMe, "pitome"),
                 (CoarsenAlgo::ToMe, "tome"),
                 (CoarsenAlgo::Random, "random")];
    let mut scratch = CoarsenScratch::new();
    let mut p = Partition::identity(0);
    // warm-up sweep grows every buffer (including the output partition)
    for &(algo, _) in &algos {
        iterative_coarsen_scratch(&kf, algo, 3, 3, 0.6, 7, &mut scratch,
                                  &mut p);
    }
    for &(algo, name) in &algos {
        let before = allocs_this_thread();
        iterative_coarsen_scratch(&kf, algo, 3, 3, 0.6, 7, &mut scratch,
                                  &mut p);
        let allocs = allocs_this_thread() - before;
        assert_eq!(allocs, 0,
                   "{name}: warmed coarsening sweep allocated {allocs} times");
    }
}

#[test]
fn first_pass_grows_buffers_then_reuses_them() {
    // whole-forward view: pass 1 grows every scratch buffer; pass 2 runs
    // on reused buffers and must allocate nothing at all
    let vcfg = ViTConfig {
        merge_mode: "pitome".into(),
        merge_r: 0.9,
        ..Default::default()
    };
    let ps = synthetic_vit_store(&vcfg, 5);
    let cfg = encoder_cfg(&vcfg);
    let re = ResolvedEncoder::new(&ps, &cfg).unwrap();
    let mut scratch = EncoderScratch::new();
    let n0 = cfg.plan[0];
    let x0 = random_input(n0, cfg.dim, 2);
    let mut per_pass = Vec::new();
    for _ in 0..2 {
        let mut x = x0.clone();
        let mut sizes = vec![1.0f32; n0];
        let mut rng = Rng::new(0);
        let before = allocs_this_thread();
        encoder_layers(&ps, &re, &cfg, &mut x, &mut sizes, &mut rng,
                       &mut scratch);
        per_pass.push(allocs_this_thread() - before);
    }
    assert!(per_pass[0] > 0,
            "cold pass must grow the scratch buffers (got {})", per_pass[0]);
    assert_eq!(per_pass[1], 0,
               "warm pass allocated {} times — scratch reuse broken?",
               per_pass[1]);
}

#[test]
fn warmed_session_runs_whole_batches_allocation_free() {
    // the engine tentpole guarantee: not just the layer loop — input
    // copy-in, fan-out, final LayerNorm, and per-sample outputs all run
    // in pooled buffers once the session has seen the batch shape
    for &mode in MODES {
        let vcfg = ViTConfig {
            merge_mode: mode.into(),
            merge_r: 0.9,
            ..Default::default()
        };
        let engine = Engine::from_store(synthetic_vit_store(&vcfg, 5));
        let mut sess = engine.session(encoder_cfg(&vcfg)).unwrap();
        let n0 = sess.cfg().plan[0];
        let dim = sess.cfg().dim;
        let xs: Vec<Mat> =
            (0..3).map(|i| random_input(n0, dim, 40 + i)).collect();
        sess.forward_batch(&xs, 1).unwrap(); // warm-up grows every pool
        let before = allocs_this_thread();
        sess.forward_batch(&xs, 1).unwrap();
        let allocs = allocs_this_thread() - before;
        assert_eq!(allocs, 0,
                   "{mode}: warmed session batch allocated {allocs} times");
    }
}

#[test]
fn warmed_cpu_serving_request_cycle_is_allocation_free() {
    // the full serving acceptance: boot the real coordinator (router,
    // dynamic batcher, engine session), warm the worker, then check the
    // worker-side inference region — request parse, patch embed, encoder,
    // final norm, classifier head, pooled logits — allocated NOTHING for
    // a complete request→response cycle.  (The worker records the count
    // around exactly that region; the owned response tensors that cross
    // the submitter's channel are the documented transport boundary.)
    let ps = Arc::new(synthetic_vit_store(&ViTConfig::default(), 7));
    let selection = [("vit", vec![("pitome".to_string(), 0.9)])];
    // tracing ON: the span recorder must not break the guarantee
    let cfg = ServingConfig { workers: 1, trace_capacity: 4096,
                              ..Default::default() };
    let coord = Coordinator::boot_cpu(&ps, &selection, cfg).unwrap();
    let item = pitome::data::shape_item(pitome::data::TEST_SEED, 0);
    let patches = pitome::data::patchify(&item.image, 4);
    let input = || {
        vec![HostTensor::F32(patches.data.clone(),
                             vec![patches.rows, patches.cols])]
    };
    // warm-up requests grow every pool on the worker thread
    for _ in 0..3 {
        coord.submit("vit", Qos::Throughput, input()).unwrap();
    }
    // steady state: a whole request's inference region must not allocate
    let resp = coord.submit("vit", Qos::Throughput, input()).unwrap();
    assert_eq!(resp.outputs[0].as_f32().unwrap().len(), 10);
    let metrics = coord.metrics();
    assert_eq!(metrics.len(), 1);
    let snap = &metrics[0].2;
    assert_eq!(snap.count, 4);
    assert_eq!(snap.last_infer_allocs, 0,
               "steady-state serving request allocated {} times in the \
                inference region",
               snap.last_infer_allocs);
}

#[test]
fn warmed_joint_request_cycle_is_allocation_free_including_transport() {
    // the multimodal tentpole acceptance: a warmed joint
    // (patches, question) → answer-logits request allocates ZERO —
    // including the response transport that PR 4 documented as the one
    // remaining per-request allocation.  Pooled input tensors are
    // checked out of the coordinator's recycling pool, the request rides
    // a bounded channel, the worker answers from a recycled buffer into
    // the client's reusable ResponseSlot, and dropping the response
    // releases everything back.  Measured on both sides: the submitter
    // thread directly, the worker thread via
    // Snapshot::{last_infer_allocs,last_cycle_allocs}.
    let ps = Arc::new(synthetic_mm_store(&ViTConfig::default(), 7));
    let workloads = CpuWorkloads {
        joint: vec![("vqa".to_string(), JointKind::Vqa,
                     vec![("pitome".to_string(), 0.9)])],
        ..Default::default()
    };
    // tracing ON: batch spans + merge telemetry ride the measured cycle
    let cfg = ServingConfig { workers: 1, trace_capacity: 4096,
                              ..Default::default() };
    let coord =
        Coordinator::boot_cpu_workloads(&ps, &workloads, cfg).unwrap();
    let pool = coord.pool().clone();
    let slot = coord.response_slot();
    let item = pitome::data::shape_item(pitome::data::TEST_SEED, 0);
    let patches = pitome::data::patchify(&item.image, 4);
    let (question, _) = pitome::data::vqa_item(pitome::data::TEST_SEED, 0);

    // the admission-controlled path (deadline stamp + non-blocking
    // try_send) must preserve the zero-allocation guarantee, so the
    // cycle submits through it with a deadline armed
    let cycle = || {
        let mut vt = pool.take_f32(patches.data.len());
        vt.fill_f32(&patches.data, &[patches.rows, patches.cols]);
        let mut qt = pool.take_i32(question.len());
        qt.fill_i32(&question, &[question.len()]);
        let adm = coord
            .try_submit_pooled(Workload::Joint, "vqa", Qos::Throughput,
                               Payload::Joint { vision: vt, text: qt },
                               Some(Duration::from_secs(60)), &slot)
            .unwrap();
        assert_eq!(adm, Admission::Admitted);
        let resp = slot.recv().unwrap();
        assert_eq!(resp.outputs[0].as_f32().unwrap().len(),
                   pitome::data::N_ANSWERS);
        // dropping `resp` returns the logits buffer to the pool
    };
    // generous warm-up: session pools grow, freelists stock every buffer
    // size, channel/parking internals finish their lazy init
    for _ in 0..8 {
        cycle();
    }
    // let the worker finish recycling the last request's input tensors
    std::thread::sleep(Duration::from_millis(50));

    let (_, fresh_before) = pool.stats();
    let before = allocs_this_thread();
    cycle();
    let allocs = allocs_this_thread() - before;
    assert_eq!(allocs, 0,
               "submitter-side joint request→response→release cycle \
                allocated {allocs} times");
    // the bucketed pool must serve the whole warmed cycle from recycled
    // buffers: zero fresh backing allocations in any capacity class
    let (_, fresh_after) = pool.stats();
    assert_eq!(fresh_after, fresh_before,
               "warmed joint cycle took {} fresh pool buffers",
               fresh_after - fresh_before);

    // worker side: the metrics land after the respond loop, so give the
    // worker a beat before reading them
    std::thread::sleep(Duration::from_millis(50));
    let typed = coord.metrics_typed();
    assert_eq!(typed.len(), 1);
    let (w, _, _, snap) = &typed[0];
    assert_eq!(*w, Workload::Joint);
    assert_eq!(snap.count, 9);
    assert_eq!(snap.last_infer_allocs, 0,
               "joint worker inference region allocated {} times",
               snap.last_infer_allocs);
    assert_eq!(snap.last_cycle_allocs, 0,
               "joint worker batch cycle (transport included) allocated \
                {} times",
               snap.last_cycle_allocs);
    assert!(snap.resp_recycled > 0,
            "steady-state responses must reuse recycled buffers");
}

#[test]
fn warmed_gallery_query_cycle_is_allocation_free_including_transport() {
    // the gallery tentpole acceptance: after ingests have grown the
    // shard segments and warm-up queries have sized the worker's scan
    // scratch (per-shard heaps, merge cursors, hit/flat buffers), a
    // query→top-k→release cycle allocates ZERO on the submitter thread
    // and across the worker's whole batch cycle, and takes no fresh
    // pool buffers.
    let ps = Arc::new(synthetic_mm_store(&ViTConfig::default(), 7));
    let workloads = CpuWorkloads {
        gallery: vec![("gal".to_string(),
                       vec![("pitome".to_string(), 0.9)])],
        ..Default::default()
    };
    // tracing ON: gallery scan spans ride the measured cycle
    let cfg = ServingConfig { workers: 1, trace_capacity: 4096,
                              ..Default::default() };
    let coord =
        Coordinator::boot_cpu_workloads(&ps, &workloads, cfg).unwrap();
    let pool = coord.pool().clone();
    let slot = coord.response_slot();
    let item = pitome::data::shape_item(pitome::data::TEST_SEED, 0);
    let patches = pitome::data::patchify(&item.image, 4);

    // populate the store through the embed-once ingest path (segment
    // growth is expected and allowed here)
    for _ in 0..6 {
        let mut t = pool.take_f32(patches.data.len());
        t.fill_f32(&patches.data, &[patches.rows, patches.cols]);
        coord.submit_pooled(Workload::Gallery, "gal", Qos::Accuracy,
                            Payload::GalleryIngest(t), &slot)
            .unwrap();
        drop(slot.recv().unwrap());
    }

    let cycle = || {
        let mut t = pool.take_f32(patches.data.len());
        t.fill_f32(&patches.data, &[patches.rows, patches.cols]);
        coord.submit_pooled(Workload::Gallery, "gal", Qos::Throughput,
                            Payload::GalleryQuery { probe: t, k: 4 },
                            &slot)
            .unwrap();
        let resp = slot.recv().unwrap();
        // (hits, 2) rows of [id, score]; 6 rows ingested, k = 4
        assert_eq!(resp.outputs[0].as_f32().unwrap().len(), 4 * 2);
        // dropping `resp` returns the hit buffer to the pool
    };
    // warm-up queries grow the scan scratch and every pool class
    for _ in 0..8 {
        cycle();
    }
    // let the worker finish recycling the last request's input tensor
    std::thread::sleep(Duration::from_millis(50));

    let (_, fresh_before) = pool.stats();
    let before = allocs_this_thread();
    cycle();
    let allocs = allocs_this_thread() - before;
    assert_eq!(allocs, 0,
               "submitter-side gallery query→top-k→release cycle \
                allocated {allocs} times");
    let (_, fresh_after) = pool.stats();
    assert_eq!(fresh_after, fresh_before,
               "warmed gallery query took {} fresh pool buffers",
               fresh_after - fresh_before);

    std::thread::sleep(Duration::from_millis(50));
    let typed = coord.metrics_typed();
    assert_eq!(typed.len(), 1);
    let (w, _, _, snap) = &typed[0];
    assert_eq!(*w, Workload::Gallery);
    assert_eq!(snap.gallery_len, 6, "every ingest must land in the store");
    assert_eq!(snap.last_infer_allocs, 0,
               "gallery worker inference region allocated {} times",
               snap.last_infer_allocs);
    assert_eq!(snap.last_cycle_allocs, 0,
               "gallery worker batch cycle (scan + transport) allocated \
                {} times",
               snap.last_cycle_allocs);
    assert!(snap.resp_recycled > 0,
            "steady-state gallery responses must reuse recycled buffers");
}

#[test]
fn warmed_eig_scratch_evaluates_spectral_distance_allocation_free() {
    let spec = ClusterSpec { sizes: vec![12, 6, 4], h: 12, noise: 0.1,
                             seed: 3, layout: Layout::Interleaved };
    let (kf, _) = clustered_tokens(&spec);
    let w = token_graph(&kf);
    let mut coarsen = CoarsenScratch::new();
    let mut p = Partition::identity(0);
    iterative_coarsen_scratch(&kf, CoarsenAlgo::PiToMe, 3, 2, 0.6, 7,
                              &mut coarsen, &mut p);
    let mut eig = EigScratch::new();
    let warm = spectral_distance_scratch(&w, &p, &mut eig);
    let before = allocs_this_thread();
    let sd = spectral_distance_scratch(&w, &p, &mut eig);
    let allocs = allocs_this_thread() - before;
    assert_eq!(allocs, 0,
               "warmed SD(G, Gc) evaluation allocated {allocs} times");
    assert_eq!(sd, warm, "warmed evaluation changed the distance");
    // and the scratch path agrees with the allocating wrapper
    let want = pitome::graph::spectral_distance(&w, &p);
    assert_eq!(sd, want, "scratch SD {sd} != wrapper SD {want}");
}
