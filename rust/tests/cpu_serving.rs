//! End-to-end serving over the pure-Rust CPU backend: boots the
//! coordinator with `boot_cpu` (no PJRT artifacts anywhere), drives it
//! with real requests, and checks every answer against direct engine
//! evaluation.  This exercises the full stack — router, dynamic batcher,
//! engine sessions, shared-Gram merge steps across worker threads — in an
//! artifact-free environment.

use std::sync::Arc;

use pitome::config::{ServingConfig, ViTConfig};
use pitome::coordinator::{Coordinator, Qos};
use pitome::data::{patchify, shape_item, TEST_SEED};
use pitome::engine::Engine;
use pitome::model::synthetic_vit_store;
use pitome::runtime::HostTensor;
use pitome::tensor::argmax;

fn patches_for(i: u64) -> pitome::tensor::Mat {
    let item = shape_item(TEST_SEED, i);
    patchify(&item.image, 4)
}

/// Direct engine predictions for `patches` under `cfg` (seed 0 — the
/// same derivation the serving worker uses).
fn direct_predictions(engine: &Engine, cfg: &ViTConfig,
                      patches: &[pitome::tensor::Mat]) -> Vec<usize> {
    let mut sess = engine.vit_session(cfg).unwrap();
    sess.begin(patches.len());
    for (i, p) in patches.iter().enumerate() {
        sess.set_patches(i, p).unwrap();
    }
    sess.forward(0).unwrap();
    (0..patches.len()).map(|i| sess.predict(i)).collect()
}

#[test]
fn cpu_coordinator_matches_direct_model() {
    let ps = Arc::new(synthetic_vit_store(&ViTConfig::default(), 7));
    let selection = [("vit", vec![("none".to_string(), 1.0),
                                  ("pitome".to_string(), 0.9)])];
    let cfg = ServingConfig { workers: 2, ..Default::default() };
    let coord = Coordinator::boot_cpu(&ps, &selection, cfg).unwrap();

    // direct reference predictions on the compressed rung
    let engine = Engine::new(ps.clone());
    let pitome_cfg = ViTConfig { merge_mode: "pitome".into(), merge_r: 0.9,
                                 ..Default::default() };
    let n = 12u64;
    let all_patches: Vec<_> = (0..n).map(patches_for).collect();
    let expected = direct_predictions(&engine, &pitome_cfg, &all_patches);

    // burst-submit so the batcher actually aggregates
    let mut rxs = Vec::new();
    for p in &all_patches {
        rxs.push(coord.submit_nowait(
            "vit", Qos::Throughput,
            vec![HostTensor::F32(p.data.clone(), vec![p.rows, p.cols])])
            .unwrap());
    }
    for (i, rx) in rxs.into_iter().enumerate() {
        let resp = rx.recv().expect("cpu worker answered");
        let logits = resp.outputs[0].as_f32().unwrap();
        assert_eq!(logits.len(), 10);
        assert_eq!(argmax(logits), expected[i], "request {i} diverged");
        assert!(resp.batch_size >= 1);
    }

    // both rungs are live and routable
    let resp = coord.submit(
        "vit", Qos::Accuracy,
        vec![HostTensor::F32(all_patches[0].data.clone(),
                             vec![all_patches[0].rows, all_patches[0].cols])])
        .unwrap();
    let direct = direct_predictions(&engine, &ViTConfig::default(),
                                    &all_patches[..1]);
    assert_eq!(argmax(resp.outputs[0].as_f32().unwrap()), direct[0]);

    let metrics = coord.metrics();
    assert_eq!(metrics.len(), 2);
    let total: u64 = metrics.iter().map(|(_, _, s)| s.count).sum();
    assert_eq!(total, n + 1);
}

#[test]
fn cpu_coordinator_rejects_malformed_input() {
    let ps = Arc::new(synthetic_vit_store(&ViTConfig::default(), 3));
    let selection = [("vit", vec![("pitome".to_string(), 0.9)])];
    let coord =
        Coordinator::boot_cpu(&ps, &selection, ServingConfig::default()).unwrap();
    // wrong shape: worker drops the whole (singleton) batch, so the
    // response channel closes without an answer
    let rx = coord.submit_nowait(
        "vit", Qos::Throughput,
        vec![HostTensor::F32(vec![0.0; 7], vec![7])]).unwrap();
    assert!(rx.recv().is_err(), "malformed request must not get a response");
    // the worker survives and keeps serving
    let p = patches_for(0);
    let resp = coord.submit(
        "vit", Qos::Throughput,
        vec![HostTensor::F32(p.data.clone(), vec![p.rows, p.cols])]).unwrap();
    assert_eq!(resp.outputs[0].as_f32().unwrap().len(), 10);
}
