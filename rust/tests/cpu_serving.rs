//! End-to-end serving over the pure-Rust CPU backend: boots the
//! coordinator with `boot_cpu` (no PJRT artifacts anywhere), drives it
//! with real requests, and checks every answer against direct engine
//! evaluation.  This exercises the full stack — router, dynamic batcher,
//! engine sessions, shared-Gram merge steps across worker threads — in an
//! artifact-free environment.

use std::sync::Arc;
use std::time::Duration;

use pitome::config::{ServingConfig, TextConfig, ViTConfig};
use pitome::coordinator::{Admission, Coordinator, CpuWorkloads, Payload, Qos,
                          Workload};
use pitome::data::{patchify, sent_item, shape_item, vqa_item, TEST_SEED};
use pitome::engine::{Engine, JointConfig, JointKind};
use pitome::model::{synthetic_mm_store, synthetic_vit_store};
use pitome::runtime::HostTensor;
use pitome::tensor::argmax;

fn patches_for(i: u64) -> pitome::tensor::Mat {
    let item = shape_item(TEST_SEED, i);
    patchify(&item.image, 4)
}

/// Direct engine predictions for `patches` under `cfg` (seed 0 — the
/// same derivation the serving worker uses).
fn direct_predictions(engine: &Engine, cfg: &ViTConfig,
                      patches: &[pitome::tensor::Mat]) -> Vec<usize> {
    let mut sess = engine.vit_session(cfg).unwrap();
    sess.begin(patches.len());
    for (i, p) in patches.iter().enumerate() {
        sess.set_patches(i, p).unwrap();
    }
    sess.forward(0).unwrap();
    (0..patches.len()).map(|i| sess.predict(i)).collect()
}

#[test]
fn cpu_coordinator_matches_direct_model() {
    let ps = Arc::new(synthetic_vit_store(&ViTConfig::default(), 7));
    let selection = [("vit", vec![("none".to_string(), 1.0),
                                  ("pitome".to_string(), 0.9)])];
    let cfg = ServingConfig { workers: 2, ..Default::default() };
    let coord = Coordinator::boot_cpu(&ps, &selection, cfg).unwrap();

    // direct reference predictions on the compressed rung
    let engine = Engine::new(ps.clone());
    let pitome_cfg = ViTConfig { merge_mode: "pitome".into(), merge_r: 0.9,
                                 ..Default::default() };
    let n = 12u64;
    let all_patches: Vec<_> = (0..n).map(patches_for).collect();
    let expected = direct_predictions(&engine, &pitome_cfg, &all_patches);

    // burst-submit so the batcher actually aggregates
    let mut rxs = Vec::new();
    for p in &all_patches {
        rxs.push(coord.submit_nowait(
            "vit", Qos::Throughput,
            vec![HostTensor::F32(p.data.clone(), vec![p.rows, p.cols])])
            .unwrap());
    }
    for (i, rx) in rxs.into_iter().enumerate() {
        let resp = rx.recv().expect("cpu worker answered");
        let logits = resp.outputs[0].as_f32().unwrap();
        assert_eq!(logits.len(), 10);
        assert_eq!(argmax(logits), expected[i], "request {i} diverged");
        assert!(resp.batch_size >= 1);
    }

    // both rungs are live and routable
    let resp = coord.submit(
        "vit", Qos::Accuracy,
        vec![HostTensor::F32(all_patches[0].data.clone(),
                             vec![all_patches[0].rows, all_patches[0].cols])])
        .unwrap();
    let direct = direct_predictions(&engine, &ViTConfig::default(),
                                    &all_patches[..1]);
    assert_eq!(argmax(resp.outputs[0].as_f32().unwrap()), direct[0]);

    let metrics = coord.metrics();
    assert_eq!(metrics.len(), 2);
    let total: u64 = metrics.iter().map(|(_, _, s)| s.count).sum();
    assert_eq!(total, n + 1);
}

#[test]
fn mixed_workload_traffic_routes_fairly_with_per_workload_metrics() {
    // one coordinator, three workload pools over one engine + one
    // recycling pool; interleaved Vision/Text/Joint requests must each
    // reach their own pool, answer correctly against direct session
    // evaluation, and show up in their own per-workload metrics
    let vcfg = ViTConfig { merge_mode: "pitome".into(), merge_r: 0.9,
                           ..Default::default() };
    let ps = Arc::new(synthetic_mm_store(&ViTConfig::default(), 7));
    let workloads = CpuWorkloads {
        vision: vec![("vit".to_string(),
                      vec![("pitome".to_string(), 0.9)])],
        text: vec![("bert".to_string(), vec![("none".to_string(), 1.0)])],
        joint: vec![("vqa".to_string(), JointKind::Vqa,
                     vec![("pitome".to_string(), 0.9)])],
        ..Default::default()
    };
    let coord = Coordinator::boot_cpu_workloads(
        &ps, &workloads, ServingConfig::default()).unwrap();
    let pool = coord.pool().clone();
    let tcfg = TextConfig { merge_mode: "none".into(), merge_r: 1.0,
                            ..Default::default() };

    // direct references (deterministic modes, so worker batching
    // composition cannot change the results)
    let engine = Engine::new(ps.clone());
    let n = 6u64;
    let mut want_vis = Vec::new();
    let mut want_txt = Vec::new();
    let mut want_ans = Vec::new();
    {
        let mut vs = engine.vit_session(&vcfg).unwrap();
        let mut bs = engine.bert_session(&tcfg).unwrap();
        let mut js =
            engine.joint_session(&JointConfig::vqa(vcfg.clone())).unwrap();
        for i in 0..n {
            let item = shape_item(TEST_SEED, i);
            let patches = patchify(&item.image, 4);
            vs.begin(1);
            vs.set_patches(0, &patches).unwrap();
            vs.forward(0).unwrap();
            want_vis.push(vs.predict(0));
            let (toks, _) = sent_item(TEST_SEED, i, tcfg.seq_len, 16);
            bs.begin(1);
            bs.set_tokens(0, &toks).unwrap();
            bs.forward(0).unwrap();
            want_txt.push(bs.predict(0));
            let (q, _) = vqa_item(TEST_SEED, i);
            js.begin(1, 1);
            js.set_patches(0, &patches).unwrap();
            js.set_text(0, &q).unwrap();
            js.forward(0).unwrap();
            js.fuse_vqa(&[(0, 0)]).unwrap();
            want_ans.push(js.answer(0));
        }
    }

    // interleaved burst across the three pools
    let mut rxs = Vec::new();
    for i in 0..n {
        let item = shape_item(TEST_SEED, i);
        let patches = patchify(&item.image, 4);
        let (toks, _) = sent_item(TEST_SEED, i, tcfg.seq_len, 16);
        let (q, _) = vqa_item(TEST_SEED, i);
        let mut vt = pool.take_f32(patches.data.len());
        vt.fill_f32(&patches.data, &[patches.rows, patches.cols]);
        rxs.push((Workload::Vision,
                  coord.submit_typed(Workload::Vision, "vit",
                                     Qos::Throughput, Payload::Vision(vt))
                      .unwrap()));
        let mut tt = pool.take_i32(toks.len());
        tt.fill_i32(&toks, &[toks.len()]);
        rxs.push((Workload::Text,
                  coord.submit_typed(Workload::Text, "bert",
                                     Qos::Throughput, Payload::Text(tt))
                      .unwrap()));
        let mut jv = pool.take_f32(patches.data.len());
        jv.fill_f32(&patches.data, &[patches.rows, patches.cols]);
        let mut jq = pool.take_i32(q.len());
        jq.fill_i32(&q, &[q.len()]);
        rxs.push((Workload::Joint,
                  coord.submit_typed(Workload::Joint, "vqa",
                                     Qos::Throughput,
                                     Payload::Joint { vision: jv, text: jq })
                      .unwrap()));
    }
    let (mut vi, mut ti, mut ji) = (0usize, 0usize, 0usize);
    for (w, rx) in rxs {
        let resp = rx.recv().expect("worker answered");
        let logits = resp.outputs[0].as_f32().unwrap();
        match w {
            Workload::Vision => {
                assert_eq!(argmax(logits), want_vis[vi],
                           "vision request {vi} diverged");
                vi += 1;
            }
            Workload::Text => {
                assert_eq!(logits.len(), tcfg.num_classes);
                assert_eq!(argmax(logits), want_txt[ti],
                           "text request {ti} diverged");
                ti += 1;
            }
            Workload::Joint => {
                assert_eq!(logits.len(), pitome::data::N_ANSWERS);
                assert_eq!(argmax(logits), want_ans[ji],
                           "joint request {ji} diverged");
                ji += 1;
            }
        }
    }
    assert_eq!((vi, ti, ji), (n as usize, n as usize, n as usize));

    // routing fairness: every workload pool saw exactly its n requests,
    // and the per-workload metrics expose them separately
    let typed = coord.metrics_typed();
    assert_eq!(typed.len(), 3);
    for (w, model, _artifact, snap) in &typed {
        assert_eq!(snap.count, n, "{} pool ({model}) count", w.name());
        assert!(snap.mean_batch >= 1.0);
    }
    assert_eq!(typed.iter().filter(|(w, ..)| *w == Workload::Vision).count(),
               1);
    assert_eq!(typed.iter().filter(|(w, ..)| *w == Workload::Text).count(),
               1);
    assert_eq!(typed.iter().filter(|(w, ..)| *w == Workload::Joint).count(),
               1);
    // settle round: every burst response has been received and dropped by
    // now, so the pool's class shelves are warm and the follow-up request
    // must recycle its response buffer from them
    let item = shape_item(TEST_SEED, 0);
    let patches = patchify(&item.image, 4);
    let mut vt = pool.take_f32(patches.data.len());
    vt.fill_f32(&patches.data, &[patches.rows, patches.cols]);
    let resp = coord
        .submit_typed(Workload::Vision, "vit", Qos::Throughput,
                      Payload::Vision(vt))
        .unwrap()
        .recv()
        .expect("settle round answered");
    assert_eq!(argmax(resp.outputs[0].as_f32().unwrap()), want_vis[0]);
    drop(resp);
    let (recycled, _fresh) = pool.stats();
    assert!(recycled > 0, "no response/request buffer was ever recycled");
}

#[test]
fn joint_worker_splits_ragged_mixed_batches() {
    // vision-only and text-only singles ride the joint pool next to full
    // pairs: the splitter must size the halves independently and answer
    // singles with their tower features
    let vcfg = ViTConfig { merge_mode: "pitome".into(), merge_r: 0.9,
                           ..Default::default() };
    let ps = Arc::new(synthetic_mm_store(&ViTConfig::default(), 7));
    let workloads = CpuWorkloads {
        joint: vec![("vqa".to_string(), JointKind::Vqa,
                     vec![("pitome".to_string(), 0.9)])],
        ..Default::default()
    };
    let coord = Coordinator::boot_cpu_workloads(
        &ps, &workloads, ServingConfig::default()).unwrap();
    let pool = coord.pool().clone();

    let item = shape_item(TEST_SEED, 1);
    let patches = patchify(&item.image, 4);
    let (q, _) = vqa_item(TEST_SEED, 1);

    // direct references
    let engine = Engine::new(ps.clone());
    let mut js =
        engine.joint_session(&JointConfig::vqa(vcfg.clone())).unwrap();
    js.begin(1, 1);
    js.set_patches(0, &patches).unwrap();
    js.set_text(0, &q).unwrap();
    js.forward(0).unwrap();
    js.fuse_vqa(&[(0, 0)]).unwrap();
    let want_ans = js.answer_logits(0).to_vec();
    let want_vf = js.image_feature(0).to_vec();
    let want_tf = js.text_feature(0).to_vec();

    // burst: pair + vision-only + text-only into the same joint queue
    let mut jv = pool.take_f32(patches.data.len());
    jv.fill_f32(&patches.data, &[patches.rows, patches.cols]);
    let mut jq = pool.take_i32(q.len());
    jq.fill_i32(&q, &[q.len()]);
    let rx_pair = coord.submit_typed(Workload::Joint, "vqa", Qos::Throughput,
                                     Payload::Joint { vision: jv, text: jq })
        .unwrap();
    let mut v = pool.take_f32(patches.data.len());
    v.fill_f32(&patches.data, &[patches.rows, patches.cols]);
    let rx_vis = coord.submit_typed(Workload::Joint, "vqa", Qos::Throughput,
                                    Payload::Vision(v)).unwrap();
    let mut t = pool.take_i32(q.len());
    t.fill_i32(&q, &[q.len()]);
    let rx_txt = coord.submit_typed(Workload::Joint, "vqa", Qos::Throughput,
                                    Payload::Text(t)).unwrap();

    let pair = rx_pair.recv().expect("pair answered");
    assert_eq!(pair.outputs[0].as_f32().unwrap(), &want_ans[..],
               "ragged pair answer diverged");
    let vis = rx_vis.recv().expect("vision single answered");
    assert_eq!(vis.outputs[0].as_f32().unwrap(), &want_vf[..],
               "vision single must get the tower feature");
    let txt = rx_txt.recv().expect("text single answered");
    assert_eq!(txt.outputs[0].as_f32().unwrap(), &want_tf[..],
               "text single must get the tower feature");
}

#[test]
fn pooled_clients_get_an_error_instead_of_hanging_on_a_failed_batch() {
    // a ResponseSlot keeps its own sender alive, so a failed batch can't
    // surface as a closed channel — the worker must deliver the explicit
    // failure marker and recv must turn it into an error, not block
    let ps = Arc::new(synthetic_mm_store(&ViTConfig::default(), 7));
    let workloads = CpuWorkloads {
        joint: vec![("vqa".to_string(), JointKind::Vqa,
                     vec![("pitome".to_string(), 0.9)])],
        ..Default::default()
    };
    let coord = Coordinator::boot_cpu_workloads(
        &ps, &workloads, ServingConfig::default()).unwrap();
    let pool = coord.pool().clone();
    let slot = coord.response_slot();

    // malformed: question of the wrong length fails set_text in the
    // (singleton) batch
    let mut bad = pool.take_i32(3);
    bad.fill_i32(&[1, 2, 3], &[3]);
    let item = shape_item(TEST_SEED, 0);
    let patches = patchify(&item.image, 4);
    let mut vt = pool.take_f32(patches.data.len());
    vt.fill_f32(&patches.data, &[patches.rows, patches.cols]);
    coord.submit_pooled(Workload::Joint, "vqa", Qos::Throughput,
                        Payload::Joint { vision: vt, text: bad }, &slot)
        .unwrap();
    assert!(slot.recv().is_err(),
            "failed batch must surface as an error on the slot");

    // the worker survives and keeps answering on the same slot
    let (q, _) = vqa_item(TEST_SEED, 0);
    let mut vt = pool.take_f32(patches.data.len());
    vt.fill_f32(&patches.data, &[patches.rows, patches.cols]);
    let mut qt = pool.take_i32(q.len());
    qt.fill_i32(&q, &[q.len()]);
    coord.submit_pooled(Workload::Joint, "vqa", Qos::Throughput,
                        Payload::Joint { vision: vt, text: qt }, &slot)
        .unwrap();
    let resp = slot.recv().expect("worker kept serving after the failure");
    assert_eq!(resp.outputs[0].as_f32().unwrap().len(),
               pitome::data::N_ANSWERS);
}

#[test]
fn balanced_routing_keeps_preferred_rung_on_small_idle_queues() {
    // regression: `has_capacity` used to compute `depth < capacity / 2`,
    // which is `depth < 0` at queue_capacity 1 — an *idle* small queue
    // reported "no headroom" and Balanced traffic silently shed down the
    // whole ladder.  With the ceiling division an idle queue always has
    // capacity, so a lone Balanced request must land on the preferred
    // rung (most-compressed-but-one: pitome r=0.9), not on tome r=0.5.
    let ps = Arc::new(synthetic_vit_store(&ViTConfig::default(), 7));
    let selection = [("vit", vec![("none".to_string(), 1.0),
                                  ("pitome".to_string(), 0.9),
                                  ("tome".to_string(), 0.5)])];
    let cfg = ServingConfig { queue_capacity: 1, workers: 1,
                              ..Default::default() };
    let coord = Coordinator::boot_cpu(&ps, &selection, cfg).unwrap();

    let p = patches_for(0);
    let resp = coord.submit(
        "vit", Qos::Balanced,
        vec![HostTensor::F32(p.data.clone(), vec![p.rows, p.cols])]).unwrap();
    assert_eq!(resp.outputs[0].as_f32().unwrap().len(), 10);

    let metrics = coord.metrics();
    assert_eq!(metrics.len(), 3);
    for (_, artifact, snap) in &metrics {
        if artifact == "cpu_pitome_r900" {
            assert_eq!(snap.count, 1,
                       "Balanced must route to the preferred rung");
        } else {
            assert_eq!(snap.count, 0,
                       "{artifact} must stay idle — Balanced shed off an \
                        idle preferred rung");
        }
    }
}

#[test]
fn deadline_expired_requests_fail_fast_with_a_counted_response() {
    // admission-control acceptance: a request whose deadline has already
    // passed when the worker dequeues it is dropped *before* execution,
    // counted in Snapshot::expired, and answered with an explicit expiry
    // marker (never silently) — and the worker keeps serving afterwards
    let ps = Arc::new(synthetic_vit_store(&ViTConfig::default(), 7));
    let selection = [("vit", vec![("pitome".to_string(), 0.9)])];
    let cfg = ServingConfig { workers: 1, ..Default::default() };
    let coord = Coordinator::boot_cpu(&ps, &selection, cfg).unwrap();
    let pool = coord.pool().clone();
    let slot = coord.response_slot();
    let p = patches_for(0);
    let submit = |deadline: Option<Duration>| {
        let mut vt = pool.take_f32(p.data.len());
        vt.fill_f32(&p.data, &[p.rows, p.cols]);
        let adm = coord.try_submit_pooled(Workload::Vision, "vit",
                                          Qos::Throughput,
                                          Payload::Vision(vt), deadline,
                                          &slot).unwrap();
        assert_eq!(adm, Admission::Admitted);
    };

    // warm: no deadline, normal answer
    submit(None);
    assert_eq!(slot.recv().unwrap().outputs[0].as_f32().unwrap().len(), 10);

    // an already-expired deadline must surface as a counted expiry error
    submit(Some(Duration::from_micros(0)));
    let err = slot.recv().expect_err("expired request must not be executed");
    assert!(err.to_string().contains("deadline"),
            "expiry marker must name the deadline, got: {err}");
    let metrics = coord.metrics();
    assert_eq!(metrics.len(), 1);
    assert_eq!(metrics[0].2.expired, 1, "worker must count the expiry");
    assert_eq!(metrics[0].2.count, 1,
               "expired request must not reach the inference region");

    // the worker survives and keeps answering on the same slot
    submit(None);
    assert_eq!(slot.recv().unwrap().outputs[0].as_f32().unwrap().len(), 10);
}

#[test]
fn cpu_coordinator_rejects_malformed_input() {
    let ps = Arc::new(synthetic_vit_store(&ViTConfig::default(), 3));
    let selection = [("vit", vec![("pitome".to_string(), 0.9)])];
    let coord =
        Coordinator::boot_cpu(&ps, &selection, ServingConfig::default()).unwrap();
    // wrong shape: worker drops the whole (singleton) batch, so the
    // response channel closes without an answer
    let rx = coord.submit_nowait(
        "vit", Qos::Throughput,
        vec![HostTensor::F32(vec![0.0; 7], vec![7])]).unwrap();
    assert!(rx.recv().is_err(), "malformed request must not get a response");
    // the worker survives and keeps serving
    let p = patches_for(0);
    let resp = coord.submit(
        "vit", Qos::Throughput,
        vec![HostTensor::F32(p.data.clone(), vec![p.rows, p.cols])]).unwrap();
    assert_eq!(resp.outputs[0].as_f32().unwrap().len(), 10);
}
