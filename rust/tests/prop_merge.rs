//! Property-based invariant tests over the merge engine, the schedules,
//! and the spectral toolkit (quickcheck helper, DESIGN.md §11).

use pitome::config::DEFAULT_TOFU_PRUNE_THRESHOLD;
use pitome::data::Rng;
use pitome::graph::{coarsen, lift, normalized_laplacian, jacobi_eigenvalues,
                    Partition};
use pitome::merge::{energy_scores, fixed_k_plan, merge_plan, merge_step,
                    tokens_after_merge, MergeCtx, MergeMode};
use pitome::tensor::Mat;
use pitome::util::quickcheck::{property, Gen};

fn random_ctx(g: &mut Gen) -> (Mat, Mat, Vec<f32>, Vec<f32>, usize) {
    let n = g.usize_in(9, 60);
    let h = *g.choose(&[4usize, 8, 16]);
    let x = Mat::from_fn(n, h, |_, _| g.f32_in(-1.0, 1.0));
    let kf = Mat::from_fn(n, h, |_, _| g.f32_in(-1.0, 1.0));
    let sizes: Vec<f32> = (0..n).map(|_| g.f32_in(0.5, 3.0)).collect();
    let attn: Vec<f32> = (0..n).map(|_| g.f32_in(0.0, 1.0)).collect();
    let k = g.usize_in(1, (n - 1) / 2 - 1);
    (x, kf, sizes, attn, k)
}

const MODES: [MergeMode; 8] = [
    MergeMode::PiToMe, MergeMode::PiToMeNoProtect, MergeMode::PiToMeRandomSplit,
    MergeMode::PiToMeAttn, MergeMode::ToMe, MergeMode::ToFu,
    MergeMode::DiffRate, MergeMode::Random,
];

#[test]
fn prop_output_shape_all_modes() {
    property("output shape", 60, |g| {
        let (x, kf, sizes, attn, k) = random_ctx(g);
        let mode = *g.choose(&MODES);
        let mut rng = Rng::new(1);
        let ctx = MergeCtx { x: &x, kf: &kf, sizes: &sizes, attn_cls: &attn,
                             margin: g.f32_in(-0.2, 0.9), k, protect_first: 1,
                             tofu_threshold: DEFAULT_TOFU_PRUNE_THRESHOLD };
        let (out, out_sizes) = merge_step(mode, &ctx, &mut rng);
        assert_eq!(out.rows, x.rows - k, "{mode:?}");
        assert_eq!(out_sizes.len(), x.rows - k);
        assert!(out.data.iter().all(|v| v.is_finite()), "{mode:?} nonfinite");
    });
}

#[test]
fn prop_mass_conservation() {
    property("mass conservation", 60, |g| {
        let (x, kf, sizes, attn, k) = random_ctx(g);
        let total: f32 = sizes.iter().sum();
        for mode in [MergeMode::PiToMe, MergeMode::PiToMeRandomSplit,
                     MergeMode::PiToMeAttn, MergeMode::ToMe,
                     MergeMode::DiffRate] {
            let mut rng = Rng::new(2);
            let ctx = MergeCtx { x: &x, kf: &kf, sizes: &sizes,
                                 attn_cls: &attn, margin: 0.5, k,
                                 protect_first: 1,
                                 tofu_threshold: DEFAULT_TOFU_PRUNE_THRESHOLD };
            let (_, out_sizes) = merge_step(mode, &ctx, &mut rng);
            let t2: f32 = out_sizes.iter().sum();
            assert!((t2 - total).abs() < total * 1e-4,
                    "{mode:?}: {t2} vs {total}");
        }
    });
}

#[test]
fn prop_convex_hull_bounds() {
    property("convex bounds", 40, |g| {
        let (x, kf, sizes, attn, k) = random_ctx(g);
        let hi = x.data.iter().cloned().fold(f32::MIN, f32::max);
        let lo = x.data.iter().cloned().fold(f32::MAX, f32::min);
        let mut rng = Rng::new(3);
        let ctx = MergeCtx { x: &x, kf: &kf, sizes: &sizes, attn_cls: &attn,
                             margin: 0.5, k, protect_first: 1,
                             tofu_threshold: DEFAULT_TOFU_PRUNE_THRESHOLD };
        let (out, _) = merge_step(MergeMode::PiToMe, &ctx, &mut rng);
        for &v in &out.data {
            assert!(v <= hi + 1e-4 && v >= lo - 1e-4);
        }
    });
}

#[test]
fn prop_cls_always_survives_unchanged() {
    property("cls protected", 40, |g| {
        let (x, kf, sizes, attn, k) = random_ctx(g);
        let mode = *g.choose(&MODES);
        let mut rng = Rng::new(4);
        let ctx = MergeCtx { x: &x, kf: &kf, sizes: &sizes, attn_cls: &attn,
                             margin: 0.5, k, protect_first: 1,
                             tofu_threshold: DEFAULT_TOFU_PRUNE_THRESHOLD };
        let (out, out_sizes) = merge_step(mode, &ctx, &mut rng);
        // CLS row must appear in the output with its original value. For
        // every mode the protected prefix lands at output row 0 except
        // diffrate, where B is sorted ascending so CLS is still row 0.
        let cls_in: Vec<f32> = x.row(0).to_vec();
        let found = (0..out.rows).any(|i| {
            out.row(i).iter().zip(&cls_in).all(|(a, b)| (a - b).abs() < 1e-5)
        });
        assert!(found, "{mode:?}: CLS vanished");
        assert!(out_sizes.iter().all(|&s| s >= 0.0));
    });
}

#[test]
fn prop_energy_bounded() {
    // E_i = mean of f_m over neighbours; f_m in [-alpha, 1]
    property("energy bounds", 60, |g| {
        let n = g.usize_in(3, 50);
        let h = g.usize_in(2, 24);
        let kf = Mat::from_fn(n, h, |_, _| g.f32_in(-2.0, 2.0));
        let margin = g.f32_in(-0.5, 0.95);
        for e in energy_scores(&kf, margin) {
            assert!(e <= 1.0 + 1e-5 && e >= -1.0 - 1e-5, "energy {e}");
        }
    });
}

#[test]
fn prop_schedule_monotone_and_bounded() {
    property("schedule", 80, |g| {
        let n0 = g.usize_in(6, 300);
        let depth = g.usize_in(1, 24);
        let r = g.f32_in(0.5, 0.999) as f64;
        let plan = merge_plan(n0, r, depth, 1, None);
        assert_eq!(plan.len(), depth + 1);
        assert_eq!(plan[0], n0);
        for w in plan.windows(2) {
            assert!(w[1] <= w[0] && w[1] >= 3.min(w[0]));
        }
        let k = g.usize_in(1, 16);
        let fp = fixed_k_plan(n0, k, depth, 1);
        for w in fp.windows(2) {
            assert!(w[1] <= w[0]);
        }
        // single-step consistency
        assert_eq!(plan[1], tokens_after_merge(n0, r, 1));
    });
}

#[test]
fn prop_coarsen_preserves_total_weight() {
    property("coarsen weight", 40, |g| {
        let n = g.usize_in(4, 24);
        let w = Mat::from_fn(n, n, |i, j| if i == j { 0.0 } else { 1.0 });
        // symmetric random-ish weights
        let mut w = w;
        for i in 0..n {
            for j in (i + 1)..n {
                let v = g.f32_in(0.0, 2.0);
                w.set(i, j, v);
                w.set(j, i, v);
            }
        }
        let groups = g.usize_in(1, n);
        let assign: Vec<usize> = (0..n).map(|_| g.usize_in(0, groups - 1)).collect();
        let p = Partition::from_assign(assign);
        let wc = coarsen(&w, &p);
        let t1: f32 = w.data.iter().sum();
        let t2: f32 = wc.data.iter().sum();
        assert!((t1 - t2).abs() < 1e-2 * t1.max(1.0), "{t1} vs {t2}");
        // lift has same total after re-expansion weighting
        let wl = lift(&wc, &p);
        assert_eq!(wl.rows, n);
    });
}

#[test]
fn prop_normalized_laplacian_spectrum_in_0_2() {
    property("laplacian spectrum", 20, |g| {
        let n = g.usize_in(4, 16);
        let mut w = Mat::zeros(n, n);
        for i in 0..n {
            for j in (i + 1)..n {
                let v = g.f32_in(0.0, 1.0);
                w.set(i, j, v);
                w.set(j, i, v);
            }
        }
        let l = normalized_laplacian(&w);
        let ev = jacobi_eigenvalues(&l, 1e-6, 100);
        assert!(ev[0] > -1e-3, "min {}", ev[0]);
        assert!(*ev.last().unwrap() < 2.0 + 1e-3);
    });
}
