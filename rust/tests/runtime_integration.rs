//! Integration over the PJRT runtime + coordinator on the real artifacts.
//! All tests skip loudly when `make artifacts` has not run.

use std::path::PathBuf;
use std::sync::Arc;

use pitome::config::ServingConfig;
use pitome::coordinator::{Coordinator, Qos};
use pitome::data::{patchify, shape_item, Rng, TEST_SEED};
use pitome::model::{load_model_params, ViTModel};
use pitome::config::ViTConfig;
use pitome::runtime::{load_flat_params, Engine, HostTensor, Registry};

fn registry() -> Option<(Registry, PathBuf)> {
    let dir = Registry::default_dir();
    match Registry::load(&dir) {
        Ok(r) => Some((r, dir)),
        Err(e) => {
            eprintln!("SKIP runtime integration: {e}");
            None
        }
    }
}

#[test]
fn artifact_executes_and_matches_cpu_model() {
    let Some((reg, dir)) = registry() else { return };
    let engine = Engine::cpu().expect("cpu client");
    let exe = engine.load(&reg, "vit_pitome_r900_b1").expect("compile");
    let params = load_flat_params(&dir, "vit_flat.bin").expect("params");
    let item = shape_item(TEST_SEED, 5);
    let patches = patchify(&item.image, 4);
    let psize = params.len();
    let out = exe.run(&[
        HostTensor::F32(params, vec![psize]),
        HostTensor::F32(patches.data.clone(), vec![1, 64, 16]),
    ]).expect("execute");
    let logits_pjrt = out[0].as_f32().unwrap();
    assert_eq!(logits_pjrt.len(), 10);

    // CPU reference must agree on the prediction (and closely on values)
    let ps = load_model_params(&dir, "vit").unwrap();
    let cfg = ViTConfig { merge_mode: "pitome".into(), merge_r: 0.9,
                          ..Default::default() };
    let model = ViTModel::new(&ps, cfg);
    let mut rng = Rng::new(0);
    let logits_cpu = model.logits(&patches, &mut rng).unwrap();
    let pred_p = pitome::tensor::argmax(logits_pjrt);
    let pred_c = pitome::tensor::argmax(&logits_cpu);
    assert_eq!(pred_p, pred_c, "PJRT vs CPU prediction diverged");
    for (a, b) in logits_pjrt.iter().zip(&logits_cpu) {
        assert!((a - b).abs() < 5e-2, "logit gap {a} vs {b}");
    }
}

#[test]
fn wrong_input_shape_is_rejected() {
    let Some((reg, dir)) = registry() else { return };
    let engine = Engine::cpu().expect("cpu client");
    let exe = engine.load(&reg, "vit_none_b1").expect("compile");
    let params = load_flat_params(&dir, "vit_flat.bin").expect("params");
    let psize = params.len();
    let err = exe.run(&[
        HostTensor::F32(params, vec![psize]),
        HostTensor::F32(vec![0.0; 7], vec![7]),
    ]);
    assert!(err.is_err(), "shape mismatch must error");
}

#[test]
fn coordinator_end_to_end_batching() {
    let Some((reg, dir)) = registry() else { return };
    let selection = [("vit", vec!["vit_pitome_r900_b8".to_string()])];
    let coord = Arc::new(Coordinator::boot(
        &reg, &dir, &selection, ServingConfig::default()).expect("boot"));

    // submit 24 requests from 3 threads; all must return the same answers
    // as direct evaluation
    let mut expected = Vec::new();
    let ps = load_model_params(&dir, "vit").unwrap();
    let cfg = ViTConfig { merge_mode: "pitome".into(), merge_r: 0.9,
                          ..Default::default() };
    let model = ViTModel::new(&ps, cfg);
    let mut rng = Rng::new(0);
    for i in 0..24u64 {
        let item = shape_item(TEST_SEED, i);
        let patches = patchify(&item.image, 4);
        expected.push(model.predict(&patches, &mut rng).unwrap());
    }

    let mut rxs = Vec::new();
    for i in 0..24u64 {
        let item = shape_item(TEST_SEED, i);
        let patches = patchify(&item.image, 4);
        rxs.push(coord.submit_nowait(
            "vit", Qos::Accuracy,
            vec![HostTensor::F32(patches.data, vec![64, 16])]).unwrap());
    }
    let mut agree = 0usize;
    for (i, rx) in rxs.into_iter().enumerate() {
        let resp = rx.recv().expect("response");
        let logits = resp.outputs[0].as_f32().unwrap();
        let pred = pitome::tensor::argmax(logits);
        if pred == expected[i] {
            agree += 1;
        }
        assert!(resp.batch_size >= 1);
    }
    assert_eq!(agree, 24, "coordinator answers diverge from direct model");

    // batching actually happened (burst of 24 into batches of <= 8)
    let snap = &coord.metrics()[0].2;
    assert!(snap.mean_batch > 1.0, "no batching: {:?}", snap.mean_batch);
}

#[test]
fn qos_routes_to_distinct_variants() {
    let Some((reg, dir)) = registry() else { return };
    let selection = [("vit", vec!["vit_none_b8".to_string(),
                                  "vit_pitome_r900_b8".to_string()])];
    let coord = Coordinator::boot(&reg, &dir, &selection,
                                  ServingConfig::default()).expect("boot");
    let item = shape_item(TEST_SEED, 1);
    let patches = patchify(&item.image, 4);
    for qos in [Qos::Accuracy, Qos::Throughput] {
        let resp = coord.submit("vit", qos,
            vec![HostTensor::F32(patches.data.clone(), vec![64, 16])])
            .expect("submit");
        assert_eq!(resp.outputs[0].as_f32().unwrap().len(), 10);
    }
    let metrics = coord.metrics();
    assert_eq!(metrics.len(), 2);
    // both variants saw exactly one request
    let total: u64 = metrics.iter().map(|(_, _, s)| s.count).sum();
    assert_eq!(total, 2);
}
