//! Property tests for the allocation-free plan builders: every
//! `*_plan_gram_into` builder must be **bitwise identical** to its
//! allocating wrapper across random (n, k, protect_first, mode) shapes —
//! including the `2k + protect_first > n` clamp edge cases from PR 1 —
//! while reusing one dirty `PlanScratch`/`MergePlan` pair for every case,
//! and every generated plan must pass `MergePlan::validate`.

use pitome::data::Rng;
use pitome::merge::diffrate::{diffrate_plan_gram, diffrate_plan_gram_into};
use pitome::merge::energy::{energy_from_gram, energy_from_gram_into,
                            energy_scores};
use pitome::merge::pitome::{ordered_bsm_plan_gram, ordered_bsm_plan_gram_into,
                            Split};
use pitome::merge::random::{random_plan, random_plan_into};
use pitome::merge::tome::{tome_plan_gram, tome_plan_gram_into};
use pitome::merge::{MergePlan, PlanScratch};
use pitome::tensor::{CosineGram, Mat};

fn random_tokens(n: usize, h: usize, seed: u64) -> Mat {
    let mut rng = Rng::new(seed);
    Mat::from_fn(n, h, |_, _| (rng.next_f64() * 2.0 - 1.0) as f32)
}

fn assert_plans_identical(got: &MergePlan, want: &MergePlan, n: usize,
                          ctx: &str) {
    assert_eq!(got.protect, want.protect, "{ctx}: protect");
    assert_eq!(got.a, want.a, "{ctx}: a");
    assert_eq!(got.b, want.b, "{ctx}: b");
    assert_eq!(got.dst, want.dst, "{ctx}: dst");
    assert_eq!(got.gate, want.gate, "{ctx}: gate");
    want.validate(n).unwrap_or_else(|e| panic!("{ctx}: wrapper plan: {e}"));
    got.validate(n).unwrap_or_else(|e| panic!("{ctx}: into plan: {e}"));
}

/// Random + PR-1 regression shapes: (n, protect_first, k).  The k values
/// deliberately overshoot so the PiToMe clamp path is exercised.
fn shapes() -> Vec<(usize, usize, usize)> {
    let mut shapes = vec![
        // the 2k + protect_first > n clamp edge cases from PR 1
        (9, 1, 10), (5, 1, 7), (8, 3, 4), (6, 1, 3), (4, 2, 5), (7, 7, 2),
        (3, 1, 1),
        // degenerate corners
        (2, 0, 1), (3, 0, 0), (12, 0, 6), (2, 1, 1),
    ];
    let mut rng = Rng::new(99);
    for _ in 0..24 {
        let n = 3 + rng.next_below(38) as usize;
        let pf = rng.next_below(4.min(n as u64)) as usize;
        let k = rng.next_below(n as u64 + 3) as usize;
        shapes.push((n, pf, k));
    }
    shapes
}

#[test]
fn pitome_into_builder_is_bitwise_identical_to_wrapper() {
    // ONE dirty scratch/plan pair reused across every case
    let mut scratch = PlanScratch::new();
    let mut plan = MergePlan::empty();
    for (ci, &(n, pf, k)) in shapes().iter().enumerate() {
        let kf = random_tokens(n, 8, 1000 + ci as u64);
        let g = CosineGram::build(&kf);
        let e = energy_from_gram(&g, 0.45);
        for split in [Split::Alternate, Split::Random] {
            for protect in [true, false] {
                let seed = (ci * 7) as u64;
                let mut r1 = Rng::new(seed);
                let want = ordered_bsm_plan_gram(&g, &e, k, pf, split,
                                                 protect, &mut r1);
                let mut r2 = Rng::new(seed);
                ordered_bsm_plan_gram_into(&g, &e, k, pf, split, protect,
                                           &mut r2, &mut scratch, &mut plan);
                assert_plans_identical(
                    &plan, &want, n,
                    &format!("pitome n={n} pf={pf} k={k} {split:?} \
                              protect={protect}"));
                // both paths must leave the RNG in the same state
                assert_eq!(r1.next_below(1 << 20), r2.next_below(1 << 20),
                           "rng state diverged at n={n} pf={pf} k={k}");
            }
        }
    }
}

#[test]
fn tome_into_builder_is_bitwise_identical_to_wrapper() {
    let mut scratch = PlanScratch::new();
    let mut plan = MergePlan::empty();
    for (ci, &(n, pf, k)) in shapes().iter().enumerate() {
        let kf = random_tokens(n, 8, 2000 + ci as u64);
        let g = CosineGram::build(&kf);
        // ToMe asserts k <= |A|; clamp to the parity split's A size, and
        // to 0 when the B side is empty (a merge needs a destination)
        let a_len = (n - pf.min(n) + 1) / 2;
        let b_len = (n - pf.min(n)) / 2;
        let k = if b_len == 0 { 0 } else { k.min(a_len) };
        for threshold in [None, Some(0.45), Some(0.99)] {
            let want = tome_plan_gram(&g, k, pf, threshold);
            tome_plan_gram_into(&g, k, pf, threshold, &mut scratch, &mut plan);
            assert_plans_identical(
                &plan, &want, n,
                &format!("tome n={n} pf={pf} k={k} thr={threshold:?}"));
        }
    }
}

#[test]
fn diffrate_into_builder_is_bitwise_identical_to_wrapper() {
    let mut scratch = PlanScratch::new();
    let mut plan = MergePlan::empty();
    for (ci, &(n, pf, k)) in shapes().iter().enumerate() {
        let kf = random_tokens(n, 8, 3000 + ci as u64);
        let g = CosineGram::build(&kf);
        let mut arng = Rng::new(31 + ci as u64);
        let attn: Vec<f32> =
            (0..n).map(|_| arng.next_f64() as f32).collect();
        // DiffRate needs a non-empty B set to receive merges
        let k = k.min(n - 1);
        let want = diffrate_plan_gram(&g, &attn, k, pf);
        diffrate_plan_gram_into(&g, &attn, k, pf, &mut scratch, &mut plan);
        assert_plans_identical(&plan, &want, n,
                               &format!("diffrate n={n} pf={pf} k={k}"));
    }
}

#[test]
fn random_into_builder_is_bitwise_identical_to_wrapper() {
    let mut scratch = PlanScratch::new();
    let mut plan = MergePlan::empty();
    for (ci, &(n, pf, k)) in shapes().iter().enumerate() {
        // random pruning requires k candidates to exist
        let k = k.min(n - pf.min(n));
        let seed = 400 + ci as u64;
        let mut r1 = Rng::new(seed);
        let want = random_plan(n, k, pf, &mut r1);
        let mut r2 = Rng::new(seed);
        random_plan_into(n, k, pf, &mut r2, &mut scratch, &mut plan);
        assert_plans_identical(&plan, &want, n,
                               &format!("random n={n} pf={pf} k={k}"));
        assert_eq!(r1.next_below(1 << 20), r2.next_below(1 << 20),
                   "rng state diverged at n={n} pf={pf} k={k}");
    }
}

#[test]
fn energy_into_matches_wrapper_and_feature_path() {
    // dirty, oversized buffer reused across shrinking shapes
    let mut e = vec![42.0f32; 64];
    for (ci, &(n, _, _)) in shapes().iter().enumerate() {
        let kf = random_tokens(n, 8, 5000 + ci as u64);
        let g = CosineGram::build(&kf);
        for margin in [-0.2f32, 0.45, 0.9] {
            let want = energy_from_gram(&g, margin);
            energy_from_gram_into(&g, margin, &mut e);
            assert_eq!(e, want, "n={n} margin={margin}");
            // and the feature-taking convenience agrees to tolerance
            let direct = energy_scores(&kf, margin);
            for (a, b) in e.iter().zip(&direct) {
                assert!((a - b).abs() < 1e-5, "n={n} margin={margin}");
            }
        }
    }
}
