//! Property tests for Gram-sharing parity: the single-pass shared-Gram
//! pipeline must match the pre-refactor two-pass results (energy pass +
//! independent normalize/dot pass inside each plan builder) across random
//! shapes, margins, and modes.

use pitome::config::DEFAULT_TOFU_PRUNE_THRESHOLD;
use pitome::data::Rng;
use pitome::merge::diffrate::diffrate_plan_gram;
use pitome::merge::energy::f_margin;
use pitome::merge::pitome::{ordered_bsm_plan_gram, Split};
use pitome::merge::tome::tome_plan_gram;
use pitome::merge::{apply_plan, energy_scores, merge_step, MergeCtx,
                    MergeMode, MergePlan};
use pitome::tensor::{argsort_asc, argsort_desc, dot, normalize_rows,
                     CosineGram, Mat};
use pitome::util::quickcheck::{property, Gen};

fn rand_mat(g: &mut Gen, n: usize, h: usize) -> Mat {
    Mat::from_fn(n, h, |_, _| g.f32_in(-1.0, 1.0))
}

/// The pre-refactor energy: its own normalize pass + naive sequential
/// per-pair dot products (no Gram, no vectorized reduction).
fn energy_two_pass(kf: &Mat, margin: f32) -> Vec<f32> {
    let n = kf.rows;
    let kn = normalize_rows(kf);
    let mut e = vec![0f32; n];
    for i in 0..n {
        let ri = kn.row(i);
        for j in (i + 1)..n {
            let d: f32 = ri.iter().zip(kn.row(j)).map(|(a, b)| a * b).sum();
            let f = f_margin(d, margin);
            e[i] += f;
            e[j] += f;
        }
    }
    let inv = 1.0 / n as f32;
    for v in e.iter_mut() {
        *v *= inv;
    }
    e
}

/// The pre-refactor PiToMe matching: re-normalizes and recomputes every
/// A×B dot from scratch (the second Gram pass `merge_step` used to pay).
fn pitome_plan_two_pass(kf: &Mat, scores: &[f32], k: usize,
                        protect_first: usize, protect: bool) -> MergePlan {
    let n = kf.rows;
    let k = k.min((n - protect_first) / 2);
    let mut s_cand = scores.to_vec();
    for it in s_cand.iter_mut().take(protect_first) {
        *it = f32::NEG_INFINITY;
    }
    let order = argsort_desc(&s_cand);
    let n_pairs = if protect { k } else { (n - protect_first) / 2 };
    let merge_idx: Vec<usize> = order[..2 * n_pairs].to_vec();
    let rest: Vec<usize> = order[2 * n_pairs..].to_vec();
    let a_all: Vec<usize> = merge_idx.iter().step_by(2).copied().collect();
    let b: Vec<usize> = merge_idx.iter().skip(1).step_by(2).copied().collect();

    let kn = normalize_rows(kf); // the redundant second pass
    let mut best = vec![f32::NEG_INFINITY; a_all.len()];
    let mut dst_all = vec![0usize; a_all.len()];
    for (ai, &aidx) in a_all.iter().enumerate() {
        for (bi, &bidx) in b.iter().enumerate() {
            let d = dot(kn.row(aidx), kn.row(bidx));
            if d > best[ai] {
                best[ai] = d;
                dst_all[ai] = bi;
            }
        }
    }
    let mut protect_idx: Vec<usize>;
    let (a, dst) = if n_pairs == k {
        protect_idx = rest;
        (a_all, dst_all)
    } else {
        let pair_rank = argsort_desc(&best);
        let mut a_merge = Vec::with_capacity(k);
        let mut dst = Vec::with_capacity(k);
        for &p in pair_rank.iter().take(k) {
            a_merge.push(a_all[p]);
            dst.push(dst_all[p]);
        }
        protect_idx = rest;
        for &p in pair_rank.iter().skip(k) {
            protect_idx.push(a_all[p]);
        }
        (a_merge, dst)
    };
    protect_idx.sort_unstable();
    let gate = vec![1.0; a.len()];
    MergePlan { protect: protect_idx, a, b, dst, gate }
}

/// The pre-refactor ToMe/ToFu matching (second normalize + dot pass).
fn tome_plan_two_pass(kf: &Mat, k: usize, protect_first: usize,
                      prune_threshold: Option<f32>) -> MergePlan {
    let n = kf.rows;
    let cand: Vec<usize> = (protect_first..n).collect();
    let a_all: Vec<usize> = cand.iter().step_by(2).copied().collect();
    let b: Vec<usize> = cand.iter().skip(1).step_by(2).copied().collect();
    let kn = normalize_rows(kf);
    let mut best = vec![f32::NEG_INFINITY; a_all.len()];
    let mut dst_all = vec![0usize; a_all.len()];
    for (ai, &aidx) in a_all.iter().enumerate() {
        for (bi, &bidx) in b.iter().enumerate() {
            let d = dot(kn.row(aidx), kn.row(bidx));
            if d > best[ai] {
                best[ai] = d;
                dst_all[ai] = bi;
            }
        }
    }
    let pair_rank = argsort_desc(&best);
    let mut a = Vec::with_capacity(k);
    let mut dst = Vec::with_capacity(k);
    let mut gate = Vec::with_capacity(k);
    for &p in pair_rank.iter().take(k) {
        a.push(a_all[p]);
        dst.push(dst_all[p]);
        gate.push(match prune_threshold {
            Some(t) if best[p] < t => 0.0,
            _ => 1.0,
        });
    }
    let mut protect: Vec<usize> = (0..protect_first).collect();
    for &p in pair_rank.iter().skip(k) {
        protect.push(a_all[p]);
    }
    protect.sort_unstable();
    MergePlan { protect, a, b, dst, gate }
}

/// The pre-refactor DiffRate matching (second normalize + dot pass).
fn diffrate_plan_two_pass(kf: &Mat, attn_cls: &[f32], k: usize,
                          protect_first: usize) -> MergePlan {
    let n = kf.rows;
    let mut score = attn_cls.to_vec();
    for it in score.iter_mut().take(protect_first) {
        *it = f32::INFINITY;
    }
    let order = argsort_asc(&score);
    let a: Vec<usize> = order[..k].to_vec();
    let mut b: Vec<usize> = order[k..].to_vec();
    b.sort_unstable();
    let kn = normalize_rows(kf);
    let mut dst = vec![0usize; k];
    for (ai, &aidx) in a.iter().enumerate() {
        let mut best = f32::NEG_INFINITY;
        for (bi, &bidx) in b.iter().enumerate() {
            if bidx < protect_first {
                continue;
            }
            let d = dot(kn.row(aidx), kn.row(bidx));
            if d > best {
                best = d;
                dst[ai] = bi;
            }
        }
    }
    MergePlan { protect: vec![], a, b, dst, gate: vec![1.0; k] }
}

fn assert_plans_equal(got: &MergePlan, want: &MergePlan, tag: &str) {
    assert_eq!(got.protect, want.protect, "{tag}: protect");
    assert_eq!(got.a, want.a, "{tag}: a");
    assert_eq!(got.b, want.b, "{tag}: b");
    assert_eq!(got.dst, want.dst, "{tag}: dst");
    assert_eq!(got.gate, want.gate, "{tag}: gate");
}

#[test]
fn prop_energy_matches_two_pass() {
    property("energy gram parity", 80, |g| {
        let n = g.usize_in(3, 48);
        let h = g.usize_in(2, 24);
        let kf = rand_mat(g, n, h);
        let margin = g.f32_in(-0.3, 0.9);
        let got = energy_scores(&kf, margin);
        let want = energy_two_pass(&kf, margin);
        for (i, (a, b)) in got.iter().zip(&want).enumerate() {
            assert!((a - b).abs() < 1e-5,
                    "energy[{i}] n={n} h={h} m={margin}: {a} vs {b}");
        }
    });
}

#[test]
fn prop_pitome_plan_matches_two_pass() {
    property("pitome plan gram parity", 60, |g| {
        let n = g.usize_in(6, 48);
        let h = *g.choose(&[4usize, 8, 16]);
        let kf = rand_mat(g, n, h);
        let protect_first = g.usize_in(0, 2);
        let k = g.usize_in(1, ((n - protect_first) / 2).max(1));
        let margin = g.f32_in(-0.2, 0.9);
        let gram = CosineGram::build(&kf);
        let scores = pitome::merge::energy::energy_from_gram(&gram, margin);
        for protect in [true, false] {
            let want =
                pitome_plan_two_pass(&kf, &scores, k, protect_first, protect);
            let mut rng = Rng::new(0);
            let got = ordered_bsm_plan_gram(&gram, &scores, k, protect_first,
                                            Split::Alternate, protect, &mut rng);
            assert_plans_equal(&got, &want,
                               &format!("pitome n={n} k={k} protect={protect}"));
        }
    });
}

#[test]
fn prop_tome_and_diffrate_plans_match_two_pass() {
    property("tome/diffrate gram parity", 60, |g| {
        let n = g.usize_in(6, 48);
        let h = *g.choose(&[4usize, 8, 16]);
        let kf = rand_mat(g, n, h);
        let protect_first = 1usize;
        let k = g.usize_in(1, (n - protect_first) / 2);
        let gram = CosineGram::build(&kf);

        let want = tome_plan_two_pass(&kf, k, protect_first, None);
        let got = tome_plan_gram(&gram, k, protect_first, None);
        assert_plans_equal(&got, &want, &format!("tome n={n} k={k}"));

        let threshold = g.f32_in(-0.5, 0.9);
        let want = tome_plan_two_pass(&kf, k, protect_first, Some(threshold));
        let got = tome_plan_gram(&gram, k, protect_first, Some(threshold));
        assert_plans_equal(&got, &want, &format!("tofu n={n} k={k}"));

        let attn: Vec<f32> = (0..n).map(|_| g.f32_in(0.0, 1.0)).collect();
        let want = diffrate_plan_two_pass(&kf, &attn, k, protect_first);
        let got = diffrate_plan_gram(&gram, &attn, k, protect_first);
        assert_plans_equal(&got, &want, &format!("diffrate n={n} k={k}"));
    });
}

#[test]
fn prop_merge_step_matches_two_pass_pipeline() {
    property("merge_step gram parity", 40, |g| {
        let n = g.usize_in(9, 48);
        let h = *g.choose(&[4usize, 8, 16]);
        let x = rand_mat(g, n, h);
        let kf = rand_mat(g, n, h);
        let sizes: Vec<f32> = (0..n).map(|_| g.f32_in(0.5, 3.0)).collect();
        let attn: Vec<f32> = (0..n).map(|_| g.f32_in(0.0, 1.0)).collect();
        let margin = g.f32_in(-0.2, 0.9);
        let k = g.usize_in(1, (n - 1) / 2 - 1);
        let ctx = MergeCtx {
            x: &x, kf: &kf, sizes: &sizes, attn_cls: &attn,
            margin, k, protect_first: 1,
            tofu_threshold: DEFAULT_TOFU_PRUNE_THRESHOLD,
        };
        for mode in [MergeMode::PiToMe, MergeMode::PiToMeAttn, MergeMode::ToMe,
                     MergeMode::ToFu, MergeMode::DiffRate] {
            // the old pipeline: standalone energy pass, then a plan builder
            // that re-derives pair similarities from scratch.  (Scores come
            // from the public energy_scores so the ranking input is
            // bit-identical on both sides; the numeric equivalence of the
            // energy itself is covered by prop_energy_matches_two_pass,
            // tolerance-based and ordering-free.)
            let want_plan = match mode {
                MergeMode::PiToMe => {
                    let e = energy_scores(&kf, margin);
                    pitome_plan_two_pass(&kf, &e, k, 1, true)
                }
                MergeMode::PiToMeAttn => {
                    let neg: Vec<f32> = attn.iter().map(|v| -v).collect();
                    pitome_plan_two_pass(&kf, &neg, k, 1, true)
                }
                MergeMode::ToMe => tome_plan_two_pass(&kf, k, 1, None),
                MergeMode::ToFu => tome_plan_two_pass(
                    &kf, k, 1, Some(DEFAULT_TOFU_PRUNE_THRESHOLD)),
                MergeMode::DiffRate =>
                    diffrate_plan_two_pass(&kf, &attn, k, 1),
                _ => unreachable!(),
            };
            let (want, want_sizes) = apply_plan(&x, &sizes, &want_plan);
            let mut rng = Rng::new(0);
            let (got, got_sizes) = merge_step(mode, &ctx, &mut rng);
            assert_eq!(got.rows, want.rows, "{mode:?}");
            assert!(got.max_abs_diff(&want) < 1e-5,
                    "{mode:?}: {}", got.max_abs_diff(&want));
            for (a, b) in got_sizes.iter().zip(&want_sizes) {
                assert!((a - b).abs() < 1e-5, "{mode:?} sizes");
            }
        }
    });
}
