//! Property tests for the scratch-workspace encoder: a reused
//! `EncoderScratch` must be indistinguishable from a fresh one — across
//! every merge mode, random shapes, and proportional attention on/off —
//! and the shared-scratch batch driver must match the serial path.
//!
//! (The deprecated free-function wrappers are exercised deliberately:
//! they are the historical contract the engine API is parity-tested
//! against in `prop_engine.rs`.)
#![allow(deprecated)]

use pitome::config::ViTConfig;
use pitome::data::Rng;
use pitome::merge::energy::layer_margin;
use pitome::merge::{merge_step, MergeCtx};
use pitome::model::{encoder_forward, encoder_forward_batch_pooled,
                    encoder_forward_scratch, synthetic_vit_store, EncoderCfg,
                    EncoderScratch, ParamStore, ScratchPool};
use pitome::tensor::{add_inplace, dense, dot, gelu_inplace, layernorm,
                     matmul, softmax_rows, Mat};

/// All modes the encoder can run (paper modes + ablations + baselines).
const MODES: &[&str] = &[
    "none", "pitome", "pitome_noprot", "pitome_rand", "pitome_attn",
    "tome", "tofu", "dct", "diffrate", "random",
];

fn encoder_cfg(vcfg: &ViTConfig, prop_attn: bool) -> EncoderCfg {
    EncoderCfg {
        prefix: "vit.".into(),
        dim: vcfg.dim,
        depth: vcfg.depth,
        heads: vcfg.heads,
        mode: vcfg.mode(),
        plan: vcfg.plan(),
        prop_attn,
        tofu_threshold: vcfg.tofu_threshold,
    }
}

fn random_input(n: usize, dim: usize, seed: u64) -> Mat {
    let mut rng = Rng::new(seed);
    Mat::from_fn(n, dim, |_, _| (rng.next_f64() * 0.2 - 0.1) as f32)
}

/// The seed's scalar attention, reimplemented as an independent reference
/// (fresh score matrix per head, sequential scalar dot products).
fn reference_attention(q: &Mat, kf: &Mat, v: &Mat, sizes: &[f32],
                       heads: usize, prop_attn: bool) -> (Mat, Vec<f32>) {
    let n = q.rows;
    let dim = q.cols;
    let d = dim / heads;
    let scale = 1.0 / (d as f32).sqrt();
    let log_m: Vec<f32> = if prop_attn {
        sizes.iter().map(|&s| s.max(1e-9).ln()).collect()
    } else {
        vec![0.0; n]
    };
    let mut out = Mat::zeros(n, dim);
    let mut attn_cls = vec![0f32; n];
    for hh in 0..heads {
        let col0 = hh * d;
        let mut s = Mat::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                let mut acc = 0f32;
                for c in 0..d {
                    acc += q.get(i, col0 + c) * kf.get(j, col0 + c);
                }
                s.set(i, j, acc * scale + log_m[j]);
            }
        }
        let mut row0: Vec<f32> = (0..n).map(|j| s.get(0, j) - log_m[j]).collect();
        let mx = row0.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0f32;
        for vj in row0.iter_mut() {
            *vj = (*vj - mx).exp();
            sum += *vj;
        }
        for (a, vj) in attn_cls.iter_mut().zip(&row0) {
            *a += vj / sum / heads as f32;
        }
        for i in 0..n {
            let mx = (0..n).map(|j| s.get(i, j)).fold(f32::NEG_INFINITY, f32::max);
            let mut se = 0f32;
            for j in 0..n {
                se += (s.get(i, j) - mx).exp();
            }
            for j in 0..n {
                let p = (s.get(i, j) - mx).exp() / se;
                for c in 0..d {
                    let o = out.get(i, col0 + c) + p * v.get(j, col0 + c);
                    out.set(i, col0 + c, o);
                }
            }
        }
    }
    (out, attn_cls)
}

#[test]
fn vectorized_attention_matches_scalar_reference() {
    let mut rng = Rng::new(31);
    for (n, dim, heads) in [(7usize, 16usize, 2usize), (23, 24, 4), (33, 64, 8)] {
        let mk = |rng: &mut Rng| {
            Mat::from_fn(n, dim, |_, _| (rng.next_f64() * 2.0 - 1.0) as f32)
        };
        let q = mk(&mut rng);
        let kf = mk(&mut rng);
        let v = mk(&mut rng);
        let sizes: Vec<f32> = (0..n).map(|i| 1.0 + (i % 4) as f32).collect();
        for prop in [true, false] {
            let (want, want_cls) =
                reference_attention(&q, &kf, &v, &sizes, heads, prop);
            let (got, got_cls) =
                pitome::model::attention(&q, &kf, &v, &sizes, heads, prop);
            let d = got.max_abs_diff(&want);
            assert!(d < 1e-4, "n={n} heads={heads} prop={prop}: diff {d}");
            for (a, b) in got_cls.iter().zip(&want_cls) {
                assert!((a - b).abs() < 1e-5,
                        "cls attn diverged: {a} vs {b}");
            }
        }
    }
}

/// The pre-tile row-streaming attention kernel, kept verbatim: scoring
/// reads each head's d-length slice out of the full `dim`-length K rows
/// (`&kf.row(j)[col0..col0 + d]`) instead of the packed head-major tile.
/// Everything else — `dot`, the CLS pass, `softmax_rows`, the P·V axpys —
/// is byte-for-byte the production code, so the only difference under
/// test is where the K operand of each dot lives.
fn row_streaming_attention(q: &Mat, kf: &Mat, v: &Mat, sizes: &[f32],
                           heads: usize, prop_attn: bool) -> (Mat, Vec<f32>) {
    let n = q.rows;
    let dim = q.cols;
    let d = dim / heads;
    let scale = 1.0 / (d as f32).sqrt();
    let log_m: Vec<f32> = if prop_attn {
        sizes.iter().map(|&s| s.max(1e-9).ln()).collect()
    } else {
        vec![0.0; n]
    };
    let mut out = Mat::zeros(n, dim);
    let mut attn_cls = vec![0f32; n];
    let mut scores = Mat::zeros(n, n);
    let mut row0 = vec![0f32; n];
    for hh in 0..heads {
        let col0 = hh * d;
        for i in 0..n {
            let qi = &q.row(i)[col0..col0 + d];
            let srow = scores.row_mut(i);
            for (j, sj) in srow.iter_mut().enumerate() {
                let kj = &kf.row(j)[col0..col0 + d];
                *sj = dot(qi, kj) * scale + log_m[j];
            }
        }
        {
            let s0 = scores.row(0);
            for (r0, (sv, lm)) in
                row0.iter_mut().zip(s0.iter().zip(log_m.iter()))
            {
                *r0 = *sv - *lm;
            }
            let mx = row0.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let mut sum = 0f32;
            for vj in row0.iter_mut() {
                *vj = (*vj - mx).exp();
                sum += *vj;
            }
            for (a, vj) in attn_cls.iter_mut().zip(row0.iter()) {
                *a += vj / sum / heads as f32;
            }
        }
        softmax_rows(&mut scores);
        for i in 0..n {
            let orow = &mut out.row_mut(i)[col0..col0 + d];
            let prow = scores.row(i);
            for (j, &p) in prow.iter().enumerate() {
                if p == 0.0 {
                    continue;
                }
                let vj = &v.row(j)[col0..col0 + d];
                for (o, &vv) in orow.iter_mut().zip(vj) {
                    *o += p * vv;
                }
            }
        }
    }
    (out, attn_cls)
}

#[test]
fn ktiled_attention_matches_row_streaming_bitwise() {
    // Packing K into the head-major tile must only relocate operands,
    // never reorder a summation: every output and every CLS weight must
    // be bit-for-bit what the row-streaming kernel produced.  (The
    // attention kernel is mode-independent — `run_layers` feeds it
    // identically in all ten merge modes — so kernel-level bitwise
    // equality carries to the full encoder forward in every mode; the
    // mode-sweep forwards above pin that composition.)
    let mut rng = Rng::new(77);
    for (n, dim, heads) in [(5usize, 8usize, 1usize), (7, 16, 2),
                            (23, 24, 4), (12, 60, 5), (33, 64, 8)] {
        let mk = |rng: &mut Rng| {
            Mat::from_fn(n, dim, |_, _| (rng.next_f64() * 2.0 - 1.0) as f32)
        };
        let q = mk(&mut rng);
        let kf = mk(&mut rng);
        let v = mk(&mut rng);
        let sizes: Vec<f32> = (0..n).map(|i| 1.0 + (i % 5) as f32).collect();
        for prop in [true, false] {
            let (want, want_cls) =
                row_streaming_attention(&q, &kf, &v, &sizes, heads, prop);
            let (got, got_cls) =
                pitome::model::attention(&q, &kf, &v, &sizes, heads, prop);
            assert!(got.max_abs_diff(&want) == 0.0,
                    "n={n} heads={heads} prop={prop}: K-tiled output \
                     is not bitwise identical");
            assert_eq!(got_cls, want_cls,
                       "n={n} heads={heads} prop={prop}: CLS attention \
                        is not bitwise identical");
        }
    }
}

/// The seed's whole encoder forward, reimplemented independently of the
/// scratch machinery: per-layer allocating LN / QKV / scalar attention /
/// merge_step / MLP, exactly as the pre-refactor `encoder_forward` was
/// composed.  Catches composition-level bugs the wrapper-vs-scratch tests
/// cannot (both of those share `run_layers`).
fn reference_forward(ps: &ParamStore, cfg: &EncoderCfg, mut x: Mat,
                     rng: &mut Rng) -> Mat {
    let mut sizes = vec![1f32; x.rows];
    for l in 0..cfg.depth {
        let b = format!("{}blk{}.", cfg.prefix, l);
        let h = layernorm(&x, ps.vec1(&format!("{b}ln1.w")).unwrap(),
                          ps.vec1(&format!("{b}ln1.b")).unwrap(), 1e-5);
        let q = matmul(&h, &ps.mat2(&format!("{b}wq")).unwrap());
        let kf = matmul(&h, &ps.mat2(&format!("{b}wk")).unwrap());
        let v = matmul(&h, &ps.mat2(&format!("{b}wv")).unwrap());
        let attn_sizes: Vec<f32> = if cfg.prop_attn {
            sizes.clone()
        } else {
            vec![1.0; x.rows]
        };
        let (o, attn_cls) = reference_attention(&q, &kf, &v, &attn_sizes,
                                                cfg.heads, cfg.prop_attn);
        let proj = dense(&o, &ps.mat2(&format!("{b}wo")).unwrap(),
                         Some(ps.vec1(&format!("{b}bo")).unwrap()));
        add_inplace(&mut x, &proj);

        let k = cfg.plan[l] - cfg.plan[l + 1];
        if k > 0 {
            let margin = layer_margin(l, cfg.depth);
            let ctx = MergeCtx {
                x: &x, kf: &kf, sizes: &sizes, attn_cls: &attn_cls,
                margin, k, protect_first: 1,
                tofu_threshold: cfg.tofu_threshold,
            };
            let (xm, sm) = merge_step(cfg.mode, &ctx, rng);
            x = xm;
            sizes = sm;
        }

        let h2 = layernorm(&x, ps.vec1(&format!("{b}ln2.w")).unwrap(),
                           ps.vec1(&format!("{b}ln2.b")).unwrap(), 1e-5);
        let mut m = dense(&h2, &ps.mat2(&format!("{b}mlp1")).unwrap(),
                          Some(ps.vec1(&format!("{b}mlp1b")).unwrap()));
        gelu_inplace(&mut m);
        let m2 = dense(&m, &ps.mat2(&format!("{b}mlp2")).unwrap(),
                       Some(ps.vec1(&format!("{b}mlp2b")).unwrap()));
        add_inplace(&mut x, &m2);
    }
    layernorm(&x, ps.vec1(&format!("{}lnf.w", cfg.prefix)).unwrap(),
              ps.vec1(&format!("{}lnf.b", cfg.prefix)).unwrap(), 1e-5)
}

#[test]
fn scratch_forward_matches_seed_composition_reference() {
    // mode "none" exercises the full block composition (LN / QKV / attn /
    // proj / MLP / final norm) against the independent seed-style
    // reference.  Merge modes are deliberately excluded here: the two
    // implementations' attention kernels round differently, and a
    // near-tied energy/similarity ranking at a deep layer could then pick
    // a different (equally valid) plan — that comparison would test tie
    // order, not correctness.  Merge composition is instead covered
    // bitwise at the merge_step level (`scratch_step_matches_allocating_
    // step_for_all_modes`) and against the JAX testvectors in parity.rs.
    let vcfg = ViTConfig::default();
    let ps = synthetic_vit_store(&vcfg, 17);
    for prop_attn in [true, false] {
        let cfg = encoder_cfg(&vcfg, prop_attn);
        let x = random_input(cfg.plan[0], cfg.dim, 7);
        let mut r1 = Rng::new(1);
        let want = reference_forward(&ps, &cfg, x.clone(), &mut r1);
        let mut r2 = Rng::new(1);
        let mut scratch = EncoderScratch::new();
        let got = encoder_forward_scratch(&ps, &cfg, x, &mut r2,
                                          &mut scratch).unwrap();
        assert_eq!(got.rows, want.rows, "prop={prop_attn}");
        let d = got.max_abs_diff(&want);
        // only the attention kernel's summation order differs
        assert!(d < 1e-3, "prop={prop_attn}: diff {d}");
    }
}

#[test]
fn scratch_forward_matches_wrapper_across_modes_and_shapes() {
    // shape sweep: (image, patch, dim, heads, depth) — dims divisible by
    // heads; token counts 17 / 26 / 65
    let shapes = [(16usize, 4usize, 32usize, 2usize, 2usize),
                  (20, 4, 48, 4, 3),
                  (32, 4, 64, 4, 4)];
    // ONE scratch reused across every mode, shape, and trial: any state
    // leak between configurations would show up as a mismatch
    let mut scratch = EncoderScratch::new();
    for (si, &(img, patch, dim, heads, depth)) in shapes.iter().enumerate() {
        for (mi, &mode) in MODES.iter().enumerate() {
            let vcfg = ViTConfig {
                image_size: img,
                patch_size: patch,
                dim,
                heads,
                depth,
                merge_mode: mode.into(),
                merge_r: 0.85,
                ..Default::default()
            };
            let ps = synthetic_vit_store(&vcfg, 100 + si as u64);
            for prop_attn in [true, false] {
                let cfg = encoder_cfg(&vcfg, prop_attn);
                let x = random_input(cfg.plan[0], dim,
                                     (si * 100 + mi) as u64);
                let seed = (si + mi) as u64;
                let mut r1 = Rng::new(seed);
                let want =
                    encoder_forward(&ps, &cfg, x.clone(), &mut r1).unwrap();
                let mut r2 = Rng::new(seed);
                let got = encoder_forward_scratch(&ps, &cfg, x, &mut r2,
                                                  &mut scratch).unwrap();
                assert_eq!(got.rows, want.rows,
                           "{mode} shape {si} prop={prop_attn}");
                let d = got.max_abs_diff(&want);
                assert!(d < 1e-6,
                        "{mode} shape {si} prop={prop_attn}: diff {d}");
            }
        }
    }
}

#[test]
fn interleaved_shape_stress_matches_fresh_scratch() {
    // ONE `EncoderScratch` (with its embedded `MergeScratch`) driven
    // through interleaved shapes — token counts, dims, head counts, and
    // depths growing AND shrinking between rounds, with the merge mode
    // changing every round — must match a fresh scratch exactly.  Any
    // stale-buffer reuse (an index vector, plan group, or Gram row
    // surviving a shape change) shows up as a bitwise mismatch.
    let mut reused = EncoderScratch::new();
    // (image, patch, dim, heads, depth): n cycles 65 -> 17 -> 37 -> 17 ->
    // 65, dim cycles 64 -> 32 -> 48 -> 64 -> 32
    let shape_cycle = [(32usize, 4usize, 64usize, 4usize, 4usize),
                       (16, 4, 32, 2, 2),
                       (24, 4, 48, 4, 3),
                       (16, 4, 64, 2, 2),
                       (32, 4, 32, 2, 3)];
    for (round, &mode) in MODES.iter().enumerate() {
        let (img, patch, dim, heads, depth) = shape_cycle[round % shape_cycle.len()];
        let vcfg = ViTConfig {
            image_size: img,
            patch_size: patch,
            dim,
            heads,
            depth,
            merge_mode: mode.into(),
            merge_r: 0.85,
            ..Default::default()
        };
        let ps = synthetic_vit_store(&vcfg, 200 + round as u64);
        let cfg = encoder_cfg(&vcfg, round % 2 == 0);
        let x = random_input(cfg.plan[0], dim, 300 + round as u64);
        let mut r1 = Rng::new(round as u64);
        let mut fresh = EncoderScratch::new();
        let want = encoder_forward_scratch(&ps, &cfg, x.clone(), &mut r1,
                                           &mut fresh).unwrap();
        let mut r2 = Rng::new(round as u64);
        let got = encoder_forward_scratch(&ps, &cfg, x, &mut r2,
                                          &mut reused).unwrap();
        assert_eq!(got.rows, want.rows, "{mode} round {round}");
        assert!(got.max_abs_diff(&want) == 0.0,
                "{mode} round {round}: reused scratch diverged");
    }
}

#[test]
fn pooled_batch_matches_serial_across_modes() {
    let mut pool = ScratchPool::new();
    for &mode in MODES {
        // stochastic modes draw from per-(layer, sample) streams in the
        // batch driver by design — the deterministic paper modes must
        // match the serial path exactly
        if mode == "random" || mode == "pitome_rand" {
            continue;
        }
        let vcfg = ViTConfig {
            merge_mode: mode.into(),
            merge_r: 0.85,
            ..Default::default()
        };
        let ps = synthetic_vit_store(&vcfg, 11);
        let cfg = encoder_cfg(&vcfg, true);
        let xs: Vec<Mat> = (0..4)
            .map(|i| random_input(cfg.plan[0], cfg.dim, 50 + i))
            .collect();
        let batched = encoder_forward_batch_pooled(&ps, &cfg, xs.clone(), 0,
                                                   3, &mut pool).unwrap();
        for (i, x) in xs.into_iter().enumerate() {
            let mut r = Rng::new(0);
            let want = encoder_forward(&ps, &cfg, x, &mut r).unwrap();
            let d = batched[i].max_abs_diff(&want);
            assert!(d < 1e-6, "{mode} sample {i}: diff {d}");
        }
    }
}

#[test]
fn stochastic_batch_is_schedule_independent() {
    let mut pool = ScratchPool::new();
    for &mode in &["random", "pitome_rand"] {
        let vcfg = ViTConfig {
            merge_mode: mode.into(),
            merge_r: 0.85,
            ..Default::default()
        };
        let ps = synthetic_vit_store(&vcfg, 13);
        let cfg = encoder_cfg(&vcfg, true);
        let xs: Vec<Mat> = (0..5)
            .map(|i| random_input(cfg.plan[0], cfg.dim, 80 + i))
            .collect();
        let a = encoder_forward_batch_pooled(&ps, &cfg, xs.clone(), 21, 1,
                                             &mut pool).unwrap();
        let b = encoder_forward_batch_pooled(&ps, &cfg, xs, 21, 5,
                                             &mut pool).unwrap();
        for (i, (x, y)) in a.iter().zip(&b).enumerate() {
            assert!(x.max_abs_diff(y) == 0.0,
                    "{mode} sample {i} depends on worker count");
        }
    }
}
