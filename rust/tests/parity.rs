//! Cross-language parity: the Rust engine vs the JAX reference, through
//! `artifacts/testvectors.json` (written by `make artifacts`).
//!
//! These tests are the trust anchor that lets the Rust CPU model run the
//! paper's r-sweeps in place of per-point HLO artifacts.  They skip (with
//! a loud message) when artifacts have not been built.

use std::path::PathBuf;

use pitome::config::ViTConfig;
use pitome::data::{patchify, Rng};
use pitome::merge::{energy_scores, merge_step, MergeCtx, MergeMode};
use pitome::model::{load_model_params, ViTModel};
use pitome::runtime::Registry;
use pitome::tensor::Mat;
use pitome::util::json::{parse as parse_json, Json};

fn testvectors() -> Option<Json> {
    let path = Registry::default_dir().join("testvectors.json");
    let text = std::fs::read_to_string(&path).ok().or_else(|| {
        eprintln!("SKIP parity: {} missing (run `make artifacts`)",
                  path.display());
        None
    })?;
    Some(parse_json(&text).expect("testvectors.json parses"))
}

fn mat_from(v: &Json) -> Mat {
    let (r, c, d) = v.f32_mat().expect("matrix");
    Mat::from_vec(r, c, d)
}

#[test]
fn prng_parity_with_python() {
    let Some(tv) = testvectors() else { return };
    let prng = tv.get("prng").unwrap();
    let expect: Vec<u64> = prng.get("u64").unwrap().arr().unwrap().iter()
        .map(|v| v.str().unwrap().parse().unwrap()).collect();
    let mut rng = Rng::new(42);
    let got: Vec<u64> = (0..4).map(|_| rng.next_u64()).collect();
    assert_eq!(got, expect, "SplitMix64 stream diverged from python");

    let f = prng.get("f64").unwrap().arr().unwrap();
    assert!((Rng::new(7).next_f64() - f[0].num().unwrap()).abs() < 1e-15);
    assert!((Rng::new(8).next_f64() - f[1].num().unwrap()).abs() < 1e-15);
}

#[test]
fn shape_item_parity_with_python() {
    let Some(tv) = testvectors() else { return };
    let prng = tv.get("prng").unwrap();
    let want_sum = prng.get("img_sum").unwrap().num().unwrap();
    let want_label = prng.get("img_label").unwrap().usize().unwrap();
    let item = pitome::data::shape_item(123, 0);
    let got_sum: f64 = item.image.iter().map(|&v| v as f64).sum();
    assert_eq!(item.label, want_label);
    assert!((got_sum - want_sum).abs() < 1e-3,
            "image diverged: {got_sum} vs {want_sum}");
}

#[test]
fn sent_item_parity_with_python() {
    let Some(tv) = testvectors() else { return };
    let prng = tv.get("prng").unwrap();
    let want: Vec<i64> = prng.get("sent_tokens").unwrap().arr().unwrap()
        .iter().map(|v| v.num().unwrap() as i64).collect();
    let want_label = prng.get("sent_label").unwrap().usize().unwrap();
    let (toks, label) = pitome::data::sent_item(9, 3, 32, 16);
    let got: Vec<i64> = toks.iter().map(|&t| t as i64).collect();
    assert_eq!(got, want, "sent tokens diverged");
    assert_eq!(label, want_label);
}

#[test]
fn energy_parity_with_jax() {
    let Some(tv) = testvectors() else { return };
    let e = tv.get("energy").unwrap();
    let kf = mat_from(e.get("kf").unwrap());
    let margin = e.get("margin").unwrap().num().unwrap() as f32;
    let expect = e.get("expected").unwrap().f32_vec().unwrap();
    let got = energy_scores(&kf, margin);
    for (i, (g, w)) in got.iter().zip(&expect).enumerate() {
        assert!((g - w).abs() < 5e-5, "energy[{i}]: rust {g} vs jax {w}");
    }
}

#[test]
fn merge_parity_with_jax() {
    let Some(tv) = testvectors() else { return };
    let m = tv.get("merge").unwrap();
    let x = mat_from(m.get("x").unwrap());
    let kf = mat_from(m.get("kf").unwrap());
    let sizes = m.get("sizes").unwrap().f32_vec().unwrap();
    let attn = m.get("attn_cls").unwrap().f32_vec().unwrap();
    let margin = m.get("margin").unwrap().num().unwrap() as f32;
    let k = m.get("k").unwrap().usize().unwrap();
    let cases = m.get("cases").unwrap();
    for (name, mode) in [("pitome", MergeMode::PiToMe),
                         ("tome", MergeMode::ToMe),
                         ("tofu", MergeMode::ToFu),
                         ("dct", MergeMode::Dct),
                         ("diffrate", MergeMode::DiffRate)] {
        let case = cases.get(name).unwrap();
        let want = mat_from(case.get("out").unwrap());
        let want_sizes = case.get("sizes").unwrap().f32_vec().unwrap();
        let mut rng = Rng::new(0);
        let ctx = MergeCtx { x: &x, kf: &kf, sizes: &sizes, attn_cls: &attn,
                             margin, k, protect_first: 1,
                             tofu_threshold:
                                 pitome::config::DEFAULT_TOFU_PRUNE_THRESHOLD };
        let (got, got_sizes) = merge_step(mode, &ctx, &mut rng);
        assert_eq!(got.rows, want.rows, "{name} rows");
        let d = got.max_abs_diff(&want);
        assert!(d < 2e-4, "{name}: max diff {d}");
        for (a, b) in got_sizes.iter().zip(&want_sizes) {
            assert!((a - b).abs() < 1e-4, "{name} sizes: {a} vs {b}");
        }
    }
}

#[test]
fn vit_logits_parity_with_jax() {
    let Some(tv) = testvectors() else { return };
    let dir: PathBuf = Registry::default_dir();
    let Ok(ps) = load_model_params(&dir, "vit") else {
        eprintln!("SKIP vit parity: params missing");
        return;
    };
    let v = tv.get("vit_logits").unwrap();
    let cases = v.get("cases").unwrap();
    // recreate the first 2 test samples exactly as python did
    let xs: Vec<Mat> = (0..2)
        .map(|i| {
            let item = pitome::data::shape_item(pitome::data::TEST_SEED, i);
            patchify(&item.image, 4)
        })
        .collect();
    for (tag, mode, r) in [("none_r1000", "none", 1.0),
                           ("pitome_r900", "pitome", 0.9),
                           ("tome_r900", "tome", 0.9)] {
        let want = mat_from(cases.get(tag).unwrap());
        let cfg = ViTConfig { merge_mode: mode.into(), merge_r: r,
                              ..Default::default() };
        let model = ViTModel::new(&ps, cfg);
        let mut rng = Rng::new(0);
        for (i, x) in xs.iter().enumerate() {
            let got = model.logits(x, &mut rng).unwrap();
            for (j, (g, w)) in got.iter().zip(want.row(i)).enumerate() {
                assert!((g - w).abs() < 2e-2,
                        "{tag} sample {i} logit {j}: rust {g} vs jax {w}");
            }
            // prediction must agree exactly
            let pred_r = pitome::tensor::argmax(&got);
            let pred_j = pitome::tensor::argmax(want.row(i));
            assert_eq!(pred_r, pred_j, "{tag} sample {i} prediction");
        }
    }
}
