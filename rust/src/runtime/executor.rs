//! PJRT executor: compiles HLO-text artifacts on the CPU client and runs
//! them with typed host buffers (pattern from /opt/xla-example/load_hlo).

use std::collections::HashMap;
use std::path::Path;
use std::sync::Mutex;

use crate::error::{Error, Result};

use super::artifact::{ArtifactEntry, Registry};

/// Host-side tensor value crossing the PJRT boundary.
#[derive(Clone, Debug)]
pub enum HostTensor {
    /// f32 data + shape
    F32(Vec<f32>, Vec<usize>),
    /// i32 data + shape
    I32(Vec<i32>, Vec<usize>),
}

impl HostTensor {
    /// Shape accessor.
    pub fn shape(&self) -> &[usize] {
        match self {
            HostTensor::F32(_, s) | HostTensor::I32(_, s) => s,
        }
    }

    /// f32 payload or error.
    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            HostTensor::F32(d, _) => Ok(d),
            HostTensor::I32(..) => Err(Error::Shape("expected f32 tensor".into())),
        }
    }

    /// i32 payload or error (token-id inputs on the text/joint serving
    /// paths).
    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            HostTensor::I32(d, _) => Ok(d),
            HostTensor::F32(..) => Err(Error::Shape("expected i32 tensor".into())),
        }
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape().iter().map(|&d| d as i64).collect();
        let lit = match self {
            HostTensor::F32(d, _) => xla::Literal::vec1(d),
            HostTensor::I32(d, _) => xla::Literal::vec1(d),
        };
        if dims.len() == 1 {
            Ok(lit)
        } else {
            Ok(lit.reshape(&dims)?)
        }
    }
}

/// A compiled model ready to execute.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    /// the manifest entry this was compiled from
    pub entry: ArtifactEntry,
}

impl Executable {
    /// Run with host inputs, returning host outputs (tuple flattened).
    pub fn run(&self, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        if inputs.len() != self.entry.inputs.len() {
            return Err(Error::Artifact(format!(
                "artifact {} expects {} inputs, got {}",
                self.entry.file, self.entry.inputs.len(), inputs.len())));
        }
        for (i, (h, spec)) in inputs.iter().zip(&self.entry.inputs).enumerate() {
            let numel: usize = h.shape().iter().product();
            if numel != spec.numel() {
                return Err(Error::Shape(format!(
                    "input {i}: got {:?}, artifact wants {:?}",
                    h.shape(), spec.shape)));
            }
        }
        let lits: Vec<xla::Literal> = inputs
            .iter()
            .map(|h| h.to_literal())
            .collect::<Result<_>>()?;
        let result = self.exe.execute::<xla::Literal>(&lits)?[0][0]
            .to_literal_sync()?;
        let parts = result.to_tuple()?;
        let mut out = Vec::with_capacity(parts.len());
        for (lit, spec) in parts.into_iter().zip(&self.entry.outputs) {
            let t = match spec.dtype.as_str() {
                "int32" => HostTensor::I32(lit.to_vec::<i32>()?, spec.shape.clone()),
                _ => HostTensor::F32(lit.to_vec::<f32>()?, spec.shape.clone()),
            };
            out.push(t);
        }
        Ok(out)
    }
}

/// PJRT CPU engine with a compile cache.
pub struct Engine {
    client: xla::PjRtClient,
    cache: Mutex<HashMap<String, std::sync::Arc<Executable>>>,
}

impl Engine {
    /// Create the CPU PJRT client.
    pub fn cpu() -> Result<Engine> {
        Ok(Engine {
            client: xla::PjRtClient::cpu()?,
            cache: Mutex::new(HashMap::new()),
        })
    }

    /// Compile an HLO-text file directly (no registry entry).
    pub fn compile_file(&self, path: &Path, entry: ArtifactEntry) -> Result<Executable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| Error::Artifact("bad path".into()))?)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        Ok(Executable { exe, entry })
    }

    /// Compile (or fetch from cache) a registry artifact.
    pub fn load(&self, reg: &Registry, name: &str) -> Result<std::sync::Arc<Executable>> {
        if let Some(e) = self.cache.lock().unwrap().get(name) {
            return Ok(e.clone());
        }
        let entry = reg.get(name)?.clone();
        let path = reg.hlo_path(name)?;
        let exe = std::sync::Arc::new(self.compile_file(&path, entry)?);
        self.cache.lock().unwrap().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Number of PJRT devices (CPU: 1).
    pub fn device_count(&self) -> usize {
        self.client.device_count()
    }
}
