//! Artifact registry: parses `artifacts/manifest.json` (written by
//! `python/compile/aot.py`) and describes every AOT-compiled model variant.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::error::{Error, Result};
use crate::util::json::{parse as parse_json, Json};

/// Tensor I/O description of an artifact.
#[derive(Clone, Debug)]
pub struct IoSpec {
    /// dimensions
    pub shape: Vec<usize>,
    /// dtype string ("float32" / "int32")
    pub dtype: String,
}

impl IoSpec {
    /// Element count.
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(v: &Json) -> Result<IoSpec> {
        Ok(IoSpec {
            shape: v.get("shape").and_then(Json::usize_vec)
                .ok_or_else(|| Error::Json("io spec missing shape".into()))?,
            dtype: v.get("dtype").and_then(Json::str)
                .unwrap_or("float32").to_string(),
        })
    }
}

/// Metadata attached by aot.py.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    /// model family ("vit", "clip_img", ...)
    pub model: String,
    /// merge mode name
    pub mode: String,
    /// keep ratio
    pub r: f64,
    /// compiled batch size
    pub batch: usize,
    /// params file under artifacts/params/ (forward artifacts only)
    pub params: Option<String>,
    /// static token plan (when applicable)
    pub plan: Option<Vec<usize>>,
    /// flat parameter vector length (train artifacts)
    pub param_size: Option<usize>,
}

/// One artifact entry.
#[derive(Clone, Debug)]
pub struct ArtifactEntry {
    /// HLO text file relative to the artifacts dir
    pub file: String,
    /// input tensor specs (in call order)
    pub inputs: Vec<IoSpec>,
    /// output tensor specs (tuple elements in order)
    pub outputs: Vec<IoSpec>,
    /// metadata
    pub meta: ArtifactMeta,
}

impl ArtifactEntry {
    fn from_json(v: &Json) -> Result<ArtifactEntry> {
        let io = |key: &str| -> Result<Vec<IoSpec>> {
            v.get(key)
                .and_then(Json::arr)
                .ok_or_else(|| Error::Json(format!("missing {key}")))?
                .iter()
                .map(IoSpec::from_json)
                .collect()
        };
        let meta = v.get("meta").ok_or_else(|| Error::Json("missing meta".into()))?;
        Ok(ArtifactEntry {
            file: v.get("file").and_then(Json::str)
                .ok_or_else(|| Error::Json("missing file".into()))?.into(),
            inputs: io("inputs")?,
            outputs: io("outputs")?,
            meta: ArtifactMeta {
                model: meta.get("model").and_then(Json::str).unwrap_or("?").into(),
                mode: meta.get("mode").and_then(Json::str).unwrap_or("none").into(),
                r: meta.get("r").and_then(Json::num).unwrap_or(1.0),
                batch: meta.get("batch").and_then(Json::usize).unwrap_or(1),
                params: meta.get("params").and_then(Json::str).map(String::from),
                plan: meta.get("plan").and_then(Json::usize_vec),
                param_size: meta.get("param_size").and_then(Json::usize),
            },
        })
    }
}

/// The parsed registry.
#[derive(Debug)]
pub struct Registry {
    /// artifacts directory
    pub dir: PathBuf,
    entries: HashMap<String, ArtifactEntry>,
}

impl Registry {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Registry> {
        let manifest = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest).map_err(|e| {
            Error::Artifact(format!(
                "cannot read {} (run `make artifacts` first): {e}",
                manifest.display()))
        })?;
        let root = parse_json(&text)?;
        let obj = root.obj().ok_or_else(|| Error::Json("manifest not an object".into()))?;
        let mut entries = HashMap::new();
        for (name, v) in obj {
            entries.insert(name.clone(), ArtifactEntry::from_json(v)?);
        }
        Ok(Registry { dir: dir.to_path_buf(), entries })
    }

    /// Look up an artifact by name.
    pub fn get(&self, name: &str) -> Result<&ArtifactEntry> {
        self.entries.get(name).ok_or_else(|| {
            let mut known: Vec<&str> = self.entries.keys().map(|s| s.as_str()).collect();
            known.sort_unstable();
            Error::Artifact(format!("unknown artifact {name:?}; known: {known:?}"))
        })
    }

    /// All artifact names (sorted).
    pub fn names(&self) -> Vec<String> {
        let mut v: Vec<String> = self.entries.keys().cloned().collect();
        v.sort();
        v
    }

    /// Absolute path of an artifact's HLO file.
    pub fn hlo_path(&self, name: &str) -> Result<PathBuf> {
        Ok(self.dir.join(&self.get(name)?.file))
    }

    /// Default artifacts dir: `$PITOME_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var("PITOME_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn missing_dir_gives_helpful_error() {
        let err = Registry::load(Path::new("/definitely/not/here")).unwrap_err();
        let s = err.to_string();
        assert!(s.contains("make artifacts"), "{s}");
    }

    #[test]
    fn parses_minimal_manifest() {
        let dir = std::env::temp_dir().join("pitome_reg_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), r#"{
            "m1": {"file": "m1.hlo.txt",
                    "inputs": [{"shape": [4], "dtype": "float32"}],
                    "outputs": [{"shape": [2], "dtype": "float32"}],
                    "meta": {"model": "vit", "mode": "pitome", "r": 0.9,
                             "batch": 1, "plan": [65, 59]}}}"#).unwrap();
        let reg = Registry::load(&dir).unwrap();
        let e = reg.get("m1").unwrap();
        assert_eq!(e.inputs[0].numel(), 4);
        assert_eq!(e.meta.mode, "pitome");
        assert_eq!(e.meta.plan.as_deref(), Some(&[65usize, 59][..]));
        assert!(reg.get("m2").is_err());
        assert_eq!(reg.names(), vec!["m1".to_string()]);
    }
}
