//! PJRT runtime: artifact registry + compile cache + typed execution.
//!
//! Loads the HLO-text artifacts produced by `make artifacts`, compiles them
//! once on the PJRT CPU client, and executes them from the coordinator's
//! hot path.  Python never runs here.

pub mod artifact;
pub mod executor;

pub use artifact::{ArtifactEntry, ArtifactMeta, IoSpec, Registry};
pub use executor::{Engine, Executable, HostTensor};

use std::path::Path;

use crate::error::Result;

/// Read a flat f32 params file from `artifacts/params/`.
pub fn load_flat_params(artifacts: &Path, file: &str) -> Result<Vec<f32>> {
    let raw = std::fs::read(artifacts.join("params").join(file))?;
    Ok(raw
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}
