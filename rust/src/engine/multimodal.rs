//! Joint vision+text serving sessions: one pooled vision tower and one
//! pooled text tower over a shared [`Engine`], fused through pooled
//! buffers for the paper's two multimodal workloads — retrieval scoring
//! (normalized feature similarity, Figure 3 / Tables 2-3) and VQA answer
//! heads (Tables 4-5 / Figure 5).
//!
//! A [`JointSession`] follows the same ownership rules as every other
//! session: one per worker thread, alive for the worker's lifetime.  Both
//! towers resolve their weights through the engine's shared resolution
//! cache, and every stage — patch/token embedding, both encoder loops,
//! the concat + `vqa.fc1`/relu/answer head, the `proj.img`/`proj.txt`
//! projections and their L2 normalization — writes into pooled buffers,
//! so a whole warmed (patches, question)→answer-logits request performs
//! **zero** heap allocations (`tests/alloc_free.rs`).
//!
//! # Ragged halves
//!
//! [`JointSession::begin`] sizes the vision and text halves
//! *independently* (`bv` images, `bt` token sequences): a retrieval round
//! can embed 30 images against 100 captions, and the coordinator's joint
//! worker splits a mixed batch the same way.  Fusion is explicit:
//! [`JointSession::fuse_vqa`] takes `(vision, text)` index pairs;
//! [`JointSession::project`] embeds every sample of both halves for
//! similarity scoring.

use std::sync::Arc;

use crate::config::ViTConfig;
use crate::config::DEFAULT_TOFU_PRUNE_THRESHOLD;
use crate::data::{Rng, CAP_LEN, VOCAB};
use crate::error::{Error, Result};
use crate::merge::MergeMode;
use crate::model::encoder::{encoder_forward_towers, TowerBatch};
use crate::model::params::{MatSpan, VecSpan};
use crate::model::text::l2_normalize;
use crate::model::{EncoderCfg, ParamStore, MM_TEXT_DEPTH, MM_TEXT_DIM};
use crate::obs::{MergeTelemetry, RingWriter};
use crate::tensor::{dense_into, Mat};

use super::{Engine, OutputPool, Session, VitSession};

/// Decorrelate the text tower's per-(layer, sample) RNG streams from the
/// vision tower's when both run under one batch seed.
const TEXT_SEED_SALT: u64 = 0x9E37_79B9_7F4A_7C15;

/// Which fusion stage a [`JointSession`] resolves and runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JointKind {
    /// CLIP-style retrieval scoring: both towers project into a shared
    /// embedding space (`proj.img` / `proj.txt`), scores are dot products
    /// of L2-normalized features.
    Retrieval,
    /// LLaVA-style VQA: the concatenated (vision CLS, question CLS)
    /// feature runs through `vqa.fc1` + relu + the answer head.
    Vqa,
}

/// Hyperparameters of a text tower paired into a [`JointSession`]
/// (mirrors `python/compile/{clip,vqa}.py`: the caption tower lives
/// under `"txt."`, the question tower under `"q."`).
#[derive(Clone, Debug)]
pub struct TextTowerCfg {
    /// parameter-name prefix, e.g. `"q."` or `"txt."`
    pub prefix: String,
    /// vocabulary size (token-id validation bound)
    pub vocab_size: usize,
    /// total tokens per sequence, CLS included
    pub tokens: usize,
    /// embedding dim
    pub dim: usize,
    /// depth
    pub depth: usize,
    /// attention heads
    pub heads: usize,
}

impl TextTowerCfg {
    /// The encoder config this tower implies (mode `none`, flat plan —
    /// exactly what the historical `text_features` calls used, so the
    /// session path stays bitwise-compatible with them).
    // lint: allow(alloc) reason=one-time EncoderCfg assembly at session construction
    pub fn encoder_cfg(&self) -> EncoderCfg {
        EncoderCfg {
            prefix: self.prefix.clone(),
            dim: self.dim,
            depth: self.depth,
            heads: self.heads,
            mode: MergeMode::None,
            plan: vec![self.tokens; self.depth + 1],
            prop_attn: true,
            tofu_threshold: DEFAULT_TOFU_PRUNE_THRESHOLD,
        }
    }
}

/// Configuration of a joint vision+text session: the vision tower's model
/// config (merge mode/ratio sweep along it), the paired text tower, and
/// the fusion stage to resolve.
#[derive(Clone, Debug)]
pub struct JointConfig {
    /// vision tower config (token merging happens here)
    pub vision: ViTConfig,
    /// paired text tower
    pub text: TextTowerCfg,
    /// fusion stage
    pub kind: JointKind,
}

impl JointConfig {
    /// The VQA pairing (question tower `"q."`, answer head `vqa.*`) for a
    /// vision config — hyperparameters mirror `python/compile/vqa.py`.
    pub fn vqa(vision: ViTConfig) -> JointConfig {
        JointConfig {
            vision,
            text: TextTowerCfg {
                prefix: "q.".into(),
                vocab_size: VOCAB,
                tokens: CAP_LEN + 1,
                dim: MM_TEXT_DIM,
                depth: MM_TEXT_DEPTH,
                heads: 4,
            },
            kind: JointKind::Vqa,
        }
    }

    /// The retrieval pairing (caption tower `"txt."`, projections
    /// `proj.img`/`proj.txt`) for a vision config — hyperparameters
    /// mirror `python/compile/clip.py`.
    pub fn retrieval(vision: ViTConfig) -> JointConfig {
        JointConfig {
            vision,
            text: TextTowerCfg {
                prefix: "txt.".into(),
                vocab_size: VOCAB,
                tokens: CAP_LEN + 1,
                dim: MM_TEXT_DIM,
                depth: MM_TEXT_DEPTH,
                heads: 4,
            },
            kind: JointKind::Retrieval,
        }
    }
}

/// Resolved spans + pooled buffers of the VQA fusion stage.
struct VqaStage {
    fc1: MatSpan,
    fc1b: VecSpan,
    head_w: MatSpan,
    head_b: VecSpan,
    /// (1, vdim + tdim) concat staging — the pooled replacement for the
    /// historical per-call `extend_from_slice` joint-feature copy
    joint: Mat,
    /// (1, fc1 out) relu hidden state
    hidden: Mat,
    /// pooled per-pair answer logits
    logits: OutputPool,
}

/// Resolved spans + pooled buffers of the retrieval fusion stage.
struct RetrievalStage {
    proj_img: MatSpan,
    proj_txt: MatSpan,
    /// (1, dim) CLS staging for the projection matmuls
    feat: Mat,
    /// pooled per-image normalized embeddings
    img: OutputPool,
    /// pooled per-caption normalized embeddings
    txt: OutputPool,
}

/// A paired vision+text session over one shared [`Engine`]: pooled
/// towers plus the pooled fusion stage `kind` selects.  See the module
/// docs for the lifecycle and the ragged-halves contract.
pub struct JointSession {
    ps: Arc<ParamStore>,
    vision: VitSession,
    text: Session,
    tok: MatSpan,
    pos: MatSpan,
    cfg: JointConfig,
    vqa: Option<VqaStage>,
    ret: Option<RetrievalStage>,
    bv: usize,
    bt: usize,
}

impl JointSession {
    // lint: allow(alloc) reason=cold constructor: parameter-name strings built once per session
    pub(super) fn new(engine: &Engine, cfg: &JointConfig)
                      -> Result<JointSession> {
        let ps = engine.params_arc();
        let vision = engine.vit_session(&cfg.vision)?;
        let text = engine.session(cfg.text.encoder_cfg())?;
        let p = &cfg.text.prefix;
        let (vqa, ret) = match cfg.kind {
            JointKind::Vqa => (
                Some(VqaStage {
                    fc1: ps.mat2_span("vqa.fc1")?,
                    fc1b: ps.vec1_span("vqa.fc1b")?,
                    head_w: ps.mat2_span("vqa.head.w")?,
                    head_b: ps.vec1_span("vqa.head.b")?,
                    joint: Mat::zeros(0, 0),
                    hidden: Mat::zeros(0, 0),
                    logits: OutputPool::new(),
                }),
                None,
            ),
            JointKind::Retrieval => (
                None,
                Some(RetrievalStage {
                    proj_img: ps.mat2_span("proj.img")?,
                    proj_txt: ps.mat2_span("proj.txt")?,
                    feat: Mat::zeros(0, 0),
                    img: OutputPool::new(),
                    txt: OutputPool::new(),
                }),
            ),
        };
        Ok(JointSession {
            tok: ps.mat2_span(&format!("{p}tok"))?,
            pos: ps.mat2_span(&format!("{p}pos"))?,
            ps,
            vision,
            text,
            cfg: cfg.clone(),
            vqa,
            ret,
            bv: 0,
            bt: 0,
        })
    }

    /// The session's joint config.
    pub fn cfg(&self) -> &JointConfig {
        &self.cfg
    }

    /// Set the joint fan-out width: with more than one worker,
    /// [`JointSession::forward`] drains *both* towers with this many
    /// work-stealing workers (one pool, fragments stolen across towers),
    /// so a slow or oversized half can no longer idle the rest.
    pub fn set_vision_workers(&mut self, workers: usize) {
        self.vision.set_workers(workers);
    }

    /// Set the text tower's own fan-out width.  Only the serial-vision
    /// configuration uses it (with one vision worker the towers run
    /// back-to-back and the text half fans out independently); the
    /// stealing path sizes one shared pool from
    /// [`JointSession::set_vision_workers`].
    pub fn set_text_workers(&mut self, workers: usize) {
        self.text.set_workers(workers);
    }

    /// Attach a span recorder + merge-telemetry capture to the vision
    /// tower's scratch pool — merging happens there, and the stealing
    /// joint forward drains both towers through that pool, so one
    /// primary lane covers the whole round (see
    /// [`Session::set_observability`](super::Session::set_observability)).
    pub fn set_observability(&mut self, rec: Option<RingWriter>,
                             telemetry_rows: usize) {
        self.vision.set_observability(rec, telemetry_rows);
    }

    /// The attached span recorder, if any (callers use it to record
    /// model-level stages around session calls).
    pub fn recorder(&self) -> Option<&RingWriter> {
        self.vision.recorder()
    }

    /// Per-layer merge telemetry captured since the last reset.
    pub fn merge_telemetry(&self) -> Option<&MergeTelemetry> {
        self.vision.merge_telemetry()
    }

    /// Reset the captured merge telemetry.
    pub fn reset_merge_telemetry(&mut self) {
        self.vision.reset_merge_telemetry();
    }

    /// Start a round with `bv` images and `bt` token sequences — the two
    /// halves are independent (a retrieval round may embed many captions
    /// against few images; a VQA round uses `bv == bt` pairs).
    pub fn begin(&mut self, bv: usize, bt: usize) {
        self.vision.begin(bv);
        self.text.begin(bt);
        self.bv = bv;
        self.bt = bt;
    }

    /// Number of images in the current round's vision half.
    pub fn vision_len(&self) -> usize {
        self.bv
    }

    /// Number of token sequences in the current round's text half.
    pub fn text_len(&self) -> usize {
        self.bt
    }

    /// Embed image `i`'s patches into its pooled vision slot.
    pub fn set_patches(&mut self, i: usize, patches: &Mat) -> Result<()> {
        self.vision.set_patches(i, patches)
    }

    /// [`JointSession::set_patches`] from a raw row-major slice (the
    /// serving path — no staging copy).
    pub fn set_patches_slice(&mut self, i: usize, data: &[f32]) -> Result<()> {
        self.vision.set_patches_slice(i, data)
    }

    /// Embed sequence `i`'s token ids into its pooled text slot (the
    /// shared [`Session::set_tokens`] stage: token table + positional
    /// embedding, numerically identical to the historical
    /// `embed_tokens`).  Rejects a length that contradicts the tower's
    /// plan and ids outside the vocabulary.
    pub fn set_text(&mut self, i: usize, tokens: &[i32]) -> Result<()> {
        let table = self.ps.mat_at(self.tok);
        let pos = self.ps.mat_at(self.pos);
        self.text.set_tokens(i, tokens, table, pos)
    }

    /// Run both towers over the current round.  With one vision worker
    /// (the default, and the allocation-free serving configuration) the
    /// towers run back-to-back on the calling thread; with more, both
    /// towers' slots are drained by one pool of work-stealing workers
    /// ([`crate::model::encoder::encoder_forward_towers`]).  Every
    /// sample's RNG stream is derived per (layer, sample) from `seed`
    /// (the text tower from a salted stream), so the results are
    /// **bitwise identical at every worker count** — stealing never
    /// changes an answer.  Fusion is separate — call
    /// [`JointSession::fuse_vqa`] or [`JointSession::project`] next.
    pub fn forward(&mut self, seed: u64) -> Result<()> {
        let workers = self.vision.workers();
        if workers <= 1 {
            self.vision.forward(seed)?;
            return self.text.forward(seed ^ TEXT_SEED_SALT);
        }
        let vp = self.vision.tower_parts()?;
        let tp = self.text.tower_parts()?;
        let total = vp.slots.len() + tp.slots.len();
        let w = workers.min(total).max(1);
        encoder_forward_towers(
            &self.ps,
            TowerBatch { re: vp.re, cfg: vp.cfg, slots: vp.slots,
                         outs: vp.outs, seed },
            TowerBatch { re: tp.re, cfg: tp.cfg, slots: tp.slots,
                         outs: tp.outs, seed: seed ^ TEXT_SEED_SALT },
            vp.pool.take(w),
        );
        self.vision.apply_head();
        Ok(())
    }

    /// Serial shared-RNG variant of [`JointSession::forward`]: the whole
    /// vision half runs first, then the whole text half, all drawing from
    /// one `rng` — for single-pair rounds this is bitwise-identical to
    /// the historical per-sample `ViTModel::features` +
    /// `text_features` call order.
    pub fn forward_serial(&mut self, rng: &mut Rng) -> Result<()> {
        self.vision.forward_serial(rng)?;
        self.text.forward_serial(rng)
    }

    /// Vision CLS feature of image `i` (len vision dim) from the most
    /// recent forward.
    pub fn image_feature(&self, i: usize) -> &[f32] {
        self.vision.features(i)
    }

    /// Text CLS feature of sequence `i` (len text dim) from the most
    /// recent forward.
    pub fn text_feature(&self, i: usize) -> &[f32] {
        self.text.output(i).row(0)
    }

    /// VQA fusion over explicit `(vision, text)` index `pairs`: for each
    /// pair, concatenate the two CLS features in the pooled joint buffer
    /// and run `vqa.fc1` + relu + the answer head into pooled per-pair
    /// logits ([`JointSession::answer_logits`]).  Allocation-free once
    /// warm.  Errors when the session was built without the VQA stage or
    /// an index falls outside the current round.
    // lint: allow(alloc) reason=error-path format! only
    pub fn fuse_vqa(&mut self, pairs: &[(usize, usize)]) -> Result<()> {
        let (bv, bt) = (self.bv, self.bt);
        let Some(stage) = self.vqa.as_mut() else {
            return Err(Error::Config(
                "joint session was built without the VQA fusion stage \
                 (JointKind::Retrieval)".into()));
        };
        for &(vi, ti) in pairs {
            if vi >= bv || ti >= bt {
                return Err(Error::Shape(format!(
                    "VQA pair ({vi}, {ti}) outside the round's halves \
                     ({bv} images, {bt} sequences)")));
            }
        }
        let vdim = self.cfg.vision.dim;
        let tdim = self.cfg.text.dim;
        let logits = stage.logits.take(pairs.len());
        for (out, &(vi, ti)) in logits.iter_mut().zip(pairs) {
            let vf = self.vision.features(vi);
            let tf = self.text.output(ti).row(0);
            stage.joint.reshape(1, vdim + tdim);
            let row = stage.joint.row_mut(0);
            row[..vdim].copy_from_slice(vf);
            row[vdim..].copy_from_slice(tf);
            dense_into(&stage.joint, self.ps.mat_at(stage.fc1),
                       Some(self.ps.vec_at(stage.fc1b)), &mut stage.hidden);
            for v in stage.hidden.data.iter_mut() {
                *v = v.max(0.0);
            }
            dense_into(&stage.hidden, self.ps.mat_at(stage.head_w),
                       Some(self.ps.vec_at(stage.head_b)), out);
        }
        Ok(())
    }

    /// Answer logits of fused pair `p` (len `N_ANSWERS`) from the most
    /// recent [`JointSession::fuse_vqa`].
    pub fn answer_logits(&self, p: usize) -> &[f32] {
        self.vqa
            .as_ref()
            .expect("joint session has no VQA stage")
            .logits
            .get(p)
            .row(0)
    }

    /// Predicted answer of fused pair `p`.
    pub fn answer(&self, p: usize) -> usize {
        crate::tensor::argmax(self.answer_logits(p))
    }

    /// Retrieval fusion: project every image and caption of the current
    /// round into the shared embedding space (`proj.img` / `proj.txt` +
    /// L2 normalization) through pooled buffers
    /// ([`JointSession::image_embed`] / [`JointSession::text_embed`]).
    /// Allocation-free once warm.  Errors when the session was built
    /// without the retrieval stage.
    pub fn project(&mut self) -> Result<()> {
        let (bv, bt) = (self.bv, self.bt);
        let Some(stage) = self.ret.as_mut() else {
            return Err(Error::Config(
                "joint session was built without the retrieval fusion \
                 stage (JointKind::Vqa)".into()));
        };
        let vdim = self.cfg.vision.dim;
        let tdim = self.cfg.text.dim;
        let imgs = stage.img.take(bv);
        for (i, out) in imgs.iter_mut().enumerate() {
            stage.feat.reshape(1, vdim);
            stage.feat.row_mut(0).copy_from_slice(self.vision.features(i));
            dense_into(&stage.feat, self.ps.mat_at(stage.proj_img), None,
                       out);
            l2_normalize(out.row_mut(0));
        }
        let txts = stage.txt.take(bt);
        for (j, out) in txts.iter_mut().enumerate() {
            stage.feat.reshape(1, tdim);
            stage.feat.row_mut(0).copy_from_slice(self.text.output(j).row(0));
            dense_into(&stage.feat, self.ps.mat_at(stage.proj_txt), None,
                       out);
            l2_normalize(out.row_mut(0));
        }
        Ok(())
    }

    /// Normalized embedding of image `i` from the most recent
    /// [`JointSession::project`].
    pub fn image_embed(&self, i: usize) -> &[f32] {
        self.ret
            .as_ref()
            .expect("joint session has no retrieval stage")
            .img
            .get(i)
            .row(0)
    }

    /// Normalized embedding of caption `j` from the most recent
    /// [`JointSession::project`].
    pub fn text_embed(&self, j: usize) -> &[f32] {
        self.ret
            .as_ref()
            .expect("joint session has no retrieval stage")
            .txt
            .get(j)
            .row(0)
    }

    /// Retrieval score of (image `i`, caption `j`): the dot product of
    /// their normalized embeddings (cosine similarity), computed with
    /// the lane-split `tensor::dot` kernel so it is bitwise-identical
    /// to the gallery scan (`gallery::scan_into`) scoring the same
    /// embeddings.
    pub fn score(&self, i: usize, j: usize) -> f32 {
        crate::tensor::dot(self.image_embed(i), self.text_embed(j))
    }

    /// One-pair VQA convenience under the serial shared-RNG contract:
    /// embed, run vision then text, fuse, and return the answer logits —
    /// bitwise-identical to the historical per-sample
    /// `eval::vqa::vqa_logits` (vision draws from `rng` first, then the
    /// question tower), but through pooled buffers and the engine's
    /// cached weight resolutions.
    pub fn vqa_one(&mut self, patches: &Mat, question: &[i32],
                   rng: &mut Rng) -> Result<&[f32]> {
        self.begin(1, 1);
        self.set_patches(0, patches)?;
        self.set_text(0, question)?;
        self.forward_serial(rng)?;
        self.fuse_vqa(&[(0, 0)])?;
        Ok(self.answer_logits(0))
    }

    /// One-pair retrieval convenience under the serial shared-RNG
    /// contract: embed, run vision then text, project, and return the
    /// (image, caption) embedding pair — bitwise-identical to the
    /// historical `clip_image_embed` followed by `clip_text_embed` with
    /// one shared RNG.
    pub fn embed_pair_one(&mut self, patches: &Mat, caption: &[i32],
                          rng: &mut Rng) -> Result<(&[f32], &[f32])> {
        self.begin(1, 1);
        self.set_patches(0, patches)?;
        self.set_text(0, caption)?;
        self.forward_serial(rng)?;
        self.project()?;
        Ok((self.image_embed(0), self.text_embed(0)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{patchify, shape_item, vqa_item, TEST_SEED};
    use crate::model::synthetic_mm_store;

    fn mm_engine(mode: &str) -> (ViTConfig, Engine) {
        let vcfg = ViTConfig { merge_mode: mode.into(), merge_r: 0.9,
                               ..Default::default() };
        let engine = Engine::from_store(synthetic_mm_store(&vcfg, 11));
        (vcfg, engine)
    }

    #[test]
    fn vqa_session_answers_deterministically() {
        let (vcfg, engine) = mm_engine("pitome");
        let mut sess = engine.joint_session(&JointConfig::vqa(vcfg)).unwrap();
        let item = shape_item(TEST_SEED, 0);
        let patches = patchify(&item.image, 4);
        let (q, _) = vqa_item(TEST_SEED, 0);
        let mut r1 = Rng::new(5);
        let a = sess.vqa_one(&patches, &q, &mut r1).unwrap().to_vec();
        let mut r2 = Rng::new(5);
        let b = sess.vqa_one(&patches, &q, &mut r2).unwrap().to_vec();
        assert_eq!(a, b, "same RNG stream must reproduce the logits");
        assert_eq!(a.len(), crate::data::N_ANSWERS);
    }

    #[test]
    fn ragged_halves_are_sized_independently() {
        let (vcfg, engine) = mm_engine("pitome");
        let mut sess =
            engine.joint_session(&JointConfig::retrieval(vcfg)).unwrap();
        sess.begin(2, 3);
        for i in 0..2 {
            let item = shape_item(TEST_SEED, i as u64);
            sess.set_patches(i, &patchify(&item.image, 4)).unwrap();
        }
        for j in 0..3 {
            let cap = crate::data::caption_for(TEST_SEED, j as u64);
            sess.set_text(j, &cap).unwrap();
        }
        sess.forward(0).unwrap();
        sess.project().unwrap();
        assert_eq!(sess.vision_len(), 2);
        assert_eq!(sess.text_len(), 3);
        // normalized embeddings: unit length, scores in [-1, 1]
        for i in 0..2 {
            let n: f32 = sess.image_embed(i).iter().map(|v| v * v).sum();
            assert!((n - 1.0).abs() < 1e-3, "image embed {i} not unit: {n}");
            for j in 0..3 {
                let s = sess.score(i, j);
                assert!((-1.001..=1.001).contains(&s), "score {s}");
            }
        }
    }

    #[test]
    fn stealing_forward_is_bitwise_identical_at_every_worker_count() {
        let (vcfg, engine) = mm_engine("pitome");
        let cfg = JointConfig::retrieval(vcfg);
        let fill = |sess: &mut JointSession| {
            sess.begin(3, 5);
            for i in 0..3 {
                let item = shape_item(TEST_SEED, i as u64);
                sess.set_patches(i, &patchify(&item.image, 4)).unwrap();
            }
            for j in 0..5 {
                let cap = crate::data::caption_for(TEST_SEED, j as u64);
                sess.set_text(j, &cap).unwrap();
            }
            sess.forward(7).unwrap();
            sess.project().unwrap();
        };
        let mut serial = engine.joint_session(&cfg).unwrap();
        fill(&mut serial);
        for workers in [2, 4] {
            let mut stealing = engine.joint_session(&cfg).unwrap();
            stealing.set_vision_workers(workers);
            fill(&mut stealing);
            for i in 0..3 {
                assert_eq!(serial.image_embed(i), stealing.image_embed(i),
                           "image {i} diverged at {workers} workers");
            }
            for j in 0..5 {
                assert_eq!(serial.text_embed(j), stealing.text_embed(j),
                           "caption {j} diverged at {workers} workers");
            }
        }
    }

    #[test]
    fn wrong_stage_and_bad_indices_are_rejected() {
        let (vcfg, engine) = mm_engine("none");
        let mut vqa = engine
            .joint_session(&JointConfig::vqa(vcfg.clone()))
            .unwrap();
        assert!(vqa.project().is_err(), "VQA session must lack projections");
        vqa.begin(1, 1);
        assert!(vqa.fuse_vqa(&[(0, 1)]).is_err(), "pair outside text half");
        let mut ret =
            engine.joint_session(&JointConfig::retrieval(vcfg)).unwrap();
        assert!(ret.fuse_vqa(&[]).is_err(),
                "retrieval session must lack the VQA head");
        // bad token ids and bad lengths
        ret.begin(0, 1);
        assert!(ret.set_text(0, &[1, 2, 3]).is_err(), "short caption");
        let bad = vec![VOCAB as i32 + 5; CAP_LEN + 1];
        assert!(ret.set_text(0, &bad).is_err(), "oov caption ids");
    }
}
