//! Full ViT serving session: patch embedding + encoder + classifier head,
//! all through pooled buffers.

use std::sync::Arc;

use crate::config::ViTConfig;
use crate::data::Rng;
use crate::error::{Error, Result};
use crate::model::params::{MatSpan, VecSpan};
use crate::model::{EncoderCfg, ParamStore};
use crate::obs::{MergeTelemetry, RingWriter, Stage};
use crate::tensor::{dense_into, Mat, MatRef};

use super::head::ClassifierHead;
use super::{Engine, Session, TowerParts};

/// A [`Session`](super::Session) extended with the ViT model's
/// non-encoder stages — patch embedding (+ CLS + positional embedding) on
/// the way in, the classifier head on the way out — so a whole
/// patches→logits request runs through pooled buffers.
///
/// Same ownership rules as the raw session: one per worker thread, alive
/// for the worker's lifetime.  This is what the coordinator's CPU
/// workers hold (`coordinator/batcher.rs`).
pub struct VitSession {
    ps: Arc<ParamStore>,
    session: Session,
    vcfg: ViTConfig,
    embed_w: MatSpan,
    embed_b: VecSpan,
    cls: VecSpan,
    pos: MatSpan,
    /// patch-embedding scratch (n_patches, dim)
    emb: Mat,
    head: ClassifierHead,
}

impl VitSession {
    // lint: allow(alloc) reason=Arc refcount clone at session construction
    pub(super) fn new(engine: &Engine, cfg: &ViTConfig) -> Result<VitSession> {
        let ps = engine.params_arc();
        let session = engine.session(EncoderCfg::from_vit(cfg))?;
        Ok(VitSession {
            embed_w: ps.mat2_span("vit.embed.w")?,
            embed_b: ps.vec1_span("vit.embed.b")?,
            cls: ps.vec1_span("vit.cls")?,
            pos: ps.mat2_span("vit.pos")?,
            head: ClassifierHead::resolve(&ps, "vit.head.w", "vit.head.b")?,
            ps,
            session,
            vcfg: cfg.clone(),
            emb: Mat::zeros(0, 0),
        })
    }

    /// The session's model config.
    pub fn cfg(&self) -> &ViTConfig {
        &self.vcfg
    }

    /// Set the encoder fan-out width (see
    /// [`Session::set_workers`](super::Session::set_workers)).
    pub fn set_workers(&mut self, workers: usize) {
        self.session.set_workers(workers);
    }

    /// Attach a span recorder + merge-telemetry capture (see
    /// [`Session::set_observability`](super::Session::set_observability));
    /// the classifier-head stage records through the same ring.
    pub fn set_observability(&mut self, rec: Option<RingWriter>,
                             telemetry_rows: usize) {
        self.session.set_observability(rec, telemetry_rows);
    }

    /// The attached span recorder, if any (callers use it to record
    /// model-level stages around session calls).
    pub fn recorder(&self) -> Option<&RingWriter> {
        self.session.recorder()
    }

    /// Per-layer merge telemetry captured since the last reset.
    pub fn merge_telemetry(&self) -> Option<&MergeTelemetry> {
        self.session.merge_telemetry()
    }

    /// Reset the captured merge telemetry.
    pub fn reset_merge_telemetry(&mut self) {
        self.session.reset_merge_telemetry();
    }

    /// Start a batch of `count` samples.
    pub fn begin(&mut self, count: usize) {
        self.session.begin(count);
    }

    /// Embed sample `i`'s patches — shape (num_patches, patch_dim) — into
    /// its pooled token slot (patch embed + CLS + positional embedding,
    /// numerically identical to `ViTModel::tokens`).  Rejects any other
    /// shape.
    pub fn set_patches(&mut self, i: usize, patches: &Mat) -> Result<()> {
        self.set_patches_view(i, patches.view())
    }

    /// [`VitSession::set_patches`] from a raw row-major slice (the
    /// serving path: request tensors arrive as flat f32 data and are
    /// consumed in place, no staging copy).
    // lint: allow(alloc) reason=error-path format! only
    pub fn set_patches_slice(&mut self, i: usize, data: &[f32]) -> Result<()> {
        let (rows, cols) = (self.vcfg.num_patches(), self.vcfg.patch_dim());
        if data.len() != rows * cols {
            return Err(Error::Shape(format!(
                "patches for sample {i}: {} elements != expected {rows}x{cols}",
                data.len())));
        }
        self.set_patches_view(i, MatRef { rows, cols, data })
    }

    // lint: allow(alloc) reason=error-path format! only
    fn set_patches_view(&mut self, i: usize, patches: MatRef<'_>)
                        -> Result<()> {
        let (want_rows, want_cols) =
            (self.vcfg.num_patches(), self.vcfg.patch_dim());
        if patches.rows != want_rows || patches.cols != want_cols {
            return Err(Error::Shape(format!(
                "patches for sample {i}: ({}, {}) != expected \
                 ({want_rows}, {want_cols})", patches.rows, patches.cols)));
        }
        dense_into(patches, self.ps.mat_at(self.embed_w),
                   Some(self.ps.vec_at(self.embed_b)), &mut self.emb);
        let dim = self.vcfg.dim;
        let n = self.emb.rows + 1;
        let x = self.session.input_mut(i);
        x.reshape(n, dim);
        x.row_mut(0).copy_from_slice(self.ps.vec_at(self.cls));
        for r in 0..self.emb.rows {
            x.row_mut(r + 1).copy_from_slice(self.emb.row(r));
        }
        let pos = self.ps.mat_at(self.pos);
        for r in 0..n {
            let xr = x.row_mut(r);
            for (v, &p) in xr.iter_mut().zip(pos.row(r)) {
                *v += p;
            }
        }
        Ok(())
    }

    /// Run encoder + classifier head over the current batch (fan-out
    /// seeded per (layer, sample) from `seed`); logits land in the pooled
    /// per-sample buffers ([`VitSession::logits`]).
    pub fn forward(&mut self, seed: u64) -> Result<()> {
        self.session.forward(seed)?;
        self.apply_head();
        Ok(())
    }

    /// The configured encoder fan-out width (the joint session reads it
    /// to size the shared stealing pool).
    pub(super) fn workers(&self) -> usize {
        self.session.workers()
    }

    /// Lend out the encoder-stage borrows for a stealing joint forward.
    /// The caller owns the encoder drive and must finish with
    /// [`VitSession::apply_head`].
    pub(super) fn tower_parts(&mut self) -> Result<TowerParts<'_>> {
        self.session.tower_parts()
    }

    /// Run the classifier head over the session's current outputs — the
    /// back half of [`VitSession::forward`], for callers that drove the
    /// encoder externally via [`VitSession::tower_parts`].
    pub(super) fn apply_head(&mut self) {
        let t0 = self.session.recorder().map(|r| r.now_us());
        self.head.apply(&self.ps, &self.session);
        if let Some(r) = self.session.recorder() {
            r.span_since(Stage::Head, 0, t0.unwrap_or(0),
                         self.session.batch_len() as u32);
        }
    }

    /// Serial shared-RNG variant (the historical single-sample contract;
    /// see [`Session::forward_serial`](super::Session::forward_serial)).
    pub fn forward_serial(&mut self, rng: &mut Rng) -> Result<()> {
        self.session.forward_serial(rng)?;
        self.apply_head();
        Ok(())
    }

    /// CLS feature of sample `i` (len dim).
    pub fn features(&self, i: usize) -> &[f32] {
        self.session.output(i).row(0)
    }

    /// Class logits of sample `i` (len num_classes).
    pub fn logits(&self, i: usize) -> &[f32] {
        self.head.logits(i)
    }

    /// Predicted class of sample `i`.
    pub fn predict(&self, i: usize) -> usize {
        self.head.predict(i)
    }

    /// One-sample convenience under the serial shared-RNG contract:
    /// embed, forward, and return the CLS feature (bitwise-identical to
    /// the historical `ViTModel::features`).
    pub fn features_one(&mut self, patches: &Mat, rng: &mut Rng)
                        -> Result<&[f32]> {
        self.begin(1);
        self.set_patches(0, patches)?;
        self.forward_serial(rng)?;
        Ok(self.features(0))
    }
}
