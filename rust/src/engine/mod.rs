//! The owning inference API: [`Engine`] (weights + resolution cache) and
//! [`Session`] (per-worker reusable state) — end-to-end zero-allocation
//! serving.
//!
//! # Why
//!
//! PRs 2–3 made the encoder *layer loop* allocation-free, but the public
//! surface had sprawled into overlapping free functions
//! (`encoder_forward` / `_scratch` / `_batch` / `_batch_pooled`,
//! `ViTModel::*_batch[_pooled]`, `bert_logits_batch_pooled`) that each
//! re-resolved weights per call, hand-threaded scratch pools, and still
//! allocated per-sample outputs in the final LayerNorm and the batch
//! driver.  This module replaces the zoo with two owning types, the way
//! ToMe's `patch()` replaces per-model glue:
//!
//! * [`Engine`] — owns the [`ParamStore`] and a weight-resolution cache
//!   (one [`ResolvedEncoder`] per [`EncoderCfg`], keyed by config hash),
//!   so **nothing is ever re-resolved per batch**.  Cheap to share:
//!   thread-safe, one per process.
//! * [`Session`] — per worker, never shared: a [`ScratchPool`], pooled
//!   input [`SeqSlot`]s, and an [`OutputPool`] the final LayerNorm writes
//!   into.  After one warm batch, a whole request — inputs, layer loop,
//!   outputs — performs **zero heap allocations** (asserted by
//!   `tests/alloc_free.rs`).
//!
//! # Lifecycle
//!
//! ```no_run
//! use pitome::config::ViTConfig;
//! use pitome::engine::Engine;
//! use pitome::model::synthetic_vit_store;
//!
//! let cfg = ViTConfig { merge_mode: "pitome".into(), merge_r: 0.9,
//!                       ..Default::default() };
//! let engine = Engine::from_store(synthetic_vit_store(&cfg, 7));
//! // one session per worker thread, alive for the worker's lifetime
//! let mut sess = engine.vit_session(&cfg).unwrap();
//! loop {
//!     let patches: Vec<pitome::tensor::Mat> = todo!("collect a batch");
//!     sess.begin(patches.len());
//!     for (i, p) in patches.iter().enumerate() {
//!         sess.set_patches(i, p).unwrap();
//!     }
//!     sess.forward(0).unwrap();
//!     for i in 0..patches.len() {
//!         let _logits: &[f32] = sess.logits(i);
//!     }
//! }
//! ```
//!
//! For the raw encoder (no model head) use [`Engine::session`] →
//! [`Session::forward_batch`].  The legacy free functions remain as thin
//! `#[deprecated]` wrappers; `tests/prop_engine.rs` proves this API is
//! bitwise-identical to every one of them in all ten merge modes.
//!
//! # Shape changes between rounds
//!
//! Pools never hold stale shapes: every buffer is reshaped in place per
//! round ([`crate::tensor::Mat::reshape`] keeps capacity, so shrinking is
//! free and growing past the previous peak is the only thing that ever
//! allocates), and inputs whose shape contradicts the session's config
//! are rejected with [`Error::Shape`](crate::error::Error) instead of
//! being silently mis-merged.

#![deny(missing_docs)]

mod head;
mod multimodal;
mod output;
mod text;
mod vit;

pub use multimodal::{JointConfig, JointKind, JointSession, TextTowerCfg};
pub use output::OutputPool;
pub use text::BertSession;
pub use vit::VitSession;

use std::collections::HashMap;
use std::hash::{Hash, Hasher};
use std::sync::{Arc, Mutex};

use crate::config::{TextConfig, ViTConfig};
use crate::data::Rng;
use crate::error::{Error, Result};
use crate::model::encoder::{encoder_forward_slot, encoder_forward_slots,
                            SeqSlot};
use crate::model::{EncoderCfg, ParamStore, ResolvedEncoder, ScratchPool};
use crate::obs::{MergeTelemetry, RingWriter};
use crate::tensor::{Mat, MatRef};

/// Disjoint borrows of everything one tower contributes to a stealing
/// joint forward ([`crate::model::encoder::encoder_forward_towers`]):
/// resolved weights, config, validated input slots, matching pooled
/// output buffers, and the session's scratch pool.  Produced by
/// `Session::tower_parts`, consumed by [`JointSession::forward`].
struct TowerParts<'a> {
    /// resolved weights of this tower
    re: &'a ResolvedEncoder,
    /// this tower's encoder config
    cfg: &'a EncoderCfg,
    /// validated, size-reset input slots of the current batch
    slots: &'a mut [SeqSlot],
    /// matching pooled output buffers (same length as `slots`)
    outs: &'a mut [Mat],
    /// the session's scratch pool (one tower lends it to the joint pool)
    pool: &'a mut ScratchPool,
}

/// Hash an [`EncoderCfg`] for the resolution cache (f32 via bit pattern).
fn cfg_key(cfg: &EncoderCfg) -> u64 {
    let mut h = std::collections::hash_map::DefaultHasher::new();
    cfg.prefix.hash(&mut h);
    cfg.dim.hash(&mut h);
    cfg.depth.hash(&mut h);
    cfg.heads.hash(&mut h);
    cfg.mode.hash(&mut h);
    cfg.plan.hash(&mut h);
    cfg.prop_attn.hash(&mut h);
    cfg.tofu_threshold.to_bits().hash(&mut h);
    h.finish()
}

/// The owning entry point for inference: parameter store + shared
/// weight-resolution cache.  One per process; hand out one [`Session`]
/// per worker thread via [`Engine::session`] /
/// [`Engine::vit_session`] / [`Engine::bert_session`].
pub struct Engine {
    ps: Arc<ParamStore>,
    /// resolved weights per config hash (the bucket holds the full
    /// configs, so hash collisions degrade to a scan, never to a wrong
    /// resolution)
    resolved: Mutex<HashMap<u64, Vec<(EncoderCfg, Arc<ResolvedEncoder>)>>>,
}

impl Engine {
    /// Wrap a shared parameter store.
    pub fn new(ps: Arc<ParamStore>) -> Engine {
        Engine { ps, resolved: Mutex::new(HashMap::new()) }
    }

    /// Convenience: take ownership of a store (wraps it in an `Arc`).
    pub fn from_store(ps: ParamStore) -> Engine {
        Engine::new(Arc::new(ps))
    }

    /// The underlying parameter store (e.g. for projection heads that
    /// live outside the encoder).
    pub fn params(&self) -> &ParamStore {
        &self.ps
    }

    /// Shared handle to the parameter store.
    // lint: allow(alloc) reason=Arc refcount clone, no heap data copied
    pub fn params_arc(&self) -> Arc<ParamStore> {
        self.ps.clone()
    }

    /// Resolve (or fetch from cache) the weights `cfg` names.  Every
    /// session for an equal config shares one resolution — nothing is
    /// re-resolved per session, let alone per batch.
    // lint: allow(alloc) reason=Arc clones and a one-time cfg clone at engine construction
    pub fn resolve(&self, cfg: &EncoderCfg) -> Result<Arc<ResolvedEncoder>> {
        let key = cfg_key(cfg);
        let mut cache = self.resolved.lock().unwrap();
        if let Some(bucket) = cache.get(&key) {
            for (c, re) in bucket {
                if c == cfg {
                    return Ok(re.clone());
                }
            }
        }
        let re = Arc::new(ResolvedEncoder::new(&self.ps, cfg)?);
        cache.entry(key).or_default().push((cfg.clone(), re.clone()));
        Ok(re)
    }

    /// Open a raw encoder session for `cfg` (per worker thread — see the
    /// module docs for the lifecycle).
    // lint: allow(alloc) reason=cold constructor: session-owned pools start empty and grow on first use
    pub fn session(&self, cfg: EncoderCfg) -> Result<Session> {
        let re = self.resolve(&cfg)?;
        Ok(Session {
            ps: self.ps.clone(),
            re,
            cfg,
            workers: 1,
            pool: ScratchPool::new(),
            slots: Vec::new(),
            outputs: OutputPool::new(),
            count: 0,
        })
    }

    /// Open a full ViT session (patch embedding + encoder + classifier
    /// head) for `cfg`.
    pub fn vit_session(&self, cfg: &ViTConfig) -> Result<VitSession> {
        VitSession::new(self, cfg)
    }

    /// Open a full BERT-style session (token embedding + encoder +
    /// classifier head) for `cfg`.
    pub fn bert_session(&self, cfg: &TextConfig) -> Result<BertSession> {
        BertSession::new(self, cfg)
    }

    /// Open a joint vision+text session (paired pooled towers + the
    /// fusion stage `cfg.kind` selects) — the serving form of the
    /// paper's multimodal workloads (retrieval scoring, VQA).
    pub fn joint_session(&self, cfg: &JointConfig) -> Result<JointSession> {
        JointSession::new(self, cfg)
    }

    /// Number of distinct configs currently resolved in the cache.
    pub fn resolved_configs(&self) -> usize {
        self.resolved.lock().unwrap().values().map(Vec::len).sum()
    }
}

/// Per-worker reusable inference state: resolved weights (shared via the
/// engine's cache), a scratch pool for the fan-out, pooled input slots,
/// and the output pool the final LayerNorm writes into.
///
/// A session is `Send` but offers no synchronized access (every useful
/// method takes `&mut self`): keep exactly one per worker thread, alive
/// for the worker's lifetime.  Reuse across batches of any (smaller or
/// larger) size is safe and allocation-free once the peak shape has been
/// seen.
pub struct Session {
    ps: Arc<ParamStore>,
    re: Arc<ResolvedEncoder>,
    cfg: EncoderCfg,
    workers: usize,
    pool: ScratchPool,
    slots: Vec<SeqSlot>,
    outputs: OutputPool,
    count: usize,
}

impl Session {
    /// The session's encoder config.
    pub fn cfg(&self) -> &EncoderCfg {
        &self.cfg
    }

    /// The underlying parameter store.
    pub fn params(&self) -> &ParamStore {
        &self.ps
    }

    /// Set the fan-out width for [`Session::forward`] (clamped to ≥ 1;
    /// default 1 = inline, no thread spawns).
    pub fn set_workers(&mut self, workers: usize) {
        self.workers = workers.max(1);
    }

    /// The configured fan-out width.
    fn workers(&self) -> usize {
        self.workers
    }

    /// Attach (or detach, with `None`) a span recorder plus per-layer
    /// merge-telemetry capture with room for `telemetry_rows` rows (size
    /// as depth × max batch).  Instrumentation rides the scratch pool's
    /// primary lane only — see the single-producer contract in
    /// [`crate::obs::ring`].  Cold path: call at boot, never per batch.
    pub fn set_observability(&mut self, rec: Option<RingWriter>,
                             telemetry_rows: usize) {
        self.pool.set_observability(rec, telemetry_rows);
    }

    /// The attached span recorder, if any (model heads record through
    /// the same ring as the layer loop).
    pub fn recorder(&self) -> Option<&RingWriter> {
        self.pool.recorder()
    }

    /// Per-layer merge telemetry captured by the primary scratch lane
    /// since its last reset (`None` until a scratch lane exists).
    pub fn merge_telemetry(&self) -> Option<&MergeTelemetry> {
        self.pool.merge_telemetry()
    }

    /// Reset the captured merge telemetry (start of an observation
    /// window).
    pub fn reset_merge_telemetry(&mut self) {
        self.pool.reset_merge_telemetry();
    }

    /// Split the session into the disjoint borrows a stealing joint
    /// forward needs: validate inputs, reset per-slot sizes, check out
    /// the output buffers, and lend out weights/slots/outputs/pool —
    /// the front half of [`Session::forward`], with the encoder drive
    /// left to [`crate::model::encoder::encoder_forward_towers`].
    fn tower_parts(&mut self) -> Result<TowerParts<'_>> {
        self.validate_inputs()?;
        for s in &mut self.slots[..self.count] {
            s.reset_sizes();
        }
        let outs = self.outputs.take(self.count);
        Ok(TowerParts {
            re: &*self.re,
            cfg: &self.cfg,
            slots: &mut self.slots[..self.count],
            outs,
            pool: &mut self.pool,
        })
    }

    /// Start a batch of `count` samples: pooled input slots are handed
    /// out for [`Session::input_mut`] to fill (contents left from
    /// previous rounds are unspecified).
    pub fn begin(&mut self, count: usize) {
        while self.slots.len() < count {
            self.slots.push(SeqSlot::new());
        }
        self.count = count;
    }

    /// Number of samples in the current batch.
    pub fn batch_len(&self) -> usize {
        self.count
    }

    /// Input buffer for sample `i` of the current batch — reshape and
    /// fill it with the (plan[0], dim) token matrix.
    pub fn input_mut(&mut self, i: usize) -> &mut Mat {
        assert!(i < self.count, "input {i} outside the batch ({})", self.count);
        &mut self.slots[i].x
    }

    /// Embed a token-id sequence into pooled input slot `i` (token
    /// `table` lookup + positional embedding `pos`, numerically identical
    /// to the historical `embed_tokens`), validating the length against
    /// the config's `plan[0]` and every id against the table — the text
    /// embedding stage [`BertSession`] and [`JointSession`] share.
    // lint: allow(alloc) reason=error-path format! only, never taken on the steady-state path
    pub fn set_tokens(&mut self, i: usize, tokens: &[i32], table: MatRef,
                      pos: MatRef) -> Result<()> {
        let want = self.cfg.plan[0];
        if tokens.len() != want {
            return Err(Error::Shape(format!(
                "token sequence {i}: length {} != expected {want}",
                tokens.len())));
        }
        for &t in tokens {
            if t < 0 || t as usize >= table.rows {
                return Err(Error::Shape(format!(
                    "token sequence {i}: id {t} outside vocab of {}",
                    table.rows)));
            }
        }
        let dim = self.cfg.dim;
        let x = self.input_mut(i);
        x.reshape(tokens.len(), dim);
        for (r, &t) in tokens.iter().enumerate() {
            let xr = x.row_mut(r);
            let e = table.row(t as usize);
            let p = pos.row(r);
            for j in 0..dim {
                xr[j] = e[j] + p[j];
            }
        }
        Ok(())
    }

    /// Check every filled input against the config (the stale-shape
    /// guard: a slot refilled at the wrong shape is an error, never a
    /// silent mis-merge).
    // lint: allow(alloc) reason=error-path format! only, never taken on the steady-state path
    fn validate_inputs(&self) -> Result<()> {
        let (want_n, want_d) = (self.cfg.plan[0], self.cfg.dim);
        for (i, s) in self.slots[..self.count].iter().enumerate() {
            if s.x.rows != want_n || s.x.cols != want_d {
                return Err(Error::Shape(format!(
                    "session input {i}: ({}, {}) does not match the \
                     config's (plan[0]={want_n}, dim={want_d})",
                    s.x.rows, s.x.cols)));
            }
        }
        Ok(())
    }

    /// Run the encoder over the current batch, fanning samples out over
    /// up to the configured worker count.  Outputs land in the session's
    /// [`OutputPool`] ([`Session::output`]); `seed` derives one
    /// deterministic RNG stream per (layer, sample), so results are
    /// independent of the fan-out width.  Zero heap allocations once
    /// warm (single-worker; each extra worker costs only its thread
    /// spawn).
    pub fn forward(&mut self, seed: u64) -> Result<()> {
        self.validate_inputs()?;
        for s in &mut self.slots[..self.count] {
            s.reset_sizes();
        }
        let outs = self.outputs.take(self.count);
        if self.count == 0 {
            return Ok(());
        }
        let w = self.workers.min(self.count);
        encoder_forward_slots(&self.ps, &self.re, &self.cfg,
                              &mut self.slots[..self.count], outs, seed,
                              self.pool.take(w));
        Ok(())
    }

    /// Serial variant of [`Session::forward`]: samples run in order on
    /// the caller's thread, all drawing from one shared `rng` — the
    /// historical single-sample contract (`encoder_forward` called in a
    /// loop), bitwise-identical to it in every mode, stochastic ones
    /// included.
    pub fn forward_serial(&mut self, rng: &mut Rng) -> Result<()> {
        self.validate_inputs()?;
        for s in &mut self.slots[..self.count] {
            s.reset_sizes();
        }
        let outs = self.outputs.take(self.count);
        let scratch = &mut self.pool.take(1)[0];
        for (slot, out) in self.slots[..self.count].iter_mut().zip(outs) {
            encoder_forward_slot(&self.ps, &self.re, &self.cfg, slot, out,
                                 rng, scratch);
        }
        Ok(())
    }

    /// Copy-in convenience over [`Session::begin`] / [`Session::forward`]:
    /// run the encoder over `xs` and return the pooled outputs in sample
    /// order.  Allocation-free once warm — inputs are copied into pooled
    /// slots, outputs live in the session until the next round.
    pub fn forward_batch(&mut self, xs: &[Mat], seed: u64) -> Result<&[Mat]> {
        self.begin(xs.len());
        for (slot, x) in self.slots[..self.count].iter_mut().zip(xs) {
            slot.set_input(x);
        }
        self.forward(seed)?;
        Ok(self.outputs.outputs())
    }

    /// One-sample convenience over [`Session::forward_serial`].
    pub fn forward_one(&mut self, x: &Mat, rng: &mut Rng) -> Result<&Mat> {
        self.begin(1);
        self.slots[0].set_input(x);
        self.forward_serial(rng)?;
        Ok(self.outputs.get(0))
    }

    /// Output tokens (plan[depth], dim) of sample `i` from the most
    /// recent forward.
    pub fn output(&self, i: usize) -> &Mat {
        self.outputs.get(i)
    }

    /// All outputs of the most recent forward, in sample order.
    pub fn outputs(&self) -> &[Mat] {
        self.outputs.outputs()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::synthetic_vit_store;

    fn vit_cfg(mode: &str) -> ViTConfig {
        ViTConfig { merge_mode: mode.into(), merge_r: 0.9,
                    ..Default::default() }
    }

    #[test]
    fn resolution_cache_shares_one_resolve_per_config() {
        let vcfg = vit_cfg("pitome");
        let engine = Engine::from_store(synthetic_vit_store(&vcfg, 1));
        let cfg = EncoderCfg::from_vit(&vcfg);
        let a = engine.resolve(&cfg).unwrap();
        let b = engine.resolve(&cfg).unwrap();
        assert!(Arc::ptr_eq(&a, &b), "equal configs must share a resolution");
        assert_eq!(engine.resolved_configs(), 1);
        let mut cfg2 = cfg.clone();
        cfg2.mode = crate::merge::MergeMode::ToMe;
        let c = engine.resolve(&cfg2).unwrap();
        assert!(!Arc::ptr_eq(&a, &c));
        assert_eq!(engine.resolved_configs(), 2);
    }

    #[test]
    fn wrong_input_shape_is_rejected() {
        let vcfg = vit_cfg("pitome");
        let engine = Engine::from_store(synthetic_vit_store(&vcfg, 1));
        let mut sess = engine.session(EncoderCfg::from_vit(&vcfg)).unwrap();
        sess.begin(1);
        sess.input_mut(0).reshape(3, 5); // neither plan[0] nor dim
        let err = sess.forward(0).unwrap_err();
        assert!(format!("{err}").contains("does not match"), "{err}");
    }
}
