//! Caller-owned pools of reusable output matrices.
//!
//! The historical batch drivers allocated one fresh `Mat` per sample for
//! the final LayerNorm (and one `Vec` to collect them), so even with the
//! allocation-free layer loop of PRs 2–3 every serving request still paid
//! per-sample output allocations.  An [`OutputPool`] closes that gap: the
//! driver writes each sample's result into a pooled buffer that survives
//! the call, and a pool that has seen its peak `(batch, shape)` never
//! allocates again — [`Mat`] buffers regrow transparently (capacity is
//! never returned), so growing/shrinking batch shapes between rounds are
//! safe by construction (`tests/prop_engine.rs` interleaves them).

use crate::tensor::Mat;

/// A pool of reusable output `Mat`s, checked out a batch at a time.
///
/// Lifecycle: [`OutputPool::take`] hands out `count` buffers for the
/// drivers to overwrite; after the forward the same buffers are read back
/// through [`OutputPool::get`] / [`OutputPool::outputs`] until the next
/// `take`.  Shapes are whatever the driver wrote — a buffer reused at a
/// new shape is reshaped in place ([`Mat::reshape`]), reusing its
/// allocation whenever capacity allows.
pub struct OutputPool {
    mats: Vec<Mat>,
    /// buffers handed out by the most recent [`OutputPool::take`]
    live: usize,
}

impl OutputPool {
    /// Empty pool; buffers are created on first use and then reused.
    // lint: allow(alloc) reason=cold constructor: output pool starts empty and grows on first use
    pub fn new() -> OutputPool {
        OutputPool { mats: Vec::new(), live: 0 }
    }

    /// Check out `count` reusable buffers (growing the pool on first
    /// use), to be fully overwritten by the caller.  Contents left over
    /// from previous rounds are unspecified.
    pub fn take(&mut self, count: usize) -> &mut [Mat] {
        while self.mats.len() < count {
            self.mats.push(Mat::zeros(0, 0));
        }
        self.live = count;
        &mut self.mats[..count]
    }

    /// Number of buffers handed out by the most recent
    /// [`OutputPool::take`].
    pub fn len(&self) -> usize {
        self.live
    }

    /// True when no buffers are checked out.
    pub fn is_empty(&self) -> bool {
        self.live == 0
    }

    /// Output `i` of the most recent round (panics when `i` is outside
    /// it).
    pub fn get(&self, i: usize) -> &Mat {
        assert!(i < self.live, "output {i} outside the live batch ({})",
                self.live);
        &self.mats[i]
    }

    /// The most recent round's outputs, in sample order.
    pub fn outputs(&self) -> &[Mat] {
        &self.mats[..self.live]
    }
}

impl Default for OutputPool {
    fn default() -> Self {
        OutputPool::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_grows_then_reuses_and_tracks_live() {
        let mut p = OutputPool::new();
        assert!(p.is_empty());
        {
            let outs = p.take(3);
            for (i, m) in outs.iter_mut().enumerate() {
                m.reshape(2, 2);
                m.data.fill(i as f32);
            }
        }
        assert_eq!(p.len(), 3);
        assert_eq!(p.get(2).data, vec![2.0; 4]);
        // shrink: live shrinks, buffers (and their capacity) survive
        let ptr = p.get(0).data.as_ptr();
        p.take(1);
        assert_eq!(p.len(), 1);
        assert_eq!(p.get(0).data.as_ptr(), ptr);
        // regrow past the old peak
        p.take(5);
        assert_eq!(p.outputs().len(), 5);
    }

    #[test]
    #[should_panic(expected = "outside the live batch")]
    fn stale_index_rejected() {
        let mut p = OutputPool::new();
        p.take(2);
        p.take(1);
        let _ = p.get(1);
    }
}
