//! Full BERT-style serving session: token embedding + encoder +
//! classifier head, all through pooled buffers.

use std::sync::Arc;

use crate::config::TextConfig;
use crate::data::Rng;
use crate::error::Result;
use crate::model::params::MatSpan;
use crate::model::{EncoderCfg, ParamStore};
use crate::obs::{MergeTelemetry, RingWriter, Stage};

use super::head::ClassifierHead;
use super::{Engine, Session};

/// A [`Session`](super::Session) extended with the text model's
/// non-encoder stages — token + positional embedding on the way in, the
/// classifier head on the way out — so a whole tokens→logits request runs
/// through pooled buffers.  One per worker thread.
pub struct BertSession {
    ps: Arc<ParamStore>,
    session: Session,
    tcfg: TextConfig,
    tok: MatSpan,
    pos: MatSpan,
    head: ClassifierHead,
}

impl BertSession {
    // lint: allow(alloc) reason=Arc refcount clone at session construction
    pub(super) fn new(engine: &Engine, cfg: &TextConfig) -> Result<BertSession> {
        let ps = engine.params_arc();
        let session = engine.session(EncoderCfg::from_text(cfg))?;
        Ok(BertSession {
            tok: ps.mat2_span("bert.tok")?,
            pos: ps.mat2_span("bert.pos")?,
            head: ClassifierHead::resolve(&ps, "bert.head.w", "bert.head.b")?,
            ps,
            session,
            tcfg: cfg.clone(),
        })
    }

    /// The session's model config.
    pub fn cfg(&self) -> &TextConfig {
        &self.tcfg
    }

    /// Set the encoder fan-out width (see
    /// [`Session::set_workers`](super::Session::set_workers)).  This is
    /// a plain per-tower fan-out; only joint sessions get cross-tower
    /// work-stealing
    /// ([`JointSession::forward`](super::JointSession::forward)).
    pub fn set_workers(&mut self, workers: usize) {
        self.session.set_workers(workers);
    }

    /// Attach a span recorder + merge-telemetry capture (see
    /// [`Session::set_observability`](super::Session::set_observability));
    /// the classifier-head stage records through the same ring.
    pub fn set_observability(&mut self, rec: Option<RingWriter>,
                             telemetry_rows: usize) {
        self.session.set_observability(rec, telemetry_rows);
    }

    /// The attached span recorder, if any (callers use it to record
    /// model-level stages around session calls).
    pub fn recorder(&self) -> Option<&RingWriter> {
        self.session.recorder()
    }

    /// Per-layer merge telemetry captured since the last reset.
    pub fn merge_telemetry(&self) -> Option<&MergeTelemetry> {
        self.session.merge_telemetry()
    }

    /// Reset the captured merge telemetry.
    pub fn reset_merge_telemetry(&mut self) {
        self.session.reset_merge_telemetry();
    }

    /// Run the classifier head over the current outputs, recording a
    /// [`Stage::Head`] span when a recorder is attached.
    fn apply_head(&mut self) {
        let t0 = self.session.recorder().map(|r| r.now_us());
        self.head.apply(&self.ps, &self.session);
        if let Some(r) = self.session.recorder() {
            r.span_since(Stage::Head, 0, t0.unwrap_or(0),
                         self.session.batch_len() as u32);
        }
    }

    /// Start a batch of `count` sequences.
    pub fn begin(&mut self, count: usize) {
        self.session.begin(count);
    }

    /// Embed sequence `i`'s token ids into its pooled slot (token table +
    /// positional embedding, numerically identical to `embed_tokens`).
    /// Rejects a length that contradicts the config's plan and ids
    /// outside the vocabulary.
    pub fn set_tokens(&mut self, i: usize, tokens: &[i32]) -> Result<()> {
        let table = self.ps.mat_at(self.tok);
        let pos = self.ps.mat_at(self.pos);
        self.session.set_tokens(i, tokens, table, pos)
    }

    /// Run encoder + classifier head over the current batch; logits land
    /// in the pooled per-sample buffers ([`BertSession::logits`]).
    pub fn forward(&mut self, seed: u64) -> Result<()> {
        self.session.forward(seed)?;
        self.apply_head();
        Ok(())
    }

    /// Serial shared-RNG variant (the historical single-sample contract).
    pub fn forward_serial(&mut self, rng: &mut Rng) -> Result<()> {
        self.session.forward_serial(rng)?;
        self.apply_head();
        Ok(())
    }

    /// CLS feature of sequence `i` (len dim).
    pub fn features(&self, i: usize) -> &[f32] {
        self.session.output(i).row(0)
    }

    /// Class logits of sequence `i` (len num_classes).
    pub fn logits(&self, i: usize) -> &[f32] {
        self.head.logits(i)
    }

    /// Predicted class of sequence `i`.
    pub fn predict(&self, i: usize) -> usize {
        self.head.predict(i)
    }
}
