//! Shared classifier-head stage for the model sessions: one dense layer
//! over each sample's CLS feature, through pooled buffers.

use crate::error::Result;
use crate::model::params::{MatSpan, VecSpan};
use crate::model::ParamStore;
use crate::tensor::{argmax, dense_into, Mat};

use super::{OutputPool, Session};

/// The head stage [`VitSession`](super::VitSession) and
/// [`BertSession`](super::BertSession) share: resolved head weight spans
/// plus the pooled per-sample logits buffers and the (1, dim) CLS-feature
/// staging matrix.  Kept in one place so the two sessions cannot diverge.
pub(super) struct ClassifierHead {
    w: MatSpan,
    b: VecSpan,
    /// (1, dim) CLS-feature staging for the head matmul
    feat: Mat,
    /// pooled (1, num_classes) logits per sample
    logits: OutputPool,
}

impl ClassifierHead {
    /// Resolve the head tensors named `w_name` / `b_name` inside `ps`.
    pub(super) fn resolve(ps: &ParamStore, w_name: &str, b_name: &str)
                          -> Result<ClassifierHead> {
        Ok(ClassifierHead {
            w: ps.mat2_span(w_name)?,
            b: ps.vec1_span(b_name)?,
            feat: Mat::zeros(0, 0),
            logits: OutputPool::new(),
        })
    }

    /// Run the head over every sample's CLS feature in `session`, into
    /// the pooled logits buffers (allocation-free once warm).
    pub(super) fn apply(&mut self, ps: &ParamStore, session: &Session) {
        let count = session.batch_len();
        let logits = self.logits.take(count);
        let hw = ps.mat_at(self.w);
        let hb = ps.vec_at(self.b);
        for (i, lg) in logits.iter_mut().enumerate() {
            let out = session.output(i);
            self.feat.reshape(1, out.cols);
            self.feat.row_mut(0).copy_from_slice(out.row(0));
            dense_into(&self.feat, hw, Some(hb), lg);
        }
    }

    /// Class logits of sample `i` from the most recent apply.
    pub(super) fn logits(&self, i: usize) -> &[f32] {
        self.logits.get(i).row(0)
    }

    /// Predicted class of sample `i`.
    pub(super) fn predict(&self, i: usize) -> usize {
        argmax(self.logits(i))
    }
}
