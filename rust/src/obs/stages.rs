//! Span taxonomy: the named stages a request passes through on its way
//! from admission to response.
//!
//! The serving path is instrumented at every layer of the stack:
//!
//! | group      | stages |
//! |------------|--------|
//! | coordinator| [`Stage::Admission`], [`Stage::QueueWait`], [`Stage::BatchGather`], [`Stage::EdfSort`], [`Stage::Respond`] |
//! | batch exec | [`Stage::Embed`], [`Stage::Exec`], [`Stage::Head`] |
//! | per layer  | [`Stage::LayerAttention`], [`Stage::LayerGram`], [`Stage::LayerPlan`], [`Stage::LayerApply`] |
//! | gallery    | [`Stage::GalleryScan`], [`Stage::GalleryCoarse`], [`Stage::GalleryRescan`], [`Stage::GalleryMerge`] |
//!
//! Stage ids are stable `u16`s so a [`SpanEvent`](super::ring::SpanEvent)
//! stays a POD record; [`Stage::from_id`] round-trips every variant.

/// One stage of the serving pipeline (the `stage` field of a
/// [`SpanEvent`](super::ring::SpanEvent)).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u16)]
pub enum Stage {
    /// admission decision at submit (payload: 1 = admitted, 0 = shed)
    Admission = 0,
    /// a batched request's wait from enqueue to batch execution
    /// (payload: position in the executing batch)
    QueueWait = 1,
    /// the worker's timed gather + opportunistic drain window
    /// (payload: requests pending after the drain)
    BatchGather = 2,
    /// earliest-deadline-first ordering of the pending set
    /// (payload: pending-set length sorted)
    EdfSort = 3,
    /// batch-exec input staging: parse + patch/token embedding
    /// (payload: batch size)
    Embed = 4,
    /// the whole batch-execution region of one batch (payload: batch
    /// size) — also the per-request execution span in harness lanes
    Exec = 5,
    /// model head / fusion stage after the encoder (payload: batch size)
    Head = 6,
    /// response construction + channel sends (payload: batch size)
    Respond = 7,
    /// per-layer attention block (id: layer index; payload: tokens in)
    LayerAttention = 8,
    /// per-layer shared-Gram rebuild (id: layer; payload: tokens in)
    LayerGram = 9,
    /// per-layer merge-plan construction (id: layer; payload: protected
    /// count; a = energy max, b = energy mean)
    LayerPlan = 10,
    /// per-layer plan application (id: layer; payload: tokens
    /// before<<16 | tokens after; a = energy mean, b = energy p90)
    LayerApply = 11,
    /// gallery exact scan over all shards (payload: rows scored)
    GalleryScan = 12,
    /// gallery two-stage coarse centroid ranking (payload: blocks ranked)
    GalleryCoarse = 13,
    /// gallery two-stage exact block rescan (payload: blocks probed)
    GalleryRescan = 14,
    /// gallery deterministic k-way merge of shard selections
    /// (payload: k)
    GalleryMerge = 15,
}

/// Every stage, in id order (export iteration, tests).
pub const ALL_STAGES: [Stage; 16] = [
    Stage::Admission,
    Stage::QueueWait,
    Stage::BatchGather,
    Stage::EdfSort,
    Stage::Embed,
    Stage::Exec,
    Stage::Head,
    Stage::Respond,
    Stage::LayerAttention,
    Stage::LayerGram,
    Stage::LayerPlan,
    Stage::LayerApply,
    Stage::GalleryScan,
    Stage::GalleryCoarse,
    Stage::GalleryRescan,
    Stage::GalleryMerge,
];

impl Stage {
    /// Stable wire id.
    #[inline]
    pub fn id(self) -> u16 {
        self as u16
    }

    /// Inverse of [`Stage::id`] (`None` for unknown ids, so a corrupted
    /// record can never panic an exporter).
    pub fn from_id(id: u16) -> Option<Stage> {
        ALL_STAGES.get(id as usize).copied()
    }

    /// Human-readable stage name (Chrome-trace span name, Prometheus
    /// label value).
    pub fn name(self) -> &'static str {
        match self {
            Stage::Admission => "admission",
            Stage::QueueWait => "queue_wait",
            Stage::BatchGather => "batch_gather",
            Stage::EdfSort => "edf_sort",
            Stage::Embed => "embed",
            Stage::Exec => "exec",
            Stage::Head => "head",
            Stage::Respond => "respond",
            Stage::LayerAttention => "layer_attention",
            Stage::LayerGram => "layer_gram",
            Stage::LayerPlan => "layer_plan",
            Stage::LayerApply => "layer_apply",
            Stage::GalleryScan => "gallery_scan",
            Stage::GalleryCoarse => "gallery_coarse_rank",
            Stage::GalleryRescan => "gallery_block_rescan",
            Stage::GalleryMerge => "gallery_kway_merge",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ids_round_trip_and_names_are_unique() {
        let mut names = std::collections::BTreeSet::new();
        for (i, s) in ALL_STAGES.iter().enumerate() {
            assert_eq!(s.id() as usize, i);
            assert_eq!(Stage::from_id(s.id()), Some(*s));
            assert!(names.insert(s.name()), "duplicate name {}", s.name());
        }
        assert_eq!(Stage::from_id(999), None);
    }
}
