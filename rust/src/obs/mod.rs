//! Zero-allocation tracing and telemetry spine.
//!
//! Three ideas, layered:
//!
//! 1. **Recording is free-threaded and free of heap traffic.**  Each
//!    worker owns a [`RingWriter`] into its private fixed-capacity
//!    [`SpanRing`] ([`ring`]); recording a [`SpanEvent`] is a handful of
//!    relaxed atomic stores.  The warmed zero-allocation serving
//!    invariant holds **with tracing enabled** (`tests/alloc_free.rs`).
//! 2. **The taxonomy is the serving path.**  [`stages::Stage`] names
//!    every hop a request makes — admission → queue wait → batch
//!    gather/EDF sort → embed → per-layer {attention, gram, plan,
//!    apply} → head → respond — plus the gallery scan stages, so a
//!    drained trace reconstructs a request timeline end to end.
//! 3. **Exporters run elsewhere.**  [`export`] drains rings into
//!    Prometheus text exposition and Chrome trace-event JSON
//!    (Perfetto-loadable); [`merge_stats::MergeTelemetry`] captures the
//!    per-layer energy distribution for adaptive-k policies (ROADMAP
//!    item 2).
//!
//! The [`ObsHub`] is the registry: boot-time code asks it for one
//! recorder per worker (cold allocation), exporters ask it to drain
//! everything.

pub mod export;
pub mod merge_stats;
pub mod ring;
pub mod stages;

pub use merge_stats::{energy_summary, MergeLayerStats, MergeTelemetry};
pub use ring::{RingWriter, SpanEvent, SpanRing};
pub use stages::{Stage, ALL_STAGES};

use std::sync::{Arc, Mutex};
use std::time::Instant;

/// One drained ring: the worker name, its events in record order, and
/// how many events the ring discarded while full.
pub struct TraceThread {
    /// ring/worker name (Chrome trace thread name)
    pub name: String,
    /// drained events
    pub events: Vec<SpanEvent>,
    /// events discarded because the ring was full
    pub dropped: u64,
}

/// Process-wide observability registry: one epoch, one span ring per
/// registered worker.  Workers call [`ObsHub::recorder`] once at boot;
/// exporters call [`ObsHub::drain`] whenever they want a trace.  The
/// registry `Mutex` is touched only at boot and drain time — never on
/// the record path.
pub struct ObsHub {
    epoch: Instant,
    ring_capacity: usize,
    rings: Mutex<Vec<(String, Arc<SpanRing>)>>,
}

impl ObsHub {
    /// A hub whose per-worker rings hold `ring_capacity` events each.
    // lint: allow(alloc) reason=cold constructor: registry built once per process
    pub fn new(ring_capacity: usize) -> Arc<ObsHub> {
        Arc::new(ObsHub {
            epoch: Instant::now(),
            ring_capacity,
            rings: Mutex::new(Vec::new()),
        })
    }

    /// The instant all span timestamps are relative to.
    pub fn epoch(&self) -> Instant {
        self.epoch
    }

    /// Microseconds elapsed since the epoch.
    pub fn now_us(&self) -> u64 {
        self.epoch.elapsed().as_micros() as u64
    }

    /// Register a new ring under `name` and return its preallocated
    /// writer (cold: called once per worker at boot).
    // lint: allow(alloc) reason=cold boot path: ring allocation + registry push happen once per worker
    pub fn recorder(&self, name: &str) -> RingWriter {
        let ring = SpanRing::with_capacity(self.ring_capacity);
        self.rings.lock().unwrap().push((name.to_string(), ring.clone()));
        ring.writer(self.epoch)
    }

    /// Drain every registered ring (exporter side; events buffered since
    /// the previous drain, plus each ring's cumulative drop count).
    // lint: allow(alloc) reason=cold exporter path: drain buffers grow off the hot path
    pub fn drain(&self) -> Vec<TraceThread> {
        let rings = self.rings.lock().unwrap();
        let mut out = Vec::with_capacity(rings.len());
        for (name, ring) in rings.iter() {
            let mut events = Vec::new();
            ring.drain_into(&mut events);
            out.push(TraceThread {
                name: name.clone(),
                events,
                dropped: ring.dropped(),
            });
        }
        out
    }

    /// Total events dropped across every ring (visibility for truncated
    /// traces).
    pub fn dropped_total(&self) -> u64 {
        self.rings.lock().unwrap().iter().map(|(_, r)| r.dropped()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Several workers record into their own rings concurrently; one
    /// drain sees every event exactly once, attributed to the right
    /// ring.
    #[test]
    fn multi_worker_record_and_drain_is_consistent() {
        let hub = ObsHub::new(1024);
        let mut handles = Vec::new();
        for w in 0..4u64 {
            let rec = hub.recorder(&format!("worker-{w}"));
            handles.push(std::thread::spawn(move || {
                for i in 0..200u64 {
                    let t0 = rec.now_us();
                    assert!(rec.record(SpanEvent {
                        stage: Stage::Exec,
                        id: w * 1000 + i,
                        t_start_us: t0,
                        t_end_us: rec.now_us(),
                        payload: i as u32,
                        a: 0.0,
                        b: 0.0,
                    }));
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let threads = hub.drain();
        assert_eq!(threads.len(), 4);
        for t in &threads {
            assert_eq!(t.events.len(), 200, "ring {}", t.name);
            assert_eq!(t.dropped, 0);
            let w: u64 = t.name.strip_prefix("worker-").unwrap()
                .parse().unwrap();
            for (i, e) in t.events.iter().enumerate() {
                assert_eq!(e.id, w * 1000 + i as u64);
                assert!(e.t_end_us >= e.t_start_us);
            }
        }
        assert_eq!(hub.dropped_total(), 0);
        // a second drain is empty (cursors advanced)
        assert!(hub.drain().iter().all(|t| t.events.is_empty()));
    }
}
