//! Exporters — everything here is **off the hot path**: rings are
//! drained and formatted by whoever asks for a report (the `pitome
//! serve` periodic dump, `pitome loadtest --trace-out`, the load
//! harness), never by the workers that record.
//!
//! Two formats:
//! * [`prometheus_text`] — Prometheus text exposition of every worker's
//!   [`Snapshot`] (the counters `Metrics::snapshot` already aggregates),
//!   labelled by workload/model/artifact.
//! * [`chrome_trace_json`] / [`write_chrome_trace`] — Chrome trace-event
//!   JSON (the `[{"ph":"X",...}]` array format) built from drained span
//!   rings; load the file in Perfetto / `chrome://tracing` to see each
//!   request's admission→respond timeline with per-layer merge stats in
//!   the span args.

use std::fmt::Write as _;
use std::io::Write as _;
use std::path::Path;

use crate::coordinator::metrics::Snapshot;
use crate::coordinator::request::Workload;

use super::TraceThread;

/// Stable lowercase label for a workload.
fn workload_label(w: Workload) -> &'static str {
    match w {
        Workload::Vision => "vision",
        Workload::Text => "text",
        Workload::Joint => "joint",
        Workload::Gallery => "gallery",
    }
}

/// Append one labelled sample line.
fn sample(out: &mut String, metric: &str, labels: &str, v: f64) {
    let _ = writeln!(out, "{metric}{{{labels}}} {v}");
}

/// Render every variant's [`Snapshot`] as Prometheus text exposition
/// (`# HELP`/`# TYPE` headers once per metric, one labelled sample per
/// variant).  Input is exactly what `Coordinator::metrics_typed`
/// returns.
// lint: allow(alloc) reason=cold exporter: text exposition is built off the hot path
pub fn prometheus_text(entries: &[(Workload, String, String, Snapshot)])
                       -> String {
    let mut out = String::new();
    let metrics: [(&str, &str, &str); 12] = [
        ("pitome_requests_total", "counter", "completed requests"),
        ("pitome_latency_us", "gauge",
         "end-to-end latency, microseconds (mean/p50/p99/p999/max in the \
          quantile label)"),
        ("pitome_batch_mean_requests", "gauge", "mean requests per batch"),
        ("pitome_shed_total", "counter",
         "requests refused at admission (queue full)"),
        ("pitome_expired_total", "counter",
         "admitted requests dropped after their deadline passed"),
        ("pitome_responses_recycled_total", "counter",
         "responses served from a recycled pool buffer"),
        ("pitome_responses_fresh_total", "counter",
         "responses that allocated a fresh buffer"),
        ("pitome_last_cycle_allocs", "gauge",
         "heap allocations in the most recent whole batch cycle"),
        ("pitome_gallery_len", "gauge", "embeddings resident in the gallery"),
        ("pitome_gallery_scanned_rows_total", "counter",
         "gallery rows scored by query scans"),
        ("pitome_gallery_evictions_total", "counter",
         "gallery top-k heap evictions"),
        ("pitome_gallery_scan_us_total", "counter",
         "microseconds spent in gallery scans"),
    ];
    for (name, kind, help) in metrics {
        let _ = writeln!(out, "# HELP {name} {help}");
        let _ = writeln!(out, "# TYPE {name} {kind}");
        for (w, model, artifact, s) in entries {
            let labels = format!(
                "workload=\"{}\",model=\"{}\",artifact=\"{}\"",
                workload_label(*w), model, artifact);
            match name {
                "pitome_requests_total" => {
                    sample(&mut out, name, &labels, s.count as f64)
                }
                "pitome_latency_us" => {
                    for (q, v) in [("mean", s.mean_us),
                                   ("p50", s.p50_us as f64),
                                   ("p99", s.p99_us as f64),
                                   ("p999", s.p999_us as f64),
                                   ("max", s.max_us as f64)] {
                        sample(&mut out, name,
                               &format!("{labels},quantile=\"{q}\""), v);
                    }
                }
                "pitome_batch_mean_requests" => {
                    sample(&mut out, name, &labels, s.mean_batch)
                }
                "pitome_shed_total" => {
                    sample(&mut out, name, &labels, s.shed as f64)
                }
                "pitome_expired_total" => {
                    sample(&mut out, name, &labels, s.expired as f64)
                }
                "pitome_responses_recycled_total" => {
                    sample(&mut out, name, &labels, s.resp_recycled as f64)
                }
                "pitome_responses_fresh_total" => {
                    sample(&mut out, name, &labels, s.resp_fresh as f64)
                }
                "pitome_last_cycle_allocs" => {
                    sample(&mut out, name, &labels, s.last_cycle_allocs as f64)
                }
                "pitome_gallery_len" => {
                    sample(&mut out, name, &labels, s.gallery_len as f64)
                }
                "pitome_gallery_scanned_rows_total" => {
                    sample(&mut out, name, &labels,
                           s.gallery_scanned_rows as f64)
                }
                "pitome_gallery_evictions_total" => {
                    sample(&mut out, name, &labels, s.gallery_evictions as f64)
                }
                "pitome_gallery_scan_us_total" => {
                    sample(&mut out, name, &labels, s.gallery_scan_us as f64)
                }
                _ => unreachable!("metric {name} not rendered"),
            }
        }
    }
    out
}

/// Escape a string for a JSON literal (worker names are plain ASCII,
/// but a malformed name must corrupt nothing).
fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out
}

/// A float that is always valid JSON (NaN/inf become 0).
fn json_f32(v: f32) -> f32 {
    if v.is_finite() {
        v
    } else {
        0.0
    }
}

/// Render drained span rings as Chrome trace-event JSON: one `"X"`
/// (complete) event per span, one trace thread per ring, with the
/// stage-specific `id`/`payload`/`a`/`b` fields in `args` — per-layer
/// merge spans carry tokens before/after and the energy summary there.
/// Rings that dropped events get a visible `spans_dropped` instant
/// event so a truncated timeline never masquerades as complete.
// lint: allow(alloc) reason=cold exporter: the JSON string is built off the hot path
pub fn chrome_trace_json(threads: &[TraceThread]) -> String {
    let mut out = String::from("{\"traceEvents\":[");
    let mut first = true;
    let mut push = |out: &mut String, ev: String| {
        if !first {
            out.push(',');
        }
        first = false;
        out.push_str(&ev);
    };
    for (tid, t) in threads.iter().enumerate() {
        push(&mut out, format!(
            "{{\"name\":\"thread_name\",\"ph\":\"M\",\"pid\":1,\
             \"tid\":{},\"args\":{{\"name\":\"{}\"}}}}",
            tid, json_escape(&t.name)));
        for e in &t.events {
            let dur = e.t_end_us.saturating_sub(e.t_start_us);
            push(&mut out, format!(
                "{{\"name\":\"{}\",\"cat\":\"pitome\",\"ph\":\"X\",\
                 \"ts\":{},\"dur\":{},\"pid\":1,\"tid\":{},\
                 \"args\":{{\"id\":{},\"payload\":{},\"a\":{},\"b\":{}}}}}",
                e.stage.name(), e.t_start_us, dur, tid, e.id, e.payload,
                json_f32(e.a), json_f32(e.b)));
        }
        if t.dropped > 0 {
            push(&mut out, format!(
                "{{\"name\":\"spans_dropped\",\"cat\":\"pitome\",\
                 \"ph\":\"i\",\"ts\":0,\"s\":\"t\",\"pid\":1,\"tid\":{},\
                 \"args\":{{\"dropped\":{}}}}}",
                tid, t.dropped));
        }
    }
    out.push_str("],\"displayTimeUnit\":\"ms\"}");
    out
}

/// Write [`chrome_trace_json`] to `path`.
// lint: allow(alloc) reason=cold exporter: file write happens off the hot path
pub fn write_chrome_trace(path: &Path, threads: &[TraceThread])
                          -> std::io::Result<()> {
    let mut f = std::fs::File::create(path)?;
    f.write_all(chrome_trace_json(threads).as_bytes())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::ring::SpanEvent;
    use crate::obs::stages::Stage;
    use crate::util::parse_json;

    fn snap() -> Snapshot {
        Snapshot {
            count: 10,
            mean_us: 1234.5,
            p50_us: 1000,
            p99_us: 4000,
            p999_us: 5000,
            max_us: 6000,
            mean_batch: 2.5,
            last_infer_allocs: 0,
            last_cycle_allocs: 0,
            resp_recycled: 9,
            resp_fresh: 1,
            shed: 2,
            expired: 1,
            gallery_len: 0,
            gallery_scanned_rows: 0,
            gallery_evictions: 0,
            gallery_scan_us: 0,
        }
    }

    #[test]
    fn prometheus_exposition_has_headers_and_labelled_samples() {
        let entries = vec![
            (Workload::Vision, "default".to_string(), "cpu_pitome_r900"
                 .to_string(), snap()),
        ];
        let text = prometheus_text(&entries);
        assert!(text.contains("# TYPE pitome_requests_total counter"));
        assert!(text.contains(
            "pitome_requests_total{workload=\"vision\",model=\"default\",\
             artifact=\"cpu_pitome_r900\"} 10"));
        assert!(text.contains("quantile=\"p99\"} 4000"));
        assert!(text.contains("pitome_shed_total{"));
        // every sample line is parseable: metric{labels} value
        for line in text.lines().filter(|l| !l.starts_with('#')) {
            assert!(line.contains('{') && line.contains("} "), "{line}");
        }
    }

    #[test]
    fn chrome_trace_is_valid_json_with_thread_names_and_drops() {
        let threads = vec![TraceThread {
            name: "pitome-cpu-\"x\"".to_string(),
            events: vec![SpanEvent {
                stage: Stage::LayerApply,
                id: 3,
                t_start_us: 100,
                t_end_us: 150,
                payload: (65 << 16) | 59,
                a: 0.5,
                b: f32::NAN,
            }],
            dropped: 7,
        }];
        let json = chrome_trace_json(&threads);
        let v = parse_json(&json).expect("trace JSON must parse");
        let events = v.get("traceEvents").and_then(|e| e.arr())
            .expect("traceEvents array");
        assert_eq!(events.len(), 3, "metadata + span + drop marker");
        let span = &events[1];
        assert_eq!(span.get("name").and_then(|n| n.str()),
                   Some("layer_apply"));
        assert_eq!(span.get("dur").and_then(|d| d.num()), Some(50.0));
        assert_eq!(events[2].get("args").and_then(|a| a.get("dropped"))
                       .and_then(|d| d.num()),
                   Some(7.0));
    }
}
