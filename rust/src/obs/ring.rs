//! Per-worker fixed-capacity SPSC ring buffer of POD span events.
//!
//! The hot-path contract: [`RingWriter::record`] performs **no locks and
//! no heap activity** — a record is five relaxed atomic stores plus one
//! release store of the write cursor into slots allocated once at
//! construction, so recording cannot break the warmed zero-allocation
//! invariant (`tests/alloc_free.rs` runs the serving cycles with tracing
//! enabled).
//!
//! **Overflow semantics are drop-newest**: when the ring is full the
//! incoming event is discarded and the `dropped` counter increments —
//! never an overwrite of unread history, never a block, never an
//! allocation.  Exporters read [`SpanRing::dropped`] and say so, instead
//! of silently presenting a truncated timeline as complete.
//!
//! Single producer at a time: exactly one execution context may hold the
//! ring's [`RingWriter`].  Producer ownership may migrate between threads
//! across a happens-before edge (the encoder fan-out's scoped-thread join
//! is one), which the release/acquire cursor protocol supports; two
//! threads recording *concurrently* to one ring is a contract violation
//! (events could collide in a slot) — give each concurrent context its
//! own ring.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

use super::stages::Stage;

/// One POD span record: stage id, request/batch id, microsecond start
/// and end timestamps (relative to the owning hub's epoch), a `u32`
/// payload and two stage-specific `f32`s (see [`Stage`] for the
/// per-stage meaning of `payload`/`a`/`b`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SpanEvent {
    /// pipeline stage
    pub stage: Stage,
    /// request id, batch id, or layer index — stage-dependent
    pub id: u64,
    /// span start, microseconds since the hub epoch
    pub t_start_us: u64,
    /// span end, microseconds since the hub epoch
    pub t_end_us: u64,
    /// stage-specific integer payload
    pub payload: u32,
    /// stage-specific float (e.g. energy mean)
    pub a: f32,
    /// stage-specific float (e.g. energy p90)
    pub b: f32,
}

/// One ring slot: the five words of a [`SpanEvent`], individually
/// atomic.  Slot contents are published by the release store of the
/// write cursor and consumed after its acquire load, so the relaxed
/// per-word accesses can never be observed half-written.
#[derive(Default)]
struct Slot {
    /// stage id (low 16 bits) | payload (high 32 bits)
    w0: AtomicU64,
    id: AtomicU64,
    t_start: AtomicU64,
    t_end: AtomicU64,
    /// a.to_bits() (low 32) | b.to_bits() (high 32)
    ab: AtomicU64,
}

/// Fixed-capacity single-producer/single-consumer span ring.
///
/// Construct via [`SpanRing::with_capacity`]; hand the producer side to
/// the worker as a [`RingWriter`] and drain from any one consumer via
/// [`SpanRing::drain_into`].
pub struct SpanRing {
    slots: Box<[Slot]>,
    mask: u64,
    /// next write position (monotonic; slot = head & mask)
    head: AtomicU64,
    /// next read position (monotonic)
    tail: AtomicU64,
    /// events discarded because the ring was full (drop-newest)
    dropped: AtomicU64,
}

impl SpanRing {
    /// A ring holding at least `capacity` events (rounded up to a power
    /// of two, minimum 2).  The only allocation the ring ever performs.
    // lint: allow(alloc) reason=cold constructor: slots allocated once, recording never allocates
    pub fn with_capacity(capacity: usize) -> Arc<SpanRing> {
        let cap = capacity.max(2).next_power_of_two();
        let slots: Vec<Slot> = (0..cap).map(|_| Slot::default()).collect();
        Arc::new(SpanRing {
            slots: slots.into_boxed_slice(),
            mask: (cap - 1) as u64,
            head: AtomicU64::new(0),
            tail: AtomicU64::new(0),
            dropped: AtomicU64::new(0),
        })
    }

    /// Slot count.
    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Events currently buffered (racy by nature; exact when producer
    /// and consumer are quiescent).
    pub fn len(&self) -> usize {
        let head = self.head.load(Ordering::Acquire);
        let tail = self.tail.load(Ordering::Acquire);
        head.wrapping_sub(tail) as usize
    }

    /// Whether the ring holds no events.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Events discarded so far because the ring was full.
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    /// The preallocated producer handle (clone of the shared ring plus
    /// the timestamp epoch).  One live writer per ring — see the module
    /// docs for the single-producer contract.
    // lint: allow(alloc) reason=cold setup: Arc refcount clone at worker boot
    pub fn writer(self: &Arc<Self>, epoch: Instant) -> RingWriter {
        RingWriter { ring: self.clone(), epoch }
    }

    /// Producer-side record (called through [`RingWriter`]).  Lock-free,
    /// allocation-free; drops the event (counted) when the ring is full.
    fn push(&self, ev: &SpanEvent) -> bool {
        let head = self.head.load(Ordering::Relaxed);
        let tail = self.tail.load(Ordering::Acquire);
        if head.wrapping_sub(tail) >= self.slots.len() as u64 {
            self.dropped.fetch_add(1, Ordering::Relaxed);
            return false;
        }
        let slot = &self.slots[(head & self.mask) as usize];
        let w0 = ev.stage.id() as u64 | ((ev.payload as u64) << 32);
        let ab = ev.a.to_bits() as u64 | ((ev.b.to_bits() as u64) << 32);
        slot.w0.store(w0, Ordering::Relaxed);
        slot.id.store(ev.id, Ordering::Relaxed);
        slot.t_start.store(ev.t_start_us, Ordering::Relaxed);
        slot.t_end.store(ev.t_end_us, Ordering::Relaxed);
        slot.ab.store(ab, Ordering::Relaxed);
        // publish: the consumer's acquire load of head orders the slot
        // words above before any read of them
        self.head.store(head.wrapping_add(1), Ordering::Release);
        true
    }

    /// Consumer-side drain: append every buffered event to `out` in
    /// record order and advance the read cursor.  Returns the number of
    /// events drained.  Off the hot path — `out` may grow.
    // lint: allow(alloc) reason=cold exporter path: the output vector grows off the hot path
    pub fn drain_into(&self, out: &mut Vec<SpanEvent>) -> usize {
        let head = self.head.load(Ordering::Acquire);
        let tail = self.tail.load(Ordering::Relaxed);
        let n = head.wrapping_sub(tail);
        for i in 0..n {
            let slot = &self.slots[((tail.wrapping_add(i)) & self.mask) as usize];
            let w0 = slot.w0.load(Ordering::Relaxed);
            let ab = slot.ab.load(Ordering::Relaxed);
            let stage = match Stage::from_id((w0 & 0xFFFF) as u16) {
                Some(s) => s,
                // unreachable with a conforming producer; skip rather
                // than panic the exporter
                None => continue,
            };
            out.push(SpanEvent {
                stage,
                id: slot.id.load(Ordering::Relaxed),
                t_start_us: slot.t_start.load(Ordering::Relaxed),
                t_end_us: slot.t_end.load(Ordering::Relaxed),
                payload: (w0 >> 32) as u32,
                a: f32::from_bits((ab & 0xFFFF_FFFF) as u32),
                b: f32::from_bits((ab >> 32) as u32),
            });
        }
        // release: the producer's acquire load of tail sees the slot
        // reads above as complete before reusing the slots
        self.tail.store(head, Ordering::Release);
        n as usize
    }
}

/// The preallocated producer handle a worker records through: the shared
/// ring plus the hub epoch for `Instant` → µs conversion.  Cloning is a
/// refcount bump (cold setup only); see the module docs for the
/// single-producer contract.
#[derive(Clone)]
pub struct RingWriter {
    ring: Arc<SpanRing>,
    epoch: Instant,
}

impl RingWriter {
    /// Microseconds elapsed since the hub epoch.
    #[inline]
    pub fn now_us(&self) -> u64 {
        self.epoch.elapsed().as_micros() as u64
    }

    /// Convert an instant captured elsewhere (e.g. a request's
    /// `enqueued_at`) to the hub timebase (0 for pre-epoch instants).
    #[inline]
    pub fn us_of(&self, t: Instant) -> u64 {
        t.saturating_duration_since(self.epoch).as_micros() as u64
    }

    /// Record one event.  Returns `false` (and counts the drop) when the
    /// ring is full.
    #[inline]
    pub fn record(&self, ev: SpanEvent) -> bool {
        self.ring.push(&ev)
    }

    /// Record a span that started at `t_start_us` and ends now.
    #[inline]
    pub fn span_since(&self, stage: Stage, id: u64, t_start_us: u64,
                      payload: u32) -> bool {
        self.record(SpanEvent {
            stage,
            id,
            t_start_us,
            t_end_us: self.now_us(),
            payload,
            a: 0.0,
            b: 0.0,
        })
    }

    /// The ring this writer feeds (drop-counter checks in tests).
    pub fn ring(&self) -> &Arc<SpanRing> {
        &self.ring
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(stage: Stage, id: u64) -> SpanEvent {
        SpanEvent {
            stage,
            id,
            t_start_us: id * 10,
            t_end_us: id * 10 + 5,
            payload: id as u32,
            a: id as f32 * 0.5,
            b: id as f32 * 2.0,
        }
    }

    #[test]
    fn events_round_trip_in_order() {
        let ring = SpanRing::with_capacity(8);
        let w = ring.writer(Instant::now());
        for i in 0..5 {
            assert!(w.record(ev(Stage::Embed, i)));
        }
        let mut out = Vec::new();
        assert_eq!(ring.drain_into(&mut out), 5);
        for (i, e) in out.iter().enumerate() {
            assert_eq!(*e, ev(Stage::Embed, i as u64));
        }
        assert_eq!(ring.dropped(), 0);
    }

    /// Overflow drops the *newest* event (the incoming one), never
    /// overwrites unread history, and counts every drop.
    #[test]
    fn full_ring_drops_newest_and_counts() {
        let ring = SpanRing::with_capacity(4);
        let w = ring.writer(Instant::now());
        for i in 0..4 {
            assert!(w.record(ev(Stage::Exec, i)));
        }
        // ring full: these are discarded, history is intact
        assert!(!w.record(ev(Stage::Exec, 100)));
        assert!(!w.record(ev(Stage::Exec, 101)));
        assert_eq!(ring.dropped(), 2);
        let mut out = Vec::new();
        assert_eq!(ring.drain_into(&mut out), 4);
        let ids: Vec<u64> = out.iter().map(|e| e.id).collect();
        assert_eq!(ids, vec![0, 1, 2, 3], "unread history must survive");
        // drained: capacity is available again
        assert!(w.record(ev(Stage::Exec, 200)));
        assert_eq!(ring.dropped(), 2, "drop counter is cumulative");
    }

    /// The cursor protocol survives many wraps of the (small) slot
    /// array: a billion-event session differs from a fresh ring only in
    /// the monotonic cursors.
    #[test]
    fn wraparound_preserves_fifo_across_many_generations() {
        let ring = SpanRing::with_capacity(4);
        let w = ring.writer(Instant::now());
        let mut out = Vec::new();
        let mut expect = 0u64;
        for round in 0..64u64 {
            let n = 1 + (round % 4);
            for i in 0..n {
                assert!(w.record(ev(Stage::QueueWait, round * 100 + i)));
            }
            out.clear();
            assert_eq!(ring.drain_into(&mut out), n as usize);
            for (i, e) in out.iter().enumerate() {
                assert_eq!(e.id, round * 100 + i as u64);
            }
            expect += n;
        }
        assert_eq!(ring.dropped(), 0);
        assert!(expect > 2 * ring.capacity() as u64);
    }

    /// Producer on one thread, consumer on another: every recorded event
    /// is drained exactly once, in order, and accepted+dropped adds up.
    #[test]
    fn concurrent_producer_consumer_is_consistent() {
        let ring = SpanRing::with_capacity(16);
        let w = ring.writer(Instant::now());
        const N: u64 = 10_000;
        let producer = std::thread::spawn(move || {
            let mut accepted = 0u64;
            for i in 0..N {
                if w.record(ev(Stage::Head, i)) {
                    accepted += 1;
                }
            }
            accepted
        });
        let mut seen: Vec<u64> = Vec::new();
        let mut out = Vec::new();
        while seen.len() < N as usize {
            out.clear();
            ring.drain_into(&mut out);
            seen.extend(out.iter().map(|e| e.id));
            if producer.is_finished() && ring.is_empty() {
                out.clear();
                ring.drain_into(&mut out);
                seen.extend(out.iter().map(|e| e.id));
                break;
            }
        }
        let accepted = producer.join().unwrap();
        assert_eq!(accepted + ring.dropped(), N,
                   "every event is either drained or counted as dropped");
        assert_eq!(seen.len() as u64, accepted);
        // drained ids are a strictly increasing subsequence of 0..N
        for w2 in seen.windows(2) {
            assert!(w2[0] < w2[1], "drain must preserve record order");
        }
    }
}
