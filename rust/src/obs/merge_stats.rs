//! Per-layer merge telemetry: the energy distribution the paper's
//! spectrum-preservation argument rests on, captured as an observable.
//!
//! Every merge step already computes the energy score of Eq. 4 for every
//! token and then discards it.  [`MergeTelemetry`] is a caller-owned,
//! fixed-capacity buffer that `merge_step_scratch` fills with one
//! [`MergeLayerStats`] row per step — tokens before/after, protected
//! count, and the energy mean/max/p90 — so adaptive-k policies (ROADMAP
//! item 2) and the trace exporters can see *why* a layer merged hard or
//! held back.
//!
//! The p90 is computed **streaming, without sorting**: one pass for
//! min/max/mean, one pass binning into a fixed histogram, then linear
//! interpolation inside the p90 bucket.  No allocation, no reordering of
//! the (scratch-owned) energy buffer.

/// Number of fixed histogram bins for the streaming p90.  64 bins over
/// the observed [min, max] keep the interpolation error well under the
/// spread of real energy distributions while the bin array stays a
/// stack-friendly 512 bytes.
const ENERGY_BINS: usize = 64;

/// One merge step's telemetry row.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct MergeLayerStats {
    /// encoder layer index of this merge step
    pub layer: u32,
    /// tokens entering the step
    pub tokens_before: u32,
    /// tokens after the plan applied
    pub tokens_after: u32,
    /// tokens protected from merging (CLS + any protected prefix)
    pub protected: u32,
    /// mean energy score across the step's tokens
    pub energy_mean: f32,
    /// max energy score
    pub energy_max: f32,
    /// 90th-percentile energy score (streaming histogram estimate)
    pub energy_p90: f32,
}

/// Summarize an energy slice without sorting or allocating: two passes
/// (min/max/mean, then a fixed-bin histogram) and an interpolated p90.
/// Returns `(mean, max, p90)`; all zeros for an empty slice.
pub fn energy_summary(energy: &[f32]) -> (f32, f32, f32) {
    if energy.is_empty() {
        return (0.0, 0.0, 0.0);
    }
    let (mut lo, mut hi, mut sum) = (f32::INFINITY, f32::NEG_INFINITY, 0.0f64);
    let mut n = 0u32;
    for &e in energy {
        if !e.is_finite() {
            continue;
        }
        lo = lo.min(e);
        hi = hi.max(e);
        sum += e as f64;
        n += 1;
    }
    if n == 0 {
        return (0.0, 0.0, 0.0);
    }
    let mean = (sum / n as f64) as f32;
    if hi <= lo {
        // degenerate distribution: every finite score identical
        return (mean, hi, hi);
    }
    let mut bins = [0u32; ENERGY_BINS];
    let scale = ENERGY_BINS as f32 / (hi - lo);
    for &e in energy {
        if !e.is_finite() {
            continue;
        }
        let b = (((e - lo) * scale) as usize).min(ENERGY_BINS - 1);
        bins[b] += 1;
    }
    let target = (n as f64 * 0.9).ceil() as u32;
    let mut acc = 0u32;
    for (i, &c) in bins.iter().enumerate() {
        if acc + c >= target {
            // linear interpolation inside the winning bin
            let frac = if c > 0 {
                (target - acc) as f32 / c as f32
            } else {
                0.0
            };
            let bin_lo = lo + i as f32 / scale;
            let p90 = bin_lo + frac / scale;
            return (mean, hi, p90.min(hi));
        }
        acc += c;
    }
    (mean, hi, hi)
}

/// Caller-owned per-layer merge telemetry buffer.
///
/// Disabled (zero-capacity) by default so the merge engine pays two
/// branch checks per step when nobody is listening.  Enable with
/// [`MergeTelemetry::enable`] (the only allocation); rows past capacity
/// are dropped and counted, mirroring the span-ring semantics.
#[derive(Default)]
pub struct MergeTelemetry {
    rows: Vec<MergeLayerStats>,
    capacity: usize,
    /// rows discarded because the buffer was full
    dropped: u64,
    /// layer index the owner stamps before each merge step
    cur_layer: u32,
}

impl MergeTelemetry {
    /// Enable capture with room for `rows` entries (one per merge step;
    /// size as `depth × max batch` for a serving worker).  Idempotent;
    /// growing re-allocates (cold path).
    // lint: allow(alloc) reason=cold setup: the row buffer is allocated once at enable time
    pub fn enable(&mut self, rows: usize) {
        self.capacity = rows;
        if self.rows.capacity() < rows {
            self.rows.reserve(rows.saturating_sub(self.rows.len()));
        }
    }

    /// Whether capture is enabled.
    #[inline]
    pub fn enabled(&self) -> bool {
        self.capacity > 0
    }

    /// Forget captured rows (start of a batch); capacity is retained.
    pub fn reset(&mut self) {
        self.rows.clear();
        self.dropped = 0;
    }

    /// Stamp the layer index for subsequent [`MergeTelemetry::push`]
    /// calls (the encoder loop sets this; the merge engine doesn't know
    /// its layer).
    #[inline]
    pub fn set_layer(&mut self, layer: u32) {
        self.cur_layer = layer;
    }

    /// The stamped layer index.
    #[inline]
    pub fn layer(&self) -> u32 {
        self.cur_layer
    }

    /// Append one row (no-op when disabled; dropped + counted when
    /// full).  Never allocates once enabled.
    #[inline]
    pub fn push(&mut self, mut row: MergeLayerStats) {
        if self.capacity == 0 {
            return;
        }
        if self.rows.len() >= self.capacity {
            self.dropped += 1;
            return;
        }
        row.layer = self.cur_layer;
        self.rows.push(row);
    }

    /// Captured rows since the last reset, in merge-step order.
    pub fn rows(&self) -> &[MergeLayerStats] {
        &self.rows
    }

    /// Rows discarded since the last reset because the buffer was full.
    pub fn dropped(&self) -> u64 {
        self.dropped
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_of_uniform_ramp_matches_closed_form() {
        // 0, 1, ..., 999: mean 499.5, max 999, p90 ≈ 900
        let e: Vec<f32> = (0..1000).map(|i| i as f32).collect();
        let (mean, max, p90) = energy_summary(&e);
        assert!((mean - 499.5).abs() < 1e-3, "mean {mean}");
        assert_eq!(max, 999.0);
        assert!((p90 - 900.0).abs() < 1000.0 / ENERGY_BINS as f32,
                "p90 {p90} not within one bin of 900");
    }

    #[test]
    fn summary_handles_empty_constant_and_nan() {
        assert_eq!(energy_summary(&[]), (0.0, 0.0, 0.0));
        let (mean, max, p90) = energy_summary(&[2.5; 17]);
        assert_eq!((mean, max, p90), (2.5, 2.5, 2.5));
        let (mean, max, p90) = energy_summary(&[1.0, f32::NAN, 3.0]);
        assert_eq!(max, 3.0);
        assert!((mean - 2.0).abs() < 1e-6);
        assert!(p90 <= 3.0 && p90 >= 1.0);
    }

    #[test]
    fn disabled_buffer_ignores_rows_and_full_buffer_counts_drops() {
        let mut t = MergeTelemetry::default();
        t.push(MergeLayerStats::default());
        assert!(t.rows().is_empty());
        assert_eq!(t.dropped(), 0);
        t.enable(2);
        t.set_layer(3);
        t.push(MergeLayerStats { tokens_before: 10, ..Default::default() });
        t.set_layer(4);
        t.push(MergeLayerStats { tokens_before: 8, ..Default::default() });
        t.push(MergeLayerStats::default()); // full: dropped
        assert_eq!(t.rows().len(), 2);
        assert_eq!(t.dropped(), 1);
        assert_eq!(t.rows()[0].layer, 3);
        assert_eq!(t.rows()[1].layer, 4);
        t.reset();
        assert!(t.rows().is_empty());
        assert_eq!(t.dropped(), 0);
        assert!(t.enabled());
    }
}
