//! # pitome — Spectrum-Preserving Token Merging, as a serving/training stack
//!
//! Production-oriented reproduction of *"Accelerating Transformers with
//! Spectrum-Preserving Token Merging"* (Tran, Nguyen et al., NeurIPS 2024).
//!
//! Three layers (see `DESIGN.md`):
//! - **L1** Pallas kernels (energy score, proportional attention) and
//! - **L2** JAX models live in `python/compile/` and are AOT-lowered to HLO
//!   text artifacts at build time (`make artifacts`);
//! - **L3** (this crate) is the runtime: a PJRT executor over those
//!   artifacts, a serving coordinator (router + dynamic batcher), a full
//!   pure-Rust implementation of PiToMe and every baseline merge algorithm,
//!   the spectral-graph toolkit used to validate Theorem 1, synthetic
//!   workload generators, and the benchmark harness that regenerates every
//!   table/figure of the paper.
//!
//! Python never runs on the request path: after `make artifacts` the crate
//! is self-contained.

pub mod config;
pub mod coordinator;
pub mod data;
pub mod engine;
pub mod error;
pub mod eval;
pub mod gallery;
pub mod graph;
pub mod merge;
pub mod model;
pub mod obs;
pub mod runtime;
pub mod tensor;
pub mod util;

pub use error::{Error, Result};
