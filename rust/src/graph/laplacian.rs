//! Graph Laplacians (Definition 1 of the paper).

use crate::tensor::Mat;

/// Node degrees `d_i = sum_j W[i,j]`.
pub fn degree_vector(w: &Mat) -> Vec<f32> {
    (0..w.rows).map(|i| w.row(i).iter().sum()).collect()
}

/// Combinatorial Laplacian `L = D - W`.
pub fn combinatorial_laplacian(w: &Mat) -> Mat {
    let d = degree_vector(w);
    Mat::from_fn(w.rows, w.cols, |i, j| {
        if i == j { d[i] - w.get(i, j) } else { -w.get(i, j) }
    })
}

/// Normalized Laplacian `L = I - D^{-1/2} W D^{-1/2}` (zero-degree nodes
/// contribute identity rows).  Allocating wrapper over
/// [`normalized_laplacian_into`].
pub fn normalized_laplacian(w: &Mat) -> Mat {
    let mut dinv = Vec::new();
    let mut out = Mat::zeros(0, 0);
    normalized_laplacian_into(w, &mut dinv, &mut out);
    out
}

/// [`normalized_laplacian`] into reusable buffers: `dinv` holds the
/// `D^{-1/2}` diagonal scratch, `out` the Laplacian — allocation-free
/// once both have seen the shape (the `EigScratch` spectral-distance
/// path, see `graph::spectral`).
pub fn normalized_laplacian_into(w: &Mat, dinv: &mut Vec<f32>, out: &mut Mat) {
    dinv.clear();
    dinv.extend((0..w.rows).map(|i| {
        let d: f32 = w.row(i).iter().sum();
        if d > 1e-12 { 1.0 / d.sqrt() } else { 0.0 }
    }));
    out.reshape(w.rows, w.cols);
    for i in 0..w.rows {
        let o = out.row_mut(i);
        let wr = w.row(i);
        for j in 0..wr.len() {
            let id = if i == j { 1.0 } else { 0.0 };
            o[j] = id - dinv[i] * wr[j] * dinv[j];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn path_graph(n: usize) -> Mat {
        Mat::from_fn(n, n, |i, j| {
            if i + 1 == j || j + 1 == i { 1.0 } else { 0.0 }
        })
    }

    #[test]
    fn degrees_of_path() {
        let w = path_graph(4);
        assert_eq!(degree_vector(&w), vec![1.0, 2.0, 2.0, 1.0]);
    }

    #[test]
    fn laplacian_rows_sum_to_zero() {
        let w = path_graph(5);
        let l = combinatorial_laplacian(&w);
        for i in 0..5 {
            let s: f32 = l.row(i).iter().sum();
            assert!(s.abs() < 1e-6);
        }
    }

    #[test]
    fn normalized_laplacian_diag_one() {
        let w = path_graph(5);
        let l = normalized_laplacian(&w);
        for i in 0..5 {
            assert!((l.get(i, i) - 1.0).abs() < 1e-6);
        }
    }
}
