//! Graph coarsening (Def. 1) and lifting (Def. 2).

use crate::tensor::Mat;

/// A partition of `n` nodes into disjoint groups.
#[derive(Clone, Debug)]
pub struct Partition {
    /// group id per node, 0..n_groups
    pub assign: Vec<usize>,
    /// number of groups
    pub n_groups: usize,
}

impl Partition {
    /// Identity partition (each node its own group).
    pub fn identity(n: usize) -> Self {
        Partition { assign: (0..n).collect(), n_groups: n }
    }

    /// Build from a group-id vector.
    pub fn from_assign(assign: Vec<usize>) -> Self {
        let n_groups = assign.iter().copied().max().map_or(0, |m| m + 1);
        Partition { assign, n_groups }
    }

    /// Group cardinalities |V_i|.
    pub fn sizes(&self) -> Vec<usize> {
        let mut s = Vec::new();
        self.sizes_into(&mut s);
        s
    }

    /// [`Partition::sizes`] into a reusable buffer (allocation-free once
    /// it has seen the group count).
    pub fn sizes_into(&self, out: &mut Vec<usize>) {
        out.clear();
        out.resize(self.n_groups, 0);
        for &g in &self.assign {
            out[g] += 1;
        }
    }

    /// Merge two groups (used by the iterative pairwise coarsening of
    /// Theorem 1's setting), renumbering so ids stay dense.
    pub fn merge_groups(&mut self, g1: usize, g2: usize) {
        assert!(g1 != g2 && g1 < self.n_groups && g2 < self.n_groups);
        let (keep, drop) = if g1 < g2 { (g1, g2) } else { (g2, g1) };
        for g in self.assign.iter_mut() {
            if *g == drop {
                *g = keep;
            } else if *g > drop {
                *g -= 1;
            }
        }
        self.n_groups -= 1;
    }
}

/// Coarsened adjacency `Wc[i,j] = sum_{u in Vi, v in Vj} W[u,v]` (Def. 1;
/// allocating wrapper over [`coarsen_into`]).
pub fn coarsen(w: &Mat, p: &Partition) -> Mat {
    let mut wc = Mat::zeros(0, 0);
    coarsen_into(w, p, &mut wc);
    wc
}

/// [`coarsen`] into a reusable output buffer — allocation-free once it
/// has seen the group count.
pub fn coarsen_into(w: &Mat, p: &Partition, wc: &mut Mat) {
    assert_eq!(w.rows, p.assign.len());
    wc.reset(p.n_groups, p.n_groups);
    for u in 0..w.rows {
        let gu = p.assign[u];
        for v in 0..w.cols {
            let gv = p.assign[v];
            wc.data[gu * p.n_groups + gv] += w.get(u, v);
        }
    }
}

/// Lifted adjacency `Wl[u,v] = Wc[gu,gv] / (|V_gu| |V_gv|)` (Def. 2) —
/// an n x n proxy for the coarse graph used by the spectral distance
/// (allocating wrapper over [`lift_into`]).
pub fn lift(wc: &Mat, p: &Partition) -> Mat {
    let mut sizes = Vec::new();
    let mut wl = Mat::zeros(0, 0);
    lift_into(wc, p, &mut sizes, &mut wl);
    wl
}

/// [`lift`] into reusable buffers: `sizes` is the group-cardinality
/// scratch, `wl` the lifted adjacency.
pub fn lift_into(wc: &Mat, p: &Partition, sizes: &mut Vec<usize>,
                 wl: &mut Mat) {
    p.sizes_into(sizes);
    let n = p.assign.len();
    wl.reshape(n, n);
    for u in 0..n {
        let gu = p.assign[u];
        let row = wl.row_mut(u);
        for (v, slot) in row.iter_mut().enumerate() {
            let gv = p.assign[v];
            *slot = wc.get(gu, gv) / (sizes[gu] * sizes[gv]) as f32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn complete_graph(n: usize) -> Mat {
        Mat::from_fn(n, n, |i, j| if i == j { 0.0 } else { 1.0 })
    }

    #[test]
    fn identity_partition_coarsen_is_noop() {
        let w = complete_graph(4);
        let p = Partition::identity(4);
        assert_eq!(coarsen(&w, &p), w);
    }

    #[test]
    fn pair_merge_sums_weights() {
        let w = complete_graph(4);
        let p = Partition::from_assign(vec![0, 0, 1, 2]);
        let wc = coarsen(&w, &p);
        assert_eq!(wc.rows, 3);
        // group0 = {0,1}: internal weight W[0,1]+W[1,0] = 2
        assert_eq!(wc.get(0, 0), 2.0);
        // group0-group1 edge: W[0,2]+W[1,2] = 2
        assert_eq!(wc.get(0, 1), 2.0);
    }

    #[test]
    fn lift_divides_by_sizes() {
        let w = complete_graph(4);
        let p = Partition::from_assign(vec![0, 0, 1, 2]);
        let wl = lift(&coarsen(&w, &p), &p);
        assert_eq!(wl.rows, 4);
        // lifted intra-group weight = 2 / (2*2) = 0.5
        assert_eq!(wl.get(0, 1), 0.5);
        // lifted cross weight = 2 / (2*1) = 1.0
        assert_eq!(wl.get(0, 2), 1.0);
    }

    #[test]
    fn merge_groups_renumbers() {
        let mut p = Partition::from_assign(vec![0, 1, 2, 3]);
        p.merge_groups(1, 3);
        assert_eq!(p.n_groups, 3);
        assert_eq!(p.assign, vec![0, 1, 2, 1]);
    }
}
