//! Symmetric eigensolver: cyclic Jacobi rotations.
//!
//! Good to ~1e-6 for the N <= 512 token graphs used here; no external
//! LAPACK dependency.  Only eigenvalues are needed for the spectral
//! distance, so eigenvectors are not accumulated.

use crate::tensor::Mat;

/// Eigenvalues of a symmetric matrix, ascending (allocating wrapper over
/// [`jacobi_eigenvalues_into`]).
///
/// Cyclic Jacobi: sweeps zero out off-diagonal entries with Givens
/// rotations until the off-diagonal Frobenius norm is below `tol`.
pub fn jacobi_eigenvalues(m: &Mat, tol: f32, max_sweeps: usize) -> Vec<f32> {
    let mut a = Mat::zeros(0, 0);
    let mut ev = Vec::new();
    jacobi_eigenvalues_into(m, tol, max_sweeps, &mut a, &mut ev);
    ev
}

/// [`jacobi_eigenvalues`] into reusable buffers: `a` is the rotation
/// working copy, `ev` receives the ascending eigenvalues —
/// allocation-free once both have seen the shape (the in-place unstable
/// sort makes equal eigenvalues bit-order unspecified, which the
/// spectral distance — a sum of |Δλ| — is insensitive to).
pub fn jacobi_eigenvalues_into(m: &Mat, tol: f32, max_sweeps: usize,
                               a: &mut Mat, ev: &mut Vec<f32>) {
    assert_eq!(m.rows, m.cols, "eigenvalues of non-square matrix");
    let n = m.rows;
    a.copy_from(m);
    // symmetrize defensively (callers pass Laplacians, symmetric up to fp)
    for i in 0..n {
        for j in (i + 1)..n {
            let v = 0.5 * (a.get(i, j) + a.get(j, i));
            a.set(i, j, v);
            a.set(j, i, v);
        }
    }
    for _sweep in 0..max_sweeps {
        let mut off = 0f32;
        for i in 0..n {
            for j in (i + 1)..n {
                off += a.get(i, j) * a.get(i, j);
            }
        }
        if off.sqrt() < tol {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = a.get(p, q);
                if apq.abs() < 1e-12 {
                    continue;
                }
                let app = a.get(p, p);
                let aqq = a.get(q, q);
                let theta = 0.5 * (aqq - app) / apq;
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                // rotate rows/cols p and q
                for k in 0..n {
                    let akp = a.get(k, p);
                    let akq = a.get(k, q);
                    a.set(k, p, c * akp - s * akq);
                    a.set(k, q, s * akp + c * akq);
                }
                for k in 0..n {
                    let apk = a.get(p, k);
                    let aqk = a.get(q, k);
                    a.set(p, k, c * apk - s * aqk);
                    a.set(q, k, s * apk + c * aqk);
                }
            }
        }
    }
    ev.clear();
    ev.extend((0..n).map(|i| a.get(i, i)));
    ev.sort_unstable_by(|x, y| x.partial_cmp(y).unwrap());
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn diagonal_matrix_eigenvalues() {
        let m = Mat::from_fn(3, 3, |i, j| if i == j { (i + 1) as f32 } else { 0.0 });
        let ev = jacobi_eigenvalues(&m, 1e-8, 50);
        assert_eq!(ev, vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn known_2x2() {
        // [[2,1],[1,2]] -> eigenvalues 1, 3
        let m = Mat::from_vec(2, 2, vec![2.0, 1.0, 1.0, 2.0]);
        let ev = jacobi_eigenvalues(&m, 1e-8, 50);
        assert!((ev[0] - 1.0).abs() < 1e-5);
        assert!((ev[1] - 3.0).abs() < 1e-5);
    }

    #[test]
    fn trace_preserved() {
        let m = Mat::from_fn(8, 8, |i, j| {
            let v = ((i * 7 + j * 3) % 5) as f32 * 0.2;
            if i <= j { v } else { ((j * 7 + i * 3) % 5) as f32 * 0.2 }
        });
        // symmetrize
        let m = Mat::from_fn(8, 8, |i, j| 0.5 * (m.get(i, j) + m.get(j, i)));
        let tr: f32 = (0..8).map(|i| m.get(i, i)).sum();
        let ev = jacobi_eigenvalues(&m, 1e-7, 100);
        let s: f32 = ev.iter().sum();
        assert!((tr - s).abs() < 1e-3, "trace {tr} vs sum {s}");
    }

    #[test]
    fn normalized_laplacian_eigenvalues_in_range() {
        use crate::graph::laplacian::normalized_laplacian;
        // ring graph
        let n = 10;
        let w = Mat::from_fn(n, n, |i, j| {
            if (i + 1) % n == j || (j + 1) % n == i { 1.0 } else { 0.0 }
        });
        let l = normalized_laplacian(&w);
        let ev = jacobi_eigenvalues(&l, 1e-7, 100);
        assert!(ev[0].abs() < 1e-4, "lambda_0 = {}", ev[0]);
        assert!(*ev.last().unwrap() <= 2.0 + 1e-4);
    }
}
