//! Spectral graph toolkit: Laplacians, eigenvalues, coarsening/lifting, and
//! the spectral distance of Eq. (5) — everything needed to *empirically
//! validate Theorem 1* (PiToMe coarsening preserves the normalized-Laplacian
//! spectrum; ToMe leaves a non-vanishing gap).

pub mod coarsen;
pub mod eigen;
pub mod laplacian;
pub mod spectral;

pub use coarsen::{coarsen, coarsen_into, lift, lift_into, Partition};
pub use eigen::{jacobi_eigenvalues, jacobi_eigenvalues_into};
pub use laplacian::{degree_vector, normalized_laplacian,
                    normalized_laplacian_into};
pub use spectral::{spectral_distance, spectral_distance_scratch, EigScratch,
                   token_graph};
