//! Spectral graph toolkit: Laplacians, eigenvalues, coarsening/lifting, and
//! the spectral distance of Eq. (5) — everything needed to *empirically
//! validate Theorem 1* (PiToMe coarsening preserves the normalized-Laplacian
//! spectrum; ToMe leaves a non-vanishing gap).

pub mod coarsen;
pub mod eigen;
pub mod laplacian;
pub mod spectral;

pub use coarsen::{coarsen, lift, Partition};
pub use eigen::jacobi_eigenvalues;
pub use laplacian::{degree_vector, normalized_laplacian};
pub use spectral::{spectral_distance, token_graph};
