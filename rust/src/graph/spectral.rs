//! Spectral distance (Eq. 5) and token-graph construction.

use super::coarsen::{coarsen, lift, Partition};
use super::eigen::jacobi_eigenvalues;
use super::laplacian::normalized_laplacian;
use crate::tensor::{cosine_matrix, Mat};

/// Token graph of Eq. (3): `W[i,j] = 1 - cos(v_i, v_j)` (cosine
/// *distance*), diagonal zero.  Near-duplicate tokens are connected by
/// near-zero weights, so merging them perturbs the Laplacian spectrum
/// vanishingly — exactly the mechanism behind Theorem 1's
/// `SD(G, G_pitome) -> 0`.
pub fn token_graph(kf: &Mat) -> Mat {
    let c = cosine_matrix(kf);
    Mat::from_fn(c.rows, c.cols, |i, j| {
        if i == j { 0.0 } else { (1.0 - c.get(i, j)).max(0.0) }
    })
}

/// `SD(G, Gc) = || lambda(L(G)) - lambda(L(lift(Gc))) ||_1` (Eq. 5),
/// computed over normalized-Laplacian spectra.
pub fn spectral_distance(w: &Mat, p: &Partition) -> f32 {
    let wl = lift(&coarsen(w, p), p);
    let l = normalized_laplacian(w);
    let ll = normalized_laplacian(&wl);
    let ev = jacobi_eigenvalues(&l, 1e-6, 100);
    let evl = jacobi_eigenvalues(&ll, 1e-6, 100);
    ev.iter().zip(&evl).map(|(a, b)| (a - b).abs()).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Rng;

    /// Two tight, well-separated clusters (assumptions A1/A2 of Thm. 1).
    pub fn two_cluster_features(n1: usize, n2: usize, h: usize, noise: f64,
                                seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        let c1: Vec<f32> = (0..h).map(|_| (rng.next_f64() * 2.0 - 1.0) as f32).collect();
        let c2: Vec<f32> = c1.iter().map(|v| -v).collect();
        Mat::from_fn(n1 + n2, h, |i, j| {
            let c = if i < n1 { &c1 } else { &c2 };
            c[j] + (noise * (rng.next_f64() - 0.5)) as f32
        })
    }

    #[test]
    fn identity_partition_distance_zero() {
        let kf = two_cluster_features(6, 6, 8, 0.1, 1);
        let w = token_graph(&kf);
        let p = Partition::identity(12);
        let sd = spectral_distance(&w, &p);
        assert!(sd < 1e-3, "SD(identity) = {sd}");
    }

    #[test]
    fn within_cluster_merge_cheaper_than_cross() {
        let kf = two_cluster_features(8, 8, 8, 0.05, 2);
        let w = token_graph(&kf);
        // merge two nodes of cluster 1
        let mut within = Partition::identity(16);
        within.merge_groups(0, 1);
        // merge one node of each cluster
        let mut cross = Partition::identity(16);
        cross.merge_groups(0, 15);
        let sd_within = spectral_distance(&w, &within);
        let sd_cross = spectral_distance(&w, &cross);
        assert!(sd_within < sd_cross,
                "within {sd_within} !< cross {sd_cross}");
    }

    #[test]
    fn distance_grows_with_coarsening_error() {
        let kf = two_cluster_features(10, 10, 8, 0.05, 3);
        let w = token_graph(&kf);
        // merge all of cluster 1 (fine) vs merge everything (destroys
        // structure)
        let mut good = vec![0usize; 20];
        for (i, g) in good.iter_mut().enumerate() {
            *g = if i < 10 { 0 } else { 1 + (i - 10) };
        }
        let all = vec![0usize; 20];
        let sd_good = spectral_distance(&w, &Partition::from_assign(good));
        let sd_all = spectral_distance(&w, &Partition::from_assign(all));
        assert!(sd_good < sd_all, "good {sd_good} !< all {sd_all}");
    }
}
