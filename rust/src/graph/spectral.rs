//! Spectral distance (Eq. 5) and token-graph construction.

use super::coarsen::{coarsen_into, lift_into, Partition};
use super::eigen::jacobi_eigenvalues_into;
use super::laplacian::normalized_laplacian_into;
use crate::tensor::{cosine_matrix, Mat};

/// Token graph of Eq. (3): `W[i,j] = 1 - cos(v_i, v_j)` (cosine
/// *distance*), diagonal zero.  Near-duplicate tokens are connected by
/// near-zero weights, so merging them perturbs the Laplacian spectrum
/// vanishingly — exactly the mechanism behind Theorem 1's
/// `SD(G, G_pitome) -> 0`.
pub fn token_graph(kf: &Mat) -> Mat {
    let c = cosine_matrix(kf);
    Mat::from_fn(c.rows, c.cols, |i, j| {
        if i == j { 0.0 } else { (1.0 - c.get(i, j)).max(0.0) }
    })
}

/// Reusable workspace for [`spectral_distance_scratch`]: the coarsened
/// and lifted adjacencies, both Laplacians, the Jacobi rotation working
/// copy, both eigenvalue vectors, and the degree/cardinality scratch.
/// One workspace serves a whole SD(G, Gc) sweep; once it has seen the
/// largest graph, every later evaluation performs **zero** heap
/// allocations (asserted by `tests/alloc_free.rs`).
pub struct EigScratch {
    wc: Mat,
    wl: Mat,
    l: Mat,
    ll: Mat,
    /// Jacobi rotation working copy (shared by both eigensolves)
    a: Mat,
    ev: Vec<f32>,
    evl: Vec<f32>,
    dinv: Vec<f32>,
    sizes: Vec<usize>,
}

impl EigScratch {
    /// Empty workspace; buffers grow on first use and are then reused.
    pub fn new() -> EigScratch {
        EigScratch {
            wc: Mat::zeros(0, 0),
            wl: Mat::zeros(0, 0),
            l: Mat::zeros(0, 0),
            ll: Mat::zeros(0, 0),
            a: Mat::zeros(0, 0),
            ev: Vec::new(),
            evl: Vec::new(),
            dinv: Vec::new(),
            sizes: Vec::new(),
        }
    }
}

impl Default for EigScratch {
    fn default() -> Self {
        EigScratch::new()
    }
}

/// `SD(G, Gc) = || lambda(L(G)) - lambda(L(lift(Gc))) ||_1` (Eq. 5),
/// computed over normalized-Laplacian spectra (allocating wrapper over
/// [`spectral_distance_scratch`]).
pub fn spectral_distance(w: &Mat, p: &Partition) -> f32 {
    let mut scratch = EigScratch::new();
    spectral_distance_scratch(w, p, &mut scratch)
}

/// [`spectral_distance`] through a caller-owned [`EigScratch`]: coarsen,
/// lift, both Laplacians, and both Jacobi eigensolves all run in pooled
/// buffers, so a warmed evaluation allocates nothing.
pub fn spectral_distance_scratch(w: &Mat, p: &Partition,
                                 s: &mut EigScratch) -> f32 {
    coarsen_into(w, p, &mut s.wc);
    lift_into(&s.wc, p, &mut s.sizes, &mut s.wl);
    normalized_laplacian_into(w, &mut s.dinv, &mut s.l);
    normalized_laplacian_into(&s.wl, &mut s.dinv, &mut s.ll);
    jacobi_eigenvalues_into(&s.l, 1e-6, 100, &mut s.a, &mut s.ev);
    jacobi_eigenvalues_into(&s.ll, 1e-6, 100, &mut s.a, &mut s.evl);
    s.ev.iter().zip(&s.evl).map(|(a, b)| (a - b).abs()).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Rng;

    /// Two tight, well-separated clusters (assumptions A1/A2 of Thm. 1).
    pub fn two_cluster_features(n1: usize, n2: usize, h: usize, noise: f64,
                                seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        let c1: Vec<f32> = (0..h).map(|_| (rng.next_f64() * 2.0 - 1.0) as f32).collect();
        let c2: Vec<f32> = c1.iter().map(|v| -v).collect();
        Mat::from_fn(n1 + n2, h, |i, j| {
            let c = if i < n1 { &c1 } else { &c2 };
            c[j] + (noise * (rng.next_f64() - 0.5)) as f32
        })
    }

    #[test]
    fn identity_partition_distance_zero() {
        let kf = two_cluster_features(6, 6, 8, 0.1, 1);
        let w = token_graph(&kf);
        let p = Partition::identity(12);
        let sd = spectral_distance(&w, &p);
        assert!(sd < 1e-3, "SD(identity) = {sd}");
    }

    #[test]
    fn within_cluster_merge_cheaper_than_cross() {
        let kf = two_cluster_features(8, 8, 8, 0.05, 2);
        let w = token_graph(&kf);
        // merge two nodes of cluster 1
        let mut within = Partition::identity(16);
        within.merge_groups(0, 1);
        // merge one node of each cluster
        let mut cross = Partition::identity(16);
        cross.merge_groups(0, 15);
        let sd_within = spectral_distance(&w, &within);
        let sd_cross = spectral_distance(&w, &cross);
        assert!(sd_within < sd_cross,
                "within {sd_within} !< cross {sd_cross}");
    }

    #[test]
    fn distance_grows_with_coarsening_error() {
        let kf = two_cluster_features(10, 10, 8, 0.05, 3);
        let w = token_graph(&kf);
        // merge all of cluster 1 (fine) vs merge everything (destroys
        // structure)
        let mut good = vec![0usize; 20];
        for (i, g) in good.iter_mut().enumerate() {
            *g = if i < 10 { 0 } else { 1 + (i - 10) };
        }
        let all = vec![0usize; 20];
        let sd_good = spectral_distance(&w, &Partition::from_assign(good));
        let sd_all = spectral_distance(&w, &Partition::from_assign(all));
        assert!(sd_good < sd_all, "good {sd_good} !< all {sd_all}");
    }
}
