//! `pitome` CLI — leader entrypoint for the serving/training stack.
//!
//! Subcommands:
//!   * `info`     — list artifacts, params, plans, FLOPs.
//!   * `classify` — off-the-shelf ShapeBench accuracy for one config.
//!   * `spectral` — Theorem-1 spectral-distance experiment.
//!   * `serve`    — boot the coordinator and run a trace through it.
//!   * `loadtest` — closed-loop load harness against the typed router.
//!   * `gallery`  — embed-once/score-millions gallery serving demo.
//!   * `trace-check` — validate a Chrome trace emitted by `--trace-out`.
//!
//! Flags: `--artifacts DIR`, per-subcommand flags below.

use std::path::PathBuf;
use std::sync::Arc;

use pitome::config::{ServingConfig, ViTConfig};
use pitome::coordinator::{run_load, Coordinator, CpuWorkloads, LoadOptions,
                          Payload, Qos, Workload};
use pitome::data::{generate_trace, patchify, sent_item, shape_item,
                   vqa_item, ArrivalModel, TraceConfig, WorkloadMix,
                   TEST_SEED};
use pitome::engine::JointKind;
use pitome::eval;
use pitome::model::load_model_params;
use pitome::runtime::{HostTensor, Registry};
use pitome::util::Args;

const USAGE: &str = "\
pitome <command> [flags]
  info                              list artifacts + cost model
  classify --mode M --r R --n N     off-the-shelf accuracy
  spectral --steps S --k K          Theorem-1 experiment
  serve --requests N --rate R       serve a synthetic trace
    [--prom-every N]  (dump Prometheus exposition every N requests)
  loadtest --requests N --rate R    load harness (shed/deadline aware)
    [--burst B] [--diurnal D] [--deadline-ms MS] [--users U --think-ms MS]
    [--queue CAP] [--scale S] [--mix-vision W --mix-text W --mix-joint W]
    [--mix-gallery W --gallery-prefill N]
    [--trace-out FILE [--trace-cap EVENTS] [--trace-sample N]]
  gallery --items N --queries Q     sharded embedding-gallery demo
    [--users U] [--rate R] [--seed S]
  trace-check FILE                  validate a --trace-out Chrome trace
global: --artifacts DIR (default ./artifacts)";

fn main() -> anyhow::Result<()> {
    let args = Args::parse();
    let dir = PathBuf::from(args.get("artifacts",
        Registry::default_dir().to_str().unwrap_or("artifacts")));
    match args.positional.first().map(String::as_str) {
        Some("info") => info(&dir),
        Some("classify") => classify(
            &dir,
            &args.get("mode", "pitome"),
            args.get_parse("r", 0.9),
            args.get_parse("n", 256),
        ),
        Some("spectral") => {
            spectral(args.get_parse("steps", 3), args.get_parse("k", 3));
            Ok(())
        }
        Some("serve") => serve(
            &dir,
            args.get_parse("requests", 256),
            args.get_parse("rate", 300.0),
            args.get_parse("prom-every", 0usize),
        ),
        Some("loadtest") => loadtest(&args),
        Some("gallery") => gallery(&args),
        Some("trace-check") => trace_check(
            args.positional
                .get(1)
                .map(String::as_str)
                .ok_or_else(|| {
                    anyhow::anyhow!("usage: pitome trace-check FILE")
                })?,
        ),
        _ => {
            println!("{USAGE}");
            Ok(())
        }
    }
}

fn info(dir: &PathBuf) -> anyhow::Result<()> {
    match Registry::load(dir) {
        Ok(reg) => {
            println!("artifacts in {}:", dir.display());
            for name in reg.names() {
                let e = reg.get(&name).unwrap();
                println!("  {name:32} model={:10} mode={:10} r={:<5} batch={}",
                         e.meta.model, e.meta.mode, e.meta.r, e.meta.batch);
            }
        }
        Err(e) => println!("(no artifact registry: {e})"),
    }
    println!("\ncost model (paper-scale backbones, pitome r=0.9):");
    for (name, g, s) in eval::classify::paper_scale_flops(&[0.9]) {
        println!("  {name:24} {g:8.1} GFLOPs  x{s:.2}");
    }
    Ok(())
}

fn classify(dir: &PathBuf, mode: &str, r: f64, n: usize) -> anyhow::Result<()> {
    let engine = pitome::engine::Engine::from_store(
        load_model_params(dir, "vit").map_err(|e| anyhow::anyhow!("{e}"))?);
    let row = eval::classify::eval_config(&engine, mode, r, n)
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    let cfg = ViTConfig { merge_mode: mode.into(), merge_r: r, ..Default::default() };
    println!("mode={} r={} acc={:.2}% gflops={:.4} speedup=x{:.2} plan={:?}",
             row.mode, row.r, row.acc, row.gflops, row.speedup, cfg.plan());
    Ok(())
}

fn spectral(steps: usize, k: usize) {
    println!("Theorem 1: SD(G, coarse) by algorithm and cluster tightness");
    println!("{:<8} {:<8} {:>10} {:>12}", "noise", "algo", "SD", "cross-frac");
    for row in eval::spectral::theorem1_sweep(&[0.02, 0.1, 0.3, 0.6], steps, k) {
        println!("{:<8} {:<8} {:>10.4} {:>12.3}",
                 row.noise, row.algo, row.sd, row.cross_cluster_frac);
    }
}

fn serve(dir: &PathBuf, requests: usize, rate: f64, prom_every: usize)
         -> anyhow::Result<()> {
    // mixed-workload traffic (vision + text + joint through the typed
    // router) is available when the store covers every tower — i.e. the
    // synthetic multimodal fallback; trained vit-only params serve the
    // vision workload alone
    let mut mixed = false;
    let coord = match Registry::load(dir) {
        Ok(reg) => {
            let selection = [("vit", vec!["vit_none_b8".to_string(),
                                          "vit_pitome_r900_b8".to_string()])];
            Arc::new(Coordinator::boot(&reg, dir, &selection,
                                       ServingConfig::default())
                .map_err(|e| anyhow::anyhow!("{e}"))?)
        }
        Err(e) => {
            // no artifacts: serve the pure-Rust CPU reference model
            // instead (trained weights if present, synthetic otherwise)
            println!("(no artifact registry: {e})");
            println!("(serving the CPU reference model via the typed router)");
            let cfg = ServingConfig {
                workers: pitome::merge::batch::recommended_workers(),
                ..Default::default()
            };
            match load_model_params(dir, "vit") {
                Ok(ps) => {
                    println!("(using trained vit params from {})", dir.display());
                    let selection = [("vit", vec![("none".to_string(), 1.0),
                                                  ("pitome".to_string(), 0.9)])];
                    Arc::new(Coordinator::boot_cpu(&Arc::new(ps), &selection,
                                                   cfg)
                        .map_err(|e| anyhow::anyhow!("{e}"))?)
                }
                Err(e) => {
                    // make the degraded mode loud: predictions from
                    // synthetic weights are deterministic but untrained
                    println!("(vit params unavailable: {e})");
                    println!("(falling back to SYNTHETIC multimodal weights \
                              — serving mixed vision/text/joint traffic)");
                    mixed = true;
                    let ps = Arc::new(pitome::model::synthetic_mm_store(
                        &ViTConfig::default(), 7));
                    let workloads = CpuWorkloads {
                        vision: vec![("vit".to_string(),
                                      vec![("none".to_string(), 1.0),
                                           ("pitome".to_string(), 0.9)])],
                        text: vec![("bert".to_string(),
                                    vec![("none".to_string(), 1.0)])],
                        joint: vec![("vqa".to_string(), JointKind::Vqa,
                                     vec![("pitome".to_string(), 0.9)])],
                        ..Default::default()
                    };
                    Arc::new(Coordinator::boot_cpu_workloads(&ps, &workloads,
                                                             cfg)
                        .map_err(|e| anyhow::anyhow!("{e}"))?)
                }
            }
        }
    };

    let trace = generate_trace(&TraceConfig {
        rate, count: requests, ..Default::default()
    }).map_err(|e| anyhow::anyhow!("{e}"))?;
    let pool = coord.pool().clone();
    let tcfg = pitome::config::TextConfig::default();
    let t0 = std::time::Instant::now();
    let mut pending = Vec::new();
    for (i, ev) in trace.iter().enumerate() {
        let target = std::time::Duration::from_micros(ev.at_us);
        if let Some(wait) = target.checked_sub(t0.elapsed()) {
            std::thread::sleep(wait);
        }
        // every 4th/5th request exercises the text/joint pools when the
        // coordinator serves them
        let submitted = if mixed && i % 5 == 3 {
            let (toks, _) = sent_item(TEST_SEED, ev.item, tcfg.seq_len, 16);
            let mut tt = pool.take_i32(toks.len());
            tt.fill_i32(&toks, &[toks.len()]);
            coord.submit_typed(Workload::Text, "bert", Qos::Accuracy,
                               Payload::Text(tt))
        } else if mixed && i % 5 == 4 {
            let item = shape_item(TEST_SEED, ev.item);
            let patches = patchify(&item.image, 4);
            let (q, _) = vqa_item(TEST_SEED, ev.item);
            let mut vt = pool.take_f32(patches.data.len());
            vt.fill_f32(&patches.data, &[patches.rows, patches.cols]);
            let mut qt = pool.take_i32(q.len());
            qt.fill_i32(&q, &[q.len()]);
            coord.submit_typed(Workload::Joint, "vqa", Qos::Throughput,
                               Payload::Joint { vision: vt, text: qt })
        } else {
            let item = shape_item(TEST_SEED, ev.item);
            let patches = patchify(&item.image, 4);
            coord.submit_nowait("vit", Qos::Balanced,
                                vec![HostTensor::F32(patches.data,
                                                     vec![64, 16])])
        };
        match submitted {
            Ok(rx) => pending.push(rx),
            Err(e) => eprintln!("submit failed: {e}"),
        }
        // periodic Prometheus dump: the scrape-endpoint stand-in for a
        // process with no HTTP listener
        if prom_every > 0 && i > 0 && i % prom_every == 0 {
            print!("{}", pitome::obs::export::prometheus_text(
                &coord.metrics_typed()));
        }
    }
    let mut ok = 0usize;
    for rx in pending {
        if rx.recv().is_ok() {
            ok += 1;
        }
    }
    let dur = t0.elapsed().as_secs_f64();
    println!("served {ok}/{requests} in {dur:.2}s ({:.1} req/s)",
             ok as f64 / dur);
    for (w, model, artifact, snap) in coord.metrics_typed() {
        println!("  {}/{model}/{artifact}: {snap}", w.name());
    }
    if mixed {
        println!("  recycle hit rate: {}", pool.hit_rate_summary());
    }
    if prom_every > 0 {
        print!("{}", pitome::obs::export::prometheus_text(
            &coord.metrics_typed()));
    }
    Ok(())
}

/// `pitome loadtest` — replay a typed arrival trace through the
/// admission-controlled submit path and print the accounting.  Shares
/// `coordinator::harness::run_load` with `benches/serving_bench.rs`;
/// `--users > 0` switches from open-loop pacing to a closed loop.
fn loadtest(args: &pitome::util::Args) -> anyhow::Result<()> {
    let users: usize = args.get_parse("users", 0usize);
    let mix_gallery: f64 = args.get_parse("mix-gallery", 0.0);
    let trace = TraceConfig {
        rate: args.get_parse("rate", 300.0),
        count: args.get_parse("requests", 256usize),
        burstiness: args.get_parse("burst", 1.0),
        diurnal: args.get_parse("diurnal", 0.0),
        diurnal_period_s: args.get_parse("diurnal-period", 10.0),
        mix: WorkloadMix {
            vision: args.get_parse("mix-vision", 1.0),
            text: args.get_parse("mix-text", 1.0),
            joint: args.get_parse("mix-joint", 1.0),
            gallery: mix_gallery,
        },
        deadline_us: args.get_parse("deadline-ms", 0u64) * 1000,
        arrival: if users > 0 {
            ArrivalModel::Closed {
                users,
                think_time_us: args.get_parse("think-ms", 0u64) * 1000,
            }
        } else {
            ArrivalModel::Open
        },
        seed: args.get_parse("seed", 11u64),
        ..Default::default()
    };
    println!("(loadtest serves SYNTHETIC multimodal weights — \
              deterministic, untrained)");
    let ps = Arc::new(pitome::model::synthetic_mm_store(
        &ViTConfig::default(), 7));
    let workloads = CpuWorkloads {
        vision: vec![("vit".to_string(),
                      vec![("none".to_string(), 1.0),
                           ("pitome".to_string(), 0.9),
                           ("tome".to_string(), 0.5)])],
        text: vec![("bert".to_string(), vec![("none".to_string(), 1.0)])],
        joint: vec![("vqa".to_string(), JointKind::Vqa,
                     vec![("pitome".to_string(), 0.9)])],
        gallery: if mix_gallery > 0.0 {
            vec![("gal".to_string(), vec![("pitome".to_string(), 0.9)])]
        } else {
            Vec::new()
        },
    };
    // --trace-out implies tracing: span rings sized by --trace-cap plus
    // client-side request sampling every --trace-sample completions
    let trace_out = args.get("trace-out", "");
    let scfg = ServingConfig {
        workers: pitome::merge::batch::recommended_workers(),
        queue_capacity: args.get_parse("queue", 64usize),
        trace_capacity: args.get_parse(
            "trace-cap",
            if trace_out.is_empty() { 0usize } else { 65_536 }),
        ..Default::default()
    };
    let coord = Coordinator::boot_cpu_workloads(&ps, &workloads, scfg)
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    let opts = LoadOptions {
        trace,
        time_scale: args.get_parse("scale", 1.0),
        gallery_prefill: args.get_parse(
            "gallery-prefill",
            if mix_gallery > 0.0 { 256usize } else { 0 }),
        trace_sample: args.get_parse(
            "trace-sample",
            if trace_out.is_empty() { 0usize } else { 1 }),
        ..Default::default()
    };
    let report = run_load(&coord, &opts)
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    report.print();
    for (w, model, artifact, snap) in coord.metrics_typed() {
        println!("  {}/{model}/{artifact}: {snap}", w.name());
    }
    if !trace_out.is_empty() {
        let mut threads = coord
            .obs_hub()
            .map(|h| h.drain())
            .unwrap_or_default();
        threads.extend(report.request_lanes);
        let path = PathBuf::from(&trace_out);
        pitome::obs::export::write_chrome_trace(&path, &threads)
            .map_err(|e| anyhow::anyhow!("{e}"))?;
        let spans: usize = threads.iter().map(|t| t.events.len()).sum();
        println!("wrote Chrome trace {trace_out}: {} lanes, {spans} spans \
                  (open in Perfetto or chrome://tracing)", threads.len());
    }
    Ok(())
}

/// `pitome trace-check FILE` — validate a Chrome trace-event file
/// emitted by `loadtest --trace-out` (the CI smoke gate): the JSON must
/// parse, carry a non-empty `traceEvents` array, and every complete
/// (`ph == "X"`) event must have a name, timestamp and duration.
fn trace_check(path: &str) -> anyhow::Result<()> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
    let doc = pitome::util::parse_json(&text)
        .map_err(|e| anyhow::anyhow!("{path}: invalid JSON: {e}"))?;
    let events = doc
        .get("traceEvents")
        .and_then(|e| e.arr())
        .ok_or_else(|| anyhow::anyhow!("{path}: no traceEvents array"))?;
    if events.is_empty() {
        return Err(anyhow::anyhow!("{path}: traceEvents is empty"));
    }
    let mut spans = 0usize;
    for (i, ev) in events.iter().enumerate() {
        let name = ev.get("name").and_then(|n| n.str());
        let ph = ev.get("ph").and_then(|p| p.str());
        if name.is_none() || ph.is_none() {
            return Err(anyhow::anyhow!(
                "{path}: event {i} missing name/ph"));
        }
        if ph == Some("X") {
            if ev.get("ts").and_then(|t| t.num()).is_none()
                || ev.get("dur").and_then(|d| d.num()).is_none()
            {
                return Err(anyhow::anyhow!(
                    "{path}: span event {i} missing ts/dur"));
            }
            spans += 1;
        }
    }
    println!("{path}: OK — {} trace events ({spans} spans)", events.len());
    Ok(())
}

/// `pitome gallery` — the embed-once/score-millions serving demo.  Boots
/// a gallery pool over synthetic multimodal weights, bulk-ingests
/// `--items` seeded embedding rows straight into the sharded store (the
/// offline-indexing path), pushes a few end-to-end
/// [`Payload::GalleryIngest`] requests through the coordinator (the
/// embed-once path), then replays `--queries` closed-loop gallery
/// queries and prints the scan accounting.
fn gallery(args: &pitome::util::Args) -> anyhow::Result<()> {
    let items: usize = args.get_parse("items", 1_000_000usize);
    let queries: usize = args.get_parse("queries", 64usize);
    println!("(gallery demo serves SYNTHETIC multimodal weights — \
              deterministic, untrained)");
    let ps = Arc::new(pitome::model::synthetic_mm_store(
        &ViTConfig::default(), 7));
    let workloads = CpuWorkloads {
        gallery: vec![("gal".to_string(),
                       vec![("pitome".to_string(), 0.9)])],
        ..Default::default()
    };
    let scfg = ServingConfig {
        workers: pitome::merge::batch::recommended_workers(),
        ..Default::default()
    };
    let coord = Coordinator::boot_cpu_workloads(&ps, &workloads, scfg)
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    let store = coord
        .gallery_store("gal")
        .ok_or_else(|| anyhow::anyhow!("gallery pool failed to boot"))?
        .clone();

    // offline indexing: seeded random rows in bounded chunks, straight
    // into the shard segments (no tower forward pass)
    let dim = store.dim();
    let mut rng = pitome::data::Rng::new(args.get_parse("seed", 0x6A11u64));
    const CHUNK: usize = 4096;
    let mut buf = vec![0f32; CHUNK * dim];
    let t0 = std::time::Instant::now();
    let mut done = 0usize;
    while done < items {
        let n = CHUNK.min(items - done);
        for v in buf[..n * dim].iter_mut() {
            *v = rng.uniform(-1.0, 1.0) as f32;
        }
        store.ingest_bulk(&buf[..n * dim])
            .map_err(|e| anyhow::anyhow!("{e}"))?;
        done += n;
    }
    let dt = t0.elapsed().as_secs_f64();
    println!("indexed {done} rows x {dim} dims ({:.0} MB) in {dt:.2}s \
              ({:.0} rows/s) across {} shards",
             (done * dim * 4) as f64 / 1e6, done as f64 / dt.max(1e-9),
             store.n_shards());

    // embed-once path: a few live ingests through the serving pipeline
    let pool = coord.pool().clone();
    let slot = coord.response_slot();
    let mut last_len = store.len();
    for i in 0..4u64 {
        let item = shape_item(TEST_SEED, i);
        let patches = patchify(&item.image, 4);
        let mut t = pool.take_f32(patches.data.len());
        t.fill_f32(&patches.data, &[patches.rows, patches.cols]);
        coord.submit_pooled(Workload::Gallery, "gal", Qos::Accuracy,
                            Payload::GalleryIngest(t), &slot)
            .map_err(|e| anyhow::anyhow!("{e}"))?;
        let resp = slot.recv().map_err(|e| anyhow::anyhow!("{e}"))?;
        if let Some(HostTensor::F32(data, _)) =
            resp.outputs.first().map(|t| t.tensor())
        {
            last_len = data.get(1).copied().unwrap_or(0.0) as usize;
        }
    }
    println!("embed-once ingest: 4 live requests, gallery now holds \
              {last_len} rows");

    // score-millions path: closed-loop query replay through run_load
    let opts = LoadOptions {
        trace: TraceConfig {
            rate: args.get_parse("rate", 200.0),
            count: queries,
            mix: WorkloadMix { vision: 0.0, text: 0.0, joint: 0.0,
                               gallery: 1.0 },
            arrival: ArrivalModel::Closed {
                users: args.get_parse("users", 2usize),
                think_time_us: 0,
            },
            seed: args.get_parse("seed", 0x6A11u64),
            ..Default::default()
        },
        ..Default::default()
    };
    let report = run_load(&coord, &opts)
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    report.print();
    for (w, model, artifact, snap) in coord.metrics_typed() {
        if snap.gallery_scanned_rows > 0 {
            println!("  {}/{model}/{artifact}: {snap}", w.name());
        }
    }
    Ok(())
}
