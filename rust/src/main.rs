//! `pitome` CLI — leader entrypoint for the serving/training stack.
//!
//! Subcommands:
//!   * `info`     — list artifacts, params, plans, FLOPs.
//!   * `classify` — off-the-shelf ShapeBench accuracy for one config.
//!   * `spectral` — Theorem-1 spectral-distance experiment.
//!   * `serve`    — boot the coordinator and run a trace through it.
//!
//! Flags: `--artifacts DIR`, per-subcommand flags below.

use std::path::PathBuf;
use std::sync::Arc;

use pitome::config::{ServingConfig, ViTConfig};
use pitome::coordinator::{Coordinator, Qos};
use pitome::data::{generate_trace, patchify, shape_item, TraceConfig, TEST_SEED};
use pitome::eval;
use pitome::model::load_model_params;
use pitome::runtime::{HostTensor, Registry};
use pitome::util::Args;

const USAGE: &str = "\
pitome <command> [flags]
  info                              list artifacts + cost model
  classify --mode M --r R --n N     off-the-shelf accuracy
  spectral --steps S --k K          Theorem-1 experiment
  serve --requests N --rate R       serve a synthetic trace
global: --artifacts DIR (default ./artifacts)";

fn main() -> anyhow::Result<()> {
    let args = Args::parse();
    let dir = PathBuf::from(args.get("artifacts",
        Registry::default_dir().to_str().unwrap_or("artifacts")));
    match args.positional.first().map(String::as_str) {
        Some("info") => info(&dir),
        Some("classify") => classify(
            &dir,
            &args.get("mode", "pitome"),
            args.get_parse("r", 0.9),
            args.get_parse("n", 256),
        ),
        Some("spectral") => {
            spectral(args.get_parse("steps", 3), args.get_parse("k", 3));
            Ok(())
        }
        Some("serve") => serve(
            &dir,
            args.get_parse("requests", 256),
            args.get_parse("rate", 300.0),
        ),
        _ => {
            println!("{USAGE}");
            Ok(())
        }
    }
}

fn info(dir: &PathBuf) -> anyhow::Result<()> {
    match Registry::load(dir) {
        Ok(reg) => {
            println!("artifacts in {}:", dir.display());
            for name in reg.names() {
                let e = reg.get(&name).unwrap();
                println!("  {name:32} model={:10} mode={:10} r={:<5} batch={}",
                         e.meta.model, e.meta.mode, e.meta.r, e.meta.batch);
            }
        }
        Err(e) => println!("(no artifact registry: {e})"),
    }
    println!("\ncost model (paper-scale backbones, pitome r=0.9):");
    for (name, g, s) in eval::classify::paper_scale_flops(&[0.9]) {
        println!("  {name:24} {g:8.1} GFLOPs  x{s:.2}");
    }
    Ok(())
}

fn classify(dir: &PathBuf, mode: &str, r: f64, n: usize) -> anyhow::Result<()> {
    let engine = pitome::engine::Engine::from_store(
        load_model_params(dir, "vit").map_err(|e| anyhow::anyhow!("{e}"))?);
    let row = eval::classify::eval_config(&engine, mode, r, n)
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    let cfg = ViTConfig { merge_mode: mode.into(), merge_r: r, ..Default::default() };
    println!("mode={} r={} acc={:.2}% gflops={:.4} speedup=x{:.2} plan={:?}",
             row.mode, row.r, row.acc, row.gflops, row.speedup, cfg.plan());
    Ok(())
}

fn spectral(steps: usize, k: usize) {
    println!("Theorem 1: SD(G, coarse) by algorithm and cluster tightness");
    println!("{:<8} {:<8} {:>10} {:>12}", "noise", "algo", "SD", "cross-frac");
    for row in eval::spectral::theorem1_sweep(&[0.02, 0.1, 0.3, 0.6], steps, k) {
        println!("{:<8} {:<8} {:>10.4} {:>12.3}",
                 row.noise, row.algo, row.sd, row.cross_cluster_frac);
    }
}

fn serve(dir: &PathBuf, requests: usize, rate: f64) -> anyhow::Result<()> {
    let coord = match Registry::load(dir) {
        Ok(reg) => {
            let selection = [("vit", vec!["vit_none_b8".to_string(),
                                          "vit_pitome_r900_b8".to_string()])];
            Arc::new(Coordinator::boot(&reg, dir, &selection,
                                       ServingConfig::default())
                .map_err(|e| anyhow::anyhow!("{e}"))?)
        }
        Err(e) => {
            // no artifacts: serve the pure-Rust CPU reference model
            // instead (trained weights if present, synthetic otherwise)
            println!("(no artifact registry: {e})");
            println!("(serving the CPU reference model via boot_cpu)");
            let ps = Arc::new(match load_model_params(dir, "vit") {
                Ok(ps) => {
                    println!("(using trained vit params from {})", dir.display());
                    ps
                }
                Err(e) => {
                    // make the degraded mode loud: predictions from
                    // synthetic weights are deterministic but untrained
                    println!("(vit params unavailable: {e})");
                    println!("(falling back to SYNTHETIC weights — \
                              predictions are untrained)");
                    pitome::model::synthetic_vit_store(&ViTConfig::default(), 7)
                }
            });
            let selection = [("vit", vec![("none".to_string(), 1.0),
                                          ("pitome".to_string(), 0.9)])];
            let cfg = ServingConfig {
                workers: pitome::merge::batch::recommended_workers(),
                ..Default::default()
            };
            Arc::new(Coordinator::boot_cpu(&ps, &selection, cfg)
                .map_err(|e| anyhow::anyhow!("{e}"))?)
        }
    };

    let trace = generate_trace(&TraceConfig {
        rate, count: requests, ..Default::default()
    });
    let t0 = std::time::Instant::now();
    let mut pending = Vec::new();
    for ev in trace {
        let target = std::time::Duration::from_micros(ev.at_us);
        if let Some(wait) = target.checked_sub(t0.elapsed()) {
            std::thread::sleep(wait);
        }
        let item = shape_item(TEST_SEED, ev.item);
        let patches = patchify(&item.image, 4);
        match coord.submit_nowait("vit", Qos::Balanced,
                                  vec![HostTensor::F32(patches.data, vec![64, 16])]) {
            Ok(rx) => pending.push(rx),
            Err(e) => eprintln!("submit failed: {e}"),
        }
    }
    let mut ok = 0usize;
    for rx in pending {
        if rx.recv().is_ok() {
            ok += 1;
        }
    }
    let dur = t0.elapsed().as_secs_f64();
    println!("served {ok}/{requests} in {dur:.2}s ({:.1} req/s)",
             ok as f64 / dur);
    for (model, artifact, snap) in coord.metrics() {
        println!("  {model}/{artifact}: n={} mean={:.0}us p50={}us p99={}us mean_batch={:.2}",
                 snap.count, snap.mean_us, snap.p50_us, snap.p99_us,
                 snap.mean_batch);
    }
    Ok(())
}
