//! Crate-wide error type.

use std::fmt;

/// Unified error for the pitome crate.
#[derive(Debug)]
pub enum Error {
    /// I/O failure (artifact files, params, manifests).
    Io(std::io::Error),
    /// JSON parse failure.
    Json(String),
    /// PJRT / XLA runtime failure.
    Xla(String),
    /// Artifact registry problems (missing artifact, shape mismatch).
    Artifact(String),
    /// Invalid configuration.
    Config(String),
    /// Coordinator-level failure (queue closed, worker died, ...).
    Coordinator(String),
    /// Shape or dimension mismatch in tensor/merge code.
    Shape(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Io(e) => write!(f, "io error: {e}"),
            Error::Json(m) => write!(f, "json error: {m}"),
            Error::Xla(m) => write!(f, "xla error: {m}"),
            Error::Artifact(m) => write!(f, "artifact error: {m}"),
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::Coordinator(m) => write!(f, "coordinator error: {m}"),
            Error::Shape(m) => write!(f, "shape error: {m}"),
        }
    }
}

impl std::error::Error for Error {}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl From<crate::util::json::JsonError> for Error {
    fn from(e: crate::util::json::JsonError) -> Self {
        Error::Json(e.to_string())
    }
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;
