//! Bounded top-k selection and k-way shard merge for gallery scans.
//!
//! [`TopK`] is a fixed-bound min-heap ordered so the *worst* retained
//! hit sits at the root; offering a better candidate replaces the root
//! in O(log k) without allocating once the spine is warm.  Ranking
//! matches `tensor::argsort_desc`: higher score first, ties broken by
//! smaller id, so gallery results are directly comparable to the dense
//! argsort reference used by `eval::recall_at_k`.
//! [`merge_shards_into`] consumes per-shard selections through a
//! cursor-based k-way merge into a caller-owned output buffer.

/// One scored gallery row.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Hit {
    /// Stable row id assigned at ingest.
    pub id: u64,
    /// Similarity score (dot or cosine, per the scan mode).
    pub score: f32,
}

/// `true` when `a` ranks strictly ahead of `b`: higher score first,
/// ties broken by smaller id (the `argsort_desc` contract).  NaN
/// scores rank behind every finite score; two NaNs fall back to id
/// order, so the relation stays a strict weak ordering.
#[inline]
pub fn ranks_ahead(a: Hit, b: Hit) -> bool {
    if a.score > b.score {
        return true;
    }
    if a.score < b.score {
        return false;
    }
    if a.score == b.score {
        return a.id < b.id;
    }
    // at least one NaN: non-NaN ranks ahead, NaN-vs-NaN by id
    match (a.score.is_nan(), b.score.is_nan()) {
        (false, true) => true,
        (true, false) => false,
        _ => a.id < b.id,
    }
}

/// Best-first ordering for sorts: the [`ranks_ahead`] relation as a
/// total order.
#[inline]
fn best_first(a: &Hit, b: &Hit) -> std::cmp::Ordering {
    if ranks_ahead(*a, *b) {
        std::cmp::Ordering::Less
    } else if ranks_ahead(*b, *a) {
        std::cmp::Ordering::Greater
    } else {
        std::cmp::Ordering::Equal
    }
}

/// Bounded min-heap of the best `k` hits seen so far.
pub struct TopK {
    k: usize,
    heap: Vec<Hit>,
    offered: u64,
    evictions: u64,
}

impl TopK {
    /// Empty selector; call [`TopK::reset`] with the query's `k`
    /// before offering candidates.
    // lint: allow(alloc) reason=cold constructor: empty heap spine, warmed by the first query
    pub fn new() -> Self {
        TopK { k: 0, heap: Vec::new(), offered: 0, evictions: 0 }
    }

    /// Clear retained hits and set the bound for the next scan.  The
    /// heap spine is kept, so a warmed selector does not allocate.
    pub fn reset(&mut self, k: usize) {
        self.k = k;
        self.heap.clear();
        self.offered = 0;
        self.evictions = 0;
        if self.heap.capacity() < k {
            self.heap.reserve_exact(k);
        }
    }

    /// Number of retained hits (≤ k).
    pub fn len(&self) -> usize {
        self.heap.len()
    }

    /// `true` when nothing has been retained.
    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Candidates offered since the last [`TopK::reset`].
    pub fn offered(&self) -> u64 {
        self.offered
    }

    /// Root replacements since the last [`TopK::reset`] — a full heap
    /// discarding its worst member for a better candidate.
    pub fn evictions(&self) -> u64 {
        self.evictions
    }

    /// Offer one candidate; O(log k) and allocation-free once warm.
    #[inline]
    pub fn offer(&mut self, id: u64, score: f32) {
        self.offered += 1;
        if self.k == 0 {
            return;
        }
        let h = Hit { id, score };
        if self.heap.len() < self.k {
            self.heap.push(h);
            self.sift_up(self.heap.len() - 1);
        } else if ranks_ahead(h, self.heap[0]) {
            self.evictions += 1;
            self.heap[0] = h;
            self.sift_down(0);
        }
    }

    /// Restore the heap property upward from leaf `i` (the root must
    /// stay the worst-ranked retained hit).
    fn sift_up(&mut self, mut i: usize) {
        while i > 0 {
            let p = (i - 1) / 2;
            if ranks_ahead(self.heap[p], self.heap[i]) {
                self.heap.swap(p, i);
                i = p;
            } else {
                break;
            }
        }
    }

    /// Restore the heap property downward from the root after a
    /// replacement.
    fn sift_down(&mut self, mut i: usize) {
        let n = self.heap.len();
        loop {
            let l = 2 * i + 1;
            if l >= n {
                break;
            }
            let r = l + 1;
            let mut worst = l;
            if r < n && ranks_ahead(self.heap[l], self.heap[r]) {
                worst = r;
            }
            if ranks_ahead(self.heap[i], self.heap[worst]) {
                self.heap.swap(i, worst);
                i = worst;
            } else {
                break;
            }
        }
    }
}

impl Default for TopK {
    fn default() -> Self {
        Self::new()
    }
}

/// Cursor-based k-way merge of per-shard selections into `out`,
/// best-first, bounded by `k`.  Each shard's retained hits are sorted
/// in place (consuming the heap order — [`TopK::reset`] before
/// reusing a selector) and then drained through per-shard cursors
/// held in `cursors`.  Allocation-free once the scratch buffers are
/// warm.
pub fn merge_shards_into(
    shards: &mut [TopK],
    cursors: &mut Vec<usize>,
    k: usize,
    out: &mut Vec<Hit>,
) {
    out.clear();
    cursors.clear();
    cursors.resize(shards.len(), 0);
    for s in shards.iter_mut() {
        s.heap.sort_unstable_by(best_first);
    }
    while out.len() < k {
        let mut best: Option<usize> = None;
        for (si, s) in shards.iter().enumerate() {
            let c = cursors[si];
            if c >= s.heap.len() {
                continue;
            }
            best = match best {
                Some(bi) if !ranks_ahead(s.heap[c], shards[bi].heap[cursors[bi]]) => Some(bi),
                _ => Some(si),
            };
        }
        match best {
            Some(si) => {
                out.push(shards[si].heap[cursors[si]]);
                cursors[si] += 1;
            }
            None => break,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Rng;

    fn drain_sorted(t: &mut TopK, k: usize) -> Vec<Hit> {
        let mut cursors = Vec::new();
        let mut out = Vec::new();
        merge_shards_into(std::slice::from_mut(t), &mut cursors, k, &mut out);
        out
    }

    #[test]
    fn empty_selector_merges_to_nothing() {
        let mut t = TopK::new();
        t.reset(5);
        assert!(t.is_empty());
        assert!(drain_sorted(&mut t, 5).is_empty());
    }

    #[test]
    fn k_larger_than_candidates_returns_all_sorted() {
        let mut t = TopK::new();
        t.reset(10);
        t.offer(0, 0.25);
        t.offer(1, 0.75);
        t.offer(2, 0.5);
        let out = drain_sorted(&mut t, 10);
        assert_eq!(out.len(), 3);
        assert_eq!(out[0], Hit { id: 1, score: 0.75 });
        assert_eq!(out[1], Hit { id: 2, score: 0.5 });
        assert_eq!(out[2], Hit { id: 0, score: 0.25 });
    }

    #[test]
    fn ties_rank_by_smaller_id_like_argsort_desc() {
        let mut t = TopK::new();
        t.reset(2);
        t.offer(7, 1.0);
        t.offer(3, 1.0);
        t.offer(5, 1.0);
        let out = drain_sorted(&mut t, 2);
        assert_eq!(out.iter().map(|h| h.id).collect::<Vec<_>>(), vec![3, 5]);
    }

    #[test]
    fn evictions_count_root_replacements() {
        let mut t = TopK::new();
        t.reset(1);
        t.offer(0, 0.1);
        t.offer(1, 0.2); // replaces
        t.offer(2, 0.05); // rejected
        t.offer(3, 0.3); // replaces
        assert_eq!(t.evictions(), 2);
        assert_eq!(t.offered(), 4);
        assert_eq!(drain_sorted(&mut t, 1)[0].id, 3);
    }

    /// Property: distributing the same candidate stream across 1, 3 or
    /// 7 shard selectors and k-way merging yields exactly the result
    /// of one full sort (shard boundaries must be invisible).
    #[test]
    fn shard_split_is_invisible_to_the_merge() {
        let mut rng = Rng::new(0x70_9c);
        for &k in &[1usize, 4, 16, 100] {
            let n = 257;
            let cand: Vec<Hit> = (0..n)
                .map(|i| Hit {
                    id: i as u64,
                    // quantized scores force plenty of ties
                    score: ((rng.next_u64() % 17) as f32) / 16.0,
                })
                .collect();
            let mut reference = cand.clone();
            reference.sort_unstable_by(best_first);
            reference.truncate(k);
            for &nshards in &[1usize, 3, 7] {
                let mut shards: Vec<TopK> = (0..nshards).map(|_| TopK::new()).collect();
                for s in shards.iter_mut() {
                    s.reset(k);
                }
                for (i, h) in cand.iter().enumerate() {
                    shards[i % nshards].offer(h.id, h.score);
                }
                let mut cursors = Vec::new();
                let mut out = Vec::new();
                merge_shards_into(&mut shards, &mut cursors, k, &mut out);
                assert_eq!(out, reference, "k={k} nshards={nshards}");
            }
        }
    }

    #[test]
    fn nan_scores_rank_behind_everything() {
        let mut t = TopK::new();
        t.reset(2);
        t.offer(0, f32::NAN);
        t.offer(1, -1.0);
        t.offer(2, 0.5);
        let out = drain_sorted(&mut t, 2);
        assert_eq!(out[0].id, 2);
        assert_eq!(out[1].id, 1);
    }
}
