//! Embedding gallery: embed once, score millions.
//!
//! The paper's CLIP retrieval result is an embed-heavy, score-light
//! workload — the expensive merged-tower forward should be amortized
//! across millions of cheap cosine scores, not re-run per pair.  This
//! module is the serving-side answer: a persistent, shard-partitioned
//! [`GalleryStore`] of fixed-dimension embeddings plus blocked
//! matrix–vector scan kernels ([`scan_into`], [`scan_two_stage_into`])
//! with bounded per-shard top-k selection ([`TopK`]) and a k-way
//! shard merge.
//!
//! The coordinator wires this in as `Workload::Gallery`: ingest
//! requests embed once through the `JointSession` towers and append
//! to the store; query requests embed one probe and scan.  Everything
//! on the query path writes into reusable scratch
//! ([`GalleryScratch`]) and pooled response buffers, so a warmed
//! query→top-k cycle allocates nothing (`tests/alloc_free.rs`).

pub mod scan;
pub mod store;
pub mod topk;

pub use scan::{scan_into, scan_two_stage_into, GalleryScratch, ScanMode, ScanStats};
pub use store::{GalleryOptions, GalleryStore};
pub use topk::{merge_shards_into, ranks_ahead, Hit, TopK};
