//! Persistent, shard-partitioned embedding store.
//!
//! A [`GalleryStore`] holds fixed-dimension f32 embedding rows in
//! append-only segments, partitioned across independently locked
//! shards so ingest (a shard write lock) never stalls queries on the
//! other shards (shard read locks).  Each row's L2 norm is stored at
//! ingest, and every segment maintains per-block coordinate sums so
//! the two-stage scan can score coarse block centroids without
//! touching the rows.  The store can snapshot itself to disk and load
//! back for persistence across boots.
//!
//! Row ids are `local_index * n_shards + shard`: single-threaded
//! ingest into an empty store assigns ids equal to the insertion
//! order (the round-robin cursor and the id layout agree), which the
//! retrieval eval relies on for parity with the dense reference.

use std::io::{Read as _, Write as _};
use std::path::Path;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::RwLock;

use crate::error::{Error, Result};

/// Magic prefix of the snapshot file format.
const SNAP_MAGIC: &[u8; 4] = b"PGAL";
/// Snapshot format version.
const SNAP_VERSION: u32 = 1;

/// Tuning knobs for [`GalleryStore`].
#[derive(Clone, Copy, Debug)]
pub struct GalleryOptions {
    /// Number of independently locked shards.
    pub shards: usize,
    /// Rows per append-only segment (segment capacity).
    pub seg_rows: usize,
    /// Rows per coarse block (two-stage search granularity).
    pub block_rows: usize,
}

impl Default for GalleryOptions {
    fn default() -> Self {
        GalleryOptions { shards: 8, seg_rows: 4096, block_rows: 256 }
    }
}

/// One append-only run of rows inside a shard.
pub(crate) struct Segment {
    /// Row-major embeddings, `rows * dim` values.
    pub(crate) data: Vec<f32>,
    /// Per-row L2 norms, stored at ingest.
    pub(crate) norms: Vec<f32>,
    /// Per-block coordinate sums (`n_blocks * dim`), maintained on
    /// append; block centroids are `sum / rows_in_block`.
    pub(crate) block_sums: Vec<f32>,
    /// Rows currently in the segment.
    pub(crate) rows: usize,
}

/// One lock domain: a list of segments plus its row count.
pub(crate) struct Shard {
    /// Append-only segments, oldest first.
    pub(crate) segs: Vec<Segment>,
    /// Total rows across segments.
    pub(crate) rows: usize,
}

impl Shard {
    /// Append one row, opening a new segment when the last is full
    /// and folding the row into its block's coordinate sums.
    // lint: allow(alloc) reason=cold ingest path: append-only segment growth, never on the query path
    fn append(&mut self, emb: &[f32], dim: usize, opts: &GalleryOptions) {
        let need_new = self.segs.last().map_or(true, |s| s.rows == opts.seg_rows);
        if need_new {
            self.segs.push(Segment {
                data: Vec::with_capacity(opts.seg_rows * dim),
                norms: Vec::with_capacity(opts.seg_rows),
                block_sums: Vec::new(),
                rows: 0,
            });
        }
        let seg = self.segs.last_mut().expect("segment just ensured");
        let b = seg.rows / opts.block_rows;
        if (b + 1) * dim > seg.block_sums.len() {
            seg.block_sums.resize((b + 1) * dim, 0.0);
        }
        let sums = &mut seg.block_sums[b * dim..(b + 1) * dim];
        let mut norm2 = 0.0f32;
        for (s, &x) in sums.iter_mut().zip(emb) {
            *s += x;
            norm2 += x * x;
        }
        seg.data.extend_from_slice(emb);
        seg.norms.push(norm2.sqrt());
        seg.rows += 1;
        self.rows += 1;
    }
}

/// Sharded, append-only embedding gallery.  See the module docs for
/// the locking and id-assignment contracts.
pub struct GalleryStore {
    dim: usize,
    opts: GalleryOptions,
    shards: Vec<RwLock<Shard>>,
    /// Round-robin ingest cursor (reserves shard slots, not ids).
    rr: AtomicUsize,
}

impl GalleryStore {
    /// Empty store for `dim`-dimensional embeddings.  Degenerate
    /// options are clamped to 1 so the store is always usable.
    // lint: allow(alloc) reason=cold constructor: empty shard table built once per gallery
    pub fn new(dim: usize, opts: GalleryOptions) -> Self {
        let opts = GalleryOptions {
            shards: opts.shards.max(1),
            seg_rows: opts.seg_rows.max(1),
            block_rows: opts.block_rows.max(1),
        };
        let shards = (0..opts.shards)
            .map(|_| RwLock::new(Shard { segs: Vec::new(), rows: 0 }))
            .collect();
        GalleryStore { dim, opts, shards, rr: AtomicUsize::new(0) }
    }

    /// Empty store with default [`GalleryOptions`].
    pub fn with_dim(dim: usize) -> Self {
        Self::new(dim, GalleryOptions::default())
    }

    /// Embedding dimension.
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Number of shards.
    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// The (clamped) options the store was built with.
    pub fn options(&self) -> GalleryOptions {
        self.opts
    }

    /// Total rows across all shards (takes each shard's read lock).
    pub fn len(&self) -> usize {
        self.shards
            .iter()
            .map(|s| s.read().expect("gallery shard lock poisoned").rows)
            .sum()
    }

    /// `true` when no rows have been ingested.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Shard lock for the scan kernels.
    pub(crate) fn shard(&self, s: usize) -> &RwLock<Shard> {
        &self.shards[s]
    }

    /// Ingest one embedding row; returns its stable id.  Takes a
    /// single shard write lock, so queries on other shards proceed
    /// concurrently.
    pub fn ingest(&self, emb: &[f32]) -> Result<u64> {
        if emb.len() != self.dim {
            return Err(Error::Shape("gallery ingest row has wrong dimension".into()));
        }
        let ns = self.shards.len();
        let s = self.rr.fetch_add(1, Ordering::Relaxed) % ns;
        let mut shard = self.shards[s].write().expect("gallery shard lock poisoned");
        let local = shard.rows;
        shard.append(emb, self.dim, &self.opts);
        Ok((local * ns + s) as u64)
    }

    /// Bulk-ingest `rows.len() / dim` rows, locking each shard once.
    /// Rows are distributed round-robin exactly as repeated
    /// [`GalleryStore::ingest`] calls would; returns the row count.
    pub fn ingest_bulk(&self, rows: &[f32]) -> Result<usize> {
        if self.dim == 0 || rows.len() % self.dim != 0 {
            return Err(Error::Shape("gallery bulk ingest not a multiple of dim".into()));
        }
        let n = rows.len() / self.dim;
        let ns = self.shards.len();
        let start = self.rr.fetch_add(n, Ordering::Relaxed);
        for off in 0..ns.min(n) {
            let s = (start + off) % ns;
            let mut shard = self.shards[s].write().expect("gallery shard lock poisoned");
            let mut i = off;
            while i < n {
                shard.append(&rows[i * self.dim..(i + 1) * self.dim], self.dim, &self.opts);
                i += ns;
            }
        }
        Ok(n)
    }

    /// Visit every row as `(id, row, stored_norm)` under shard read
    /// locks — for tests and benches building reference results.
    pub fn for_each_row(&self, mut f: impl FnMut(u64, &[f32], f32)) {
        let ns = self.shards.len();
        for (s, lock) in self.shards.iter().enumerate() {
            let shard = lock.read().expect("gallery shard lock poisoned");
            let mut local = 0usize;
            for seg in &shard.segs {
                for r in 0..seg.rows {
                    let row = &seg.data[r * self.dim..(r + 1) * self.dim];
                    f(((local + r) * ns + s) as u64, row, seg.norms[r]);
                }
                local += seg.rows;
            }
        }
    }

    /// Write the gallery to `path` (magic + version + dim + shard
    /// layout + per-shard rows).  Cold persistence path.
    // lint: allow(alloc) reason=cold persistence path: one write buffer per snapshot
    pub fn snapshot_to(&self, path: &Path) -> Result<()> {
        let mut buf: Vec<u8> = Vec::new();
        buf.extend_from_slice(SNAP_MAGIC);
        buf.extend_from_slice(&SNAP_VERSION.to_le_bytes());
        buf.extend_from_slice(&(self.dim as u64).to_le_bytes());
        buf.extend_from_slice(&(self.shards.len() as u64).to_le_bytes());
        for lock in &self.shards {
            let shard = lock.read().expect("gallery shard lock poisoned");
            buf.extend_from_slice(&(shard.rows as u64).to_le_bytes());
            for seg in &shard.segs {
                for x in &seg.data[..seg.rows * self.dim] {
                    buf.extend_from_slice(&x.to_le_bytes());
                }
            }
        }
        let mut f = std::fs::File::create(path)?;
        f.write_all(&buf)?;
        Ok(())
    }

    /// Load a snapshot written by [`GalleryStore::snapshot_to`].  The
    /// shard count comes from the file; `opts.seg_rows`/`block_rows`
    /// shape the rebuilt segments (norms and block sums are
    /// recomputed on append).
    // lint: allow(alloc) reason=cold persistence path: one read buffer per load
    pub fn load(path: &Path, opts: GalleryOptions) -> Result<Self> {
        let mut bytes: Vec<u8> = Vec::new();
        std::fs::File::open(path)?.read_to_end(&mut bytes)?;
        let mut off = 0usize;
        fn take<'a>(bytes: &'a [u8], off: &mut usize, n: usize) -> Result<&'a [u8]> {
            if *off + n > bytes.len() {
                return Err(Error::Io(std::io::Error::new(
                    std::io::ErrorKind::UnexpectedEof,
                    "gallery snapshot truncated",
                )));
            }
            let s = &bytes[*off..*off + n];
            *off += n;
            Ok(s)
        }
        if take(&bytes, &mut off, 4)? != SNAP_MAGIC {
            return Err(Error::Config("not a gallery snapshot (bad magic)".into()));
        }
        let ver = u32::from_le_bytes(take(&bytes, &mut off, 4)?.try_into().expect("4 bytes"));
        if ver != SNAP_VERSION {
            return Err(Error::Config("unsupported gallery snapshot version".into()));
        }
        let dim = u64::from_le_bytes(take(&bytes, &mut off, 8)?.try_into().expect("8 bytes")) as usize;
        let ns = u64::from_le_bytes(take(&bytes, &mut off, 8)?.try_into().expect("8 bytes")) as usize;
        if dim == 0 || ns == 0 {
            return Err(Error::Config("gallery snapshot has empty layout".into()));
        }
        let store = Self::new(dim, GalleryOptions { shards: ns, ..opts });
        let mut total = 0usize;
        let mut row = vec![0.0f32; dim];
        for lock in &store.shards {
            let rows = u64::from_le_bytes(take(&bytes, &mut off, 8)?.try_into().expect("8 bytes")) as usize;
            let mut shard = lock.write().expect("gallery shard lock poisoned");
            for _ in 0..rows {
                let raw = take(&bytes, &mut off, dim * 4)?;
                for (d, chunk) in row.iter_mut().zip(raw.chunks_exact(4)) {
                    *d = f32::from_le_bytes(chunk.try_into().expect("4 bytes"));
                }
                shard.append(&row, dim, &store.opts);
            }
            total += rows;
        }
        store.rr.store(total, Ordering::Relaxed);
        Ok(store)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Rng;

    fn random_rows(rng: &mut Rng, n: usize, dim: usize) -> Vec<f32> {
        (0..n * dim).map(|_| rng.next_f64() as f32 - 0.5).collect()
    }

    #[test]
    fn sequential_ingest_assigns_ids_in_insertion_order() {
        let store = GalleryStore::new(4, GalleryOptions { shards: 3, ..Default::default() });
        for i in 0..20u64 {
            let id = store.ingest(&[i as f32; 4]).expect("ingest");
            assert_eq!(id, i);
        }
        assert_eq!(store.len(), 20);
        let mut seen = vec![false; 20];
        store.for_each_row(|id, row, _| {
            assert_eq!(row[0] as u64, id);
            seen[id as usize] = true;
        });
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn bulk_ingest_matches_repeated_single_ingest() {
        let mut rng = Rng::new(0xB0_17);
        let rows = random_rows(&mut rng, 37, 8);
        let opts = GalleryOptions { shards: 4, seg_rows: 8, block_rows: 4 };
        let a = GalleryStore::new(8, opts);
        let b = GalleryStore::new(8, opts);
        for r in rows.chunks(8) {
            a.ingest(r).expect("ingest");
        }
        assert_eq!(b.ingest_bulk(&rows).expect("bulk"), 37);
        let mut rows_a: Vec<(u64, Vec<f32>, f32)> = Vec::new();
        a.for_each_row(|id, row, n| rows_a.push((id, row.to_vec(), n)));
        let mut i = 0;
        b.for_each_row(|id, row, n| {
            assert_eq!((id, row, n), (rows_a[i].0, &rows_a[i].1[..], rows_a[i].2));
            i += 1;
        });
        assert_eq!(i, 37);
    }

    #[test]
    fn stored_norms_match_row_l2() {
        let store = GalleryStore::new(3, GalleryOptions { shards: 2, ..Default::default() });
        store.ingest(&[3.0, 4.0, 0.0]).expect("ingest");
        store.for_each_row(|_, _, n| assert!((n - 5.0).abs() < 1e-6));
    }

    #[test]
    fn block_sums_track_appended_rows() {
        let opts = GalleryOptions { shards: 1, seg_rows: 8, block_rows: 2 };
        let store = GalleryStore::new(2, opts);
        for i in 0..5 {
            store.ingest(&[i as f32, 1.0]).expect("ingest");
        }
        let shard = store.shard(0).read().expect("lock");
        let seg = &shard.segs[0];
        // blocks: [0,1] [2,3] [4]
        assert_eq!(seg.block_sums.len(), 6);
        assert_eq!(&seg.block_sums[0..2], &[1.0, 2.0]);
        assert_eq!(&seg.block_sums[2..4], &[5.0, 2.0]);
        assert_eq!(&seg.block_sums[4..6], &[4.0, 1.0]);
    }

    #[test]
    fn snapshot_roundtrip_preserves_rows_ids_and_norms() {
        let mut rng = Rng::new(0x51A9);
        let opts = GalleryOptions { shards: 3, seg_rows: 16, block_rows: 4 };
        let store = GalleryStore::new(6, opts);
        store.ingest_bulk(&random_rows(&mut rng, 41, 6)).expect("bulk");
        let path = std::env::temp_dir().join("pitome_gallery_snap_test.bin");
        store.snapshot_to(&path).expect("snapshot");
        let loaded = GalleryStore::load(&path, opts).expect("load");
        std::fs::remove_file(&path).ok();
        assert_eq!(loaded.len(), 41);
        assert_eq!(loaded.n_shards(), 3);
        let mut orig: Vec<(u64, Vec<f32>, f32)> = Vec::new();
        store.for_each_row(|id, row, n| orig.push((id, row.to_vec(), n)));
        let mut i = 0;
        loaded.for_each_row(|id, row, n| {
            assert_eq!((id, row, n), (orig[i].0, &orig[i].1[..], orig[i].2));
            i += 1;
        });
        // ingest after load continues the id sequence
        let next = loaded.ingest(&[0.0; 6]).expect("ingest");
        assert_eq!(next, 41);
    }
}
