//! Blocked matrix–vector scan kernels over a [`GalleryStore`].
//!
//! [`scan_into`] is the exact kernel: every row is scored against the
//! probe with the lane-split dot product
//! (`dot_with_lanes::<DOT_LANES>`, the same kernel the `CosineGram`
//! machinery blocks over), the best `k` per shard are kept in a
//! bounded heap, and the per-shard selections are k-way merged.  With
//! `workers > 1` disjoint shard ranges scan on scoped threads;
//! results are bitwise identical at any worker count because shard
//! selections never interact until the deterministic merge.
//!
//! [`scan_two_stage_into`] is the coarse-then-exact variant: stage
//! one ranks per-block centroids (maintained by the store as
//! coordinate sums), stage two rescans only the best `probe_blocks`
//! blocks exactly.  It is approximate; `gallery_bench` reports its
//! recall@k against the exact scan.
//!
//! All kernels write into caller-owned scratch and output buffers, so
//! a warmed query→top-k cycle performs zero allocations.

use super::store::GalleryStore;
use super::topk::{merge_shards_into, Hit, TopK};
use crate::error::{Error, Result};
use crate::obs::{RingWriter, SpanEvent, Stage};
use crate::tensor::{dot_with_lanes, DOT_LANES};

/// How row similarities are scored.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScanMode {
    /// Raw dot product — exact cosine when the gallery holds
    /// unit-norm embeddings (the serving path stores
    /// `JointSession::project` output), and bitwise-identical to
    /// `JointSession::score` on the same embeddings.
    Dot,
    /// Dot product normalized by the stored row norm and the probe
    /// norm (zero-norm rows score 0).
    Cosine,
}

/// Counters from one scan.
#[derive(Clone, Copy, Debug, Default)]
pub struct ScanStats {
    /// Rows scored exactly.
    pub rows: u64,
    /// Top-k heap root replacements (evictions).
    pub evictions: u64,
    /// Coarse blocks rescanned exactly (two-stage only).
    pub blocks_probed: u64,
    /// Coarse blocks present at scan time (two-stage only).
    pub blocks_total: u64,
}

/// One coarse candidate block in the two-stage scan.
#[derive(Clone, Copy)]
struct BlockRef {
    score: f32,
    shard: u32,
    seg: u32,
    block: u32,
}

/// Reusable per-caller scan state: per-shard heaps, merge cursors and
/// the coarse block-score buffer.  Keeping one scratch per worker
/// makes a warmed query→top-k cycle allocation-free.
pub struct GalleryScratch {
    topks: Vec<TopK>,
    cursors: Vec<usize>,
    blocks: Vec<BlockRef>,
    /// span recorder for this scratch's owning worker (recording stays
    /// on the calling thread — scoped scan workers never touch it, so
    /// the ring's single-producer contract holds)
    recorder: Option<RingWriter>,
    /// monotonically increasing query ordinal stamped on scan spans
    queries: u64,
}

impl GalleryScratch {
    /// Empty scratch; buffers warm on first use.
    // lint: allow(alloc) reason=cold constructor: empty scratch spines, warmed by the first query
    pub fn new() -> Self {
        GalleryScratch { topks: Vec::new(), cursors: Vec::new(),
                         blocks: Vec::new(), recorder: None, queries: 0 }
    }

    /// Attach (or detach) a span recorder: subsequent scans record
    /// coarse-rank / exact-scan / rescan / k-way-merge spans through it.
    /// Cold path: call once when the owning worker boots.
    pub fn set_recorder(&mut self, rec: Option<RingWriter>) {
        self.recorder = rec;
    }

    /// Next query ordinal (advances the counter).
    fn next_query(&mut self) -> u64 {
        let q = self.queries;
        self.queries += 1;
        q
    }
}

impl Default for GalleryScratch {
    fn default() -> Self {
        Self::new()
    }
}

/// Inverse probe norm for [`ScanMode::Cosine`] (1.0 under
/// [`ScanMode::Dot`], 0.0 for a zero probe).
fn inv_probe_norm(probe: &[f32], mode: ScanMode) -> f32 {
    match mode {
        ScanMode::Dot => 1.0,
        ScanMode::Cosine => {
            let n = dot_with_lanes::<DOT_LANES>(probe, probe).sqrt();
            if n > 0.0 {
                1.0 / n
            } else {
                0.0
            }
        }
    }
}

/// Score one raw dot product under `mode`.
#[inline]
fn score_row(d: f32, norm: f32, mode: ScanMode, inv_probe: f32) -> f32 {
    match mode {
        ScanMode::Dot => d,
        ScanMode::Cosine => {
            if norm > 0.0 {
                d * inv_probe / norm
            } else {
                0.0
            }
        }
    }
}

/// Scan one shard into its bounded selector, block by block.
fn scan_shard(
    store: &GalleryStore,
    s: usize,
    probe: &[f32],
    mode: ScanMode,
    inv_probe: f32,
    top: &mut TopK,
) {
    let dim = store.dim();
    let ns = store.n_shards();
    let block_rows = store.options().block_rows;
    let shard = store.shard(s).read().expect("gallery shard lock poisoned");
    let mut local = 0usize;
    for seg in &shard.segs {
        let mut r0 = 0usize;
        while r0 < seg.rows {
            let r1 = (r0 + block_rows).min(seg.rows);
            for r in r0..r1 {
                let row = &seg.data[r * dim..(r + 1) * dim];
                let d = dot_with_lanes::<DOT_LANES>(probe, row);
                let score = score_row(d, seg.norms[r], mode, inv_probe);
                top.offer(((local + r) * ns + s) as u64, score);
            }
            r0 = r1;
        }
        local += seg.rows;
    }
}

/// Exact scan: score the probe against every row, keep the best `k`
/// per shard, and k-way merge the shard selections into `out`
/// (best-first, ties by smaller id).  `workers > 1` scans disjoint
/// shard ranges on scoped threads; the result is identical at any
/// worker count.  Allocation-free once `scratch` and `out` are warm
/// (thread spawns under `workers > 1` allocate in the OS, so the
/// zero-alloc serving contract applies to `workers == 1`).
pub fn scan_into(
    store: &GalleryStore,
    probe: &[f32],
    k: usize,
    mode: ScanMode,
    workers: usize,
    scratch: &mut GalleryScratch,
    out: &mut Vec<Hit>,
) -> Result<ScanStats> {
    if probe.len() != store.dim() {
        return Err(Error::Shape("gallery probe has wrong dimension".into()));
    }
    let qid = scratch.next_query();
    let t0 = scratch.recorder.as_ref().map(|r| r.now_us());
    let ns = store.n_shards();
    while scratch.topks.len() < ns {
        scratch.topks.push(TopK::new());
    }
    for t in scratch.topks[..ns].iter_mut() {
        t.reset(k);
    }
    let inv_probe = inv_probe_norm(probe, mode);
    let workers = workers.max(1).min(ns);
    if workers <= 1 {
        for (s, t) in scratch.topks[..ns].iter_mut().enumerate() {
            scan_shard(store, s, probe, mode, inv_probe, t);
        }
    } else {
        let chunk = ns.div_ceil(workers);
        let topks = &mut scratch.topks[..ns];
        std::thread::scope(|scope| {
            for (ci, tchunk) in topks.chunks_mut(chunk).enumerate() {
                scope.spawn(move || {
                    for (off, t) in tchunk.iter_mut().enumerate() {
                        scan_shard(store, ci * chunk + off, probe, mode, inv_probe, t);
                    }
                });
            }
        });
    }
    let mut stats = ScanStats::default();
    for t in scratch.topks[..ns].iter() {
        stats.rows += t.offered();
        stats.evictions += t.evictions();
    }
    let t1 = scratch.recorder.as_ref().map(|r| r.now_us());
    merge_shards_into(&mut scratch.topks[..ns], &mut scratch.cursors, k, out);
    if let Some(r) = scratch.recorder.as_ref() {
        r.record(SpanEvent {
            stage: Stage::GalleryScan,
            id: qid,
            t_start_us: t0.unwrap_or(0),
            t_end_us: t1.unwrap_or(0),
            payload: stats.rows.min(u32::MAX as u64) as u32,
            a: stats.evictions as f32,
            b: 0.0,
        });
        r.span_since(Stage::GalleryMerge, qid, t1.unwrap_or(0),
                     out.len() as u32);
    }
    Ok(stats)
}

/// Coarse-then-exact scan: rank per-block centroids by mean dot
/// product against the probe, then rescan only the best
/// `probe_blocks` blocks exactly (serial).  Approximate by design —
/// recall@k against [`scan_into`] is workload-dependent and reported
/// by `gallery_bench`.  Probing every block reproduces the exact
/// result.  Allocation-free once `scratch` and `out` are warm.
pub fn scan_two_stage_into(
    store: &GalleryStore,
    probe: &[f32],
    k: usize,
    probe_blocks: usize,
    mode: ScanMode,
    scratch: &mut GalleryScratch,
    out: &mut Vec<Hit>,
) -> Result<ScanStats> {
    if probe.len() != store.dim() {
        return Err(Error::Shape("gallery probe has wrong dimension".into()));
    }
    let qid = scratch.next_query();
    let t0 = scratch.recorder.as_ref().map(|r| r.now_us());
    let dim = store.dim();
    let ns = store.n_shards();
    let block_rows = store.options().block_rows;
    if scratch.topks.is_empty() {
        scratch.topks.push(TopK::new());
    }
    scratch.topks[0].reset(k);
    let inv_probe = inv_probe_norm(probe, mode);
    // stage one: score every block centroid (sum / rows_in_block)
    scratch.blocks.clear();
    for s in 0..ns {
        let shard = store.shard(s).read().expect("gallery shard lock poisoned");
        for (gi, seg) in shard.segs.iter().enumerate() {
            let mut b = 0usize;
            let mut r0 = 0usize;
            while r0 < seg.rows {
                let r1 = (r0 + block_rows).min(seg.rows);
                let sums = &seg.block_sums[b * dim..(b + 1) * dim];
                let d = dot_with_lanes::<DOT_LANES>(probe, sums);
                let score = d / (r1 - r0) as f32;
                scratch.blocks.push(BlockRef {
                    score,
                    shard: s as u32,
                    seg: gi as u32,
                    block: b as u32,
                });
                b += 1;
                r0 = r1;
            }
        }
    }
    let total = scratch.blocks.len();
    let nprobe = probe_blocks.min(total);
    // total_cmp keeps the comparator a strict total order even if NaN
    // embeddings were ingested (partial_cmp's Equal fallback violated
    // transitivity, which sort_unstable_by may detect and panic on)
    scratch.blocks.sort_unstable_by(|a, b| {
        b.score
            .total_cmp(&a.score)
            .then((a.shard, a.seg, a.block).cmp(&(b.shard, b.seg, b.block)))
    });
    let t1 = scratch.recorder.as_ref().map(|r| r.now_us());
    if let Some(r) = scratch.recorder.as_ref() {
        r.record(SpanEvent {
            stage: Stage::GalleryCoarse,
            id: qid,
            t_start_us: t0.unwrap_or(0),
            t_end_us: t1.unwrap_or(0),
            payload: total.min(u32::MAX as usize) as u32,
            a: nprobe as f32,
            b: 0.0,
        });
    }
    // stage two: exact rescan of the selected blocks
    for br in scratch.blocks[..nprobe].iter() {
        let s = br.shard as usize;
        let shard = store.shard(s).read().expect("gallery shard lock poisoned");
        let seg = &shard.segs[br.seg as usize];
        let mut base = 0usize;
        for g in 0..br.seg as usize {
            base += shard.segs[g].rows;
        }
        let r0 = br.block as usize * block_rows;
        let r1 = (r0 + block_rows).min(seg.rows);
        for r in r0..r1 {
            let row = &seg.data[r * dim..(r + 1) * dim];
            let d = dot_with_lanes::<DOT_LANES>(probe, row);
            let score = score_row(d, seg.norms[r], mode, inv_probe);
            scratch.topks[0].offer(((base + r) * ns + s) as u64, score);
        }
    }
    let stats = ScanStats {
        rows: scratch.topks[0].offered(),
        evictions: scratch.topks[0].evictions(),
        blocks_probed: nprobe as u64,
        blocks_total: total as u64,
    };
    let t2 = scratch.recorder.as_ref().map(|r| r.now_us());
    if let Some(r) = scratch.recorder.as_ref() {
        r.record(SpanEvent {
            stage: Stage::GalleryRescan,
            id: qid,
            t_start_us: t1.unwrap_or(0),
            t_end_us: t2.unwrap_or(0),
            payload: stats.rows.min(u32::MAX as u64) as u32,
            a: nprobe as f32,
            b: 0.0,
        });
    }
    merge_shards_into(&mut scratch.topks[..1], &mut scratch.cursors, k, out);
    if let Some(r) = scratch.recorder.as_ref() {
        r.span_since(Stage::GalleryMerge, qid, t2.unwrap_or(0),
                     out.len() as u32);
    }
    Ok(stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Rng;
    use crate::gallery::store::GalleryOptions;

    fn build_store(n: usize, dim: usize, shards: usize, seed: u64) -> GalleryStore {
        let opts = GalleryOptions { shards, seg_rows: 32, block_rows: 8 };
        let store = GalleryStore::new(dim, opts);
        let mut rng = Rng::new(seed);
        let rows: Vec<f32> = (0..n * dim).map(|_| rng.next_f64() as f32 - 0.5).collect();
        store.ingest_bulk(&rows).expect("bulk ingest");
        store
    }

    fn naive_topk(store: &GalleryStore, probe: &[f32], k: usize, mode: ScanMode) -> Vec<Hit> {
        let inv_probe = inv_probe_norm(probe, mode);
        let mut all: Vec<Hit> = Vec::new();
        store.for_each_row(|id, row, norm| {
            let d = dot_with_lanes::<DOT_LANES>(probe, row);
            all.push(Hit { id, score: score_row(d, norm, mode, inv_probe) });
        });
        all.sort_unstable_by(|a, b| {
            b.score.total_cmp(&a.score).then(a.id.cmp(&b.id))
        });
        all.truncate(k);
        all
    }

    fn probe_for(dim: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..dim).map(|_| rng.next_f64() as f32 - 0.5).collect()
    }

    #[test]
    fn exact_scan_matches_naive_reference() {
        for &mode in &[ScanMode::Dot, ScanMode::Cosine] {
            let store = build_store(301, 16, 4, 0x5CA1);
            let probe = probe_for(16, 0x90_B3);
            let mut scratch = GalleryScratch::new();
            let mut out = Vec::new();
            let stats =
                scan_into(&store, &probe, 10, mode, 1, &mut scratch, &mut out).expect("scan");
            assert_eq!(stats.rows, 301);
            assert_eq!(out, naive_topk(&store, &probe, 10, mode), "{mode:?}");
        }
    }

    /// Property: shard partitioning is invisible — stores built with
    /// 1, 3 and 7 shards return identical hits for the same data.
    #[test]
    fn shard_count_does_not_change_results() {
        let probe = probe_for(12, 0xFEED);
        let reference = {
            let store = build_store(157, 12, 1, 0xABCD);
            naive_topk(&store, &probe, 8, ScanMode::Dot)
        };
        for &shards in &[1usize, 3, 7] {
            let store = build_store(157, 12, shards, 0xABCD);
            let mut scratch = GalleryScratch::new();
            let mut out = Vec::new();
            scan_into(&store, &probe, 8, ScanMode::Dot, 1, &mut scratch, &mut out).expect("scan");
            // ids differ across shard layouts only in shard assignment;
            // ingest order is round-robin so id == insertion index for
            // every layout, making results directly comparable.
            assert_eq!(out, reference, "shards={shards}");
        }
    }

    #[test]
    fn worker_count_does_not_change_results() {
        let store = build_store(223, 8, 5, 0x1D_E5);
        let probe = probe_for(8, 0x77);
        let mut scratch = GalleryScratch::new();
        let mut serial = Vec::new();
        scan_into(&store, &probe, 7, ScanMode::Dot, 1, &mut scratch, &mut serial).expect("scan");
        for &w in &[2usize, 3, 8] {
            let mut out = Vec::new();
            scan_into(&store, &probe, 7, ScanMode::Dot, w, &mut scratch, &mut out).expect("scan");
            assert_eq!(out, serial, "workers={w}");
        }
    }

    #[test]
    fn empty_gallery_returns_no_hits() {
        let store = GalleryStore::with_dim(8);
        let probe = probe_for(8, 0x0);
        let mut scratch = GalleryScratch::new();
        let mut out = Vec::new();
        let stats =
            scan_into(&store, &probe, 5, ScanMode::Dot, 2, &mut scratch, &mut out).expect("scan");
        assert!(out.is_empty());
        assert_eq!(stats.rows, 0);
        let stats = scan_two_stage_into(&store, &probe, 5, 4, ScanMode::Dot, &mut scratch, &mut out)
            .expect("two-stage");
        assert!(out.is_empty());
        assert_eq!(stats.blocks_total, 0);
    }

    #[test]
    fn k_larger_than_gallery_returns_everything_ranked() {
        let store = build_store(9, 4, 3, 0xB00);
        let probe = probe_for(4, 0x1);
        let mut scratch = GalleryScratch::new();
        let mut out = Vec::new();
        scan_into(&store, &probe, 50, ScanMode::Dot, 1, &mut scratch, &mut out).expect("scan");
        assert_eq!(out.len(), 9);
        assert_eq!(out, naive_topk(&store, &probe, 50, ScanMode::Dot));
    }

    #[test]
    fn two_stage_probing_all_blocks_is_exact() {
        let store = build_store(301, 16, 4, 0x5CA1);
        let probe = probe_for(16, 0x90_B3);
        let mut scratch = GalleryScratch::new();
        let mut exact = Vec::new();
        scan_into(&store, &probe, 10, ScanMode::Dot, 1, &mut scratch, &mut exact).expect("scan");
        let mut out = Vec::new();
        let stats = scan_two_stage_into(
            &store,
            &probe,
            10,
            usize::MAX,
            ScanMode::Dot,
            &mut scratch,
            &mut out,
        )
        .expect("two-stage");
        assert_eq!(stats.blocks_probed, stats.blocks_total);
        assert_eq!(out, exact);
    }

    #[test]
    fn two_stage_partial_probe_scans_fewer_rows() {
        let store = build_store(512, 8, 4, 0xCAFE);
        let probe = probe_for(8, 0xF00D);
        let mut scratch = GalleryScratch::new();
        let mut out = Vec::new();
        let stats =
            scan_two_stage_into(&store, &probe, 5, 8, ScanMode::Dot, &mut scratch, &mut out)
                .expect("two-stage");
        assert_eq!(stats.blocks_probed, 8);
        assert!(stats.blocks_total > 8);
        assert!(stats.rows < 512);
        assert!(!out.is_empty());
    }

    /// A recorder-attached scan returns identical hits and records the
    /// gallery stage spans with advancing query ordinals.
    #[test]
    fn instrumented_scans_record_spans_and_match_bare_results() {
        let store = build_store(301, 16, 4, 0x5CA1);
        let probe = probe_for(16, 0x90_B3);
        let mut bare = GalleryScratch::new();
        let mut want = Vec::new();
        scan_into(&store, &probe, 10, ScanMode::Dot, 1, &mut bare, &mut want)
            .expect("bare scan");

        let ring = crate::obs::SpanRing::with_capacity(64);
        let mut obs = GalleryScratch::new();
        obs.set_recorder(Some(ring.writer(std::time::Instant::now())));
        let mut out = Vec::new();
        scan_into(&store, &probe, 10, ScanMode::Dot, 1, &mut obs, &mut out)
            .expect("instrumented scan");
        assert_eq!(out, want, "recorder must not change results");
        scan_two_stage_into(&store, &probe, 10, 8, ScanMode::Dot, &mut obs,
                            &mut out)
            .expect("instrumented two-stage");
        let mut evs = Vec::new();
        ring.drain_into(&mut evs);
        let stages: Vec<Stage> = evs.iter().map(|e| e.stage).collect();
        assert_eq!(stages,
                   vec![Stage::GalleryScan, Stage::GalleryMerge,
                        Stage::GalleryCoarse, Stage::GalleryRescan,
                        Stage::GalleryMerge]);
        assert_eq!(evs[0].payload, 301, "exact scan scored every row");
        assert_eq!(evs[0].id, 0);
        assert_eq!(evs[2].id, 1, "query ordinal advances per scan");
        assert_eq!(evs[3].a, 8.0, "rescan probed 8 blocks");
    }

    #[test]
    fn probe_dimension_mismatch_is_an_error() {
        let store = GalleryStore::with_dim(8);
        let mut scratch = GalleryScratch::new();
        let mut out = Vec::new();
        assert!(scan_into(&store, &[0.0; 4], 5, ScanMode::Dot, 1, &mut scratch, &mut out).is_err());
    }
}
