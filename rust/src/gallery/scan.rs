//! Blocked matrix–vector scan kernels over a [`GalleryStore`].
//!
//! [`scan_into`] is the exact kernel: every row is scored against the
//! probe with the lane-split dot product
//! (`dot_with_lanes::<DOT_LANES>`, the same kernel the `CosineGram`
//! machinery blocks over), the best `k` per shard are kept in a
//! bounded heap, and the per-shard selections are k-way merged.  With
//! `workers > 1` disjoint shard ranges scan on scoped threads;
//! results are bitwise identical at any worker count because shard
//! selections never interact until the deterministic merge.
//!
//! [`scan_two_stage_into`] is the coarse-then-exact variant: stage
//! one ranks per-block centroids (maintained by the store as
//! coordinate sums), stage two rescans only the best `probe_blocks`
//! blocks exactly.  It is approximate; `gallery_bench` reports its
//! recall@k against the exact scan.
//!
//! All kernels write into caller-owned scratch and output buffers, so
//! a warmed query→top-k cycle performs zero allocations.

use super::store::GalleryStore;
use super::topk::{merge_shards_into, Hit, TopK};
use crate::error::{Error, Result};
use crate::tensor::{dot_with_lanes, DOT_LANES};

/// How row similarities are scored.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScanMode {
    /// Raw dot product — exact cosine when the gallery holds
    /// unit-norm embeddings (the serving path stores
    /// `JointSession::project` output), and bitwise-identical to
    /// `JointSession::score` on the same embeddings.
    Dot,
    /// Dot product normalized by the stored row norm and the probe
    /// norm (zero-norm rows score 0).
    Cosine,
}

/// Counters from one scan.
#[derive(Clone, Copy, Debug, Default)]
pub struct ScanStats {
    /// Rows scored exactly.
    pub rows: u64,
    /// Top-k heap root replacements (evictions).
    pub evictions: u64,
    /// Coarse blocks rescanned exactly (two-stage only).
    pub blocks_probed: u64,
    /// Coarse blocks present at scan time (two-stage only).
    pub blocks_total: u64,
}

/// One coarse candidate block in the two-stage scan.
#[derive(Clone, Copy)]
struct BlockRef {
    score: f32,
    shard: u32,
    seg: u32,
    block: u32,
}

/// Reusable per-caller scan state: per-shard heaps, merge cursors and
/// the coarse block-score buffer.  Keeping one scratch per worker
/// makes a warmed query→top-k cycle allocation-free.
pub struct GalleryScratch {
    topks: Vec<TopK>,
    cursors: Vec<usize>,
    blocks: Vec<BlockRef>,
}

impl GalleryScratch {
    /// Empty scratch; buffers warm on first use.
    // lint: allow(alloc) reason=cold constructor: empty scratch spines, warmed by the first query
    pub fn new() -> Self {
        GalleryScratch { topks: Vec::new(), cursors: Vec::new(), blocks: Vec::new() }
    }
}

impl Default for GalleryScratch {
    fn default() -> Self {
        Self::new()
    }
}

/// Inverse probe norm for [`ScanMode::Cosine`] (1.0 under
/// [`ScanMode::Dot`], 0.0 for a zero probe).
fn inv_probe_norm(probe: &[f32], mode: ScanMode) -> f32 {
    match mode {
        ScanMode::Dot => 1.0,
        ScanMode::Cosine => {
            let n = dot_with_lanes::<DOT_LANES>(probe, probe).sqrt();
            if n > 0.0 {
                1.0 / n
            } else {
                0.0
            }
        }
    }
}

/// Score one raw dot product under `mode`.
#[inline]
fn score_row(d: f32, norm: f32, mode: ScanMode, inv_probe: f32) -> f32 {
    match mode {
        ScanMode::Dot => d,
        ScanMode::Cosine => {
            if norm > 0.0 {
                d * inv_probe / norm
            } else {
                0.0
            }
        }
    }
}

/// Scan one shard into its bounded selector, block by block.
fn scan_shard(
    store: &GalleryStore,
    s: usize,
    probe: &[f32],
    mode: ScanMode,
    inv_probe: f32,
    top: &mut TopK,
) {
    let dim = store.dim();
    let ns = store.n_shards();
    let block_rows = store.options().block_rows;
    let shard = store.shard(s).read().expect("gallery shard lock poisoned");
    let mut local = 0usize;
    for seg in &shard.segs {
        let mut r0 = 0usize;
        while r0 < seg.rows {
            let r1 = (r0 + block_rows).min(seg.rows);
            for r in r0..r1 {
                let row = &seg.data[r * dim..(r + 1) * dim];
                let d = dot_with_lanes::<DOT_LANES>(probe, row);
                let score = score_row(d, seg.norms[r], mode, inv_probe);
                top.offer(((local + r) * ns + s) as u64, score);
            }
            r0 = r1;
        }
        local += seg.rows;
    }
}

/// Exact scan: score the probe against every row, keep the best `k`
/// per shard, and k-way merge the shard selections into `out`
/// (best-first, ties by smaller id).  `workers > 1` scans disjoint
/// shard ranges on scoped threads; the result is identical at any
/// worker count.  Allocation-free once `scratch` and `out` are warm
/// (thread spawns under `workers > 1` allocate in the OS, so the
/// zero-alloc serving contract applies to `workers == 1`).
pub fn scan_into(
    store: &GalleryStore,
    probe: &[f32],
    k: usize,
    mode: ScanMode,
    workers: usize,
    scratch: &mut GalleryScratch,
    out: &mut Vec<Hit>,
) -> Result<ScanStats> {
    if probe.len() != store.dim() {
        return Err(Error::Shape("gallery probe has wrong dimension".into()));
    }
    let ns = store.n_shards();
    while scratch.topks.len() < ns {
        scratch.topks.push(TopK::new());
    }
    for t in scratch.topks[..ns].iter_mut() {
        t.reset(k);
    }
    let inv_probe = inv_probe_norm(probe, mode);
    let workers = workers.max(1).min(ns);
    if workers <= 1 {
        for (s, t) in scratch.topks[..ns].iter_mut().enumerate() {
            scan_shard(store, s, probe, mode, inv_probe, t);
        }
    } else {
        let chunk = ns.div_ceil(workers);
        let topks = &mut scratch.topks[..ns];
        std::thread::scope(|scope| {
            for (ci, tchunk) in topks.chunks_mut(chunk).enumerate() {
                scope.spawn(move || {
                    for (off, t) in tchunk.iter_mut().enumerate() {
                        scan_shard(store, ci * chunk + off, probe, mode, inv_probe, t);
                    }
                });
            }
        });
    }
    let mut stats = ScanStats::default();
    for t in scratch.topks[..ns].iter() {
        stats.rows += t.offered();
        stats.evictions += t.evictions();
    }
    merge_shards_into(&mut scratch.topks[..ns], &mut scratch.cursors, k, out);
    Ok(stats)
}

/// Coarse-then-exact scan: rank per-block centroids by mean dot
/// product against the probe, then rescan only the best
/// `probe_blocks` blocks exactly (serial).  Approximate by design —
/// recall@k against [`scan_into`] is workload-dependent and reported
/// by `gallery_bench`.  Probing every block reproduces the exact
/// result.  Allocation-free once `scratch` and `out` are warm.
pub fn scan_two_stage_into(
    store: &GalleryStore,
    probe: &[f32],
    k: usize,
    probe_blocks: usize,
    mode: ScanMode,
    scratch: &mut GalleryScratch,
    out: &mut Vec<Hit>,
) -> Result<ScanStats> {
    if probe.len() != store.dim() {
        return Err(Error::Shape("gallery probe has wrong dimension".into()));
    }
    let dim = store.dim();
    let ns = store.n_shards();
    let block_rows = store.options().block_rows;
    if scratch.topks.is_empty() {
        scratch.topks.push(TopK::new());
    }
    scratch.topks[0].reset(k);
    let inv_probe = inv_probe_norm(probe, mode);
    // stage one: score every block centroid (sum / rows_in_block)
    scratch.blocks.clear();
    for s in 0..ns {
        let shard = store.shard(s).read().expect("gallery shard lock poisoned");
        for (gi, seg) in shard.segs.iter().enumerate() {
            let mut b = 0usize;
            let mut r0 = 0usize;
            while r0 < seg.rows {
                let r1 = (r0 + block_rows).min(seg.rows);
                let sums = &seg.block_sums[b * dim..(b + 1) * dim];
                let d = dot_with_lanes::<DOT_LANES>(probe, sums);
                let score = d / (r1 - r0) as f32;
                scratch.blocks.push(BlockRef {
                    score,
                    shard: s as u32,
                    seg: gi as u32,
                    block: b as u32,
                });
                b += 1;
                r0 = r1;
            }
        }
    }
    let total = scratch.blocks.len();
    let nprobe = probe_blocks.min(total);
    // total_cmp keeps the comparator a strict total order even if NaN
    // embeddings were ingested (partial_cmp's Equal fallback violated
    // transitivity, which sort_unstable_by may detect and panic on)
    scratch.blocks.sort_unstable_by(|a, b| {
        b.score
            .total_cmp(&a.score)
            .then((a.shard, a.seg, a.block).cmp(&(b.shard, b.seg, b.block)))
    });
    // stage two: exact rescan of the selected blocks
    for br in scratch.blocks[..nprobe].iter() {
        let s = br.shard as usize;
        let shard = store.shard(s).read().expect("gallery shard lock poisoned");
        let seg = &shard.segs[br.seg as usize];
        let mut base = 0usize;
        for g in 0..br.seg as usize {
            base += shard.segs[g].rows;
        }
        let r0 = br.block as usize * block_rows;
        let r1 = (r0 + block_rows).min(seg.rows);
        for r in r0..r1 {
            let row = &seg.data[r * dim..(r + 1) * dim];
            let d = dot_with_lanes::<DOT_LANES>(probe, row);
            let score = score_row(d, seg.norms[r], mode, inv_probe);
            scratch.topks[0].offer(((base + r) * ns + s) as u64, score);
        }
    }
    let stats = ScanStats {
        rows: scratch.topks[0].offered(),
        evictions: scratch.topks[0].evictions(),
        blocks_probed: nprobe as u64,
        blocks_total: total as u64,
    };
    merge_shards_into(&mut scratch.topks[..1], &mut scratch.cursors, k, out);
    Ok(stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Rng;
    use crate::gallery::store::GalleryOptions;

    fn build_store(n: usize, dim: usize, shards: usize, seed: u64) -> GalleryStore {
        let opts = GalleryOptions { shards, seg_rows: 32, block_rows: 8 };
        let store = GalleryStore::new(dim, opts);
        let mut rng = Rng::new(seed);
        let rows: Vec<f32> = (0..n * dim).map(|_| rng.next_f64() as f32 - 0.5).collect();
        store.ingest_bulk(&rows).expect("bulk ingest");
        store
    }

    fn naive_topk(store: &GalleryStore, probe: &[f32], k: usize, mode: ScanMode) -> Vec<Hit> {
        let inv_probe = inv_probe_norm(probe, mode);
        let mut all: Vec<Hit> = Vec::new();
        store.for_each_row(|id, row, norm| {
            let d = dot_with_lanes::<DOT_LANES>(probe, row);
            all.push(Hit { id, score: score_row(d, norm, mode, inv_probe) });
        });
        all.sort_unstable_by(|a, b| {
            b.score.total_cmp(&a.score).then(a.id.cmp(&b.id))
        });
        all.truncate(k);
        all
    }

    fn probe_for(dim: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..dim).map(|_| rng.next_f64() as f32 - 0.5).collect()
    }

    #[test]
    fn exact_scan_matches_naive_reference() {
        for &mode in &[ScanMode::Dot, ScanMode::Cosine] {
            let store = build_store(301, 16, 4, 0x5CA1);
            let probe = probe_for(16, 0x90_B3);
            let mut scratch = GalleryScratch::new();
            let mut out = Vec::new();
            let stats =
                scan_into(&store, &probe, 10, mode, 1, &mut scratch, &mut out).expect("scan");
            assert_eq!(stats.rows, 301);
            assert_eq!(out, naive_topk(&store, &probe, 10, mode), "{mode:?}");
        }
    }

    /// Property: shard partitioning is invisible — stores built with
    /// 1, 3 and 7 shards return identical hits for the same data.
    #[test]
    fn shard_count_does_not_change_results() {
        let probe = probe_for(12, 0xFEED);
        let reference = {
            let store = build_store(157, 12, 1, 0xABCD);
            naive_topk(&store, &probe, 8, ScanMode::Dot)
        };
        for &shards in &[1usize, 3, 7] {
            let store = build_store(157, 12, shards, 0xABCD);
            let mut scratch = GalleryScratch::new();
            let mut out = Vec::new();
            scan_into(&store, &probe, 8, ScanMode::Dot, 1, &mut scratch, &mut out).expect("scan");
            // ids differ across shard layouts only in shard assignment;
            // ingest order is round-robin so id == insertion index for
            // every layout, making results directly comparable.
            assert_eq!(out, reference, "shards={shards}");
        }
    }

    #[test]
    fn worker_count_does_not_change_results() {
        let store = build_store(223, 8, 5, 0x1D_E5);
        let probe = probe_for(8, 0x77);
        let mut scratch = GalleryScratch::new();
        let mut serial = Vec::new();
        scan_into(&store, &probe, 7, ScanMode::Dot, 1, &mut scratch, &mut serial).expect("scan");
        for &w in &[2usize, 3, 8] {
            let mut out = Vec::new();
            scan_into(&store, &probe, 7, ScanMode::Dot, w, &mut scratch, &mut out).expect("scan");
            assert_eq!(out, serial, "workers={w}");
        }
    }

    #[test]
    fn empty_gallery_returns_no_hits() {
        let store = GalleryStore::with_dim(8);
        let probe = probe_for(8, 0x0);
        let mut scratch = GalleryScratch::new();
        let mut out = Vec::new();
        let stats =
            scan_into(&store, &probe, 5, ScanMode::Dot, 2, &mut scratch, &mut out).expect("scan");
        assert!(out.is_empty());
        assert_eq!(stats.rows, 0);
        let stats = scan_two_stage_into(&store, &probe, 5, 4, ScanMode::Dot, &mut scratch, &mut out)
            .expect("two-stage");
        assert!(out.is_empty());
        assert_eq!(stats.blocks_total, 0);
    }

    #[test]
    fn k_larger_than_gallery_returns_everything_ranked() {
        let store = build_store(9, 4, 3, 0xB00);
        let probe = probe_for(4, 0x1);
        let mut scratch = GalleryScratch::new();
        let mut out = Vec::new();
        scan_into(&store, &probe, 50, ScanMode::Dot, 1, &mut scratch, &mut out).expect("scan");
        assert_eq!(out.len(), 9);
        assert_eq!(out, naive_topk(&store, &probe, 50, ScanMode::Dot));
    }

    #[test]
    fn two_stage_probing_all_blocks_is_exact() {
        let store = build_store(301, 16, 4, 0x5CA1);
        let probe = probe_for(16, 0x90_B3);
        let mut scratch = GalleryScratch::new();
        let mut exact = Vec::new();
        scan_into(&store, &probe, 10, ScanMode::Dot, 1, &mut scratch, &mut exact).expect("scan");
        let mut out = Vec::new();
        let stats = scan_two_stage_into(
            &store,
            &probe,
            10,
            usize::MAX,
            ScanMode::Dot,
            &mut scratch,
            &mut out,
        )
        .expect("two-stage");
        assert_eq!(stats.blocks_probed, stats.blocks_total);
        assert_eq!(out, exact);
    }

    #[test]
    fn two_stage_partial_probe_scans_fewer_rows() {
        let store = build_store(512, 8, 4, 0xCAFE);
        let probe = probe_for(8, 0xF00D);
        let mut scratch = GalleryScratch::new();
        let mut out = Vec::new();
        let stats =
            scan_two_stage_into(&store, &probe, 5, 8, ScanMode::Dot, &mut scratch, &mut out)
                .expect("two-stage");
        assert_eq!(stats.blocks_probed, 8);
        assert!(stats.blocks_total > 8);
        assert!(stats.rows < 512);
        assert!(!out.is_empty());
    }

    #[test]
    fn probe_dimension_mismatch_is_an_error() {
        let store = GalleryStore::with_dim(8);
        let mut scratch = GalleryScratch::new();
        let mut out = Vec::new();
        assert!(scan_into(&store, &[0.0; 4], 5, ScanMode::Dot, 1, &mut scratch, &mut out).is_err());
    }
}
