//! Ablation experiments (Table 1 / Figure 4): protection off, random split,
//! attention indicator, ratio-r vs fixed-k schedules.

use crate::config::{TextConfig, ViTConfig};
use crate::engine::Engine;
use crate::error::Result;
use crate::merge::{fixed_k_plan, merge_plan};

use super::retrieval::{self, RetrievalRow};
use super::textcls::{self, TextRow};

/// Ablation variants of Table 1 / Figure 4 (plus full PiToMe and ToMe).
pub const VARIANTS: [&str; 5] = [
    "pitome", "pitome_noprot", "pitome_rand", "pitome_attn", "tome",
];

/// Retrieval ablation rows (Table 1 left block); `clip` is an engine
/// over the CLIP parameter store.
pub fn retrieval_ablation(clip: &Engine, rs: &[f64], n: usize)
                          -> Result<Vec<RetrievalRow>> {
    let mut rows = Vec::new();
    for &variant in VARIANTS.iter() {
        for &r in rs {
            rows.push(retrieval::eval_config(clip, variant, r, n)?);
        }
    }
    Ok(rows)
}

/// Text-classification ablation rows (Table 1 right block); `bert` is an
/// engine over the BERT parameter store.
pub fn textcls_ablation(bert: &Engine, rs: &[f64], n: usize)
                        -> Result<Vec<TextRow>> {
    let mut rows = Vec::new();
    for &variant in VARIANTS.iter() {
        for &r in rs {
            rows.push(textcls::eval_config(bert, variant, r, n)?);
        }
    }
    Ok(rows)
}

/// Schedule comparison (Figures 8-9): same FLOPs via ratio-r vs fixed-k.
/// Returns (label, plan, total_removed).
pub fn schedule_plans(n0: usize, depth: usize) -> Vec<(String, Vec<usize>, usize)> {
    let mut out = Vec::new();
    for &r in &[0.95, 0.9, 0.85] {
        let p = merge_plan(n0, r, depth, 1, None);
        let rem = p[0] - p[depth];
        out.push((format!("ratio r={r}"), p, rem));
    }
    for &k in &[2usize, 4, 8] {
        let p = fixed_k_plan(n0, k, depth, 1);
        let rem = p[0] - p[depth];
        out.push((format!("fixed k={k}"), p, rem));
    }
    out
}

/// Match a fixed-k plan to a ratio plan with (approximately) equal total
/// token removal, for the equal-FLOPs comparison of App. C.
pub fn matched_fixed_k(n0: usize, depth: usize, r: f64) -> usize {
    let target = {
        let p = merge_plan(n0, r, depth, 1, None);
        p[0] - p[depth]
    };
    let mut best_k = 1;
    let mut best_err = usize::MAX;
    for k in 1..(n0 / 2) {
        let p = fixed_k_plan(n0, k, depth, 1);
        let rem = p[0] - p[depth];
        let err = rem.abs_diff(target);
        if err < best_err {
            best_err = err;
            best_k = k;
        }
    }
    best_k
}

/// ViT/Text configs for the proportional-attention on/off ablation.
pub fn prop_attn_configs(r: f64) -> (ViTConfig, ViTConfig) {
    let on = ViTConfig { merge_mode: "pitome".into(), merge_r: r, ..Default::default() };
    let mut off = on.clone();
    off.prop_attn = false;
    (on, off)
}

/// Text config helper for consistency with the python side.
pub fn text_cfg(mode: &str, r: f64) -> TextConfig {
    TextConfig { merge_mode: mode.into(), merge_r: r, ..Default::default() }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matched_k_removes_similar_total() {
        let k = matched_fixed_k(197, 12, 0.9);
        let rp = merge_plan(197, 0.9, 12, 1, None);
        let fp = fixed_k_plan(197, k, 12, 1);
        let rr = rp[0] - rp[12];
        let fr = fp[0] - fp[12];
        assert!(rr.abs_diff(fr) <= 12, "ratio removed {rr}, fixed {fr}");
    }

    #[test]
    fn schedule_plans_shapes() {
        let plans = schedule_plans(65, 4);
        assert_eq!(plans.len(), 6);
        for (_, p, _) in &plans {
            assert_eq!(p.len(), 5);
        }
    }
}
