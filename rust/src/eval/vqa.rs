//! VQA experiments (Tables 4-5 / Figure 5): answer accuracy vs compression
//! ratio with the synthetic VQA model (DESIGN.md §6 LLaVA stand-in).

use crate::config::ViTConfig;
use crate::data::{patchify, shape_item, vqa_item, Rng, TEST_SEED};
use crate::error::Result;
use crate::merge::MergeMode;
use crate::model::text::text_features;
use crate::model::{flops, ParamStore, ViTModel};
use crate::tensor::{argmax, dense, Mat};

/// One VQA result row.
#[derive(Clone, Debug)]
pub struct VqaRow {
    /// merge mode of the vision tower
    pub mode: String,
    /// keep ratio
    pub r: f64,
    /// answer accuracy (%)
    pub acc: f64,
    /// vision-tower GFLOPs
    pub gflops: f64,
    /// visual tokens entering the answer head (r^L * N effect)
    pub visual_tokens: usize,
}

/// Answer logits for one (image, question) pair.
pub fn vqa_logits(ps: &ParamStore, vcfg: &ViTConfig, patches: &Mat,
                  question: &[i32], rng: &mut Rng) -> Result<Vec<f32>> {
    let model = ViTModel::new(ps, vcfg.clone());
    let vf = model.features(patches, rng)?;
    let qf = text_features(ps, "q.", question, 64, 2, 4, MergeMode::None,
                           vec![question.len(); 3], rng)?;
    let mut joint = vf;
    joint.extend_from_slice(&qf);
    let jm = Mat::from_vec(1, joint.len(), joint);
    let mut h = dense(&jm, &ps.mat2("vqa.fc1")?, Some(ps.vec1("vqa.fc1b")?));
    for v in h.data.iter_mut() {
        *v = v.max(0.0);
    }
    Ok(dense(&h, &ps.mat2("vqa.head.w")?, Some(ps.vec1("vqa.head.b")?)).data)
}

/// Evaluate one configuration over `n` test QA pairs.
pub fn eval_config(ps: &ParamStore, mode: &str, r: f64, n: usize)
                   -> Result<VqaRow> {
    let vcfg = ViTConfig {
        merge_mode: mode.into(),
        merge_r: r,
        ..Default::default()
    };
    let mut rng = Rng::new(0x0A0A);
    let mut correct = 0usize;
    for i in 0..n {
        let item = shape_item(TEST_SEED, i as u64);
        let patches = patchify(&item.image, vcfg.patch_size);
        let (q, ans) = vqa_item(TEST_SEED, i as u64);
        let lg = vqa_logits(ps, &vcfg, &patches, &q, &mut rng)?;
        if argmax(&lg) == ans {
            correct += 1;
        }
    }
    Ok(VqaRow {
        mode: mode.into(),
        r,
        acc: 100.0 * correct as f64 / n as f64,
        gflops: flops::vit_gflops(&vcfg),
        visual_tokens: *vcfg.plan().last().unwrap(),
    })
}

/// Sweep (Figure 5 / Table 4 rows).
pub fn sweep(ps: &ParamStore, modes: &[&str], rs: &[f64], n: usize)
             -> Result<Vec<VqaRow>> {
    let mut rows = vec![eval_config(ps, "none", 1.0, n)?];
    for &mode in modes {
        for &r in rs {
            rows.push(eval_config(ps, mode, r, n)?);
        }
    }
    Ok(rows)
}
