//! VQA experiments (Tables 4-5 / Figure 5): answer accuracy vs compression
//! ratio with the synthetic VQA model (DESIGN.md §6 LLaVA stand-in).
//!
//! The sweep drives one engine [`JointSession`] per configuration (all
//! configurations share the engine's weight-resolution cache): patches
//! and question embed into pooled tower slots, and the answer head runs
//! over a pooled concat buffer — no per-call `ViTModel` construction and
//! no per-call joint-feature copy.  The legacy single-sample
//! [`vqa_logits`] remains as a `#[deprecated]` reference; the session
//! path is bitwise-identical to it (`tests/prop_engine.rs`).

use crate::config::ViTConfig;
use crate::data::{patchify, shape_item, vqa_item, Rng, TEST_SEED};
use crate::engine::{Engine, JointConfig, JointSession};
use crate::error::Result;
use crate::merge::MergeMode;
use crate::model::text::text_features;
use crate::model::{flops, ParamStore, ViTModel};
use crate::tensor::{argmax, dense, Mat};

/// One VQA result row.
#[derive(Clone, Debug)]
pub struct VqaRow {
    /// merge mode of the vision tower
    pub mode: String,
    /// keep ratio
    pub r: f64,
    /// answer accuracy (%)
    pub acc: f64,
    /// vision-tower GFLOPs
    pub gflops: f64,
    /// visual tokens entering the answer head (r^L * N effect)
    pub visual_tokens: usize,
}

/// Answer logits for one (image, question) pair — builds a fresh
/// `ViTModel`, re-resolves weights, and copies the joint feature per
/// call.
#[deprecated(note = "drive a `crate::engine::JointSession` (vqa_one) \
                     instead — pooled buffers, cached weight resolution")]
pub fn vqa_logits(ps: &ParamStore, vcfg: &ViTConfig, patches: &Mat,
                  question: &[i32], rng: &mut Rng) -> Result<Vec<f32>> {
    let model = ViTModel::new(ps, vcfg.clone());
    let vf = model.features(patches, rng)?;
    let qf = text_features(ps, "q.", question, 64, 2, 4, MergeMode::None,
                           vec![question.len(); 3], rng)?;
    let mut joint = vf;
    joint.extend_from_slice(&qf);
    let jm = Mat::from_vec(1, joint.len(), joint);
    let mut h = dense(&jm, &ps.mat2("vqa.fc1")?, Some(ps.vec1("vqa.fc1b")?));
    for v in h.data.iter_mut() {
        *v = v.max(0.0);
    }
    Ok(dense(&h, &ps.mat2("vqa.head.w")?, Some(ps.vec1("vqa.head.b")?)).data)
}

/// Evaluate one configuration over `n` test QA pairs through a caller's
/// session (exposed so the sweep and the serving bench share one
/// warm-session path).
fn eval_with(sess: &mut JointSession, mode: &str, r: f64, n: usize,
             vcfg: &ViTConfig) -> Result<VqaRow> {
    let mut rng = Rng::new(0x0A0A);
    let mut correct = 0usize;
    for i in 0..n {
        let item = shape_item(TEST_SEED, i as u64);
        let patches = patchify(&item.image, vcfg.patch_size);
        let (q, ans) = vqa_item(TEST_SEED, i as u64);
        let lg = sess.vqa_one(&patches, &q, &mut rng)?;
        if argmax(lg) == ans {
            correct += 1;
        }
    }
    Ok(VqaRow {
        mode: mode.into(),
        r,
        acc: 100.0 * correct as f64 / n as f64,
        gflops: flops::vit_gflops(vcfg),
        visual_tokens: *vcfg.plan().last().unwrap(),
    })
}

/// Evaluate one configuration over `n` test QA pairs (one pooled
/// [`JointSession`] serves every pair; the serial shared-RNG contract
/// keeps results bitwise-identical to the deprecated per-sample path).
pub fn eval_config(engine: &Engine, mode: &str, r: f64, n: usize)
                   -> Result<VqaRow> {
    let vcfg = ViTConfig {
        merge_mode: mode.into(),
        merge_r: r,
        ..Default::default()
    };
    let mut sess = engine.joint_session(&JointConfig::vqa(vcfg.clone()))?;
    eval_with(&mut sess, mode, r, n, &vcfg)
}

/// Sweep (Figure 5 / Table 4 rows).  Every configuration shares the
/// engine's weight-resolution cache, so the question tower and answer
/// head resolve once for the whole sweep.
pub fn sweep(engine: &Engine, modes: &[&str], rs: &[f64], n: usize)
             -> Result<Vec<VqaRow>> {
    let mut rows = vec![eval_config(engine, "none", 1.0, n)?];
    for &mode in modes {
        for &r in rs {
            rows.push(eval_config(engine, mode, r, n)?);
        }
    }
    Ok(rows)
}
