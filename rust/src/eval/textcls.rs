//! Text classification experiments (Table 7 / Table 9 / Figure 10):
//! sentiment accuracy vs FLOPs with compression on the first three layers.

use crate::config::TextConfig;
use crate::data::{sent_item, TEST_SEED};
use crate::engine::Engine;
use crate::error::Result;
use crate::model::flops::encoder_flops;

/// One text-classification row.
#[derive(Clone, Debug)]
pub struct TextRow {
    /// merge mode
    pub mode: String,
    /// keep ratio
    pub r: f64,
    /// accuracy (%)
    pub acc: f64,
    /// FLOPs speedup vs uncompressed encoder
    pub flops_speedup: f64,
}

/// Sentences scored per batched encoder pass.
const EVAL_CHUNK: usize = 32;

/// Evaluate one configuration over `n` test sentences, batching the
/// encoder across all available worker threads.
pub fn eval_config(engine: &Engine, mode: &str, r: f64, n: usize)
                   -> Result<TextRow> {
    eval_config_with_workers(engine, mode, r, n,
                             crate::merge::batch::recommended_workers())
}

/// [`eval_config`] with an explicit worker-thread count (1 = serial).
pub fn eval_config_with_workers(engine: &Engine, mode: &str, r: f64, n: usize,
                                workers: usize) -> Result<TextRow> {
    let cfg = TextConfig {
        merge_mode: mode.into(),
        merge_r: r,
        ..Default::default()
    };
    let mut correct = 0usize;
    let mut done = 0usize;
    // one session for the whole sweep: slots, scratches, outputs, and
    // logits buffers are all reused across every eval chunk
    let mut sess = engine.bert_session(&cfg)?;
    sess.set_workers(workers);
    while done < n {
        let count = EVAL_CHUNK.min(n - done);
        sess.begin(count);
        let mut labels = Vec::with_capacity(count);
        for j in 0..count {
            let (toks, label) =
                sent_item(TEST_SEED ^ 0xAB, (done + j) as u64, cfg.seq_len, 16);
            sess.set_tokens(j, &toks)?;
            labels.push(label);
        }
        sess.forward(0x7E57 ^ done as u64)?;
        correct += labels
            .iter()
            .enumerate()
            .filter(|(j, l)| sess.predict(*j) == **l)
            .count();
        done += count;
    }
    let base = TextConfig::default();
    let f_base = encoder_flops(&base.plan(), base.dim, (base.dim as f64 * base.mlp_ratio) as usize, false);
    let f_cfg = encoder_flops(&cfg.plan(), cfg.dim, (cfg.dim as f64 * cfg.mlp_ratio) as usize, mode != "none");
    Ok(TextRow {
        mode: mode.into(),
        r,
        acc: 100.0 * correct as f64 / n as f64,
        flops_speedup: f_base / f_cfg,
    })
}

/// Sweep modes x ratios (Table 9's r in {0.8, 0.75, 0.7}).
pub fn sweep(engine: &Engine, modes: &[&str], rs: &[f64], n: usize)
             -> Result<Vec<TextRow>> {
    let mut rows = vec![eval_config(engine, "none", 1.0, n)?];
    for &mode in modes {
        for &r in rs {
            rows.push(eval_config(engine, mode, r, n)?);
        }
    }
    Ok(rows)
}
