//! Text classification experiments (Table 7 / Table 9 / Figure 10):
//! sentiment accuracy vs FLOPs with compression on the first three layers.

use crate::config::TextConfig;
use crate::data::{sent_item, Rng, TEST_SEED};
use crate::error::Result;
use crate::model::flops::encoder_flops;
use crate::model::{bert_logits, ParamStore};
use crate::tensor::argmax;

/// One text-classification row.
#[derive(Clone, Debug)]
pub struct TextRow {
    /// merge mode
    pub mode: String,
    /// keep ratio
    pub r: f64,
    /// accuracy (%)
    pub acc: f64,
    /// FLOPs speedup vs uncompressed encoder
    pub flops_speedup: f64,
}

/// Evaluate one configuration over `n` test sentences.
pub fn eval_config(ps: &ParamStore, mode: &str, r: f64, n: usize)
                   -> Result<TextRow> {
    let cfg = TextConfig {
        merge_mode: mode.into(),
        merge_r: r,
        ..Default::default()
    };
    let mut rng = Rng::new(0x7E57);
    let mut correct = 0usize;
    for i in 0..n {
        let (toks, label) = sent_item(TEST_SEED ^ 0xAB, i as u64, cfg.seq_len, 16);
        let lg = bert_logits(ps, &cfg, &toks, &mut rng)?;
        if argmax(&lg) == label {
            correct += 1;
        }
    }
    let base = TextConfig::default();
    let f_base = encoder_flops(&base.plan(), base.dim, (base.dim as f64 * base.mlp_ratio) as usize, false);
    let f_cfg = encoder_flops(&cfg.plan(), cfg.dim, (cfg.dim as f64 * cfg.mlp_ratio) as usize, mode != "none");
    Ok(TextRow {
        mode: mode.into(),
        r,
        acc: 100.0 * correct as f64 / n as f64,
        flops_speedup: f_base / f_cfg,
    })
}

/// Sweep modes x ratios (Table 9's r in {0.8, 0.75, 0.7}).
pub fn sweep(ps: &ParamStore, modes: &[&str], rs: &[f64], n: usize)
             -> Result<Vec<TextRow>> {
    let mut rows = vec![eval_config(ps, "none", 1.0, n)?];
    for &mode in modes {
        for &r in rs {
            rows.push(eval_config(ps, mode, r, n)?);
        }
    }
    Ok(rows)
}
