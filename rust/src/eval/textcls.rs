//! Text classification experiments (Table 7 / Table 9 / Figure 10):
//! sentiment accuracy vs FLOPs with compression on the first three layers.

use crate::config::TextConfig;
use crate::data::{sent_item, TEST_SEED};
use crate::error::Result;
use crate::model::flops::encoder_flops;
use crate::model::{bert_logits_batch_pooled, ParamStore, ScratchPool};
use crate::tensor::argmax;

/// One text-classification row.
#[derive(Clone, Debug)]
pub struct TextRow {
    /// merge mode
    pub mode: String,
    /// keep ratio
    pub r: f64,
    /// accuracy (%)
    pub acc: f64,
    /// FLOPs speedup vs uncompressed encoder
    pub flops_speedup: f64,
}

/// Sentences scored per batched encoder pass.
const EVAL_CHUNK: usize = 32;

/// Evaluate one configuration over `n` test sentences, batching the
/// encoder across all available worker threads.
pub fn eval_config(ps: &ParamStore, mode: &str, r: f64, n: usize)
                   -> Result<TextRow> {
    eval_config_with_workers(ps, mode, r, n,
                             crate::merge::batch::recommended_workers())
}

/// [`eval_config`] with an explicit worker-thread count (1 = serial).
pub fn eval_config_with_workers(ps: &ParamStore, mode: &str, r: f64, n: usize,
                                workers: usize) -> Result<TextRow> {
    let cfg = TextConfig {
        merge_mode: mode.into(),
        merge_r: r,
        ..Default::default()
    };
    let mut correct = 0usize;
    let mut done = 0usize;
    // one scratch pool for the whole sweep: encoder buffers are reused
    // across every eval chunk
    let mut pool = ScratchPool::new();
    while done < n {
        let count = EVAL_CHUNK.min(n - done);
        let mut seqs = Vec::with_capacity(count);
        let mut labels = Vec::with_capacity(count);
        for j in 0..count {
            let (toks, label) =
                sent_item(TEST_SEED ^ 0xAB, (done + j) as u64, cfg.seq_len, 16);
            seqs.push(toks);
            labels.push(label);
        }
        let logits = bert_logits_batch_pooled(ps, &cfg, &seqs,
                                              0x7E57 ^ done as u64, workers,
                                              &mut pool)?;
        correct += logits
            .iter()
            .zip(&labels)
            .filter(|(lg, l)| argmax(lg) == **l)
            .count();
        done += count;
    }
    let base = TextConfig::default();
    let f_base = encoder_flops(&base.plan(), base.dim, (base.dim as f64 * base.mlp_ratio) as usize, false);
    let f_cfg = encoder_flops(&cfg.plan(), cfg.dim, (cfg.dim as f64 * cfg.mlp_ratio) as usize, mode != "none");
    Ok(TextRow {
        mode: mode.into(),
        r,
        acc: 100.0 * correct as f64 / n as f64,
        flops_speedup: f_base / f_cfg,
    })
}

/// Sweep modes x ratios (Table 9's r in {0.8, 0.75, 0.7}).
pub fn sweep(ps: &ParamStore, modes: &[&str], rs: &[f64], n: usize)
             -> Result<Vec<TextRow>> {
    let mut rows = vec![eval_config(ps, "none", 1.0, n)?];
    for &mode in modes {
        for &r in rs {
            rows.push(eval_config(ps, mode, r, n)?);
        }
    }
    Ok(rows)
}
