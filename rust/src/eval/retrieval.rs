//! Image-text retrieval experiments (Figure 3 / Tables 2-3): recall vs
//! FLOPs on synthetic caption pairs with the CPU reference CLIP.
//!
//! The sweep drives one engine [`JointSession`] per configuration
//! (retrieval kind: both towers project into the shared embedding space
//! through pooled buffers).  The legacy single-sample helpers remain as
//! `#[deprecated]` references; the session path is bitwise-identical to
//! them (`tests/prop_engine.rs`).

use crate::config::ViTConfig;
use crate::data::{caption_for, patchify, shape_item, Rng, TEST_SEED};
use crate::engine::{Engine, JointConfig};
use crate::error::Result;
use crate::model::flops;
use crate::model::text::l2_normalize;
use crate::tensor::{dense, matmul_nt, Mat};

use super::recall_at_k;

/// CLIP vision-tower embedding for one sample under a merge config —
/// builds a transient session and copies the feature per call.
#[deprecated(note = "drive a `crate::engine::JointSession` \
                     (embed_pair_one / project) instead")]
pub fn clip_image_embed(engine: &Engine, cfg: &ViTConfig, patches: &Mat,
                        rng: &mut Rng) -> Result<Vec<f32>> {
    let mut sess = engine.vit_session(cfg)?;
    let f = sess.features_one(patches, rng)?;
    let fm = Mat::from_vec(1, f.len(), f.to_vec());
    let mut e = dense(&fm, &engine.params().mat2("proj.img")?, None).data;
    l2_normalize(&mut e);
    Ok(e)
}

/// One retrieval result row.
#[derive(Clone, Debug)]
pub struct RetrievalRow {
    /// merge mode of the vision tower
    pub mode: String,
    /// keep ratio
    pub r: f64,
    /// recall@1 text retrieval
    pub rt1: f64,
    /// recall@1 image retrieval
    pub ri1: f64,
    /// Rsum over @1/@5/@10 both directions
    pub rsum: f64,
    /// vision-tower GFLOPs
    pub gflops: f64,
}

/// Evaluate one merge config over `n` test pairs.
pub fn eval_config(engine: &Engine, mode: &str, r: f64, n: usize)
                   -> Result<RetrievalRow> {
    let vcfg = ViTConfig {
        merge_mode: mode.into(),
        merge_r: r,
        num_classes: 10,
        ..Default::default()
    };
    let mut rng = Rng::new(0x0C11);
    let embed_dim = 64usize;
    let mut img = Mat::zeros(n, embed_dim);
    let mut txt = Mat::zeros(n, embed_dim);
    // one joint session for the whole config: pooled tower slots and
    // projection buffers serve all `n` (image, caption) pairs; the
    // serial shared-RNG contract matches the historical per-sample
    // `clip_image_embed` + `clip_text_embed` loop bitwise
    let mut sess =
        engine.joint_session(&JointConfig::retrieval(vcfg.clone()))?;
    for i in 0..n {
        let item = shape_item(TEST_SEED, i as u64);
        let patches = patchify(&item.image, vcfg.patch_size);
        let cap = caption_for(TEST_SEED, i as u64);
        let (ie, te) = sess.embed_pair_one(&patches, &cap, &mut rng)?;
        img.row_mut(i).copy_from_slice(ie);
        txt.row_mut(i).copy_from_slice(te);
    }
    let sim = matmul_nt(&img, &txt);
    let (rt, ri, rsum) = recall_at_k(&sim, &[1, 5, 10]);
    Ok(RetrievalRow {
        mode: mode.into(),
        r,
        rt1: rt[0],
        ri1: ri[0],
        rsum,
        gflops: flops::vit_gflops(&vcfg),
    })
}

/// Sweep for the Figure 3 curves.
pub fn sweep(engine: &Engine, modes: &[&str], rs: &[f64], n: usize)
             -> Result<Vec<RetrievalRow>> {
    let mut rows = vec![eval_config(engine, "none", 1.0, n)?];
    for &mode in modes {
        for &r in rs {
            rows.push(eval_config(engine, mode, r, n)?);
        }
    }
    Ok(rows)
}
