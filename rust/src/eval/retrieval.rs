//! Image-text retrieval experiments (Figure 3 / Tables 2-3): recall vs
//! FLOPs on synthetic caption pairs with the CPU reference CLIP.
//!
//! The sweep drives one engine [`JointSession`](crate::engine::JointSession)
//! per configuration to embed every (image, caption) pair **once**, then
//! computes recall through the embedding-gallery scan kernel
//! ([`crate::gallery::scan_into`]): each direction ingests one side into
//! a [`GalleryStore`] and ranks the other side's probes by blocked
//! lane-split dot products — the embed-once/score-many shape the gallery
//! serving path uses.  The historical per-pair scoring loop remains as
//! the `#[deprecated]` reference [`eval_config_pairwise`]; the gallery
//! path reproduces its recall numbers exactly (same dot kernel, same
//! tie order — asserted by this module's tests).

use crate::config::ViTConfig;
use crate::data::{caption_for, patchify, shape_item, Rng, TEST_SEED};
use crate::engine::{Engine, JointConfig};
use crate::error::Result;
use crate::gallery::{scan_into, GalleryOptions, GalleryScratch,
                     GalleryStore, Hit, ScanMode};
use crate::model::flops;
use crate::model::text::l2_normalize;
use crate::tensor::{dense, dot, Mat};

use super::recall_at_k;

/// CLIP vision-tower embedding for one sample under a merge config —
/// builds a transient session and copies the feature per call.
#[deprecated(note = "drive a `crate::engine::JointSession` \
                     (embed_pair_one / project) instead")]
pub fn clip_image_embed(engine: &Engine, cfg: &ViTConfig, patches: &Mat,
                        rng: &mut Rng) -> Result<Vec<f32>> {
    let mut sess = engine.vit_session(cfg)?;
    let f = sess.features_one(patches, rng)?;
    let fm = Mat::from_vec(1, f.len(), f.to_vec());
    let mut e = dense(&fm, &engine.params().mat2("proj.img")?, None).data;
    l2_normalize(&mut e);
    Ok(e)
}

/// One retrieval result row.
#[derive(Clone, Debug)]
pub struct RetrievalRow {
    /// merge mode of the vision tower
    pub mode: String,
    /// keep ratio
    pub r: f64,
    /// recall@1 text retrieval
    pub rt1: f64,
    /// recall@1 image retrieval
    pub ri1: f64,
    /// Rsum over @1/@5/@10 both directions
    pub rsum: f64,
    /// vision-tower GFLOPs
    pub gflops: f64,
}

/// Embed `n` (image, caption) test pairs once through a joint retrieval
/// session, returning the vision config and the two embedding matrices.
/// The serial shared-RNG contract matches the historical per-sample
/// `clip_image_embed` + `clip_text_embed` loop bitwise.
fn embed_pairs(engine: &Engine, mode: &str, r: f64, n: usize)
               -> Result<(ViTConfig, Mat, Mat)> {
    let vcfg = ViTConfig {
        merge_mode: mode.into(),
        merge_r: r,
        num_classes: 10,
        ..Default::default()
    };
    let mut rng = Rng::new(0x0C11);
    let embed_dim = 64usize;
    let mut img = Mat::zeros(n, embed_dim);
    let mut txt = Mat::zeros(n, embed_dim);
    let mut sess =
        engine.joint_session(&JointConfig::retrieval(vcfg.clone()))?;
    for i in 0..n {
        let item = shape_item(TEST_SEED, i as u64);
        let patches = patchify(&item.image, vcfg.patch_size);
        let cap = caption_for(TEST_SEED, i as u64);
        let (ie, te) = sess.embed_pair_one(&patches, &cap, &mut rng)?;
        img.row_mut(i).copy_from_slice(ie);
        txt.row_mut(i).copy_from_slice(te);
    }
    Ok((vcfg, img, txt))
}

/// Recall@`ks` of `probes` against `items` through the gallery scan
/// kernel: `items.row(i)` is the match for `probes.row(i)`.  Items
/// ingest sequentially into a fresh [`GalleryStore`] (ids are then row
/// indices), each probe scans for the top `max(ks)` hits, and a probe
/// scores a hit at `@k` when its own row ranks inside the first `k`.
/// The gallery ranking (score descending, ties by ascending id) is the
/// order `crate::tensor::argsort_desc` produces, so the result is
/// identical to full-sort recall over the pairwise similarity matrix.
fn gallery_recall(probes: &Mat, items: &Mat, ks: &[usize])
                  -> Result<Vec<f64>> {
    let store = GalleryStore::new(items.cols, GalleryOptions::default());
    for i in 0..items.rows {
        store.ingest(items.row(i))?;
    }
    let kmax = ks.iter().copied().max().unwrap_or(1);
    let mut scratch = GalleryScratch::new();
    let mut hits: Vec<Hit> = Vec::new();
    let mut recall = vec![0f64; ks.len()];
    for i in 0..probes.rows {
        scan_into(&store, probes.row(i), kmax, ScanMode::Dot, 1,
                  &mut scratch, &mut hits)?;
        let rank = hits
            .iter()
            .position(|h| h.id == i as u64)
            .unwrap_or(usize::MAX);
        for (qi, &k) in ks.iter().enumerate() {
            if rank < k {
                recall[qi] += 1.0;
            }
        }
    }
    for v in recall.iter_mut() {
        *v = *v * 100.0 / probes.rows.max(1) as f64;
    }
    Ok(recall)
}

/// Evaluate one merge config over `n` test pairs: embed every pair once,
/// then compute both retrieval directions through the gallery scan
/// kernel (text retrieval probes with image embeddings over a caption
/// gallery; image retrieval the reverse).
pub fn eval_config(engine: &Engine, mode: &str, r: f64, n: usize)
                   -> Result<RetrievalRow> {
    let (vcfg, img, txt) = embed_pairs(engine, mode, r, n)?;
    let ks = [1usize, 5, 10];
    let rt = gallery_recall(&img, &txt, &ks)?;
    let ri = gallery_recall(&txt, &img, &ks)?;
    let rsum = rt.iter().sum::<f64>() + ri.iter().sum::<f64>();
    Ok(RetrievalRow {
        mode: mode.into(),
        r,
        rt1: rt[0],
        ri1: ri[0],
        rsum,
        gflops: flops::vit_gflops(&vcfg),
    })
}

/// Historical reference: score every (image, caption) pair individually
/// into the full `n x n` similarity matrix and full-sort the ranks.
/// Scoring uses the same lane-split [`dot`] as the gallery scan (and as
/// [`JointSession::score`](crate::engine::JointSession::score)), so the
/// gallery-backed [`eval_config`] reproduces these numbers exactly —
/// kept solely as the parity oracle for that claim.
#[deprecated(note = "use the gallery-backed `eval_config`; this per-pair \
                     O(n^2) loop is its recall parity reference")]
pub fn eval_config_pairwise(engine: &Engine, mode: &str, r: f64, n: usize)
                            -> Result<RetrievalRow> {
    let (vcfg, img, txt) = embed_pairs(engine, mode, r, n)?;
    let mut sim = Mat::zeros(n, n);
    for i in 0..n {
        for j in 0..n {
            sim.data[i * n + j] = dot(img.row(i), txt.row(j));
        }
    }
    let (rt, ri, rsum) = recall_at_k(&sim, &[1, 5, 10]);
    Ok(RetrievalRow {
        mode: mode.into(),
        r,
        rt1: rt[0],
        ri1: ri[0],
        rsum,
        gflops: flops::vit_gflops(&vcfg),
    })
}

/// Sweep for the Figure 3 curves.
pub fn sweep(engine: &Engine, modes: &[&str], rs: &[f64], n: usize)
             -> Result<Vec<RetrievalRow>> {
    let mut rows = vec![eval_config(engine, "none", 1.0, n)?];
    for &mode in modes {
        for &r in rs {
            rows.push(eval_config(engine, mode, r, n)?);
        }
    }
    Ok(rows)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::synthetic_mm_store;

    /// The gallery-backed recall sweep must reproduce the per-pair
    /// full-sort reference **exactly** (f64 equality, no tolerance):
    /// identical dot kernel, identical tie order, top-k == full-sort
    /// prefix.
    #[test]
    #[allow(deprecated)]
    fn gallery_recall_matches_pairwise_reference_exactly() {
        let engine = Engine::from_store(synthetic_mm_store(
            &ViTConfig::default(), 7));
        for (mode, r) in [("none", 1.0f64), ("pitome", 0.9)] {
            let a = eval_config(&engine, mode, r, 24).unwrap();
            let b = eval_config_pairwise(&engine, mode, r, 24).unwrap();
            assert_eq!(a.rt1, b.rt1, "{mode}: rt1 diverged");
            assert_eq!(a.ri1, b.ri1, "{mode}: ri1 diverged");
            assert_eq!(a.rsum, b.rsum, "{mode}: rsum diverged");
        }
    }
}
