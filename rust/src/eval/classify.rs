//! Image classification experiments (Table 6 / Figure 6): off-the-shelf
//! accuracy vs FLOPs per merge mode and ratio, on ShapeBench with the CPU
//! reference ViT, plus FLOPs cost-model rows for the paper-scale backbones.

use crate::config::ViTConfig;
use crate::data::{patchify, shape_item, Rng, TEST_SEED};
use crate::error::Result;
use crate::model::{flops, ParamStore, ViTModel};

/// One result row.
#[derive(Clone, Debug)]
pub struct ClassifyRow {
    /// merge mode
    pub mode: String,
    /// keep-ratio
    pub r: f64,
    /// off-the-shelf accuracy (%)
    pub acc: f64,
    /// GFLOPs per sample (analytic)
    pub gflops: f64,
    /// FLOPs speedup vs uncompressed
    pub speedup: f64,
}

/// Evaluate one (mode, r) configuration over `n_test` ShapeBench items.
pub fn eval_config(ps: &ParamStore, mode: &str, r: f64, n_test: usize)
                   -> Result<ClassifyRow> {
    let cfg = ViTConfig {
        merge_mode: mode.to_string(),
        merge_r: r,
        ..Default::default()
    };
    let model = ViTModel::new(ps, cfg.clone());
    let mut rng = Rng::new(0xE7A1);
    let mut correct = 0usize;
    for i in 0..n_test {
        let item = shape_item(TEST_SEED, i as u64);
        let patches = patchify(&item.image, cfg.patch_size);
        if model.predict(&patches, &mut rng)? == item.label {
            correct += 1;
        }
    }
    Ok(ClassifyRow {
        mode: mode.to_string(),
        r,
        acc: 100.0 * correct as f64 / n_test as f64,
        gflops: flops::vit_gflops(&cfg),
        speedup: flops::flops_speedup(&cfg),
    })
}

/// Sweep modes x ratios (the Figure 6 curves).
pub fn sweep(ps: &ParamStore, modes: &[&str], rs: &[f64], n_test: usize)
             -> Result<Vec<ClassifyRow>> {
    let mut rows = Vec::new();
    rows.push(eval_config(ps, "none", 1.0, n_test)?);
    for &mode in modes {
        for &r in rs {
            rows.push(eval_config(ps, mode, r, n_test)?);
        }
    }
    Ok(rows)
}

/// Paper-scale FLOPs rows (Table 6's FLOPs column) via the cost model —
/// these backbones are cost-modeled, not executed (DESIGN.md §6).
pub fn paper_scale_flops(rs: &[f64]) -> Vec<(String, f64, f64)> {
    let mut out = Vec::new();
    for name in ["deit-t", "deit-s", "mae-l", "mae-h"] {
        let base = ViTConfig::preset(name).unwrap();
        out.push((format!("{name} (base)"), flops::vit_gflops(&base), 1.0));
        for &r in rs {
            let mut c = base.clone();
            c.merge_mode = "pitome".into();
            c.merge_r = r;
            out.push((format!("{name} r={r}"), flops::vit_gflops(&c),
                      flops::flops_speedup(&c)));
        }
    }
    out
}
