//! Image classification experiments (Table 6 / Figure 6): off-the-shelf
//! accuracy vs FLOPs per merge mode and ratio, on ShapeBench with the CPU
//! reference ViT, plus FLOPs cost-model rows for the paper-scale backbones.

use crate::config::ViTConfig;
use crate::data::{patchify, shape_item, TEST_SEED};
use crate::engine::Engine;
use crate::error::Result;
use crate::model::flops;

/// One result row.
#[derive(Clone, Debug)]
pub struct ClassifyRow {
    /// merge mode
    pub mode: String,
    /// keep-ratio
    pub r: f64,
    /// off-the-shelf accuracy (%)
    pub acc: f64,
    /// GFLOPs per sample (analytic)
    pub gflops: f64,
    /// FLOPs speedup vs uncompressed
    pub speedup: f64,
}

/// Items scored per batched encoder pass.
const EVAL_CHUNK: usize = 32;

/// Evaluate one (mode, r) configuration over `n_test` ShapeBench items,
/// batching the encoder across all available worker threads.
pub fn eval_config(engine: &Engine, mode: &str, r: f64, n_test: usize)
                   -> Result<ClassifyRow> {
    eval_config_with_workers(engine, mode, r, n_test,
                             crate::merge::batch::recommended_workers())
}

/// [`eval_config`] with an explicit worker-thread count (1 = serial).
pub fn eval_config_with_workers(engine: &Engine, mode: &str, r: f64,
                                n_test: usize, workers: usize)
                                -> Result<ClassifyRow> {
    let cfg = ViTConfig {
        merge_mode: mode.to_string(),
        merge_r: r,
        ..Default::default()
    };
    let mut correct = 0usize;
    let mut done = 0usize;
    // one session for the whole sweep: slots, scratches, outputs, and
    // logits buffers are all reused across every eval chunk
    let mut sess = engine.vit_session(&cfg)?;
    sess.set_workers(workers);
    while done < n_test {
        let count = EVAL_CHUNK.min(n_test - done);
        sess.begin(count);
        let mut labels = Vec::with_capacity(count);
        for j in 0..count {
            let item = shape_item(TEST_SEED, (done + j) as u64);
            sess.set_patches(j, &patchify(&item.image, cfg.patch_size))?;
            labels.push(item.label);
        }
        sess.forward(0xE7A1 ^ done as u64)?;
        correct += labels
            .iter()
            .enumerate()
            .filter(|(j, l)| sess.predict(*j) == **l)
            .count();
        done += count;
    }
    Ok(ClassifyRow {
        mode: mode.to_string(),
        r,
        acc: 100.0 * correct as f64 / n_test as f64,
        gflops: flops::vit_gflops(&cfg),
        speedup: flops::flops_speedup(&cfg),
    })
}

/// Sweep modes x ratios (the Figure 6 curves).
pub fn sweep(engine: &Engine, modes: &[&str], rs: &[f64], n_test: usize)
             -> Result<Vec<ClassifyRow>> {
    let mut rows = Vec::new();
    rows.push(eval_config(engine, "none", 1.0, n_test)?);
    for &mode in modes {
        for &r in rs {
            rows.push(eval_config(engine, mode, r, n_test)?);
        }
    }
    Ok(rows)
}

/// Paper-scale FLOPs rows (Table 6's FLOPs column) via the cost model —
/// these backbones are cost-modeled, not executed (DESIGN.md §6).
pub fn paper_scale_flops(rs: &[f64]) -> Vec<(String, f64, f64)> {
    let mut out = Vec::new();
    for name in ["deit-t", "deit-s", "mae-l", "mae-h"] {
        let base = ViTConfig::preset(name).unwrap();
        out.push((format!("{name} (base)"), flops::vit_gflops(&base), 1.0));
        for &r in rs {
            let mut c = base.clone();
            c.merge_mode = "pitome".into();
            c.merge_r = r;
            out.push((format!("{name} r={r}"), flops::vit_gflops(&c),
                      flops::flops_speedup(&c)));
        }
    }
    out
}
