//! Experiment drivers: one module per paper table/figure family.
//! The `bench_*` binaries are thin CLI wrappers over these.

pub mod ablation;
pub mod classify;
pub mod retrieval;
pub mod spectral;
pub mod textcls;
pub mod vqa;

use crate::tensor::argmax;

/// Accuracy of predicted-class vs labels.
pub fn accuracy(preds: &[usize], labels: &[usize]) -> f64 {
    assert_eq!(preds.len(), labels.len());
    if preds.is_empty() {
        return 0.0;
    }
    let ok = preds.iter().zip(labels).filter(|(p, l)| p == l).count();
    ok as f64 / preds.len() as f64
}

/// argmax over logits rows.
pub fn predict_rows(logits: &[Vec<f32>]) -> Vec<usize> {
    logits.iter().map(|r| argmax(r)).collect()
}

/// Recall@k both directions over a similarity matrix (images x texts,
/// diagonal = matching pairs). Returns (Rt@ks, Ri@ks, Rsum).
pub fn recall_at_k(sim: &crate::tensor::Mat, ks: &[usize])
                   -> (Vec<f64>, Vec<f64>, f64) {
    let n = sim.rows;
    let mut rt = vec![0f64; ks.len()];
    let mut ri = vec![0f64; ks.len()];
    for i in 0..n {
        // text retrieval given image i: rank texts by sim[i, :]
        let row: Vec<f32> = sim.row(i).to_vec();
        let order = crate::tensor::argsort_desc(&row);
        let rank = order.iter().position(|&j| j == i).unwrap();
        for (qi, &k) in ks.iter().enumerate() {
            if rank < k {
                rt[qi] += 1.0;
            }
        }
        // image retrieval given text i: rank images by sim[:, i]
        let col: Vec<f32> = (0..n).map(|r| sim.get(r, i)).collect();
        let order = crate::tensor::argsort_desc(&col);
        let rank = order.iter().position(|&j| j == i).unwrap();
        for (qi, &k) in ks.iter().enumerate() {
            if rank < k {
                ri[qi] += 1.0;
            }
        }
    }
    for v in rt.iter_mut().chain(ri.iter_mut()) {
        *v = *v * 100.0 / n as f64;
    }
    let rsum = rt.iter().sum::<f64>() + ri.iter().sum::<f64>();
    (rt, ri, rsum)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Mat;

    #[test]
    fn accuracy_basics() {
        assert_eq!(accuracy(&[1, 2, 3], &[1, 2, 0]), 2.0 / 3.0);
    }

    #[test]
    fn perfect_sim_gives_full_recall() {
        let n = 10;
        let sim = Mat::from_fn(n, n, |i, j| if i == j { 1.0 } else { 0.0 });
        let (rt, ri, rsum) = recall_at_k(&sim, &[1, 5]);
        assert_eq!(rt, vec![100.0, 100.0]);
        assert_eq!(ri, vec![100.0, 100.0]);
        assert!((rsum - 400.0).abs() < 1e-9);
    }

    #[test]
    fn anti_diagonal_sim_fails_r1() {
        let n = 10;
        let sim = Mat::from_fn(n, n, |i, j| if i + j == n - 1 { 1.0 } else { 0.0 });
        let (rt, _, _) = recall_at_k(&sim, &[1]);
        assert!(rt[0] < 20.0);
    }
}
