//! Theorem 1 validation: spectral distance of PiToMe vs ToMe coarsening.
//!
//! Generates clustered token sets satisfying assumptions A1-A3 (tight
//! intra-cluster cosine, separated clusters, ordered cardinalities),
//! iteratively coarsens with each algorithm's partition, and measures
//! SD(G, Gc) (Eq. 5).  Expected shape: SD_pitome -> ~0 as clusters tighten,
//! SD_tome -> a positive constant.

use crate::data::Rng;
use crate::graph::{spectral_distance_scratch, token_graph, EigScratch,
                   Partition};
use crate::merge::energy::energy_from_gram_into;
use crate::merge::pitome::{ordered_bsm_plan_gram_into, Split};
use crate::merge::tome::tome_plan_gram_into;
use crate::merge::{apply_plan_into, MergePlan, PlanScratch};
use crate::tensor::{CosineGram, Mat};

/// How cluster members are laid out over token positions.  ToMe's parity
/// split is sensitive to this (Lemma 3 / Fig. 1): when a cluster
/// concentrates in one parity class, ToMe must merge across clusters.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Layout {
    /// cluster members occupy consecutive positions (ToMe-friendly)
    Contiguous,
    /// the largest cluster sits on even positions, the rest on odd —
    /// the adversarial case of Fig. 1 (vertical object in raster order)
    Interleaved,
    /// uniformly shuffled positions (average case)
    Shuffled,
}

/// Cluster spec for the synthetic token sets.
#[derive(Clone, Debug)]
pub struct ClusterSpec {
    /// cluster cardinalities (descending, A3)
    pub sizes: Vec<usize>,
    /// feature dim
    pub h: usize,
    /// intra-cluster noise amplitude (A1: smaller -> cos -> 1)
    pub noise: f64,
    /// RNG seed
    pub seed: u64,
    /// token position layout
    pub layout: Layout,
}

/// Generate token features with well-separated cluster centers.
/// Also returns the ground-truth cluster id per token.
pub fn clustered_tokens(spec: &ClusterSpec) -> (Mat, Vec<usize>) {
    let mut rng = Rng::new(spec.seed);
    let n_clusters = spec.sizes.len();
    // near-orthogonal centers: random +-1 sign vectors scaled
    let mut centers: Vec<Vec<f32>> = Vec::with_capacity(n_clusters);
    for _ in 0..n_clusters {
        centers.push((0..spec.h)
            .map(|_| if rng.next_f64() < 0.5 { -1.0 } else { 1.0 })
            .collect());
    }
    let n: usize = spec.sizes.iter().sum();
    let mut labels = Vec::with_capacity(n);
    for (c, &sz) in spec.sizes.iter().enumerate() {
        for _ in 0..sz {
            labels.push(c);
        }
    }
    match spec.layout {
        Layout::Contiguous => {}
        Layout::Interleaved => {
            // big cluster -> even slots (as far as it reaches), rest -> odd
            let big: Vec<usize> = labels.iter().copied()
                .filter(|&l| l == 0).collect();
            let rest: Vec<usize> = labels.iter().copied()
                .filter(|&l| l != 0).collect();
            let mut out = vec![0usize; n];
            let (mut bi, mut ri) = (0usize, 0usize);
            for (pos, slot) in out.iter_mut().enumerate() {
                *slot = if pos % 2 == 0 && bi < big.len() {
                    bi += 1;
                    big[bi - 1]
                } else if ri < rest.len() {
                    ri += 1;
                    rest[ri - 1]
                } else {
                    bi += 1;
                    big[bi - 1]
                };
            }
            labels = out;
        }
        Layout::Shuffled => {
            for i in (1..n).rev() {
                let j = rng.next_below((i + 1) as u64) as usize;
                labels.swap(i, j);
            }
        }
    }
    let mut kf = Mat::zeros(n, spec.h);
    for (i, &lab) in labels.iter().enumerate() {
        let r = kf.row_mut(i);
        for (j, v) in r.iter_mut().enumerate() {
            *v = centers[lab][j]
                + (spec.noise * (rng.next_f64() * 2.0 - 1.0)) as f32;
        }
    }
    (kf, labels)
}

/// Which algorithm drives the partition.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CoarsenAlgo {
    /// energy-ordered protected BSM
    PiToMe,
    /// parity-split BSM
    ToMe,
    /// random pruning-style pairing
    Random,
}

/// Reusable workspace for [`iterative_coarsen_scratch`]: the per-step
/// shared Gram, ranking-signal and plan-builder buffers, the in-place
/// [`MergePlan`], ping-pong token/size buffers, and the
/// partition-tracking arrays.  One workspace serves a whole SD(G, Gc)
/// sweep — every (noise, algo, steps) point reuses it, and a warmed
/// sweep performs zero heap allocations (asserted by
/// `tests/alloc_free.rs`).
pub struct CoarsenScratch {
    gram: CosineGram,
    kn: Mat,
    energy: Vec<f32>,
    plan_bufs: PlanScratch,
    plan: MergePlan,
    /// current (coarsened) token features
    kf: Mat,
    /// apply output; ping-pongs with `kf` via `mem::swap`
    next_kf: Mat,
    sizes: Vec<f32>,
    next_sizes: Vec<f32>,
    /// group id per original token
    groups: Vec<usize>,
    /// group id per current token
    token_group: Vec<usize>,
    next_token_group: Vec<usize>,
    /// dense-renumbering table (group ids live in 0..n0)
    remap: Vec<usize>,
}

impl CoarsenScratch {
    /// Empty workspace; buffers grow on first use and are then reused.
    pub fn new() -> CoarsenScratch {
        CoarsenScratch {
            gram: CosineGram::empty(),
            kn: Mat::zeros(0, 0),
            energy: Vec::new(),
            plan_bufs: PlanScratch::new(),
            plan: MergePlan::empty(),
            kf: Mat::zeros(0, 0),
            next_kf: Mat::zeros(0, 0),
            sizes: Vec::new(),
            next_sizes: Vec::new(),
            groups: Vec::new(),
            token_group: Vec::new(),
            next_token_group: Vec::new(),
            remap: Vec::new(),
        }
    }
}

impl Default for CoarsenScratch {
    fn default() -> Self {
        CoarsenScratch::new()
    }
}

/// Iteratively coarsen `steps` times, merging `k` pairs per step, tracking
/// the induced partition of the *original* tokens (allocating wrapper
/// over [`iterative_coarsen_scratch`]).
pub fn iterative_coarsen(kf0: &Mat, algo: CoarsenAlgo, steps: usize, k: usize,
                         margin: f32, seed: u64) -> Partition {
    let mut scratch = CoarsenScratch::new();
    let mut p = Partition::identity(0);
    iterative_coarsen_scratch(kf0, algo, steps, k, margin, seed, &mut scratch,
                              &mut p);
    p
}

/// Iteratively coarsen into a caller-owned workspace and output
/// partition: one in-place Gram rebuild per step
/// ([`CosineGram::rebuild`]), plans built by the allocation-free
/// `*_plan_gram_into` builders, and tokens merged via [`apply_plan_into`]
/// with ping-ponged buffers — numerically identical to the historical
/// per-step-build path (the smoke mode of `benches/spectral_bench.rs`
/// gates on that parity at 1e-6 before reporting timings).
#[allow(clippy::too_many_arguments)]
pub fn iterative_coarsen_scratch(kf0: &Mat, algo: CoarsenAlgo, steps: usize,
                                 k: usize, margin: f32, seed: u64,
                                 s: &mut CoarsenScratch, out: &mut Partition) {
    let n0 = kf0.rows;
    // group id per original token; current tokens map to group ids
    s.groups.clear();
    s.groups.extend(0..n0);
    s.token_group.clear();
    s.token_group.extend(0..n0);
    s.kf.copy_from(kf0);
    s.sizes.clear();
    s.sizes.resize(n0, 1f32);
    let mut rng = Rng::new(seed);
    for _ in 0..steps {
        if s.kf.rows < 2 * k + 1 {
            break;
        }
        // one shared Gram per coarsening step, rebuilt in place and
        // reused by scoring + matching
        s.gram.rebuild(&s.kf, &mut s.kn);
        match algo {
            CoarsenAlgo::PiToMe => {
                energy_from_gram_into(&s.gram, margin, &mut s.energy);
                ordered_bsm_plan_gram_into(&s.gram, &s.energy, k, 0,
                                           Split::Alternate, true, &mut rng,
                                           &mut s.plan_bufs, &mut s.plan);
            }
            CoarsenAlgo::ToMe => {
                tome_plan_gram_into(&s.gram, k, 0, None, &mut s.plan_bufs,
                                    &mut s.plan);
            }
            CoarsenAlgo::Random => {
                s.energy.clear();
                for _ in 0..s.kf.rows {
                    s.energy.push(rng.next_f64() as f32);
                }
                ordered_bsm_plan_gram_into(&s.gram, &s.energy, k, 0,
                                           Split::Random, true, &mut rng,
                                           &mut s.plan_bufs, &mut s.plan);
            }
        }
        // update partition: token a joins the group of b[dst[a]]
        s.next_token_group.clear();
        for &p in &s.plan.protect {
            s.next_token_group.push(s.token_group[p]);
        }
        for &b in &s.plan.b {
            s.next_token_group.push(s.token_group[b]);
        }
        for (ai, &a) in s.plan.a.iter().enumerate() {
            let target_group = s.token_group[s.plan.b[s.plan.dst[ai]]];
            let src_group = s.token_group[a];
            for g in s.groups.iter_mut() {
                if *g == src_group {
                    *g = target_group;
                }
            }
        }
        apply_plan_into(&s.kf, &s.sizes, &s.plan, &mut s.next_kf,
                        &mut s.next_sizes);
        std::mem::swap(&mut s.kf, &mut s.next_kf);
        std::mem::swap(&mut s.sizes, &mut s.next_sizes);
        std::mem::swap(&mut s.token_group, &mut s.next_token_group);
    }
    // renumber groups densely in first-seen order (allocation-free: group
    // ids are original token indices, so the table is indexed by 0..n0)
    s.remap.clear();
    s.remap.resize(n0, usize::MAX);
    let mut next = 0usize;
    out.assign.clear();
    for &g in &s.groups {
        if s.remap[g] == usize::MAX {
            s.remap[g] = next;
            next += 1;
        }
        out.assign.push(s.remap[g]);
    }
    out.n_groups = next;
}

/// One Theorem-1 experiment row.
#[derive(Clone, Debug)]
pub struct SpectralRow {
    /// intra-cluster noise
    pub noise: f64,
    /// algorithm
    pub algo: String,
    /// spectral distance after coarsening
    pub sd: f32,
    /// fraction of merges that crossed ground-truth clusters
    pub cross_cluster_frac: f64,
}

/// Run the sweep: for each noise level, coarsen with each algorithm and
/// report SD and cross-cluster merge fraction.  One [`CoarsenScratch`]
/// and one [`EigScratch`] serve the whole sweep, so every SD(G, Gc)
/// point after the first runs through warmed buffers.
pub fn theorem1_sweep(noises: &[f64], steps: usize, k: usize)
                      -> Vec<SpectralRow> {
    let mut rows = Vec::new();
    let mut scratch = CoarsenScratch::new();
    let mut eig = EigScratch::new();
    let mut p = Partition::identity(0);
    for &noise in noises {
        let spec = ClusterSpec {
            sizes: vec![16, 8, 6, 2],
            h: 16,
            noise,
            seed: 42,
            layout: Layout::Interleaved,
        };
        let (kf, labels) = clustered_tokens(&spec);
        let w = token_graph(&kf);
        for (algo, name) in [(CoarsenAlgo::PiToMe, "pitome"),
                             (CoarsenAlgo::ToMe, "tome"),
                             (CoarsenAlgo::Random, "random")] {
            iterative_coarsen_scratch(&kf, algo, steps, k, 0.6, 7,
                                      &mut scratch, &mut p);
            let sd = spectral_distance_scratch(&w, &p, &mut eig);
            rows.push(SpectralRow {
                noise,
                algo: name.into(),
                sd,
                cross_cluster_frac: cross_cluster_fraction(&p, &labels),
            });
        }
    }
    rows
}

/// Fraction of partition groups that mix ground-truth clusters.
pub fn cross_cluster_fraction(p: &Partition, labels: &[usize]) -> f64 {
    let mut mixed = 0usize;
    let mut merged_groups = 0usize;
    for g in 0..p.n_groups {
        let members: Vec<usize> = (0..labels.len())
            .filter(|&i| p.assign[i] == g)
            .collect();
        if members.len() < 2 {
            continue;
        }
        merged_groups += 1;
        let first = labels[members[0]];
        if members.iter().any(|&m| labels[m] != first) {
            mixed += 1;
        }
    }
    if merged_groups == 0 {
        0.0
    } else {
        mixed as f64 / merged_groups as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pitome_beats_tome_on_tight_clusters() {
        let rows = theorem1_sweep(&[0.05], 3, 3);
        let sd = |name: &str| rows.iter().find(|r| r.algo == name).unwrap().sd;
        assert!(sd("pitome") <= sd("tome") + 1e-4,
                "pitome {} vs tome {}", sd("pitome"), sd("tome"));
    }

    #[test]
    fn pitome_never_crosses_clusters_when_tight() {
        let rows = theorem1_sweep(&[0.02], 3, 3);
        let r = rows.iter().find(|r| r.algo == "pitome").unwrap();
        assert_eq!(r.cross_cluster_frac, 0.0, "{r:?}");
    }

    #[test]
    fn scratch_coarsen_matches_fresh_across_algos_and_shapes() {
        // ONE reused workspace driven through growing and shrinking token
        // sets and every algorithm must reproduce the allocating wrapper
        // (which runs the same code against fresh buffers) exactly
        let mut scratch = CoarsenScratch::new();
        let mut p = Partition::identity(0);
        let specs = [
            ClusterSpec { sizes: vec![16, 8, 6, 2], h: 16, noise: 0.1,
                          seed: 5, layout: Layout::Interleaved },
            ClusterSpec { sizes: vec![8, 4], h: 8, noise: 0.05,
                          seed: 1, layout: Layout::Contiguous },
            ClusterSpec { sizes: vec![12, 10, 6], h: 12, noise: 0.2,
                          seed: 3, layout: Layout::Shuffled },
        ];
        for (si, spec) in specs.iter().enumerate() {
            let (kf, _) = clustered_tokens(spec);
            for algo in [CoarsenAlgo::PiToMe, CoarsenAlgo::ToMe,
                         CoarsenAlgo::Random] {
                iterative_coarsen_scratch(&kf, algo, 3, 2, 0.5, 9,
                                          &mut scratch, &mut p);
                let want = iterative_coarsen(&kf, algo, 3, 2, 0.5, 9);
                assert_eq!(p.assign, want.assign, "spec {si} {algo:?}");
                assert_eq!(p.n_groups, want.n_groups, "spec {si} {algo:?}");
            }
        }
    }

    #[test]
    fn partition_covers_all_tokens() {
        let spec = ClusterSpec { sizes: vec![8, 4], h: 8, noise: 0.05,
                                 seed: 1, layout: Layout::Contiguous };
        let (kf, _) = clustered_tokens(&spec);
        let p = iterative_coarsen(&kf, CoarsenAlgo::PiToMe, 2, 2, 0.5, 3);
        assert_eq!(p.assign.len(), 12);
        // sizes sum to n
        assert_eq!(p.sizes().iter().sum::<usize>(), 12);
    }
}
