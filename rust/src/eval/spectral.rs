//! Theorem 1 validation: spectral distance of PiToMe vs ToMe coarsening.
//!
//! Generates clustered token sets satisfying assumptions A1-A3 (tight
//! intra-cluster cosine, separated clusters, ordered cardinalities),
//! iteratively coarsens with each algorithm's partition, and measures
//! SD(G, Gc) (Eq. 5).  Expected shape: SD_pitome -> ~0 as clusters tighten,
//! SD_tome -> a positive constant.

use crate::data::Rng;
use crate::graph::{spectral_distance, token_graph, Partition};
use crate::merge::energy::energy_from_gram;
use crate::merge::pitome::{ordered_bsm_plan_gram, Split};
use crate::merge::tome::tome_plan_gram;
use crate::merge::{apply_plan, MergePlan};
use crate::tensor::{CosineGram, Mat};

/// How cluster members are laid out over token positions.  ToMe's parity
/// split is sensitive to this (Lemma 3 / Fig. 1): when a cluster
/// concentrates in one parity class, ToMe must merge across clusters.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Layout {
    /// cluster members occupy consecutive positions (ToMe-friendly)
    Contiguous,
    /// the largest cluster sits on even positions, the rest on odd —
    /// the adversarial case of Fig. 1 (vertical object in raster order)
    Interleaved,
    /// uniformly shuffled positions (average case)
    Shuffled,
}

/// Cluster spec for the synthetic token sets.
#[derive(Clone, Debug)]
pub struct ClusterSpec {
    /// cluster cardinalities (descending, A3)
    pub sizes: Vec<usize>,
    /// feature dim
    pub h: usize,
    /// intra-cluster noise amplitude (A1: smaller -> cos -> 1)
    pub noise: f64,
    /// RNG seed
    pub seed: u64,
    /// token position layout
    pub layout: Layout,
}

/// Generate token features with well-separated cluster centers.
/// Also returns the ground-truth cluster id per token.
pub fn clustered_tokens(spec: &ClusterSpec) -> (Mat, Vec<usize>) {
    let mut rng = Rng::new(spec.seed);
    let n_clusters = spec.sizes.len();
    // near-orthogonal centers: random +-1 sign vectors scaled
    let mut centers: Vec<Vec<f32>> = Vec::with_capacity(n_clusters);
    for _ in 0..n_clusters {
        centers.push((0..spec.h)
            .map(|_| if rng.next_f64() < 0.5 { -1.0 } else { 1.0 })
            .collect());
    }
    let n: usize = spec.sizes.iter().sum();
    let mut labels = Vec::with_capacity(n);
    for (c, &sz) in spec.sizes.iter().enumerate() {
        for _ in 0..sz {
            labels.push(c);
        }
    }
    match spec.layout {
        Layout::Contiguous => {}
        Layout::Interleaved => {
            // big cluster -> even slots (as far as it reaches), rest -> odd
            let big: Vec<usize> = labels.iter().copied()
                .filter(|&l| l == 0).collect();
            let rest: Vec<usize> = labels.iter().copied()
                .filter(|&l| l != 0).collect();
            let mut out = vec![0usize; n];
            let (mut bi, mut ri) = (0usize, 0usize);
            for (pos, slot) in out.iter_mut().enumerate() {
                *slot = if pos % 2 == 0 && bi < big.len() {
                    bi += 1;
                    big[bi - 1]
                } else if ri < rest.len() {
                    ri += 1;
                    rest[ri - 1]
                } else {
                    bi += 1;
                    big[bi - 1]
                };
            }
            labels = out;
        }
        Layout::Shuffled => {
            for i in (1..n).rev() {
                let j = rng.next_below((i + 1) as u64) as usize;
                labels.swap(i, j);
            }
        }
    }
    let mut kf = Mat::zeros(n, spec.h);
    for (i, &lab) in labels.iter().enumerate() {
        let r = kf.row_mut(i);
        for (j, v) in r.iter_mut().enumerate() {
            *v = centers[lab][j]
                + (spec.noise * (rng.next_f64() * 2.0 - 1.0)) as f32;
        }
    }
    (kf, labels)
}

/// Which algorithm drives the partition.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CoarsenAlgo {
    /// energy-ordered protected BSM
    PiToMe,
    /// parity-split BSM
    ToMe,
    /// random pruning-style pairing
    Random,
}

/// Iteratively coarsen `steps` times, merging `k` pairs per step, tracking
/// the induced partition of the *original* tokens.
pub fn iterative_coarsen(kf0: &Mat, algo: CoarsenAlgo, steps: usize, k: usize,
                         margin: f32, seed: u64) -> Partition {
    let n0 = kf0.rows;
    // group id per original token; current tokens map to group ids
    let mut groups: Vec<usize> = (0..n0).collect(); // original -> group
    let mut token_group: Vec<usize> = (0..n0).collect(); // current token -> group
    let mut kf = kf0.clone();
    let mut sizes = vec![1f32; n0];
    let mut rng = Rng::new(seed);
    for _ in 0..steps {
        if kf.rows < 2 * k + 1 {
            break;
        }
        // one shared Gram per coarsening step, reused by scoring + matching
        let g = CosineGram::build(&kf);
        let plan: MergePlan = match algo {
            CoarsenAlgo::PiToMe => {
                let e = energy_from_gram(&g, margin);
                ordered_bsm_plan_gram(&g, &e, k, 0, Split::Alternate, true, &mut rng)
            }
            CoarsenAlgo::ToMe => tome_plan_gram(&g, k, 0, None),
            CoarsenAlgo::Random => {
                let e: Vec<f32> = (0..kf.rows).map(|_| rng.next_f64() as f32).collect();
                ordered_bsm_plan_gram(&g, &e, k, 0, Split::Random, true, &mut rng)
            }
        };
        // update partition: token a joins the group of b[dst[a]]
        let mut new_token_group = Vec::with_capacity(plan.n_out());
        for &p in &plan.protect {
            new_token_group.push(token_group[p]);
        }
        for &b in &plan.b {
            new_token_group.push(token_group[b]);
        }
        for (ai, &a) in plan.a.iter().enumerate() {
            let target_group = token_group[plan.b[plan.dst[ai]]];
            let src_group = token_group[a];
            for g in groups.iter_mut() {
                if *g == src_group {
                    *g = target_group;
                }
            }
        }
        let (kf2, sizes2) = apply_plan(&kf, &sizes, &plan);
        kf = kf2;
        sizes = sizes2;
        token_group = new_token_group;
    }
    // renumber groups densely
    let mut remap = std::collections::HashMap::new();
    let mut next = 0usize;
    let assign: Vec<usize> = groups
        .iter()
        .map(|&g| *remap.entry(g).or_insert_with(|| { let v = next; next += 1; v }))
        .collect();
    Partition::from_assign(assign)
}

/// One Theorem-1 experiment row.
#[derive(Clone, Debug)]
pub struct SpectralRow {
    /// intra-cluster noise
    pub noise: f64,
    /// algorithm
    pub algo: String,
    /// spectral distance after coarsening
    pub sd: f32,
    /// fraction of merges that crossed ground-truth clusters
    pub cross_cluster_frac: f64,
}

/// Run the sweep: for each noise level, coarsen with each algorithm and
/// report SD and cross-cluster merge fraction.
pub fn theorem1_sweep(noises: &[f64], steps: usize, k: usize)
                      -> Vec<SpectralRow> {
    let mut rows = Vec::new();
    for &noise in noises {
        let spec = ClusterSpec {
            sizes: vec![16, 8, 6, 2],
            h: 16,
            noise,
            seed: 42,
            layout: Layout::Interleaved,
        };
        let (kf, labels) = clustered_tokens(&spec);
        let w = token_graph(&kf);
        for (algo, name) in [(CoarsenAlgo::PiToMe, "pitome"),
                             (CoarsenAlgo::ToMe, "tome"),
                             (CoarsenAlgo::Random, "random")] {
            let p = iterative_coarsen(&kf, algo, steps, k, 0.6, 7);
            let sd = spectral_distance(&w, &p);
            rows.push(SpectralRow {
                noise,
                algo: name.into(),
                sd,
                cross_cluster_frac: cross_cluster_fraction(&p, &labels),
            });
        }
    }
    rows
}

/// Fraction of partition groups that mix ground-truth clusters.
pub fn cross_cluster_fraction(p: &Partition, labels: &[usize]) -> f64 {
    let mut mixed = 0usize;
    let mut merged_groups = 0usize;
    for g in 0..p.n_groups {
        let members: Vec<usize> = (0..labels.len())
            .filter(|&i| p.assign[i] == g)
            .collect();
        if members.len() < 2 {
            continue;
        }
        merged_groups += 1;
        let first = labels[members[0]];
        if members.iter().any(|&m| labels[m] != first) {
            mixed += 1;
        }
    }
    if merged_groups == 0 {
        0.0
    } else {
        mixed as f64 / merged_groups as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pitome_beats_tome_on_tight_clusters() {
        let rows = theorem1_sweep(&[0.05], 3, 3);
        let sd = |name: &str| rows.iter().find(|r| r.algo == name).unwrap().sd;
        assert!(sd("pitome") <= sd("tome") + 1e-4,
                "pitome {} vs tome {}", sd("pitome"), sd("tome"));
    }

    #[test]
    fn pitome_never_crosses_clusters_when_tight() {
        let rows = theorem1_sweep(&[0.02], 3, 3);
        let r = rows.iter().find(|r| r.algo == "pitome").unwrap();
        assert_eq!(r.cross_cluster_frac, 0.0, "{r:?}");
    }

    #[test]
    fn partition_covers_all_tokens() {
        let spec = ClusterSpec { sizes: vec![8, 4], h: 8, noise: 0.05,
                                 seed: 1, layout: Layout::Contiguous };
        let (kf, _) = clustered_tokens(&spec);
        let p = iterative_coarsen(&kf, CoarsenAlgo::PiToMe, 2, 2, 0.5, 3);
        assert_eq!(p.assign.len(), 12);
        // sizes sum to n
        assert_eq!(p.sizes().iter().sum::<usize>(), 12);
    }
}
