//! THM1 — Theorem 1 reproduction: spectral distance SD(G, Gc) of PiToMe vs
//! ToMe vs random coarsening as intra-cluster noise varies (assumption A1).
//!
//! Expected shape (paper): SD_pitome -> 0 as clusters tighten; SD_tome
//! converges to a positive constant; see EXPERIMENTS.md §THM1.

use pitome::eval::spectral::{clustered_tokens, cross_cluster_fraction,
                             iterative_coarsen_scratch, theorem1_sweep,
                             ClusterSpec, CoarsenAlgo, CoarsenScratch, Layout};
use pitome::graph::{spectral_distance, token_graph, Partition};
use pitome::util::Args;

fn main() {
    let args = Args::parse();
    let steps = args.get_parse("steps", 4);
    let k = args.get_parse("k", 3);

    println!("# Theorem 1: spectrum preservation of token merging");
    println!("# clusters |V| = [16, 8, 6, 2] (A3), h=16, margin=0.6, \
              interleaved layout (Fig. 1 case)");
    println!("{:<10} {:<10} {:>12} {:>14}", "noise", "algo", "SD(G,Gc)",
             "cross-merges");
    let noises = [0.01, 0.02, 0.05, 0.1, 0.2, 0.4, 0.8];
    for row in theorem1_sweep(&noises, steps, k) {
        println!("{:<10} {:<10} {:>12.4} {:>14.3}",
                 row.noise, row.algo, row.sd, row.cross_cluster_frac);
    }

    // convergence table: SD vs coarsening depth at fixed tight noise
    println!("\n# SD vs coarsening depth (noise = 0.05)");
    println!("{:<8} {:<10} {:>12}", "steps", "algo", "SD(G,Gc)");
    let spec = ClusterSpec { sizes: vec![16, 8, 6, 2], h: 16, noise: 0.05,
                             seed: 42, layout: Layout::Interleaved };
    let (kf, labels) = clustered_tokens(&spec);
    let w = token_graph(&kf);
    // one workspace serves the whole depth table (the scratch-reuse
    // serving pattern; see eval::spectral::CoarsenScratch)
    let mut scratch = CoarsenScratch::new();
    let mut p = Partition::identity(0);
    for s in 1..=5usize {
        for (algo, name) in [(CoarsenAlgo::PiToMe, "pitome"),
                             (CoarsenAlgo::ToMe, "tome"),
                             (CoarsenAlgo::Random, "random")] {
            iterative_coarsen_scratch(&kf, algo, s, k, 0.6, 7, &mut scratch,
                                      &mut p);
            println!("{:<8} {:<10} {:>12.4}  (cross {:.2})", s, name,
                     spectral_distance(&w, &p),
                     cross_cluster_fraction(&p, &labels));
        }
    }
}
