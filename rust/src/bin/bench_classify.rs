//! T6/F6 — Image classification: off-the-shelf accuracy vs FLOPs across
//! merge modes and ratios (Table 6 rows + Figure 6 curves), plus the
//! paper-scale FLOPs cost model for DeiT/MAE backbones.

use pitome::engine::Engine;
use pitome::eval::classify::{eval_config, paper_scale_flops, sweep};
use pitome::model::load_model_params;
use pitome::runtime::Registry;
use pitome::util::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse();
    let dir = std::path::PathBuf::from(args.get("artifacts",
        Registry::default_dir().to_str().unwrap_or("artifacts")));
    let n = args.get_parse("n", 512);
    let ps = load_model_params(&dir, "vit").map_err(|e| anyhow::anyhow!("{e}"))?;
    let engine = Engine::from_store(ps);

    if args.has("figure6") {
        println!("# Figure 6: OTS accuracy vs GFLOPs (ShapeBench ViT-Ti)");
        let rs = [0.975, 0.95, 0.925, 0.9, 0.85, 0.8];
        let modes = ["pitome", "tome", "tofu", "dct", "diffrate"];
        println!("{:<10} {:<7} {:>8} {:>9} {:>9}", "mode", "r", "acc%",
                 "GFLOPs", "speedup");
        for row in sweep(&engine, &modes, &rs, n).map_err(|e| anyhow::anyhow!("{e}"))? {
            println!("{:<10} {:<7} {:>8.2} {:>9.4} {:>8.2}x",
                     row.mode, row.r, row.acc, row.gflops, row.speedup);
        }
        return Ok(());
    }

    println!("# Table 6 (ShapeBench substitution): OTS accuracy per mode, r=0.9");
    println!("{:<10} {:>8} {:>9} {:>9}", "mode", "acc%", "GFLOPs", "speedup");
    let base = eval_config(&engine, "none", 1.0, n).map_err(|e| anyhow::anyhow!("{e}"))?;
    println!("{:<10} {:>8.2} {:>9.4} {:>8.2}x (base)", base.mode, base.acc,
             base.gflops, base.speedup);
    for mode in ["pitome", "tome", "tofu", "dct", "diffrate", "random"] {
        let row = eval_config(&engine, mode, 0.9, n).map_err(|e| anyhow::anyhow!("{e}"))?;
        println!("{:<10} {:>8.2} {:>9.4} {:>8.2}x  (drop {:+.2})",
                 row.mode, row.acc, row.gflops, row.speedup, row.acc - base.acc);
    }

    println!("\n# Table 6 FLOPs column at paper scale (cost model, DESIGN.md §6)");
    for (name, g, s) in paper_scale_flops(&[0.95, 0.9]) {
        println!("  {name:24} {g:8.1} GFLOPs  x{s:.2}");
    }
    Ok(())
}
