//! T7/T9/F10 — Text classification: sentiment accuracy vs FLOPs speedup
//! with compression on the first three layers (Tables 7, 9; Figure 10).

use pitome::engine::Engine;
use pitome::eval::textcls::{eval_config, sweep};
use pitome::model::load_model_params;
use pitome::runtime::Registry;
use pitome::util::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse();
    let dir = std::path::PathBuf::from(args.get("artifacts",
        Registry::default_dir().to_str().unwrap_or("artifacts")));
    let n = args.get_parse("n", 384);
    let ps = load_model_params(&dir, "bert").map_err(|e| anyhow::anyhow!("{e}"))?;
    let engine = Engine::from_store(ps);

    if args.has("sweep") || args.has("figure10") {
        let deep = args.has("deep");
        println!("# Table 9 / Figure 10: accuracy vs r{}",
                 if deep { " (deep-compression extension)" } else { "" });
        let rs = if deep { vec![0.5, 0.35, 0.25, 0.15] }
                 else { vec![0.8, 0.75, 0.7] };
        let modes = ["pitome", "tome", "tofu", "dct", "diffrate"];
        println!("{:<10} {:<7} {:>8} {:>10}", "mode", "r", "acc%", "flops x");
        for row in sweep(&engine, &modes, &rs, n).map_err(|e| anyhow::anyhow!("{e}"))? {
            println!("{:<10} {:<7} {:>8.2} {:>9.2}x",
                     row.mode, row.r, row.acc, row.flops_speedup);
        }
        return Ok(());
    }

    println!("# Table 7 (synthetic sentiment substitution): r = 0.8, first 3 layers");
    println!("{:<10} {:>8} {:>10}", "mode", "acc%", "flops x");
    let base = eval_config(&engine, "none", 1.0, n).map_err(|e| anyhow::anyhow!("{e}"))?;
    println!("{:<10} {:>8.2} {:>9.2}x (base)", base.mode, base.acc,
             base.flops_speedup);
    for mode in ["pitome", "tome", "tofu", "dct", "diffrate"] {
        let row = eval_config(&engine, mode, 0.8, n).map_err(|e| anyhow::anyhow!("{e}"))?;
        println!("{:<10} {:>8.2} {:>9.2}x  (drop {:+.2})",
                 row.mode, row.acc, row.flops_speedup, row.acc - base.acc);
    }
    Ok(())
}
