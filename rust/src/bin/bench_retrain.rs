//! T3/T6-trained — Retrained comparison: drive the AOT `train_step`
//! artifacts from Rust for a few hundred steps per merge mode and compare
//! the resulting accuracy (the Table 3 / Table 6 "trained" columns).

use std::path::PathBuf;

use pitome::data::{patchify, shape_batch, shape_item, Rng, TEST_SEED, TRAIN_SEED};
use pitome::runtime::{load_flat_params, Engine, HostTensor, Registry};
use pitome::util::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse();
    let dir = PathBuf::from(args.get("artifacts",
        Registry::default_dir().to_str().unwrap_or("artifacts")));
    let steps = args.get_parse("steps", 150);
    let n_eval = args.get_parse("n", 256);
    let reg = Registry::load(&dir).map_err(|e| anyhow::anyhow!("{e}"))?;
    let engine = Engine::cpu().map_err(|e| anyhow::anyhow!("{e}"))?;

    println!("# Table 3 shape: retrain-from-scratch with merging active");
    println!("{:<22} {:>9} {:>9}", "train artifact", "loss@end", "eval acc%");

    for name in ["vit_train_none_b32", "vit_train_pitome_r900_b32"] {
        if reg.get(name).is_err() {
            println!("  (skipping {name}: not in registry)");
            continue;
        }
        let exe = engine.load(&reg, name).map_err(|e| anyhow::anyhow!("{e}"))?;
        let psize = exe.entry.meta.param_size.unwrap_or(0);
        let mut flat = load_flat_params(&dir, "vit_init.bin")
            .map_err(|e| anyhow::anyhow!("{e}"))?;
        assert_eq!(flat.len(), psize, "init params size mismatch");
        let mut m = vec![0f32; psize];
        let mut v = vec![0f32; psize];
        let mut last_loss = f32::NAN;
        let batch = 32usize;
        for s in 1..=steps {
            let start = ((s - 1) * batch) % 4000;
            let (xs, ys) = shape_batch(TRAIN_SEED, start as u64, batch, 4);
            let mut xdata = Vec::with_capacity(batch * 64 * 16);
            for x in &xs {
                xdata.extend_from_slice(&x.data);
            }
            let ydata: Vec<i32> = ys.iter().map(|&y| y as i32).collect();
            let out = exe.run(&[
                HostTensor::F32(flat, vec![psize]),
                HostTensor::F32(m, vec![psize]),
                HostTensor::F32(v, vec![psize]),
                HostTensor::F32(vec![s as f32], vec![]),
                HostTensor::F32(xdata, vec![batch, 64, 16]),
                HostTensor::I32(ydata, vec![batch]),
            ]).map_err(|e| anyhow::anyhow!("{e}"))?;
            flat = out[0].as_f32().map_err(|e| anyhow::anyhow!("{e}"))?.to_vec();
            m = out[1].as_f32().map_err(|e| anyhow::anyhow!("{e}"))?.to_vec();
            v = out[2].as_f32().map_err(|e| anyhow::anyhow!("{e}"))?.to_vec();
            last_loss = out[3].as_f32().map_err(|e| anyhow::anyhow!("{e}"))?[0];
            if s % 50 == 0 {
                eprintln!("  [{name}] step {s}/{steps} loss={last_loss:.4}");
            }
        }
        // evaluate with the matching forward artifact (batch 8)
        let fwd_name = if name.contains("pitome") {
            "vit_pitome_r900_b8"
        } else {
            "vit_none_b8"
        };
        let acc = eval_forward(&engine, &reg, fwd_name, &flat, n_eval)?;
        println!("{:<22} {:>9.4} {:>9.2}", name, last_loss, acc);
    }
    Ok(())
}

fn eval_forward(engine: &Engine, reg: &Registry, name: &str, flat: &[f32],
                n: usize) -> anyhow::Result<f64> {
    let exe = engine.load(reg, name).map_err(|e| anyhow::anyhow!("{e}"))?;
    let b = exe.entry.meta.batch;
    let mut ok = 0usize;
    let mut done = 0usize;
    while done < n {
        let count = b.min(n - done);
        let mut xdata = Vec::with_capacity(b * 64 * 16);
        let mut labels = Vec::with_capacity(b);
        for i in 0..b {
            let idx = (done + i.min(count - 1)) as u64;
            let item = shape_item(TEST_SEED, idx);
            xdata.extend_from_slice(&patchify(&item.image, 4).data);
            labels.push(item.label);
        }
        let out = exe.run(&[
            HostTensor::F32(flat.to_vec(), vec![flat.len()]),
            HostTensor::F32(xdata, vec![b, 64, 16]),
        ]).map_err(|e| anyhow::anyhow!("{e}"))?;
        let logits = out[0].as_f32().map_err(|e| anyhow::anyhow!("{e}"))?;
        let classes = logits.len() / b;
        for i in 0..count {
            let row = &logits[i * classes..(i + 1) * classes];
            let pred = row.iter().enumerate()
                .max_by(|a, b2| a.1.partial_cmp(b2.1).unwrap()).unwrap().0;
            if pred == labels[i] {
                ok += 1;
            }
        }
        done += count;
    }
    let _ = Rng::new(0);
    Ok(100.0 * ok as f64 / n as f64)
}
