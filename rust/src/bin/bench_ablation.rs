//! T1/F4 — Ablations: PiToMe without protection, with random split, with
//! CLS-attention indicator (Table 1 rows / Figure 4 curves), on both
//! retrieval and text classification.

use pitome::engine::Engine;
use pitome::eval::ablation::{retrieval_ablation, textcls_ablation, VARIANTS};
use pitome::model::load_model_params;
use pitome::runtime::Registry;
use pitome::util::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse();
    let dir = std::path::PathBuf::from(args.get("artifacts",
        Registry::default_dir().to_str().unwrap_or("artifacts")));
    let n_ret = args.get_parse("n-retrieval", 160);
    let n_txt = args.get_parse("n-text", 256);

    println!("# Table 1 / Figure 4 ablations; variants: {VARIANTS:?}");

    let clip = Engine::from_store(
        load_model_params(&dir, "clip").map_err(|e| anyhow::anyhow!("{e}"))?);
    println!("\n## image-text retrieval (Rsum), r in {{0.925, 0.95, 0.975}}");
    println!("{:<16} {:<7} {:>9}", "variant", "r", "Rsum");
    for row in retrieval_ablation(&clip, &[0.925, 0.95, 0.975], n_ret)
        .map_err(|e| anyhow::anyhow!("{e}"))? {
        println!("{:<16} {:<7} {:>9.2}", row.mode, row.r, row.rsum);
    }

    let bert = Engine::from_store(
        load_model_params(&dir, "bert").map_err(|e| anyhow::anyhow!("{e}"))?);
    println!("\n## text classification (acc %), r in {{0.6, 0.7, 0.8}}");
    println!("{:<16} {:<7} {:>8}", "variant", "r", "acc%");
    for row in textcls_ablation(&bert, &[0.6, 0.7, 0.8], n_txt)
        .map_err(|e| anyhow::anyhow!("{e}"))? {
        println!("{:<16} {:<7} {:>8.2}", row.mode, row.r, row.acc);
    }
    Ok(())
}
