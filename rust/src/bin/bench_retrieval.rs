//! T2/F3 — Image-text retrieval: recall vs FLOPs (Figure 3 curves,
//! Table 2 rows) on synthetic caption pairs with the CPU reference CLIP.
//! Recall runs on the gallery scan kernel; `--pairwise` cross-checks one
//! config against the deprecated per-pair full-sort reference (the two
//! must agree exactly).

use pitome::engine::Engine;
use pitome::eval::retrieval::{eval_config, sweep};
use pitome::model::load_model_params;
use pitome::runtime::Registry;
use pitome::util::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse();
    let dir = std::path::PathBuf::from(args.get("artifacts",
        Registry::default_dir().to_str().unwrap_or("artifacts")));
    let n = args.get_parse("n", 256);
    let ps = match load_model_params(&dir, "clip") {
        Ok(ps) => ps,
        Err(e) => {
            // loud degraded mode: synthetic weights are deterministic
            // but untrained
            println!("(clip params unavailable: {e})");
            println!("(falling back to SYNTHETIC multimodal weights)");
            pitome::model::synthetic_mm_store(
                &pitome::config::ViTConfig::default(), 7)
        }
    };
    let engine = Engine::from_store(ps);

    if args.has("pairwise") {
        return pairwise_parity(&engine, n);
    }

    if args.has("figure3") {
        println!("# Figure 3: Rsum vs GFLOPs per algorithm (synthetic Flickr stand-in)");
        let rs = [0.975, 0.95, 0.925, 0.9, 0.85];
        let modes = ["pitome", "tome", "tofu", "dct", "diffrate"];
        println!("{:<10} {:<7} {:>8} {:>8} {:>9} {:>9}", "mode", "r", "Rt@1",
                 "Ri@1", "Rsum", "GFLOPs");
        for row in sweep(&engine, &modes, &rs, n).map_err(|e| anyhow::anyhow!("{e}"))? {
            println!("{:<10} {:<7} {:>8.2} {:>8.2} {:>9.2} {:>9.4}",
                     row.mode, row.r, row.rt1, row.ri1, row.rsum, row.gflops);
        }
        return Ok(());
    }

    println!("# Table 2 (synthetic substitution): retrieval at r in {{0.95, 0.975}}");
    println!("{:<22} {:>8} {:>8} {:>9} {:>9}", "config", "Rt@1", "Ri@1",
             "Rsum", "GFLOPs");
    let base = eval_config(&engine, "none", 1.0, n).map_err(|e| anyhow::anyhow!("{e}"))?;
    println!("{:<22} {:>8.2} {:>8.2} {:>9.2} {:>9.4}", "base (no merge)",
             base.rt1, base.ri1, base.rsum, base.gflops);
    for (mode, r) in [("pitome", 0.975), ("pitome", 0.95), ("tome", 0.95),
                      ("tofu", 0.95), ("dct", 0.95), ("diffrate", 0.95)] {
        let row = eval_config(&engine, mode, r, n).map_err(|e| anyhow::anyhow!("{e}"))?;
        println!("{:<22} {:>8.2} {:>8.2} {:>9.2} {:>9.4}  (dRsum {:+.2})",
                 format!("{mode} r={r}"), row.rt1, row.ri1, row.rsum,
                 row.gflops, row.rsum - base.rsum);
    }
    Ok(())
}

/// `--pairwise`: cross-check the gallery-backed recall against the
/// historical per-pair O(n^2) reference — the two must agree exactly.
#[allow(deprecated)]
fn pairwise_parity(engine: &Engine, n: usize) -> anyhow::Result<()> {
    let a = eval_config(engine, "pitome", 0.9, n)
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    let b = pitome::eval::retrieval::eval_config_pairwise(
        engine, "pitome", 0.9, n)
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    println!("# pairwise parity (pitome r=0.9, n={n})");
    println!("gallery : Rt@1 {:.2} Ri@1 {:.2} Rsum {:.2}",
             a.rt1, a.ri1, a.rsum);
    println!("pairwise: Rt@1 {:.2} Ri@1 {:.2} Rsum {:.2}",
             b.rt1, b.ri1, b.rsum);
    anyhow::ensure!(a.rt1 == b.rt1 && a.ri1 == b.ri1 && a.rsum == b.rsum,
                    "gallery recall diverged from the pairwise reference");
    println!("parity OK (exact)");
    Ok(())
}
