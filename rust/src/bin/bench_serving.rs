//! T5 — Serving wall-time: end-to-end latency/throughput of the
//! coordinator across compression variants and arrival rates (the Table 5
//! inference-time shape), on the PJRT artifacts.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

use pitome::config::ServingConfig;
use pitome::coordinator::{Coordinator, Qos};
use pitome::data::{generate_trace, patchify, shape_item, TraceConfig, TEST_SEED};
use pitome::runtime::{HostTensor, Registry};
use pitome::util::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse();
    let dir = PathBuf::from(args.get("artifacts",
        Registry::default_dir().to_str().unwrap_or("artifacts")));
    let requests = args.get_parse("requests", 400);
    let reg = Registry::load(&dir).map_err(|e| anyhow::anyhow!("{e}"))?;

    println!("# Table 5 (serving substitution): wall-time per variant");
    println!("{:<22} {:>7} {:>10} {:>10} {:>10} {:>11} {:>10}",
             "variant", "rate", "wall s", "mean us", "p99 us", "mean batch",
             "req/s");

    for (artifact, qos) in [("vit_none_b8", Qos::Accuracy),
                            ("vit_pitome_r900_b8", Qos::Accuracy)] {
        if reg.get(artifact).is_err() {
            println!("  (skipping {artifact}: not in registry)");
            continue;
        }
        for rate in [200.0, 800.0, 3200.0] {
            let selection = [("m", vec![artifact.to_string()])];
            let coord = Arc::new(Coordinator::boot(
                &reg, &dir, &selection, ServingConfig::default())
                .map_err(|e| anyhow::anyhow!("{e}"))?);
            // allow the worker thread to finish compiling
            warmup(&coord)?;
            let trace = generate_trace(&TraceConfig {
                rate, count: requests, seed: 3, ..Default::default()
            });
            let t0 = Instant::now();
            let mut pending = Vec::new();
            for ev in &trace {
                let target = Duration::from_micros(ev.at_us);
                if let Some(w) = target.checked_sub(t0.elapsed()) {
                    std::thread::sleep(w);
                }
                let item = shape_item(TEST_SEED, ev.item);
                let patches = patchify(&item.image, 4);
                pending.push(coord.submit_nowait(
                    "m", qos, vec![HostTensor::F32(patches.data, vec![64, 16])])
                    .map_err(|e| anyhow::anyhow!("{e}"))?);
            }
            let mut ok = 0usize;
            for rx in pending {
                if rx.recv().is_ok() {
                    ok += 1;
                }
            }
            let wall = t0.elapsed().as_secs_f64();
            let snap = &coord.metrics()[0].2;
            println!("{:<22} {:>7} {:>10.2} {:>10.0} {:>10} {:>11.2} {:>10.1}",
                     artifact, rate, wall, snap.mean_us, snap.p99_us,
                     snap.mean_batch, ok as f64 / wall);
        }
    }
    Ok(())
}

fn warmup(coord: &Coordinator) -> anyhow::Result<()> {
    let item = shape_item(TEST_SEED, 0);
    let patches = patchify(&item.image, 4);
    // first request blocks until the worker compiled the artifact
    coord.submit("m", Qos::Accuracy,
                 vec![HostTensor::F32(patches.data, vec![64, 16])])
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    Ok(())
}
