//! T5 — Serving wall-time: end-to-end latency/throughput of the
//! coordinator across compression variants and arrival rates (the Table 5
//! inference-time shape).  With PJRT artifacts present it drives the
//! compiled variants; without them it boots the multi-workload CPU
//! coordinator (vision + text + joint pools over one engine) and replays
//! a mixed trace through the typed router instead.

use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::{Duration, Instant};

use pitome::config::{ServingConfig, ViTConfig};
use pitome::coordinator::{Coordinator, CpuWorkloads, Payload, Qos, Workload};
use pitome::data::{generate_trace, patchify, sent_item, shape_item,
                   vqa_item, TraceConfig, TEST_SEED};
use pitome::engine::JointKind;
use pitome::model::synthetic_mm_store;
use pitome::runtime::{HostTensor, Registry};
use pitome::util::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse();
    let dir = PathBuf::from(args.get("artifacts",
        Registry::default_dir().to_str().unwrap_or("artifacts")));
    let requests = args.get_parse("requests", 400);
    match Registry::load(&dir) {
        Ok(reg) => pjrt_bench(&reg, &dir, requests),
        Err(e) => {
            println!("(no artifact registry: {e})");
            println!("(benching the CPU multi-workload coordinator instead)");
            cpu_mixed_bench(requests)
        }
    }
}

fn pjrt_bench(reg: &Registry, dir: &Path, requests: usize)
              -> anyhow::Result<()> {
    println!("# Table 5 (serving substitution): wall-time per variant");
    println!("{:<22} {:>7} {:>10} {:>10} {:>10} {:>11} {:>10}",
             "variant", "rate", "wall s", "mean us", "p99 us", "mean batch",
             "req/s");

    for (artifact, qos) in [("vit_none_b8", Qos::Accuracy),
                            ("vit_pitome_r900_b8", Qos::Accuracy)] {
        if reg.get(artifact).is_err() {
            println!("  (skipping {artifact}: not in registry)");
            continue;
        }
        for rate in [200.0, 800.0, 3200.0] {
            let selection = [("m", vec![artifact.to_string()])];
            let coord = Arc::new(Coordinator::boot(
                reg, dir, &selection, ServingConfig::default())
                .map_err(|e| anyhow::anyhow!("{e}"))?);
            // allow the worker thread to finish compiling
            warmup(&coord)?;
            let trace = generate_trace(&TraceConfig {
                rate, count: requests, seed: 3, ..Default::default()
            }).map_err(|e| anyhow::anyhow!("{e}"))?;
            let t0 = Instant::now();
            let mut pending = Vec::new();
            for ev in &trace {
                let target = Duration::from_micros(ev.at_us);
                if let Some(w) = target.checked_sub(t0.elapsed()) {
                    std::thread::sleep(w);
                }
                let item = shape_item(TEST_SEED, ev.item);
                let patches = patchify(&item.image, 4);
                pending.push(coord.submit_nowait(
                    "m", qos, vec![HostTensor::F32(patches.data, vec![64, 16])])
                    .map_err(|e| anyhow::anyhow!("{e}"))?);
            }
            let mut ok = 0usize;
            for rx in pending {
                if rx.recv().is_ok() {
                    ok += 1;
                }
            }
            let wall = t0.elapsed().as_secs_f64();
            let snap = &coord.metrics()[0].2;
            println!("{:<22} {:>7} {:>10.2} {:>10.0} {:>10} {:>11.2} {:>10.1}",
                     artifact, rate, wall, snap.mean_us, snap.p99_us,
                     snap.mean_batch, ok as f64 / wall);
        }
    }
    Ok(())
}

/// Replay a mixed Vision/Text/Joint trace through the typed router over
/// the CPU multi-workload coordinator (synthetic multimodal weights).
fn cpu_mixed_bench(requests: usize) -> anyhow::Result<()> {
    let ps = Arc::new(synthetic_mm_store(&ViTConfig::default(), 7));
    let workloads = CpuWorkloads {
        vision: vec![("vit".to_string(),
                      vec![("none".to_string(), 1.0),
                           ("pitome".to_string(), 0.9)])],
        text: vec![("bert".to_string(), vec![("none".to_string(), 1.0)])],
        joint: vec![("vqa".to_string(), JointKind::Vqa,
                     vec![("pitome".to_string(), 0.9)])],
        ..Default::default()
    };
    let cfg = ServingConfig {
        workers: pitome::merge::batch::recommended_workers(),
        ..Default::default()
    };
    let coord = Arc::new(Coordinator::boot_cpu_workloads(&ps, &workloads, cfg)
        .map_err(|e| anyhow::anyhow!("{e}"))?);
    let pool = coord.pool().clone();
    let tcfg = pitome::config::TextConfig::default();

    println!("# mixed-workload CPU serving: {requests} requests \
              (3:1:1 vision:text:joint)");
    let trace = generate_trace(&TraceConfig {
        rate: 600.0, count: requests, seed: 3, ..Default::default()
    }).map_err(|e| anyhow::anyhow!("{e}"))?;
    let t0 = Instant::now();
    let mut pending = Vec::new();
    for (i, ev) in trace.iter().enumerate() {
        let target = Duration::from_micros(ev.at_us);
        if let Some(w) = target.checked_sub(t0.elapsed()) {
            std::thread::sleep(w);
        }
        let rx = match i % 5 {
            3 => {
                let (toks, _) = sent_item(TEST_SEED, ev.item, tcfg.seq_len,
                                          16);
                let mut tt = pool.take_i32(toks.len());
                tt.fill_i32(&toks, &[toks.len()]);
                coord.submit_typed(Workload::Text, "bert", Qos::Accuracy,
                                   Payload::Text(tt))
            }
            4 => {
                let item = shape_item(TEST_SEED, ev.item);
                let patches = patchify(&item.image, 4);
                let (q, _) = vqa_item(TEST_SEED, ev.item);
                let mut vt = pool.take_f32(patches.data.len());
                vt.fill_f32(&patches.data, &[patches.rows, patches.cols]);
                let mut qt = pool.take_i32(q.len());
                qt.fill_i32(&q, &[q.len()]);
                coord.submit_typed(Workload::Joint, "vqa", Qos::Throughput,
                                   Payload::Joint { vision: vt, text: qt })
            }
            _ => {
                let item = shape_item(TEST_SEED, ev.item);
                let patches = patchify(&item.image, 4);
                let mut vt = pool.take_f32(patches.data.len());
                vt.fill_f32(&patches.data, &[patches.rows, patches.cols]);
                coord.submit_typed(Workload::Vision, "vit", Qos::Balanced,
                                   Payload::Vision(vt))
            }
        };
        pending.push(rx.map_err(|e| anyhow::anyhow!("{e}"))?);
    }
    let mut ok = 0usize;
    for rx in pending {
        if rx.recv().is_ok() {
            ok += 1;
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    println!("served {ok}/{requests} in {wall:.2}s ({:.1} req/s)",
             ok as f64 / wall);
    println!("{:<8} {:<6} {:>18} {:>8} {:>10} {:>10} {:>11}",
             "workload", "model", "artifact", "n", "mean us", "p99 us",
             "mean batch");
    for (w, model, artifact, snap) in coord.metrics_typed() {
        println!("{:<8} {:<6} {:>18} {:>8} {:>10.0} {:>10} {:>11.2}",
                 w.name(), model, artifact, snap.count, snap.mean_us,
                 snap.p99_us, snap.mean_batch);
    }
    println!("recycle hit rate: {}", pool.hit_rate_summary());
    Ok(())
}

fn warmup(coord: &Coordinator) -> anyhow::Result<()> {
    let item = shape_item(TEST_SEED, 0);
    let patches = patchify(&item.image, 4);
    // first request blocks until the worker compiled the artifact
    coord.submit("m", Qos::Accuracy,
                 vec![HostTensor::F32(patches.data, vec![64, 16])])
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    Ok(())
}
