//! F8/F9 — Merging schedules: ratio-r vs fixed-k at matched total token
//! removal (App. C).  Reports plans, FLOPs, and OTS accuracy for both
//! schedules on the ShapeBench ViT.

use pitome::config::ViTConfig;
use pitome::data::{patchify, shape_item, Rng, TEST_SEED};
use pitome::engine::Engine;
use pitome::eval::ablation::{matched_fixed_k, schedule_plans};
use pitome::merge::fixed_k_plan;
use pitome::model::flops::encoder_flops;
use pitome::model::{load_model_params, ViTModel};
use pitome::runtime::Registry;
use pitome::util::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse();
    let dir = std::path::PathBuf::from(args.get("artifacts",
        Registry::default_dir().to_str().unwrap_or("artifacts")));
    let n = args.get_parse("n", 384);

    println!("# Figures 8-9: ratio-r vs fixed-k schedules");
    println!("\n## plan shapes (ViT-Ti, 65 tokens, 4 blocks)");
    for (label, plan, removed) in schedule_plans(65, 4) {
        println!("  {label:<14} plan={plan:?} removed={removed}");
    }
    println!("\n## plan shapes at paper scale (197 tokens, 12 blocks)");
    for (label, plan, removed) in schedule_plans(197, 12) {
        let f = encoder_flops(&plan, 384, 1536, true) / 1e9;
        println!("  {label:<14} removed={removed:<4} {f:7.2} GFLOPs end={}",
                 plan.last().unwrap());
    }

    // matched-removal accuracy comparison on ShapeBench
    let engine = Engine::from_store(
        load_model_params(&dir, "vit").map_err(|e| anyhow::anyhow!("{e}"))?);
    println!("\n## OTS accuracy: ratio-r vs matched fixed-k (pitome, ShapeBench)");
    println!("{:<22} {:>8} {:>10}", "schedule", "acc%", "end-tokens");
    for r in [0.95, 0.9, 0.85] {
        // ratio schedule
        let cfg_r = ViTConfig { merge_mode: "pitome".into(), merge_r: r,
                                ..Default::default() };
        let acc_r = accuracy(&engine, &cfg_r, n)?;
        println!("{:<22} {:>8.2} {:>10}", format!("ratio r={r}"), acc_r,
                 cfg_r.plan().last().unwrap());
        // matched fixed-k schedule
        let k = matched_fixed_k(65, 4, r);
        let plan = fixed_k_plan(65, k, 4, 1);
        let mut cfg_k = cfg_r.clone();
        cfg_k.merge_r = 1.0; // plan injected manually below
        let acc_k = accuracy_with_plan(&engine, &cfg_k, plan.clone(), n)?;
        println!("{:<22} {:>8.2} {:>10}", format!("fixed k={k}"), acc_k,
                 plan.last().unwrap());
    }
    Ok(())
}

fn accuracy(engine: &Engine, cfg: &ViTConfig, n: usize)
            -> anyhow::Result<f64> {
    // one session for the whole sweep point (serial shared-RNG contract,
    // matching the historical per-sample predict loop bitwise)
    let mut sess = engine.vit_session(cfg).map_err(|e| anyhow::anyhow!("{e}"))?;
    let mut rng = Rng::new(7);
    let mut ok = 0usize;
    for i in 0..n {
        let item = shape_item(TEST_SEED, i as u64);
        let patches = patchify(&item.image, cfg.patch_size);
        sess.begin(1);
        sess.set_patches(0, &patches).map_err(|e| anyhow::anyhow!("{e}"))?;
        sess.forward_serial(&mut rng).map_err(|e| anyhow::anyhow!("{e}"))?;
        if sess.predict(0) == item.label {
            ok += 1;
        }
    }
    Ok(100.0 * ok as f64 / n as f64)
}

/// Accuracy with an explicit token plan (fixed-k schedules are not a
/// ratio, so we drive the encoder directly).
fn accuracy_with_plan(engine: &Engine, cfg: &ViTConfig,
                      plan: Vec<usize>, n: usize) -> anyhow::Result<f64> {
    use pitome::model::EncoderCfg;
    use pitome::tensor::{argmax, dense, Mat};
    let mut rng = Rng::new(7);
    let ecfg = EncoderCfg {
        prefix: "vit.".into(),
        dim: cfg.dim,
        depth: cfg.depth,
        heads: cfg.heads,
        mode: pitome::merge::MergeMode::PiToMe,
        plan,
        prop_attn: true,
        tofu_threshold: cfg.tofu_threshold,
    };
    // the raw encoder session takes any hand-built config (a fixed-k
    // plan is not expressible as a ratio), reusing its pools per sample
    let mut sess = engine.session(ecfg).map_err(|e| anyhow::anyhow!("{e}"))?;
    let ps = engine.params();
    let model = ViTModel::new(ps, cfg.clone());
    let mut ok = 0usize;
    for i in 0..n {
        let item = shape_item(TEST_SEED, i as u64);
        let patches = patchify(&item.image, cfg.patch_size);
        let x = model.tokens(&patches).map_err(|e| anyhow::anyhow!("{e}"))?;
        let out = sess.forward_one(&x, &mut rng)
            .map_err(|e| anyhow::anyhow!("{e}"))?;
        let f = Mat::from_vec(1, cfg.dim, out.row(0).to_vec());
        let lg = dense(&f, &ps.mat2("vit.head.w").map_err(|e| anyhow::anyhow!("{e}"))?,
                       Some(ps.vec1("vit.head.b").map_err(|e| anyhow::anyhow!("{e}"))?));
        if argmax(&lg.data) == item.label {
            ok += 1;
        }
    }
    Ok(100.0 * ok as f64 / n as f64)
}
