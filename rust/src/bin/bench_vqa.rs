//! T4/F5 — VQA: answer accuracy per merge mode (Table 4 shape) and the
//! accuracy-vs-r curve of Figure 5, on the synthetic VQA model
//! (LLaVA stand-in, DESIGN.md §6), driven through engine
//! `JointSession`s.  `--serve` additionally routes (image, question)
//! pairs through the coordinator's joint worker pool and reports the
//! serving-side numbers (recycle hit rate included).

use std::sync::Arc;

use pitome::config::{ServingConfig, ViTConfig};
use pitome::coordinator::{Coordinator, CpuWorkloads, Payload, Qos, Workload};
use pitome::data::{patchify, shape_item, vqa_item, TEST_SEED};
use pitome::engine::{Engine, JointKind};
use pitome::eval::vqa::{eval_config, sweep};
use pitome::model::{load_model_params, synthetic_mm_store};
use pitome::runtime::Registry;
use pitome::tensor::argmax;
use pitome::util::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse();
    let dir = std::path::PathBuf::from(args.get("artifacts",
        Registry::default_dir().to_str().unwrap_or("artifacts")));
    let n = args.get_parse("n", 384);
    let ps = match load_model_params(&dir, "vqa") {
        Ok(ps) => ps,
        Err(e) => {
            // make the degraded mode loud: synthetic multimodal weights
            // are deterministic but untrained
            println!("(vqa params unavailable: {e})");
            println!("(falling back to SYNTHETIC multimodal weights)");
            synthetic_mm_store(&ViTConfig::default(), 7)
        }
    };
    let engine = Engine::from_store(ps);

    if args.has("serve") {
        return serve_section(&engine, n.min(64));
    }

    if args.has("sweep") {
        println!("# Figure 5: VQA accuracy vs compression ratio r (pitome)");
        println!("{:<10} {:<7} {:>8} {:>9} {:>8}", "mode", "r", "acc%",
                 "GFLOPs", "vis-tok");
        let rs = [0.975, 0.95, 0.925, 0.9, 0.85, 0.8];
        for row in sweep(&engine, &["pitome", "tome"], &rs, n)
            .map_err(|e| anyhow::anyhow!("{e}"))? {
            println!("{:<10} {:<7} {:>8.2} {:>9.4} {:>8}",
                     row.mode, row.r, row.acc, row.gflops, row.visual_tokens);
        }
        return Ok(());
    }

    println!("# Table 4 (synthetic VQA substitution): r = 0.9");
    println!("{:<10} {:>8} {:>9} {:>8}", "mode", "acc%", "GFLOPs", "vis-tok");
    let base = eval_config(&engine, "none", 1.0, n)
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    println!("{:<10} {:>8.2} {:>9.4} {:>8} (base)", base.mode, base.acc,
             base.gflops, base.visual_tokens);
    for mode in ["pitome", "tome", "tofu", "dct", "diffrate"] {
        let row = eval_config(&engine, mode, 0.9, n)
            .map_err(|e| anyhow::anyhow!("{e}"))?;
        println!("{:<10} {:>8.2} {:>9.4} {:>8}  (drop {:+.2})",
                 row.mode, row.acc, row.gflops, row.visual_tokens,
                 row.acc - base.acc);
    }
    Ok(())
}

/// Route `n` (image, question) pairs through the coordinator's joint
/// worker pool (the serving form of Table 5's VQA column) and compare
/// against direct session evaluation.
fn serve_section(engine: &Engine, n: usize) -> anyhow::Result<()> {
    println!("# VQA through the typed router (joint workload, pitome r=0.9)");
    let ps = engine.params_arc();
    let workloads = CpuWorkloads {
        joint: vec![("vqa".to_string(), JointKind::Vqa,
                     vec![("pitome".to_string(), 0.9)])],
        ..Default::default()
    };
    let coord = Arc::new(
        Coordinator::boot_cpu_workloads(&ps, &workloads,
                                        ServingConfig::default())
            .map_err(|e| anyhow::anyhow!("{e}"))?);
    let pool = coord.pool().clone();
    let slot = coord.response_slot();
    let t0 = std::time::Instant::now();
    let mut answers = Vec::with_capacity(n);
    for i in 0..n {
        let item = shape_item(TEST_SEED, i as u64);
        let patches = patchify(&item.image, 4);
        let (q, _) = vqa_item(TEST_SEED, i as u64);
        let mut vt = pool.take_f32(patches.data.len());
        vt.fill_f32(&patches.data, &[patches.rows, patches.cols]);
        let mut qt = pool.take_i32(q.len());
        qt.fill_i32(&q, &[q.len()]);
        coord.submit_pooled(Workload::Joint, "vqa", Qos::Throughput,
                            Payload::Joint { vision: vt, text: qt }, &slot)
            .map_err(|e| anyhow::anyhow!("{e}"))?;
        let resp = slot.recv().map_err(|e| anyhow::anyhow!("{e}"))?;
        answers.push(argmax(resp.outputs[0].as_f32()
            .map_err(|e| anyhow::anyhow!("{e}"))?));
    }
    let wall = t0.elapsed().as_secs_f64();
    let snap = &coord.metrics()[0].2;
    println!("pairs={} wall={:.3}s ({:.1} pair/s) mean={:.0}us p99={}us \
              mean_batch={:.2}",
             n, wall, n as f64 / wall, snap.mean_us, snap.p99_us,
             snap.mean_batch);
    println!("recycle hit rate: {}", pool.hit_rate_summary());
    println!("first answers: {:?}", &answers[..answers.len().min(8)]);
    Ok(())
}
