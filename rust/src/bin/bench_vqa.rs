//! T4/F5 — VQA: answer accuracy per merge mode (Table 4 shape) and the
//! accuracy-vs-r curve of Figure 5, on the synthetic VQA model
//! (LLaVA stand-in, DESIGN.md §6).

use pitome::eval::vqa::{eval_config, sweep};
use pitome::model::load_model_params;
use pitome::runtime::Registry;
use pitome::util::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse();
    let dir = std::path::PathBuf::from(args.get("artifacts",
        Registry::default_dir().to_str().unwrap_or("artifacts")));
    let n = args.get_parse("n", 384);
    let ps = load_model_params(&dir, "vqa").map_err(|e| anyhow::anyhow!("{e}"))?;

    if args.has("sweep") {
        println!("# Figure 5: VQA accuracy vs compression ratio r (pitome)");
        println!("{:<10} {:<7} {:>8} {:>9} {:>8}", "mode", "r", "acc%",
                 "GFLOPs", "vis-tok");
        let rs = [0.975, 0.95, 0.925, 0.9, 0.85, 0.8];
        for row in sweep(&ps, &["pitome", "tome"], &rs, n)
            .map_err(|e| anyhow::anyhow!("{e}"))? {
            println!("{:<10} {:<7} {:>8.2} {:>9.4} {:>8}",
                     row.mode, row.r, row.acc, row.gflops, row.visual_tokens);
        }
        return Ok(());
    }

    println!("# Table 4 (synthetic VQA substitution): r = 0.9");
    println!("{:<10} {:>8} {:>9} {:>8}", "mode", "acc%", "GFLOPs", "vis-tok");
    let base = eval_config(&ps, "none", 1.0, n).map_err(|e| anyhow::anyhow!("{e}"))?;
    println!("{:<10} {:>8.2} {:>9.4} {:>8} (base)", base.mode, base.acc,
             base.gflops, base.visual_tokens);
    for mode in ["pitome", "tome", "tofu", "dct", "diffrate"] {
        let row = eval_config(&ps, mode, 0.9, n).map_err(|e| anyhow::anyhow!("{e}"))?;
        println!("{:<10} {:>8.2} {:>9.4} {:>8}  (drop {:+.2})",
                 row.mode, row.acc, row.gflops, row.visual_tokens,
                 row.acc - base.acc);
    }
    Ok(())
}
