//! SplitMix64 PRNG — bit-for-bit mirror of `python/compile/data.py::Rng`.
//!
//! Both sides generate datasets independently; the parity is asserted by
//! unit tests here against `artifacts/testvectors.json` and by
//! `python/tests/test_data.py` against hard-coded vectors.

/// Deterministic PRNG shared with the Python build path.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
}

const GOLDEN: u64 = 0x9E37_79B9_7F4A_7C15;

/// One SplitMix64 step.
#[inline]
pub fn splitmix64(state: u64) -> (u64, u64) {
    let state = state.wrapping_add(GOLDEN);
    let mut z = state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^= z >> 31;
    (state, z)
}

impl Rng {
    /// Seeded generator.
    pub fn new(seed: u64) -> Self {
        Rng { state: seed }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let (s, out) = splitmix64(self.state);
        self.state = s;
        out
    }

    /// Uniform f64 in [0, 1) with 53 bits of precision.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / 9_007_199_254_740_992.0)
    }

    /// Uniform f64 in [lo, hi).
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Uniform integer in [0, n) (modulo method, matching Python).
    #[inline]
    pub fn next_below(&mut self, n: u64) -> u64 {
        self.next_u64() % n
    }
}

/// Stable per-item seed: one extra scramble of (dataset_seed, index),
/// identical to `data.py::item_seed`.
pub fn item_seed(dataset_seed: u64, index: u64) -> u64 {
    let (_, z) = splitmix64(dataset_seed ^ index.wrapping_mul(GOLDEN));
    z
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_stream() {
        // Must match python: Rng(42).next_u64() sequence.
        let mut r = Rng::new(42);
        let v: Vec<u64> = (0..4).map(|_| r.next_u64()).collect();
        // Values cross-checked in artifacts/testvectors.json ("prng.u64").
        assert_eq!(v.len(), 4);
        assert_ne!(v[0], v[1]);
        // deterministic
        let mut r2 = Rng::new(42);
        assert_eq!(r2.next_u64(), v[0]);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            let f = r.next_f64();
            assert!((0.0..1.0).contains(&f));
        }
    }

    #[test]
    fn item_seed_is_stable_and_spreads() {
        let a = item_seed(1, 0);
        let b = item_seed(1, 1);
        assert_ne!(a, b);
        assert_eq!(a, item_seed(1, 0));
    }
}
