//! Serving request traces: Poisson and bursty arrival processes.
//!
//! Used by the coordinator benches (Table 5-style wall-time runs) and the
//! serving example.  Inter-arrival sampling uses inverse-CDF on the shared
//! SplitMix64 stream — deterministic across runs.

use super::rng::Rng;

/// One synthetic request arrival.
#[derive(Clone, Debug)]
pub struct TraceEvent {
    /// arrival time in microseconds from trace start
    pub at_us: u64,
    /// dataset item index to run
    pub item: u64,
    /// requested model key (index into the router's variant table)
    pub variant: usize,
}

/// Trace generator configuration.
#[derive(Clone, Debug)]
pub struct TraceConfig {
    /// mean arrival rate, requests/second
    pub rate: f64,
    /// number of requests
    pub count: usize,
    /// number of model variants to spread requests over
    pub n_variants: usize,
    /// burstiness: 0 = pure Poisson; >0 mixes in on/off bursts
    pub burstiness: f64,
    /// RNG seed
    pub seed: u64,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig { rate: 200.0, count: 1000, n_variants: 1, burstiness: 0.0, seed: 1 }
    }
}

/// Generate a deterministic arrival trace.
pub fn generate_trace(cfg: &TraceConfig) -> Vec<TraceEvent> {
    let mut rng = Rng::new(cfg.seed);
    let mut t = 0f64; // seconds
    let mut out = Vec::with_capacity(cfg.count);
    let mut in_burst = false;
    for _ in 0..cfg.count {
        // exponential inter-arrival via inverse CDF
        let u = rng.next_f64().max(1e-12);
        let mut rate = cfg.rate;
        if cfg.burstiness > 0.0 {
            // flip burst state occasionally; bursts run 5x rate, gaps 0.2x
            if rng.next_f64() < 0.05 {
                in_burst = !in_burst;
            }
            rate *= if in_burst { 1.0 + 4.0 * cfg.burstiness } else { 1.0 - 0.8 * cfg.burstiness };
        }
        t += -u.ln() / rate;
        out.push(TraceEvent {
            at_us: (t * 1e6) as u64,
            item: rng.next_u64() % 512,
            variant: (rng.next_u64() % cfg.n_variants as u64) as usize,
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_is_sorted_and_deterministic() {
        let cfg = TraceConfig { count: 200, ..Default::default() };
        let a = generate_trace(&cfg);
        let b = generate_trace(&cfg);
        assert_eq!(a.len(), 200);
        for w in a.windows(2) {
            assert!(w[0].at_us <= w[1].at_us);
        }
        assert_eq!(a[10].at_us, b[10].at_us);
    }

    #[test]
    fn mean_rate_roughly_matches() {
        let cfg = TraceConfig { rate: 1000.0, count: 5000, ..Default::default() };
        let tr = generate_trace(&cfg);
        let dur_s = tr.last().unwrap().at_us as f64 / 1e6;
        let rate = tr.len() as f64 / dur_s;
        assert!((rate - 1000.0).abs() < 150.0, "rate {rate}");
    }
}
