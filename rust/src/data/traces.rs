//! Serving request traces: Poisson and bursty arrival processes over
//! typed workloads, with open- and closed-loop arrival models.
//!
//! Used by the coordinator benches (Table 5-style wall-time runs), the
//! serving example, and the closed-loop load harness
//! (`coordinator::harness`).  Inter-arrival sampling uses inverse-CDF on
//! the shared SplitMix64 stream — deterministic across runs.

use super::rng::Rng;
use crate::error::{Error, Result};

/// Fraction of the nominal rate used as a hard positive floor for the
/// effective arrival rate after burst/diurnal modulation.  Without it,
/// `burstiness >= 1.25` drives the gap-phase rate to zero or below, the
/// exponential inter-arrival sample goes negative, `t` runs backwards,
/// and the `(t * 1e6) as u64` cast silently saturates — breaking the
/// trace's own sorted invariant.
const RATE_FLOOR_FRAC: f64 = 0.01;

/// Which typed coordinator pool a trace event targets.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum TraceWorkload {
    /// patches → class logits (ViT tower)
    Vision,
    /// tokens → sentiment logits (BERT tower)
    Text,
    /// paired vision+text request (VQA / retrieval)
    Joint,
    /// embedding-gallery query (probe embed + store scan)
    Gallery,
}

/// Relative traffic weights across the typed workloads.  Weights
/// are normalized at sampling time; they need not sum to 1.
#[derive(Clone, Copy, Debug)]
pub struct WorkloadMix {
    /// relative weight of `TraceWorkload::Vision`
    pub vision: f64,
    /// relative weight of `TraceWorkload::Text`
    pub text: f64,
    /// relative weight of `TraceWorkload::Joint`
    pub joint: f64,
    /// relative weight of `TraceWorkload::Gallery`
    pub gallery: f64,
}

impl WorkloadMix {
    /// All traffic on the vision pool (the pre-multimodal default).
    pub fn vision_only() -> Self {
        WorkloadMix { vision: 1.0, text: 0.0, joint: 0.0, gallery: 0.0 }
    }

    /// Equal weight across vision, text, and joint (no gallery traffic;
    /// opt in by setting `gallery` explicitly).
    pub fn balanced() -> Self {
        WorkloadMix { vision: 1.0, text: 1.0, joint: 1.0, gallery: 0.0 }
    }

    /// Validate the mix and return the total weight.  Weights must be
    /// finite and non-negative, and at least one must be positive.
    pub fn validate(&self) -> Result<f64> {
        for (name, w) in [
            ("vision", self.vision),
            ("text", self.text),
            ("joint", self.joint),
            ("gallery", self.gallery),
        ] {
            if !w.is_finite() || w < 0.0 {
                return Err(Error::Config(format!(
                    "workload mix weight `{name}` must be finite and >= 0, got {w}"
                )));
            }
        }
        let sum = self.vision + self.text + self.joint + self.gallery;
        if sum <= 0.0 {
            return Err(Error::Config(
                "workload mix has zero total weight".into(),
            ));
        }
        Ok(sum)
    }
}

impl Default for WorkloadMix {
    fn default() -> Self {
        WorkloadMix::vision_only()
    }
}

/// How arrivals are driven against the serving stack.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ArrivalModel {
    /// Open loop: events carry absolute arrival timestamps; the driver
    /// submits on schedule regardless of completions (overload possible).
    Open,
    /// Closed loop: a fixed population of users, each submitting its next
    /// request only after the previous one completes (plus think time).
    /// Events carry `at_us = 0`; ordering is the submission order.
    Closed {
        /// concurrent user count (in-flight ceiling)
        users: usize,
        /// per-user pause between completion and next submission, µs
        think_time_us: u64,
    },
}

/// One synthetic request arrival.
#[derive(Clone, Debug)]
pub struct TraceEvent {
    /// arrival time in microseconds from trace start (0 for closed loops)
    pub at_us: u64,
    /// dataset item index to run
    pub item: u64,
    /// requested model key (index into the router's variant table)
    pub variant: usize,
    /// which typed pool this request targets
    pub workload: TraceWorkload,
    /// end-to-end deadline in microseconds (0 = no deadline)
    pub deadline_us: u64,
}

/// Trace generator configuration.
#[derive(Clone, Debug)]
pub struct TraceConfig {
    /// mean arrival rate, requests/second
    pub rate: f64,
    /// number of requests
    pub count: usize,
    /// number of model variants to spread requests over
    pub n_variants: usize,
    /// burstiness: 0 = pure Poisson; >0 mixes in on/off bursts
    pub burstiness: f64,
    /// diurnal modulation depth in [0, 1]: 0 = flat, 1 = full-depth
    /// sinusoid (rate swings between the floor and 2x nominal)
    pub diurnal: f64,
    /// diurnal period in seconds (trace time, not wall time)
    pub diurnal_period_s: f64,
    /// traffic split across typed workloads
    pub mix: WorkloadMix,
    /// per-request deadline stamped on every event, µs (0 = none)
    pub deadline_us: u64,
    /// open- vs closed-loop arrival semantics
    pub arrival: ArrivalModel,
    /// RNG seed
    pub seed: u64,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig {
            rate: 200.0,
            count: 1000,
            n_variants: 1,
            burstiness: 0.0,
            diurnal: 0.0,
            diurnal_period_s: 60.0,
            mix: WorkloadMix::default(),
            deadline_us: 0,
            arrival: ArrivalModel::Open,
            seed: 1,
        }
    }
}

/// Generate a deterministic arrival trace.
///
/// Validates the configuration up front: `rate` must be finite and
/// positive, `n_variants >= 1` (the per-event variant draw is a modulo),
/// `burstiness` finite and non-negative, and `diurnal` in `[0, 1]`.
/// The effective rate after burst + diurnal modulation is clamped to
/// `RATE_FLOOR_FRAC * rate`, so inter-arrival times stay positive and
/// the output is always sorted by `at_us`.
pub fn generate_trace(cfg: &TraceConfig) -> Result<Vec<TraceEvent>> {
    if !cfg.rate.is_finite() || cfg.rate <= 0.0 {
        return Err(Error::Config(format!(
            "trace rate must be finite and > 0, got {}",
            cfg.rate
        )));
    }
    if cfg.n_variants == 0 {
        return Err(Error::Config(
            "trace n_variants must be >= 1 (variant draw is modulo n_variants)"
                .into(),
        ));
    }
    if !cfg.burstiness.is_finite() || cfg.burstiness < 0.0 {
        return Err(Error::Config(format!(
            "trace burstiness must be finite and >= 0, got {}",
            cfg.burstiness
        )));
    }
    if !cfg.diurnal.is_finite() || !(0.0..=1.0).contains(&cfg.diurnal) {
        return Err(Error::Config(format!(
            "trace diurnal depth must be in [0, 1], got {}",
            cfg.diurnal
        )));
    }
    let wsum = cfg.mix.validate()?;
    let closed = matches!(cfg.arrival, ArrivalModel::Closed { .. });
    let mut rng = Rng::new(cfg.seed);
    let mut t = 0f64; // seconds
    let mut out = Vec::with_capacity(cfg.count);
    let mut in_burst = false;
    for _ in 0..cfg.count {
        // exponential inter-arrival via inverse CDF
        let u = rng.next_f64().max(1e-12);
        let mut rate = cfg.rate;
        if cfg.burstiness > 0.0 {
            // flip burst state occasionally; bursts speed up, gaps slow
            // down (floored below so time never runs backwards)
            if rng.next_f64() < 0.05 {
                in_burst = !in_burst;
            }
            rate *= if in_burst {
                1.0 + 4.0 * cfg.burstiness
            } else {
                1.0 - 0.8 * cfg.burstiness
            };
        }
        if cfg.diurnal > 0.0 {
            let phase = std::f64::consts::TAU * t
                / cfg.diurnal_period_s.max(1e-6);
            rate *= 1.0 + cfg.diurnal * phase.sin();
        }
        // positive floor: high burstiness / deep diurnal troughs must
        // slow arrivals down, never reverse them
        let rate = rate.max(cfg.rate * RATE_FLOOR_FRAC);
        t += -u.ln() / rate;
        let workload = {
            let draw = rng.next_f64() * wsum;
            if draw < cfg.mix.vision {
                TraceWorkload::Vision
            } else if draw < cfg.mix.vision + cfg.mix.text {
                TraceWorkload::Text
            } else if draw < cfg.mix.vision + cfg.mix.text + cfg.mix.joint {
                TraceWorkload::Joint
            } else if cfg.mix.gallery > 0.0 {
                TraceWorkload::Gallery
            } else {
                // fp rounding pushed the draw past every positive weight
                TraceWorkload::Joint
            }
        };
        out.push(TraceEvent {
            at_us: if closed { 0 } else { (t * 1e6) as u64 },
            item: rng.next_u64() % 512,
            variant: (rng.next_u64() % cfg.n_variants as u64) as usize,
            workload,
            deadline_us: cfg.deadline_us,
        });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_is_sorted_and_deterministic() {
        let cfg = TraceConfig { count: 200, ..Default::default() };
        let a = generate_trace(&cfg).unwrap();
        let b = generate_trace(&cfg).unwrap();
        assert_eq!(a.len(), 200);
        for w in a.windows(2) {
            assert!(w[0].at_us <= w[1].at_us);
        }
        assert_eq!(a[10].at_us, b[10].at_us);
    }

    #[test]
    fn mean_rate_roughly_matches() {
        let cfg =
            TraceConfig { rate: 1000.0, count: 5000, ..Default::default() };
        let tr = generate_trace(&cfg).unwrap();
        let dur_s = tr.last().unwrap().at_us as f64 / 1e6;
        let rate = tr.len() as f64 / dur_s;
        assert!((rate - 1000.0).abs() < 150.0, "rate {rate}");
    }

    /// Property sweep: for every burstiness in [0, 2], every mix, and
    /// diurnal depth 0 and 1, the trace stays sorted (time never runs
    /// backwards) and the total span is bounded by what the rate floor
    /// allows — the burstiness >= 1.25 regression made both fail.
    #[test]
    fn high_burstiness_stays_sorted_and_positive_rate() {
        let mixes = [
            WorkloadMix::vision_only(),
            WorkloadMix::balanced(),
            WorkloadMix { vision: 0.0, text: 2.0, joint: 1.0, gallery: 0.5 },
        ];
        let count = 400usize;
        let rate = 500.0f64;
        for &burstiness in &[0.0, 0.5, 1.0, 1.5, 2.0] {
            for mix in mixes {
                for &diurnal in &[0.0, 1.0] {
                    let cfg = TraceConfig {
                        rate,
                        count,
                        burstiness,
                        diurnal,
                        diurnal_period_s: 2.0,
                        mix,
                        seed: 42,
                        ..Default::default()
                    };
                    let tr = generate_trace(&cfg).unwrap();
                    assert_eq!(tr.len(), count);
                    for w in tr.windows(2) {
                        assert!(
                            w[0].at_us <= w[1].at_us,
                            "trace unsorted at burstiness {burstiness}: \
                             {} > {}",
                            w[0].at_us,
                            w[1].at_us
                        );
                    }
                    // the floored rate bounds the total span: worst case
                    // every gap runs at rate * RATE_FLOOR_FRAC, and the
                    // u64 cast saturating would blow far past this
                    let bound =
                        count as f64 * 1e6 * 1000.0 / rate;
                    let last = tr.last().unwrap().at_us as f64;
                    assert!(
                        last < bound,
                        "span {last} exceeds floor bound {bound} \
                         (burstiness {burstiness}, diurnal {diurnal})"
                    );
                }
            }
        }
    }

    #[test]
    fn zero_variants_is_rejected_not_a_panic() {
        let cfg = TraceConfig { n_variants: 0, ..Default::default() };
        let err = generate_trace(&cfg).unwrap_err();
        assert!(err.to_string().contains("n_variants"));
    }

    #[test]
    fn bad_rate_and_diurnal_are_rejected() {
        let bad_rate = TraceConfig { rate: 0.0, ..Default::default() };
        assert!(generate_trace(&bad_rate).is_err());
        let nan_rate = TraceConfig { rate: f64::NAN, ..Default::default() };
        assert!(generate_trace(&nan_rate).is_err());
        let bad_diurnal = TraceConfig { diurnal: 1.5, ..Default::default() };
        assert!(generate_trace(&bad_diurnal).is_err());
        let bad_mix = TraceConfig {
            mix: WorkloadMix {
                vision: 0.0,
                text: 0.0,
                joint: 0.0,
                gallery: 0.0,
            },
            ..Default::default()
        };
        assert!(generate_trace(&bad_mix).is_err());
    }

    #[test]
    fn balanced_mix_produces_all_three_workloads() {
        let cfg = TraceConfig {
            count: 600,
            mix: WorkloadMix::balanced(),
            ..Default::default()
        };
        let tr = generate_trace(&cfg).unwrap();
        for want in
            [TraceWorkload::Vision, TraceWorkload::Text, TraceWorkload::Joint]
        {
            assert!(
                tr.iter().any(|e| e.workload == want),
                "balanced mix never produced {want:?}"
            );
        }
        assert!(
            tr.iter().all(|e| e.workload != TraceWorkload::Gallery),
            "balanced mix carries no gallery weight"
        );
    }

    #[test]
    fn gallery_weight_produces_gallery_events() {
        let cfg = TraceConfig {
            count: 600,
            mix: WorkloadMix { gallery: 1.0, ..WorkloadMix::balanced() },
            ..Default::default()
        };
        let tr = generate_trace(&cfg).unwrap();
        let n_gallery = tr
            .iter()
            .filter(|e| e.workload == TraceWorkload::Gallery)
            .count();
        // ~1/4 of 600 draws; a wide band keeps this deterministic-seed
        // test robust to RNG-stream changes
        assert!(
            (60..=300).contains(&n_gallery),
            "expected roughly a quarter gallery events, got {n_gallery}"
        );
    }

    #[test]
    fn closed_model_zeroes_timestamps_and_stamps_deadlines() {
        let cfg = TraceConfig {
            count: 50,
            deadline_us: 25_000,
            arrival: ArrivalModel::Closed { users: 4, think_time_us: 100 },
            ..Default::default()
        };
        let tr = generate_trace(&cfg).unwrap();
        assert!(tr.iter().all(|e| e.at_us == 0));
        assert!(tr.iter().all(|e| e.deadline_us == 25_000));
    }
}
