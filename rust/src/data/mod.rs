//! Synthetic workload generators (DESIGN.md §6 substitutions).
//!
//! Bit-for-bit mirrors of `python/compile/data.py`: both languages generate
//! identical datasets from the same SplitMix64 streams, so Python-side
//! build-time training and Rust-side runtime evaluation agree.

pub mod rng;
pub mod shapes;
pub mod text;
pub mod traces;

pub use rng::{item_seed, splitmix64, Rng};
pub use shapes::{patchify, shape_batch, shape_item, ShapeItem, IMG, N_SHAPE_CLASSES};
pub use text::{caption_for, sent_batch, sent_item, vqa_item, CAP_LEN, N_ANSWERS, VOCAB};
pub use traces::{generate_trace, ArrivalModel, TraceConfig, TraceEvent,
                 TraceWorkload, WorkloadMix};

/// Dataset seeds shared with `python/compile/train.py`.
pub const TRAIN_SEED: u64 = 1000;
/// Test split seed.
pub const TEST_SEED: u64 = 2000;
/// Train set size used at build time.
pub const N_TRAIN: usize = 4096;
/// Test set size.
pub const N_TEST: usize = 512;
