//! ShapeBench synthetic image dataset — mirror of `data.py` (DESIGN.md §6).
//!
//! 32x32 grayscale images: a smooth noisy background (one large redundant
//! token cluster) plus one foreground shape from 10 classes (the small
//! informative cluster) — exactly the structure the paper's energy score
//! exploits.

use super::rng::{item_seed, Rng};
use crate::tensor::Mat;

/// Image side length.
pub const IMG: usize = 32;
/// Number of shape classes.
pub const N_SHAPE_CLASSES: usize = 10;
/// Human-readable class names.
pub const SHAPE_NAMES: [&str; 10] = [
    "disk", "ring", "square", "frame", "triangle", "cross", "hbar", "vbar",
    "diamond", "checker",
];

/// One generated item.
#[derive(Clone, Debug)]
pub struct ShapeItem {
    /// (IMG*IMG) row-major pixel values in [0,1].
    pub image: Vec<f32>,
    /// shape class 0..10
    pub label: usize,
    /// quadrant of the shape center, 0..4
    pub quadrant: usize,
    /// size bucket 0..3
    pub size_bucket: usize,
}

/// Pixel predicate for shape `cls` at offset (dx, dy), scale `s`.
/// Identical branch structure to `data.py::_inside`.
fn inside(cls: usize, dx: f64, dy: f64, s: f64, phase: u64) -> bool {
    let (ax, ay) = (dx.abs(), dy.abs());
    match cls {
        0 => dx * dx + dy * dy <= s * s,
        1 => {
            let rr = dx * dx + dy * dy;
            (0.36 * s * s) <= rr && rr <= s * s
        }
        2 => ax <= s && ay <= s,
        3 => (ax <= s && ay <= s) && !(ax <= 0.55 * s && ay <= 0.55 * s),
        4 => dy <= s && dy >= -s && ax <= (s - dy) * 0.5,
        5 => (ax <= 0.33 * s && ay <= s) || (ay <= 0.33 * s && ax <= s),
        6 => ax <= s && ay <= 0.33 * s,
        7 => ax <= 0.33 * s && ay <= s,
        8 => ax + ay <= s,
        9 => {
            if !(ax <= s && ay <= s) {
                return false;
            }
            let cx = ((dx + s) / (0.5 * s + 1e-9)).floor() as i64;
            let cy = ((dy + s) / (0.5 * s + 1e-9)).floor() as i64;
            (cx + cy + phase as i64).rem_euclid(2) == 0
        }
        _ => unreachable!("bad shape class"),
    }
}

/// Generate item `index` of the dataset with seed `dataset_seed`.
pub fn shape_item(dataset_seed: u64, index: u64) -> ShapeItem {
    let mut rng = Rng::new(item_seed(dataset_seed, index));
    let cls = rng.next_below(N_SHAPE_CLASSES as u64) as usize;
    let bg = rng.uniform(0.25, 0.55);
    let fg_delta = rng.uniform(0.3, 0.42);
    let flip = rng.next_f64() < 0.5;
    let fg = if flip { bg + fg_delta } else { bg - fg_delta };
    let noise_amp = rng.uniform(0.01, 0.05);
    let s = rng.uniform(4.0, 9.0);
    let cx = rng.uniform(s + 2.0, IMG as f64 - s - 2.0);
    let cy = rng.uniform(s + 2.0, IMG as f64 - s - 2.0);
    let phase = rng.next_below(2);
    let grad = rng.uniform(-0.08, 0.08);

    let mut image = vec![0f32; IMG * IMG];
    for y in 0..IMG {
        for x in 0..IMG {
            let mut base = bg + grad * (x as f64 / (IMG as f64 - 1.0) - 0.5);
            if inside(cls, x as f64 - cx, y as f64 - cy, s, phase) {
                base = fg;
            }
            base += rng.uniform(-noise_amp, noise_amp);
            image[y * IMG + x] = base.clamp(0.0, 1.0) as f32;
        }
    }

    let quadrant = (if cx >= IMG as f64 / 2.0 { 1 } else { 0 })
        + (if cy >= IMG as f64 / 2.0 { 2 } else { 0 });
    let size_bucket = if s < 5.7 { 0 } else if s < 7.4 { 1 } else { 2 };
    ShapeItem { image, label: cls, quadrant, size_bucket }
}

/// Cut an image into `patch x patch` row-major patches:
/// returns (n_patches, patch*patch).
pub fn patchify(image: &[f32], patch: usize) -> Mat {
    let ph = IMG / patch;
    let mut out = Mat::zeros(ph * ph, patch * patch);
    for py in 0..ph {
        for px in 0..ph {
            let r = out.row_mut(py * ph + px);
            for iy in 0..patch {
                for ix in 0..patch {
                    r[iy * patch + ix] =
                        image[(py * patch + iy) * IMG + (px * patch + ix)];
                }
            }
        }
    }
    out
}

/// Batched patches + labels for items [start, start+count).
pub fn shape_batch(dataset_seed: u64, start: u64, count: usize, patch: usize)
    -> (Vec<Mat>, Vec<usize>) {
    let mut xs = Vec::with_capacity(count);
    let mut ys = Vec::with_capacity(count);
    for i in 0..count {
        let it = shape_item(dataset_seed, start + i as u64);
        xs.push(patchify(&it.image, patch));
        ys.push(it.label);
    }
    (xs, ys)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_in_range() {
        let a = shape_item(123, 0);
        let b = shape_item(123, 0);
        assert_eq!(a.image, b.image);
        assert!(a.label < N_SHAPE_CLASSES);
        assert!(a.quadrant < 4);
        assert!(a.image.iter().all(|v| (0.0..=1.0).contains(v)));
    }

    #[test]
    fn different_items_differ() {
        let a = shape_item(123, 0);
        let b = shape_item(123, 1);
        assert_ne!(a.image, b.image);
    }

    #[test]
    fn patchify_shape_and_content() {
        let it = shape_item(5, 7);
        let p = patchify(&it.image, 4);
        assert_eq!(p.rows, 64);
        assert_eq!(p.cols, 16);
        // first pixel of first patch == first pixel of image
        assert_eq!(p.get(0, 0), it.image[0]);
        // patch (1,0) starts at column 4 of row 0
        assert_eq!(p.get(1, 0), it.image[4]);
    }

    #[test]
    fn class_balance_roughly_uniform() {
        let mut counts = [0usize; N_SHAPE_CLASSES];
        for i in 0..500 {
            counts[shape_item(9, i).label] += 1;
        }
        for &c in &counts {
            assert!(c > 20, "class starved: {counts:?}");
        }
    }
}
