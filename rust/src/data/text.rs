//! SynthSent text dataset + caption/VQA token views — mirror of `data.py`.

use super::rng::{item_seed, Rng};
use super::shapes::shape_item;

/// Vocabulary size shared across text models.
pub const VOCAB: usize = 512;
/// Padding token.
pub const PAD: i32 = 0;
/// Classification token (always at position 0).
pub const CLS_TOK: i32 = 1;

const DISTRACT_LO: u64 = 4;
const DISTRACT_HI: u64 = 452;
const POS_LO: u64 = 452;
const POS_HI: u64 = 482;
const NEG_LO: u64 = 482;
const NEG_HI: u64 = 512;

/// Sentiment item: (tokens (seq_len+1), label). tokens[0] = CLS.
pub fn sent_item(dataset_seed: u64, index: u64, seq_len: usize, min_len: usize)
    -> (Vec<i32>, usize) {
    let mut rng = Rng::new(item_seed(dataset_seed ^ 0x5E17, index));
    let label = rng.next_below(2) as usize;
    let length = min_len + rng.next_below((seq_len - min_len + 1) as u64) as usize;
    let n_sent = 3 + rng.next_below(6) as usize;
    let n_noise = rng.next_below(2) as usize;
    let mut toks = vec![PAD; seq_len + 1];
    toks[0] = CLS_TOK;
    // python builds a set then sorts it; mirror with a sorted dedup vec
    let mut pos: Vec<usize> = Vec::new();
    let want = (n_sent + n_noise).min(length);
    while pos.len() < want {
        let p = 1 + rng.next_below(length as u64) as usize;
        if !pos.contains(&p) {
            pos.push(p);
        }
    }
    pos.sort_unstable();
    for p in 1..=length {
        toks[p] = (DISTRACT_LO + rng.next_below(DISTRACT_HI - DISTRACT_LO)) as i32;
    }
    for (j, &p) in pos.iter().enumerate() {
        let flip = j >= n_sent;
        let pol = label ^ usize::from(flip);
        toks[p] = if pol == 1 {
            (POS_LO + rng.next_below(POS_HI - POS_LO)) as i32
        } else {
            (NEG_LO + rng.next_below(NEG_HI - NEG_LO)) as i32
        };
    }
    (toks, label)
}

/// Batched sentiment items.
pub fn sent_batch(dataset_seed: u64, start: u64, count: usize, seq_len: usize)
    -> (Vec<Vec<i32>>, Vec<usize>) {
    let mut xs = Vec::with_capacity(count);
    let mut ys = Vec::with_capacity(count);
    for i in 0..count {
        let (t, l) = sent_item(dataset_seed, start + i as u64, seq_len, 16);
        xs.push(t);
        ys.push(l);
    }
    (xs, ys)
}

// ---------------------------------------------------------------------------
// captions + VQA (derived from ShapeBench items)
// ---------------------------------------------------------------------------

/// Caption length (without CLS).
pub const CAP_LEN: usize = 16;
const CAP_SHAPE_BASE: i32 = 8;
const CAP_QUAD_BASE: i32 = 24;
const CAP_SIZE_BASE: i32 = 32;
const CAP_FILLER_LO: u64 = 64;
const CAP_FILLER_HI: u64 = 256;

/// Number of VQA answers (10 shapes + 4 quadrants + 3 sizes).
pub const N_ANSWERS: usize = 17;
const Q_TOKENS: [i32; 3] = [2, 3, 4];

/// Caption tokens (CAP_LEN+1) describing image `index`; CLS first.
/// Mirror of `data.py::caption_for`.
pub fn caption_for(dataset_seed: u64, index: u64) -> Vec<i32> {
    let item = shape_item(dataset_seed, index);
    let mut rng = Rng::new(item_seed(dataset_seed ^ 0xCA97, index));
    let mut toks = vec![PAD; CAP_LEN + 1];
    toks[0] = CLS_TOK;
    let content = [
        CAP_SHAPE_BASE + item.label as i32,
        CAP_QUAD_BASE + item.quadrant as i32,
        CAP_SIZE_BASE + item.size_bucket as i32,
    ];
    let mut order = [0usize, 1, 2];
    for i in (1..=2).rev() {
        let j = rng.next_below((i + 1) as u64) as usize;
        order.swap(i, j);
    }
    let length = 6 + rng.next_below((CAP_LEN - 6 - 1) as u64) as usize;
    // python: sorted({1 + below(length) for _ in range(8)})[:3]
    let mut set: Vec<usize> = Vec::new();
    for _ in 0..8 {
        let p = 1 + rng.next_below(length as u64) as usize;
        if !set.contains(&p) {
            set.push(p);
        }
    }
    set.sort_unstable();
    set.truncate(3);
    while set.len() < 3 {
        let nxt = set.last().map(|v| v + 1).unwrap_or(1);
        set.push(nxt);
    }
    for p in 1..=length {
        toks[p] = (CAP_FILLER_LO + rng.next_below(CAP_FILLER_HI - CAP_FILLER_LO)) as i32;
    }
    for (slot, o) in set.iter().zip(order.iter()) {
        toks[*slot] = content[*o];
    }
    toks
}

/// VQA item: (question tokens (CAP_LEN+1), answer id).
pub fn vqa_item(dataset_seed: u64, index: u64) -> (Vec<i32>, usize) {
    let item = shape_item(dataset_seed, index);
    let mut rng = Rng::new(item_seed(dataset_seed ^ 0x70A, index));
    let qtype = rng.next_below(3) as usize;
    let mut toks = vec![PAD; CAP_LEN + 1];
    toks[0] = CLS_TOK;
    toks[1] = Q_TOKENS[qtype];
    for p in 2..8 {
        toks[p] = (CAP_FILLER_LO + rng.next_below(CAP_FILLER_HI - CAP_FILLER_LO)) as i32;
    }
    let ans = match qtype {
        0 => item.label,
        1 => 10 + item.quadrant,
        _ => 14 + item.size_bucket,
    };
    (toks, ans)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sent_item_deterministic() {
        let (a, la) = sent_item(9, 3, 32, 16);
        let (b, lb) = sent_item(9, 3, 32, 16);
        assert_eq!(a, b);
        assert_eq!(la, lb);
        assert_eq!(a[0], CLS_TOK);
        assert_eq!(a.len(), 33);
    }

    #[test]
    fn sent_tokens_in_vocab() {
        for i in 0..50 {
            let (t, l) = sent_item(1, i, 64, 16);
            assert!(l < 2);
            assert!(t.iter().all(|&v| (v as usize) < VOCAB));
        }
    }

    #[test]
    fn caption_contains_class_token() {
        for i in 0..20 {
            let item = shape_item(7, i);
            let cap = caption_for(7, i);
            assert!(cap.contains(&(CAP_SHAPE_BASE + item.label as i32)),
                    "caption missing class token: {cap:?}");
        }
    }

    #[test]
    fn vqa_answer_consistent_with_item() {
        for i in 0..30 {
            let item = shape_item(3, i);
            let (q, a) = vqa_item(3, i);
            assert_eq!(q[0], CLS_TOK);
            assert!(a < N_ANSWERS);
            match q[1] {
                2 => assert_eq!(a, item.label),
                3 => assert_eq!(a, 10 + item.quadrant),
                4 => assert_eq!(a, 14 + item.size_bucket),
                _ => panic!("bad q token"),
            }
        }
    }
}
