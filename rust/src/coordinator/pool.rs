//! Response/request tensor recycling: the pool that closes the
//! "one remaining allocation per request" transport boundary.
//!
//! PR 4 made the worker-side inference region allocation-free but left
//! the owned `HostTensor` responses crossing the submitter's channel as
//! a documented per-request allocation.  A [`TensorPool`] recycles those
//! buffers; PR 7 reworked it from two Mutex'd freelists with an O(n)
//! best-fit scan into **size-bucketed capacity classes with per-thread
//! sub-pools**:
//!
//! * Buffers live in power-of-two capacity classes (class `c` holds
//!   capacities in `[2^c, 2^(c+1))`), so a take is an O(1) shelf pop
//!   and a put is an O(1) shelf push — no scan, no scaling with pool
//!   population.  Fresh checkouts pre-reserve the class boundary and
//!   [`PooledTensor::fill_f32`] regrows straight to the next power of
//!   two, so every buffer that re-enters the pool sits on a shelf that
//!   future takes of its class actually probe.
//! * Each thread keeps a small **lock-free local sub-pool** (a
//!   `thread_local!` registry keyed by pool identity): takes probe the
//!   local shelf first, and a dropped [`PooledTensor`] returns to the
//!   *releasing* thread's sub-pool, spilling to the shared shelves —
//!   where other workers can steal it — only past a small per-class
//!   cap.  Same-thread reuse never touches a lock.
//! * The shared class shelves are **leaf mutexes**: no shelf lock is
//!   ever held while acquiring another lock, so the pool cannot
//!   participate in a lock cycle no matter how many pools or workers
//!   exist.
//!
//! Workers build responses from recycled buffers ([`TensorPool::take_f32`]
//! reuses both the data and the shape vectors in place), and a
//! [`PooledTensor`] **returns its buffer to the pool on drop** — callers
//! cannot leak pool capacity by forgetting a release.  Request inputs
//! ride the same pool, so a warmed request→response→release cycle
//! allocates nothing on either side of the channel
//! (`tests/alloc_free.rs`).
//!
//! Recycled/fresh/steal counters ([`TensorPool::stats`],
//! [`TensorPool::steals`]) feed the serving metrics
//! (`Snapshot::{resp_recycled,resp_fresh}`) and the `coordinator_bench`
//! recycle-hit-rate and O(1)-take sections.

use std::cell::RefCell;
use std::ops::Deref;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, Weak};

use crate::runtime::HostTensor;

/// Number of power-of-two capacity classes (class 31 holds multi-GB
/// buffers; anything larger bypasses the pool entirely).
pub const POOL_CLASSES: usize = 32;

/// Max buffers retained per shared class shelf; beyond this, returned
/// buffers are simply dropped (bounds worst-case pool memory).
const SHARED_PER_CLASS: usize = 64;

/// Max buffers a thread's local sub-pool retains per class before a
/// release spills to the shared shelves.  Two covers the common
/// steady-state (one in flight, one returning) while keeping buffers
/// visible to other workers quickly.
const LOCAL_PER_CLASS: usize = 2;

/// Capacity class that can *serve* a request for `len` elements: the
/// smallest `c` with `2^c >= len` (0 for `len <= 1`).
fn class_for_len(len: usize) -> usize {
    (usize::BITS - len.saturating_sub(1).leading_zeros()) as usize
}

/// Capacity class a buffer with `cap > 0` elements *lands on*: floor
/// log2, so a shelf only ever holds buffers at least as large as the
/// takes that probe it.
fn class_for_cap(cap: usize) -> usize {
    debug_assert!(cap > 0);
    (usize::BITS - 1 - cap.leading_zeros()) as usize
}

/// Pop one buffer from a shared class shelf (leaf lock, O(1)).
fn pop_shelf(shelf: &Mutex<Vec<HostTensor>>) -> Option<HostTensor> {
    shelf.lock().unwrap().pop()
}

/// Push a buffer onto a shared class shelf (leaf lock, O(1)); beyond
/// the retention cap the buffer is dropped, bounding pool memory.
fn push_shelf(shelf: &Mutex<Vec<HostTensor>>, t: HostTensor) {
    let mut g = shelf.lock().unwrap();
    if g.len() < SHARED_PER_CLASS {
        g.push(t);
    }
}

/// One thread's lock-free sub-pool for one [`TensorPool`] instance.
///
/// Keyed by the pool's `Arc` address; the `Weak` both proves liveness
/// and pins the allocation so the key cannot be reused by a different
/// pool while this entry exists (no ABA).
struct LocalShelves {
    key: *const TensorPool,
    pool: Weak<TensorPool>,
    f32s: [Vec<HostTensor>; POOL_CLASSES],
    i32s: [Vec<HostTensor>; POOL_CLASSES],
}

impl LocalShelves {
    // lint: allow(alloc) reason=once-per-(thread,pool) registry entry; the shelves start empty and their spines warm with the pool
    fn new(pool: &Arc<TensorPool>) -> LocalShelves {
        LocalShelves {
            key: Arc::as_ptr(pool),
            pool: Arc::downgrade(pool),
            f32s: std::array::from_fn(|_| Vec::new()),
            i32s: std::array::from_fn(|_| Vec::new()),
        }
    }

    /// Reuse a dead entry (its pool dropped) for a new pool.
    fn rebind(&mut self, pool: &Arc<TensorPool>) {
        self.key = Arc::as_ptr(pool);
        self.pool = Arc::downgrade(pool);
        for s in self.f32s.iter_mut().chain(self.i32s.iter_mut()) {
            s.clear();
        }
    }
}

/// Per-thread registry of sub-pools (one entry per live pool this
/// thread has touched).
struct LocalPools {
    entries: Vec<LocalShelves>,
}

thread_local! {
    // lint: allow(alloc) reason=per-thread registry shell, built once per thread
    static LOCAL: RefCell<LocalPools> =
        RefCell::new(LocalPools { entries: Vec::new() });
}

/// Run `f` against the calling thread's sub-pool for `pool`, creating
/// or rebinding the registry entry as needed.  Returns `None` during
/// thread teardown (TLS destroyed) — callers fall back to the shared
/// shelves.
fn with_local<R>(
    pool: &Arc<TensorPool>,
    f: impl FnOnce(&mut LocalShelves) -> R,
) -> Option<R> {
    LOCAL
        .try_with(|cell| {
            let mut reg = cell.borrow_mut();
            let key = Arc::as_ptr(pool);
            let mut found = None;
            let mut dead = None;
            for (i, e) in reg.entries.iter().enumerate() {
                if e.key == key && e.pool.strong_count() > 0 {
                    found = Some(i);
                    break;
                }
                if dead.is_none() && e.pool.strong_count() == 0 {
                    dead = Some(i);
                }
            }
            let idx = match (found, dead) {
                (Some(i), _) => i,
                (None, Some(i)) => {
                    reg.entries[i].rebind(pool);
                    i
                }
                (None, None) => {
                    reg.entries.push(LocalShelves::new(pool));
                    reg.entries.len() - 1
                }
            };
            f(&mut reg.entries[idx])
        })
        .ok()
}

/// A bucketed pool of reusable [`HostTensor`] buffers: power-of-two
/// capacity classes (per dtype) behind per-class leaf mutexes, fronted
/// by lock-free per-thread sub-pools, with recycled/fresh/steal
/// accounting.  Shared as `Arc<TensorPool>` by the coordinator's
/// workers and clients.
pub struct TensorPool {
    f32s: [Mutex<Vec<HostTensor>>; POOL_CLASSES],
    i32s: [Mutex<Vec<HostTensor>>; POOL_CLASSES],
    recycled: AtomicU64,
    fresh: AtomicU64,
    steals: AtomicU64,
}

impl Default for TensorPool {
    fn default() -> TensorPool {
        TensorPool::new()
    }
}

impl TensorPool {
    /// New empty pool.
    // lint: allow(alloc) reason=cold constructor: empty class shelves, populated only by recycling
    pub fn new() -> TensorPool {
        TensorPool {
            f32s: std::array::from_fn(|_| Mutex::new(Vec::new())),
            i32s: std::array::from_fn(|_| Mutex::new(Vec::new())),
            recycled: AtomicU64::new(0),
            fresh: AtomicU64::new(0),
            steals: AtomicU64::new(0),
        }
    }

    /// Check out an f32 buffer with room for `min_len` elements: the
    /// calling thread's sub-pool first (lock-free), then the shared
    /// class shelf (and the one above it, covering allocator round-up),
    /// fresh otherwise — every path O(1).  Fill it with
    /// [`PooledTensor::fill_f32`]; dropping the returned handle puts
    /// the buffer back.
    // lint: allow(alloc) reason=fresh checkout reserves the class boundary once (then recycles) + Arc refcount clone for the drop hook
    pub fn take_f32(self: &Arc<Self>, min_len: usize) -> PooledTensor {
        let cls = class_for_len(min_len);
        if cls < POOL_CLASSES {
            let local = with_local(self, |ls| ls.f32s[cls].pop()).flatten();
            if let Some(t) = local {
                self.recycled.fetch_add(1, Ordering::Relaxed);
                return PooledTensor { t, home: Some(self.clone()), recycled: true };
            }
            for c in [cls, cls + 1] {
                if c >= POOL_CLASSES {
                    break;
                }
                if let Some(t) = pop_shelf(&self.f32s[c]) {
                    self.recycled.fetch_add(1, Ordering::Relaxed);
                    self.steals.fetch_add(1, Ordering::Relaxed);
                    return PooledTensor { t, home: Some(self.clone()), recycled: true };
                }
            }
        }
        self.fresh.fetch_add(1, Ordering::Relaxed);
        let cap = fresh_cap(min_len, cls);
        PooledTensor {
            t: HostTensor::F32(Vec::with_capacity(cap), Vec::new()),
            home: Some(self.clone()),
            recycled: false,
        }
    }

    /// i32 counterpart of [`TensorPool::take_f32`] (token-id inputs).
    // lint: allow(alloc) reason=fresh checkout reserves the class boundary once (then recycles) + Arc refcount clone for the drop hook
    pub fn take_i32(self: &Arc<Self>, min_len: usize) -> PooledTensor {
        let cls = class_for_len(min_len);
        if cls < POOL_CLASSES {
            let local = with_local(self, |ls| ls.i32s[cls].pop()).flatten();
            if let Some(t) = local {
                self.recycled.fetch_add(1, Ordering::Relaxed);
                return PooledTensor { t, home: Some(self.clone()), recycled: true };
            }
            for c in [cls, cls + 1] {
                if c >= POOL_CLASSES {
                    break;
                }
                if let Some(t) = pop_shelf(&self.i32s[c]) {
                    self.recycled.fetch_add(1, Ordering::Relaxed);
                    self.steals.fetch_add(1, Ordering::Relaxed);
                    return PooledTensor { t, home: Some(self.clone()), recycled: true };
                }
            }
        }
        self.fresh.fetch_add(1, Ordering::Relaxed);
        let cap = fresh_cap(min_len, cls);
        PooledTensor {
            t: HostTensor::I32(Vec::with_capacity(cap), Vec::new()),
            home: Some(self.clone()),
            recycled: false,
        }
    }

    /// Return a buffer: the releasing thread's sub-pool first
    /// (lock-free), spilling to the shared class shelf past
    /// `LOCAL_PER_CLASS`, dropped past the shared retention cap.
    fn put(home: &Arc<TensorPool>, t: HostTensor) {
        let (cap, is_f32) = match &t {
            HostTensor::F32(d, _) => (d.capacity(), true),
            HostTensor::I32(d, _) => (d.capacity(), false),
        };
        if cap == 0 {
            return;
        }
        let cls = class_for_cap(cap);
        if cls >= POOL_CLASSES {
            return;
        }
        let mut carry = Some(t);
        with_local(home, |ls| {
            let shelf = if is_f32 { &mut ls.f32s[cls] } else { &mut ls.i32s[cls] };
            if shelf.len() < LOCAL_PER_CLASS {
                if let Some(t) = carry.take() {
                    shelf.push(t);
                }
            }
        });
        if let Some(t) = carry {
            let shelf = if is_f32 { &home.f32s[cls] } else { &home.i32s[cls] };
            push_shelf(shelf, t);
        }
    }

    /// `(recycled, fresh)` checkout counts since the pool was created —
    /// the recycle hit rate is `recycled / (recycled + fresh)`.
    pub fn stats(&self) -> (u64, u64) {
        (self.recycled.load(Ordering::Relaxed),
         self.fresh.load(Ordering::Relaxed))
    }

    /// Recycled checkouts satisfied from the *shared* shelves rather
    /// than the calling thread's sub-pool — i.e. the buffer crossed
    /// threads since its release (a steal).  Subset of the recycled
    /// count in [`TensorPool::stats`].
    pub fn steals(&self) -> u64 {
        self.steals.load(Ordering::Relaxed)
    }

    /// Human-readable recycle summary, e.g. `"412/420 (98.1%, 31
    /// stolen)"` — the one formatting of [`TensorPool::stats`] every
    /// bench/CLI report shares.
    // lint: allow(alloc) reason=diagnostics string for operator tooling, never on the serving path
    pub fn hit_rate_summary(&self) -> String {
        let (recycled, fresh) = self.stats();
        format!("{recycled}/{} ({:.1}%, {} stolen)", recycled + fresh,
                100.0 * recycled as f64 / (recycled + fresh).max(1) as f64,
                self.steals())
    }

    /// Buffers currently idle on the **shared** class shelves (other
    /// threads' sub-pools are not visible; see
    /// [`TensorPool::local_idle`]).  Shelf mutexes are leaf locks taken
    /// one at a time, never nested.
    pub fn idle(&self) -> usize {
        let mut n = 0;
        for shelf in self.f32s.iter().chain(self.i32s.iter()) {
            n += shelf.lock().unwrap().len();
        }
        n
    }

    /// Buffers idle on the *calling thread's* sub-pool for this pool
    /// (test/diagnostic hook).
    pub fn local_idle(self: &Arc<Self>) -> usize {
        with_local(self, |ls| {
            ls.f32s.iter().chain(ls.i32s.iter()).map(Vec::len).sum()
        })
        .unwrap_or(0)
    }
}

/// Data capacity for a fresh checkout: the class boundary (so the
/// buffer recycles into the class that serves `min_len`), zero for
/// empty takes, exact for beyond-pool sizes.
fn fresh_cap(min_len: usize, cls: usize) -> usize {
    if min_len == 0 {
        0
    } else if cls < POOL_CLASSES {
        1usize << cls
    } else {
        min_len
    }
}

/// A [`HostTensor`] checked out of a [`TensorPool`] (or detached, for
/// PJRT outputs that have no pool).  Dereferences to the tensor for
/// reading; **returns the buffer to its pool on drop**, so response
/// consumers release capacity by simply letting the response go out of
/// scope.
pub struct PooledTensor {
    t: HostTensor,
    home: Option<Arc<TensorPool>>,
    recycled: bool,
}

impl PooledTensor {
    /// Wrap an owned tensor with no pool behind it (PJRT outputs, tests);
    /// drop simply frees it.
    pub fn detached(t: HostTensor) -> PooledTensor {
        PooledTensor { t, home: None, recycled: false }
    }

    /// Whether this checkout reused a pooled buffer (feeds the
    /// recycled-vs-fresh serving metric).
    pub fn recycled(&self) -> bool {
        self.recycled
    }

    /// Overwrite with f32 `data` + `shape`, reusing the existing data and
    /// shape vectors in place — allocation-free once the buffer has seen
    /// the capacity.  A regrow jumps straight to the next power of two
    /// so the buffer re-enters the pool on a class boundary.
    // lint: allow(alloc) reason=one-time pow2 regrow to the class boundary + dtype-flip fallback; steady state reuses capacity
    pub fn fill_f32(&mut self, data: &[f32], shape: &[usize]) {
        match &mut self.t {
            HostTensor::F32(d, s) => {
                d.clear();
                if d.capacity() < data.len() {
                    d.reserve_exact(data.len().next_power_of_two());
                }
                d.extend_from_slice(data);
                s.clear();
                s.extend_from_slice(shape);
            }
            t @ HostTensor::I32(..) => {
                let mut d = Vec::with_capacity(data.len().next_power_of_two());
                d.extend_from_slice(data);
                *t = HostTensor::F32(d, shape.to_vec());
            }
        }
    }

    /// i32 counterpart of [`PooledTensor::fill_f32`].
    // lint: allow(alloc) reason=one-time pow2 regrow to the class boundary + dtype-flip fallback; steady state reuses capacity
    pub fn fill_i32(&mut self, data: &[i32], shape: &[usize]) {
        match &mut self.t {
            HostTensor::I32(d, s) => {
                d.clear();
                if d.capacity() < data.len() {
                    d.reserve_exact(data.len().next_power_of_two());
                }
                d.extend_from_slice(data);
                s.clear();
                s.extend_from_slice(shape);
            }
            t @ HostTensor::F32(..) => {
                let mut d = Vec::with_capacity(data.len().next_power_of_two());
                d.extend_from_slice(data);
                *t = HostTensor::I32(d, shape.to_vec());
            }
        }
    }

    /// The wrapped tensor.
    pub fn tensor(&self) -> &HostTensor {
        &self.t
    }
}

impl Deref for PooledTensor {
    type Target = HostTensor;

    fn deref(&self) -> &HostTensor {
        &self.t
    }
}

impl std::fmt::Debug for PooledTensor {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PooledTensor")
            .field("tensor", &self.t)
            .field("pooled", &self.home.is_some())
            .finish()
    }
}

impl Drop for PooledTensor {
    // lint: allow(alloc) reason=teardown swaps empty Vecs in to drain the pool
    fn drop(&mut self) {
        if let Some(home) = self.home.take() {
            // swapping in an empty vec allocates nothing
            let t = std::mem::replace(&mut self.t,
                                      HostTensor::F32(Vec::new(), Vec::new()));
            TensorPool::put(&home, t);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn capacity_classes_are_pow2_buckets() {
        assert_eq!(class_for_len(0), 0);
        assert_eq!(class_for_len(1), 0);
        assert_eq!(class_for_len(2), 1);
        assert_eq!(class_for_len(8), 3);
        assert_eq!(class_for_len(9), 4);
        assert_eq!(class_for_cap(1), 0);
        assert_eq!(class_for_cap(8), 3);
        // floor: a cap-9 buffer lands where class-3 takes can use it
        assert_eq!(class_for_cap(9), 3);
        assert_eq!(class_for_cap(16), 4);
    }

    #[test]
    fn local_subpool_recycles_on_the_same_thread() {
        let pool = Arc::new(TensorPool::new());
        let mut a = pool.take_f32(4);
        assert!(!a.recycled());
        a.fill_f32(&[1.0, 2.0, 3.0, 4.0], &[4]);
        let ptr = a.as_f32().unwrap().as_ptr();
        drop(a);
        assert_eq!(pool.local_idle(), 1, "drop lands on the local sub-pool");
        assert_eq!(pool.idle(), 0, "shared shelves stay untouched");
        let b = pool.take_f32(3);
        assert!(b.recycled(), "same-class take must reuse the local buffer");
        assert_eq!(b.as_f32().unwrap().as_ptr(), ptr,
                   "reused buffer must keep its allocation");
        assert_eq!(pool.stats(), (1, 1));
        assert_eq!(pool.steals(), 0, "same-thread recycling is not a steal");
    }

    #[test]
    fn dtypes_use_separate_class_shelves() {
        let pool = Arc::new(TensorPool::new());
        drop(pool.take_i32(3));
        assert_eq!(pool.local_idle(), 1);
        let f = pool.take_f32(3);
        assert!(!f.recycled(), "an i32 buffer must not satisfy an f32 take");
        let i = pool.take_i32(4);
        assert!(i.recycled(), "same class + dtype hits the local shelf");
    }

    #[test]
    fn bucket_boundaries_exact_one_over_and_regrow() {
        let pool = Arc::new(TensorPool::new());
        // exact capacity: a cap-8 buffer serves any take in its class
        let mut t = pool.take_f32(8);
        t.fill_f32(&[0.0; 8], &[8]);
        let ptr = t.as_f32().unwrap().as_ptr();
        drop(t);
        let t = pool.take_f32(5);
        assert!(t.recycled());
        assert_eq!(t.as_f32().unwrap().as_ptr(), ptr);
        drop(t);
        // one-over: len 9 is the next class; the idle cap-8 buffer must
        // NOT serve it (it could not hold the data without regrowing)
        let mut t9 = pool.take_f32(9);
        assert!(!t9.recycled(), "class-3 buffer must not serve a class-4 take");
        match t9.tensor() {
            HostTensor::F32(d, _) => {
                assert_eq!(d.capacity(), 16, "fresh take reserves the class boundary");
            }
            HostTensor::I32(..) => unreachable!(),
        }
        // ...and once released at its pow2 capacity it serves the whole
        // class, including the exact boundary
        t9.fill_f32(&[0.0; 9], &[9]);
        drop(t9);
        let t16 = pool.take_f32(16);
        assert!(t16.recycled(), "cap-16 buffer serves the exact boundary take");
        drop(t16);
        // regrow: filling past capacity normalizes to the next pow2, so
        // the regrown buffer recycles at its NEW class
        let mut small = pool.take_f32(4);
        assert!(!small.recycled());
        small.fill_f32(&[0.0; 100], &[100]);
        match small.tensor() {
            HostTensor::F32(d, _) => assert_eq!(d.capacity(), 128),
            HostTensor::I32(..) => unreachable!(),
        }
        drop(small);
        let big = pool.take_f32(100);
        assert!(big.recycled(), "a regrown buffer recycles at its new class");
    }

    #[test]
    fn overflow_spills_to_shared_and_other_threads_steal() {
        let pool = Arc::new(TensorPool::new());
        let ts = [pool.take_f32(8), pool.take_f32(8),
                  pool.take_f32(8), pool.take_f32(8)];
        drop(ts);
        assert_eq!(pool.local_idle(), 2,
                   "local sub-pool keeps LOCAL_PER_CLASS buffers");
        assert_eq!(pool.idle(), 2, "the rest spill to the shared shelf");
        std::thread::scope(|s| {
            s.spawn(|| {
                let t = pool.take_f32(8);
                assert!(t.recycled(),
                        "cross-thread take recycles via the shared shelf");
            });
        });
        assert!(pool.steals() >= 1, "shared-shelf hits count as steals");
        assert_eq!(pool.stats().1, 4, "only the four originals were fresh");
    }

    #[test]
    fn multithread_take_put_stress_mostly_recycles() {
        let pool = Arc::new(TensorPool::new());
        let iters = 200usize;
        std::thread::scope(|s| {
            for w in 0..4usize {
                let pool = &pool;
                s.spawn(move || {
                    for i in 0..iters {
                        let len = [3usize, 17, 65, 300][(i + w) % 4];
                        let mut t = pool.take_f32(len);
                        t.fill_f32(&vec![0.5; len], &[len]);
                        let mut q = pool.take_i32(len);
                        q.fill_i32(&vec![1; len], &[len]);
                    }
                });
            }
        });
        let (recycled, fresh) = pool.stats();
        assert_eq!(recycled + fresh, (4 * iters * 2) as u64,
                   "every take is accounted exactly once");
        assert!(recycled > fresh,
                "steady-state stress must mostly recycle ({recycled} vs {fresh})");
    }

    #[test]
    fn warmed_steady_state_is_fully_recycled() {
        let pool = Arc::new(TensorPool::new());
        for _ in 0..3 {
            let mut a = pool.take_f32(10);
            a.fill_f32(&[0.0; 10], &[10]);
            let mut b = pool.take_f32(100);
            b.fill_f32(&[0.0; 100], &[100]);
        }
        let fresh0 = pool.stats().1;
        for _ in 0..100 {
            let a = pool.take_f32(10);
            assert!(a.recycled());
            let b = pool.take_f32(100);
            assert!(b.recycled());
            drop((a, b));
        }
        let (recycled, fresh) = pool.stats();
        assert_eq!(fresh, fresh0,
                   "warmed steady-state checkouts take no fresh buffers");
        assert!(recycled >= 200);
    }

    #[test]
    fn detached_tensors_never_reenter_the_pool() {
        let pool = Arc::new(TensorPool::new());
        drop(pool.take_f32(4));
        let idle = pool.idle() + pool.local_idle();
        drop(PooledTensor::detached(HostTensor::F32(vec![1.0], vec![1])));
        assert_eq!(pool.idle() + pool.local_idle(), idle);
    }
}
