//! Response/request tensor recycling: the pool that closes the
//! "one remaining allocation per request" transport boundary.
//!
//! PR 4 made the worker-side inference region allocation-free but left
//! the owned `HostTensor` responses crossing the submitter's channel as
//! a documented per-request allocation.  A [`TensorPool`] is a bounded
//! freelist of `HostTensor` buffers shared by the coordinator's workers
//! and clients: workers build responses from recycled buffers
//! ([`TensorPool::take_f32`] reuses both the data and the shape vectors
//! in place), and a [`PooledTensor`] **returns its buffer to the pool on
//! drop** — callers cannot leak pool capacity by forgetting a release.
//! Request inputs ride the same pool, so a warmed
//! request→response→release cycle allocates nothing on either side of
//! the channel (`tests/alloc_free.rs`).
//!
//! Recycled-vs-fresh counters ([`TensorPool::stats`]) feed the serving
//! metrics (`Snapshot::{resp_recycled,resp_fresh}`) and the
//! `coordinator_bench` recycle-hit-rate section.

use std::ops::Deref;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::runtime::HostTensor;

/// Max buffers retained per dtype freelist; beyond this, returned
/// buffers are simply dropped (bounds worst-case pool memory).
const MAX_RETAINED: usize = 256;

/// A bounded freelist of reusable [`HostTensor`] buffers (one list per
/// dtype) with recycled/fresh accounting.  Shared as `Arc<TensorPool>`
/// by the coordinator's workers and clients.
#[derive(Default)]
pub struct TensorPool {
    f32s: Mutex<Vec<HostTensor>>,
    i32s: Mutex<Vec<HostTensor>>,
    recycled: AtomicU64,
    fresh: AtomicU64,
}

impl TensorPool {
    /// New empty pool.
    pub fn new() -> TensorPool {
        TensorPool::default()
    }

    /// Pop the buffer whose data capacity fits `min_len` most tightly
    /// (true best-fit, so small checkouts never hog large buffers and a
    /// warmed mixed-size pool stays reallocation-free); falls back to
    /// the largest free buffer, which regrows in place at most once.
    /// The second value reports whether the buffer genuinely fits —
    /// only a true fit counts as a recycle hit (a fallback checkout
    /// still reallocates on fill, so it is accounted as fresh).
    fn pop(list: &Mutex<Vec<HostTensor>>, min_len: usize,
           cap_of: impl Fn(&HostTensor) -> usize)
           -> Option<(HostTensor, bool)> {
        let mut g = list.lock().unwrap();
        if g.is_empty() {
            return None;
        }
        let mut fit: Option<(usize, usize)> = None;
        let mut largest: (usize, usize) = (0, 0);
        for (i, t) in g.iter().enumerate() {
            let c = cap_of(t);
            let tighter = match fit {
                Some((_, fc)) => c < fc,
                None => true,
            };
            if c >= min_len && tighter {
                fit = Some((i, c));
            }
            if c > largest.1 {
                largest = (i, c);
            }
        }
        let (idx, fits) = match fit {
            Some((i, _)) => (i, true),
            None => (largest.0, false),
        };
        Some((g.swap_remove(idx), fits))
    }

    /// Account a checkout and wrap it (a fallback buffer that will have
    /// to regrow counts as fresh, so the recycle hit rate stays honest).
    // lint: allow(alloc) reason=Arc refcount clones handing the shared pool to a session (startup, not per-request)
    fn checkout(self: &Arc<Self>, popped: Option<(HostTensor, bool)>,
                empty: HostTensor) -> PooledTensor {
        match popped {
            Some((t, fits)) => {
                if fits {
                    self.recycled.fetch_add(1, Ordering::Relaxed);
                } else {
                    self.fresh.fetch_add(1, Ordering::Relaxed);
                }
                PooledTensor { t, home: Some(self.clone()), recycled: fits }
            }
            None => {
                self.fresh.fetch_add(1, Ordering::Relaxed);
                PooledTensor {
                    t: empty,
                    home: Some(self.clone()),
                    recycled: false,
                }
            }
        }
    }

    /// Check out an f32 buffer with room for `min_len` elements
    /// (recycled when the freelist has a fitting one, fresh otherwise);
    /// fill it with [`PooledTensor::fill_f32`].  Dropping the returned
    /// handle puts the buffer back.
    // lint: allow(alloc) reason=empty-Vec sentinel on a pool miss; capacity grows once and is recycled
    pub fn take_f32(self: &Arc<Self>, min_len: usize) -> PooledTensor {
        let popped = Self::pop(&self.f32s, min_len, |t| match t {
            HostTensor::F32(d, _) => d.capacity(),
            HostTensor::I32(..) => 0,
        });
        self.checkout(popped, HostTensor::F32(Vec::new(), Vec::new()))
    }

    /// i32 counterpart of [`TensorPool::take_f32`] (token-id inputs).
    // lint: allow(alloc) reason=empty-Vec sentinel on a pool miss; capacity grows once and is recycled
    pub fn take_i32(self: &Arc<Self>, min_len: usize) -> PooledTensor {
        let popped = Self::pop(&self.i32s, min_len, |t| match t {
            HostTensor::I32(d, _) => d.capacity(),
            HostTensor::F32(..) => 0,
        });
        self.checkout(popped, HostTensor::I32(Vec::new(), Vec::new()))
    }

    /// Return a buffer to its freelist (no-op beyond the retention cap).
    fn put(&self, t: HostTensor) {
        let list = match &t {
            HostTensor::F32(..) => &self.f32s,
            HostTensor::I32(..) => &self.i32s,
        };
        let mut g = list.lock().unwrap();
        if g.len() < MAX_RETAINED {
            g.push(t);
        }
    }

    /// `(recycled, fresh)` checkout counts since the pool was created —
    /// the recycle hit rate is `recycled / (recycled + fresh)`.
    pub fn stats(&self) -> (u64, u64) {
        (self.recycled.load(Ordering::Relaxed),
         self.fresh.load(Ordering::Relaxed))
    }

    /// Human-readable recycle summary, e.g. `"412/420 (98.1%)"` — the
    /// one formatting of [`TensorPool::stats`] every bench/CLI report
    /// shares.
    // lint: allow(alloc) reason=diagnostics string for operator tooling, never on the serving path
    pub fn hit_rate_summary(&self) -> String {
        let (recycled, fresh) = self.stats();
        format!("{recycled}/{} ({:.1}%)", recycled + fresh,
                100.0 * recycled as f64 / (recycled + fresh).max(1) as f64)
    }

    /// Buffers currently idle in the freelists.
    pub fn idle(&self) -> usize {
        // lock-order: f32s before i32s (matches every other dual-freelist
        // path in this module; neither lock is held across the other's
        // unlock elsewhere, but keep the order anyway)
        self.f32s.lock().unwrap().len() + self.i32s.lock().unwrap().len()
    }
}

/// A [`HostTensor`] checked out of a [`TensorPool`] (or detached, for
/// PJRT outputs that have no pool).  Dereferences to the tensor for
/// reading; **returns the buffer to its pool on drop**, so response
/// consumers release capacity by simply letting the response go out of
/// scope.
pub struct PooledTensor {
    t: HostTensor,
    home: Option<Arc<TensorPool>>,
    recycled: bool,
}

impl PooledTensor {
    /// Wrap an owned tensor with no pool behind it (PJRT outputs, tests);
    /// drop simply frees it.
    pub fn detached(t: HostTensor) -> PooledTensor {
        PooledTensor { t, home: None, recycled: false }
    }

    /// Whether this checkout reused a freelist buffer (feeds the
    /// recycled-vs-fresh serving metric).
    pub fn recycled(&self) -> bool {
        self.recycled
    }

    /// Overwrite with f32 `data` + `shape`, reusing the existing data and
    /// shape vectors in place — allocation-free once the buffer has seen
    /// the capacity.
    // lint: allow(alloc) reason=dtype-flip fallback copies once before the slot is recycled
    pub fn fill_f32(&mut self, data: &[f32], shape: &[usize]) {
        match &mut self.t {
            HostTensor::F32(d, s) => {
                d.clear();
                d.extend_from_slice(data);
                s.clear();
                s.extend_from_slice(shape);
            }
            t @ HostTensor::I32(..) => {
                *t = HostTensor::F32(data.to_vec(), shape.to_vec());
            }
        }
    }

    /// i32 counterpart of [`PooledTensor::fill_f32`].
    // lint: allow(alloc) reason=dtype-flip fallback copies once before the slot is recycled
    pub fn fill_i32(&mut self, data: &[i32], shape: &[usize]) {
        match &mut self.t {
            HostTensor::I32(d, s) => {
                d.clear();
                d.extend_from_slice(data);
                s.clear();
                s.extend_from_slice(shape);
            }
            t @ HostTensor::F32(..) => {
                *t = HostTensor::I32(data.to_vec(), shape.to_vec());
            }
        }
    }

    /// The wrapped tensor.
    pub fn tensor(&self) -> &HostTensor {
        &self.t
    }
}

impl Deref for PooledTensor {
    type Target = HostTensor;

    fn deref(&self) -> &HostTensor {
        &self.t
    }
}

impl std::fmt::Debug for PooledTensor {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PooledTensor")
            .field("tensor", &self.t)
            .field("pooled", &self.home.is_some())
            .finish()
    }
}

impl Drop for PooledTensor {
    // lint: allow(alloc) reason=teardown swaps empty Vecs in to drain the pool
    fn drop(&mut self) {
        if let Some(home) = self.home.take() {
            // swapping in an empty vec allocates nothing
            let t = std::mem::replace(&mut self.t,
                                      HostTensor::F32(Vec::new(), Vec::new()));
            home.put(t);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn drop_returns_buffer_and_counts_recycles() {
        let pool = Arc::new(TensorPool::new());
        let mut a = pool.take_f32(4);
        assert!(!a.recycled());
        a.fill_f32(&[1.0, 2.0, 3.0, 4.0], &[4]);
        let ptr = a.as_f32().unwrap().as_ptr();
        drop(a);
        assert_eq!(pool.idle(), 1);
        let b = pool.take_f32(2);
        assert!(b.recycled(), "freelist buffer must be reused");
        assert_eq!(b.as_f32().unwrap().as_ptr(), ptr,
                   "reused buffer must keep its allocation");
        assert_eq!(pool.stats(), (1, 1));
    }

    #[test]
    fn dtypes_use_separate_freelists() {
        let pool = Arc::new(TensorPool::new());
        drop(pool.take_i32(3));
        assert_eq!(pool.idle(), 1);
        let f = pool.take_f32(3);
        assert!(!f.recycled(), "an i32 buffer must not satisfy an f32 take");
        let i = pool.take_i32(0);
        assert!(i.recycled());
    }

    #[test]
    fn best_fit_prefers_large_enough_capacity() {
        let pool = Arc::new(TensorPool::new());
        let mut small = pool.take_f32(2);
        small.fill_f32(&[0.0; 2], &[2]);
        let mut big = pool.take_f32(100);
        big.fill_f32(&[0.0; 100], &[100]);
        drop(small);
        drop(big);
        let t = pool.take_f32(50);
        // a popped buffer keeps its previous contents until refilled, so
        // the retained shape identifies which one was chosen
        assert_eq!(t.tensor().shape(), &[100],
                   "take should prefer the buffer that already fits");
        // nothing left that fits 1000: the fallback buffer will have to
        // regrow, so it must NOT count as a recycle hit
        let fallback = pool.take_f32(1000);
        assert!(!fallback.recycled(),
                "a too-small fallback checkout must be accounted fresh");
        drop(fallback);
        drop(t);
        // detached tensors never re-enter the pool
        let idle = pool.idle();
        drop(PooledTensor::detached(HostTensor::F32(vec![1.0], vec![1])));
        assert_eq!(pool.idle(), idle);
    }
}
