//! Closed-loop load harness: replay typed arrival traces against a
//! booted [`Coordinator`] end-to-end (ROADMAP item 4).
//!
//! The harness drives the admission-controlled submit path
//! ([`Coordinator::try_submit_pooled`]) with pooled payloads built from
//! the synthetic dataset generators, under either arrival model:
//!
//! * **Open loop** ([`ArrivalModel::Open`]): events are submitted on
//!   their trace timestamps (optionally time-scaled, or unpaced for a
//!   worst-case spike), regardless of completions — overload is real,
//!   and the coordinator answers it by shedding at admission and
//!   dropping deadline-expired work before execution.
//! * **Closed loop** ([`ArrivalModel::Closed`]): a fixed user
//!   population submits its next request only after the previous one
//!   completes — the classic saturation probe that measures capacity.
//!
//! Every offered request is accounted for exactly once:
//! `offered = admitted + shed` and
//! `admitted = completed + failed` (expiry markers land in `failed` on
//! the client side; the authoritative expiry count comes from the
//! worker metrics).  The per-workload [`WorkloadReport`] carries its own
//! latency [`Snapshot`] (p50/p99/p999 clamped to the observed max) plus
//! queue-depth max/mean sampled over the run.  `serving_bench` and the
//! `pitome loadtest` subcommand are thin wrappers over [`run_load`].

use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::config::TextConfig;
use crate::data::{generate_trace, patchify, sent_item, shape_item,
                  vqa_item, ArrivalModel, TraceConfig, TraceEvent,
                  TraceWorkload, TEST_SEED};
use crate::error::{Error, Result};
use crate::obs::{ObsHub, SpanEvent, Stage, TraceThread};
use crate::tensor::Mat;

use super::metrics::{Metrics, Snapshot};
use super::request::{Admission, InferResponse, Payload, Qos, ResponseSlot,
                     Workload};
use super::server::Coordinator;

/// Distinct request templates cycled through per workload (item index
/// modulo this), enough to exercise the pools without re-generating
/// dataset items inside the timed loop.
const N_TEMPLATES: u64 = 8;

/// Hits requested by every gallery-lane query.
const GALLERY_K: usize = 8;

fn widx(w: TraceWorkload) -> usize {
    match w {
        TraceWorkload::Vision => 0,
        TraceWorkload::Text => 1,
        TraceWorkload::Joint => 2,
        TraceWorkload::Gallery => 3,
    }
}

fn to_workload(w: TraceWorkload) -> Workload {
    match w {
        TraceWorkload::Vision => Workload::Vision,
        TraceWorkload::Text => Workload::Text,
        TraceWorkload::Joint => Workload::Joint,
        TraceWorkload::Gallery => Workload::Gallery,
    }
}

/// How to drive a trace against the coordinator.
#[derive(Clone, Debug)]
pub struct LoadOptions {
    /// the arrival trace to generate and replay
    pub trace: TraceConfig,
    /// QoS class stamped on every request (Balanced exercises the
    /// ladder-shedding router policy)
    pub qos: Qos,
    /// open-loop pacing factor: 1.0 replays trace timestamps in real
    /// time, 2.0 at half speed, ... ; 0.0 disables pacing entirely
    /// (submit as fast as possible — a worst-case spike)
    pub time_scale: f64,
    /// sample queue depths every N submissions (>= 1)
    pub sample_every: usize,
    /// items ingested into the gallery (through the serving-path
    /// [`Payload::GalleryIngest`]) before the replay starts, so gallery
    /// queries scan a non-trivial store.  Requires a booted gallery pool
    /// when > 0; ignored otherwise.
    pub gallery_prefill: usize,
    /// sample every Nth completed request per lane into a reconstructed
    /// admission → queue-wait → exec timeline
    /// ([`LoadReport::request_lanes`]); 0 disables capture.  When the
    /// coordinator has tracing enabled the timelines share the hub's
    /// timebase, so a Chrome trace shows them aligned with the worker
    /// span rings.
    pub trace_sample: usize,
}

impl Default for LoadOptions {
    fn default() -> Self {
        LoadOptions {
            trace: TraceConfig::default(),
            qos: Qos::Balanced,
            time_scale: 1.0,
            sample_every: 1,
            gallery_prefill: 0,
            trace_sample: 0,
        }
    }
}

/// Per-workload accounting for one load run.
#[derive(Clone, Debug)]
pub struct WorkloadReport {
    /// the typed pool this lane drove
    pub workload: Workload,
    /// logical model the lane's requests named
    pub model: String,
    /// requests the trace offered
    pub offered: u64,
    /// requests that passed admission
    pub admitted: u64,
    /// requests refused at admission (queue full)
    pub shed: u64,
    /// admitted requests the workers dropped as deadline-expired
    /// (from the worker metrics delta over the run)
    pub expired: u64,
    /// admitted requests answered with a failure/expiry marker
    pub failed: u64,
    /// admitted requests answered with real outputs
    pub completed: u64,
    /// completed requests that finished within the trace deadline
    /// (equals `completed` when the trace carries no deadline)
    pub deadline_met: u64,
    /// end-to-end latency distribution of completed requests
    pub latency: Snapshot,
    /// queue-wait component (submit → execution start) of the same
    /// completed requests — where time goes when the pool is saturated
    pub queue_wait: Snapshot,
    /// execution component (batch exec wall time attributed to the
    /// request) of the same completed requests
    pub exec: Snapshot,
    /// max queue depth sampled across the workload's variant queues
    pub depth_max: usize,
    /// mean sampled queue depth
    pub depth_mean: f64,
}

/// Whole-run result of [`run_load`].
#[derive(Clone, Debug)]
pub struct LoadReport {
    /// wall-clock duration of the replay, seconds
    pub wall_s: f64,
    /// whether the trace stamped per-request deadlines
    pub had_deadline: bool,
    /// one report per workload present in the trace
    pub per_workload: Vec<WorkloadReport>,
    /// sampled per-request timelines, one synthetic trace lane per
    /// workload (empty unless [`LoadOptions::trace_sample`] > 0); feed
    /// them to [`chrome_trace_json`](crate::obs::export::chrome_trace_json)
    /// alongside the drained worker rings
    pub request_lanes: Vec<TraceThread>,
}

impl LoadReport {
    /// Total requests offered across workloads.
    pub fn offered(&self) -> u64 {
        self.per_workload.iter().map(|w| w.offered).sum()
    }

    /// Total requests admitted.
    pub fn admitted(&self) -> u64 {
        self.per_workload.iter().map(|w| w.admitted).sum()
    }

    /// Total requests shed at admission.
    pub fn shed(&self) -> u64 {
        self.per_workload.iter().map(|w| w.shed).sum()
    }

    /// Total admitted requests dropped as deadline-expired.
    pub fn expired(&self) -> u64 {
        self.per_workload.iter().map(|w| w.expired).sum()
    }

    /// Total requests completed with real outputs.
    pub fn completed(&self) -> u64 {
        self.per_workload.iter().map(|w| w.completed).sum()
    }

    /// Total completions within deadline.
    pub fn deadline_met(&self) -> u64 {
        self.per_workload.iter().map(|w| w.deadline_met).sum()
    }

    /// Useful completions per second: deadline-met completions when the
    /// trace carried deadlines, all completions otherwise.
    pub fn goodput_rps(&self) -> f64 {
        let good =
            if self.had_deadline { self.deadline_met() } else { self.completed() };
        good as f64 / self.wall_s.max(1e-9)
    }

    /// Fraction of offered load refused or expired instead of served.
    pub fn shed_rate(&self) -> f64 {
        (self.shed() + self.expired()) as f64 / self.offered().max(1) as f64
    }

    /// Human-readable per-workload summary.
    pub fn print(&self) {
        println!("  load report: {:.3}s wall, goodput {:.1} req/s, \
                  shed rate {:.3}",
                 self.wall_s, self.goodput_rps(), self.shed_rate());
        for w in &self.per_workload {
            println!("    {:<7} {:<6} offered {:>6} admitted {:>6} \
                      shed {:>5} expired {:>5} failed {:>5}",
                     w.workload.name(), w.model, w.offered, w.admitted,
                     w.shed, w.expired, w.failed);
            println!("            p50 {} us  p99 {} us  p999 {} us  \
                      max {} us  depth max {} mean {:.2}",
                     w.latency.p50_us, w.latency.p99_us, w.latency.p999_us,
                     w.latency.max_us, w.depth_max, w.depth_mean);
            println!("            queue-wait p50 {} us p99 {} us | \
                      exec p50 {} us p99 {} us",
                     w.queue_wait.p50_us, w.queue_wait.p99_us,
                     w.exec.p50_us, w.exec.p99_us);
        }
    }
}

/// Clock the sampled request timelines are stamped with: the hub's
/// epoch when the coordinator traces (so request lanes and worker span
/// rings align in one Chrome trace), a local epoch otherwise.
enum TraceClock {
    /// microseconds since the coordinator hub's epoch
    Hub(Arc<ObsHub>),
    /// microseconds since the replay's own start
    Local(Instant),
}

impl TraceClock {
    fn now_us(&self) -> u64 {
        match self {
            TraceClock::Hub(h) => h.now_us(),
            TraceClock::Local(t0) => t0.elapsed().as_micros() as u64,
        }
    }
}

/// Per-lane sampled request-timeline capture (client side of the span
/// story: the worker rings see batches, this sees requests).
struct LaneTrace {
    every: u64,
    events: Vec<SpanEvent>,
}

impl LaneTrace {
    /// Reconstruct one completed request's timeline from its response
    /// latency decomposition: execution ended (approximately) when the
    /// client drained the response, ran for `exec_us` before that, and
    /// waited `queue_us` before *that*.  The drain delay rides the
    /// Admission/Exec spans — an accepted skew, since responses are
    /// drained non-blockingly between submissions.
    fn push(&mut self, id: u64, resp: &InferResponse, end_us: u64) {
        let exec_start = end_us.saturating_sub(resp.exec_us);
        let submit = exec_start.saturating_sub(resp.queue_us);
        let b = resp.batch_size as u32;
        self.events.push(SpanEvent {
            stage: Stage::Admission, id, t_start_us: submit,
            t_end_us: end_us, payload: b, a: 0.0, b: 0.0,
        });
        self.events.push(SpanEvent {
            stage: Stage::QueueWait, id, t_start_us: submit,
            t_end_us: exec_start, payload: 0, a: 0.0, b: 0.0,
        });
        self.events.push(SpanEvent {
            stage: Stage::Exec, id, t_start_us: exec_start,
            t_end_us: end_us, payload: b, a: 0.0, b: 0.0,
        });
    }
}

/// Pre-built request payloads, one set per workload, generated outside
/// the timed loop from the shared synthetic datasets.
struct Templates {
    patches: Vec<Mat>,
    tokens: Vec<Vec<i32>>,
    questions: Vec<Vec<i32>>,
}

impl Templates {
    fn build() -> Templates {
        let tcfg = TextConfig::default();
        let mut patches = Vec::new();
        let mut tokens = Vec::new();
        let mut questions = Vec::new();
        for i in 0..N_TEMPLATES {
            let item = shape_item(TEST_SEED, i);
            patches.push(patchify(&item.image, 4));
            tokens.push(sent_item(TEST_SEED, i, tcfg.seq_len, 16).0);
            questions.push(vqa_item(TEST_SEED, i).0);
        }
        Templates { patches, tokens, questions }
    }
}

/// Per-workload driver state: its own [`ResponseSlot`] (responses carry
/// no request id, so each workload drains its own slot), client-side
/// latency metrics, and the accounting counters.  The slot is sized to
/// the lane's total event count so no response can ever overflow it —
/// the final blocking drain relies on every admitted request delivering
/// exactly one response or marker.
struct Lane {
    workload: TraceWorkload,
    model: String,
    slot: ResponseSlot,
    metrics: Metrics,
    queue_metrics: Metrics,
    exec_metrics: Metrics,
    trace: Option<LaneTrace>,
    offered: u64,
    admitted: u64,
    shed: u64,
    failed: u64,
    completed: u64,
    deadline_met: u64,
    drained: u64,
    depth_max: usize,
    depth_sum: u64,
    depth_n: u64,
}

fn lane_index(lanes: &[Lane], w: TraceWorkload) -> usize {
    lanes
        .iter()
        .position(|l| l.workload == w)
        .expect("a lane exists for every workload present in the trace")
}

/// Build the event's pooled payload and submit it through the shed path.
/// Returns whether the request was admitted.
fn submit_event(coord: &Coordinator, tpl: &Templates, lane: &mut Lane,
                ev: &TraceEvent, qos: Qos) -> Result<bool> {
    let pool = coord.pool();
    let ti = (ev.item % N_TEMPLATES) as usize;
    let payload = match ev.workload {
        TraceWorkload::Vision => {
            let m = &tpl.patches[ti];
            let mut t = pool.take_f32(m.data.len());
            t.fill_f32(&m.data, &[m.rows, m.cols]);
            Payload::Vision(t)
        }
        TraceWorkload::Text => {
            let toks = &tpl.tokens[ti];
            let mut t = pool.take_i32(toks.len());
            t.fill_i32(toks, &[toks.len()]);
            Payload::Text(t)
        }
        TraceWorkload::Joint => {
            let m = &tpl.patches[ti];
            let mut vt = pool.take_f32(m.data.len());
            vt.fill_f32(&m.data, &[m.rows, m.cols]);
            let q = &tpl.questions[ti];
            let mut qt = pool.take_i32(q.len());
            qt.fill_i32(q, &[q.len()]);
            Payload::Joint { vision: vt, text: qt }
        }
        TraceWorkload::Gallery => {
            // image-probe query: embed the probe once, scan the store
            let m = &tpl.patches[ti];
            let mut t = pool.take_f32(m.data.len());
            t.fill_f32(&m.data, &[m.rows, m.cols]);
            Payload::GalleryQuery { probe: t, k: GALLERY_K }
        }
    };
    let deadline = if ev.deadline_us > 0 {
        Some(Duration::from_micros(ev.deadline_us))
    } else {
        None
    };
    lane.offered += 1;
    match coord.try_submit_pooled(to_workload(ev.workload), &lane.model, qos,
                                  payload, deadline, &lane.slot)? {
        Admission::Admitted => {
            lane.admitted += 1;
            Ok(true)
        }
        Admission::Shed => {
            lane.shed += 1;
            Ok(false)
        }
    }
}

/// Account one delivered response (or failure/expiry marker).
fn absorb(lane: &mut Lane, r: Result<InferResponse>, deadline_us: u64,
          clock: &TraceClock) {
    lane.drained += 1;
    match r {
        Ok(resp) => {
            let lat = resp.queue_us + resp.exec_us;
            lane.metrics.record(lat);
            lane.queue_metrics.record(resp.queue_us);
            lane.exec_metrics.record(resp.exec_us);
            lane.completed += 1;
            if deadline_us == 0 || lat <= deadline_us {
                lane.deadline_met += 1;
            }
            if let Some(tr) = lane.trace.as_mut() {
                let n = lane.completed - 1;
                if n % tr.every == 0 {
                    tr.push(n, &resp, clock.now_us());
                }
            }
        }
        Err(_) => lane.failed += 1,
    }
}

/// Sample the lane's workload queue depth (summed over its variants).
fn sample_depth(coord: &Coordinator, lane: &mut Lane) {
    let target = to_workload(lane.workload);
    let depth: usize = coord
        .router()
        .queue_depths()
        .iter()
        .filter(|(w, _, _, _)| *w == target)
        .map(|(_, _, _, d)| *d)
        .sum();
    lane.depth_max = lane.depth_max.max(depth);
    lane.depth_sum += depth as u64;
    lane.depth_n += 1;
}

/// Sum of worker-side `expired` counters per workload — the
/// authoritative deadline-drop count (client-side markers land in
/// `failed` without distinguishing expiry from batch failure).
fn expired_by_workload(coord: &Coordinator) -> [u64; 4] {
    let mut out = [0u64; 4];
    for (w, _, _, s) in coord.metrics_typed() {
        let i = match w {
            Workload::Vision => 0,
            Workload::Text => 1,
            Workload::Joint => 2,
            Workload::Gallery => 3,
        };
        out[i] += s.expired;
    }
    out
}

/// Ingest `n` template items into the gallery through the serving path
/// (one blocking request per item — ids are then the insertion order),
/// so the replay's queries scan a populated store.
fn prefill_gallery(coord: &Coordinator, tpl: &Templates, model: &str,
                   n: usize) -> Result<()> {
    let pool = coord.pool();
    let slot = coord.response_slot();
    for i in 0..n as u64 {
        let m = &tpl.patches[(i % N_TEMPLATES) as usize];
        let mut t = pool.take_f32(m.data.len());
        t.fill_f32(&m.data, &[m.rows, m.cols]);
        coord.submit_pooled(Workload::Gallery, model, Qos::Accuracy,
                            Payload::GalleryIngest(t), &slot)?;
        slot.recv()?;
    }
    Ok(())
}

/// Open-loop replay: submit on (scaled) trace timestamps, draining
/// responses non-blockingly between submissions, then drain every
/// outstanding admitted request.
fn run_open(coord: &Coordinator, tpl: &Templates, lanes: &mut [Lane],
            trace: &[TraceEvent], opts: &LoadOptions, t0: Instant,
            clock: &TraceClock) -> Result<()> {
    let every = opts.sample_every.max(1);
    for (i, ev) in trace.iter().enumerate() {
        if opts.time_scale > 0.0 {
            let target = Duration::from_micros(
                (ev.at_us as f64 * opts.time_scale) as u64);
            if let Some(wait) = target.checked_sub(t0.elapsed()) {
                if !wait.is_zero() {
                    std::thread::sleep(wait);
                }
            }
        }
        for lane in lanes.iter_mut() {
            loop {
                match lane.slot.try_recv() {
                    Ok(Some(resp)) => {
                        absorb(lane, Ok(resp), opts.trace.deadline_us, clock);
                    }
                    Ok(None) => break,
                    // a failure/expiry marker: one delivery, consumed
                    Err(e) => {
                        absorb(lane, Err(e), opts.trace.deadline_us, clock);
                    }
                }
            }
        }
        let li = lane_index(lanes, ev.workload);
        submit_event(coord, tpl, &mut lanes[li], ev, opts.qos)?;
        if i % every == 0 {
            sample_depth(coord, &mut lanes[li]);
        }
    }
    for lane in lanes.iter_mut() {
        while lane.drained < lane.admitted {
            let r = lane.slot.recv();
            absorb(lane, r, opts.trace.deadline_us, clock);
        }
    }
    Ok(())
}

/// Closed-loop replay: per workload, keep `users` requests in flight,
/// submitting the next only after a completion (plus think time).
#[allow(clippy::too_many_arguments)]
fn run_closed(coord: &Coordinator, tpl: &Templates, lanes: &mut [Lane],
              trace: &[TraceEvent], opts: &LoadOptions, users: usize,
              think_time_us: u64, clock: &TraceClock) -> Result<()> {
    let users = users.max(1);
    for lane in lanes.iter_mut() {
        let mut events =
            trace.iter().filter(|e| e.workload == lane.workload);
        let mut inflight = 0usize;
        loop {
            while inflight < users {
                match events.next() {
                    Some(ev) => {
                        if submit_event(coord, tpl, lane, ev, opts.qos)? {
                            inflight += 1;
                        }
                    }
                    None => break,
                }
            }
            if inflight == 0 {
                break;
            }
            let r = lane.slot.recv();
            absorb(lane, r, opts.trace.deadline_us, clock);
            inflight -= 1;
            sample_depth(coord, lane);
            if think_time_us > 0 {
                std::thread::sleep(Duration::from_micros(think_time_us));
            }
        }
    }
    Ok(())
}

/// Generate `opts.trace` and replay it against `coord`, returning the
/// full accounting.  The coordinator must have a pool for every
/// workload the trace's mix produces (the lane targets the first model
/// registered under that workload).
pub fn run_load(coord: &Coordinator, opts: &LoadOptions)
                -> Result<LoadReport> {
    let trace = generate_trace(&opts.trace)?;
    let tpl = Templates::build();
    let mut counts = [0usize; 4];
    for ev in &trace {
        counts[widx(ev.workload)] += 1;
    }
    let tws = [
        TraceWorkload::Vision,
        TraceWorkload::Text,
        TraceWorkload::Joint,
        TraceWorkload::Gallery,
    ];
    let mut lanes: Vec<Lane> = Vec::new();
    for (i, tw) in tws.iter().enumerate() {
        if counts[i] == 0 {
            continue;
        }
        let w = to_workload(*tw);
        let model = coord
            .router()
            .models_for(w)
            .first()
            .map(|s| s.to_string())
            .ok_or_else(|| {
                Error::Config(format!(
                    "load trace targets the {} pool but the coordinator \
                     has no {} models",
                    w.name(),
                    w.name()
                ))
            })?;
        lanes.push(Lane {
            workload: *tw,
            model,
            slot: ResponseSlot::new(counts[i]),
            metrics: Metrics::default(),
            queue_metrics: Metrics::default(),
            exec_metrics: Metrics::default(),
            trace: (opts.trace_sample > 0).then(|| LaneTrace {
                every: opts.trace_sample as u64,
                events: Vec::new(),
            }),
            offered: 0,
            admitted: 0,
            shed: 0,
            failed: 0,
            completed: 0,
            deadline_met: 0,
            drained: 0,
            depth_max: 0,
            depth_sum: 0,
            depth_n: 0,
        });
    }
    if opts.gallery_prefill > 0 {
        let model = coord
            .router()
            .models_for(Workload::Gallery)
            .first()
            .map(|s| s.to_string())
            .ok_or_else(|| {
                Error::Config(
                    "gallery_prefill > 0 but the coordinator has no \
                     gallery models".into(),
                )
            })?;
        prefill_gallery(coord, &tpl, &model, opts.gallery_prefill)?;
    }
    let expired_before = expired_by_workload(coord);
    let clock = match coord.obs_hub() {
        Some(h) => TraceClock::Hub(h.clone()),
        None => TraceClock::Local(Instant::now()),
    };
    let t0 = Instant::now();
    match opts.trace.arrival {
        ArrivalModel::Open => {
            run_open(coord, &tpl, &mut lanes, &trace, opts, t0, &clock)?;
        }
        ArrivalModel::Closed { users, think_time_us } => {
            run_closed(coord, &tpl, &mut lanes, &trace, opts, users,
                       think_time_us, &clock)?;
        }
    }
    let wall_s = t0.elapsed().as_secs_f64();
    let expired_after = expired_by_workload(coord);
    let had_deadline = opts.trace.deadline_us > 0;
    let mut request_lanes = Vec::new();
    let per_workload = lanes
        .into_iter()
        .map(|mut lane| {
            let i = widx(lane.workload);
            if let Some(tr) = lane.trace.take() {
                request_lanes.push(TraceThread {
                    name: format!("requests-{}",
                                  to_workload(lane.workload).name()),
                    events: tr.events,
                    dropped: 0,
                });
            }
            WorkloadReport {
                workload: to_workload(lane.workload),
                model: lane.model,
                offered: lane.offered,
                admitted: lane.admitted,
                shed: lane.shed,
                expired: expired_after[i]
                    .saturating_sub(expired_before[i]),
                failed: lane.failed,
                completed: lane.completed,
                deadline_met: lane.deadline_met,
                latency: lane.metrics.snapshot(),
                queue_wait: lane.queue_metrics.snapshot(),
                exec: lane.exec_metrics.snapshot(),
                depth_max: lane.depth_max,
                depth_mean: lane.depth_sum as f64
                    / lane.depth_n.max(1) as f64,
            }
        })
        .collect();
    Ok(LoadReport { wall_s, had_deadline, per_workload, request_lanes })
}

#[cfg(test)]
mod tests {
    use std::sync::Arc;

    use crate::config::{ServingConfig, ViTConfig};
    use crate::data::WorkloadMix;
    use crate::engine::JointKind;
    use crate::model::synthetic_mm_store;

    use super::super::server::CpuWorkloads;
    use super::*;

    fn boot(queue_capacity: usize) -> Coordinator {
        let ps = Arc::new(synthetic_mm_store(&ViTConfig::default(), 7));
        let workloads = CpuWorkloads {
            vision: vec![("vit".to_string(),
                          vec![("pitome".to_string(), 0.9)])],
            text: vec![("bert".to_string(),
                        vec![("none".to_string(), 1.0)])],
            joint: vec![("vqa".to_string(), JointKind::Vqa,
                         vec![("pitome".to_string(), 0.9)])],
            ..Default::default()
        };
        let cfg = ServingConfig {
            max_batch: 4,
            batch_timeout_us: 500,
            queue_capacity,
            workers: 1,
            trace_capacity: 0,
        };
        Coordinator::boot_cpu_workloads(&ps, &workloads, cfg).expect("boot")
    }

    /// Closed loop with ample queue room: every offered request is
    /// admitted and completed, and the per-lane accounting balances.
    #[test]
    fn closed_loop_accounts_for_every_request() {
        let coord = boot(64);
        let opts = LoadOptions {
            trace: TraceConfig {
                count: 12,
                mix: WorkloadMix::balanced(),
                arrival: ArrivalModel::Closed { users: 3, think_time_us: 0 },
                seed: 3,
                ..Default::default()
            },
            ..Default::default()
        };
        let rep = run_load(&coord, &opts).unwrap();
        assert_eq!(rep.offered(), 12);
        assert_eq!(rep.shed(), 0, "closed loop under capacity must not shed");
        assert_eq!(rep.completed(), 12);
        for w in &rep.per_workload {
            assert_eq!(w.admitted, w.completed + w.failed,
                       "{} lane lost a request", w.workload.name());
            assert_eq!(w.latency.count, w.completed);
        }
        assert!(rep.goodput_rps() > 0.0);
    }

    /// Gallery lane end-to-end: prefill the store through the serving
    /// path, then replay a gallery-only query trace and check both the
    /// client-side accounting and the worker-side gallery counters.
    #[test]
    fn gallery_lane_replays_queries_against_a_prefilled_store() {
        let ps = Arc::new(synthetic_mm_store(&ViTConfig::default(), 7));
        let workloads = CpuWorkloads {
            gallery: vec![("gal".to_string(),
                           vec![("pitome".to_string(), 0.9)])],
            ..Default::default()
        };
        let cfg = ServingConfig {
            max_batch: 4,
            batch_timeout_us: 500,
            queue_capacity: 64,
            workers: 1,
            trace_capacity: 0,
        };
        let coord =
            Coordinator::boot_cpu_workloads(&ps, &workloads, cfg).unwrap();
        let opts = LoadOptions {
            trace: TraceConfig {
                count: 10,
                mix: WorkloadMix {
                    vision: 0.0,
                    text: 0.0,
                    joint: 0.0,
                    gallery: 1.0,
                },
                arrival: ArrivalModel::Closed { users: 2, think_time_us: 0 },
                seed: 9,
                ..Default::default()
            },
            gallery_prefill: 12,
            ..Default::default()
        };
        let rep = run_load(&coord, &opts).unwrap();
        assert_eq!(rep.offered(), 10);
        assert_eq!(rep.completed(), 10,
                   "every gallery query must answer");
        let gal = rep
            .per_workload
            .iter()
            .find(|w| w.workload == Workload::Gallery)
            .expect("gallery lane present in the report");
        assert_eq!(gal.completed, 10);
        let snaps = coord.metrics_typed();
        let snap = &snaps
            .iter()
            .find(|(w, _, _, _)| *w == Workload::Gallery)
            .expect("gallery pool metrics")
            .3;
        assert_eq!(snap.gallery_len, 12, "prefill must populate the store");
        assert_eq!(snap.gallery_scanned_rows, 10 * 12,
                   "each query scans the whole prefilled store");
    }

    /// Unpaced open-loop burst against a capacity-1 queue: submission is
    /// microseconds, service is milliseconds, so admission control must
    /// shed — and every admitted request still gets answered.
    #[test]
    fn unpaced_open_overload_sheds_instead_of_blocking() {
        let coord = boot(1);
        let opts = LoadOptions {
            trace: TraceConfig {
                count: 40,
                rate: 10_000.0,
                deadline_us: 50_000,
                seed: 4,
                ..Default::default()
            },
            time_scale: 0.0,
            ..Default::default()
        };
        let rep = run_load(&coord, &opts).unwrap();
        assert_eq!(rep.offered(), 40);
        assert_eq!(rep.admitted() + rep.shed(), 40);
        assert!(rep.shed() > 0,
                "capacity-1 queue under an unpaced burst must shed");
        let answered: u64 =
            rep.per_workload.iter().map(|w| w.completed + w.failed).sum();
        assert_eq!(answered, rep.admitted(),
                   "every admitted request must be answered");
    }

    /// Tracing end-to-end: a coordinator booted with a span-ring hub
    /// plus request-lane sampling yields a Chrome trace carrying both
    /// the worker-side batch spans and the client-side request lanes,
    /// and the queue-wait/exec decomposition covers every completion.
    #[test]
    fn traced_run_reconstructs_request_and_worker_timelines() {
        let ps = Arc::new(synthetic_mm_store(&ViTConfig::default(), 7));
        let workloads = CpuWorkloads {
            vision: vec![("vit".to_string(),
                          vec![("pitome".to_string(), 0.9)])],
            ..Default::default()
        };
        let cfg = ServingConfig {
            max_batch: 4,
            batch_timeout_us: 500,
            queue_capacity: 64,
            workers: 1,
            trace_capacity: 4096,
        };
        let coord =
            Coordinator::boot_cpu_workloads(&ps, &workloads, cfg).unwrap();
        let opts = LoadOptions {
            trace: TraceConfig {
                count: 8,
                mix: WorkloadMix {
                    vision: 1.0,
                    text: 0.0,
                    joint: 0.0,
                    gallery: 0.0,
                },
                arrival: ArrivalModel::Closed { users: 2, think_time_us: 0 },
                seed: 5,
                ..Default::default()
            },
            trace_sample: 1,
            ..Default::default()
        };
        let rep = run_load(&coord, &opts).unwrap();
        assert_eq!(rep.completed(), 8);
        let w = &rep.per_workload[0];
        assert_eq!(w.queue_wait.count, 8,
                   "decomposition covers every completion");
        assert_eq!(w.exec.count, 8);
        let lane = rep
            .request_lanes
            .iter()
            .find(|t| t.name == "requests-vision")
            .expect("vision request lane");
        assert_eq!(lane.events.len(), 8 * 3,
                   "three spans per sampled request");
        assert!(lane.events.iter().all(|e| e.t_end_us >= e.t_start_us),
                "request spans must not run backwards");
        // the worker rings carry the batch-side story on the same hub
        let hub = coord.obs_hub().expect("tracing enabled").clone();
        let mut all = hub.drain();
        let exec_spans = all
            .iter()
            .flat_map(|t| t.events.iter())
            .filter(|e| e.stage == Stage::Exec)
            .count();
        assert!(exec_spans > 0, "worker rings must record Exec spans");
        // and the combined trace exports as valid Chrome-trace JSON
        all.extend(rep.request_lanes);
        let json = crate::obs::export::chrome_trace_json(&all);
        let doc = crate::util::parse_json(&json).expect("valid JSON");
        assert!(doc.get("traceEvents").and_then(|e| e.arr()).is_some());
    }
}
