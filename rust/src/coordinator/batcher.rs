//! Dynamic batcher: one worker thread per model variant, collecting
//! requests up to `max_batch` or `batch_timeout_us`, executing the batch,
//! and splitting the outputs back per request.
//!
//! Execution backends sharing the same batching loop:
//! * **PJRT** ([`VariantWorker::spawn`]) — pads the batch to the
//!   artifact's compiled batch size and executes the HLO artifact.
//! * **CPU vision** ([`VariantWorker::spawn_cpu`]) — runs the pure-Rust
//!   ViT through an engine [`VitSession`] the worker holds for its whole
//!   lifetime.
//! * **CPU text** ([`VariantWorker::spawn_cpu_text`]) — the BERT-style
//!   classifier through a long-lived [`BertSession`].
//! * **CPU joint** ([`VariantWorker::spawn_cpu_joint`]) — paired
//!   vision+text inference through a [`JointSession`], with a
//!   ragged-batch splitter: a collected batch's vision half
//!   (`Payload::{Vision,Joint}`) and text half (`Payload::{Text,Joint}`)
//!   are sized independently.  With `cfg.workers > 1` the two halves are
//!   split into batch fragments and drained by one pool of
//!   **work-stealing** workers (idle workers steal fragments across
//!   towers; see [`crate::model::encoder::encoder_forward_towers`]), so
//!   one oversized half no longer idles the rest of the pool.  Each
//!   fragment queue's mutex is a leaf lock held only for the O(1) split —
//!   never while running a sample or touching the other queue — so the
//!   two queues need no lock ordering between them.
//! * **CPU gallery** ([`VariantWorker::spawn_cpu_gallery`]) —
//!   embedding-gallery serving through a retrieval [`JointSession`]:
//!   ingest requests embed once and append to the shared
//!   [`GalleryStore`]; query requests embed one probe and scan the
//!   store with the blocked top-k kernel ([`crate::gallery`]).
//!
//! All CPU workers resolve weights once at boot (shared engine cache)
//! and pool every buffer a request touches — including the **response
//! tensors**, which are checked out of the coordinator's [`TensorPool`]
//! and returned to it when the caller drops the response.  A warmed
//! worker's whole batch cycle — parse, forward, fusion, response build,
//! channel send — performs **zero** heap allocations
//! ([`Snapshot::last_cycle_allocs`](super::metrics::Snapshot), asserted
//! by `tests/alloc_free.rs`); the inference region alone is still
//! tracked separately in `Snapshot::last_infer_allocs`.
//!
//! Built on std sync primitives (DESIGN.md §11): a bounded
//! `mpsc::sync_channel` is the admission-control boundary; `recv_timeout`
//! implements the batching deadline without spinning.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{Receiver, SyncSender, TrySendError};
use std::sync::Arc;
use std::time::{Duration, Instant};

use std::path::PathBuf;

use crate::config::{ServingConfig, TextConfig, ViTConfig};
use crate::engine::{BertSession, Engine, JointConfig, JointKind,
                    JointSession, VitSession};
use crate::error::{Error, Result};
use crate::gallery::{scan_into, GalleryScratch, GalleryStore, Hit, ScanMode};
use crate::obs::{ObsHub, RingWriter, SpanEvent, Stage};
use crate::runtime::{ArtifactEntry, Engine as PjrtEngine, Executable,
                     HostTensor};
use crate::util::alloc::allocs_this_thread;

use super::metrics::Metrics;
use super::pool::{PooledTensor, TensorPool};
use super::request::{Admission, InferOutputs, InferRequest, InferResponse,
                     Payload};

/// Handle to a running variant worker.
pub struct VariantWorker {
    tx: SyncSender<InferRequest>,
    /// shared metrics
    pub metrics: Arc<Metrics>,
    /// approximate backlog (admission signal): requests submitted but
    /// not yet entered into an executing batch — counts both the
    /// bounded channel and the worker's carried-over pending set
    depth: Arc<AtomicUsize>,
    /// queue capacity
    pub capacity: usize,
    join: Option<std::thread::JoinHandle<()>>,
}

impl VariantWorker {
    /// Shared worker bootstrap: channel, metrics, depth counter, thread.
    /// `init` runs on the worker thread (handed the worker's metrics
    /// sink and, when tracing is on, the worker's span recorder to
    /// attach to its session) and produces the batch-execution closure
    /// (returning `None` aborts the worker, e.g. when PJRT is
    /// unavailable — submitters then observe a closed queue).  The
    /// closure fills `outs` with exactly one [`InferOutputs`] per
    /// request.  When `hub` is `Some`, one span ring is registered under
    /// the worker's name; batch-stage spans record into it from the
    /// worker thread only (the ring's single-producer contract).
    // lint: allow(alloc) reason=cold bootstrap: channel, metrics Arcs, ring registration, and thread spawn happen once per worker
    fn spawn_worker<E, I>(name: String, cfg: &ServingConfig, max_batch: usize,
                          hub: Option<&Arc<ObsHub>>, init: I) -> VariantWorker
    where
        E: FnMut(&[InferRequest], &mut Vec<InferOutputs>) -> Result<()>
            + 'static,
        I: FnOnce(&Arc<Metrics>, Option<&RingWriter>) -> Option<E>
            + Send + 'static,
    {
        let (tx, rx) = std::sync::mpsc::sync_channel::<InferRequest>(cfg.queue_capacity);
        let metrics = Arc::new(Metrics::default());
        let depth = Arc::new(AtomicUsize::new(0));
        let m2 = metrics.clone();
        let d2 = depth.clone();
        let timeout = Duration::from_micros(cfg.batch_timeout_us);
        let rec = hub.map(|h| h.recorder(&name));
        let join = std::thread::Builder::new()
            .name(name)
            .spawn(move || {
                let Some(exec) = init(&m2, rec.as_ref()) else { return };
                worker_loop(exec, rx, m2, d2, max_batch, timeout, rec)
            })
            .expect("spawn worker");
        VariantWorker {
            tx,
            metrics,
            depth,
            capacity: cfg.queue_capacity,
            join: Some(join),
        }
    }

    /// Spawn a worker that compiles `hlo_path` on its own PJRT client
    /// (PJRT handles are not Send; per-thread clients keep this safe) and
    /// serves batches.  `params` is the artifact's leading flat-weights
    /// input (empty vec for artifacts without params).
    // lint: allow(alloc) reason=PJRT transport path copies host tensors by design; zero-alloc serving is the CPU path
    pub fn spawn(hlo_path: PathBuf, entry: ArtifactEntry, params: Vec<f32>,
                 cfg: &ServingConfig, hub: Option<&Arc<ObsHub>>)
                 -> VariantWorker {
        let max_batch = cfg.max_batch.min(entry.meta.batch);
        let name = format!("pitome-worker-{}", entry.file);
        Self::spawn_worker(name, cfg, max_batch, hub,
                           move |_metrics: &Arc<Metrics>,
                                 _rec: Option<&RingWriter>| {
            let engine = match PjrtEngine::cpu() {
                Ok(e) => e,
                Err(e) => {
                    eprintln!("[pitome worker] PJRT client failed: {e}");
                    return None;
                }
            };
            let exe = match engine.compile_file(&hlo_path, entry) {
                Ok(e) => e,
                Err(e) => {
                    eprintln!("[pitome worker] compile failed: {e}");
                    return None;
                }
            };
            Some(move |batch: &[InferRequest],
                       outs: &mut Vec<InferOutputs>| {
                // the client must outlive its executable
                let _ = &engine;
                let per_request = run_batch(&exe, &params, batch)?;
                for tensors in per_request {
                    outs.push(InferOutputs::Many(
                        tensors.into_iter().map(PooledTensor::detached)
                            .collect()));
                }
                Ok(())
            })
        })
    }

    /// Spawn a worker that serves the pure-Rust CPU reference ViT (no
    /// PJRT artifacts required).  Requests carry a single f32 patches
    /// tensor `(n_patches, patch_dim)`; responses carry the class logits
    /// in a recycled buffer from `pool`.  Each collected batch runs
    /// through the worker's [`VitSession`], whose encoder fan-out uses
    /// `cfg.workers` threads.
    // lint: allow(alloc) reason=cold bootstrap: worker-name format! and Arc clones happen once per worker
    pub fn spawn_cpu(engine: Arc<Engine>, model_cfg: ViTConfig,
                     pool: Arc<TensorPool>, cfg: &ServingConfig,
                     hub: Option<&Arc<ObsHub>>)
                     -> VariantWorker {
        let max_batch = cfg.max_batch;
        let workers = cfg.workers.max(1);
        let name = format!("pitome-cpu-{}-r{:.0}",
                           model_cfg.merge_mode, model_cfg.merge_r * 1000.0);
        Self::spawn_worker(name, cfg, max_batch, hub,
                           move |metrics: &Arc<Metrics>,
                                 rec: Option<&RingWriter>| {
            // one session per variant worker, alive for the worker's
            // whole lifetime: weights resolve once here (the engine cache
            // shares the resolution across equal-config workers) and
            // never again; after the first batch warms the pools,
            // steady-state inference allocates nothing
            let mut sess = match engine.vit_session(&model_cfg) {
                Ok(s) => s,
                Err(e) => {
                    eprintln!("[pitome worker] session init failed: {e}");
                    return None;
                }
            };
            sess.set_workers(workers);
            if let Some(r) = rec {
                sess.set_observability(Some(r.clone()),
                                       model_cfg.depth * max_batch);
            }
            let metrics = metrics.clone();
            Some(move |batch: &[InferRequest],
                       outs: &mut Vec<InferOutputs>| {
                cpu_run_batch(&mut sess, &metrics, &pool, batch, outs)
            })
        })
    }

    /// Spawn a worker that serves the pure-Rust BERT-style text
    /// classifier.  Requests carry a single i32 token-id tensor
    /// `(n_tokens,)`; responses carry the class logits in a recycled
    /// buffer from `pool`.
    // lint: allow(alloc) reason=cold bootstrap: worker-name format! and Arc clones happen once per worker
    pub fn spawn_cpu_text(engine: Arc<Engine>, model_cfg: TextConfig,
                          pool: Arc<TensorPool>, cfg: &ServingConfig,
                          hub: Option<&Arc<ObsHub>>)
                          -> VariantWorker {
        let max_batch = cfg.max_batch;
        let workers = cfg.workers.max(1);
        let name = format!("pitome-text-{}-r{:.0}",
                           model_cfg.merge_mode, model_cfg.merge_r * 1000.0);
        Self::spawn_worker(name, cfg, max_batch, hub,
                           move |metrics: &Arc<Metrics>,
                                 rec: Option<&RingWriter>| {
            let mut sess = match engine.bert_session(&model_cfg) {
                Ok(s) => s,
                Err(e) => {
                    eprintln!("[pitome worker] text session init failed: {e}");
                    return None;
                }
            };
            sess.set_workers(workers);
            if let Some(r) = rec {
                sess.set_observability(Some(r.clone()),
                                       model_cfg.depth * max_batch);
            }
            let metrics = metrics.clone();
            Some(move |batch: &[InferRequest],
                       outs: &mut Vec<InferOutputs>| {
                cpu_run_text_batch(&mut sess, &metrics, &pool, batch, outs)
            })
        })
    }

    /// Spawn a worker that serves joint vision+text requests through a
    /// long-lived [`JointSession`].  The ragged-batch splitter sizes the
    /// two halves independently per batch: `Payload::Joint` pairs join
    /// both halves, `Payload::Vision` / `Payload::Text` singles join one
    /// (their responses are the corresponding tower feature/embedding).
    /// With `cfg.workers > 1` both halves drain through one pool of
    /// work-stealing workers (fragments stolen across towers, results
    /// bitwise-independent of the schedule); with one worker the towers
    /// run back-to-back on the worker thread, allocation-free once warm.
    // lint: allow(alloc) reason=cold bootstrap: worker-name format!, Arc clones, and empty splitter scratch built once per worker
    pub fn spawn_cpu_joint(engine: Arc<Engine>, model_cfg: JointConfig,
                           pool: Arc<TensorPool>, cfg: &ServingConfig,
                           hub: Option<&Arc<ObsHub>>)
                           -> VariantWorker {
        let max_batch = cfg.max_batch;
        let workers = cfg.workers.max(1);
        let name = format!("pitome-joint-{}-r{:.0}",
                           model_cfg.vision.merge_mode,
                           model_cfg.vision.merge_r * 1000.0);
        Self::spawn_worker(name, cfg, max_batch, hub,
                           move |metrics: &Arc<Metrics>,
                                 rec: Option<&RingWriter>| {
            let mut sess = match engine.joint_session(&model_cfg) {
                Ok(s) => s,
                Err(e) => {
                    eprintln!("[pitome worker] joint session init failed: {e}");
                    return None;
                }
            };
            sess.set_vision_workers(workers);
            if let Some(r) = rec {
                sess.set_observability(Some(r.clone()),
                                       model_cfg.vision.depth * max_batch);
            }
            let metrics = metrics.clone();
            // splitter scratch, reused across batches
            let mut pairs: Vec<(usize, usize)> = Vec::new();
            let mut slots: Vec<JointSlot> = Vec::new();
            Some(move |batch: &[InferRequest],
                       outs: &mut Vec<InferOutputs>| {
                cpu_run_joint_batch(&mut sess, &metrics, &pool, batch, outs,
                                    &mut pairs, &mut slots)
            })
        })
    }

    /// Spawn a worker that serves the embedding gallery: ingest
    /// requests embed once through the retrieval [`JointSession`]
    /// towers (f32 patches → image tower, i32 token ids → text tower)
    /// and append the normalized embedding to the shared
    /// [`GalleryStore`]; query requests embed one probe the same way,
    /// then scan the store with the blocked lane-split kernel and
    /// answer the best `k` hits from the recycled pool.  Ingests and
    /// queries mix freely in a batch: all ingests apply before any
    /// query scans, so a query observes every ingest that shared its
    /// batch.  `model_cfg` must be a retrieval-kind joint config.
    // lint: allow(alloc) reason=cold bootstrap: worker-name format!, Arc clones, and empty gallery scratch built once per worker
    pub fn spawn_cpu_gallery(engine: Arc<Engine>, model_cfg: JointConfig,
                             store: Arc<GalleryStore>,
                             pool: Arc<TensorPool>, cfg: &ServingConfig,
                             hub: Option<&Arc<ObsHub>>)
                             -> VariantWorker {
        let max_batch = cfg.max_batch;
        let workers = cfg.workers.max(1);
        let name = format!("pitome-gallery-{}-r{:.0}",
                           model_cfg.vision.merge_mode,
                           model_cfg.vision.merge_r * 1000.0);
        Self::spawn_worker(name, cfg, max_batch, hub,
                           move |metrics: &Arc<Metrics>,
                                 rec: Option<&RingWriter>| {
            if model_cfg.kind != JointKind::Retrieval {
                eprintln!("[pitome worker] gallery worker needs a \
                           retrieval-kind joint config");
                return None;
            }
            let mut sess = match engine.joint_session(&model_cfg) {
                Ok(s) => s,
                Err(e) => {
                    eprintln!("[pitome worker] gallery session init \
                               failed: {e}");
                    return None;
                }
            };
            sess.set_vision_workers(workers);
            if let Some(r) = rec {
                sess.set_observability(Some(r.clone()),
                                       model_cfg.vision.depth * max_batch);
            }
            let metrics = metrics.clone();
            // per-worker batch + scan scratch, reused across batches
            let mut slots: Vec<GallerySlot> = Vec::new();
            let mut ids: Vec<u64> = Vec::new();
            let mut scratch = GalleryScratch::new();
            scratch.set_recorder(rec.cloned());
            let mut hits: Vec<Hit> = Vec::new();
            let mut flat: Vec<f32> = Vec::new();
            Some(move |batch: &[InferRequest],
                       outs: &mut Vec<InferOutputs>| {
                cpu_run_gallery_batch(&mut sess, &store, &metrics, &pool,
                                      batch, outs, &mut slots, &mut ids,
                                      &mut scratch, &mut hits, &mut flat,
                                      workers)
            })
        })
    }

    /// Blocking submit (backpressure by blocking on the bounded queue).
    pub fn submit(&self, req: InferRequest) -> Result<()> {
        self.depth.fetch_add(1, Ordering::Relaxed);
        self.tx.send(req).map_err(|_| {
            self.depth.fetch_sub(1, Ordering::Relaxed);
            Error::Coordinator("worker queue closed".into())
        })
    }

    /// Non-blocking submit; `Err` when the queue is full (admission
    /// control) or closed.
    pub fn try_submit(&self, req: InferRequest) -> Result<()> {
        self.depth.fetch_add(1, Ordering::Relaxed);
        self.tx.try_send(req).map_err(|e| {
            self.depth.fetch_sub(1, Ordering::Relaxed);
            match e {
                TrySendError::Full(_) => Error::Coordinator("queue full (backpressure)".into()),
                TrySendError::Disconnected(_) => Error::Coordinator("worker queue closed".into()),
            }
        })
    }

    /// Non-blocking admission-controlled submit: enqueue if the bounded
    /// queue has room, otherwise refuse immediately ([`Admission::Shed`],
    /// counted in the worker's `shed` metric).  Unlike [`try_submit`],
    /// a full queue is a normal, non-error outcome here — the load
    /// harness and `Coordinator::try_submit_pooled` use this as the shed
    /// path so overload never blocks the submitting thread.
    ///
    /// [`try_submit`]: VariantWorker::try_submit
    pub fn submit_shed(&self, req: InferRequest) -> Result<Admission> {
        self.depth.fetch_add(1, Ordering::Relaxed);
        match self.tx.try_send(req) {
            Ok(()) => Ok(Admission::Admitted),
            Err(TrySendError::Full(_)) => {
                self.depth.fetch_sub(1, Ordering::Relaxed);
                self.metrics.record_shed();
                Ok(Admission::Shed)
            }
            Err(TrySendError::Disconnected(_)) => {
                self.depth.fetch_sub(1, Ordering::Relaxed);
                Err(Error::Coordinator("worker queue closed".into()))
            }
        }
    }

    /// Queue headroom signal used by the router's load-shedding policy.
    /// The threshold is a ceiling half: `depth < capacity / 2` was always
    /// false for `queue_capacity = 1` (threshold 0), so `Qos::Balanced`
    /// routing permanently shed to the deepest-compression rung on small
    /// queues even when the preferred worker sat idle.
    pub fn has_capacity(&self) -> bool {
        self.depth.load(Ordering::Relaxed) < (self.capacity + 1) / 2
    }

    /// Current approximate backlog: requests submitted but not yet
    /// executing (queued in the channel or held pending by the worker).
    pub fn depth(&self) -> usize {
        self.depth.load(Ordering::Relaxed)
    }
}

impl Drop for VariantWorker {
    fn drop(&mut self) {
        let (dead_tx, _) = std::sync::mpsc::sync_channel(1);
        drop(std::mem::replace(&mut self.tx, dead_tx));
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

/// Shared batching loop: collect up to `max_batch` requests (or until the
/// deadline), order them earliest-deadline-first, run the front of the
/// queue through `exec`, and fan the responses back out.
///
/// **Deadline-aware ordering:** after the timed gather, already-queued
/// requests are drained opportunistically — but only until the worker
/// holds two batches' worth (`2 * max_batch`); the rest stay in the
/// bounded channel so the queue fills, `submit_shed` sheds, and the
/// backlog stays bounded instead of laundering into an unbounded Vec.
/// The pending set is sorted earliest-deadline-first (deadline-less
/// requests after all deadlined ones, FIFO within a class) and only
/// the first `max_batch` requests execute this cycle; the rest carry
/// over and run *before* the worker blocks for new arrivals, so under
/// overload a tight-deadline request buried behind a full batch is
/// promoted instead of expiring mid-queue.  One fairness floor caps
/// how long EDF may bypass a request: the globally oldest pending
/// request always rides the executing batch, so a continuous stream
/// of deadlined traffic cannot starve deadline-less carry-overs.
///
/// `depth` counts a request from submit until it enters an executing
/// batch — requests the worker holds in `pending` still register as
/// backlog for `has_capacity()`/`depth()` admission signals.
///
/// The pending/batch/output vectors are loop-owned and reused, so a
/// warmed cycle performs no allocations of its own; the per-cycle
/// allocation count (inference + transport) lands in
/// [`Snapshot::last_cycle_allocs`](super::metrics::Snapshot).
// lint: allow(alloc) reason=loop-owned pending/batch/output vectors allocated once and reused every cycle
fn worker_loop<E>(mut exec: E, rx: Receiver<InferRequest>,
                  metrics: Arc<Metrics>, depth: Arc<AtomicUsize>,
                  max_batch: usize, timeout: Duration,
                  rec: Option<RingWriter>)
where
    E: FnMut(&[InferRequest], &mut Vec<InferOutputs>) -> Result<()>,
{
    let mut pending: Vec<InferRequest> = Vec::new();
    let mut batch: Vec<InferRequest> = Vec::new();
    let mut outs: Vec<InferOutputs> = Vec::new();
    // worker-held backlog cap: one executing batch plus one carried-over
    // batch.  Anything beyond stays in the bounded channel, preserving
    // submit_shed backpressure and bounding memory under overload.
    let pending_cap = max_batch.saturating_mul(2).max(1);
    // worker-local batch ordinal: every span of one batch cycle carries
    // it, so an exporter can group a cycle's stages back together
    let mut batch_id: u64 = 0;
    let mut open = true;
    while open || !pending.is_empty() {
        // gather clock starts when work is in hand (after the idle
        // block, so a quiet queue doesn't inflate the gather span)
        let mut gather_t0 = rec.as_ref().map(|w| w.now_us());
        if open && pending.is_empty() {
            // idle: block for the first arrival, then gather its batch
            match rx.recv() {
                Ok(r) => {
                    gather_t0 = rec.as_ref().map(|w| w.now_us());
                    pending.push(r);
                }
                Err(_) => {
                    open = false;
                    continue;
                }
            }
            let deadline = Instant::now() + timeout;
            while pending.len() < max_batch {
                let remaining =
                    deadline.saturating_duration_since(Instant::now());
                if remaining.is_zero() {
                    break;
                }
                match rx.recv_timeout(remaining) {
                    Ok(r) => pending.push(r),
                    Err(std::sync::mpsc::RecvTimeoutError::Timeout) => break,
                    Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => {
                        open = false;
                        break;
                    }
                }
            }
        }
        if open {
            // opportunistic drain: pull already-queued requests (capped
            // at pending_cap) so the EDF sort can promote near-deadline
            // requests past a full batch; carried-over requests run
            // before new arrivals
            while pending.len() < pending_cap {
                match rx.try_recv() {
                    Ok(r) => pending.push(r),
                    Err(std::sync::mpsc::TryRecvError::Empty) => break,
                    Err(std::sync::mpsc::TryRecvError::Disconnected) => {
                        open = false;
                        break;
                    }
                }
            }
        }
        if pending.is_empty() {
            continue;
        }
        let sort_t0 = rec.as_ref().map(|w| w.now_us());
        if pending.len() > 1 {
            // earliest-deadline-first; in-place unstable sort (ties are
            // fully ordered by enqueue time, so stability is irrelevant)
            pending.sort_unstable_by(|a, b| match (a.deadline, b.deadline) {
                (Some(x), Some(y)) => {
                    x.cmp(&y).then(a.enqueued_at.cmp(&b.enqueued_at))
                }
                (Some(_), None) => std::cmp::Ordering::Less,
                (None, Some(_)) => std::cmp::Ordering::Greater,
                (None, None) => a.enqueued_at.cmp(&b.enqueued_at),
            });
        }
        if let Some(w) = rec.as_ref() {
            w.record(SpanEvent {
                stage: Stage::BatchGather,
                id: batch_id,
                t_start_us: gather_t0.unwrap_or(0),
                t_end_us: sort_t0.unwrap_or(0),
                payload: pending.len() as u32,
                a: 0.0,
                b: 0.0,
            });
            w.span_since(Stage::EdfSort, batch_id, sort_t0.unwrap_or(0),
                         pending.len() as u32);
        }
        batch.clear();
        let take = pending.len().min(max_batch);
        if pending.len() > take {
            // fairness floor: the globally oldest request always rides
            // this batch, so EDF cannot bypass any request indefinitely
            // (deadline-less carry-overs would otherwise starve under a
            // continuous stream of deadlined traffic)
            let oldest = pending
                .iter()
                .enumerate()
                .min_by_key(|(_, r)| r.enqueued_at)
                .map(|(i, _)| i)
                .expect("pending is non-empty");
            if oldest >= take {
                pending.swap(take - 1, oldest);
            }
        }
        batch.extend(pending.drain(..take));
        // requests leave the admission-visible backlog only now, as they
        // enter the executing batch
        depth.fetch_sub(take, Ordering::Relaxed);
        if let Some(w) = rec.as_ref() {
            // one queue-wait span per request: submit time → batch entry,
            // payload = position in the executing batch
            for (pos, req) in batch.iter().enumerate() {
                w.record(SpanEvent {
                    stage: Stage::QueueWait,
                    id: batch_id,
                    t_start_us: w.us_of(req.enqueued_at),
                    t_end_us: w.now_us(),
                    payload: pos as u32,
                    a: 0.0,
                    b: 0.0,
                });
            }
        }
        // deadline-aware batching: drop requests whose deadline already
        // passed *before* spending execution on them.  Counted first
        // (so a client that observes the expiry marker sees the count),
        // then answered with an explicit expiry marker (batch_size 0)
        // so slot clients never hang; legacy channel submitters observe
        // a closed channel as the request drops.
        let now = Instant::now();
        let expired = batch
            .iter()
            .filter(|r| matches!(r.deadline, Some(d) if d <= now))
            .count();
        if expired > 0 {
            metrics.record_expired(expired as u64);
            batch.retain(|req| {
                let dead = matches!(req.deadline, Some(d) if d <= now);
                if dead && req.respond.is_slot() {
                    let _ = req.respond.send(InferResponse {
                        outputs: InferOutputs::Many(Vec::new()),
                        queue_us: now
                            .duration_since(req.enqueued_at)
                            .as_micros() as u64,
                        exec_us: 0,
                        batch_size: 0,
                    });
                }
                !dead
            });
            if batch.is_empty() {
                continue;
            }
        }
        let exec_start = Instant::now();
        let cycle_before = allocs_this_thread();
        outs.clear();
        let result = exec(&batch, &mut outs);
        let exec_us = exec_start.elapsed().as_micros() as u64;
        let batch_size = batch.len();
        metrics.record_batch(batch_size);
        if let Some(w) = rec.as_ref() {
            w.span_since(Stage::Exec, batch_id, w.us_of(exec_start),
                         batch_size as u32);
        }
        let respond_t0 = rec.as_ref().map(|w| w.now_us());
        match result {
            Ok(()) if outs.len() == batch_size => {
                for (req, outputs) in batch.drain(..).zip(outs.drain(..)) {
                    let queue_us =
                        exec_start.duration_since(req.enqueued_at).as_micros() as u64;
                    metrics.record(queue_us + exec_us);
                    // a gone receiver just recycles the response buffers
                    let _ = req.respond.send(InferResponse {
                        outputs,
                        queue_us,
                        exec_us,
                        batch_size,
                    });
                }
            }
            Ok(()) => {
                eprintln!("[pitome worker] batch produced {} outputs for {} \
                           requests", outs.len(), batch_size);
                fail_batch(&mut batch, exec_us, batch_size);
                outs.clear();
            }
            Err(e) => {
                eprintln!("[pitome worker] batch failed: {e}");
                fail_batch(&mut batch, exec_us, batch_size);
                outs.clear();
            }
        }
        if let Some(w) = rec.as_ref() {
            w.span_since(Stage::Respond, batch_id, respond_t0.unwrap_or(0),
                         batch_size as u32);
        }
        batch_id += 1;
        metrics.record_cycle_allocs(allocs_this_thread() - cycle_before);
    }
}

/// Drop a failed batch's requests.  Legacy per-request channels are
/// simply dropped — their submitters observe a closed channel — but a
/// reusable [`ResponseSlot`](super::request::ResponseSlot) keeps its own
/// sender alive and can never disconnect, so slot-targeted requests get
/// an explicit failure marker (a response with no outputs) that
/// `ResponseSlot::recv` translates back into an error; a blocked client
/// always wakes up.  Pooled inputs recycle as the requests drop.
// lint: allow(alloc) reason=failure path only, never taken in steady state
fn fail_batch(batch: &mut Vec<InferRequest>, exec_us: u64,
              batch_size: usize) {
    for req in batch.drain(..) {
        if req.respond.is_slot() {
            let _ = req.respond.send(InferResponse {
                outputs: InferOutputs::Many(Vec::new()),
                queue_us: 0,
                exec_us,
                batch_size,
            });
        }
    }
}

/// Build one single-tensor response from a recycled pool buffer.
fn respond_f32(pool: &Arc<TensorPool>, outs: &mut Vec<InferOutputs>,
               data: &[f32], recycled: &mut u64, fresh: &mut u64) {
    let mut t = pool.take_f32(data.len());
    if t.recycled() {
        *recycled += 1;
    } else {
        *fresh += 1;
    }
    t.fill_f32(data, &[data.len()]);
    outs.push(InferOutputs::One(t));
}

/// Execute a batch on the CPU reference ViT through the worker's
/// long-lived [`VitSession`]: parse each request's patches tensor into a
/// pooled slot, run embed + encoder + head, and return one logits tensor
/// per request from the recycled response pool.
///
/// The span from the first parse through `forward` is the *inference
/// region*; its allocation count is recorded per batch
/// ([`Metrics::record_infer_allocs`]) and must be zero for a warmed
/// worker (`tests/alloc_free.rs`).  Response construction happens after
/// the region and is covered by the whole-cycle count instead.
// lint: allow(alloc) reason=error-path format! only, never taken on the steady-state path
fn cpu_run_batch(sess: &mut VitSession, metrics: &Metrics,
                 pool: &Arc<TensorPool>, batch: &[InferRequest],
                 outs: &mut Vec<InferOutputs>) -> Result<()> {
    let before = allocs_this_thread();
    sess.reset_merge_telemetry();
    let t_embed = sess.recorder().map(|r| r.now_us());
    // exact-shape admission: a malformed request must become an error (the
    // responders are dropped, submitters see a closed channel), never a
    // panic that would kill the worker thread for every later request
    let (want_rows, want_cols) =
        (sess.cfg().num_patches(), sess.cfg().patch_dim());
    sess.begin(batch.len());
    for (i, req) in batch.iter().enumerate() {
        let t = req.payload.vision_tensor().ok_or_else(|| {
            Error::Coordinator(format!(
                "cpu worker: request {i} carries no patches tensor"))
        })?;
        let d = t.as_f32()?;
        let shape = t.shape();
        if shape != [want_rows, want_cols] || d.len() != want_rows * want_cols {
            return Err(Error::Shape(format!(
                "cpu worker: request {i} patches shape {shape:?} != \
                 expected ({want_rows}, {want_cols})")));
        }
        sess.set_patches_slice(i, d)?;
    }
    if let Some(r) = sess.recorder() {
        r.span_since(Stage::Embed, 0, t_embed.unwrap_or(0),
                     batch.len() as u32);
    }
    sess.forward(0)?;
    metrics.record_infer_allocs(allocs_this_thread() - before);
    let (mut recycled, mut fresh) = (0u64, 0u64);
    for i in 0..batch.len() {
        respond_f32(pool, outs, sess.logits(i), &mut recycled, &mut fresh);
    }
    metrics.record_responses(recycled, fresh);
    Ok(())
}

/// Execute a batch on the CPU text classifier through the worker's
/// long-lived [`BertSession`] — the text-workload counterpart of
/// [`cpu_run_batch`].
// lint: allow(alloc) reason=error-path format! only, never taken on the steady-state path
fn cpu_run_text_batch(sess: &mut BertSession, metrics: &Metrics,
                      pool: &Arc<TensorPool>, batch: &[InferRequest],
                      outs: &mut Vec<InferOutputs>) -> Result<()> {
    let before = allocs_this_thread();
    sess.reset_merge_telemetry();
    let t_embed = sess.recorder().map(|r| r.now_us());
    sess.begin(batch.len());
    for (i, req) in batch.iter().enumerate() {
        let t = req.payload.text_tensor().ok_or_else(|| {
            Error::Coordinator(format!(
                "text worker: request {i} carries no token tensor"))
        })?;
        sess.set_tokens(i, t.as_i32()?)?;
    }
    if let Some(r) = sess.recorder() {
        r.span_since(Stage::Embed, 0, t_embed.unwrap_or(0),
                     batch.len() as u32);
    }
    sess.forward(0)?;
    metrics.record_infer_allocs(allocs_this_thread() - before);
    let (mut recycled, mut fresh) = (0u64, 0u64);
    for i in 0..batch.len() {
        respond_f32(pool, outs, sess.logits(i), &mut recycled, &mut fresh);
    }
    metrics.record_responses(recycled, fresh);
    Ok(())
}

/// What each joint-batch request gets answered with (index into the
/// session's pairs / vision half / text half).
enum JointSlot {
    /// fused pair `p`: VQA answer logits, or the retrieval score
    Pair(usize),
    /// vision-only sample `i`: tower feature (VQA kind) or normalized
    /// image embedding (retrieval kind)
    Vis(usize),
    /// text-only sample `j`: tower feature or normalized text embedding
    Txt(usize),
}

/// How a joint-worker request participates in the ragged split.
enum JointWant {
    Pair,
    VisionOnly,
    TextOnly,
}

// lint: allow(alloc) reason=error-path format! only, never taken on the steady-state path
fn classify_joint(p: &Payload) -> Result<JointWant> {
    match p {
        Payload::Joint { .. } => Ok(JointWant::Pair),
        Payload::Vision(_) => Ok(JointWant::VisionOnly),
        Payload::Text(_) => Ok(JointWant::TextOnly),
        Payload::Tensors(v) if v.len() == 2 => Ok(JointWant::Pair),
        Payload::Tensors(v) => Err(Error::Coordinator(format!(
            "joint worker: legacy tensor payload must be the \
             [patches, question] pair, got {} tensors", v.len()))),
        Payload::GalleryIngest(_) | Payload::GalleryQuery { .. } => {
            Err(Error::Coordinator(
                "joint worker: gallery payload routed to joint worker \
                 (route it to Workload::Gallery)".into()))
        }
    }
}

/// Execute a mixed batch through the worker's long-lived
/// [`JointSession`]: the ragged splitter files every request into the
/// vision and/or text half, both towers run once over their halves
/// (independently sized), the kind's fusion stage runs over the explicit
/// pair list, and each request is answered from the recycled pool —
/// pairs with answer logits (VQA) or the similarity score (retrieval),
/// singles with their tower feature/embedding.
// lint: allow(alloc) reason=error-path format! only, never taken on the steady-state path
fn cpu_run_joint_batch(sess: &mut JointSession, metrics: &Metrics,
                       pool: &Arc<TensorPool>, batch: &[InferRequest],
                       outs: &mut Vec<InferOutputs>,
                       pairs: &mut Vec<(usize, usize)>,
                       slots: &mut Vec<JointSlot>) -> Result<()> {
    let before = allocs_this_thread();
    sess.reset_merge_telemetry();
    let t_embed = sess.recorder().map(|r| r.now_us());
    pairs.clear();
    slots.clear();
    // pass 1: size the two halves independently
    let (mut bv, mut bt) = (0usize, 0usize);
    for req in batch {
        match classify_joint(&req.payload)? {
            JointWant::Pair => {
                bv += 1;
                bt += 1;
            }
            JointWant::VisionOnly => bv += 1,
            JointWant::TextOnly => bt += 1,
        }
    }
    sess.begin(bv, bt);
    // pass 2: embed every half member into its pooled slot
    let (mut vi, mut ti) = (0usize, 0usize);
    for (ri, req) in batch.iter().enumerate() {
        match classify_joint(&req.payload)? {
            JointWant::Pair => {
                let v = req.payload.vision_tensor().ok_or_else(|| {
                    Error::Coordinator(format!(
                        "joint worker: pair request {ri} lost its patches"))
                })?;
                let t = req.payload.text_tensor().ok_or_else(|| {
                    Error::Coordinator(format!(
                        "joint worker: pair request {ri} lost its tokens"))
                })?;
                sess.set_patches_slice(vi, v.as_f32()?)?;
                sess.set_text(ti, t.as_i32()?)?;
                slots.push(JointSlot::Pair(pairs.len()));
                pairs.push((vi, ti));
                vi += 1;
                ti += 1;
            }
            JointWant::VisionOnly => {
                let v = req.payload.vision_tensor().unwrap();
                sess.set_patches_slice(vi, v.as_f32()?)?;
                slots.push(JointSlot::Vis(vi));
                vi += 1;
            }
            JointWant::TextOnly => {
                let t = req.payload.text_tensor().unwrap();
                sess.set_text(ti, t.as_i32()?)?;
                slots.push(JointSlot::Txt(ti));
                ti += 1;
            }
        }
    }
    if let Some(r) = sess.recorder() {
        r.span_since(Stage::Embed, 0, t_embed.unwrap_or(0),
                     batch.len() as u32);
    }
    // both towers, then the kind's fusion stage
    sess.forward(0)?;
    let kind = sess.cfg().kind;
    match kind {
        JointKind::Vqa => sess.fuse_vqa(pairs)?,
        JointKind::Retrieval => sess.project()?,
    }
    metrics.record_infer_allocs(allocs_this_thread() - before);
    // responses from the recycled pool
    let (mut recycled, mut fresh) = (0u64, 0u64);
    for slot in slots.iter() {
        match (kind, slot) {
            (JointKind::Vqa, JointSlot::Pair(p)) => {
                respond_f32(pool, outs, sess.answer_logits(*p),
                            &mut recycled, &mut fresh);
            }
            (JointKind::Retrieval, JointSlot::Pair(p)) => {
                let (i, j) = pairs[*p];
                respond_f32(pool, outs, &[sess.score(i, j)],
                            &mut recycled, &mut fresh);
            }
            (JointKind::Vqa, JointSlot::Vis(i)) => {
                respond_f32(pool, outs, sess.image_feature(*i),
                            &mut recycled, &mut fresh);
            }
            (JointKind::Retrieval, JointSlot::Vis(i)) => {
                respond_f32(pool, outs, sess.image_embed(*i),
                            &mut recycled, &mut fresh);
            }
            (JointKind::Vqa, JointSlot::Txt(j)) => {
                respond_f32(pool, outs, sess.text_feature(*j),
                            &mut recycled, &mut fresh);
            }
            (JointKind::Retrieval, JointSlot::Txt(j)) => {
                respond_f32(pool, outs, sess.text_embed(*j),
                            &mut recycled, &mut fresh);
            }
        }
    }
    metrics.record_responses(recycled, fresh);
    Ok(())
}

/// What each gallery-batch request gets answered with (index into the
/// session's vision or text half, plus the query's `k`).
enum GallerySlot {
    /// ingest of the image embedding at vision slot `vi`
    IngestVis(usize),
    /// ingest of the caption embedding at text slot `ti`
    IngestTxt(usize),
    /// query probing with the image embedding at vision slot `vi`
    QueryVis(usize, usize),
    /// query probing with the caption embedding at text slot `ti`
    QueryTxt(usize, usize),
}

/// Build one single-tensor response with an explicit shape from a
/// recycled pool buffer (the gallery query's `(hits, 2)` layout).
fn respond_f32_shaped(pool: &Arc<TensorPool>, outs: &mut Vec<InferOutputs>,
                      data: &[f32], shape: &[usize],
                      recycled: &mut u64, fresh: &mut u64) {
    let mut t = pool.take_f32(data.len().max(1));
    if t.recycled() {
        *recycled += 1;
    } else {
        *fresh += 1;
    }
    t.fill_f32(data, shape);
    outs.push(InferOutputs::One(t));
}

/// Execute a mixed gallery batch through the worker's long-lived
/// retrieval [`JointSession`]: every request's tensor is filed into
/// the tower matching its dtype (f32 patches → image tower, i32 token
/// ids → text tower), both towers run once over the ragged halves,
/// ingests append their normalized embedding to the shared store
/// *before* any query scans (a query observes every ingest that
/// shared its batch), then each query scans the store through the
/// worker's reusable [`GalleryScratch`].  Ingests answer
/// `[id, gallery_len]`; queries answer a `(hits, 2)` tensor of
/// `[id, score]` rows.
///
/// The inference region spans parse → embed → ingest; scans and
/// responses land in the whole-cycle allocation count.  A warmed
/// query-only batch allocates nothing in either region
/// (`tests/alloc_free.rs`); ingest batches may grow the store's
/// append-only segments, which is the documented cold path.
// lint: allow(alloc) reason=error-path format! only, never taken on the steady-state path
#[allow(clippy::too_many_arguments)]
fn cpu_run_gallery_batch(sess: &mut JointSession, store: &Arc<GalleryStore>,
                         metrics: &Metrics, pool: &Arc<TensorPool>,
                         batch: &[InferRequest],
                         outs: &mut Vec<InferOutputs>,
                         slots: &mut Vec<GallerySlot>, ids: &mut Vec<u64>,
                         scratch: &mut GalleryScratch, hits: &mut Vec<Hit>,
                         flat: &mut Vec<f32>, workers: usize) -> Result<()> {
    let before = allocs_this_thread();
    sess.reset_merge_telemetry();
    let t_embed = sess.recorder().map(|r| r.now_us());
    slots.clear();
    ids.clear();
    // pass 1: size the ragged halves by payload dtype
    let (mut bv, mut bt) = (0usize, 0usize);
    for (ri, req) in batch.iter().enumerate() {
        let (t, k) = match &req.payload {
            Payload::GalleryIngest(t) => (t, None),
            Payload::GalleryQuery { probe, k } => (probe, Some(*k)),
            _ => {
                return Err(Error::Coordinator(format!(
                    "gallery worker: request {ri} carries a non-gallery \
                     payload")))
            }
        };
        let vision = matches!(t.tensor(), HostTensor::F32(..));
        let slot = match (vision, k) {
            (true, None) => {
                bv += 1;
                GallerySlot::IngestVis(bv - 1)
            }
            (false, None) => {
                bt += 1;
                GallerySlot::IngestTxt(bt - 1)
            }
            (true, Some(k)) => {
                bv += 1;
                GallerySlot::QueryVis(bv - 1, k)
            }
            (false, Some(k)) => {
                bt += 1;
                GallerySlot::QueryTxt(bt - 1, k)
            }
        };
        slots.push(slot);
    }
    sess.begin(bv, bt);
    // pass 2: file each tensor into its tower slot
    for (ri, (req, slot)) in batch.iter().zip(slots.iter()).enumerate() {
        let t = match &req.payload {
            Payload::GalleryIngest(t) => t,
            Payload::GalleryQuery { probe, .. } => probe,
            _ => {
                return Err(Error::Coordinator(format!(
                    "gallery worker: request {ri} changed payload class")))
            }
        };
        match slot {
            GallerySlot::IngestVis(vi) | GallerySlot::QueryVis(vi, _) => {
                sess.set_patches_slice(*vi, t.as_f32()?)?;
            }
            GallerySlot::IngestTxt(ti) | GallerySlot::QueryTxt(ti, _) => {
                sess.set_text(*ti, t.as_i32()?)?;
            }
        }
    }
    if let Some(r) = sess.recorder() {
        r.span_since(Stage::Embed, 0, t_embed.unwrap_or(0),
                     batch.len() as u32);
    }
    // both towers once, then the retrieval projection
    sess.forward(0)?;
    sess.project()?;
    // ingests first, so queries in this batch observe them
    for slot in slots.iter() {
        let id = match slot {
            GallerySlot::IngestVis(vi) => store.ingest(sess.image_embed(*vi))?,
            GallerySlot::IngestTxt(ti) => store.ingest(sess.text_embed(*ti))?,
            GallerySlot::QueryVis(..) | GallerySlot::QueryTxt(..) => 0,
        };
        ids.push(id);
    }
    metrics.record_infer_allocs(allocs_this_thread() - before);
    // queries scan, everything answers from the recycled pool
    let (mut rows, mut evictions, mut scan_us) = (0u64, 0u64, 0u64);
    let (mut recycled, mut fresh) = (0u64, 0u64);
    for (si, slot) in slots.iter().enumerate() {
        let (probe, k) = match slot {
            GallerySlot::IngestVis(_) | GallerySlot::IngestTxt(_) => {
                respond_f32(pool, outs,
                            &[ids[si] as f32, store.len() as f32],
                            &mut recycled, &mut fresh);
                continue;
            }
            GallerySlot::QueryVis(vi, k) => (sess.image_embed(*vi), *k),
            GallerySlot::QueryTxt(ti, k) => (sess.text_embed(*ti), *k),
        };
        let scan_start = Instant::now();
        let stats =
            scan_into(store, probe, k, ScanMode::Dot, workers, scratch, hits)?;
        scan_us += scan_start.elapsed().as_micros() as u64;
        rows += stats.rows;
        evictions += stats.evictions;
        flat.clear();
        for h in hits.iter() {
            flat.push(h.id as f32);
            flat.push(h.score);
        }
        respond_f32_shaped(pool, outs, flat, &[hits.len(), 2],
                           &mut recycled, &mut fresh);
    }
    // unconditional: the gallery_len gauge must track ingest-only
    // batches too; the cumulative counters just add zero for them
    metrics.record_gallery(store.len() as u64, rows, evictions, scan_us);
    metrics.record_responses(recycled, fresh);
    Ok(())
}

/// Stack per-request inputs into the artifact batch, execute, split.
// lint: allow(alloc) reason=PJRT transport path stacks/splits host tensors by design; zero-alloc serving is the CPU path
fn run_batch(exe: &Executable, params: &[f32], batch: &[InferRequest])
             -> Result<Vec<Vec<HostTensor>>> {
    let entry = &exe.entry;
    let b_art = entry.meta.batch;
    if batch.len() > b_art {
        return Err(Error::Coordinator(format!(
            "batch {} exceeds artifact batch {}", batch.len(), b_art)));
    }
    let n_sample_inputs = entry.inputs.len() - 1; // first input = params
    let mut full_inputs: Vec<HostTensor> = Vec::with_capacity(entry.inputs.len());
    full_inputs.push(HostTensor::F32(params.to_vec(),
                                     entry.inputs[0].shape.clone()));
    let first_inputs = batch[0].payload.artifact_tensors()?;
    for si in 0..n_sample_inputs {
        let spec = &entry.inputs[si + 1];
        let per = spec.numel() / b_art;
        match &first_inputs[si] {
            HostTensor::F32(..) => {
                let mut data = Vec::with_capacity(spec.numel());
                for bi in 0..b_art {
                    let req = &batch[bi.min(batch.len() - 1)];
                    let d = match &req.payload.artifact_tensors()?[si] {
                        HostTensor::F32(d, _) => d,
                        _ => return Err(Error::Shape("dtype mix in batch".into())),
                    };
                    if d.len() != per {
                        return Err(Error::Shape(format!(
                            "sample input {si}: {} elems, artifact wants {per}",
                            d.len())));
                    }
                    data.extend_from_slice(d);
                }
                full_inputs.push(HostTensor::F32(data, spec.shape.clone()));
            }
            HostTensor::I32(..) => {
                let mut data = Vec::with_capacity(spec.numel());
                for bi in 0..b_art {
                    let req = &batch[bi.min(batch.len() - 1)];
                    let d = match &req.payload.artifact_tensors()?[si] {
                        HostTensor::I32(d, _) => d,
                        _ => return Err(Error::Shape("dtype mix in batch".into())),
                    };
                    data.extend_from_slice(d);
                }
                full_inputs.push(HostTensor::I32(data, spec.shape.clone()));
            }
        }
    }
    let outputs = exe.run(&full_inputs)?;
    // split each output along the batch axis
    let mut per_request: Vec<Vec<HostTensor>> =
        (0..batch.len()).map(|_| Vec::new()).collect();
    for (out, spec) in outputs.iter().zip(&entry.outputs) {
        let per = spec.numel() / b_art;
        let sample_shape: Vec<usize> = if spec.shape.len() > 1 {
            spec.shape[1..].to_vec()
        } else {
            vec![1]
        };
        for (bi, sink) in per_request.iter_mut().enumerate() {
            let t = match out {
                HostTensor::F32(d, _) => HostTensor::F32(
                    d[bi * per..(bi + 1) * per].to_vec(), sample_shape.clone()),
                HostTensor::I32(d, _) => HostTensor::I32(
                    d[bi * per..(bi + 1) * per].to_vec(), sample_shape.clone()),
            };
            sink.push(t);
        }
    }
    Ok(per_request)
}

#[cfg(test)]
mod tests {
    use std::sync::mpsc;

    use super::super::request::{Responder, ResponseSlot};
    use super::*;

    fn one_output(outs: &mut Vec<InferOutputs>) {
        outs.push(InferOutputs::One(PooledTensor::detached(
            HostTensor::F32(vec![0.0], vec![1]))));
    }

    /// Worker whose exec answers every request with a dummy tensor.
    fn noop_worker(cfg: &ServingConfig) -> VariantWorker {
        VariantWorker::spawn_worker(
            "test-noop".to_string(), cfg, cfg.max_batch, None,
            |_m: &Arc<Metrics>, _rec: Option<&RingWriter>| {
                Some(|batch: &[InferRequest],
                      outs: &mut Vec<InferOutputs>| {
                    for _ in batch {
                        one_output(outs);
                    }
                    Ok(())
                })
            })
    }

    fn slot_request(slot: &ResponseSlot, deadline: Option<Instant>)
                    -> InferRequest {
        InferRequest {
            payload: Payload::Tensors(Vec::new()),
            enqueued_at: Instant::now(),
            deadline,
            respond: Responder::Slot(slot.sender()),
        }
    }

    /// Regression for the `depth < capacity / 2` headroom test: with
    /// `queue_capacity = 1` the old threshold was 0, so an idle worker
    /// reported no capacity and Balanced routing permanently shed.
    #[test]
    fn capacity_one_queue_reports_headroom_when_idle() {
        let cfg = ServingConfig {
            max_batch: 1,
            batch_timeout_us: 100,
            queue_capacity: 1,
            workers: 1,
            trace_capacity: 0,
        };
        let w = noop_worker(&cfg);
        assert!(w.has_capacity(),
                "idle capacity-1 worker must report headroom");
    }

    /// A full queue sheds without blocking the submitter, and the shed
    /// is counted in the worker's metrics.
    #[test]
    fn full_queue_sheds_nonblocking_and_counts() {
        let cfg = ServingConfig {
            max_batch: 1,
            batch_timeout_us: 100,
            queue_capacity: 2,
            workers: 1,
            trace_capacity: 0,
        };
        let (started_tx, started_rx) = mpsc::channel::<()>();
        let (release_tx, release_rx) = mpsc::channel::<()>();
        let w = VariantWorker::spawn_worker(
            "test-gated".to_string(), &cfg, cfg.max_batch, None,
            move |_m: &Arc<Metrics>, _rec: Option<&RingWriter>| {
                Some(move |batch: &[InferRequest],
                           outs: &mut Vec<InferOutputs>| {
                    let _ = started_tx.send(());
                    let _ = release_rx.recv();
                    for _ in batch {
                        one_output(outs);
                    }
                    Ok(())
                })
            });
        let slot = ResponseSlot::new(8);
        // first request: picked up by the worker, which then blocks in
        // exec until released — the queue itself is empty again
        w.submit(slot_request(&slot, None)).unwrap();
        started_rx.recv().unwrap();
        // fill the 2-slot queue behind the blocked worker
        assert_eq!(w.submit_shed(slot_request(&slot, None)).unwrap(),
                   Admission::Admitted);
        assert_eq!(w.submit_shed(slot_request(&slot, None)).unwrap(),
                   Admission::Admitted);
        // queue full: shed, without blocking this thread
        assert_eq!(w.submit_shed(slot_request(&slot, None)).unwrap(),
                   Admission::Shed);
        assert_eq!(w.metrics.snapshot().shed, 1);
        // release the three admitted batches and drain their responses
        for _ in 0..3 {
            release_tx.send(()).unwrap();
        }
        for _ in 0..3 {
            slot.recv().expect("admitted request must answer");
        }
    }

    /// Deadline-expired requests are dropped before execution, counted,
    /// and answered with an expiry marker — never a hang, and never a
    /// silent drop.
    #[test]
    fn expired_requests_are_counted_and_answered_with_markers() {
        let cfg = ServingConfig {
            max_batch: 4,
            batch_timeout_us: 100,
            queue_capacity: 8,
            workers: 1,
            trace_capacity: 0,
        };
        let w = noop_worker(&cfg);
        let slot = ResponseSlot::new(8);
        // already-expired deadline: the worker must drop it pre-exec
        w.submit(slot_request(&slot, Some(Instant::now()))).unwrap();
        let err = slot.recv().expect_err("expired request must error");
        assert!(err.to_string().contains("deadline"),
                "unexpected error: {err}");
        assert_eq!(w.metrics.snapshot().expired, 1);
        // the worker keeps serving after dropping an expired batch
        w.submit(slot_request(&slot, None)).unwrap();
        slot.recv().expect("live request must answer");
    }

    /// Earliest-deadline-first ordering: a tight-deadline request
    /// enqueued *behind* a full batch of deadline-less requests is
    /// promoted into the next executing batch instead of waiting its
    /// FIFO turn (and possibly expiring mid-queue).
    #[test]
    fn tight_deadline_request_is_promoted_past_a_full_batch() {
        let cfg = ServingConfig {
            max_batch: 2,
            batch_timeout_us: 100,
            queue_capacity: 8,
            workers: 1,
            trace_capacity: 0,
        };
        let (started_tx, started_rx) = mpsc::channel::<()>();
        let (release_tx, release_rx) = mpsc::channel::<()>();
        let w = VariantWorker::spawn_worker(
            "test-edf".to_string(), &cfg, cfg.max_batch, None,
            move |_m: &Arc<Metrics>, _rec: Option<&RingWriter>| {
                Some(move |batch: &[InferRequest],
                           outs: &mut Vec<InferOutputs>| {
                    let _ = started_tx.send(());
                    let _ = release_rx.recv();
                    for _ in batch {
                        one_output(outs);
                    }
                    Ok(())
                })
            });
        let bulk = ResponseSlot::new(8);
        let urgent = ResponseSlot::new(8);
        // occupy the worker so everything below queues up behind it
        w.submit(slot_request(&bulk, None)).unwrap();
        started_rx.recv().unwrap();
        // a full batch of deadline-less requests, then the deadlined one
        // last — strict FIFO would execute it in the *third* batch
        for _ in 0..3 {
            w.submit(slot_request(&bulk, None)).unwrap();
        }
        let deadline = Instant::now() + Duration::from_secs(60);
        w.submit(slot_request(&urgent, Some(deadline))).unwrap();
        // run the three batches to completion
        release_tx.send(()).unwrap(); // batch 1: the occupier
        started_rx.recv().unwrap();
        release_tx.send(()).unwrap(); // batch 2: must contain `urgent`
        started_rx.recv().unwrap();
        release_tx.send(()).unwrap(); // batch 3: the remaining two
        let r = urgent.recv().expect("deadlined request must answer");
        assert_eq!(r.batch_size, 2,
                   "deadlined request must ride the first post-occupier \
                    batch (EDF promotion), not its FIFO slot");
        for _ in 0..4 {
            bulk.recv().expect("deadline-less request must answer");
        }
        assert_eq!(w.metrics.snapshot().expired, 0,
                   "nothing expired: the deadline was generous, only the \
                    ordering changed");
    }

    /// Fairness floor under EDF: the globally oldest pending request
    /// always rides the executing batch, so a continuous stream of
    /// deadlined traffic cannot starve a deadline-less request that is
    /// carried over in the worker's pending set.
    #[test]
    fn oldest_deadline_less_request_is_not_starved_by_deadlined_traffic() {
        let cfg = ServingConfig {
            max_batch: 1,
            batch_timeout_us: 100,
            queue_capacity: 8,
            workers: 1,
            trace_capacity: 0,
        };
        let (started_tx, started_rx) = mpsc::channel::<()>();
        let (release_tx, release_rx) = mpsc::channel::<()>();
        let w = VariantWorker::spawn_worker(
            "test-fairness".to_string(), &cfg, cfg.max_batch, None,
            move |_m: &Arc<Metrics>, _rec: Option<&RingWriter>| {
                Some(move |batch: &[InferRequest],
                           outs: &mut Vec<InferOutputs>| {
                    let _ = started_tx.send(());
                    let _ = release_rx.recv();
                    for _ in batch {
                        one_output(outs);
                    }
                    Ok(())
                })
            });
        let deadlined = ResponseSlot::new(8);
        let patient = ResponseSlot::new(8);
        // occupy the worker so everything below queues up behind it
        w.submit(slot_request(&deadlined, None)).unwrap();
        started_rx.recv().unwrap();
        // the deadline-less request is enqueued first, then buried under
        // deadlined traffic that pure EDF would always order ahead of it
        w.submit(slot_request(&patient, None)).unwrap();
        let d = Instant::now() + Duration::from_secs(60);
        for _ in 0..4 {
            w.submit(slot_request(&deadlined, Some(d))).unwrap();
        }
        release_tx.send(()).unwrap(); // batch 1: the occupier
        started_rx.recv().unwrap();
        release_tx.send(()).unwrap(); // batch 2: must be `patient`
        patient.recv().expect(
            "oldest (deadline-less) request must ride the first \
             post-occupier batch instead of being bypassed by EDF");
        // drain the four deadlined batches
        for _ in 0..4 {
            started_rx.recv().unwrap();
            release_tx.send(()).unwrap();
        }
        for _ in 0..5 {
            deadlined.recv().expect("deadlined request must answer");
        }
    }

    /// End-to-end worker tracing: with an [`ObsHub`] attached, a served
    /// batch leaves a reconstructable gather → sort → queue-wait → exec →
    /// respond span sequence in the worker's ring, attributed to the
    /// worker's name.
    #[test]
    fn hub_attached_worker_records_batch_spans() {
        let cfg = ServingConfig {
            max_batch: 4,
            batch_timeout_us: 100,
            queue_capacity: 8,
            workers: 1,
            trace_capacity: 256,
        };
        let hub = ObsHub::new(cfg.trace_capacity);
        let w = VariantWorker::spawn_worker(
            "test-traced".to_string(), &cfg, cfg.max_batch, Some(&hub),
            |_m: &Arc<Metrics>, rec: Option<&RingWriter>| {
                assert!(rec.is_some(), "hub must hand the worker a recorder");
                Some(|batch: &[InferRequest],
                      outs: &mut Vec<InferOutputs>| {
                    for _ in batch {
                        one_output(outs);
                    }
                    Ok(())
                })
            });
        let slot = ResponseSlot::new(8);
        w.submit(slot_request(&slot, None)).unwrap();
        slot.recv().expect("traced request must answer");
        drop(w); // join the worker so every span is published
        let threads = hub.drain();
        let t = threads.iter().find(|t| t.name == "test-traced")
            .expect("worker ring must be registered under its name");
        assert_eq!(t.dropped, 0);
        for s in [Stage::BatchGather, Stage::EdfSort, Stage::QueueWait,
                  Stage::Exec, Stage::Respond] {
            assert!(t.events.iter().any(|e| e.stage == s),
                    "missing {} span", s.name());
        }
        let qw = t.events.iter().find(|e| e.stage == Stage::QueueWait)
            .unwrap();
        assert!(qw.t_end_us >= qw.t_start_us,
                "queue-wait span must not run backwards");
    }
}
