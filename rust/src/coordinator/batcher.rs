//! Dynamic batcher: one worker thread per model variant, collecting
//! requests up to `max_batch` or `batch_timeout_us`, executing the batch,
//! and splitting the outputs back per request.
//!
//! Two execution backends share the same batching loop:
//! * **PJRT** ([`VariantWorker::spawn`]) — pads the batch to the
//!   artifact's compiled batch size and executes the HLO artifact.
//! * **CPU reference** ([`VariantWorker::spawn_cpu`]) — runs the pure-Rust
//!   ViT through an engine [`VitSession`] the worker holds for its whole
//!   lifetime: weights are resolved once at boot (never per batch), and
//!   every buffer a request touches — input slots, encoder scratch,
//!   final-norm outputs, logits — is pooled in the session, so a warmed
//!   worker's inference region performs **zero** heap allocations per
//!   request (tracked per batch in
//!   [`Snapshot::last_infer_allocs`](super::metrics::Snapshot), asserted
//!   by `tests/alloc_free.rs`).  Needs no artifacts, so serving works
//!   even before `make artifacts`.
//!
//! Built on std sync primitives (DESIGN.md §11): a bounded
//! `mpsc::sync_channel` is the admission-control boundary; `recv_timeout`
//! implements the batching deadline without spinning.

use std::cell::RefCell;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{Receiver, RecvTimeoutError, SyncSender, TrySendError};
use std::sync::Arc;
use std::time::{Duration, Instant};

use std::path::PathBuf;

use crate::config::{ServingConfig, ViTConfig};
use crate::engine::{Engine, VitSession};
use crate::error::{Error, Result};
use crate::runtime::{ArtifactEntry, Engine as PjrtEngine, Executable,
                     HostTensor};
use crate::util::alloc::allocs_this_thread;

use super::metrics::Metrics;
use super::request::InferRequest;

/// Handle to a running variant worker.
pub struct VariantWorker {
    tx: SyncSender<InferRequest>,
    /// shared metrics
    pub metrics: Arc<Metrics>,
    /// approximate queued-request count (admission signal)
    depth: Arc<AtomicUsize>,
    /// queue capacity
    pub capacity: usize,
    join: Option<std::thread::JoinHandle<()>>,
}

impl VariantWorker {
    /// Shared worker bootstrap: channel, metrics, depth counter, thread.
    /// `init` runs on the worker thread (handed the worker's metrics
    /// sink) and produces the batch-execution closure (returning `None`
    /// aborts the worker, e.g. when PJRT is unavailable — submitters then
    /// observe a closed queue).
    fn spawn_worker<E, I>(name: String, cfg: &ServingConfig, max_batch: usize,
                          init: I) -> VariantWorker
    where
        E: Fn(&[InferRequest]) -> Result<Vec<Vec<HostTensor>>> + 'static,
        I: FnOnce(&Arc<Metrics>) -> Option<E> + Send + 'static,
    {
        let (tx, rx) = std::sync::mpsc::sync_channel::<InferRequest>(cfg.queue_capacity);
        let metrics = Arc::new(Metrics::default());
        let depth = Arc::new(AtomicUsize::new(0));
        let m2 = metrics.clone();
        let d2 = depth.clone();
        let timeout = Duration::from_micros(cfg.batch_timeout_us);
        let join = std::thread::Builder::new()
            .name(name)
            .spawn(move || {
                let Some(exec) = init(&m2) else { return };
                worker_loop(exec, rx, m2, d2, max_batch, timeout)
            })
            .expect("spawn worker");
        VariantWorker {
            tx,
            metrics,
            depth,
            capacity: cfg.queue_capacity,
            join: Some(join),
        }
    }

    /// Spawn a worker that compiles `hlo_path` on its own PJRT client
    /// (PJRT handles are not Send; per-thread clients keep this safe) and
    /// serves batches.  `params` is the artifact's leading flat-weights
    /// input (empty vec for artifacts without params).
    pub fn spawn(hlo_path: PathBuf, entry: ArtifactEntry, params: Vec<f32>,
                 cfg: &ServingConfig) -> VariantWorker {
        let max_batch = cfg.max_batch.min(entry.meta.batch);
        let name = format!("pitome-worker-{}", entry.file);
        Self::spawn_worker(name, cfg, max_batch, move |_metrics: &Arc<Metrics>| {
            let engine = match PjrtEngine::cpu() {
                Ok(e) => e,
                Err(e) => {
                    eprintln!("[pitome worker] PJRT client failed: {e}");
                    return None;
                }
            };
            let exe = match engine.compile_file(&hlo_path, entry) {
                Ok(e) => e,
                Err(e) => {
                    eprintln!("[pitome worker] compile failed: {e}");
                    return None;
                }
            };
            Some(move |batch: &[InferRequest]| {
                // the client must outlive its executable
                let _ = &engine;
                run_batch(&exe, &params, batch)
            })
        })
    }

    /// Spawn a worker that serves the pure-Rust CPU reference ViT (no
    /// PJRT artifacts required).  Requests carry a single f32 patches
    /// tensor `(n_patches, patch_dim)`; responses carry the class logits.
    /// Each collected batch runs through the worker's [`VitSession`],
    /// whose encoder fan-out uses `cfg.workers` threads.
    pub fn spawn_cpu(engine: Arc<Engine>, model_cfg: ViTConfig,
                     cfg: &ServingConfig) -> VariantWorker {
        let max_batch = cfg.max_batch;
        let workers = cfg.workers.max(1);
        let name = format!("pitome-cpu-{}-r{:.0}",
                           model_cfg.merge_mode, model_cfg.merge_r * 1000.0);
        Self::spawn_worker(name, cfg, max_batch, move |metrics: &Arc<Metrics>| {
            // one session per variant worker, alive for the worker's
            // whole lifetime: weights resolve once here (the engine cache
            // shares the resolution across equal-config workers) and
            // never again, and after the first batch warms the pools,
            // steady-state inference allocates nothing (the worker loop
            // is single-threaded, so the RefCell is never contended)
            let mut sess = match engine.vit_session(&model_cfg) {
                Ok(s) => s,
                Err(e) => {
                    eprintln!("[pitome worker] session init failed: {e}");
                    return None;
                }
            };
            sess.set_workers(workers);
            let sess = RefCell::new(sess);
            let metrics = metrics.clone();
            Some(move |batch: &[InferRequest]| {
                cpu_run_batch(&mut sess.borrow_mut(), &metrics, batch)
            })
        })
    }

    /// Blocking submit (backpressure by blocking on the bounded queue).
    pub fn submit(&self, req: InferRequest) -> Result<()> {
        self.depth.fetch_add(1, Ordering::Relaxed);
        self.tx.send(req).map_err(|_| {
            self.depth.fetch_sub(1, Ordering::Relaxed);
            Error::Coordinator("worker queue closed".into())
        })
    }

    /// Non-blocking submit; `Err` when the queue is full (admission
    /// control) or closed.
    pub fn try_submit(&self, req: InferRequest) -> Result<()> {
        self.depth.fetch_add(1, Ordering::Relaxed);
        self.tx.try_send(req).map_err(|e| {
            self.depth.fetch_sub(1, Ordering::Relaxed);
            match e {
                TrySendError::Full(_) => Error::Coordinator("queue full (backpressure)".into()),
                TrySendError::Disconnected(_) => Error::Coordinator("worker queue closed".into()),
            }
        })
    }

    /// Queue headroom signal used by the router's load-shedding policy.
    pub fn has_capacity(&self) -> bool {
        self.depth.load(Ordering::Relaxed) < self.capacity / 2
    }

    /// Current approximate depth.
    pub fn depth(&self) -> usize {
        self.depth.load(Ordering::Relaxed)
    }
}

impl Drop for VariantWorker {
    fn drop(&mut self) {
        let (dead_tx, _) = std::sync::mpsc::sync_channel(1);
        drop(std::mem::replace(&mut self.tx, dead_tx));
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

/// Shared batching loop: collect up to `max_batch` requests (or until the
/// deadline), run them through `exec`, and fan the responses back out.
fn worker_loop<E>(exec: E, rx: Receiver<InferRequest>, metrics: Arc<Metrics>,
                  depth: Arc<AtomicUsize>, max_batch: usize, timeout: Duration)
where
    E: Fn(&[InferRequest]) -> Result<Vec<Vec<HostTensor>>>,
{
    loop {
        let first = match rx.recv() {
            Ok(r) => r,
            Err(_) => return,
        };
        let mut batch = vec![first];
        let deadline = Instant::now() + timeout;
        while batch.len() < max_batch {
            let remaining = deadline.saturating_duration_since(Instant::now());
            if remaining.is_zero() {
                break;
            }
            match rx.recv_timeout(remaining) {
                Ok(r) => batch.push(r),
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }
        depth.fetch_sub(batch.len(), Ordering::Relaxed);
        let exec_start = Instant::now();
        let result = exec(&batch);
        let exec_us = exec_start.elapsed().as_micros() as u64;
        let batch_size = batch.len();
        metrics.record_batch(batch_size);
        match result {
            Ok(per_request) => {
                for (req, outputs) in batch.into_iter().zip(per_request) {
                    let queue_us =
                        exec_start.duration_since(req.enqueued_at).as_micros() as u64;
                    metrics.record(queue_us + exec_us);
                    let _ = req.respond.send(super::request::InferResponse {
                        outputs,
                        queue_us,
                        exec_us,
                        batch_size,
                    });
                }
            }
            Err(e) => {
                eprintln!("[pitome worker] batch failed: {e}");
                // responders dropped; submitters observe a closed channel
            }
        }
    }
}

/// Execute a batch on the CPU reference ViT through the worker's
/// long-lived [`VitSession`]: parse each request's patches tensor into a
/// pooled slot, run embed + encoder + head, and return one logits tensor
/// per request.
///
/// The span from the first parse through `forward` — everything except
/// materializing the owned response tensors handed to the submitter's
/// channel — is the *inference region*; its allocation count is recorded
/// per batch ([`Metrics::record_infer_allocs`]) and must be zero for a
/// warmed worker (`tests/alloc_free.rs`).
fn cpu_run_batch(sess: &mut VitSession, metrics: &Metrics,
                 batch: &[InferRequest]) -> Result<Vec<Vec<HostTensor>>> {
    let before = allocs_this_thread();
    // exact-shape admission: a malformed request must become an error (the
    // responders are dropped, submitters see a closed channel), never a
    // panic that would kill the worker thread for every later request
    let (want_rows, want_cols) =
        (sess.cfg().num_patches(), sess.cfg().patch_dim());
    sess.begin(batch.len());
    for (i, req) in batch.iter().enumerate() {
        let t = req.inputs.first().ok_or_else(|| {
            Error::Coordinator(format!("cpu worker: request {i} has no inputs"))
        })?;
        let d = t.as_f32()?;
        let shape = t.shape();
        if shape != [want_rows, want_cols] || d.len() != want_rows * want_cols {
            return Err(Error::Shape(format!(
                "cpu worker: request {i} patches shape {shape:?} != \
                 expected ({want_rows}, {want_cols})")));
        }
        sess.set_patches_slice(i, d)?;
    }
    sess.forward(0)?;
    metrics.record_infer_allocs(allocs_this_thread() - before);
    // transport boundary: the response tensors are owned by the submitter
    // and cross a channel, so they are allocated (outside the zero-alloc
    // guarantee, which covers everything the model computes)
    Ok((0..batch.len())
        .map(|i| {
            let lg = sess.logits(i);
            vec![HostTensor::F32(lg.to_vec(), vec![lg.len()])]
        })
        .collect())
}

/// Stack per-request inputs into the artifact batch, execute, split.
fn run_batch(exe: &Executable, params: &[f32], batch: &[InferRequest])
             -> Result<Vec<Vec<HostTensor>>> {
    let entry = &exe.entry;
    let b_art = entry.meta.batch;
    if batch.len() > b_art {
        return Err(Error::Coordinator(format!(
            "batch {} exceeds artifact batch {}", batch.len(), b_art)));
    }
    let n_sample_inputs = entry.inputs.len() - 1; // first input = params
    let mut full_inputs: Vec<HostTensor> = Vec::with_capacity(entry.inputs.len());
    full_inputs.push(HostTensor::F32(params.to_vec(),
                                     entry.inputs[0].shape.clone()));
    for si in 0..n_sample_inputs {
        let spec = &entry.inputs[si + 1];
        let per = spec.numel() / b_art;
        match &batch[0].inputs[si] {
            HostTensor::F32(..) => {
                let mut data = Vec::with_capacity(spec.numel());
                for bi in 0..b_art {
                    let req = &batch[bi.min(batch.len() - 1)];
                    let d = match &req.inputs[si] {
                        HostTensor::F32(d, _) => d,
                        _ => return Err(Error::Shape("dtype mix in batch".into())),
                    };
                    if d.len() != per {
                        return Err(Error::Shape(format!(
                            "sample input {si}: {} elems, artifact wants {per}",
                            d.len())));
                    }
                    data.extend_from_slice(d);
                }
                full_inputs.push(HostTensor::F32(data, spec.shape.clone()));
            }
            HostTensor::I32(..) => {
                let mut data = Vec::with_capacity(spec.numel());
                for bi in 0..b_art {
                    let req = &batch[bi.min(batch.len() - 1)];
                    let d = match &req.inputs[si] {
                        HostTensor::I32(d, _) => d,
                        _ => return Err(Error::Shape("dtype mix in batch".into())),
                    };
                    data.extend_from_slice(d);
                }
                full_inputs.push(HostTensor::I32(data, spec.shape.clone()));
            }
        }
    }
    let outputs = exe.run(&full_inputs)?;
    // split each output along the batch axis
    let mut per_request: Vec<Vec<HostTensor>> =
        (0..batch.len()).map(|_| Vec::new()).collect();
    for (out, spec) in outputs.iter().zip(&entry.outputs) {
        let per = spec.numel() / b_art;
        let sample_shape: Vec<usize> = if spec.shape.len() > 1 {
            spec.shape[1..].to_vec()
        } else {
            vec![1]
        };
        for (bi, sink) in per_request.iter_mut().enumerate() {
            let t = match out {
                HostTensor::F32(d, _) => HostTensor::F32(
                    d[bi * per..(bi + 1) * per].to_vec(), sample_shape.clone()),
                HostTensor::I32(d, _) => HostTensor::I32(
                    d[bi * per..(bi + 1) * per].to_vec(), sample_shape.clone()),
            };
            sink.push(t);
        }
    }
    Ok(per_request)
}
