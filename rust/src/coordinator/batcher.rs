//! Dynamic batcher: one worker thread per model variant, collecting
//! requests up to `max_batch` or `batch_timeout_us`, padding the batch to
//! the artifact's compiled batch size, executing on PJRT, and splitting the
//! outputs back per request.
//!
//! Built on std sync primitives (DESIGN.md §11): a bounded
//! `mpsc::sync_channel` is the admission-control boundary; `recv_timeout`
//! implements the batching deadline without spinning.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{Receiver, RecvTimeoutError, SyncSender, TrySendError};
use std::sync::Arc;
use std::time::{Duration, Instant};

use std::path::PathBuf;

use crate::config::ServingConfig;
use crate::error::{Error, Result};
use crate::runtime::{ArtifactEntry, Engine, Executable, HostTensor};

use super::metrics::Metrics;
use super::request::InferRequest;

/// Handle to a running variant worker.
pub struct VariantWorker {
    tx: SyncSender<InferRequest>,
    /// shared metrics
    pub metrics: Arc<Metrics>,
    /// approximate queued-request count (admission signal)
    depth: Arc<AtomicUsize>,
    /// queue capacity
    pub capacity: usize,
    join: Option<std::thread::JoinHandle<()>>,
}

impl VariantWorker {
    /// Spawn a worker that compiles `hlo_path` on its own PJRT client
    /// (PJRT handles are not Send; per-thread clients keep this safe) and
    /// serves batches.  `params` is the artifact's leading flat-weights
    /// input (empty vec for artifacts without params).
    pub fn spawn(hlo_path: PathBuf, entry: ArtifactEntry, params: Vec<f32>,
                 cfg: &ServingConfig) -> VariantWorker {
        let (tx, rx) = std::sync::mpsc::sync_channel::<InferRequest>(cfg.queue_capacity);
        let metrics = Arc::new(Metrics::default());
        let depth = Arc::new(AtomicUsize::new(0));
        let m2 = metrics.clone();
        let d2 = depth.clone();
        let max_batch = cfg.max_batch.min(entry.meta.batch);
        let timeout = Duration::from_micros(cfg.batch_timeout_us);
        let join = std::thread::Builder::new()
            .name(format!("pitome-worker-{}", entry.file))
            .spawn(move || {
                let engine = match Engine::cpu() {
                    Ok(e) => e,
                    Err(e) => {
                        eprintln!("[pitome worker] PJRT client failed: {e}");
                        return;
                    }
                };
                let exe = match engine.compile_file(&hlo_path, entry) {
                    Ok(e) => e,
                    Err(e) => {
                        eprintln!("[pitome worker] compile failed: {e}");
                        return;
                    }
                };
                worker_loop(exe, params, rx, m2, d2, max_batch, timeout)
            })
            .expect("spawn worker");
        VariantWorker {
            tx,
            metrics,
            depth,
            capacity: cfg.queue_capacity,
            join: Some(join),
        }
    }

    /// Blocking submit (backpressure by blocking on the bounded queue).
    pub fn submit(&self, req: InferRequest) -> Result<()> {
        self.depth.fetch_add(1, Ordering::Relaxed);
        self.tx.send(req).map_err(|_| {
            self.depth.fetch_sub(1, Ordering::Relaxed);
            Error::Coordinator("worker queue closed".into())
        })
    }

    /// Non-blocking submit; `Err` when the queue is full (admission
    /// control) or closed.
    pub fn try_submit(&self, req: InferRequest) -> Result<()> {
        self.depth.fetch_add(1, Ordering::Relaxed);
        self.tx.try_send(req).map_err(|e| {
            self.depth.fetch_sub(1, Ordering::Relaxed);
            match e {
                TrySendError::Full(_) => Error::Coordinator("queue full (backpressure)".into()),
                TrySendError::Disconnected(_) => Error::Coordinator("worker queue closed".into()),
            }
        })
    }

    /// Queue headroom signal used by the router's load-shedding policy.
    pub fn has_capacity(&self) -> bool {
        self.depth.load(Ordering::Relaxed) < self.capacity / 2
    }

    /// Current approximate depth.
    pub fn depth(&self) -> usize {
        self.depth.load(Ordering::Relaxed)
    }
}

impl Drop for VariantWorker {
    fn drop(&mut self) {
        let (dead_tx, _) = std::sync::mpsc::sync_channel(1);
        drop(std::mem::replace(&mut self.tx, dead_tx));
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn worker_loop(exe: Executable, params: Vec<f32>,
               rx: Receiver<InferRequest>, metrics: Arc<Metrics>,
               depth: Arc<AtomicUsize>, max_batch: usize, timeout: Duration) {
    loop {
        let first = match rx.recv() {
            Ok(r) => r,
            Err(_) => return,
        };
        let mut batch = vec![first];
        let deadline = Instant::now() + timeout;
        while batch.len() < max_batch {
            let remaining = deadline.saturating_duration_since(Instant::now());
            if remaining.is_zero() {
                break;
            }
            match rx.recv_timeout(remaining) {
                Ok(r) => batch.push(r),
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }
        depth.fetch_sub(batch.len(), Ordering::Relaxed);
        let exec_start = Instant::now();
        let result = run_batch(&exe, &params, &batch);
        let exec_us = exec_start.elapsed().as_micros() as u64;
        let batch_size = batch.len();
        metrics.record_batch(batch_size);
        match result {
            Ok(per_request) => {
                for (req, outputs) in batch.into_iter().zip(per_request) {
                    let queue_us =
                        exec_start.duration_since(req.enqueued_at).as_micros() as u64;
                    metrics.record(queue_us + exec_us);
                    let _ = req.respond.send(super::request::InferResponse {
                        outputs,
                        queue_us,
                        exec_us,
                        batch_size,
                    });
                }
            }
            Err(e) => {
                eprintln!("[pitome worker] batch failed: {e}");
                // responders dropped; submitters observe a closed channel
            }
        }
    }
}

/// Stack per-request inputs into the artifact batch, execute, split.
fn run_batch(exe: &Executable, params: &[f32], batch: &[InferRequest])
             -> Result<Vec<Vec<HostTensor>>> {
    let entry = &exe.entry;
    let b_art = entry.meta.batch;
    if batch.len() > b_art {
        return Err(Error::Coordinator(format!(
            "batch {} exceeds artifact batch {}", batch.len(), b_art)));
    }
    let n_sample_inputs = entry.inputs.len() - 1; // first input = params
    let mut full_inputs: Vec<HostTensor> = Vec::with_capacity(entry.inputs.len());
    full_inputs.push(HostTensor::F32(params.to_vec(),
                                     entry.inputs[0].shape.clone()));
    for si in 0..n_sample_inputs {
        let spec = &entry.inputs[si + 1];
        let per = spec.numel() / b_art;
        match &batch[0].inputs[si] {
            HostTensor::F32(..) => {
                let mut data = Vec::with_capacity(spec.numel());
                for bi in 0..b_art {
                    let req = &batch[bi.min(batch.len() - 1)];
                    let d = match &req.inputs[si] {
                        HostTensor::F32(d, _) => d,
                        _ => return Err(Error::Shape("dtype mix in batch".into())),
                    };
                    if d.len() != per {
                        return Err(Error::Shape(format!(
                            "sample input {si}: {} elems, artifact wants {per}",
                            d.len())));
                    }
                    data.extend_from_slice(d);
                }
                full_inputs.push(HostTensor::F32(data, spec.shape.clone()));
            }
            HostTensor::I32(..) => {
                let mut data = Vec::with_capacity(spec.numel());
                for bi in 0..b_art {
                    let req = &batch[bi.min(batch.len() - 1)];
                    let d = match &req.inputs[si] {
                        HostTensor::I32(d, _) => d,
                        _ => return Err(Error::Shape("dtype mix in batch".into())),
                    };
                    data.extend_from_slice(d);
                }
                full_inputs.push(HostTensor::I32(data, spec.shape.clone()));
            }
        }
    }
    let outputs = exe.run(&full_inputs)?;
    // split each output along the batch axis
    let mut per_request: Vec<Vec<HostTensor>> =
        (0..batch.len()).map(|_| Vec::new()).collect();
    for (out, spec) in outputs.iter().zip(&entry.outputs) {
        let per = spec.numel() / b_art;
        let sample_shape: Vec<usize> = if spec.shape.len() > 1 {
            spec.shape[1..].to_vec()
        } else {
            vec![1]
        };
        for (bi, sink) in per_request.iter_mut().enumerate() {
            let t = match out {
                HostTensor::F32(d, _) => HostTensor::F32(
                    d[bi * per..(bi + 1) * per].to_vec(), sample_shape.clone()),
                HostTensor::I32(d, _) => HostTensor::I32(
                    d[bi * per..(bi + 1) * per].to_vec(), sample_shape.clone()),
            };
            sink.push(t);
        }
    }
    Ok(per_request)
}
