//! The serving coordinator: wires registry -> engine -> workers -> router
//! and exposes submit APIs with admission control.

use std::path::Path;
use std::sync::mpsc;
use std::sync::Arc;
use std::time::Instant;

use crate::config::{ServingConfig, ViTConfig};
use crate::engine::Engine;
use crate::error::{Error, Result};
use crate::model::ParamStore;
use crate::runtime::{load_flat_params, HostTensor, Registry};

use super::batcher::VariantWorker;
use super::metrics::Snapshot;
use super::request::{InferRequest, InferResponse, Qos};
use super::router::{Router, Variant};

/// The serving coordinator.
pub struct Coordinator {
    router: Router,
    /// serving config used for all workers
    pub cfg: ServingConfig,
}

impl Coordinator {
    /// Boot: start one worker per variant; each worker compiles its
    /// artifact on its own PJRT client thread.
    ///
    /// `selection`: (logical model, artifact names most-accurate-first).
    pub fn boot(registry: &Registry, artifacts_dir: &Path,
                selection: &[(&str, Vec<String>)], cfg: ServingConfig)
                -> Result<Coordinator> {
        let mut router = Router::new();
        for (model, names) in selection {
            for name in names {
                let entry = registry.get(name)?.clone();
                let params = match &entry.meta.params {
                    Some(f) => load_flat_params(artifacts_dir, f)?,
                    None => Vec::new(),
                };
                let hlo = registry.hlo_path(name)?;
                let mode = entry.meta.mode.clone();
                let r = entry.meta.r;
                let worker = VariantWorker::spawn(hlo, entry, params, &cfg);
                router.add_variant(model, Variant {
                    artifact: name.clone(),
                    mode,
                    r,
                    worker,
                });
            }
        }
        Ok(Coordinator { router, cfg })
    }

    /// Boot a coordinator that serves the pure-Rust CPU reference ViT —
    /// no PJRT artifacts required.  `selection` maps each logical model to
    /// its compression ladder of `(merge mode, keep ratio)` rungs,
    /// most-accurate-first.  Every rung shares one [`Engine`] (weights +
    /// resolution cache); each variant worker holds a long-lived
    /// `VitSession` from it, whose encoder fan-out uses `cfg.workers`
    /// threads, so steady-state serving re-resolves nothing and allocates
    /// nothing in the inference region.
    pub fn boot_cpu(ps: &Arc<ParamStore>,
                    selection: &[(&str, Vec<(String, f64)>)],
                    cfg: ServingConfig) -> Result<Coordinator> {
        let engine = Arc::new(Engine::new(ps.clone()));
        let mut router = Router::new();
        for (model, rungs) in selection {
            for (mode, r) in rungs {
                let model_cfg = ViTConfig {
                    merge_mode: mode.clone(),
                    merge_r: *r,
                    ..Default::default()
                };
                let worker =
                    VariantWorker::spawn_cpu(engine.clone(), model_cfg, &cfg);
                router.add_variant(model, Variant {
                    artifact: format!("cpu_{}_r{:.0}", mode, r * 1000.0),
                    mode: mode.clone(),
                    r: *r,
                    worker,
                });
            }
        }
        Ok(Coordinator { router, cfg })
    }

    /// Submit one request and block until its response arrives.
    pub fn submit(&self, model: &str, qos: Qos,
                  inputs: Vec<HostTensor>) -> Result<InferResponse> {
        self.submit_nowait(model, qos, inputs)?
            .recv()
            .map_err(|_| Error::Coordinator("worker dropped request".into()))
    }

    /// Submit and return the response channel without blocking on the
    /// result (callers fan out and collect).
    pub fn submit_nowait(&self, model: &str, qos: Qos, inputs: Vec<HostTensor>)
                         -> Result<mpsc::Receiver<InferResponse>> {
        let variant = self.router.route(model, qos)?;
        let (tx, rx) = mpsc::channel();
        let req = InferRequest { inputs, enqueued_at: Instant::now(), respond: tx };
        variant.worker.submit(req)?;
        Ok(rx)
    }

    /// Non-blocking admission-controlled submit: errors immediately when
    /// the chosen variant's queue is full.
    pub fn try_submit(&self, model: &str, qos: Qos, inputs: Vec<HostTensor>)
                      -> Result<mpsc::Receiver<InferResponse>> {
        let variant = self.router.route(model, qos)?;
        let (tx, rx) = mpsc::channel();
        let req = InferRequest { inputs, enqueued_at: Instant::now(), respond: tx };
        variant.worker.try_submit(req)?;
        Ok(rx)
    }

    /// Metrics snapshot of every variant: (model, artifact, snapshot).
    pub fn metrics(&self) -> Vec<(String, String, Snapshot)> {
        let mut out = Vec::new();
        for model in self.router.models() {
            if let Ok(ladder) = self.router.ladder(model) {
                for v in ladder {
                    out.push((model.to_string(), v.artifact.clone(),
                              v.worker.metrics.snapshot()));
                }
            }
        }
        out
    }

    /// Access the router (tests, benches).
    pub fn router(&self) -> &Router {
        &self.router
    }
}
