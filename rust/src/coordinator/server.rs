//! The serving coordinator: wires registry -> engine -> workers -> router
//! and exposes submit APIs with admission control.
//!
//! Worker pools are typed by [`Workload`]: `boot_cpu_workloads` boots
//! vision ([`VitSession`](crate::engine::VitSession)-backed), text
//! ([`BertSession`](crate::engine::BertSession)) and joint
//! ([`JointSession`](crate::engine::JointSession)) pools over one shared
//! [`Engine`] and one shared response-recycling [`TensorPool`].  The
//! hot-path submit ([`Coordinator::submit_pooled`]) carries pooled input
//! tensors and answers into a reusable [`ResponseSlot`], so a warmed
//! request→response→release cycle allocates nothing on either side of
//! the channel (`tests/alloc_free.rs`).

use std::path::Path;
use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::config::{ServingConfig, TextConfig, ViTConfig};
use crate::engine::{Engine, JointConfig, JointKind};
use crate::error::{Error, Result};
use crate::gallery::{GalleryOptions, GalleryStore};
use crate::model::ParamStore;
use crate::obs::ObsHub;
use crate::runtime::{load_flat_params, HostTensor, Registry};

use super::batcher::VariantWorker;
use super::metrics::Snapshot;
use super::pool::TensorPool;
use super::request::{Admission, InferRequest, InferResponse, Payload, Qos,
                     Responder, ResponseSlot, Workload};
use super::router::{Router, Variant};

/// CPU worker-pool selection for [`Coordinator::boot_cpu_workloads`]:
/// each workload maps logical models to their compression ladders of
/// `(merge mode, keep ratio)` rungs, most-accurate-first.
#[derive(Default)]
pub struct CpuWorkloads {
    /// vision pools: (model, rungs) served by `VitSession` workers
    pub vision: Vec<(String, Vec<(String, f64)>)>,
    /// text pools: (model, rungs) served by `BertSession` workers
    pub text: Vec<(String, Vec<(String, f64)>)>,
    /// joint pools: (model, fusion kind, rungs — the vision tower sweeps
    /// the ladder, the text tower stays uncompressed) served by
    /// `JointSession` workers
    pub joint: Vec<(String, JointKind, Vec<(String, f64)>)>,
    /// embedding-gallery pools: (model, rungs) served by gallery workers
    /// over a retrieval-kind `JointSession`.  Every rung of a model
    /// shares one [`GalleryStore`], so an item ingested through any rung
    /// is visible to queries on every rung.
    pub gallery: Vec<(String, Vec<(String, f64)>)>,
}

/// The serving coordinator.
pub struct Coordinator {
    router: Router,
    pool: Arc<TensorPool>,
    /// per-gallery-model shared embedding stores (empty unless
    /// [`CpuWorkloads::gallery`] booted a gallery pool)
    galleries: Vec<(String, Arc<GalleryStore>)>,
    /// span-ring hub shared by every worker; `None` unless
    /// [`ServingConfig::trace_capacity`] > 0
    hub: Option<Arc<ObsHub>>,
    /// serving config used for all workers
    pub cfg: ServingConfig,
}

impl Coordinator {
    /// Boot: start one worker per variant; each worker compiles its
    /// artifact on its own PJRT client thread.
    ///
    /// `selection`: (logical model, artifact names most-accurate-first).
    // lint: allow(alloc) reason=cold boot path: per-variant params/entry clones happen once
    pub fn boot(registry: &Registry, artifacts_dir: &Path,
                selection: &[(&str, Vec<String>)], cfg: ServingConfig)
                -> Result<Coordinator> {
        let hub = (cfg.trace_capacity > 0)
            .then(|| ObsHub::new(cfg.trace_capacity));
        let mut router = Router::new();
        for (model, names) in selection {
            for name in names {
                let entry = registry.get(name)?.clone();
                let params = match &entry.meta.params {
                    Some(f) => load_flat_params(artifacts_dir, f)?,
                    None => Vec::new(),
                };
                let hlo = registry.hlo_path(name)?;
                let mode = entry.meta.mode.clone();
                let r = entry.meta.r;
                let worker = VariantWorker::spawn(hlo, entry, params, &cfg,
                                                  hub.as_ref());
                router.add_variant(model, Variant {
                    artifact: name.clone(),
                    mode,
                    r,
                    worker,
                });
            }
        }
        Ok(Coordinator {
            router,
            pool: Arc::new(TensorPool::new()),
            galleries: Vec::new(),
            hub,
            cfg,
        })
    }

    /// Boot a vision-only CPU coordinator (back-compat shorthand for
    /// [`Coordinator::boot_cpu_workloads`]).  `selection` maps each
    /// logical model to its compression ladder of `(merge mode, keep
    /// ratio)` rungs, most-accurate-first.
    // lint: allow(alloc) reason=cold boot path: selection clones into the workload table once
    pub fn boot_cpu(ps: &Arc<ParamStore>,
                    selection: &[(&str, Vec<(String, f64)>)],
                    cfg: ServingConfig) -> Result<Coordinator> {
        let workloads = CpuWorkloads {
            vision: selection
                .iter()
                .map(|(m, rungs)| (m.to_string(), rungs.clone()))
                .collect(),
            ..Default::default()
        };
        Self::boot_cpu_workloads(ps, &workloads, cfg)
    }

    /// Boot a multi-workload CPU coordinator — no PJRT artifacts
    /// required.  Every worker across every pool shares one [`Engine`]
    /// (weights + resolution cache) and one response-recycling
    /// [`TensorPool`]; each holds its session for its whole lifetime, so
    /// steady-state serving re-resolves nothing and allocates nothing in
    /// a whole batch cycle.
    // lint: allow(alloc) reason=cold boot path: per-worker config clones and artifact-name format! happen once
    pub fn boot_cpu_workloads(ps: &Arc<ParamStore>, workloads: &CpuWorkloads,
                              cfg: ServingConfig) -> Result<Coordinator> {
        let engine = Arc::new(Engine::new(ps.clone()));
        let pool = Arc::new(TensorPool::new());
        let hub = (cfg.trace_capacity > 0)
            .then(|| ObsHub::new(cfg.trace_capacity));
        let mut router = Router::new();
        for (model, rungs) in &workloads.vision {
            for (mode, r) in rungs {
                let model_cfg = ViTConfig {
                    merge_mode: mode.clone(),
                    merge_r: *r,
                    ..Default::default()
                };
                let worker = VariantWorker::spawn_cpu(
                    engine.clone(), model_cfg, pool.clone(), &cfg,
                    hub.as_ref());
                router.add_variant_for(Workload::Vision, model, Variant {
                    artifact: format!("cpu_{}_r{:.0}", mode, r * 1000.0),
                    mode: mode.clone(),
                    r: *r,
                    worker,
                });
            }
        }
        for (model, rungs) in &workloads.text {
            for (mode, r) in rungs {
                let model_cfg = TextConfig {
                    merge_mode: mode.clone(),
                    merge_r: *r,
                    ..Default::default()
                };
                let worker = VariantWorker::spawn_cpu_text(
                    engine.clone(), model_cfg, pool.clone(), &cfg,
                    hub.as_ref());
                router.add_variant_for(Workload::Text, model, Variant {
                    artifact: format!("text_{}_r{:.0}", mode, r * 1000.0),
                    mode: mode.clone(),
                    r: *r,
                    worker,
                });
            }
        }
        for (model, kind, rungs) in &workloads.joint {
            for (mode, r) in rungs {
                let vision = ViTConfig {
                    merge_mode: mode.clone(),
                    merge_r: *r,
                    ..Default::default()
                };
                let model_cfg = match kind {
                    JointKind::Vqa => JointConfig::vqa(vision),
                    JointKind::Retrieval => JointConfig::retrieval(vision),
                };
                let worker = VariantWorker::spawn_cpu_joint(
                    engine.clone(), model_cfg, pool.clone(), &cfg,
                    hub.as_ref());
                router.add_variant_for(Workload::Joint, model, Variant {
                    artifact: format!("joint_{}_r{:.0}", mode, r * 1000.0),
                    mode: mode.clone(),
                    r: *r,
                    worker,
                });
            }
        }
        let mut galleries = Vec::new();
        for (model, rungs) in &workloads.gallery {
            // one store per logical gallery model, shared by every rung:
            // the embedding dim is the retrieval projection width, which
            // the compression ladder does not change
            let dim = JointConfig::retrieval(ViTConfig::default()).text.dim;
            let store =
                Arc::new(GalleryStore::new(dim, GalleryOptions::default()));
            galleries.push((model.clone(), store.clone()));
            for (mode, r) in rungs {
                let vision = ViTConfig {
                    merge_mode: mode.clone(),
                    merge_r: *r,
                    ..Default::default()
                };
                let model_cfg = JointConfig::retrieval(vision);
                let worker = VariantWorker::spawn_cpu_gallery(
                    engine.clone(), model_cfg, store.clone(), pool.clone(),
                    &cfg, hub.as_ref());
                router.add_variant_for(Workload::Gallery, model, Variant {
                    artifact: format!("gallery_{}_r{:.0}", mode, r * 1000.0),
                    mode: mode.clone(),
                    r: *r,
                    worker,
                });
            }
        }
        Ok(Coordinator { router, pool, galleries, hub, cfg })
    }

    /// The shared span-ring hub, when tracing is enabled
    /// ([`ServingConfig::trace_capacity`] > 0).  Callers drain it
    /// ([`ObsHub::drain`]) to reconstruct per-stage request timelines —
    /// the load harness turns the drained spans into a Chrome trace.
    pub fn obs_hub(&self) -> Option<&Arc<ObsHub>> {
        self.hub.as_ref()
    }

    /// The shared embedding store behind a gallery model's worker pool
    /// (`None` when no gallery pool was booted under that name).  Exposed
    /// for bulk raw-row ingest and snapshot management; serving-path
    /// ingest goes through [`Payload::GalleryIngest`](super::request::Payload).
    pub fn gallery_store(&self, model: &str) -> Option<&Arc<GalleryStore>> {
        self.galleries
            .iter()
            .find(|(m, _)| m == model)
            .map(|(_, s)| s)
    }

    /// The coordinator's shared tensor-recycling pool: clients check
    /// request buffers out of it ([`TensorPool::take_f32`] /
    /// [`TensorPool::take_i32`]) and responses return theirs to it on
    /// drop.
    pub fn pool(&self) -> &Arc<TensorPool> {
        &self.pool
    }

    /// A reusable bounded response channel for
    /// [`Coordinator::submit_pooled`] (one per client thread).  Sized to
    /// `queue_capacity + max_batch` — the most responses a client
    /// pipelining against a single worker can ever have undelivered
    /// (the queue plus the worker's in-flight batch), so slot sends
    /// never overflow in that configuration.  A client fanning one slot
    /// across several pools should drain between submits or build a
    /// proportionally larger [`ResponseSlot`] itself.
    pub fn response_slot(&self) -> ResponseSlot {
        ResponseSlot::new(self.cfg.queue_capacity + self.cfg.max_batch)
    }

    /// Submit one vision request and block until its response arrives
    /// (legacy convenience: per-request channel, untyped tensor list).
    pub fn submit(&self, model: &str, qos: Qos,
                  inputs: Vec<HostTensor>) -> Result<InferResponse> {
        self.submit_nowait(model, qos, inputs)?
            .recv()
            .map_err(|_| Error::Coordinator("worker dropped request".into()))
    }

    /// Submit a vision request and return the response channel without
    /// blocking on the result (callers fan out and collect).
    pub fn submit_nowait(&self, model: &str, qos: Qos, inputs: Vec<HostTensor>)
                         -> Result<mpsc::Receiver<InferResponse>> {
        self.submit_typed(Workload::Vision, model, qos,
                          Payload::Tensors(inputs))
    }

    /// Non-blocking admission-controlled vision submit: errors
    /// immediately when the chosen variant's queue is full.
    pub fn try_submit(&self, model: &str, qos: Qos, inputs: Vec<HostTensor>)
                      -> Result<mpsc::Receiver<InferResponse>> {
        let variant = self.router.route_for(Workload::Vision, model, qos)?;
        let (tx, rx) = mpsc::channel();
        let req = InferRequest {
            payload: Payload::Tensors(inputs),
            enqueued_at: Instant::now(),
            deadline: None,
            respond: Responder::Channel(tx),
        };
        variant.worker.try_submit(req)?;
        Ok(rx)
    }

    /// Submit a typed request to its workload pool, returning a
    /// per-request response channel (allocates the channel; use
    /// [`Coordinator::submit_pooled`] on the hot path).
    pub fn submit_typed(&self, workload: Workload, model: &str, qos: Qos,
                        payload: Payload)
                        -> Result<mpsc::Receiver<InferResponse>> {
        let variant = self.router.route_for(workload, model, qos)?;
        let (tx, rx) = mpsc::channel();
        let req = InferRequest {
            payload,
            enqueued_at: Instant::now(),
            deadline: None,
            respond: Responder::Channel(tx),
        };
        variant.worker.submit(req)?;
        Ok(rx)
    }

    /// Hot-path typed submit: the response lands in the caller's
    /// reusable `slot`.  With pooled payload tensors this whole
    /// request→response→release cycle performs zero heap allocations
    /// once warm (`tests/alloc_free.rs`).
    pub fn submit_pooled(&self, workload: Workload, model: &str, qos: Qos,
                         payload: Payload, slot: &ResponseSlot)
                         -> Result<()> {
        let variant = self.router.route_for(workload, model, qos)?;
        let req = InferRequest {
            payload,
            enqueued_at: Instant::now(),
            deadline: None,
            respond: Responder::Slot(slot.sender()),
        };
        variant.worker.submit(req)
    }

    /// Admission-controlled hot-path submit: like
    /// [`Coordinator::submit_pooled`], but never blocks — a full queue
    /// refuses the request ([`Admission::Shed`], counted in the chosen
    /// worker's `shed` metric) instead of applying backpressure, and an
    /// optional relative `deadline` arms the worker's pre-execution
    /// expiry drop (counted in `expired`, answered with an error through
    /// the slot).  The load harness drives overload through this path.
    pub fn try_submit_pooled(&self, workload: Workload, model: &str,
                             qos: Qos, payload: Payload,
                             deadline: Option<Duration>,
                             slot: &ResponseSlot) -> Result<Admission> {
        let variant = self.router.route_for(workload, model, qos)?;
        let now = Instant::now();
        let req = InferRequest {
            payload,
            enqueued_at: now,
            deadline: deadline.map(|d| now + d),
            respond: Responder::Slot(slot.sender()),
        };
        variant.worker.submit_shed(req)
    }

    /// Metrics snapshot of every variant across every workload:
    /// (model, artifact, snapshot), ordered by workload then model.
    // lint: allow(alloc) reason=observability snapshot, not a serving path
    pub fn metrics(&self) -> Vec<(String, String, Snapshot)> {
        self.metrics_typed()
            .into_iter()
            .map(|(_, m, a, s)| (m, a, s))
            .collect()
    }

    /// Typed metrics snapshot: (workload, model, artifact, snapshot),
    /// ordered by workload then model.
    // lint: allow(alloc) reason=observability snapshot, not a serving path
    pub fn metrics_typed(&self)
                         -> Vec<(Workload, String, String, Snapshot)> {
        let mut out = Vec::new();
        for (w, model, ladder) in self.router.iter() {
            for v in ladder {
                out.push((w, model.to_string(), v.artifact.clone(),
                          v.worker.metrics.snapshot()));
            }
        }
        out
    }

    /// Access the router (tests, benches).
    pub fn router(&self) -> &Router {
        &self.router
    }
}
