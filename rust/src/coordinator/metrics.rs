//! Serving metrics: counters + latency histogram with percentile queries.
//!
//! Recording is **lock-free**: every field is an atomic updated with
//! relaxed read-modify-write ops, so a submitter thread recording a shed
//! and four workers recording latencies never serialize on a `Mutex`
//! (the previous design took one lock per request, a measurable
//! contention point at high worker counts).  Aggregation happens at
//! [`Metrics::snapshot`] time: the reader loads each counter once;
//! counters updated mid-snapshot may land in this snapshot or the next,
//! which is the usual (and acceptable) monitoring semantics.
//!
//! Percentiles come from a fixed log-scaled histogram and are **linearly
//! interpolated inside the winning bucket** (rank position between the
//! bucket's lower and upper bound), so a p50 of uniform samples lands
//! near the true median instead of snapping to a bucket edge.  The
//! open-ended top bucket uses the observed max as its upper bound, and
//! every percentile stays clamped to `max_us`.

use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering::Relaxed};

/// Fixed log-scaled latency buckets (microseconds).
const BUCKETS_US: [u64; 16] = [
    50, 100, 200, 400, 800, 1_600, 3_200, 6_400, 12_800, 25_600, 51_200,
    102_400, 204_800, 409_600, 819_200, u64::MAX,
];

/// Thread-safe, lock-free metrics sink.  Cumulative counters use
/// `fetch_add`, the latency max uses `fetch_max`, and the gauges
/// (`infer_allocs`, `cycle_allocs`, `gallery_len`) use plain stores —
/// all relaxed, merged by [`Metrics::snapshot`].
#[derive(Default)]
pub struct Metrics {
    count: AtomicU64,
    total_us: AtomicU64,
    max_us: AtomicU64,
    hist: [AtomicU64; 16],
    batches: AtomicU64,
    batched_requests: AtomicU64,
    infer_allocs: AtomicU64,
    cycle_allocs: AtomicU64,
    resp_recycled: AtomicU64,
    resp_fresh: AtomicU64,
    shed: AtomicU64,
    expired: AtomicU64,
    gallery_len: AtomicU64,
    gallery_scanned_rows: AtomicU64,
    gallery_evictions: AtomicU64,
    gallery_scan_us: AtomicU64,
}

/// A point-in-time snapshot.
#[derive(Clone, Debug)]
pub struct Snapshot {
    /// completed requests
    pub count: u64,
    /// mean end-to-end latency, microseconds
    pub mean_us: f64,
    /// p50 latency (interpolated within its bucket, clamped to `max_us`)
    pub p50_us: u64,
    /// p99 latency (interpolated within its bucket, clamped to `max_us`
    /// so a sample in the open-ended top bucket never reports
    /// `u64::MAX`)
    pub p99_us: u64,
    /// p999 latency (interpolated within its bucket, clamped to `max_us`)
    pub p999_us: u64,
    /// max observed latency
    pub max_us: u64,
    /// mean requests per executed batch
    pub mean_batch: f64,
    /// heap allocations inside the most recent batch's inference region
    /// (parse + embed + forward + heads, outputs included; response
    /// transport excluded).  Always 0 unless the process installs the
    /// `CountingAllocator` test hook — the steady-state acceptance is 0
    /// (`tests/alloc_free.rs`).
    pub last_infer_allocs: u64,
    /// heap allocations across the most recent **whole batch cycle** on
    /// the worker thread — inference region *plus* response construction
    /// and channel sends (the formerly-exempt transport boundary).  With
    /// recycled response buffers and a bounded client slot this is 0 at
    /// steady state (`tests/alloc_free.rs`); the legacy per-request
    /// channel path still allocates here.
    pub last_cycle_allocs: u64,
    /// responses built from a recycled pool buffer (cumulative)
    pub resp_recycled: u64,
    /// responses that had to allocate a fresh buffer (cumulative)
    pub resp_fresh: u64,
    /// requests refused at admission because the queue was full
    pub shed: u64,
    /// admitted requests dropped by the worker because their deadline
    /// had already passed when their batch was picked up
    pub expired: u64,
    /// embeddings resident in the gallery store at the last gallery
    /// batch (gauge; 0 when no gallery workload runs)
    pub gallery_len: u64,
    /// gallery rows scored by query scans (cumulative); divide by
    /// `gallery_scan_us` for the serving-side scan rate
    pub gallery_scanned_rows: u64,
    /// top-k heap evictions across gallery scans (cumulative) — how
    /// often a candidate displaced a weaker provisional hit
    pub gallery_evictions: u64,
    /// microseconds spent inside gallery scans (cumulative)
    pub gallery_scan_us: u64,
}

impl Metrics {
    /// Record one completed request.  Lock-free: four relaxed atomic
    /// read-modify-writes.
    pub fn record(&self, latency_us: u64) {
        self.count.fetch_add(1, Relaxed);
        self.total_us.fetch_add(latency_us, Relaxed);
        self.max_us.fetch_max(latency_us, Relaxed);
        let idx = BUCKETS_US.iter().position(|&b| latency_us <= b).unwrap_or(15);
        self.hist[idx].fetch_add(1, Relaxed);
    }

    /// Record one executed batch of `n` requests.
    pub fn record_batch(&self, n: usize) {
        self.batches.fetch_add(1, Relaxed);
        self.batched_requests.fetch_add(n as u64, Relaxed);
    }

    /// Record the allocation count of one batch's inference region (the
    /// CPU worker calls this with the `CountingAllocator` delta around
    /// its parse→forward→heads span).
    pub fn record_infer_allocs(&self, allocs: u64) {
        self.infer_allocs.store(allocs, Relaxed);
    }

    /// Record the allocation count of one whole batch cycle (inference +
    /// response transport) on the worker thread.
    pub fn record_cycle_allocs(&self, allocs: u64) {
        self.cycle_allocs.store(allocs, Relaxed);
    }

    /// Record how many of a batch's responses reused a recycled pool
    /// buffer vs allocated a fresh one.
    pub fn record_responses(&self, recycled: u64, fresh: u64) {
        self.resp_recycled.fetch_add(recycled, Relaxed);
        self.resp_fresh.fetch_add(fresh, Relaxed);
    }

    /// Record one request shed at admission (queue full).
    pub fn record_shed(&self) {
        self.shed.fetch_add(1, Relaxed);
    }

    /// Record `n` admitted requests dropped because their deadline
    /// expired before execution.
    pub fn record_expired(&self, n: u64) {
        self.expired.fetch_add(n, Relaxed);
    }

    /// Record one gallery batch's scan work: the store size at the time
    /// (a gauge) plus cumulative rows scored, top-k heap evictions, and
    /// scan wall time.
    pub fn record_gallery(&self, len: u64, rows: u64, evictions: u64,
                          scan_us: u64) {
        self.gallery_len.store(len, Relaxed);
        self.gallery_scanned_rows.fetch_add(rows, Relaxed);
        self.gallery_evictions.fetch_add(evictions, Relaxed);
        self.gallery_scan_us.fetch_add(scan_us, Relaxed);
    }

    /// Rank-`q` latency from the histogram: find the winning bucket,
    /// then linearly interpolate the target rank between the bucket's
    /// lower bound (the previous bucket's edge, 0 for the first) and its
    /// upper bound (the observed max for the open-ended top bucket).
    fn percentile(hist: &[u64; 16], count: u64, max_us: u64, q: f64) -> u64 {
        if count == 0 {
            return 0;
        }
        let target = (count as f64 * q).ceil().max(1.0) as u64;
        let mut acc = 0u64;
        for (i, &c) in hist.iter().enumerate() {
            if acc + c >= target && c > 0 {
                let lo = if i == 0 { 0 } else { BUCKETS_US[i - 1] };
                let hi = if i == 15 { max_us.max(lo) } else { BUCKETS_US[i] };
                // rank position inside this bucket, in (0, 1]
                let frac = (target - acc) as f64 / c as f64;
                let v = lo as f64 + frac * (hi - lo) as f64;
                return (v.round() as u64).min(max_us);
            }
            acc += c;
        }
        max_us
    }

    /// Take a snapshot.  Lock-free; counters racing with the snapshot
    /// land in this one or the next.
    pub fn snapshot(&self) -> Snapshot {
        let count = self.count.load(Relaxed);
        let total_us = self.total_us.load(Relaxed);
        let max_us = self.max_us.load(Relaxed);
        let mut hist = [0u64; 16];
        for (h, a) in hist.iter_mut().zip(self.hist.iter()) {
            *h = a.load(Relaxed);
        }
        let batches = self.batches.load(Relaxed);
        let batched_requests = self.batched_requests.load(Relaxed);
        Snapshot {
            count,
            mean_us: if count > 0 { total_us as f64 / count as f64 } else { 0.0 },
            p50_us: Self::percentile(&hist, count, max_us, 0.5),
            p99_us: Self::percentile(&hist, count, max_us, 0.99),
            p999_us: Self::percentile(&hist, count, max_us, 0.999),
            max_us,
            mean_batch: if batches > 0 {
                batched_requests as f64 / batches as f64
            } else {
                0.0
            },
            last_infer_allocs: self.infer_allocs.load(Relaxed),
            last_cycle_allocs: self.cycle_allocs.load(Relaxed),
            resp_recycled: self.resp_recycled.load(Relaxed),
            resp_fresh: self.resp_fresh.load(Relaxed),
            shed: self.shed.load(Relaxed),
            expired: self.expired.load(Relaxed),
            gallery_len: self.gallery_len.load(Relaxed),
            gallery_scanned_rows: self.gallery_scanned_rows.load(Relaxed),
            gallery_evictions: self.gallery_evictions.load(Relaxed),
            gallery_scan_us: self.gallery_scan_us.load(Relaxed),
        }
    }
}

impl Snapshot {
    /// The canonical one-line human rendering — the single formatter the
    /// `serve`/`loadtest`/`gallery` subcommands and test logs all share
    /// (previously each call site hand-rolled its own subset of fields).
    /// Gallery scan accounting is appended only when the snapshot saw
    /// gallery work.
    // lint: allow(alloc) reason=cold reporting path: human-readable summary string
    pub fn to_text(&self) -> String {
        let mut s = format!(
            "n={} mean={:.0}us p50={}us p99={}us p999={}us max={}us \
             mean_batch={:.2} shed={} expired={}",
            self.count, self.mean_us, self.p50_us, self.p99_us,
            self.p999_us, self.max_us, self.mean_batch, self.shed,
            self.expired);
        if self.gallery_scanned_rows > 0 {
            s.push_str(&format!(
                " | gallery len={} scanned={} rows ({:.1} Mrows/s) \
                 evictions={}",
                self.gallery_len, self.gallery_scanned_rows,
                self.gallery_scanned_rows as f64
                    / self.gallery_scan_us.max(1) as f64,
                self.gallery_evictions));
        }
        s
    }
}

impl fmt::Display for Snapshot {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_text())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_ordered() {
        let m = Metrics::default();
        for i in 0..1000u64 {
            m.record(i * 10);
        }
        let s = m.snapshot();
        assert_eq!(s.count, 1000);
        assert!(s.p50_us <= s.p99_us);
        assert!(s.p99_us <= s.max_us.max(BUCKETS_US[14]));
        assert!(s.mean_us > 0.0);
    }

    /// Samples in the open-ended top bucket (> 819.2 ms) used to make
    /// every high percentile report `BUCKETS_US[15] = u64::MAX`; the
    /// snapshot now clamps bucket bounds to the observed max.
    #[test]
    fn top_bucket_percentiles_clamp_to_observed_max() {
        let m = Metrics::default();
        m.record(100);
        for _ in 0..10 {
            m.record(2_000_000); // top bucket: beyond 819_200 us
        }
        let s = m.snapshot();
        assert_eq!(s.max_us, 2_000_000);
        assert!(s.p99_us <= s.max_us, "p99 {} > max {}", s.p99_us, s.max_us);
        assert!(s.p999_us <= s.max_us);
        assert_ne!(s.p99_us, u64::MAX);
        assert!(s.p50_us <= s.p99_us && s.p99_us <= s.p999_us);
    }

    /// Known distribution, closed-form check: 1..=100 µs, one sample
    /// each.  Ranks 1..=50 land in bucket [0, 50], ranks 51..=100 in
    /// (50, 100].  Interpolation puts p50 at the bucket top (rank 50 of
    /// 50 → 0+1.0·50 = 50) and p99 at rank 49 of 50 inside (50, 100] →
    /// 50+0.98·50 = 99 — both exactly the true order statistics, where
    /// the old bucket-edge rounding reported 50 and 100.
    #[test]
    fn percentiles_interpolate_within_buckets() {
        let m = Metrics::default();
        for v in 1..=100u64 {
            m.record(v);
        }
        let s = m.snapshot();
        assert_eq!(s.p50_us, 50);
        assert_eq!(s.p99_us, 99);
        assert_eq!(s.max_us, 100);
        // all mass in one bucket: interpolation stays inside it
        let m2 = Metrics::default();
        for _ in 0..4 {
            m2.record(300); // bucket (200, 400]
        }
        let s2 = m2.snapshot();
        assert!(s2.p50_us > 200 && s2.p50_us <= 300,
                "p50 {} must stay in-bucket and clamped to max", s2.p50_us);
        assert_eq!(s2.p999_us, 300, "top rank clamps to observed max");
    }

    /// A single sample reports itself (clamped) at every percentile.
    #[test]
    fn single_sample_percentiles_clamp_to_it() {
        let m = Metrics::default();
        m.record(75);
        let s = m.snapshot();
        assert_eq!((s.p50_us, s.p99_us, s.p999_us, s.max_us),
                   (75, 75, 75, 75));
    }

    #[test]
    fn shed_and_expired_counters_accumulate() {
        let m = Metrics::default();
        m.record_shed();
        m.record_shed();
        m.record_expired(3);
        let s = m.snapshot();
        assert_eq!(s.shed, 2);
        assert_eq!(s.expired, 3);
    }

    #[test]
    fn gallery_counters_accumulate_and_len_is_a_gauge() {
        let m = Metrics::default();
        m.record_gallery(100, 100, 5, 40);
        m.record_gallery(250, 250, 9, 90);
        let s = m.snapshot();
        assert_eq!(s.gallery_len, 250);
        assert_eq!(s.gallery_scanned_rows, 350);
        assert_eq!(s.gallery_evictions, 14);
        assert_eq!(s.gallery_scan_us, 130);
    }

    #[test]
    fn batch_accounting() {
        let m = Metrics::default();
        m.record_batch(8);
        m.record_batch(4);
        assert!((m.snapshot().mean_batch - 6.0).abs() < 1e-9);
    }

    /// Many threads hammer the sink lock-free; the final snapshot sums
    /// must be exact (relaxed RMWs never lose increments).
    #[test]
    fn concurrent_recording_loses_nothing() {
        let m = std::sync::Arc::new(Metrics::default());
        let mut handles = Vec::new();
        for t in 0..8u64 {
            let m = m.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..1000u64 {
                    m.record(t * 1000 + i);
                    if i % 10 == 0 {
                        m.record_shed();
                    }
                    m.record_responses(1, 0);
                }
                m.record_batch(5);
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let s = m.snapshot();
        assert_eq!(s.count, 8000);
        assert_eq!(s.shed, 800);
        assert_eq!(s.resp_recycled, 8000);
        assert_eq!(s.max_us, 7999);
        assert!((s.mean_batch - 5.0).abs() < 1e-9);
    }

    #[test]
    fn snapshot_text_is_shared_and_complete() {
        let m = Metrics::default();
        m.record(100);
        m.record_shed();
        let s = m.snapshot();
        let text = s.to_text();
        assert!(text.contains("n=1"));
        assert!(text.contains("shed=1"));
        assert!(!text.contains("gallery"), "no gallery work → no suffix");
        assert_eq!(format!("{s}"), text, "Display delegates to to_text");
        m.record_gallery(10, 500, 1, 20);
        assert!(m.snapshot().to_text().contains("gallery len=10"));
    }
}
