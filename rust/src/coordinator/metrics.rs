//! Serving metrics: counters + latency histogram with percentile queries.

use std::sync::Mutex;

/// Fixed log-scaled latency buckets (microseconds).
const BUCKETS_US: [u64; 16] = [
    50, 100, 200, 400, 800, 1_600, 3_200, 6_400, 12_800, 25_600, 51_200,
    102_400, 204_800, 409_600, 819_200, u64::MAX,
];

#[derive(Default, Clone, Debug)]
struct Inner {
    count: u64,
    total_us: u64,
    max_us: u64,
    hist: [u64; 16],
    batches: u64,
    batched_requests: u64,
    infer_allocs: u64,
    cycle_allocs: u64,
    resp_recycled: u64,
    resp_fresh: u64,
    shed: u64,
    expired: u64,
    gallery_len: u64,
    gallery_scanned_rows: u64,
    gallery_evictions: u64,
    gallery_scan_us: u64,
}

/// Thread-safe metrics sink.
#[derive(Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

/// A point-in-time snapshot.
#[derive(Clone, Debug)]
pub struct Snapshot {
    /// completed requests
    pub count: u64,
    /// mean end-to-end latency, microseconds
    pub mean_us: f64,
    /// p50 latency (bucket upper bound, clamped to `max_us`)
    pub p50_us: u64,
    /// p99 latency (bucket upper bound, clamped to `max_us` so a sample
    /// in the open-ended top bucket never reports `u64::MAX`)
    pub p99_us: u64,
    /// p999 latency (bucket upper bound, clamped to `max_us`)
    pub p999_us: u64,
    /// max observed latency
    pub max_us: u64,
    /// mean requests per executed batch
    pub mean_batch: f64,
    /// heap allocations inside the most recent batch's inference region
    /// (parse + embed + forward + heads, outputs included; response
    /// transport excluded).  Always 0 unless the process installs the
    /// `CountingAllocator` test hook — the steady-state acceptance is 0
    /// (`tests/alloc_free.rs`).
    pub last_infer_allocs: u64,
    /// heap allocations across the most recent **whole batch cycle** on
    /// the worker thread — inference region *plus* response construction
    /// and channel sends (the formerly-exempt transport boundary).  With
    /// recycled response buffers and a bounded client slot this is 0 at
    /// steady state (`tests/alloc_free.rs`); the legacy per-request
    /// channel path still allocates here.
    pub last_cycle_allocs: u64,
    /// responses built from a recycled pool buffer (cumulative)
    pub resp_recycled: u64,
    /// responses that had to allocate a fresh buffer (cumulative)
    pub resp_fresh: u64,
    /// requests refused at admission because the queue was full
    pub shed: u64,
    /// admitted requests dropped by the worker because their deadline
    /// had already passed when their batch was picked up
    pub expired: u64,
    /// embeddings resident in the gallery store at the last gallery
    /// batch (gauge; 0 when no gallery workload runs)
    pub gallery_len: u64,
    /// gallery rows scored by query scans (cumulative); divide by
    /// `gallery_scan_us` for the serving-side scan rate
    pub gallery_scanned_rows: u64,
    /// top-k heap evictions across gallery scans (cumulative) — how
    /// often a candidate displaced a weaker provisional hit
    pub gallery_evictions: u64,
    /// microseconds spent inside gallery scans (cumulative)
    pub gallery_scan_us: u64,
}

impl Metrics {
    /// Record one completed request.
    pub fn record(&self, latency_us: u64) {
        let mut g = self.inner.lock().unwrap();
        g.count += 1;
        g.total_us += latency_us;
        g.max_us = g.max_us.max(latency_us);
        let idx = BUCKETS_US.iter().position(|&b| latency_us <= b).unwrap_or(15);
        g.hist[idx] += 1;
    }

    /// Record one executed batch of `n` requests.
    pub fn record_batch(&self, n: usize) {
        let mut g = self.inner.lock().unwrap();
        g.batches += 1;
        g.batched_requests += n as u64;
    }

    /// Record the allocation count of one batch's inference region (the
    /// CPU worker calls this with the `CountingAllocator` delta around
    /// its parse→forward→heads span).
    pub fn record_infer_allocs(&self, allocs: u64) {
        let mut g = self.inner.lock().unwrap();
        g.infer_allocs = allocs;
    }

    /// Record the allocation count of one whole batch cycle (inference +
    /// response transport) on the worker thread.
    pub fn record_cycle_allocs(&self, allocs: u64) {
        let mut g = self.inner.lock().unwrap();
        g.cycle_allocs = allocs;
    }

    /// Record how many of a batch's responses reused a recycled pool
    /// buffer vs allocated a fresh one.
    pub fn record_responses(&self, recycled: u64, fresh: u64) {
        let mut g = self.inner.lock().unwrap();
        g.resp_recycled += recycled;
        g.resp_fresh += fresh;
    }

    /// Record one request shed at admission (queue full).
    pub fn record_shed(&self) {
        let mut g = self.inner.lock().unwrap();
        g.shed += 1;
    }

    /// Record `n` admitted requests dropped because their deadline
    /// expired before execution.
    pub fn record_expired(&self, n: u64) {
        let mut g = self.inner.lock().unwrap();
        g.expired += n;
    }

    /// Record one gallery batch's scan work: the store size at the time
    /// (a gauge) plus cumulative rows scored, top-k heap evictions, and
    /// scan wall time.
    pub fn record_gallery(&self, len: u64, rows: u64, evictions: u64,
                          scan_us: u64) {
        let mut g = self.inner.lock().unwrap();
        g.gallery_len = len;
        g.gallery_scanned_rows += rows;
        g.gallery_evictions += evictions;
        g.gallery_scan_us += scan_us;
    }

    fn percentile(hist: &[u64; 16], count: u64, q: f64) -> u64 {
        if count == 0 {
            return 0;
        }
        let target = (count as f64 * q).ceil() as u64;
        let mut acc = 0;
        for (i, &c) in hist.iter().enumerate() {
            acc += c;
            if acc >= target {
                return BUCKETS_US[i];
            }
        }
        BUCKETS_US[15]
    }

    /// Take a snapshot.
    pub fn snapshot(&self) -> Snapshot {
        let g = self.inner.lock().unwrap();
        Snapshot {
            count: g.count,
            mean_us: if g.count > 0 { g.total_us as f64 / g.count as f64 } else { 0.0 },
            p50_us: Self::percentile(&g.hist, g.count, 0.5).min(g.max_us),
            p99_us: Self::percentile(&g.hist, g.count, 0.99).min(g.max_us),
            p999_us: Self::percentile(&g.hist, g.count, 0.999)
                .min(g.max_us),
            max_us: g.max_us,
            mean_batch: if g.batches > 0 {
                g.batched_requests as f64 / g.batches as f64
            } else {
                0.0
            },
            last_infer_allocs: g.infer_allocs,
            last_cycle_allocs: g.cycle_allocs,
            resp_recycled: g.resp_recycled,
            resp_fresh: g.resp_fresh,
            shed: g.shed,
            expired: g.expired,
            gallery_len: g.gallery_len,
            gallery_scanned_rows: g.gallery_scanned_rows,
            gallery_evictions: g.gallery_evictions,
            gallery_scan_us: g.gallery_scan_us,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_ordered() {
        let m = Metrics::default();
        for i in 0..1000u64 {
            m.record(i * 10);
        }
        let s = m.snapshot();
        assert_eq!(s.count, 1000);
        assert!(s.p50_us <= s.p99_us);
        assert!(s.p99_us <= s.max_us.max(BUCKETS_US[14]));
        assert!(s.mean_us > 0.0);
    }

    /// Samples in the open-ended top bucket (> 819.2 ms) used to make
    /// every high percentile report `BUCKETS_US[15] = u64::MAX`; the
    /// snapshot now clamps bucket bounds to the observed max.
    #[test]
    fn top_bucket_percentiles_clamp_to_observed_max() {
        let m = Metrics::default();
        m.record(100);
        for _ in 0..10 {
            m.record(2_000_000); // top bucket: beyond 819_200 us
        }
        let s = m.snapshot();
        assert_eq!(s.max_us, 2_000_000);
        assert!(s.p99_us <= s.max_us, "p99 {} > max {}", s.p99_us, s.max_us);
        assert!(s.p999_us <= s.max_us);
        assert_ne!(s.p99_us, u64::MAX);
        assert!(s.p50_us <= s.p99_us && s.p99_us <= s.p999_us);
    }

    #[test]
    fn shed_and_expired_counters_accumulate() {
        let m = Metrics::default();
        m.record_shed();
        m.record_shed();
        m.record_expired(3);
        let s = m.snapshot();
        assert_eq!(s.shed, 2);
        assert_eq!(s.expired, 3);
    }

    #[test]
    fn gallery_counters_accumulate_and_len_is_a_gauge() {
        let m = Metrics::default();
        m.record_gallery(100, 100, 5, 40);
        m.record_gallery(250, 250, 9, 90);
        let s = m.snapshot();
        assert_eq!(s.gallery_len, 250);
        assert_eq!(s.gallery_scanned_rows, 350);
        assert_eq!(s.gallery_evictions, 14);
        assert_eq!(s.gallery_scan_us, 130);
    }

    #[test]
    fn batch_accounting() {
        let m = Metrics::default();
        m.record_batch(8);
        m.record_batch(4);
        assert!((m.snapshot().mean_batch - 6.0).abs() < 1e-9);
    }
}
