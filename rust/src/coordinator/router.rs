//! Typed workload router over compression-aware variant ladders.
//!
//! The router keys worker pools by **(workload, logical model)**: vision,
//! text, and joint requests dispatch to separate pools
//! ([`Workload`]), and within a pool each logical model ("vit", "bert",
//! "mm", ...) owns a ladder of variants ordered from most accurate
//! (mode=none) to most compressed.  Routing policy:
//!   * explicit [`Qos`] picks a rung directly;
//!   * under load (`Qos::Balanced` and the preferred rung saturated) the
//!     router *sheds to a more compressed variant* instead of queueing —
//!     the serving-side payoff of token merging that the paper's Table 5
//!     wall-times imply.
//!
//! Lookups borrow the model name (nested maps, no key construction), so
//! routing a request performs no heap allocations — part of the
//! end-to-end zero-alloc submit cycle (`tests/alloc_free.rs`).

use std::collections::HashMap;

use crate::error::{Error, Result};

use super::batcher::VariantWorker;
use super::request::{Qos, Workload};

/// One rung on a model's compression ladder.
pub struct Variant {
    /// artifact name (registry key)
    pub artifact: String,
    /// merge mode name
    pub mode: String,
    /// keep ratio (1.0 = uncompressed)
    pub r: f64,
    /// the running worker
    pub worker: VariantWorker,
}

/// Router over (workload, logical model) worker pools.
#[derive(Default)]
pub struct Router {
    pools: HashMap<Workload, HashMap<String, Vec<Variant>>>,
}

impl Router {
    /// Create an empty router.
    pub fn new() -> Self {
        Self::default()
    }

    /// Register a vision variant (back-compat shorthand for
    /// [`Router::add_variant_for`] with [`Workload::Vision`]).
    pub fn add_variant(&mut self, model: &str, v: Variant) {
        self.add_variant_for(Workload::Vision, model, v);
    }

    /// Register a variant under a workload pool; ladders keep
    /// most-accurate first (sorted by descending r, mode "none" treated
    /// as r=1.0+).
    pub fn add_variant_for(&mut self, workload: Workload, model: &str,
                           v: Variant) {
        let ladder = self
            .pools
            .entry(workload)
            .or_default()
            .entry(model.to_string())
            .or_default();
        ladder.push(v);
        ladder.sort_by(|a, b| {
            let ra = if a.mode == "none" { 1.5 } else { a.r };
            let rb = if b.mode == "none" { 1.5 } else { b.r };
            rb.partial_cmp(&ra).unwrap()
        });
    }

    /// Known vision-workload logical models (back-compat).
    pub fn models(&self) -> Vec<&str> {
        self.models_for(Workload::Vision)
    }

    /// Known logical models under a workload, sorted by name.
    // lint: allow(alloc) reason=introspection helper for boot/tests, not a routing path
    pub fn models_for(&self, workload: Workload) -> Vec<&str> {
        let mut out: Vec<&str> = self
            .pools
            .get(&workload)
            .map(|m| m.keys().map(String::as_str).collect())
            .unwrap_or_default();
        out.sort_unstable();
        out
    }

    /// Every registered (workload, model, ladder), ordered by workload
    /// then model name (deterministic for metrics/reporting).
    // lint: allow(alloc) reason=observability enumeration, not a routing path
    pub fn iter(&self) -> Vec<(Workload, &str, &[Variant])> {
        let mut out: Vec<(Workload, &str, &[Variant])> = self
            .pools
            .iter()
            .flat_map(|(w, models)| {
                models.iter().map(|(m, l)| (*w, m.as_str(), l.as_slice()))
            })
            .collect();
        out.sort_by(|a, b| (a.0, a.1).cmp(&(b.0, b.1)));
        out
    }

    /// Queue depth of every variant: (workload, model, artifact, depth) —
    /// the per-workload admission signal `coordinator_bench` reports.
    // lint: allow(alloc) reason=observability snapshot clones names, not a routing path
    pub fn queue_depths(&self) -> Vec<(Workload, String, String, usize)> {
        self.iter()
            .into_iter()
            .flat_map(|(w, m, ladder)| {
                ladder.iter().map(move |v| {
                    (w, m.to_string(), v.artifact.clone(), v.worker.depth())
                })
            })
            .collect()
    }

    /// The ladder of a vision-workload model (back-compat).
    pub fn ladder(&self, model: &str) -> Result<&[Variant]> {
        self.ladder_for(Workload::Vision, model)
    }

    /// The ladder of a model under a workload (borrowed lookup — no
    /// allocation on the routing hot path).
    // lint: allow(alloc) reason=error-path format! only, never taken on the steady-state path
    pub fn ladder_for(&self, workload: Workload, model: &str)
                      -> Result<&[Variant]> {
        self.pools
            .get(&workload)
            .and_then(|m| m.get(model))
            .map(|v| v.as_slice())
            .ok_or_else(|| Error::Coordinator(format!(
                "unknown {} model {model}", workload.name())))
    }

    /// Pick a vision variant for a request (back-compat).
    pub fn route(&self, model: &str, qos: Qos) -> Result<&Variant> {
        self.route_for(Workload::Vision, model, qos)
    }

    /// Pick a variant for a typed request.
    // lint: allow(alloc) reason=error-path format! only, never taken on the steady-state path
    pub fn route_for(&self, workload: Workload, model: &str, qos: Qos)
                     -> Result<&Variant> {
        let ladder = self.ladder_for(workload, model)?;
        if ladder.is_empty() {
            return Err(Error::Coordinator(format!(
                "{} model {model} has no variants", workload.name())));
        }
        let v = match qos {
            Qos::Accuracy => &ladder[0],
            Qos::Throughput => &ladder[ladder.len() - 1],
            Qos::Balanced => {
                // preferred = most-compressed-but-one if available
                let pref = if ladder.len() > 1 { 1 } else { 0 };
                // shed to deeper compression when saturated
                let mut pick = pref;
                while pick + 1 < ladder.len() && !ladder[pick].worker.has_capacity() {
                    pick += 1;
                }
                &ladder[pick]
            }
        };
        Ok(v)
    }
}
