//! Compression-aware variant router.
//!
//! Each logical model ("vit", "bert", ...) owns a ladder of compiled
//! variants ordered from most accurate (mode=none) to most compressed.
//! Routing policy:
//!   * explicit [`Qos`] picks a rung directly;
//!   * under load (`Qos::Balanced` and the preferred rung saturated) the
//!     router *sheds to a more compressed variant* instead of queueing —
//!     the serving-side payoff of token merging that the paper's Table 5
//!     wall-times imply.

use std::collections::HashMap;

use crate::error::{Error, Result};

use super::batcher::VariantWorker;
use super::request::Qos;

/// One rung on a model's compression ladder.
pub struct Variant {
    /// artifact name (registry key)
    pub artifact: String,
    /// merge mode name
    pub mode: String,
    /// keep ratio (1.0 = uncompressed)
    pub r: f64,
    /// the running worker
    pub worker: VariantWorker,
}

/// Router over logical models.
#[derive(Default)]
pub struct Router {
    ladders: HashMap<String, Vec<Variant>>,
}

impl Router {
    /// Create an empty router.
    pub fn new() -> Self {
        Self::default()
    }

    /// Register a variant; ladders keep most-accurate first (sorted by
    /// descending r, mode "none" treated as r=1.0+).
    pub fn add_variant(&mut self, model: &str, v: Variant) {
        let ladder = self.ladders.entry(model.to_string()).or_default();
        ladder.push(v);
        ladder.sort_by(|a, b| {
            let ra = if a.mode == "none" { 1.5 } else { a.r };
            let rb = if b.mode == "none" { 1.5 } else { b.r };
            rb.partial_cmp(&ra).unwrap()
        });
    }

    /// Known logical models.
    pub fn models(&self) -> Vec<&str> {
        self.ladders.keys().map(|s| s.as_str()).collect()
    }

    /// The ladder of a model.
    pub fn ladder(&self, model: &str) -> Result<&[Variant]> {
        self.ladders
            .get(model)
            .map(|v| v.as_slice())
            .ok_or_else(|| Error::Coordinator(format!("unknown model {model}")))
    }

    /// Pick a variant for a request.
    pub fn route(&self, model: &str, qos: Qos) -> Result<&Variant> {
        let ladder = self.ladder(model)?;
        if ladder.is_empty() {
            return Err(Error::Coordinator(format!("model {model} has no variants")));
        }
        let v = match qos {
            Qos::Accuracy => &ladder[0],
            Qos::Throughput => &ladder[ladder.len() - 1],
            Qos::Balanced => {
                // preferred = most-compressed-but-one if available
                let pref = if ladder.len() > 1 { 1 } else { 0 };
                // shed to deeper compression when saturated
                let mut pick = pref;
                while pick + 1 < ladder.len() && !ladder[pick].worker.has_capacity() {
                    pick += 1;
                }
                &ladder[pick]
            }
        };
        Ok(v)
    }
}
