//! L3 serving coordinator (the system contribution around the paper's
//! algorithm): typed workload routing over compression ladders, dynamic
//! batching with a ragged joint-batch splitter, admission control,
//! response-buffer recycling, and metrics.
//!
//! Shape: vLLM-router-like.  Requests are typed by [`Workload`]
//! (vision / text / joint / gallery); each workload owns worker pools whose
//! logical models ladder variants compiled (or configured) at different
//! merge ratios.  The router picks a rung per request QoS and sheds to
//! deeper compression under load; each variant has a dedicated batcher
//! thread feeding its session (CPU) or PJRT executable.  Response
//! tensors are checked out of a shared [`TensorPool`] and return to it
//! when the caller drops the [`InferResponse`] — the full
//! request→response→release cycle is allocation-free once warm.
//!
//! The [`harness`] module closes the loop: it replays typed arrival
//! traces ([`crate::data::generate_trace`]) against a booted coordinator
//! through the admission-controlled submit path
//! ([`Coordinator::try_submit_pooled`]), measuring goodput, shed rate,
//! and latency percentiles under open- and closed-loop load.

pub mod batcher;
pub mod harness;
pub mod metrics;
pub mod pool;
pub mod request;
pub mod router;
pub mod server;

pub use batcher::VariantWorker;
pub use harness::{run_load, LoadOptions, LoadReport, WorkloadReport};
pub use metrics::{Metrics, Snapshot};
pub use pool::{PooledTensor, TensorPool};
pub use request::{Admission, InferOutputs, InferRequest, InferResponse,
                  Payload, Qos, Responder, ResponseSlot, Workload};
pub use router::{Router, Variant};
pub use server::{Coordinator, CpuWorkloads};
