//! L3 serving coordinator (the system contribution around the paper's
//! algorithm): request routing over a compression ladder, dynamic batching,
//! admission control, and metrics.
//!
//! Shape: vLLM-router-like.  Each logical model owns variants compiled at
//! different merge ratios; the router picks a rung per request QoS and
//! sheds to deeper compression under load; each variant has a dedicated
//! batcher thread feeding the PJRT executable.

pub mod batcher;
pub mod metrics;
pub mod request;
pub mod router;
pub mod server;

pub use batcher::VariantWorker;
pub use metrics::{Metrics, Snapshot};
pub use request::{InferRequest, InferResponse, Qos};
pub use router::{Router, Variant};
pub use server::Coordinator;
