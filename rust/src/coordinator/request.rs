//! Request/response types crossing the coordinator boundary.

use std::sync::mpsc;
use std::time::Instant;

use crate::runtime::HostTensor;

/// A single-sample inference request (no batch dimension; the batcher adds
/// it).  `inputs` holds the per-sample tensors in artifact order, *without*
/// the leading params tensor (the worker prepends it).
pub struct InferRequest {
    /// per-sample input tensors
    pub inputs: Vec<HostTensor>,
    /// enqueue timestamp (set by the coordinator)
    pub enqueued_at: Instant,
    /// response channel (single-shot)
    pub respond: mpsc::Sender<InferResponse>,
}

/// The coordinator's reply.
#[derive(Clone, Debug)]
pub struct InferResponse {
    /// per-sample output tensors (batch dim stripped)
    pub outputs: Vec<HostTensor>,
    /// microseconds spent queued before execution began
    pub queue_us: u64,
    /// microseconds of PJRT execution (shared by the whole batch)
    pub exec_us: u64,
    /// how many requests shared the batch
    pub batch_size: usize,
}

/// Quality-of-service class used by the router to pick a variant.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Qos {
    /// maximize accuracy: uncompressed variant
    Accuracy,
    /// balanced: the default compressed variant
    Balanced,
    /// minimize latency/FLOPs: most compressed variant
    Throughput,
}
