//! Request/response types crossing the coordinator boundary.
//!
//! Requests are **typed by workload** ([`Workload`]): the router keeps a
//! separate worker pool per workload, and a request's [`Payload`] names
//! which tower(s) it exercises.  Hot-path payloads carry
//! [`PooledTensor`]s from the coordinator's [`TensorPool`](super::pool::TensorPool),
//! so the whole request→response→release cycle recycles buffers instead
//! of allocating; the legacy `Vec<HostTensor>` form remains for the PJRT
//! artifact path and the untyped `submit` convenience.

use std::sync::mpsc;
use std::time::Instant;

use crate::error::{Error, Result};
use crate::runtime::HostTensor;

use super::pool::PooledTensor;

/// The workload class a request belongs to; the router dispatches each
/// class to its own worker pool.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Workload {
    /// single-tower vision inference (patches → class logits)
    Vision,
    /// single-tower text inference (token ids → class logits)
    Text,
    /// joint vision+text inference (retrieval scoring / VQA)
    Joint,
    /// embedding-gallery serving: ingest embeds an item once into the
    /// gallery store, query embeds one probe and scans the store
    Gallery,
}

impl Workload {
    /// Stable display name.
    pub fn name(&self) -> &'static str {
        match self {
            Workload::Vision => "vision",
            Workload::Text => "text",
            Workload::Joint => "joint",
            Workload::Gallery => "gallery",
        }
    }
}

/// What a request carries.  The joint worker's ragged-batch splitter
/// sizes a batch's vision half ([`Payload::Vision`] + [`Payload::Joint`])
/// and text half ([`Payload::Text`] + [`Payload::Joint`]) independently.
pub enum Payload {
    /// legacy/PJRT form: per-sample tensors in artifact order (without
    /// the leading params tensor; the worker prepends it)
    Tensors(Vec<HostTensor>),
    /// one patches tensor (f32, `(n_patches, patch_dim)`)
    Vision(PooledTensor),
    /// one token-id tensor (i32, `(tokens,)`)
    Text(PooledTensor),
    /// a paired (patches, token-ids) sample — e.g. a VQA
    /// (image, question) request
    Joint {
        /// patches tensor (f32)
        vision: PooledTensor,
        /// token-id tensor (i32)
        text: PooledTensor,
    },
    /// gallery ingest: embed this item once and append it to the
    /// gallery store.  An f32 patches tensor goes through the image
    /// tower, an i32 token-id tensor through the text tower.  The
    /// response is `[id, gallery_len]` as f32 (ids are exact below
    /// 2^24).
    GalleryIngest(PooledTensor),
    /// gallery query: embed the probe once, scan the store, and answer
    /// the best `k` hits as an f32 tensor of shape `(hits, 2)` with
    /// `[id, score]` rows (`hits = min(k, gallery_len)`)
    GalleryQuery {
        /// probe tensor — f32 patches (image tower) or i32 token ids
        /// (text tower)
        probe: PooledTensor,
        /// number of hits requested
        k: usize,
    },
}

impl Payload {
    /// The patches tensor this payload contributes to a batch's vision
    /// half, if any (legacy `Tensors` payloads contribute their first).
    pub fn vision_tensor(&self) -> Option<&HostTensor> {
        match self {
            Payload::Tensors(v) => v.first(),
            Payload::Vision(t) => Some(t.tensor()),
            Payload::Joint { vision, .. } => Some(vision.tensor()),
            Payload::Text(_) => None,
            // gallery payloads route by dtype inside the gallery
            // worker, not through the joint splitter
            Payload::GalleryIngest(_) | Payload::GalleryQuery { .. } => None,
        }
    }

    /// The token-id tensor this payload contributes to a batch's text
    /// half, if any (legacy `Tensors` payloads contribute their second
    /// tensor when present, else their first — the two-tensor form is
    /// the legacy joint pair `[patches, question]`).
    pub fn text_tensor(&self) -> Option<&HostTensor> {
        match self {
            Payload::Tensors(v) if v.len() >= 2 => v.get(1),
            Payload::Tensors(v) => v.first(),
            Payload::Text(t) => Some(t.tensor()),
            Payload::Joint { text, .. } => Some(text.tensor()),
            Payload::Vision(_) => None,
            Payload::GalleryIngest(_) | Payload::GalleryQuery { .. } => None,
        }
    }

    /// The artifact-order tensor list (PJRT workers only).
    pub fn artifact_tensors(&self) -> Result<&[HostTensor]> {
        match self {
            Payload::Tensors(v) => Ok(v),
            _ => Err(Error::Coordinator(
                "PJRT workers take Payload::Tensors in artifact order".into())),
        }
    }
}

/// Where a response goes.  [`Responder::Slot`] targets a reusable
/// bounded [`ResponseSlot`] channel — the zero-allocation transport —
/// while [`Responder::Channel`] is the per-request unbounded channel the
/// legacy submit convenience creates.
pub enum Responder {
    /// per-request unbounded channel (allocates per send; legacy path)
    Channel(mpsc::Sender<InferResponse>),
    /// reusable bounded client slot (allocation-free sends once warm)
    Slot(mpsc::SyncSender<InferResponse>),
}

impl Responder {
    /// Deliver the response; `false` when it could not be delivered (the
    /// response is dropped and its pooled buffers recycle).  Slot sends
    /// never block the worker: a client that stopped draining its
    /// [`ResponseSlot`] (buffer full) loses the response instead of
    /// wedging the batcher thread for every other client — size the slot
    /// to the client's maximum in-flight requests
    /// (`Coordinator::response_slot` uses the worker queue capacity).
    pub fn send(&self, resp: InferResponse) -> bool {
        match self {
            Responder::Channel(tx) => tx.send(resp).is_ok(),
            Responder::Slot(tx) => tx.try_send(resp).is_ok(),
        }
    }

    /// True when this responder targets a reusable [`ResponseSlot`].
    pub fn is_slot(&self) -> bool {
        matches!(self, Responder::Slot(_))
    }
}

/// Outcome of a non-blocking admission attempt
/// (`Coordinator::try_submit_pooled` / `VariantWorker::submit_shed`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Admission {
    /// the request was enqueued and will receive exactly one response
    /// (or failure/expiry marker) on its responder
    Admitted,
    /// the queue was full; the request was refused without blocking and
    /// counted in the worker's `shed` metric — nothing will arrive on
    /// the responder
    Shed,
}

/// A single-sample inference request (no batch dimension; the batcher
/// adds it).
pub struct InferRequest {
    /// what the request carries
    pub payload: Payload,
    /// enqueue timestamp (set by the coordinator)
    pub enqueued_at: Instant,
    /// absolute deadline; the worker drops the request (counted, with an
    /// expiry marker to slot responders) if this has passed when its
    /// batch is picked up
    pub deadline: Option<Instant>,
    /// response destination
    pub respond: Responder,
}

/// Per-request outputs: exactly one tensor for every CPU workload (the
/// allocation-free form), or a list for multi-output PJRT artifacts.
#[derive(Debug)]
pub enum InferOutputs {
    /// single output tensor (CPU serving paths)
    One(PooledTensor),
    /// multi-output artifact results
    Many(Vec<PooledTensor>),
}

impl InferOutputs {
    /// Number of output tensors.
    pub fn len(&self) -> usize {
        match self {
            InferOutputs::One(_) => 1,
            InferOutputs::Many(v) => v.len(),
        }
    }

    /// True when there are no outputs.
    pub fn is_empty(&self) -> bool {
        match self {
            InferOutputs::One(_) => false,
            InferOutputs::Many(v) => v.is_empty(),
        }
    }

    /// First output tensor, if any.
    pub fn first(&self) -> Option<&PooledTensor> {
        match self {
            InferOutputs::One(t) => Some(t),
            InferOutputs::Many(v) => v.first(),
        }
    }
}

impl std::ops::Index<usize> for InferOutputs {
    type Output = PooledTensor;

    fn index(&self, i: usize) -> &PooledTensor {
        match self {
            InferOutputs::One(t) => {
                assert_eq!(i, 0, "single-output response indexed at {i}");
                t
            }
            InferOutputs::Many(v) => &v[i],
        }
    }
}

/// The coordinator's reply.  Dropping it returns every pooled output
/// buffer to the coordinator's [`TensorPool`](super::pool::TensorPool)
/// automatically — consumers cannot leak pool capacity.
#[derive(Debug)]
pub struct InferResponse {
    /// per-sample output tensors (batch dim stripped)
    pub outputs: InferOutputs,
    /// microseconds spent queued before execution began
    pub queue_us: u64,
    /// microseconds of batch execution (shared by the whole batch)
    pub exec_us: u64,
    /// how many requests shared the batch
    pub batch_size: usize,
}

/// A reusable bounded response channel: create one per client thread,
/// pass it to `Coordinator::submit_pooled`, and `recv` replies from it.
/// The channel's ring buffer is allocated once here, so steady-state
/// response delivery allocates nothing.
///
/// Because the slot keeps its own sender alive (that is what makes it
/// reusable), a failed batch cannot surface as a closed channel the way
/// the legacy per-request path does.  Workers instead deliver an
/// explicit **failure marker** (a response with no outputs) for every
/// slot-targeted request they drop; [`ResponseSlot::recv`] /
/// [`ResponseSlot::try_recv`] translate it back into an error, so a
/// blocked client always wakes up.
pub struct ResponseSlot {
    tx: mpsc::SyncSender<InferResponse>,
    rx: mpsc::Receiver<InferResponse>,
}

impl ResponseSlot {
    /// New slot holding at most `capacity` undelivered responses (size
    /// it to the client's maximum in-flight requests: slot sends are
    /// non-blocking, so overflowing responses are dropped).
    pub fn new(capacity: usize) -> ResponseSlot {
        let (tx, rx) = mpsc::sync_channel(capacity.max(1));
        ResponseSlot { tx, rx }
    }

    /// The sender half a request carries back here.
    pub(super) fn sender(&self) -> mpsc::SyncSender<InferResponse> {
        self.tx.clone()
    }

    /// Reject the worker's failure/expiry markers as errors.  Expiry
    /// markers (deadline passed before execution) carry `batch_size: 0`;
    /// batch-failure markers report the failed batch's size.
    fn check(r: InferResponse) -> Result<InferResponse> {
        if r.outputs.is_empty() {
            if r.batch_size == 0 {
                return Err(Error::Coordinator(
                    "request deadline expired before execution".into()));
            }
            return Err(Error::Coordinator(
                "worker failed the batch and dropped the request".into()));
        }
        Ok(r)
    }

    /// Block until the next response arrives (`Err` when the worker
    /// failed the batch this request was in).
    pub fn recv(&self) -> Result<InferResponse> {
        let r = self
            .rx
            .recv()
            .map_err(|_| Error::Coordinator("worker dropped request".into()))?;
        Self::check(r)
    }

    /// Non-blocking receive (`Ok(None)` when nothing is pending).
    pub fn try_recv(&self) -> Result<Option<InferResponse>> {
        match self.rx.try_recv() {
            Ok(r) => Self::check(r).map(Some),
            Err(mpsc::TryRecvError::Empty) => Ok(None),
            Err(mpsc::TryRecvError::Disconnected) => {
                Err(Error::Coordinator("worker dropped request".into()))
            }
        }
    }
}

/// Quality-of-service class used by the router to pick a variant.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Qos {
    /// maximize accuracy: uncompressed variant
    Accuracy,
    /// balanced: the default compressed variant
    Balanced,
    /// minimize latency/FLOPs: most compressed variant
    Throughput,
}
