//! Parameter store: loads the flat f32 weight vectors + JSON manifests that
//! `python/compile/params.py` writes, exposing named tensors to the CPU
//! reference model and raw flat vectors to the PJRT runtime.  Also builds
//! randomly-initialized synthetic stores so model-level tests, benches,
//! and CPU serving run without `make artifacts`.

use std::collections::HashMap;
use std::path::Path;

use crate::config::{TextConfig, ViTConfig};
use crate::data::Rng;
use crate::error::{Error, Result};
use crate::tensor::{Mat, MatRef};
use crate::util::json::{parse as parse_json, Json};

/// One manifest entry.
#[derive(Clone, Debug)]
pub struct ParamEntry {
    /// parameter name (e.g. "vit.blk0.wq")
    pub name: String,
    /// tensor shape
    pub shape: Vec<usize>,
    /// offset into the flat vector
    pub offset: usize,
    /// element count
    pub size: usize,
}

/// Resolved location of a 1-D parameter inside the store's flat vector.
/// Spans are plain offsets — no borrow — so weight resolutions can be
/// cached owned (see [`crate::engine::Engine`]) and turned back into
/// slices with [`ParamStore::vec_at`] at zero cost.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct VecSpan {
    /// offset into the flat vector
    pub offset: usize,
    /// element count
    pub len: usize,
}

/// Resolved location of a 2-D parameter inside the store's flat vector
/// (the owned counterpart of [`MatRef`]; rehydrate with
/// [`ParamStore::mat_at`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MatSpan {
    /// offset into the flat vector
    pub offset: usize,
    /// number of rows
    pub rows: usize,
    /// number of columns
    pub cols: usize,
}

/// Named parameter tensors plus the original flat vector.
pub struct ParamStore {
    /// the flat f32 vector (fed to PJRT artifacts as-is)
    pub flat: Vec<f32>,
    entries: HashMap<String, ParamEntry>,
}

impl ParamStore {
    /// Load `<stem>.bin` + `<stem>.json` (as written by `save_params`).
    pub fn load(bin: &Path, manifest: &Path) -> Result<ParamStore> {
        let raw = std::fs::read(bin)?;
        if raw.len() % 4 != 0 {
            return Err(Error::Artifact(format!(
                "params bin {} not a multiple of 4 bytes", bin.display())));
        }
        let flat: Vec<f32> = raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        let root = parse_json(&std::fs::read_to_string(manifest)?)?;
        let total = root.get("total").and_then(Json::usize)
            .ok_or_else(|| Error::Json("params manifest missing total".into()))?;
        if total != flat.len() {
            return Err(Error::Artifact(format!(
                "manifest total {} != bin length {}", total, flat.len())));
        }
        let mut entries = HashMap::new();
        for v in root.get("entries").and_then(Json::arr)
            .ok_or_else(|| Error::Json("params manifest missing entries".into()))? {
            let e = ParamEntry {
                name: v.get("name").and_then(Json::str)
                    .ok_or_else(|| Error::Json("entry missing name".into()))?.into(),
                shape: v.get("shape").and_then(Json::usize_vec)
                    .ok_or_else(|| Error::Json("entry missing shape".into()))?,
                offset: v.get("offset").and_then(Json::usize)
                    .ok_or_else(|| Error::Json("entry missing offset".into()))?,
                size: v.get("size").and_then(Json::usize)
                    .ok_or_else(|| Error::Json("entry missing size".into()))?,
            };
            entries.insert(e.name.clone(), e);
        }
        Ok(ParamStore { flat, entries })
    }

    /// Build directly from in-memory parts (tests).
    pub fn from_parts(flat: Vec<f32>, entries: Vec<ParamEntry>) -> ParamStore {
        let map = entries.into_iter().map(|e| (e.name.clone(), e)).collect();
        ParamStore { flat, entries: map }
    }

    fn entry(&self, name: &str) -> Result<&ParamEntry> {
        self.entries
            .get(name)
            .ok_or_else(|| Error::Artifact(format!("missing param {name}")))
    }

    /// Slice view of a parameter.
    pub fn slice(&self, name: &str) -> Result<&[f32]> {
        let e = self.entry(name)?;
        Ok(&self.flat[e.offset..e.offset + e.size])
    }

    /// 1-D parameter as a vector slice.
    pub fn vec1(&self, name: &str) -> Result<&[f32]> {
        let e = self.entry(name)?;
        if e.shape.len() != 1 {
            return Err(Error::Shape(format!(
                "{name} has shape {:?}, expected 1-D", e.shape)));
        }
        self.slice(name)
    }

    /// 2-D parameter as a borrowed view over the flat vector (no copy —
    /// the scratch-workspace forward resolves all weights through this
    /// once per call, so the layer loop never clones a weight matrix).
    pub fn mat2_view(&self, name: &str) -> Result<MatRef<'_>> {
        let e = self.entry(name)?;
        if e.shape.len() != 2 {
            return Err(Error::Shape(format!(
                "{name} has shape {:?}, expected 2-D", e.shape)));
        }
        Ok(MatRef {
            rows: e.shape[0],
            cols: e.shape[1],
            data: &self.flat[e.offset..e.offset + e.size],
        })
    }

    /// Resolve a 1-D parameter to its [`VecSpan`] (one name lookup; the
    /// span stays valid for the store's lifetime).
    pub fn vec1_span(&self, name: &str) -> Result<VecSpan> {
        let e = self.entry(name)?;
        if e.shape.len() != 1 {
            return Err(Error::Shape(format!(
                "{name} has shape {:?}, expected 1-D", e.shape)));
        }
        Ok(VecSpan { offset: e.offset, len: e.size })
    }

    /// Resolve a 2-D parameter to its [`MatSpan`] (one name lookup; the
    /// span stays valid for the store's lifetime).
    pub fn mat2_span(&self, name: &str) -> Result<MatSpan> {
        let e = self.entry(name)?;
        if e.shape.len() != 2 {
            return Err(Error::Shape(format!(
                "{name} has shape {:?}, expected 2-D", e.shape)));
        }
        Ok(MatSpan { offset: e.offset, rows: e.shape[0], cols: e.shape[1] })
    }

    /// Slice behind a resolved [`VecSpan`] (no lookup, no copy).
    #[inline]
    pub fn vec_at(&self, s: VecSpan) -> &[f32] {
        &self.flat[s.offset..s.offset + s.len]
    }

    /// Borrowed matrix view behind a resolved [`MatSpan`] (no lookup, no
    /// copy).
    #[inline]
    pub fn mat_at(&self, s: MatSpan) -> MatRef<'_> {
        MatRef {
            rows: s.rows,
            cols: s.cols,
            data: &self.flat[s.offset..s.offset + s.rows * s.cols],
        }
    }

    /// 2-D parameter as a Mat copy.
    pub fn mat2(&self, name: &str) -> Result<Mat> {
        let e = self.entry(name)?;
        if e.shape.len() != 2 {
            return Err(Error::Shape(format!(
                "{name} has shape {:?}, expected 2-D", e.shape)));
        }
        Ok(Mat::from_vec(e.shape[0], e.shape[1],
                         self.slice(name)?.to_vec()))
    }

    /// Parameter count.
    pub fn len(&self) -> usize {
        self.flat.len()
    }

    /// True when no parameters are loaded.
    pub fn is_empty(&self) -> bool {
        self.flat.is_empty()
    }
}

/// Incremental builder for in-memory [`ParamStore`]s (tests / synthetic
/// weights).
struct StoreBuilder {
    flat: Vec<f32>,
    entries: Vec<ParamEntry>,
    rng: Rng,
}

impl StoreBuilder {
    fn new(seed: u64) -> StoreBuilder {
        StoreBuilder { flat: Vec::new(), entries: Vec::new(), rng: Rng::new(seed) }
    }

    /// Append a tensor filled by `f` (which may draw from the RNG).
    fn push(&mut self, name: &str, shape: &[usize],
            mut f: impl FnMut(&mut Rng) -> f32) {
        let size: usize = shape.iter().product();
        let offset = self.flat.len();
        for _ in 0..size {
            let v = f(&mut self.rng);
            self.flat.push(v);
        }
        self.entries.push(ParamEntry {
            name: name.into(),
            shape: shape.to_vec(),
            offset,
            size,
        });
    }

    fn randn_scaled(&mut self, name: &str, shape: &[usize], scale: f32) {
        self.push(name, shape, |rng| (rng.next_f64() * 2.0 - 1.0) as f32 * scale);
    }

    fn constant(&mut self, name: &str, shape: &[usize], value: f32) {
        self.push(name, shape, |_| value);
    }

    fn finish(self) -> ParamStore {
        ParamStore::from_parts(self.flat, self.entries)
    }
}

/// Push one transformer block's tensors under `prefix` (shared by the
/// ViT and every text tower — same naming scheme as `python/compile`).
fn push_blocks(b: &mut StoreBuilder, prefix: &str, dim: usize,
               hidden: usize, depth: usize) {
    let scale = 1.0 / (dim as f32).sqrt();
    for l in 0..depth {
        let p = format!("{prefix}blk{l}.");
        b.constant(&format!("{p}ln1.w"), &[dim], 1.0);
        b.constant(&format!("{p}ln1.b"), &[dim], 0.0);
        b.randn_scaled(&format!("{p}wq"), &[dim, dim], scale);
        b.randn_scaled(&format!("{p}wk"), &[dim, dim], scale);
        b.randn_scaled(&format!("{p}wv"), &[dim, dim], scale);
        b.randn_scaled(&format!("{p}wo"), &[dim, dim], scale);
        b.constant(&format!("{p}bo"), &[dim], 0.0);
        b.constant(&format!("{p}ln2.w"), &[dim], 1.0);
        b.constant(&format!("{p}ln2.b"), &[dim], 0.0);
        b.randn_scaled(&format!("{p}mlp1"), &[dim, hidden], scale);
        b.constant(&format!("{p}mlp1b"), &[hidden], 0.0);
        b.randn_scaled(&format!("{p}mlp2"), &[hidden, dim],
                       1.0 / (hidden as f32).sqrt());
        b.constant(&format!("{p}mlp2b"), &[dim], 0.0);
    }
    b.constant(&format!("{prefix}lnf.w"), &[dim], 1.0);
    b.constant(&format!("{prefix}lnf.b"), &[dim], 0.0);
}

/// Push every ViT tensor (embed / cls / pos / blocks / lnf / head).
fn push_vit(b: &mut StoreBuilder, cfg: &ViTConfig) {
    let dim = cfg.dim;
    let scale = 1.0 / (dim as f32).sqrt();
    b.randn_scaled("vit.embed.w", &[cfg.patch_dim(), dim], scale);
    b.constant("vit.embed.b", &[dim], 0.0);
    b.randn_scaled("vit.cls", &[dim], scale);
    b.randn_scaled("vit.pos", &[cfg.n_tokens(), dim], 0.02);
    push_blocks(b, "vit.", dim, cfg.mlp_hidden(), cfg.depth);
    b.randn_scaled("vit.head.w", &[dim, cfg.num_classes], scale);
    b.constant("vit.head.b", &[cfg.num_classes], 0.0);
}

/// Push a text-encoder tower under `prefix` (tok / pos / blocks / lnf —
/// mirror of `python/compile/model.py::init_text_encoder`).
fn push_text_encoder(b: &mut StoreBuilder, prefix: &str, vocab: usize,
                     n_tokens: usize, dim: usize, hidden: usize,
                     depth: usize) {
    b.randn_scaled(&format!("{prefix}tok"), &[vocab, dim], 0.02);
    b.randn_scaled(&format!("{prefix}pos"), &[n_tokens, dim], 0.02);
    push_blocks(b, prefix, dim, hidden, depth);
}

/// Build a randomly-initialized [`ParamStore`] covering every tensor the
/// CPU reference ViT needs (`vit.embed` / `vit.cls` / `vit.pos` /
/// per-block weights / `vit.lnf` / `vit.head`).
///
/// The weights are untrained — predictions are arbitrary but fully
/// deterministic in `seed` — which is exactly what encoder-parity tests,
/// merge benches, and artifact-free CPU serving need.
pub fn synthetic_vit_store(cfg: &ViTConfig, seed: u64) -> ParamStore {
    let mut b = StoreBuilder::new(seed);
    push_vit(&mut b, cfg);
    b.finish()
}

/// Push the BERT classifier (text tower + head) a [`TextConfig`] names.
fn push_bert(b: &mut StoreBuilder, cfg: &TextConfig) {
    let dim = cfg.dim;
    let hidden = (dim as f64 * cfg.mlp_ratio) as usize;
    push_text_encoder(b, "bert.", cfg.vocab_size, cfg.n_tokens(), dim,
                      hidden, cfg.depth);
    b.randn_scaled("bert.head.w", &[dim, cfg.num_classes],
                   1.0 / (dim as f32).sqrt());
    b.constant("bert.head.b", &[cfg.num_classes], 0.0);
}

/// Build a randomly-initialized [`ParamStore`] covering every tensor the
/// BERT-style text classifier path names (`bert.tok` / `bert.pos` /
/// per-block weights / `bert.lnf` / `bert.head`) — the text counterpart
/// of [`synthetic_vit_store`].
pub fn synthetic_bert_store(cfg: &TextConfig, seed: u64) -> ParamStore {
    let mut b = StoreBuilder::new(seed);
    push_bert(&mut b, cfg);
    b.finish()
}

/// Hidden width of the synthetic joint VQA head (mirror of
/// `python/compile/vqa.py`: `vqa.fc1` maps the concatenated
/// vision+question feature to 128 units before the answer head).
pub const MM_VQA_HIDDEN: usize = 128;
/// Embedding/text-tower width of the synthetic multimodal towers
/// (mirror of `clip.py::ClipConfig` / `vqa.py::VqaConfig`: text_dim =
/// embed_dim = 64, text_depth = 2, MLP hidden = text_dim * 2).
pub const MM_TEXT_DIM: usize = 64;
/// Depth of the synthetic multimodal text towers.
pub const MM_TEXT_DEPTH: usize = 2;

/// Build a randomly-initialized [`ParamStore`] covering the **whole
/// multimodal serving surface** in one store: the ViT vision tower
/// (`vit.*`, including the classifier head), the BERT classifier
/// (`bert.*` at [`TextConfig::default`] shapes), the CLIP caption tower
/// + projections (`txt.*`, `proj.img`, `proj.txt`), and the VQA question
/// tower + answer head (`q.*`, `vqa.fc1[b]`, `vqa.head.{w,b}`).
///
/// Tower hyperparameters mirror `python/compile/{clip,vqa}.py` (text
/// dim 64, depth 2, heads 4, MLP hidden 128, caption/question length
/// `CAP_LEN + 1`, vocab `VOCAB`), so the store drives every eval path
/// and the mixed-workload coordinator without `make artifacts`.  The
/// `vit.*` tensors are generated first from the same RNG stream, so they
/// are bit-identical to `synthetic_vit_store(cfg, seed)`.
pub fn synthetic_mm_store(cfg: &ViTConfig, seed: u64) -> ParamStore {
    use crate::data::{CAP_LEN, N_ANSWERS, VOCAB};
    let tdim = MM_TEXT_DIM;
    let tscale = 1.0 / (tdim as f32).sqrt();
    let mut b = StoreBuilder::new(seed);
    push_vit(&mut b, cfg);
    // BERT classifier tower at the default text-config shapes
    push_bert(&mut b, &TextConfig::default());
    // CLIP caption tower + shared-embedding projections
    push_text_encoder(&mut b, "txt.", VOCAB, CAP_LEN + 1, tdim, tdim * 2,
                      MM_TEXT_DEPTH);
    b.randn_scaled("proj.img", &[cfg.dim, tdim],
                   1.0 / (cfg.dim as f32).sqrt());
    b.randn_scaled("proj.txt", &[tdim, tdim], tscale);
    // VQA question tower + joint answer head
    push_text_encoder(&mut b, "q.", VOCAB, CAP_LEN + 1, tdim, tdim * 2,
                      MM_TEXT_DEPTH);
    let joint = cfg.dim + tdim;
    b.randn_scaled("vqa.fc1", &[joint, MM_VQA_HIDDEN],
                   1.0 / (joint as f32).sqrt());
    b.constant("vqa.fc1b", &[MM_VQA_HIDDEN], 0.0);
    b.randn_scaled("vqa.head.w", &[MM_VQA_HIDDEN, N_ANSWERS],
                   1.0 / (MM_VQA_HIDDEN as f32).sqrt());
    b.constant("vqa.head.b", &[N_ANSWERS], 0.0);
    b.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn store() -> ParamStore {
        ParamStore::from_parts(
            vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0],
            vec![
                ParamEntry { name: "w".into(), shape: vec![2, 2], offset: 0, size: 4 },
                ParamEntry { name: "b".into(), shape: vec![2], offset: 4, size: 2 },
            ],
        )
    }

    #[test]
    fn mat2_and_vec1() {
        let s = store();
        let w = s.mat2("w").unwrap();
        assert_eq!(w.get(1, 0), 3.0);
        assert_eq!(s.vec1("b").unwrap(), &[5.0, 6.0]);
    }

    #[test]
    fn wrong_rank_errors() {
        let s = store();
        assert!(s.mat2("b").is_err());
        assert!(s.mat2_view("b").is_err());
        assert!(s.vec1("w").is_err());
        assert!(s.slice("nope").is_err());
    }

    #[test]
    fn mat2_view_aliases_flat_storage() {
        let s = store();
        let v = s.mat2_view("w").unwrap();
        assert_eq!((v.rows, v.cols), (2, 2));
        assert_eq!(v.row(1), &[3.0, 4.0]);
        assert_eq!(v.data, s.mat2("w").unwrap().data.as_slice());
    }

    #[test]
    fn synthetic_store_covers_encoder_tensors() {
        let cfg = ViTConfig::default();
        let s = synthetic_vit_store(&cfg, 1);
        assert_eq!(s.mat2("vit.embed.w").unwrap().rows, cfg.patch_dim());
        assert_eq!(s.vec1("vit.cls").unwrap().len(), cfg.dim);
        assert_eq!(s.mat2("vit.pos").unwrap().rows, cfg.n_tokens());
        for l in 0..cfg.depth {
            assert_eq!(s.mat2(&format!("vit.blk{l}.wq")).unwrap().cols, cfg.dim);
            assert_eq!(s.mat2(&format!("vit.blk{l}.mlp1")).unwrap().cols,
                       cfg.mlp_hidden());
        }
        assert_eq!(s.mat2("vit.head.w").unwrap().cols, cfg.num_classes);
        // deterministic in seed
        let s2 = synthetic_vit_store(&cfg, 1);
        assert_eq!(s.flat, s2.flat);
    }

    #[test]
    fn synthetic_bert_store_covers_text_tensors() {
        let cfg = crate::config::TextConfig::default();
        let s = synthetic_bert_store(&cfg, 2);
        assert_eq!(s.mat2("bert.tok").unwrap().rows, cfg.vocab_size);
        assert_eq!(s.mat2("bert.pos").unwrap().rows, cfg.n_tokens());
        for l in 0..cfg.depth {
            assert_eq!(s.mat2(&format!("bert.blk{l}.wq")).unwrap().cols,
                       cfg.dim);
        }
        assert_eq!(s.vec1("bert.lnf.w").unwrap().len(), cfg.dim);
        assert_eq!(s.mat2("bert.head.w").unwrap().cols, cfg.num_classes);
    }

    #[test]
    fn synthetic_mm_store_covers_all_towers() {
        use crate::data::{CAP_LEN, N_ANSWERS, VOCAB};
        let cfg = ViTConfig::default();
        let s = synthetic_mm_store(&cfg, 3);
        // vit prefix is bit-identical to the vision-only store
        let vit = synthetic_vit_store(&cfg, 3);
        assert_eq!(&s.flat[..vit.flat.len()], &vit.flat[..]);
        assert_eq!(s.slice("vit.head.b").unwrap(),
                   vit.slice("vit.head.b").unwrap());
        // clip tower + projections
        assert_eq!(s.mat2("txt.tok").unwrap().rows, VOCAB);
        assert_eq!(s.mat2("txt.pos").unwrap().rows, CAP_LEN + 1);
        assert_eq!(s.mat2("proj.img").unwrap().rows, cfg.dim);
        assert_eq!(s.mat2("proj.txt").unwrap().cols, MM_TEXT_DIM);
        // vqa tower + joint head
        assert_eq!(s.mat2(&format!("q.blk{}.mlp1", MM_TEXT_DEPTH - 1))
                       .unwrap().cols, MM_TEXT_DIM * 2);
        assert_eq!(s.mat2("vqa.fc1").unwrap().rows, cfg.dim + MM_TEXT_DIM);
        assert_eq!(s.mat2("vqa.head.w").unwrap().cols, N_ANSWERS);
        assert_eq!(s.vec1("vqa.head.b").unwrap().len(), N_ANSWERS);
        // bert classifier at default text shapes
        let tcfg = crate::config::TextConfig::default();
        assert_eq!(s.mat2("bert.tok").unwrap().rows, tcfg.vocab_size);
        assert_eq!(s.mat2("bert.head.w").unwrap().cols, tcfg.num_classes);
    }
}
