//! CPU reference ViT classifier / feature extractor over [`ParamStore`].
//!
//! The batch methods are deprecated shims: hot callers hold a
//! [`crate::engine::VitSession`] (one per worker), which runs the same
//! pipeline through pooled buffers and never re-resolves weights.

use crate::config::ViTConfig;
use crate::data::Rng;
use crate::error::Result;
use crate::tensor::{dense, Mat};

#[allow(deprecated)]
use super::encoder::encoder_forward_batch_pooled;
use super::encoder::{encoder_forward, EncoderCfg, ScratchPool};
use super::params::ParamStore;

/// A loaded ViT model (weights + config).
pub struct ViTModel<'a> {
    /// parameter store
    pub ps: &'a ParamStore,
    /// config (merge mode / r live here)
    pub cfg: ViTConfig,
}

impl<'a> ViTModel<'a> {
    /// Wrap a parameter store with a config.
    pub fn new(ps: &'a ParamStore, cfg: ViTConfig) -> Self {
        ViTModel { ps, cfg }
    }

    fn encoder_cfg(&self) -> EncoderCfg {
        EncoderCfg::from_vit(&self.cfg)
    }

    /// Patch embed + CLS + positional embedding for one sample.
    pub fn tokens(&self, patches: &Mat) -> Result<Mat> {
        let emb = dense(patches, &self.ps.mat2("vit.embed.w")?,
                        Some(self.ps.vec1("vit.embed.b")?));
        let cls = self.ps.vec1("vit.cls")?;
        let pos = self.ps.mat2("vit.pos")?;
        let n = emb.rows + 1;
        let mut x = Mat::zeros(n, self.cfg.dim);
        x.row_mut(0).copy_from_slice(cls);
        for i in 0..emb.rows {
            x.row_mut(i + 1).copy_from_slice(emb.row(i));
        }
        for i in 0..n {
            let r = x.row_mut(i);
            let p = pos.row(i);
            for j in 0..r.len() {
                r[j] += p[j];
            }
        }
        Ok(x)
    }

    /// CLS feature for one sample (patches: (n_patches, patch_dim)).
    pub fn features(&self, patches: &Mat, rng: &mut Rng) -> Result<Vec<f32>> {
        let x = self.tokens(patches)?;
        let out = encoder_forward(self.ps, &self.encoder_cfg(), x, rng)?;
        Ok(out.row(0).to_vec())
    }

    /// Class logits for one sample.
    pub fn logits(&self, patches: &Mat, rng: &mut Rng) -> Result<Vec<f32>> {
        let f = self.features(patches, rng)?;
        let fm = Mat::from_vec(1, f.len(), f);
        let lg = dense(&fm, &self.ps.mat2("vit.head.w")?,
                       Some(self.ps.vec1("vit.head.b")?));
        Ok(lg.data)
    }

    /// Predicted class for one sample.
    pub fn predict(&self, patches: &Mat, rng: &mut Rng) -> Result<usize> {
        let lg = self.logits(patches, rng)?;
        Ok(crate::tensor::argmax(&lg))
    }

    /// Batched CLS features with a caller-owned scratch pool: samples fan
    /// out over `workers` threads, each worker reusing one
    /// `EncoderScratch` from `pool` (see
    /// [`encoder_forward_batch_pooled`]).  Long-lived servers keep the
    /// pool alive across batches so steady state allocates no encoder
    /// buffers.
    #[deprecated(note = "hold a `crate::engine::VitSession` (one per \
                         worker) instead")]
    #[allow(deprecated)]
    pub fn features_batch_pooled(&self, patches: &[Mat], seed: u64,
                                 workers: usize, pool: &mut ScratchPool)
                                 -> Result<Vec<Vec<f32>>> {
        let xs: Vec<Mat> =
            patches.iter().map(|p| self.tokens(p)).collect::<Result<_>>()?;
        let outs = encoder_forward_batch_pooled(self.ps, &self.encoder_cfg(),
                                                xs, seed, workers, pool)?;
        Ok(outs.into_iter().map(|m| m.row(0).to_vec()).collect())
    }

    /// Batched CLS features (transient scratch pool).
    #[deprecated(note = "hold a `crate::engine::VitSession` (one per \
                         worker) instead")]
    #[allow(deprecated)]
    pub fn features_batch(&self, patches: &[Mat], seed: u64, workers: usize)
                          -> Result<Vec<Vec<f32>>> {
        let mut pool = ScratchPool::new();
        self.features_batch_pooled(patches, seed, workers, &mut pool)
    }

    /// Batched class logits with a caller-owned scratch pool.
    #[deprecated(note = "hold a `crate::engine::VitSession` (one per \
                         worker) instead")]
    #[allow(deprecated)]
    pub fn logits_batch_pooled(&self, patches: &[Mat], seed: u64,
                               workers: usize, pool: &mut ScratchPool)
                               -> Result<Vec<Vec<f32>>> {
        let feats = self.features_batch_pooled(patches, seed, workers, pool)?;
        let w = self.ps.mat2("vit.head.w")?;
        let b = self.ps.vec1("vit.head.b")?;
        Ok(feats
            .into_iter()
            .map(|f| {
                let fm = Mat::from_vec(1, f.len(), f);
                dense(&fm, &w, Some(b)).data
            })
            .collect())
    }

    /// Batched class logits (transient scratch pool).
    #[deprecated(note = "hold a `crate::engine::VitSession` (one per \
                         worker) instead")]
    #[allow(deprecated)]
    pub fn logits_batch(&self, patches: &[Mat], seed: u64, workers: usize)
                        -> Result<Vec<Vec<f32>>> {
        let mut pool = ScratchPool::new();
        self.logits_batch_pooled(patches, seed, workers, &mut pool)
    }

    /// Batched predictions with a caller-owned scratch pool.
    #[deprecated(note = "hold a `crate::engine::VitSession` (one per \
                         worker) instead")]
    #[allow(deprecated)]
    pub fn predict_batch_pooled(&self, patches: &[Mat], seed: u64,
                                workers: usize, pool: &mut ScratchPool)
                                -> Result<Vec<usize>> {
        Ok(self
            .logits_batch_pooled(patches, seed, workers, pool)?
            .iter()
            .map(|lg| crate::tensor::argmax(lg))
            .collect())
    }

    /// Batched predictions (transient scratch pool).
    #[deprecated(note = "hold a `crate::engine::VitSession` (one per \
                         worker) instead")]
    #[allow(deprecated)]
    pub fn predict_batch(&self, patches: &[Mat], seed: u64, workers: usize)
                         -> Result<Vec<usize>> {
        let mut pool = ScratchPool::new();
        self.predict_batch_pooled(patches, seed, workers, &mut pool)
    }
}
