//! Analytic FLOPs cost model for a transformer under a merge schedule —
//! reproduces the paper's FLOPs columns and the x-axes of Figs. 3/6
//! (complexity analysis of App. B.3).

use crate::config::ViTConfig;

/// FLOPs of one transformer block on `n` tokens (fwd pass, mults+adds).
///
/// qkv+proj: 4 * 2 n d^2; attention: 2 * 2 n^2 d; mlp: 2 * 2 n d d_mlp.
pub fn block_flops(n: usize, dim: usize, mlp_hidden: usize) -> f64 {
    let n = n as f64;
    let d = dim as f64;
    let dm = mlp_hidden as f64;
    8.0 * n * d * d + 4.0 * n * n * d + 4.0 * n * d * dm
}

/// FLOPs of one PiToMe/BSM merge step on `n` tokens (Gram + reduction;
/// App. B.2: O(n^2 h) dominated).
pub fn merge_flops(n: usize, dim: usize) -> f64 {
    let n = n as f64;
    let d = dim as f64;
    2.0 * n * n * d + 4.0 * n * n
}

/// Total fwd FLOPs of an encoder following a static token plan.
pub fn encoder_flops(plan: &[usize], dim: usize, mlp_hidden: usize,
                     with_merge: bool) -> f64 {
    let depth = plan.len() - 1;
    let mut total = 0.0;
    for l in 0..depth {
        total += block_flops(plan[l], dim, mlp_hidden);
        if with_merge && plan[l + 1] < plan[l] {
            total += merge_flops(plan[l], dim);
        }
    }
    total
}

/// GFLOPs for a ViT config (incl. patch embed + head, which are small).
pub fn vit_gflops(cfg: &ViTConfig) -> f64 {
    let plan = cfg.plan();
    let enc = encoder_flops(&plan, cfg.dim, cfg.mlp_hidden(),
                            cfg.mode() != crate::merge::MergeMode::None);
    let embed = 2.0 * cfg.num_patches() as f64 * cfg.patch_dim() as f64
        * cfg.dim as f64;
    let head = 2.0 * cfg.dim as f64 * cfg.num_classes as f64;
    (enc + embed + head) / 1e9
}

/// FLOPs ratio vs the uncompressed model (paper reports e.g. "x2.1").
pub fn flops_speedup(cfg: &ViTConfig) -> f64 {
    let mut base = cfg.clone();
    base.merge_mode = "none".into();
    base.merge_r = 1.0;
    vit_gflops(&base) / vit_gflops(cfg)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn merging_reduces_flops() {
        let base = ViTConfig::preset("deit-s").unwrap();
        let mut merged = base.clone();
        merged.merge_mode = "pitome".into();
        merged.merge_r = 0.9;
        assert!(vit_gflops(&merged) < vit_gflops(&base));
        assert!(flops_speedup(&merged) > 1.2);
    }

    #[test]
    fn deit_s_flops_magnitude_matches_paper() {
        // paper Table 6: ViT-DEIT-S = 4.6 GFLOPs. Our analytic count should
        // land in the same ballpark (2x tolerance: papers count MACs
        // differently).
        let g = vit_gflops(&ViTConfig::preset("deit-s").unwrap());
        assert!(g > 2.0 && g < 12.0, "deit-s gflops {g}");
    }

    #[test]
    fn r_09_speedup_near_paper_ratio() {
        // paper: r=0.9-ish schedules give ~x1.5-2.1 FLOPs reduction on
        // 12-layer backbones.
        let mut c = ViTConfig::preset("deit-s").unwrap();
        c.merge_mode = "pitome".into();
        c.merge_r = 0.9;
        let s = flops_speedup(&c);
        assert!(s > 1.3 && s < 3.0, "speedup {s}");
    }

    #[test]
    fn quadratic_term_dominates_large_n() {
        let f1 = block_flops(1000, 64, 128);
        let f2 = block_flops(2000, 64, 128);
        assert!(f2 / f1 > 3.0); // superlinear
    }
}
