//! CPU reference transformer encoder with in-block token merging.
//!
//! Numerically mirrors `python/compile/model.py::encoder_forward`; the
//! parity is asserted against `artifacts/testvectors.json` (trained ViT
//! logits) and used for the r-sweep experiments where compiling one HLO
//! artifact per (mode, r) point would be wasteful.
//!
//! Two drivers share the same per-block helpers (so they are numerically
//! identical):
//! * [`encoder_forward`] — one sample, serial.
//! * [`encoder_forward_batch`] — a batch of samples advanced layer by
//!   layer; attention/MLP fan out per sample over scoped worker threads
//!   and the merge step goes through
//!   [`merge_step_batch`](crate::merge::batch::merge_step_batch), so the
//!   whole batch shares the thread pool while each sequence still builds
//!   exactly one cosine Gram per step.

use crate::data::Rng;
use crate::error::Result;
use crate::merge::batch::{merge_step_batch, parallel_map_mut, BatchSeq};
use crate::merge::energy::layer_margin;
use crate::merge::{merge_step, MergeCtx, MergeMode};
use crate::tensor::{add_inplace, dense, gelu_inplace, layernorm, matmul,
                    softmax_rows, Mat};

use super::params::ParamStore;

/// Encoder hyperparameters (subset shared by ViT and text models).
#[derive(Clone, Debug)]
pub struct EncoderCfg {
    /// parameter-name prefix, e.g. "vit."
    pub prefix: String,
    /// embedding dim
    pub dim: usize,
    /// depth
    pub depth: usize,
    /// heads
    pub heads: usize,
    /// merge mode
    pub mode: MergeMode,
    /// static token plan (len depth+1)
    pub plan: Vec<usize>,
    /// proportional attention
    pub prop_attn: bool,
    /// ToFu prune threshold (see `config::DEFAULT_TOFU_PRUNE_THRESHOLD`)
    pub tofu_threshold: f32,
}

/// Multi-head proportional attention for one sample.
///
/// q, kf, v: (n, dim) pre-split projections; sizes: len n.
/// Returns (attn output (n, dim), mean CLS attention over heads (n,)).
pub fn attention(q: &Mat, kf: &Mat, v: &Mat, sizes: &[f32], heads: usize,
                 prop_attn: bool) -> (Mat, Vec<f32>) {
    let n = q.rows;
    let dim = q.cols;
    let d = dim / heads;
    let scale = 1.0 / (d as f32).sqrt();
    let log_m: Vec<f32> = if prop_attn {
        sizes.iter().map(|&s| s.max(1e-9).ln()).collect()
    } else {
        vec![0.0; n]
    };
    let mut out = Mat::zeros(n, dim);
    let mut attn_cls = vec![0f32; n];
    // per-head blocked views into the (n, dim) projections
    for hh in 0..heads {
        let col0 = hh * d;
        // scores = qh @ kh^T * scale + log m
        let mut s = Mat::zeros(n, n);
        for i in 0..n {
            let qi = &q.row(i)[col0..col0 + d];
            for j in 0..n {
                let kj = &kf.row(j)[col0..col0 + d];
                let mut dot = 0f32;
                for c in 0..d {
                    dot += qi[c] * kj[c];
                }
                s.set(i, j, dot * scale + log_m[j]);
            }
        }
        // CLS attention uses the *unbiased* logits, matching model.py
        {
            let mut row0 = vec![0f32; n];
            for j in 0..n {
                row0[j] = s.get(0, j) - log_m[j];
            }
            let mx = row0.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let mut sum = 0f32;
            for vj in row0.iter_mut() {
                *vj = (*vj - mx).exp();
                sum += *vj;
            }
            for (a, vj) in attn_cls.iter_mut().zip(&row0) {
                *a += vj / sum / heads as f32;
            }
        }
        softmax_rows(&mut s);
        // out_h = p @ vh
        for i in 0..n {
            let orow = out.row_mut(i);
            for j in 0..n {
                let p = s.get(i, j);
                if p == 0.0 {
                    continue;
                }
                let vj = &v.row(j)[col0..col0 + d];
                for c in 0..d {
                    orow[col0 + c] += p * vj[c];
                }
            }
        }
    }
    (out, attn_cls)
}

/// Attention half of block `l`: pre-LN, QKV, proportional attention,
/// output projection, residual add (in place).  Returns the key features
/// (the merge similarity signal) and the mean CLS attention.
fn block_attention(ps: &ParamStore, cfg: &EncoderCfg, l: usize, x: &mut Mat,
                   sizes: &[f32]) -> Result<(Mat, Vec<f32>)> {
    let b = format!("{}blk{}.", cfg.prefix, l);
    let h = layernorm(x, ps.vec1(&format!("{b}ln1.w"))?,
                      ps.vec1(&format!("{b}ln1.b"))?, 1e-5);
    let q = matmul(&h, &ps.mat2(&format!("{b}wq"))?);
    let kf = matmul(&h, &ps.mat2(&format!("{b}wk"))?);
    let v = matmul(&h, &ps.mat2(&format!("{b}wv"))?);

    let attn_sizes: Vec<f32> = if cfg.prop_attn {
        sizes.to_vec()
    } else {
        vec![1.0; x.rows]
    };
    let (o, attn_cls) = attention(&q, &kf, &v, &attn_sizes, cfg.heads,
                                  cfg.prop_attn);
    let proj = dense(&o, &ps.mat2(&format!("{b}wo"))?,
                     Some(ps.vec1(&format!("{b}bo"))?));
    add_inplace(x, &proj);
    Ok((kf, attn_cls))
}

/// MLP half of block `l`: pre-LN, GELU MLP, residual add (in place).
fn block_mlp(ps: &ParamStore, cfg: &EncoderCfg, l: usize, x: &mut Mat)
             -> Result<()> {
    let b = format!("{}blk{}.", cfg.prefix, l);
    let h2 = layernorm(x, ps.vec1(&format!("{b}ln2.w"))?,
                       ps.vec1(&format!("{b}ln2.b"))?, 1e-5);
    let mut m = dense(&h2, &ps.mat2(&format!("{b}mlp1"))?,
                      Some(ps.vec1(&format!("{b}mlp1b"))?));
    gelu_inplace(&mut m);
    let m2 = dense(&m, &ps.mat2(&format!("{b}mlp2"))?,
                   Some(ps.vec1(&format!("{b}mlp2b"))?));
    add_inplace(x, &m2);
    Ok(())
}

fn final_norm(ps: &ParamStore, cfg: &EncoderCfg, x: &Mat) -> Result<Mat> {
    Ok(layernorm(x,
                 ps.vec1(&format!("{}lnf.w", cfg.prefix))?,
                 ps.vec1(&format!("{}lnf.b", cfg.prefix))?, 1e-5))
}

/// Run the encoder on one sample `x` (plan[0], dim). Returns final tokens
/// (plan[depth], dim) after the output LayerNorm.
pub fn encoder_forward(ps: &ParamStore, cfg: &EncoderCfg, x: Mat,
                       rng: &mut Rng) -> Result<Mat> {
    let mut x = x;
    let mut sizes = vec![1f32; x.rows];
    for l in 0..cfg.depth {
        let n_in = cfg.plan[l];
        let n_out = cfg.plan[l + 1];
        debug_assert_eq!(x.rows, n_in, "plan mismatch at layer {l}");

        let (kf, attn_cls) = block_attention(ps, cfg, l, &mut x, &sizes)?;

        // merge between attention and MLP (Eq. 2)
        let k = n_in - n_out;
        if k > 0 {
            let margin = layer_margin(l, cfg.depth);
            let ctx = MergeCtx {
                x: &x,
                kf: &kf,
                sizes: &sizes,
                attn_cls: &attn_cls,
                margin,
                k,
                protect_first: 1,
                tofu_threshold: cfg.tofu_threshold,
            };
            let (xm, sm) = merge_step(cfg.mode, &ctx, rng);
            x = xm;
            sizes = sm;
        }

        block_mlp(ps, cfg, l, &mut x)?;
    }
    final_norm(ps, cfg, &x)
}

/// Per-sequence state carried across layers by the batch driver.
struct SeqState {
    x: Mat,
    sizes: Vec<f32>,
}

/// Run the encoder on a batch of samples, advancing all sequences layer by
/// layer.  Attention and MLP fan out per sample over up to `workers`
/// scoped threads; the merge step runs through
/// [`merge_step_batch`](crate::merge::batch::merge_step_batch).
///
/// `seed` derives one deterministic RNG seed per (layer, sample), so
/// stochastic modes are reproducible under any thread schedule; for the
/// deterministic modes (PiToMe/ToMe/ToFu/DCT/DiffRate) the outputs match
/// [`encoder_forward`] exactly.
pub fn encoder_forward_batch(ps: &ParamStore, cfg: &EncoderCfg, xs: Vec<Mat>,
                             seed: u64, workers: usize) -> Result<Vec<Mat>> {
    let mut states: Vec<SeqState> = xs
        .into_iter()
        .map(|x| {
            let sizes = vec![1f32; x.rows];
            SeqState { x, sizes }
        })
        .collect();
    for l in 0..cfg.depth {
        let n_in = cfg.plan[l];
        let n_out = cfg.plan[l + 1];
        let k = n_in - n_out;

        let pre = parallel_map_mut(&mut states, workers, &|_, st: &mut SeqState| {
            debug_assert_eq!(st.x.rows, n_in, "plan mismatch at layer {l}");
            block_attention(ps, cfg, l, &mut st.x, &st.sizes)
        });
        let mut kfs = Vec::with_capacity(states.len());
        let mut attns = Vec::with_capacity(states.len());
        for r in pre {
            let (kf, attn_cls) = r?;
            kfs.push(kf);
            attns.push(attn_cls);
        }

        if k > 0 {
            let margin = layer_margin(l, cfg.depth);
            let merged = {
                let seqs: Vec<BatchSeq> = states
                    .iter()
                    .zip(kfs.iter())
                    .zip(attns.iter())
                    .enumerate()
                    .map(|(i, ((st, kf), attn_cls))| BatchSeq {
                        ctx: MergeCtx {
                            x: &st.x,
                            kf,
                            sizes: &st.sizes,
                            attn_cls,
                            margin,
                            k,
                            protect_first: 1,
                            tofu_threshold: cfg.tofu_threshold,
                        },
                        seed: seed ^ ((l as u64) << 32) ^ i as u64,
                    })
                    .collect();
                merge_step_batch(cfg.mode, &seqs, workers)
            };
            for (st, (xm, sm)) in states.iter_mut().zip(merged) {
                st.x = xm;
                st.sizes = sm;
            }
        }

        let post = parallel_map_mut(&mut states, workers, &|_, st: &mut SeqState| {
            block_mlp(ps, cfg, l, &mut st.x)
        });
        for r in post {
            r?;
        }
    }
    states
        .iter()
        .map(|st| final_norm(ps, cfg, &st.x))
        .collect()
}

/// Plain (non-proportional) attention convenience used in tests.
pub fn plain_attention(q: &Mat, kf: &Mat, v: &Mat, heads: usize) -> Mat {
    let ones = vec![1.0; q.rows];
    attention(q, kf, v, &ones, heads, true).0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ViTConfig;
    use crate::model::params::synthetic_vit_store;

    #[test]
    fn attention_rows_are_convex_combinations() {
        let mut rng = Rng::new(2);
        let n = 7;
        let q = Mat::from_fn(n, 8, |_, _| (rng.next_f64() * 2.0 - 1.0) as f32);
        let kf = Mat::from_fn(n, 8, |_, _| (rng.next_f64() * 2.0 - 1.0) as f32);
        let v = Mat::from_fn(n, 8, |_, _| (rng.next_f64() * 2.0 - 1.0) as f32);
        let (o, attn_cls) = attention(&q, &kf, &v, &vec![1.0; n], 2, true);
        assert_eq!(o.rows, n);
        let s: f32 = attn_cls.iter().sum();
        assert!((s - 1.0).abs() < 1e-4, "cls attn sums to {s}");
        // each output coordinate within v's column bounds per head block
        for c in 0..8 {
            let cmax = (0..n).map(|i| v.get(i, c)).fold(f32::MIN, f32::max);
            let cmin = (0..n).map(|i| v.get(i, c)).fold(f32::MAX, f32::min);
            for i in 0..n {
                assert!(o.get(i, c) <= cmax + 1e-5);
                assert!(o.get(i, c) >= cmin - 1e-5);
            }
        }
    }

    #[test]
    fn size_bias_shifts_attention() {
        let n = 5;
        let q = Mat::from_fn(n, 4, |_, _| 1.0);
        let kf = Mat::zeros(n, 4); // uniform logits
        let v = Mat::from_fn(n, 4, |i, j| if i == 3 && j == 0 { 10.0 } else { 0.0 });
        let mut sizes = vec![1.0; n];
        sizes[3] = 1e6;
        let (o, _) = attention(&q, &kf, &v, &sizes, 1, true);
        assert!(o.get(0, 0) > 9.0, "huge token dominates: {}", o.get(0, 0));
    }

    #[test]
    fn batch_forward_matches_serial_forward() {
        let vcfg = ViTConfig {
            merge_mode: "pitome".into(),
            merge_r: 0.9,
            ..Default::default()
        };
        let ps = synthetic_vit_store(&vcfg, 42);
        let cfg = EncoderCfg {
            prefix: "vit.".into(),
            dim: vcfg.dim,
            depth: vcfg.depth,
            heads: vcfg.heads,
            mode: vcfg.mode(),
            plan: vcfg.plan(),
            prop_attn: true,
            tofu_threshold: vcfg.tofu_threshold,
        };
        let n0 = cfg.plan[0];
        let mut rng = Rng::new(9);
        let xs: Vec<Mat> = (0..5)
            .map(|_| Mat::from_fn(n0, cfg.dim,
                                  |_, _| (rng.next_f64() * 0.2 - 0.1) as f32))
            .collect();
        let batched =
            encoder_forward_batch(&ps, &cfg, xs.clone(), 0, 3).unwrap();
        for (i, x) in xs.into_iter().enumerate() {
            let mut r = Rng::new(0);
            let want = encoder_forward(&ps, &cfg, x, &mut r).unwrap();
            assert_eq!(batched[i].rows, want.rows);
            assert!(batched[i].max_abs_diff(&want) < 1e-5,
                    "sample {i} diverged: {}", batched[i].max_abs_diff(&want));
        }
    }
}
